"""End-to-end driver: train a transformer LM with CL-SIA gradient
aggregation (the paper's best algorithm) as the data-parallel collective.

Default is a CPU-friendly ~3M-param model for a few hundred steps; pass
--params 100m for the full-size run (same code path — the 100M config
simply takes hours on CPU).

    PYTHONPATH=src python examples/train_lm_sia.py --steps 200
    PYTHONPATH=src python examples/train_lm_sia.py --params 100m --steps 300
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro import compat
from repro.configs.base import ModelConfig
from repro.core.algorithms import AggConfig, AggKind
from repro.data.synthetic import lm_batch, make_bigram_lm
from repro.launch.mesh import make_mesh
from repro.optim.optimizers import OptConfig
from repro.train.state import TrainConfig
from repro.train.step import build_train_step, init_state, state_shardings

CONFIGS = {
    "3m": ModelConfig(name="lm-3m", family="dense", num_layers=4,
                      d_model=128, num_heads=4, num_kv_heads=2, d_ff=512,
                      vocab_size=512, head_dim=32, param_dtype="float32"),
    "100m": ModelConfig(name="lm-100m", family="dense", num_layers=12,
                        d_model=768, num_heads=12, num_kv_heads=4,
                        d_ff=3072, vocab_size=32000, head_dim=64,
                        param_dtype="float32"),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--params", choices=list(CONFIGS), default="3m")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--q-frac", type=float, default=0.01)
    args = ap.parse_args()

    cfg = CONFIGS[args.params]
    mesh = make_mesh((len(jax.devices()), 1), ("data", "model"))
    tc = TrainConfig(agg=AggConfig(kind=AggKind.CL_SIA, q=1),
                     opt=OptConfig(name="adamw", lr=1e-3, grad_clip=1.0),
                     q_frac=args.q_frac, agg_dtype="float32",
                     ef_dtype="float32", lr_warmup=20)

    with compat.set_mesh(mesh):
        state = jax.device_put(
            init_state(cfg, tc, mesh, jax.random.PRNGKey(0)),
            state_shardings(cfg, tc, mesh))
        step = jax.jit(build_train_step(cfg, tc, mesh))
        lm = make_bigram_lm(jax.random.PRNGKey(7), cfg.vocab_size)
        key = jax.random.PRNGKey(1)
        t0 = time.time()
        for i in range(args.steps):
            key, kb = jax.random.split(key)
            state, m = step(state, lm_batch(lm, kb, args.batch, args.seq))
            if i % 20 == 0 or i == args.steps - 1:
                print(f"step {i:4d} loss {float(m['loss']):.4f} "
                      f"uplink {float(m['agg_bits'])/8e6:.2f} MB "
                      f"({time.time()-t0:.0f}s)")
        # a bigram LM's optimal CE is well below the unigram entropy —
        # verify we actually learned structure
        print(f"final loss {float(m['loss']):.4f} "
              f"(uniform would be {float(jnp.log(cfg.vocab_size)):.2f})")


if __name__ == "__main__":
    main()
