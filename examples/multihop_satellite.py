"""The paper's motivating scenario: a satellite constellation chain with
link failures and stragglers (DESIGN §6).

A K=12 chain trains while: (a) random compute stragglers miss round
deadlines (their updates bank into error feedback and arrive later);
(b) a relay dies at round 30 and the chain heals around it; (c) it
recovers at round 60. Communication stays CL-SIA-constant throughout.

    PYTHONPATH=src python examples/multihop_satellite.py
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import PAPER
from repro.core.algorithms import AggConfig, AggKind
from repro.data.federated import partition_iid
from repro.data.synthetic import make_synthetic_mnist
from repro.fed.simulator import Simulator
from repro.runtime.fault import StragglerModel, banked_mass
from repro.fed.topology import FailureSchedule

K, ROUNDS = 12, 90
pc = dataclasses.replace(PAPER, num_clients=K)

train = make_synthetic_mnist(jax.random.PRNGKey(0), K * 150)
test = make_synthetic_mnist(jax.random.PRNGKey(1), 1000)
fed = partition_iid(jax.random.PRNGKey(2), train, K)

sim = Simulator(pc, AggConfig(kind=AggKind.CL_SIA, q=pc.q), fed,
                local_lr=pc.lr)
stragglers = StragglerModel(p_straggle=0.15)
failures = FailureSchedule(K, {30: ([5], []), 60: ([], [5])})


def participate_fn(r, state):
    mask = np.array(stragglers.sample(jax.random.PRNGKey(9000 + r), K))
    for dead in failures.dead_at(r):
        mask[dead] = 0.0          # dead node contributes nothing
    return jnp.asarray(mask)


out = sim.run(ROUNDS, test_x=test.x, test_y=test.y, eval_every=10,
              participate_fn=participate_fn)

print("round  acc    (relay 5 dead rounds 30-59; 15% stragglers/round)")
for r, acc in out["accuracy"]:
    marker = "  ← node 5 down" if 30 <= r < 60 else ""
    print(f"{r:5d}  {acc:.3f}{marker}")
bm = banked_mass(out["state"].ef)
print(f"\nbits/round stayed {out['bits'][-1]/1e3:.1f} kbit "
      f"(CL-SIA constant-length property)")
print(f"banked |e| per node: {[f'{float(x):.1f}' for x in bm]}")
print("note: node 5's queued mass transmits after recovery — error "
      "feedback doubles as the straggler/failure recovery mechanism.")
