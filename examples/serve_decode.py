"""Serve a small model with batched requests: prefill + decode loop,
reporting tokens/s and the shape of the KV-cache working set.

    PYTHONPATH=src python examples/serve_decode.py --arch mixtral-8x7b
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro import compat
from repro.configs import ARCHS, get_config
from repro.launch.mesh import make_mesh
from repro.models import model as model_mod


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS, default="mixtral-8x7b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)
    mesh = make_mesh((len(jax.devices()), 1), ("data", "model"))
    max_len = args.prompt_len + args.gen

    with compat.set_mesh(mesh):
        params = model_mod.init_params(cfg, jax.random.PRNGKey(0))
        cache = model_mod.init_cache(cfg, args.batch, max_len)
        cache_bytes = sum(l.nbytes for l in jax.tree.leaves(cache))
        prompts = jax.random.randint(jax.random.PRNGKey(1),
                                     (args.batch, args.prompt_len), 0,
                                     cfg.vocab_size)
        prefill = jax.jit(lambda p, t, c: model_mod.prefill(cfg, p, t, c))
        decode = jax.jit(
            lambda p, c, t, pos: model_mod.decode_step(cfg, p, c, t, pos))

        logits, cache = prefill(params, prompts, cache)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        toks = [tok]
        t0 = time.time()
        for i in range(args.gen - 1):
            logits, cache = decode(params, cache, tok,
                                   jnp.int32(args.prompt_len + i))
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
            toks.append(tok)
        jax.block_until_ready(tok)
        dt = time.time() - t0
        print(f"{cfg.name}: batch={args.batch}, KV cache "
              f"{cache_bytes/1e6:.1f} MB"
              + (f" (SWA ring buffer, window={cfg.sliding_window})"
                 if cfg.sliding_window else ""))
        print(f"decode: {args.batch*(args.gen-1)/dt:.1f} tok/s "
              f"({dt*1000/(args.gen-1):.1f} ms/step)")
        print("first request's tokens:",
              [int(t[0]) for t in toks[:12]], "...")


if __name__ == "__main__":
    main()
