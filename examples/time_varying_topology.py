"""Time-varying LEO topology demo: per-round re-routing, one compilation.

A 3×4 Walker-delta shell trains the paper's MNIST logistic model while its
ISLs churn: the gateway's inter-plane link drops out mid-training (occlusion
/ handover), forcing every affected satellite onto longer routes, then comes
back. A second periodic schedule re-routes every round by cycling the
routing policy's view of the constellation.

The point of the plan/execute API: all of these routes compile into
``AggPlan``s padded to ONE (L, W) level-schedule shape, so the jitted round
is traced exactly once no matter how often the topology changes —
previously each distinct tree was its own specialization.

    PYTHONPATH=src python examples/time_varying_topology.py
"""

import dataclasses

import jax

from repro.agg import TopologySchedule
from repro.configs import PAPER
from repro.core.algorithms import AggConfig, AggKind
from repro.data.federated import partition_iid
from repro.data.synthetic import make_synthetic_mnist
from repro.fed.simulator import Simulator
from repro.topo.graph import walker_delta

ROUNDS = 60
g = walker_delta(3, 4, gateways=(1, 7))
K = g.num_clients
pc = dataclasses.replace(PAPER, num_clients=K)

train = make_synthetic_mnist(jax.random.PRNGKey(0), K * 150)
test = make_synthetic_mnist(jax.random.PRNGKey(1), 1000)
fed = partition_iid(jax.random.PRNGKey(2), train, K)

# Link timeline: at round 20 the seam ISL (1, 5) and the intra-plane link
# (1, 2) drop — satellite 1 keeps only its remaining ring/ground links and
# its neighborhood re-routes; both links recover at round 40.
events = {20: ([(1, 5), (1, 2)], []), 40: ([], [(1, 5), (1, 2)])}
sched = TopologySchedule.from_link_events(g, events, rounds=ROUNDS,
                                          routing="widest")
print(f"link-event schedule: {len(sched.plans)} distinct routed trees over "
      f"{ROUNDS} rounds, all padded to (L, W) = {sched.shape}")
print("→ the jitted round specializes once on that shape; every re-route "
      "is a host-side plan swap\n")

sim = Simulator(pc, AggConfig(kind=AggKind.CL_SIA, q=pc.q), fed,
                local_lr=pc.lr)
out = sim.run(ROUNDS, test_x=test.x, test_y=test.y, eval_every=10,
              topology_schedule=sched)

print("round  acc    (ISLs (1,5) and (1,2) down rounds 20-39)")
for r, acc in out["accuracy"]:
    marker = "  ← re-routed around lost ISLs" if 20 <= r < 40 else ""
    print(f"{r:5d}  {acc:.3f}{marker}")
print(f"\nbits/round stayed {out['bits'][-1] / 1e3:.1f} kbit "
      f"(CL-SIA constant-length, route-invariant)")
