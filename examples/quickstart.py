"""Quickstart: the paper in 40 lines.

Train a d=7850 logistic-regression over a K=10 multi-hop chain with each
of the five sparse-IA algorithms and print accuracy + exact uplink bits.

    PYTHONPATH=src python examples/quickstart.py
"""

import dataclasses

import jax

from repro.configs import PAPER
from repro.core.algorithms import AggConfig, AggKind
from repro.data.federated import partition_iid
from repro.data.synthetic import make_synthetic_mnist
from repro.fed.simulator import Simulator

K, ROUNDS = 10, 80
pc = dataclasses.replace(PAPER, num_clients=K)

train = make_synthetic_mnist(jax.random.PRNGKey(0), K * 150)
test = make_synthetic_mnist(jax.random.PRNGKey(1), 1000)
fed = partition_iid(jax.random.PRNGKey(2), train, K)

print(f"K={K} clients on a chain, d={pc.d}, Q={pc.q} (1% of d)\n")
print(f"{'algorithm':12s} {'test acc':>8s} {'kbit/round':>11s} "
      f"{'vs dense IA':>11s}")
dense_bits = K * pc.d * pc.omega
for kind in (AggKind.SIA, AggKind.RE_SIA, AggKind.CL_SIA, AggKind.TC_SIA,
             AggKind.CL_TC_SIA, AggKind.DENSE_IA):
    agg = AggConfig(kind=kind, q=pc.q, q_global=pc.q_global,
                    q_local=pc.q_local)
    sim = Simulator(pc, agg, fed, local_lr=pc.lr)
    out = sim.run(ROUNDS, test_x=test.x, test_y=test.y,
                  eval_every=ROUNDS - 1)
    acc = out["accuracy"][-1][1]
    bits = out["bits"][-1]
    print(f"{kind.value:12s} {acc:8.3f} {bits/1e3:11.1f} "
          f"{dense_bits/bits:10.1f}x")
