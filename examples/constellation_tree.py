"""Constellation-tree demo: Walker-delta LEO shell training over routed
aggregation trees, with a gateway-adjacent relay failure mid-training.

A 3-plane × 4-satellite Walker-delta constellation (torus ISL mesh, ground
station uplinked to satellite 1) trains the paper's MNIST logistic model with
CL-SIA over the widest-path aggregation tree. At round 25 the gateway-adjacent
satellite dies; routing re-roots its whole subtree through surviving ISLs
(compare: the chain would lose everything beyond the break until healing).
It recovers at round 50 and its banked error-feedback mass drains.

    PYTHONPATH=src python examples/constellation_tree.py
"""

import dataclasses

import jax

from repro.configs import PAPER
from repro.core.algorithms import AggConfig, AggKind
from repro.data.federated import partition_iid
from repro.data.synthetic import make_synthetic_mnist
from repro.fed.simulator import Simulator
from repro.fed.topology import FailureSchedule, TreeTopology
from repro.runtime.fault import banked_mass
from repro.topo.graph import walker_delta

ROUNDS = 75
# 12 satellites + ground-station PS with two gateway uplinks (sats 1 and 7)
# so the constellation survives losing a gateway-adjacent relay.
g = walker_delta(3, 4, gateways=(1, 7))
K = g.num_clients
pc = dataclasses.replace(PAPER, num_clients=K)

train = make_synthetic_mnist(jax.random.PRNGKey(0), K * 150)
test = make_synthetic_mnist(jax.random.PRNGKey(1), 1000)
fed = partition_iid(jax.random.PRNGKey(2), train, K)

topo = TreeTopology(g, routing="widest")
tree = topo.tree()
plan = topo.plan()
print("aggregation tree (client → parent, PS = -1):", tree.parent)
print(f"depth {tree.max_depth()} vs chain depth {K} — "
      f"{K / tree.max_depth():.1f}× shorter critical path")
print(f"compiled plan: level schedule (L, W) = {plan.shape} — one jit "
      f"specialization per padded shape\n")

sim = Simulator(pc, AggConfig(kind=AggKind.CL_SIA, q=pc.q), fed,
                local_lr=pc.lr, tree_topology=topo)
failures = FailureSchedule(K, {25: ([0], []), 50: ([], [0])})

out = sim.run(ROUNDS, test_x=test.x, test_y=test.y, eval_every=10,
              failure_schedule=failures)

print("round  acc    (gateway-adjacent sat 0 dead rounds 25-49)")
for r, acc in out["accuracy"]:
    marker = "  ← sat 0 down, subtree re-rooted" if 25 <= r < 50 else ""
    print(f"{r:5d}  {acc:.3f}{marker}")

healed = topo.tree(dead=(0,))
print(f"\nhealed tree parents: {healed.parent}")
print(f"bits/round stayed {out['bits'][-1] / 1e3:.1f} kbit "
      f"(CL-SIA constant-length property, topology-invariant)")
bm = banked_mass(out["state"].ef)
print(f"banked |e| per sat: {[f'{float(x):.1f}' for x in bm]}")
print("note: the dead satellite's subtree kept aggregating through the "
      "re-rooted tree — only the dead node itself banked into EF.")
