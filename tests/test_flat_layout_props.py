"""FlatLayout single-device property tests (hypothesis, no subprocess)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro import compat
from repro.core.flat_layout import FlatLayout
from repro.configs.base import ModelConfig
from repro.models import model as model_mod
from repro.models import partition


def _mesh11():
    return compat.make_mesh((1, 1), ("data", "model"))


@settings(max_examples=8, deadline=None)
@given(st.integers(1, 3), st.sampled_from([16, 48]), st.sampled_from([2, 6]))
def test_layout_roundtrip_1dev(layers, d_model, heads):
    """flatten → unflatten is the identity for arbitrary tiny configs."""
    cfg = ModelConfig(name="t", family="dense", num_layers=layers,
                      d_model=d_model, num_heads=heads, num_kv_heads=heads,
                      d_ff=2 * d_model, vocab_size=64,
                      head_dim=d_model // heads, param_dtype="float32")
    mesh = _mesh11()
    params = model_mod.init_params(cfg, jax.random.PRNGKey(0))
    layout = FlatLayout(model_mod.param_specs(cfg),
                        partition.param_pspecs(cfg, mesh), mesh)
    col = layout.local_flatten(jax.tree.leaves(params), jnp.int32(0))
    assert col.shape == (layout.n_local,)
    back = layout.local_unflatten(col, jnp.int32(0))
    for a, b in zip(jax.tree.leaves(params), back):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), rtol=1e-6)


def test_layout_total_size_accounting():
    cfg = ModelConfig(name="t", family="dense", num_layers=2, d_model=32,
                      num_heads=4, num_kv_heads=2, d_ff=64, vocab_size=64,
                      head_dim=8, param_dtype="float32")
    mesh = _mesh11()
    params = model_mod.init_params(cfg, jax.random.PRNGKey(0))
    layout = FlatLayout(model_mod.param_specs(cfg),
                        partition.param_pspecs(cfg, mesh), mesh)
    n_params = sum(int(jnp.size(l)) for l in jax.tree.leaves(params))
    assert n_params <= layout.d_flat <= n_params + layout.m * (
        len(layout.plans) + layout.k_dp)
