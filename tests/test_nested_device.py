"""Nested-plan device lowerings: multi-device equivalence.

Acceptance contracts of the nested-plan ISSUE:

* ``execute_nested_sharded`` (client-per-rank mesh) is **bit-exact** to
  host ``execute_nested`` for all five algorithms + dense IA, over the
  chain×chain stack and a tree×chain stack — aggregate, both EF tiers,
  per-stage §V stats — and one jit specialization serves every same-shape
  nested plan (trace counter);
* ``run_nested_segments_local`` on the (pod, data) mesh is bit-exact to
  the historic hand-composed two-stage ``rotated_ring_local`` pair on the
  chain×chain stack (``hierarchical_ring_local`` is now a thin delegate —
  tests/test_hierarchical.py runs unchanged), and bit-exact per
  (stage, segment) to the staged host reference for per-pod *different*
  trees (the traced/butterfly transport) — per-rank segments, both EF
  tiers, per-stage stats;
* ``build_train_step(topology=...)`` trains over a nested plan on a
  (pod, data, model) mesh: stage-order master layout, persistent
  ``stage_ef`` tier, ``agg_bits_relay`` < ``agg_bits``, and DENSE_IA
  nested loss == flat-ring loss (the exact-sum composition);
* ``Simulator(nested_topology=..., backend="device")`` curves match the
  host backend.
"""


CLIENTS_NESTED_EQUIV = r"""
import jax, jax.numpy as jnp, numpy as np
from repro.agg.nested import pod_ring_nested, execute_nested, compile_nested
from repro.agg.device import execute_nested_sharded
from repro.core.algorithms import AggConfig, AggKind
from repro.topo.tree import AggTree, PS

K, D = 8, 97
g = jax.random.normal(jax.random.PRNGKey(0), (K, D))
e = 0.1 * jax.random.normal(jax.random.PRNGKey(1), (K, D))
w = jnp.ones((K,), jnp.float32)
part = jnp.asarray([1, 0, 1, 1, 1, 0, 1, 1], jnp.float32)
se = (0.2 * jax.random.normal(jax.random.PRNGKey(2), (2, D)),)

from repro.agg.schedule import common_shape
chainx = pod_ring_nested(2, 4)
intra = AggTree(parent=(PS, 0, 0, 1))
treex = compile_nested([[(tuple(range(4)), intra), (tuple(range(4, 8)), None)],
                        [((0, 1), None)]])
shape = common_shape([chainx, treex])
chainx, treex = chainx.pad(shape), treex.pad(shape)
assert chainx.shape == treex.shape

ALL = [AggKind.SIA, AggKind.RE_SIA, AggKind.CL_SIA, AggKind.TC_SIA,
       AggKind.CL_TC_SIA, AggKind.DENSE_IA]
for kind in ALL:
    cfg = AggConfig(kind=kind, q=9)
    gm = jnp.zeros((D,))
    if kind in (AggKind.TC_SIA, AggKind.CL_TC_SIA):
        gm = gm.at[jnp.arange(cfg.q_global)].set(1.0)
    traces = []

    @jax.jit
    def dev_round(nested, g, e, w):
        traces.append(1)                       # runs at trace time only
        return execute_nested_sharded(cfg, nested, g, e, w, stage_e=se,
                                      global_mask=gm, participate=part)

    for name, nested in [("chainxchain", chainx), ("treexchain", treex)]:
        want = execute_nested(cfg, nested, g, e, w, stage_e=se,
                              global_mask=gm, participate=part)
        got = dev_round(nested, g, e, w)
        np.testing.assert_array_equal(np.asarray(want.aggregate),
                                      np.asarray(got.aggregate),
                                      err_msg=f"{name}/{kind.value}")
        np.testing.assert_array_equal(np.asarray(want.e_new),
                                      np.asarray(got.e_new),
                                      err_msg=f"{name}/{kind.value}/ef")
        for a, b in zip(want.stage_e_new, got.stage_e_new):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                          err_msg=f"{name}/{kind.value}/sef")
        for field in ("bits", "nnz_out", "nnz_local"):
            np.testing.assert_array_equal(
                np.asarray(getattr(want.stats, field)),
                np.asarray(getattr(got.stats, field)),
                err_msg=f"{name}/{kind.value}/stats.{field}")
            for a, b in zip(want.stage_stats, got.stage_stats):
                np.testing.assert_array_equal(
                    np.asarray(getattr(a, field)),
                    np.asarray(getattr(b, field)),
                    err_msg=f"{name}/{kind.value}/stage_stats.{field}")
    # one XLA executable serves every same-shape nested plan
    assert len(traces) == 1, (kind, len(traces))
    print(f"{kind.value}: nested device == host, 1 trace / 2 plans")
print("PASS")
"""


SEGMENTS_CHAIN_EQUIV = r"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro import compat
from repro.core.algorithms import AggConfig, AggKind
from repro.core.ring import RingStats, rotated_ring_local
from repro.core.hierarchical import hierarchical_ring_local, HierStats
from repro.agg.nested import pod_ring_nested
from repro.agg.device import run_nested_segments_local

KP, KD, n = 2, 4, 4 * 2 * 16
mesh = compat.make_mesh((KP, KD), ("pod", "data"))
K = KP * KD
G = jax.random.normal(jax.random.PRNGKey(0), (K, n))
EF = 0.05 * jax.random.normal(jax.random.PRNGKey(1), (K, n))
PEF = 0.02 * jax.random.normal(jax.random.PRNGKey(2), (K, n // KD))
w = jnp.float32(1.3)
sspec = HierStats(jax.tree.map(lambda _: P(), RingStats(0., 0., 0.)),
                  jax.tree.map(lambda _: P(), RingStats(0., 0., 0.)))

for kind in (AggKind.CL_SIA, AggKind.SIA, AggKind.CL_TC_SIA):
    cfg = AggConfig(kind=kind, q=8)
    gm = None
    if kind in (AggKind.TC_SIA, AggKind.CL_TC_SIA):
        gm = jnp.zeros((n,)).at[::17].set(1.0)

    # the historic hand-composed two-stage rings (pre-delegate program)
    def ref_fn(g_l, ef_l, pef_l):
        seg1, ef_new, st1 = rotated_ring_local(
            cfg, g_l[0], ef_l[0], w, axis="data", global_mask_local=gm)
        mask2 = None
        if gm is not None:
            r = jax.lax.axis_index("data"); seg = n // KD
            mask2 = jax.lax.dynamic_slice(gm, (r * seg,), (seg,))
        seg2, pef_new, st2 = rotated_ring_local(
            cfg, seg1, pef_l[0], jnp.float32(1), axis="pod",
            global_mask_local=mask2)
        st = jax.tree.map(lambda s: jax.lax.psum(s, ("pod", "data")),
                          HierStats(st1, st2))
        return seg2[None], ef_new[None], pef_new[None], st

    nested = pod_ring_nested(KP, KD)
    def new_fn(g_l, ef_l, pef_l):
        seg2, ef_new, (pef_new,), (st1, st2) = run_nested_segments_local(
            cfg, nested, g_l[0], ef_l[0], (pef_l[0],), w,
            axes=("data", "pod"), global_mask_local=gm)
        st = jax.tree.map(lambda s: jax.lax.psum(s, ("pod", "data")),
                          HierStats(st1, st2))
        return seg2[None], ef_new[None], pef_new[None], st

    def hier_fn(g_l, ef_l, pef_l):
        seg2, ef_new, pef_new, st = hierarchical_ring_local(
            cfg, g_l[0], ef_l[0], pef_l[0], w, global_mask_local=gm)
        st = jax.tree.map(lambda s: jax.lax.psum(s, ("pod", "data")), st)
        return seg2[None], ef_new[None], pef_new[None], st

    def run(fn):
        return jax.jit(compat.shard_map(
            fn, mesh=mesh, in_specs=(P(("pod", "data")),) * 3,
            out_specs=(P(("pod", "data")),) * 3 + (sspec,),
            axis_names={"pod", "data"}))(G, EF, PEF)

    ref, new, hier = run(ref_fn), run(new_fn), run(hier_fn)
    for i, name in enumerate(["seg", "ef", "pef"]):
        np.testing.assert_array_equal(np.asarray(ref[i]), np.asarray(new[i]),
                                      err_msg=f"{kind.value}/{name}")
        np.testing.assert_array_equal(np.asarray(ref[i]), np.asarray(hier[i]),
                                      err_msg=f"{kind.value}/hier/{name}")
    for other in (new[3], hier[3]):
        for stage in ("intra", "inter"):
            for f in ("bits", "nnz", "err_sq"):
                np.testing.assert_array_equal(
                    np.asarray(getattr(getattr(ref[3], stage), f)),
                    np.asarray(getattr(getattr(other, stage), f)),
                    err_msg=f"{kind.value}/{stage}/{f}")
    print(f"{kind.value}: chainxchain nested == historic two-stage rings")
print("PASS")
"""


SEGMENTS_TREE_EQUIV = r"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro import compat
from repro.core.algorithms import AggConfig, AggKind
from repro.core.ring import RingStats
from repro.agg.nested import compile_nested
from repro.agg.plan import execute
from repro.agg.device import run_nested_segments_local
from repro.topo.tree import AggTree, PS

KP, KD, n = 2, 4, 4 * 2 * 12
K = KP * KD
seg1, seg2 = n // KD, n // (KD * KP)
mesh = compat.make_mesh((KP, KD), ("pod", "data"))
G = jax.random.normal(jax.random.PRNGKey(0), (K, n))
EF = 0.05 * jax.random.normal(jax.random.PRNGKey(1), (K, n))
PEF = 0.02 * jax.random.normal(jax.random.PRNGKey(2), (K, seg1))
w = jnp.float32(1.1)

# per-pod DIFFERENT intra trees (forces the traced/butterfly transport)
# + a tree inter stage
intra0 = AggTree(parent=(1, 2, 3, PS))
intra1 = AggTree(parent=(3, 0, 0, PS))
inter = AggTree(parent=(1, PS))
nested = compile_nested(
    [[(tuple(range(0, 4)), intra0), (tuple(range(4, 8)), intra1)],
     [((0, 1), inter)]])
assert not nested.clustered[0].uniform()
stage0_ref, stage1_ref = nested.stages

for kind in (AggKind.CL_SIA, AggKind.SIA, AggKind.CL_TC_SIA):
    cfg = AggConfig(kind=kind, q=5)
    gm = None
    if kind in (AggKind.TC_SIA, AggKind.CL_TC_SIA):
        gm = jnp.zeros((n,)).at[::37].set(1.0)

    def fn(g_l, ef_l, pef_l):
        s2, ef_new, (pef_new,), (st1, st2) = run_nested_segments_local(
            cfg, nested, g_l[0], ef_l[0], (pef_l[0],), w,
            axes=("data", "pod"), global_mask_local=gm)
        st = jax.tree.map(lambda s: jax.lax.psum(s, ("pod", "data")),
                          (st1, st2))
        return s2[None], ef_new[None], pef_new[None], st

    sspec = jax.tree.map(lambda _: P(),
                         (RingStats(0., 0., 0.), RingStats(0., 0., 0.)))
    seg2_dev, ef_dev, pef_dev, st_dev = jax.jit(compat.shard_map(
        fn, mesh=mesh, in_specs=(P(("pod", "data")),) * 3,
        out_specs=(P(("pod", "data")),) * 3 + (sspec,),
        axis_names={"pod", "data"}))(G, EF, PEF)
    seg2_dev, ef_dev, pef_dev = map(np.asarray, (seg2_dev, ef_dev, pef_dev))

    # staged host reference: stage 0 per data segment s (rotated start
    # ranks, the merged multi-sink forest through host `execute`), stage 1
    # per (s, pod sub-segment t) on the stage-0 sink partials
    bits0 = bits1 = 0.0
    for s in range(KD):
        rows = np.asarray([p * KD + ((k + s) % KD)
                           for p in range(KP) for k in range(KD)])
        lo1 = s * seg1
        gm_s = None if gm is None else gm[lo1:lo1 + seg1]
        res0 = execute(cfg, stage0_ref,
                       jnp.asarray(np.asarray(G)[rows, lo1:lo1 + seg1]),
                       jnp.asarray(np.asarray(EF)[rows, lo1:lo1 + seg1]),
                       jnp.full((K,), w), global_mask=gm_s)
        bits0 += float(jnp.sum(res0.stats.bits))
        for i, rr in enumerate(rows):
            np.testing.assert_array_equal(
                ef_dev[rr, lo1:lo1 + seg1], np.asarray(res0.e_new[i]),
                err_msg=f"{kind.value} ef s={s} i={i}")
        sinks = np.asarray(res0.aggregate)          # [KP, seg1]
        for t in range(KP):
            urows = [(u + t) % KP for u in range(KP)]
            pe_rows = np.asarray([u * KD + s for u in urows])
            gm1 = (None if gm is None
                   else gm[lo1 + t * seg2: lo1 + (t + 1) * seg2])
            res1 = execute(
                cfg, stage1_ref,
                jnp.asarray(sinks[urows, t * seg2:(t + 1) * seg2]),
                jnp.asarray(np.asarray(PEF)[pe_rows,
                                            t * seg2:(t + 1) * seg2]),
                jnp.ones((KP,)), global_mask=gm1)
            bits1 += float(jnp.sum(res1.stats.bits))
            np.testing.assert_array_equal(
                seg2_dev[t * KD + s], np.asarray(res1.aggregate),
                err_msg=f"{kind.value} agg s={s} t={t}")
            for u, rr in zip(range(KP), pe_rows):
                np.testing.assert_array_equal(
                    pef_dev[rr, t * seg2:(t + 1) * seg2],
                    np.asarray(res1.e_new[u]),
                    err_msg=f"{kind.value} pef s={s} t={t} u={u}")
    np.testing.assert_allclose(float(st_dev[0].bits), bits0, rtol=1e-6)
    np.testing.assert_allclose(float(st_dev[1].bits), bits1, rtol=1e-6)
    print(f"{kind.value}: per-pod-tree nested segments == staged host ref")
print("PASS")
"""


TRAIN_NESTED = r"""
import jax, jax.numpy as jnp, numpy as np
from repro import compat
from repro.configs.base import ModelConfig
from repro.core.algorithms import AggConfig, AggKind
from repro.launch.mesh import dp_clients, make_agg_plan
from repro.optim.optimizers import OptConfig
from repro.train.state import TrainConfig
from repro.train import build_train_step, init_state, state_shardings

# model axis size 1: two *manual* DP axes + a >1 auto model axis trips a
# pre-existing XLA 0.4.37 partial-manual partitioner RET_CHECK (the seed's
# known `--mesh 4x2` mamba crash family) — not a nested-plan limitation
mesh = compat.make_mesh((2, 4, 1), ("pod", "data", "model"))
assert dp_clients(mesh) == 8
cfg = ModelConfig(name="tiny", family="dense", num_layers=2, d_model=64,
                  num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=256,
                  head_dim=16, param_dtype="float32")
toks = jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0, 256)
batch = {"tokens": toks, "labels": jnp.roll(toks, -1, axis=1)}
tc = TrainConfig(agg=AggConfig(kind=AggKind.CL_SIA, q=1),
                 opt=OptConfig(name="adamw", lr=1e-3), q_frac=0.05,
                 agg_dtype="float32", ef_dtype="float32")

plan = make_agg_plan(mesh, "hierarchical")
assert plan.stage_units == (8, 2)
with compat.set_mesh(mesh):
    st = jax.device_put(
        init_state(cfg, tc, mesh, jax.random.PRNGKey(0), topology=plan),
        state_shardings(cfg, tc, mesh, topology=plan))
    step = jax.jit(build_train_step(cfg, tc, mesh, topology=plan))
    losses = []
    for _ in range(6):
        st, m = step(st, dict(batch))
        losses.append(float(m["loss"]))
assert losses[-1] < losses[0], losses
relay, total = float(m["agg_bits_relay"]), float(m["agg_bits"])
assert 0 < relay < total, (relay, total)
assert len(st.stage_ef) == 1 and st.stage_ef[0].shape[0] == 8
assert float(jnp.sum(jnp.abs(st.stage_ef[0]))) > 0   # pod-edge EF banks
print(f"nested train converges ({losses[0]:.3f} -> {losses[-1]:.3f}); "
      f"relay/total bits {relay:.0f}/{total:.0f}")

# DENSE_IA: staged composition is the exact sum → same loss as the flat
# ring step on identical inputs
tc2 = TrainConfig(agg=AggConfig(kind=AggKind.DENSE_IA),
                  opt=OptConfig(name="sgd", lr=1e-2), q_frac=0.05,
                  agg_dtype="float32", ef_dtype="float32")
with compat.set_mesh(mesh):
    st_f = jax.device_put(init_state(cfg, tc2, mesh, jax.random.PRNGKey(0)),
                          state_shardings(cfg, tc2, mesh))
    st_n = jax.device_put(
        init_state(cfg, tc2, mesh, jax.random.PRNGKey(0), topology=plan),
        state_shardings(cfg, tc2, mesh, topology=plan))
    _, mf = jax.jit(build_train_step(cfg, tc2, mesh))(st_f, dict(batch))
    _, mn = jax.jit(build_train_step(cfg, tc2, mesh, topology=plan))(
        st_n, dict(batch))
np.testing.assert_allclose(np.asarray(mf["loss"]), np.asarray(mn["loss"]),
                           rtol=1e-6)
print("dense nested train loss == flat ring train loss")
print("PASS")
"""


SIM_NESTED = r"""
import dataclasses
import jax, numpy as np
from repro.agg import TopologySchedule, pod_ring_nested
from repro.configs import PAPER
from repro.core.algorithms import AggConfig, AggKind
from repro.data.federated import partition_iid
from repro.data.synthetic import make_synthetic_mnist
from repro.fed.simulator import Simulator
from repro.topo import graph as tg
from repro.topo.routing import cluster_routed

k = 8
pc = dataclasses.replace(PAPER, num_clients=k)
train = make_synthetic_mnist(jax.random.PRNGKey(0), k * 40)
fed = partition_iid(jax.random.PRNGKey(2), train, k)

nt = cluster_routed(tg.grid_graph(2, 4), 2)
for kind in (AggKind.CL_SIA, AggKind.TC_SIA):
    cfg = AggConfig(kind=kind, q=pc.q)
    host = Simulator(pc, cfg, fed, local_lr=pc.lr,
                     nested_topology=nt).run(5, seed=1)
    dev = Simulator(pc, cfg, fed, local_lr=pc.lr, nested_topology=nt,
                    backend="device").run(5, seed=1)
    np.testing.assert_allclose(host["loss"], dev["loss"], rtol=1e-5)
    np.testing.assert_allclose(host["bits"], dev["bits"], rtol=1e-6)
    assert host["loss"][-1] < host["loss"][0]
    print(f"{kind.value}: nested device backend matches host curves")

# a schedule of nested plans (per-round re-clustering) still trains
sched = TopologySchedule.from_topologies(
    [cluster_routed(tg.grid_graph(2, 4), 2), pod_ring_nested(2, 4),
     cluster_routed(tg.walker_delta(2, 4), 2)])
out = Simulator(pc, AggConfig(kind=AggKind.CL_SIA, q=pc.q), fed,
                local_lr=pc.lr).run(6, seed=1, topology_schedule=sched)
assert out["loss"][-1] < out["loss"][0]
print("PASS")
"""


def test_nested_clients_matches_host_execute(multidev):
    """execute_nested_sharded ≡ host execute_nested, 6 algorithms ×
    chain×chain / tree×chain, one trace per shape."""
    multidev(CLIENTS_NESTED_EQUIV, devices=8)


def test_nested_segments_chainxchain_is_the_hierarchical_ring(multidev):
    """Chain×chain nested segments ≡ the historic two-stage
    rotated_ring_local composition ≡ the hierarchical_ring_local
    delegate — bitwise, both EF tiers, per-stage stats."""
    multidev(SEGMENTS_CHAIN_EQUIV, devices=8)


def test_nested_segments_tree_matches_staged_host_reference(multidev):
    """Per-pod different intra trees (butterfly transport) + tree inter
    stage ≡ the staged per-segment host reference."""
    multidev(SEGMENTS_TREE_EQUIV, devices=8)


def test_train_step_nested_topology(multidev):
    multidev(TRAIN_NESTED, devices=8)


def test_simulator_nested_topology(multidev):
    multidev(SIM_NESTED, devices=8)
