"""Property test (hypothesis): fused (Pallas-interpret) node steps are
bit-exact to the unfused jnp reference through ``execute`` on random
topologies, budgets, straggler sets and sparsifier implementations, for
all five algorithms. See tests/test_fused_node_step.py for the directed
suite and the jit/FMA comparison rules (both paths jitted; err_sq to
1 ulp)."""

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.agg import compile_plan, execute
from repro.core.algorithms import AggConfig, AggKind
from repro.topo.tree import PS, AggTree

ALL_KINDS = [AggKind.SIA, AggKind.RE_SIA, AggKind.CL_SIA, AggKind.TC_SIA,
             AggKind.CL_TC_SIA]

D = 48


def _gmask(cfg, d):
    if cfg.kind in (AggKind.TC_SIA, AggKind.CL_TC_SIA):
        return jnp.zeros((d,)).at[jnp.arange(cfg.q_global)].set(1.0)
    return None


@settings(max_examples=12, deadline=None)
@given(data=st.data(),
       kind=st.sampled_from(ALL_KINDS),
       impl=st.sampled_from(["exact", "threshold"]),
       seed=st.integers(0, 2**16))
def test_fused_execute_bit_exact_on_random_trees(data, kind, impl, seed):
    k = data.draw(st.integers(2, 7), label="k")
    # random attachment tree: node i hangs off a node < i (or the PS)
    parent = [PS] + [data.draw(st.integers(-1, i - 1), label=f"p{i}")
                     for i in range(1, k)]
    tree = AggTree(parent=tuple(parent))
    cfg_u = AggConfig(kind=kind, q=data.draw(st.integers(1, D), label="q"),
                      topq_impl=impl, kernel_mode="never",
                      hist_rounds=2, hist_branch=16)
    cfg_f = dataclasses.replace(cfg_u, kernel_mode="always")

    g = jax.random.normal(jax.random.PRNGKey(seed), (k, D))
    e = 0.1 * jax.random.normal(jax.random.PRNGKey(seed + 1), (k, D))
    w = jnp.ones((k,), jnp.float32)
    gm = _gmask(cfg_u, D)
    part = None
    if data.draw(st.booleans(), label="stragglers"):
        bits = [data.draw(st.booleans(), label=f"s{i}") for i in range(k)]
        part = jnp.asarray(bits, jnp.float32)
    qb = None
    if impl == "exact" and data.draw(st.booleans(), label="budgets"):
        qb = np.asarray([data.draw(st.integers(0, D), label=f"q{i}")
                         for i in range(k)], np.int32)

    pad = (tree.max_depth() + data.draw(st.integers(0, 2), label="padl"),
           k + data.draw(st.integers(0, 2), label="padw"))
    plan = compile_plan(tree, pad_to=pad, q_budget=qb)
    ru = jax.jit(functools.partial(
        execute, cfg_u, global_mask=gm, participate=part))(plan, g, e, w)
    rf = jax.jit(functools.partial(
        execute, cfg_f, global_mask=gm, participate=part))(plan, g, e, w)
    np.testing.assert_array_equal(np.asarray(ru.aggregate),
                                  np.asarray(rf.aggregate))
    np.testing.assert_array_equal(np.asarray(ru.e_new),
                                  np.asarray(rf.e_new))
    for field in ("nnz_out", "nnz_global", "nnz_local", "bits"):
        np.testing.assert_array_equal(
            np.asarray(getattr(ru.stats, field)),
            np.asarray(getattr(rf.stats, field)), err_msg=field)
    np.testing.assert_allclose(np.asarray(ru.stats.err_sq),
                               np.asarray(rf.stats.err_sq), rtol=1e-6)
