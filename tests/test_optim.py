"""Optimizers: flat vs tree vs hand-rolled numpy."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import (OptConfig, apply_flat, apply_tree, init_flat,
                         init_tree, lr_schedule)


def _numpy_adamw(p, g, m, v, t, cfg):
    m = cfg.b1 * m + (1 - cfg.b1) * g
    v = cfg.b2 * v + (1 - cfg.b2) * g * g
    mh = m / (1 - cfg.b1 ** t)
    vh = v / (1 - cfg.b2 ** t)
    p = p - cfg.lr * (mh / (np.sqrt(vh) + cfg.eps) + cfg.weight_decay * p)
    return p, m, v


@pytest.mark.parametrize("name", ["sgd", "momentum", "adamw"])
def test_flat_matches_tree(name):
    cfg = OptConfig(name=name, lr=0.01, weight_decay=0.1)
    d = 257
    p = jax.random.normal(jax.random.PRNGKey(0), (d,))
    tree_p = {"a": p[:100].reshape(10, 10), "b": p[100:]}
    fs = init_flat(cfg, d)
    ts = init_tree(cfg, tree_p)
    for i in range(3):
        g = jax.random.normal(jax.random.PRNGKey(i + 1), (d,))
        tree_g = {"a": g[:100].reshape(10, 10), "b": g[100:]}
        p, fs = apply_flat(cfg, fs, p, g)
        tree_p, ts = apply_tree(cfg, ts, tree_p, tree_g)
    flat_from_tree = jnp.concatenate(
        [tree_p["a"].reshape(-1), tree_p["b"]])
    np.testing.assert_allclose(np.asarray(p), np.asarray(flat_from_tree),
                               rtol=1e-5, atol=1e-6)


def test_adamw_matches_numpy():
    cfg = OptConfig(name="adamw", lr=0.003, weight_decay=0.02)
    d = 64
    rng = np.random.default_rng(0)
    p = rng.normal(size=d).astype(np.float32)
    m = np.zeros(d, np.float32)
    v = np.zeros(d, np.float32)
    jp = jnp.asarray(p)
    st = init_flat(cfg, d)
    for t in range(1, 4):
        g = rng.normal(size=d).astype(np.float32)
        p, m, v = _numpy_adamw(p, g, m, v, t, cfg)
        jp, st = apply_flat(cfg, st, jp, jnp.asarray(g))
    np.testing.assert_allclose(np.asarray(jp), p, rtol=1e-5, atol=1e-6)


def test_grad_clip():
    cfg = OptConfig(name="sgd", lr=1.0, grad_clip=1.0)
    p = jnp.zeros((4,))
    g = jnp.asarray([10.0, 0, 0, 0])
    p2, _ = apply_flat(cfg, init_flat(cfg, 4), p, g)
    np.testing.assert_allclose(np.asarray(p2), [-1.0, 0, 0, 0], rtol=1e-6)


def test_lr_schedule_shapes():
    assert float(lr_schedule(jnp.int32(0), warmup=10)) == pytest.approx(0.1)
    assert float(lr_schedule(jnp.int32(9), warmup=10)) == pytest.approx(1.0)
    end = float(lr_schedule(jnp.int32(10_000), warmup=10,
                            decay_steps=10_000, kind="cosine"))
    assert end == pytest.approx(0.1, abs=1e-3)
