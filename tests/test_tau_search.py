"""Fused τ search: operand-on-the-fly counts, hist bisection, kernel err_sq.

Contracts (ISSUE acceptance criteria):

* the fused-operand τ search (bisection counts consuming the operand
  rebuilt tile-by-tile from the raw node inputs, ``kernel_mode="ref"`` /
  ``"always"``) is **bitwise identical** to the materialized-operand
  search (``kernel_mode="never"``) through whole rounds — every
  algorithm, chain and padded tree plans, stragglers, dynamic per-node
  budgets, cohort-shared global masks;
* ``tau_impl="hist"`` (one joint digit histogram) reproduces the scan's
  per-round candidate-count **integers** and τ bit-for-bit for
  rounds ∈ {1, 2} (hypothesis-randomized over data, branch, q);
* the §V over-selection contract (≥ q survivors, bits charge the
  realized support) holds under the hist bisection;
* the in-kernel pinned-order ‖e'‖² (``err_sq_mode="kernel"``) matches
  the jnp reference kernels bitwise and leaves every other round output
  (aggregate, EF rows, counts, bits) untouched.

Both sides of every parity assertion run under ``jax.jit`` — XLA:CPU
contracts ``w·g + e`` into an FMA inside jitted graphs but not in eager
op-by-op dispatch, so jitted-vs-eager comparisons show 1-ulp noise that
has nothing to do with the kernels (see tests/test_fused_node_step.py).
"""

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.agg import compile_plan, execute
from repro.core import sparsify as sp
from repro.core.algorithms import AggConfig, AggKind, index_bits
from repro.core.chain import run_chain
from repro.kernels import ops as kops
from repro.kernels import ref as kref
from repro.topo.tree import AggTree, PS

ALL_KINDS = [AggKind.SIA, AggKind.RE_SIA, AggKind.CL_SIA, AggKind.TC_SIA,
             AggKind.CL_TC_SIA]
FUSED_MODES = ["ref", "always"]          # jnp bodies / Pallas-interpret

K, D = 7, 96
TREE = AggTree(parent=(PS, 0, 1, 1, 3, 0, 5))


def _inputs(k=K, d=D, seed=0):
    g = jax.random.normal(jax.random.PRNGKey(seed), (k, d))
    e = 0.1 * jax.random.normal(jax.random.PRNGKey(seed + 1), (k, d))
    w = jnp.ones((k,), jnp.float32)
    return g, e, w


def _pair(kind, fused_mode, **kw):
    base = AggConfig(kind=kind, q=11, topq_impl="threshold",
                     kernel_mode="never", **kw)
    return base, dataclasses.replace(base, kernel_mode=fused_mode)


def _gmask(cfg, d):
    if cfg.kind in (AggKind.TC_SIA, AggKind.CL_TC_SIA):
        return jnp.zeros((d,)).at[jnp.arange(cfg.q_global)].set(1.0)
    return None


def _assert_same_round(a, b, msg=""):
    np.testing.assert_array_equal(np.asarray(a.aggregate),
                                  np.asarray(b.aggregate),
                                  err_msg=f"{msg}/aggregate")
    np.testing.assert_array_equal(np.asarray(a.e_new), np.asarray(b.e_new),
                                  err_msg=f"{msg}/e_new")
    for field in ("nnz_out", "nnz_global", "nnz_local", "bits"):
        np.testing.assert_array_equal(
            np.asarray(getattr(a.stats, field)),
            np.asarray(getattr(b.stats, field)),
            err_msg=f"{msg}/stats.{field}")
    np.testing.assert_allclose(np.asarray(a.stats.err_sq),
                               np.asarray(b.stats.err_sq), rtol=1e-6,
                               err_msg=f"{msg}/stats.err_sq")


# ---------------------------------------------------------------------------
# Fused-operand τ search ≡ materialized τ search, end to end
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("fused_mode", FUSED_MODES)
@pytest.mark.parametrize("kind", ALL_KINDS)
def test_fused_operand_round_parity(kind, fused_mode):
    cfg_m, cfg_f = _pair(kind, fused_mode)
    g, e, w = _inputs(seed=2)
    gm = _gmask(cfg_m, D)
    part = jnp.asarray([1, 0, 1, 1, 0, 1, 1], jnp.float32)
    for name, topo, pad in [("chain", K, None), ("tree", TREE, (K, 4))]:
        plan = compile_plan(topo, pad_to=pad)
        for pname, p in [("all", None), ("stragglers", part)]:
            run_m = jax.jit(functools.partial(execute, cfg_m,
                                              global_mask=gm,
                                              participate=p))
            run_f = jax.jit(functools.partial(execute, cfg_f,
                                              global_mask=gm,
                                              participate=p))
            _assert_same_round(run_m(plan, g, e, w), run_f(plan, g, e, w),
                               f"{kind.value}/{fused_mode}/{name}/{pname}")


@pytest.mark.parametrize("kind", ALL_KINDS)
def test_fused_operand_round_parity_q_budget(kind):
    """Dynamic per-node budgets materialize the operand for the full sort —
    parity must still hold through the fused structure."""
    cfg_m, cfg_f = _pair(kind, "ref")
    g, e, w = _inputs(seed=3)
    gm = _gmask(cfg_m, D)
    qb = np.asarray([5, 3, 5, 2, 5, 1, 4], np.int32)
    plan = compile_plan(TREE, q_budget=qb, pad_to=(K, 3))
    run_m = jax.jit(functools.partial(execute, cfg_m, global_mask=gm))
    run_f = jax.jit(functools.partial(execute, cfg_f, global_mask=gm))
    _assert_same_round(run_m(plan, g, e, w), run_f(plan, g, e, w),
                       f"{kind.value}/q_budget")


@pytest.mark.parametrize("mode", ["never", "always"])
def test_operand_fn_tau_matches_materialized(mode):
    """Unit-level: ``threshold_for_topq(operand_fn=...)`` over the
    dispatched fused counts ≡ the materialized search, bitwise, for the
    full operand family (γ and global-mask factors on)."""
    w_l = 4
    g, e, _ = _inputs(k=w_l, d=300, seed=4)
    gin = jax.random.normal(jax.random.PRNGKey(9), (w_l, 300)) * 0.2
    wv = jnp.asarray([1.0, 0.5, 2.0, 1.0], jnp.float32)
    p = jnp.asarray([1, 1, 0, 1], jnp.float32)
    gm = jnp.zeros((300,)).at[jnp.arange(40)].set(1.0)
    x = kref.fused_operand(g, e, gin, wv, p, gm, include_gamma=True)
    op = sp.TauOperand(
        count=lambda taus: kops.count_ge_fused_level(
            g, e, gin, wv, p, taus, gm, include_gamma=True, mode=mode),
        max_abs=lambda: jnp.max(jnp.abs(x), axis=-1),
        batched=True,
        hist=lambda tables: kops.hist_topq_level(
            g, e, gin, wv, p, tables, gm, include_gamma=True, mode=mode))
    for q in (3, 29, 250):
        for impl, rounds in (("scan", 3), ("scan", 2), ("hist", 2)):
            tau_m = jax.jit(functools.partial(
                sp.threshold_for_topq, q=q, rounds=rounds,
                tau_impl=impl))(x)
            tau_f = jax.jit(functools.partial(
                sp.threshold_for_topq, None, q, rounds=rounds,
                operand_fn=op, tau_impl=impl))()
            np.testing.assert_array_equal(
                np.asarray(tau_m), np.asarray(tau_f),
                err_msg=f"q={q}/{impl}/{rounds}/{mode}")


def test_fused_count_cohort_gmask_parity():
    """Cohort-shared [B, d] global masks (the multi-tenant batched-round
    lane layout) through the fused count and hist kernels ≡ the jnp
    reference, in interpret mode."""
    b, lanes, d = 2, 3, 1000
    w_l = b * lanes
    g, e, _ = _inputs(k=w_l, d=d, seed=5)
    gin = jnp.zeros_like(g)
    wv = jnp.ones((w_l,), jnp.float32)
    p = jnp.ones((w_l,), jnp.float32)
    gm = (jax.random.uniform(jax.random.PRNGKey(6), (b, d)) < 0.1
          ).astype(jnp.float32)
    taus = jnp.sort(jax.random.uniform(jax.random.PRNGKey(7),
                                       (w_l, 16)), axis=-1)
    got = jax.jit(functools.partial(
        kops.count_ge_fused_level, gmask_cohorts=b,
        mode="always"))(g, e, gin, wv, p, taus, gm)
    want = jax.jit(functools.partial(
        kref.ref_count_ge_fused_level, gmask_cohorts=b))(
            g, e, gin, wv, p, taus, gm)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    x = kref.fused_operand(g, e, gin, wv, p, gm, gmask_cohorts=b)
    hi = jnp.max(jnp.abs(x), axis=-1) * jnp.float32(1 + 1e-6)
    tables = sp._hist_tables(jnp.zeros_like(hi), jnp.maximum(hi, 1e-30), 64)
    d2_k, f_k = jax.jit(functools.partial(
        kops.hist_topq_level, gmask_cohorts=b,
        mode="always"))(g, e, gin, wv, p, tables, gm)
    d2_r, f_r = jax.jit(functools.partial(
        kref.ref_hist_topq_level, gmask_cohorts=b))(
            g, e, gin, wv, p, tables, gm)
    # lane padding lands in the never-read bin D2[·, 0, 0]
    zero = jnp.zeros((), jnp.int32)
    d2_k = np.asarray(d2_k.at[:, 0, 0].set(zero))
    d2_r = np.asarray(d2_r.at[:, 0, 0].set(zero))
    np.testing.assert_array_equal(d2_k, d2_r)
    np.testing.assert_array_equal(np.asarray(f_k), np.asarray(f_r))


# ---------------------------------------------------------------------------
# hist bisection ≡ scan bisection (τ AND the per-round count integers)
# ---------------------------------------------------------------------------

def _assert_hist_matches_scan(x, q, branch, rounds):
    tau_s, c_s = sp.threshold_for_topq(x, q, branch=branch, rounds=rounds,
                                       with_counts=True)
    tau_h, c_h = sp.threshold_for_topq(x, q, branch=branch, rounds=rounds,
                                       tau_impl="hist", with_counts=True)
    np.testing.assert_array_equal(np.asarray(tau_s), np.asarray(tau_h),
                                  err_msg=f"tau q={q} b={branch} r={rounds}")
    np.testing.assert_array_equal(np.asarray(c_s), np.asarray(c_h),
                                  err_msg=f"counts q={q} b={branch} "
                                          f"r={rounds}")


def test_default_scan_shortcut_matches_counting_scan():
    """The single-host count-free scan (top_k resolves the count >= q
    predicate) returns bitwise the same τ as the per-round counting scan
    — including q ≤ 0, q ≥ d, all-zero operands and ties."""
    x = jax.random.normal(jax.random.PRNGKey(11), (5, 4096))
    cases = [(x, q) for q in (0, 1, 40, 4096, 5000)]
    cases += [(x[0], 40), (jnp.zeros((512,)), 5),
              (jnp.ones((512,)).at[3].set(7.0), 5)]
    for xx, q in cases:
        count_fn = sp.count_ge_batch if xx.ndim == 2 else sp.count_ge
        got = sp.threshold_for_topq(xx, q)
        want = sp.threshold_for_topq(xx, q, count_fn=count_fn)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want),
                                      err_msg=f"q={q} shape={xx.shape}")


def test_hist_matches_scan_directed():
    x = jax.random.normal(jax.random.PRNGKey(12), (5, 4096))
    for q in (1, 40, 1000, 4095):
        for branch in (8, 64):
            for rounds in (1, 2):
                _assert_hist_matches_scan(x, q, branch, rounds)
    # 1-D path, all-zero operand, ties
    _assert_hist_matches_scan(jnp.zeros((512,)), 5, 64, 2)
    _assert_hist_matches_scan(jnp.ones((512,)).at[3].set(7.0), 5, 64, 2)


def test_hist_matches_scan_property():
    hyp = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=30, deadline=None)
    @given(seed=st.integers(0, 2**16), d=st.integers(2, 600),
           q=st.integers(1, 600), branch=st.sampled_from([4, 16, 64, 256]),
           rounds=st.integers(1, 2), scale=st.sampled_from([1e-6, 1.0, 1e6]))
    def run(seed, d, q, branch, rounds, scale):
        x = scale * jax.random.normal(jax.random.PRNGKey(seed), (d,))
        _assert_hist_matches_scan(x, min(q, d), branch, rounds)

    run()


def test_hist_round_parity_all_kinds():
    """Whole rounds under tau_impl='hist' ≡ the scan at the same rounds —
    materialized and fused-operand structures alike."""
    g, e, w = _inputs(seed=13)
    plan = compile_plan(TREE, pad_to=(K, 4))
    for kind in ALL_KINDS:
        for kmode in ("never", "ref"):
            cfg_s = AggConfig(kind=kind, q=11, topq_impl="threshold",
                              kernel_mode=kmode, hist_rounds=2)
            cfg_h = dataclasses.replace(cfg_s, tau_impl="hist")
            gm = _gmask(cfg_s, D)
            run_s = jax.jit(functools.partial(execute, cfg_s,
                                              global_mask=gm))
            run_h = jax.jit(functools.partial(execute, cfg_h,
                                              global_mask=gm))
            _assert_same_round(run_s(plan, g, e, w), run_h(plan, g, e, w),
                               f"{kind.value}/{kmode}/hist")


def test_hist_validation():
    with pytest.raises(ValueError, match="rounds must be 1 or 2"):
        sp.threshold_for_topq(jnp.ones((8,)), 2, rounds=3, tau_impl="hist")
    with pytest.raises(ValueError, match="branch"):
        sp.threshold_for_topq(jnp.ones((8,)), 2, rounds=2, branch=2048,
                              tau_impl="hist")
    with pytest.raises(ValueError, match="hist_rounds"):
        AggConfig(kind=AggKind.SIA, q=5, tau_impl="hist")   # hist_rounds=3
    with pytest.raises(ValueError, match="tau_impl"):
        AggConfig(kind=AggKind.SIA, q=5, tau_impl="histo")


def test_threshold_bits_charge_realized_nnz_hist():
    """§V regression under the hist bisection: ≥ q survivors and bits
    charge the realized support, not q."""
    cfg = AggConfig(kind=AggKind.CL_SIA, q=11, topq_impl="threshold",
                    tau_impl="hist", hist_rounds=2)
    g, e, w = _inputs(seed=14)
    res = run_chain(cfg, g, e, w)
    nnz = np.asarray(res.stats.nnz_out)
    assert (nnz >= cfg.q).all(), nnz
    word = cfg.omega + index_bits(D)
    np.testing.assert_array_equal(np.asarray(res.stats.bits),
                                  (word * nnz).astype(np.float32))


# ---------------------------------------------------------------------------
# In-kernel pinned-order err_sq
# ---------------------------------------------------------------------------

def test_err_sq_kernel_matches_ref_pinned():
    """with_err=True: Pallas-interpret kernels ≡ the jnp reference —
    bitwise, including the pinned-summation-order ‖e'‖²."""
    w_l, d = 4, 9000                     # d > 8192 exercises multi-block
    g, e, _ = _inputs(k=w_l, d=d, seed=15)
    gin = 0.3 * jax.random.normal(jax.random.PRNGKey(16), (w_l, d))
    mask = (jax.random.uniform(jax.random.PRNGKey(17), (w_l, d)) < 0.2
            ).astype(jnp.float32)
    wv = jnp.asarray([1.0, 0.5, 2.0, 1.0], jnp.float32)
    tau = jnp.asarray([0.5, 0.1, 1.0, 0.2], jnp.float32)
    p = jnp.asarray([1, 1, 0, 1], jnp.float32)
    valid = jnp.asarray([1, 1, 1, 0], jnp.float32)

    for fn_k, fn_r, args in (
            (kops.sparsify_ef_level, kref.ref_sparsify_ef_level,
             (g, e, mask, wv, tau, valid)),
            (kops.cl_fuse_level, kref.ref_cl_fuse_level,
             (g, e, gin, wv, tau, p, valid))):
        got = jax.jit(functools.partial(fn_k, with_err=True,
                                        mode="always"))(*args)
        want = jax.jit(functools.partial(fn_r, with_err=True))(*args)
        assert len(got) == len(want)
        for i, (a, b) in enumerate(zip(got, want)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                          err_msg=f"{fn_k.__name__}[{i}]")
        np.testing.assert_array_equal(np.asarray(got[-1][valid == 0]), 0.0)


@pytest.mark.parametrize("kind", ALL_KINDS)
def test_err_sq_mode_kernel_leaves_round_unchanged(kind):
    """err_sq_mode='kernel' must not perturb any §V-relevant output —
    aggregate, EF, counts and bits stay bitwise; err_sq stays within the
    float-reduction-order tolerance of the jnp value."""
    base = AggConfig(kind=kind, q=11, topq_impl="threshold",
                     kernel_mode="ref")
    cfg_k = dataclasses.replace(base, err_sq_mode="kernel")
    g, e, w = _inputs(seed=18)
    gm = _gmask(base, D)
    part = jnp.asarray([1, 0, 1, 1, 0, 1, 1], jnp.float32)
    plan = compile_plan(TREE, pad_to=(K, 4))
    run_j = jax.jit(functools.partial(execute, base, global_mask=gm,
                                      participate=part))
    run_k = jax.jit(functools.partial(execute, cfg_k, global_mask=gm,
                                      participate=part))
    _assert_same_round(run_j(plan, g, e, w), run_k(plan, g, e, w),
                       f"{kind.value}/err_sq_mode")


def test_err_sq_mode_validated():
    with pytest.raises(ValueError, match="err_sq_mode"):
        AggConfig(kind=AggKind.SIA, q=5, err_sq_mode="pallas")
