"""repro.agg: plan/execute equivalence, schedules, budgets, jit amortization.

Key contracts (ISSUE acceptance criteria):
* ``execute(compile_plan(t), ...)`` is **bit-exact** to ``run_chain`` /
  ``run_chain_with_topology`` / ``run_tree`` for all five Algorithm 1–5
  node steps, including plans padded to a larger ``(L, W)``;
* a ``TopologySchedule`` over ≥3 distinct graphs triggers exactly one jit
  specialization (traced-side-effect counter);
* bandwidth-scaled per-client Top-Q budgets strictly reduce total §V bits
  vs the uniform budget on a heterogeneous-bandwidth graph;
* the simulator's ``order_fn`` (healed/permuted chains) actually reaches
  the aggregation path.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.agg import (Aggregator, TopologySchedule, bandwidth_budgets,
                       compile_plan, execute)
from repro.core.algorithms import AggConfig, AggKind, NodeCtx, node_step
from repro.core.chain import run_chain, run_chain_with_topology
from repro.topo import graph as tg
from repro.topo.routing import shortest_path_tree, widest_path_tree
from repro.topo.tree import PS, AggTree, run_tree

ALL_KINDS = [AggKind.SIA, AggKind.RE_SIA, AggKind.CL_SIA, AggKind.TC_SIA,
             AggKind.CL_TC_SIA]

K, D = 7, 96


def _inputs(k=K, d=D, seed=0):
    g = jax.random.normal(jax.random.PRNGKey(seed), (k, d))
    e = 0.1 * jax.random.normal(jax.random.PRNGKey(seed + 1), (k, d))
    w = jnp.ones((k,), jnp.float32)
    return g, e, w


def _cfg(kind, q=11):
    return AggConfig(kind=kind, q=q)


def _gmask(cfg, d):
    if cfg.kind in (AggKind.TC_SIA, AggKind.CL_TC_SIA):
        return jnp.zeros((d,)).at[jnp.arange(cfg.q_global)].set(1.0)
    return None


def _assert_same(a, b):
    np.testing.assert_array_equal(np.asarray(a.aggregate),
                                  np.asarray(b.aggregate))
    np.testing.assert_array_equal(np.asarray(a.e_new), np.asarray(b.e_new))
    np.testing.assert_array_equal(np.asarray(a.stats.bits),
                                  np.asarray(b.stats.bits))
    np.testing.assert_array_equal(np.asarray(a.stats.nnz_out),
                                  np.asarray(b.stats.nnz_out))


# ---------------------------------------------------------------------------
# execute(compile_plan(·)) ≡ run_chain / run_chain_with_topology / run_tree
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", ALL_KINDS + [AggKind.DENSE_IA])
def test_chain_plan_bit_exact(kind):
    cfg = _cfg(kind)
    g, e, w = _inputs()
    gm = _gmask(cfg, D)
    chain = run_chain(cfg, g, e, w, global_mask=gm)
    plan = compile_plan(K)
    assert plan.shape == (K, 1)
    _assert_same(chain, execute(cfg, plan, g, e, w, global_mask=gm))


@pytest.mark.parametrize("kind", ALL_KINDS)
def test_padded_chain_plan_bit_exact(kind):
    """Padding slots are no-ops: same bits, same EF, same aggregate."""
    cfg = _cfg(kind)
    g, e, w = _inputs(seed=2)
    gm = _gmask(cfg, D)
    chain = run_chain(cfg, g, e, w, global_mask=gm)
    padded = compile_plan(K, pad_to=(K + 4, 3))
    assert padded.shape == (K + 4, 3)
    _assert_same(chain, execute(cfg, padded, g, e, w, global_mask=gm))


@pytest.mark.parametrize("kind", ALL_KINDS)
def test_permuted_chain_plan_bit_exact(kind):
    cfg = _cfg(kind)
    g, e, w = _inputs(seed=3)
    gm = _gmask(cfg, D)
    order = np.asarray([3, 1, 0, 6, 4, 2, 5], np.int32)
    want = run_chain_with_topology(cfg, g, e, w, jnp.asarray(order),
                                   global_mask=gm)
    got = execute(cfg, compile_plan(order), g, e, w, global_mask=gm)
    _assert_same(want, got)


@pytest.mark.parametrize("kind", ALL_KINDS)
def test_routed_tree_padded_plan_bit_exact(kind):
    """Padded tree plan ≡ run_tree (natural shape) on a routed grid."""
    cfg = _cfg(kind)
    tree = shortest_path_tree(tg.grid_graph(2, 3))
    k = tree.num_clients
    g, e, w = _inputs(k=k, seed=4)
    gm = _gmask(cfg, D)
    want = run_tree(cfg, tree, g, e, w, global_mask=gm)
    pad = (tree.max_depth() + 2, k)
    got = execute(cfg, compile_plan(tree, pad_to=pad), g, e, w,
                  global_mask=gm)
    _assert_same(want, got)


def test_stragglers_through_plan():
    cfg = _cfg(AggKind.CL_SIA)
    g, e, w = _inputs(seed=5)
    part = jnp.asarray([1, 0, 1, 1, 0, 1, 1], jnp.float32)
    chain = run_chain(cfg, g, e, w, participate=part)
    got = execute(cfg, compile_plan(K), g, e, w, participate=part)
    _assert_same(chain, got)


def test_compile_plan_rejects_partial_order():
    with pytest.raises(ValueError, match="permutation"):
        compile_plan(np.asarray([0, 2]), num_clients=3)


def test_plan_is_a_pytree():
    plan = compile_plan(K, pad_to=(K + 1, 2))
    leaves, treedef = jax.tree.flatten(plan)
    again = jax.tree.unflatten(treedef, leaves)
    assert again.shape == plan.shape
    assert again.num_clients == plan.num_clients
    np.testing.assert_array_equal(np.asarray(again.node_id),
                                  np.asarray(plan.node_id))


# ---------------------------------------------------------------------------
# Pure-python reference (independent oracle for tree semantics)
# ---------------------------------------------------------------------------

def _ref_tree(cfg, tree, g, e, w, global_mask=None):
    """Node-by-node recursion with the raw node steps — no scan, no vmap."""
    k, d = g.shape
    gm = jnp.zeros((d,), g.dtype) if global_mask is None else global_mask
    step = node_step(cfg)
    inbox = [jnp.zeros((d,), g.dtype) for _ in range(k + 1)]  # [k] = PS
    e_new = [None] * k
    bits = [None] * k
    depth = tree.depths()
    for i in sorted(range(k), key=lambda i: (-depth[i], i)):
        ctx = NodeCtx(global_mask=gm, participate=jnp.float32(1))
        gamma, e_i, st = step(cfg, g[i], inbox[i], e[i], w[i], ctx)
        e_new[i] = e_i
        bits[i] = st.bits
        p = tree.parent[i]
        inbox[k if p == PS else p] = inbox[k if p == PS else p] + gamma
    return inbox[k], jnp.stack(e_new), jnp.stack(bits)


@pytest.mark.parametrize("kind", ALL_KINDS)
def test_tree_plan_matches_python_reference(kind):
    cfg = _cfg(kind)
    #       PS ── 0 ── 1 ─┬─ 2
    #              │      └─ 3 ── 4
    #              └─ 5 ── 6
    tree = AggTree(parent=(PS, 0, 1, 1, 3, 0, 5))
    g, e, w = _inputs(seed=6)
    gm = _gmask(cfg, D)
    agg_ref, e_ref, bits_ref = _ref_tree(cfg, tree, g, e, w, gm)
    got = execute(cfg, compile_plan(tree), g, e, w, global_mask=gm)
    np.testing.assert_allclose(np.asarray(got.aggregate),
                               np.asarray(agg_ref), rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(np.asarray(got.e_new), np.asarray(e_ref),
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(np.asarray(got.stats.bits),
                               np.asarray(bits_ref), rtol=1e-6)


# ---------------------------------------------------------------------------
# TopologySchedule: one jit specialization for many graphs
# ---------------------------------------------------------------------------

def test_schedule_single_jit_specialization():
    """5 plans from ≥3 distinct graphs padded to one (L, W) → one trace."""
    k = 12
    graphs = [tg.path_graph(k), tg.star_graph(k), tg.grid_graph(3, 4),
              tg.walker_delta(3, 4), tg.random_geometric(k, seed=7)]
    sched = TopologySchedule.from_topologies(graphs)
    assert len(sched.plans) == 5
    assert len({p.shape for p in sched.plans}) == 1

    cfg = _cfg(AggKind.CL_SIA, q=9)
    g, e, w = _inputs(k=k, seed=8)
    traces = []

    @jax.jit
    def round_step(plan, g, e, w):
        traces.append(1)            # runs at trace time only
        return execute(cfg, plan, g, e, w).aggregate

    outs = [round_step(sched.plan_at(r), g, e, w) for r in range(10)]
    assert len(traces) == 1
    assert all(o.shape == (D,) for o in outs)


def test_schedule_from_link_events_reroutes():
    g = tg.grid_graph(2, 3)
    # drop the (1, 2) ISL for rounds 2-3; every client must stay reachable
    sched = TopologySchedule.from_link_events(
        g, {2: ([(1, 2)], []), 4: ([], [(1, 2)])}, rounds=6)
    assert len(sched.plans) == 2          # base route + re-route, deduped
    assert sched.round_index == (0, 0, 1, 1, 0, 0)
    assert len({p.shape for p in sched.plans}) == 1
    for p in sched.plans:
        assert float(np.asarray(p.alive).min()) == 1.0


def test_schedule_rejects_mixed_shapes():
    p1 = compile_plan(3)
    p2 = compile_plan(5)
    with pytest.raises(ValueError, match="share one"):
        TopologySchedule(plans=(p1, p2), round_index=(0, 1))


# ---------------------------------------------------------------------------
# Bandwidth-aware budgets
# ---------------------------------------------------------------------------

def test_bandwidth_budgets_reduce_bits():
    """Narrow uplinks get smaller Top-Q budgets → total bits strictly drop
    vs the uniform budget on a heterogeneous-bandwidth constellation."""
    g = tg.walker_delta(3, 4)      # intra 200M / inter 100M / ground 50M bps
    tree = widest_path_tree(g)
    cfg = _cfg(AggKind.CL_SIA, q=9)
    qb = bandwidth_budgets(cfg, tree)
    bw = np.asarray(tree.uplink_bw_bps)
    assert qb.shape == (tree.num_clients,)
    assert qb.max() == cfg.q                      # widest link: full budget
    assert qb[bw < bw.max()].max() < cfg.q        # narrow links: scaled down
    grads, e, w = _inputs(k=tree.num_clients, seed=9)
    uni = execute(cfg, compile_plan(tree), grads, e, w)
    bwa = execute(cfg, compile_plan(tree, q_budget=qb), grads, e, w)
    assert float(jnp.sum(bwa.stats.bits)) < float(jnp.sum(uni.stats.bits))


def test_bandwidth_budget_caps_nnz_per_hop():
    g = tg.walker_delta(3, 4)
    tree = widest_path_tree(g)
    cfg = _cfg(AggKind.CL_SIA, q=9)
    qb = bandwidth_budgets(cfg, tree)
    grads, e, w = _inputs(k=tree.num_clients, seed=10)
    res = execute(cfg, compile_plan(tree, q_budget=qb), grads, e, w)
    nnz = np.asarray(res.stats.nnz_out)
    assert (nnz <= np.asarray(qb)).all(), (nnz, qb)


# ---------------------------------------------------------------------------
# Aggregator object + deprecated wrappers
# ---------------------------------------------------------------------------

def test_aggregator_is_topology_polymorphic():
    tree = shortest_path_tree(tg.grid_graph(2, 3))
    k = tree.num_clients
    cfg = _cfg(AggKind.CL_SIA)
    g, e, w = _inputs(k=k, seed=11)
    agg = Aggregator(cfg, k, D, topology=tree)
    out = agg.round(g, agg.init_state(), w)
    want = run_tree(cfg, tree, g, jnp.zeros((k, D)), w)
    np.testing.assert_array_equal(np.asarray(out.aggregate),
                                  np.asarray(want.aggregate))
    # per-round plan override (schedule-driven training)
    out2 = agg.round(g, agg.init_state(), w, plan=compile_plan(k))
    want2 = run_chain(cfg, g, jnp.zeros((k, D)), w)
    np.testing.assert_array_equal(np.asarray(out2.aggregate),
                                  np.asarray(want2.aggregate))


def test_deprecated_wrappers_still_work():
    from repro.core.api import ChainAggregator, make_aggregator
    g, e, w = _inputs()
    with pytest.warns(DeprecationWarning):
        agg = make_aggregator(_cfg(AggKind.SIA), K, D)
    out = agg.round(g, agg.init_state(), w)
    want = run_chain(_cfg(AggKind.SIA), g, jnp.zeros((K, D)), w)
    np.testing.assert_array_equal(np.asarray(out.aggregate),
                                  np.asarray(want.aggregate))
    with pytest.warns(DeprecationWarning):
        ChainAggregator(_cfg(AggKind.SIA), K, D)


# ---------------------------------------------------------------------------
# Simulator wiring: order_fn (the previously-unreachable chain permutations)
# ---------------------------------------------------------------------------

def _sim(k=6, kind=AggKind.CL_SIA):
    from repro.configs import PAPER
    from repro.data.federated import partition_iid
    from repro.data.synthetic import make_synthetic_mnist
    from repro.fed.simulator import Simulator

    pc = dataclasses.replace(PAPER, num_clients=k)
    train = make_synthetic_mnist(jax.random.PRNGKey(0), k * 40)
    fed = partition_iid(jax.random.PRNGKey(2), train, k)
    return Simulator(pc, AggConfig(kind=kind, q=pc.q), fed, local_lr=pc.lr)


def test_simulator_order_fn_identity_matches_default():
    k = 6
    base = _sim(k).run(5, seed=1)
    perm = _sim(k).run(5, seed=1,
                       order_fn=lambda r, s: np.arange(k, dtype=np.int32))
    np.testing.assert_array_equal(base["loss"], perm["loss"])
    np.testing.assert_array_equal(base["bits"], perm["bits"])


def test_simulator_order_fn_rotating_chain():
    """Rotating visiting orders (healed-chain machinery) reach the
    aggregation path and still train; CL-SIA bits stay constant-length."""
    k = 6
    rng = np.random.default_rng(0)
    orders = [rng.permutation(k).astype(np.int32) for _ in range(3)]
    out = _sim(k).run(9, seed=1, order_fn=lambda r, s: orders[r % 3])
    assert out["loss"][-1] < out["loss"][0]
    assert len(set(out["bits"][2:])) == 1     # constant-length property


def test_simulator_order_fn_guardrails():
    sim = _sim(4)
    sched = TopologySchedule.from_topologies([4, 4])
    with pytest.raises(ValueError, match="order_fn"):
        sim.run(2, order_fn=lambda r, s: np.arange(4),
                topology_schedule=sched)


def test_simulator_topology_schedule_mode():
    k = 6
    sched = TopologySchedule.from_topologies(
        [tg.path_graph(k), tg.star_graph(k), tg.grid_graph(2, 3)])
    out = _sim(k).run(6, seed=1, topology_schedule=sched)
    assert out["loss"][-1] < out["loss"][0]
    assert len(out["bits"]) == 6


def test_pad_preserves_q_budget_semantics():
    """Regression (nested-plan ISSUE satellite): ``AggPlan.pad`` must
    round-trip ``q_budget`` — the padded plan keeps the per-client dynamic
    budgets, the padded round is bit-exact (aggregate, EF, per-hop nnz),
    and the §V bits are identical (padding slots transmit nothing)."""
    cfg = _cfg(AggKind.CL_SIA, q=9)
    g, e, w = _inputs()
    tree = shortest_path_tree(tg.grid_graph(1, K))
    qb = np.asarray([9, 3, 5, 1, 7, 2, 4], np.int32)
    plan = compile_plan(tree, q_budget=qb)
    big = plan.pad((plan.shape[0] + 3, plan.shape[1] + 2))
    assert big.q_budget is not None
    np.testing.assert_array_equal(np.asarray(big.q_budget), qb)
    assert big.num_sinks == plan.num_sinks

    want = execute(cfg, plan, g, e, w)
    got = execute(cfg, big, g, e, w)
    np.testing.assert_array_equal(np.asarray(want.aggregate),
                                  np.asarray(got.aggregate))
    np.testing.assert_array_equal(np.asarray(want.e_new),
                                  np.asarray(got.e_new))
    for field in ("bits", "nnz_out", "nnz_local", "nnz_global"):
        np.testing.assert_array_equal(
            np.asarray(getattr(want.stats, field)),
            np.asarray(getattr(got.stats, field)), err_msg=field)
    # dynamic budgets actually bind per client on both plans
    assert (np.asarray(got.stats.nnz_out) <= np.maximum(qb, 1)).all()
    # and the §V bits stay within the budgeted bound
    from repro.core.algorithms import index_bits
    assert float(jnp.sum(got.stats.bits)) <= float(
        qb.sum() * (cfg.omega + index_bits(D)))
