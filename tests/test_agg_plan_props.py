"""Property test (hypothesis): ``execute(compile_plan(t), ...)`` is
bit-exact to ``run_chain``/``run_chain_with_topology`` on random visiting
orders and to ``run_tree`` on random attachment trees, for all five
algorithms, including plans padded to a larger ``(L, W)``."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.agg import compile_plan, execute
from repro.core.algorithms import AggConfig, AggKind
from repro.core.chain import run_chain, run_chain_with_topology
from repro.topo.tree import PS, AggTree, run_tree

ALL_KINDS = [AggKind.SIA, AggKind.RE_SIA, AggKind.CL_SIA, AggKind.TC_SIA,
             AggKind.CL_TC_SIA]

D = 32


def _gmask(cfg, d):
    if cfg.kind in (AggKind.TC_SIA, AggKind.CL_TC_SIA):
        return jnp.zeros((d,)).at[jnp.arange(cfg.q_global)].set(1.0)
    return None


@settings(max_examples=20, deadline=None)
@given(data=st.data(),
       kind=st.sampled_from(ALL_KINDS),
       seed=st.integers(0, 2**16))
def test_plan_execute_bit_exact_on_random_topologies(data, kind, seed):
    cfg = AggConfig(kind=kind, q=7)
    k = data.draw(st.integers(2, 8), label="k")
    g = jax.random.normal(jax.random.PRNGKey(seed), (k, D))
    e = 0.1 * jax.random.normal(jax.random.PRNGKey(seed + 1), (k, D))
    w = jnp.ones((k,), jnp.float32)
    gm = _gmask(cfg, D)

    # identity chain ≡ run_chain
    want_c = run_chain(cfg, g, e, w, global_mask=gm)
    got_c = execute(cfg, compile_plan(k), g, e, w, global_mask=gm)
    np.testing.assert_array_equal(np.asarray(want_c.aggregate),
                                  np.asarray(got_c.aggregate))

    # random permuted chain ≡ run_chain_with_topology, bit-exact
    order = np.asarray(data.draw(st.permutations(list(range(k))),
                                 label="order"), np.int32)
    want = run_chain_with_topology(cfg, g, e, w, jnp.asarray(order),
                                   global_mask=gm)
    got = execute(cfg, compile_plan(order), g, e, w, global_mask=gm)
    np.testing.assert_array_equal(np.asarray(want.aggregate),
                                  np.asarray(got.aggregate))
    np.testing.assert_array_equal(np.asarray(want.e_new),
                                  np.asarray(got.e_new))
    np.testing.assert_array_equal(np.asarray(want.stats.bits),
                                  np.asarray(got.stats.bits))

    # random attachment tree ≡ run_tree, padded (L, W) plan included
    rng = np.random.default_rng(seed)
    parent = [PS] + [int(rng.integers(-1, i)) for i in range(1, k)]
    tree = AggTree(parent=tuple(parent))
    want_t = run_tree(cfg, tree, g, e, w, global_mask=gm)
    pad_l = data.draw(st.integers(0, 3), label="pad_l")
    pad_w = data.draw(st.integers(0, 2), label="pad_w")
    nat = compile_plan(tree).shape
    got_t = execute(cfg, compile_plan(tree, pad_to=(nat[0] + pad_l,
                                                    nat[1] + pad_w)),
                    g, e, w, global_mask=gm)
    np.testing.assert_array_equal(np.asarray(want_t.aggregate),
                                  np.asarray(got_t.aggregate))
    np.testing.assert_array_equal(np.asarray(want_t.e_new),
                                  np.asarray(got_t.e_new))
    np.testing.assert_array_equal(np.asarray(want_t.stats.bits),
                                  np.asarray(got_t.stats.bits))
