"""Unit + property tests for Top-Q primitives."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import sparsify as sp


def test_topq_keeps_largest():
    x = jnp.asarray([1.0, -5.0, 0.5, 3.0, -2.0])
    out = sp.topq(x, 2)
    np.testing.assert_allclose(np.asarray(out), [0, -5, 0, 3, 0])


def test_topq_mask_matches_topq():
    x = jax.random.normal(jax.random.PRNGKey(0), (257,))
    for q in (1, 17, 256, 257, 300):
        np.testing.assert_allclose(
            np.asarray(sp.topq(x, q)),
            np.asarray(sp.topq_mask(x, q) * x))


def test_topq_edge_cases():
    x = jnp.asarray([1.0, 2.0, 3.0])
    assert int(sp.nnz(sp.topq(x, 0))) == 0
    np.testing.assert_allclose(np.asarray(sp.topq(x, 5)), np.asarray(x))


@settings(max_examples=30, deadline=None)
@given(st.integers(1, 200), st.integers(0, 2**31 - 1))
def test_topq_property_count_and_energy(q, seed):
    """‖S(x,Q)‖₀ = min(Q, d) and S keeps maximal energy (optimality, eq. 3)."""
    d = 256
    x = jax.random.normal(jax.random.PRNGKey(seed), (d,))
    out = sp.topq(x, q)
    assert int(sp.nnz(out)) == min(q, d)
    # energy of kept = sum of q largest squares
    kept = np.sort(np.abs(np.asarray(out)))[::-1][:q]
    best = np.sort(np.abs(np.asarray(x)))[::-1][:q]
    np.testing.assert_allclose(np.sort(kept), np.sort(best), rtol=1e-6)


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 500), st.integers(0, 2**31 - 1))
def test_threshold_topq_overselects_boundedly(q, seed):
    d = 4096
    x = jax.random.normal(jax.random.PRNGKey(seed), (d,))
    tau = sp.threshold_for_topq(x, q, branch=64, rounds=3)
    kept = int(jnp.sum(jnp.abs(x) >= tau))
    assert kept >= min(q, d)
    # over-selection bounded by within-bin ties: loose 2% + 2 bound
    assert kept <= min(q, d) + max(2, int(0.02 * d))


def test_threshold_matches_exact_on_distinct_values():
    x = jnp.asarray(np.random.default_rng(0).permutation(1000).astype(
        np.float32)) + 1.0
    tau = sp.threshold_for_topq(x, 100, branch=64, rounds=4)
    kept = int(jnp.sum(jnp.abs(x) >= tau))
    assert kept == 100


def test_compact_scatter_roundtrip():
    key = jax.random.PRNGKey(3)
    d, q = 512, 40
    x = sp.topq(jax.random.normal(key, (d,)), q)
    vals, idx, cnt = sp.compact(x, q)
    assert int(cnt) == q
    back = sp.scatter(vals, idx, d)
    np.testing.assert_allclose(np.asarray(back), np.asarray(x))


def test_compact_pads_with_sentinel():
    x = jnp.zeros((16,)).at[3].set(5.0)
    vals, idx, cnt = sp.compact(x, 4)
    assert int(cnt) == 1
    assert int((idx == 16).sum()) == 3          # sentinel = d
    np.testing.assert_allclose(np.asarray(sp.scatter(vals, idx, 16)),
                               np.asarray(x))


def test_mask_union_and_support():
    a = jnp.asarray([1.0, 0.0, 1.0, 0.0])
    b = jnp.asarray([0.0, 1.0, 1.0, 0.0])
    np.testing.assert_allclose(np.asarray(sp.mask_union(a, b)), [1, 1, 1, 0])
    np.testing.assert_allclose(
        np.asarray(sp.support(jnp.asarray([0.0, -2.0, 3.0]))), [0, 1, 1])
