"""Shared fixtures. NOTE: no XLA_FLAGS here — smoke tests must see the real
single CPU device; multi-device tests spawn subprocesses that set
``--xla_force_host_platform_device_count`` before importing jax."""

import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")


def run_multidev(script: str, devices: int = 8, timeout: int = 600) -> str:
    """Run a python snippet in a subprocess with N fake devices.

    The snippet must print 'PASS' on success; stdout is returned.
    """
    env = dict(os.environ)
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count={devices}")
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run([sys.executable, "-c", script], env=env,
                          capture_output=True, text=True, timeout=timeout)
    if proc.returncode != 0 or "PASS" not in proc.stdout:
        raise AssertionError(
            f"multidev subprocess failed\nSTDOUT:\n{proc.stdout[-4000:]}\n"
            f"STDERR:\n{proc.stderr[-4000:]}")
    return proc.stdout


@pytest.fixture(scope="session")
def multidev():
    return run_multidev
