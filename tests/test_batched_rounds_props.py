"""Property test (hypothesis): random (B, plan-shape) bucket packings.

Random cohort batches — random cohort count, random mix of chain /
permuted-chain / random-tree topologies, random straggler sets, random
extra padding — always produce, per cohort, the result of a sequential
``execute`` on that cohort's own plan: value leaves and integer §V
counters bitwise, ``err_sq`` to float summation order. And the
:class:`repro.agg.RoundScheduler` never traces more than once per shape
bucket while doing so.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.agg import (CohortRound, RoundScheduler, compile_plan, execute,
                       execute_batched, stack_plans)
from repro.core.algorithms import AggConfig, AggKind
from repro.topo.tree import PS, AggTree

ALL_KINDS = [AggKind.SIA, AggKind.RE_SIA, AggKind.CL_SIA, AggKind.TC_SIA,
             AggKind.CL_TC_SIA]

D = 32


def _assert_result(got, ref):
    """Value leaves and integer counters bitwise; err_sq to float
    summation order (stacked-plan gathers re-associate the reduction)."""
    np.testing.assert_array_equal(np.asarray(got.aggregate),
                                  np.asarray(ref.aggregate))
    np.testing.assert_array_equal(np.asarray(got.e_new),
                                  np.asarray(ref.e_new))
    for fld in ("nnz_out", "nnz_global", "nnz_local", "bits"):
        np.testing.assert_array_equal(np.asarray(getattr(got.stats, fld)),
                                      np.asarray(getattr(ref.stats, fld)))
    np.testing.assert_allclose(np.asarray(got.stats.err_sq),
                               np.asarray(ref.stats.err_sq),
                               rtol=1e-5, atol=1e-5)


def _random_plan(data, k, label):
    shape_kind = data.draw(st.sampled_from(["chain", "perm", "tree"]),
                           label=f"{label}-topology")
    if shape_kind == "chain":
        return compile_plan(k)
    if shape_kind == "perm":
        return compile_plan(data.draw(st.permutations(list(range(k))),
                                      label=f"{label}-order"))
    parent = [PS]
    for i in range(1, k):
        parent.append(data.draw(st.integers(0, i - 1),
                                label=f"{label}-parent{i}"))
    return compile_plan(AggTree(parent=tuple(parent)))


def _inputs(data, k, seed, label):
    r = np.random.default_rng(seed)
    g = jnp.asarray(r.standard_normal((k, D)), jnp.float32)
    e = jnp.asarray(0.1 * r.standard_normal((k, D)), jnp.float32)
    w = jnp.asarray(r.uniform(0.5, 2.0, (k,)), jnp.float32)
    p = jnp.asarray(
        data.draw(st.lists(st.sampled_from([0.0, 1.0]), min_size=k,
                           max_size=k), label=f"{label}-part"),
        jnp.float32)
    return g, e, w, p


def _gmask(cfg, seed):
    if cfg.kind in (AggKind.TC_SIA, AggKind.CL_TC_SIA):
        r = np.random.default_rng(seed + 999)
        sel = r.choice(D, size=cfg.q_global, replace=False)
        return jnp.zeros((D,), jnp.float32).at[jnp.asarray(sel)].set(1.0)
    return None


@settings(max_examples=15, deadline=None)
@given(data=st.data(), kind=st.sampled_from(ALL_KINDS),
       seed=st.integers(0, 2**16))
def test_random_packings_bitwise_per_cohort(data, kind, seed):
    """stack_plans over a random padded bucket == sequential, bitwise."""
    cfg = AggConfig(kind=kind, q=7, q_global=5, q_local=3)
    b = data.draw(st.integers(1, 4), label="B")
    k = data.draw(st.integers(2, 6), label="k")
    plans = [_random_plan(data, k, f"c{i}") for i in range(b)]
    pad_l = data.draw(st.integers(0, 2), label="padL")
    pad_w = data.draw(st.integers(0, 2), label="padW")
    shape = (max(p.shape[0] for p in plans) + pad_l,
             max(p.shape[1] for p in plans) + pad_w)
    stacked = stack_plans([p.pad(shape) for p in plans])

    ins = [_inputs(data, k, seed + 31 * i, f"c{i}") for i in range(b)]
    gm = _gmask(cfg, seed)
    g, e, w, p = (jnp.stack([c[j] for c in ins]) for j in range(4))
    gm_b = None if gm is None else jnp.broadcast_to(gm, (b, D))
    res = execute_batched(cfg, stacked, g, e, w, global_mask=gm_b,
                          participate=p)
    for i in range(b):
        ref = execute(cfg, plans[i], *ins[i][:3], global_mask=gm,
                      participate=ins[i][3])
        _assert_result(jax.tree.map(lambda x: x[i], res), ref)


@settings(max_examples=10, deadline=None)
@given(data=st.data(), seed=st.integers(0, 2**16))
def test_scheduler_random_buckets_bitwise_and_bounded(data, seed):
    """Random multi-bucket submissions: per-cohort bitwise parity and
    spec count ≤ one per (bucket, shape, padded-B)."""
    cfg = AggConfig(kind=AggKind.CL_SIA, q=7)
    sched = RoundScheduler(cfg)
    n_submits = data.draw(st.integers(1, 3), label="submits")
    cid = 0
    for s in range(n_submits):
        subs = []
        for _ in range(data.draw(st.integers(1, 5), label=f"s{s}-n")):
            k = data.draw(st.sampled_from([3, 5]), label=f"s{s}-k")
            plan = _random_plan(data, k, f"s{s}-c{cid}")
            g, e, w, p = _inputs(data, k, seed + 7 * cid, f"s{s}-c{cid}")
            subs.append(CohortRound(cohort_id=cid, plan=plan, grads=g,
                                    e=e, weights=w, participate=p))
            cid += 1
        res = sched.submit(subs)
        for r in subs:
            ref = execute(cfg, r.plan, r.grads, r.e, r.weights,
                          participate=r.participate)
            _assert_result(res[r.cohort_id], ref)
    sched.assert_bucket_specializations()
    assert sched.trace_counter.count <= len(sched._specs)
