"""Per-arch smoke tests (reduced configs): one forward/train step on CPU,
shape + finiteness assertions, and prefill↔forward consistency."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.models import model
from repro.models.stubs import audio_stub_embeds, vision_stub_embeds


def _batch(cfg, b=2, s=16):
    toks = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0,
                              cfg.vocab_size)
    batch = {"tokens": toks,
             "labels": jnp.roll(toks, -1, axis=1)}
    if cfg.frontend == "vision":
        fe, m = vision_stub_embeds(cfg, jax.random.PRNGKey(3), b, s, 4)
        batch |= {"frontend_embeds": fe, "frontend_mask": m}
    elif cfg.frontend == "audio":
        batch |= {"frontend_embeds":
                  audio_stub_embeds(cfg, jax.random.PRNGKey(3), b, s)}
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_smoke_train_step(arch):
    cfg = get_config(arch, smoke=True)
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg)
    (loss, metrics), grads = jax.value_and_grad(
        lambda p: model.loss_fn(cfg, p, batch), has_aux=True)(params)
    assert jnp.isfinite(loss), (arch, loss)
    gnorm = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0
    logits, _ = model.forward(cfg, params, batch["tokens"],
                              batch.get("frontend_embeds"),
                              batch.get("frontend_mask"))
    assert logits.shape == (2, 16, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_smoke_decode_matches_forward(arch):
    """prefill(t_0..t_{n-1}) then decode(t_n) ≡ teacher-forcing logits."""
    cfg = get_config(arch, smoke=True)
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    b, s = 2, 12
    toks = jax.random.randint(jax.random.PRNGKey(2), (b, s), 0,
                              cfg.vocab_size)
    # teacher forcing logits at position s-2 predict token s-1 step
    logits_tf, _ = model.forward(cfg, params, toks)

    cache = model.init_cache(cfg, b, 32)
    last, cache = model.prefill(cfg, params, toks[:, :-1], cache)
    np.testing.assert_allclose(np.asarray(last, np.float32),
                               np.asarray(logits_tf[:, -2], np.float32),
                               rtol=2e-2, atol=2e-2)
    step_logits, cache = model.decode_step(cfg, params, cache,
                                           toks[:, -1], jnp.int32(s - 1))
    np.testing.assert_allclose(np.asarray(step_logits, np.float32),
                               np.asarray(logits_tf[:, -1], np.float32),
                               rtol=2e-2, atol=2e-2)


def test_swa_ring_cache_decode_matches_forward():
    """SWA ring-buffer cache (mixtral-style) stays consistent past window."""
    cfg = get_config("mixtral-8x7b", smoke=True)   # window 32
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    b, total = 1, 48                                # beyond the window
    toks = jax.random.randint(jax.random.PRNGKey(5), (b, total), 0,
                              cfg.vocab_size)
    logits_tf, _ = model.forward(cfg, params, toks)

    cache = model.init_cache(cfg, b, cfg.sliding_window)
    last, cache = model.prefill(cfg, params, toks[:, :32], cache)
    for pos in range(32, total):
        step_logits, cache = model.decode_step(cfg, params, cache,
                                               toks[:, pos], jnp.int32(pos))
        if pos + 1 < total:
            np.testing.assert_allclose(
                np.asarray(step_logits, np.float32),
                np.asarray(logits_tf[:, pos], np.float32),
                rtol=3e-2, atol=3e-2)


def test_gelu_and_tied_variants_exercised():
    g = get_config("granite-34b")
    assert g.mlp_type == "gelu"
    p4 = get_config("phi4-mini-3.8b")
    assert p4.tie_embeddings
    m2 = get_config("mamba2-130m")
    assert m2.tie_embeddings
