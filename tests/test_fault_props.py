"""Property tests (hypothesis): ``StragglerModel.sample`` seed-stream
determinism and ``prev``-correlation semantics — the scenario engine's
deterministic replay rests on these."""

import jax
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.runtime.fault import StragglerModel


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**31 - 1),
       rounds=st.integers(1, 6),
       k=st.integers(1, 16),
       p=st.floats(0.05, 0.95),
       correlated=st.booleans(),
       p_recover=st.floats(0.0, 1.0))
def test_seed_stream_determinism(seed, rounds, k, p, correlated, p_recover):
    """The fold_in(key, round) stream realizes the same masks on every
    replay — bit-identical, prev threading included."""
    sm = StragglerModel(p_straggle=p, correlated=correlated,
                        p_recover=p_recover)
    base = jax.random.PRNGKey(seed)

    def realize():
        out, prev = [], None
        for r in range(rounds):
            m = sm.sample(jax.random.fold_in(base, r), k, prev)
            prev = m
            out.append(np.asarray(m))
        return out

    a, b = realize(), realize()
    for ma, mb in zip(a, b):
        np.testing.assert_array_equal(ma, mb)
        assert set(np.unique(ma)) <= {0.0, 1.0}


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**31 - 1),
       k=st.integers(1, 32),
       p=st.floats(0.05, 0.95),
       prev_bits=st.lists(st.booleans(), min_size=32, max_size=32))
def test_prev_correlation_semantics(seed, k, p, prev_bits):
    """correlated + p_recover=0: a prev-slow client stays slow; a
    prev-fast client draws exactly the fresh (uncorrelated) mask; and the
    correlated mask never resurrects clients the fresh draw slowed."""
    key = jax.random.PRNGKey(seed)
    prev = np.asarray(prev_bits[:k], np.float32)
    fresh = np.asarray(
        StragglerModel(p_straggle=p).sample(key, k), np.float32)
    stuck = np.asarray(
        StragglerModel(p_straggle=p, correlated=True, p_recover=0.0)
        .sample(key, k, prev), np.float32)
    np.testing.assert_array_equal(stuck[prev == 0], 0.0)
    np.testing.assert_array_equal(stuck[prev == 1], fresh[prev == 1])
    assert np.all(stuck <= fresh)
    # p_recover=1: the correlation term vanishes entirely
    free = np.asarray(
        StragglerModel(p_straggle=p, correlated=True, p_recover=1.0)
        .sample(key, k, prev), np.float32)
    np.testing.assert_array_equal(free, fresh)


def test_prev_none_matches_uncorrelated():
    key = jax.random.PRNGKey(3)
    a = StragglerModel(p_straggle=0.4).sample(key, 64)
    b = StragglerModel(p_straggle=0.4, correlated=True).sample(key, 64)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_p_zero_all_participate():
    m = StragglerModel(p_straggle=0.0).sample(jax.random.PRNGKey(0), 9)
    np.testing.assert_array_equal(np.asarray(m), np.ones(9, np.float32))
