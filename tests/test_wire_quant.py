"""Beyond-paper knob: bf16 wire quantization (ω=16) through the ring."""

WIRE = r"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro import compat
from repro.core import ring as ring_mod
from repro.core import sparsify as sp
from repro.core.algorithms import AggConfig, AggKind

K, n = 8, 8 * 64
mesh = compat.make_mesh((K,), ("data",))
G = jax.random.normal(jax.random.PRNGKey(0), (K, n))
EF = jnp.zeros((K, n))
w = jnp.float32(1.0)

def run(wire_dtype):
    cfg = AggConfig(kind=AggKind.CL_SIA, q=5, wire_dtype=wire_dtype,
                    omega=32 if wire_dtype == "float32" else 16)
    def fn(g_l, ef_l):
        final, ef_new, stats = ring_mod.rotated_ring_local(
            cfg, g_l[0], ef_l[0], w, axis="data")
        stats = jax.tree.map(lambda s: jax.lax.psum(s, "data"), stats)
        return final[None], ef_new[None], stats
    return jax.jit(compat.shard_map(
        fn, mesh=mesh, in_specs=(P("data"), P("data")),
        out_specs=(P("data"), P("data"),
                   jax.tree.map(lambda _: P(), ring_mod.RingStats(0., 0., 0.))),
        axis_names={"data"}))(G, EF)

f32_seg, f32_ef, f32_st = run("float32")
bf16_seg, bf16_ef, bf16_st = run("bfloat16")

# quantized wire ≈ exact wire (bf16 rel error on transported values)
denom = np.maximum(np.abs(np.asarray(f32_seg)), 1e-3)
rel = np.max(np.abs(np.asarray(f32_seg) - np.asarray(bf16_seg)) / denom)
assert rel < 2e-2, rel
# support is identical (indices not quantized)
np.testing.assert_array_equal(np.asarray(f32_seg) != 0,
                               np.asarray(bf16_seg) != 0)
# ω accounting halves
assert float(bf16_st.bits) < 0.7 * float(f32_st.bits)
print("PASS")
"""


def test_bf16_wire_quantization(multidev):
    multidev(WIRE, devices=8)
