"""Serve CLI smoke: batched prefill + decode end to end."""

import os
import subprocess
import sys

from conftest import SRC


def test_serve_cli():
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    p = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve",
         "--arch", "mixtral-8x7b", "--batch", "2", "--prompt-len", "16",
         "--gen", "8"],
        env=env, capture_output=True, text=True, timeout=600)
    assert p.returncode == 0, p.stderr[-2000:]
    assert "generated=8 tokens" in p.stdout
    assert "sample generations" in p.stdout
