"""Scenario replay on the device backend: run-twice bit-identical, §V hop
bits bit-identical to host, one jit specialization — 8 fake devices via
subprocess (see conftest)."""

SCENARIO_DEVICE = r"""
import os, tempfile
from repro.obs import iter_trace, validate_trace
from repro.scenario import preset
from repro.scenario.run import run_scenario

tmp = tempfile.mkdtemp()

def rounds_of(path):
    return [r for r in iter_trace(path) if r["kind"] == "round"]

for name in ("relay-cascade", "straggler-storm"):
    paths = {key: os.path.join(tmp, f"{name}_{key}.jsonl")
             for key in ("host", "dev1", "dev2")}
    host = run_scenario(preset(name), backend="host", out=paths["host"])
    dev1 = run_scenario(preset(name), backend="device", out=paths["dev1"])
    dev2 = run_scenario(preset(name), backend="device", out=paths["dev2"])
    assert host["_retraces"] == 1 and dev1["_retraces"] == 1, (
        host["_retraces"], dev1["_retraces"])

    # device replay is bit-deterministic: loss curves AND traces identical
    assert dev1["loss"] == dev2["loss"], name
    assert dev1["bits"] == dev2["bits"], name

    # round-level SS V hop bits are bit-identical across backends
    for a, b in zip(rounds_of(paths["host"]), rounds_of(paths["dev1"])):
        for sa, sb in zip(a["stages"], b["stages"]):
            assert sa["bits"] == sb["bits"], (name, a["round"])
            assert sa["nnz"] == sb["nnz"], (name, a["round"])
        assert a["participation"] == b["participation"], (name, a["round"])

    for p in paths.values():
        assert validate_trace(p)["errors"] == []
    print(f"{name}: device scenario bit-identical (replay + vs host)")
print("PASS")
"""


def test_scenario_device_bit_identical(multidev):
    multidev(SCENARIO_DEVICE, devices=8)
