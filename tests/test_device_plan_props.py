"""Device-plan lowering property tests (hypothesis + multidev subprocess).

Randomized version of tests/test_device_plan.py's equivalence contract:
for *arbitrary* aggregation trees (random parent pointers), permuted chain
orders, and algorithms, the shard_map-lowered execution on 8 forced host
devices matches host ``agg.execute()`` bit-exactly. Each example bakes the
sampled topology into a snippet run through the shared ``run_multidev``
helper (tests/conftest.py).
"""

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import HealthCheck, given, settings, strategies as st

from conftest import run_multidev

K = 8

SNIPPET = r"""
import jax, jax.numpy as jnp, numpy as np
from repro.agg import compile_plan, execute, execute_sharded
from repro.core.algorithms import AggConfig, AggKind
from repro.topo.tree import AggTree, PS

K = {k}
topo = {topo}
kind = AggKind("{kind}")
cfg = AggConfig(kind=kind, q={q})
g = jax.random.normal(jax.random.PRNGKey({seed}), (K, {d}))
e = 0.1 * jax.random.normal(jax.random.PRNGKey({seed} + 1), (K, {d}))
w = jnp.ones((K,), jnp.float32)
gm = None
if kind in (AggKind.TC_SIA, AggKind.CL_TC_SIA):
    gm = jnp.zeros(({d},)).at[jnp.arange(cfg.q_global)].set(1.0)

plan = compile_plan(topo, num_clients=K, pad_to={pad})
want = execute(cfg, plan, g, e, w, global_mask=gm)
got = jax.jit(lambda p, g, e, w: execute_sharded(
    cfg, p, g, e, w, global_mask=gm))(plan, g, e, w)
np.testing.assert_array_equal(np.asarray(want.aggregate),
                              np.asarray(got.aggregate))
np.testing.assert_array_equal(np.asarray(want.e_new), np.asarray(got.e_new))
np.testing.assert_array_equal(np.asarray(want.stats.bits),
                              np.asarray(got.stats.bits))
print("PASS")
"""


def _random_tree_src(parent_choices):
    """Acyclic by construction: parent[i] ∈ {PS} ∪ {0..i−1}."""
    parent = [-1]
    for i, c in enumerate(parent_choices, start=1):
        parent.append(-1 if c >= i else c)
    return f"AggTree(parent=tuple({parent}))"


@settings(max_examples=6, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(
    choices=st.tuples(*[st.integers(0, K - 1) for _ in range(K - 1)]),
    kind=st.sampled_from(["sia", "re_sia", "cl_sia", "tc_sia", "cl_tc_sia"]),
    q=st.integers(1, 13),
    seed=st.integers(0, 2 ** 16),
)
def test_random_tree_device_matches_host(choices, kind, q, seed):
    src = SNIPPET.format(k=K, topo=_random_tree_src(choices), kind=kind,
                         q=q, seed=seed, d=61, pad=(K + 1, K))
    run_multidev(src, devices=K)


@settings(max_examples=4, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(
    perm=st.permutations(list(range(K))),
    kind=st.sampled_from(["cl_sia", "re_sia"]),
    seed=st.integers(0, 2 ** 16),
)
def test_random_order_device_matches_host(perm, kind, seed):
    topo = f"np.asarray({list(perm)}, np.int32)"
    src = SNIPPET.format(k=K, topo=topo, kind=kind, q=7, seed=seed, d=61,
                         pad=(K, 2))
    run_multidev(src, devices=K)
