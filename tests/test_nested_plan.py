"""Nested (staged) aggregation plans: compile_nested / execute_nested.

Host-side contracts of the nested-plan ISSUE:

* ``compile_nested`` lowers stage specs / routed ``NestedTopology``s into
  forest stages whose sink numbering is the inter-stage wiring;
* dense nested aggregation is the exact sum (composition introduces no
  loss without sparsification) and CL mass conservation holds per stage
  (aggregate + every EF tier telescopes to Σ w·g + e);
* the cluster-aware router partitions a constellation and routes
  intra-cluster trees + an inter-cluster relay tree;
* per-stage §V accounting matches the staged closed forms in
  ``core/comm_cost.py`` (CL exact; the DCI wire split matches
  ``dci_bytes_flat_vs_hier`` on chains);
* same-shape nested plans share ONE jit specialization (plans are traced
  pytrees), and padding is bit-exact.

Device equivalence lives in tests/test_nested_device.py.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.agg.nested import (NestedPlan, compile_nested, execute_nested,
                              pod_ring_nested, zero_stage_ef)
from repro.core import comm_cost as cc
from repro.core.algorithms import AggConfig, AggKind
from repro.core.hierarchical import dci_bytes_flat_vs_hier
from repro.topo import graph as tg
from repro.topo.routing import cluster_routed, partition_clusters
from repro.topo.tree import PS, AggTree

ALL_SPARSE = [AggKind.SIA, AggKind.RE_SIA, AggKind.CL_SIA, AggKind.TC_SIA,
              AggKind.CL_TC_SIA]


def _inputs(k, d, seed=0):
    g = jax.random.normal(jax.random.PRNGKey(seed), (k, d))
    e = 0.1 * jax.random.normal(jax.random.PRNGKey(seed + 1), (k, d))
    return g, e, jnp.ones((k,), jnp.float32)


def _gmask(cfg, d):
    if cfg.kind in (AggKind.TC_SIA, AggKind.CL_TC_SIA):
        return jnp.zeros((d,)).at[jnp.arange(cfg.q_global)].set(1.0)
    return None


# ---------------------------------------------------------------------------
# compile_nested structure
# ---------------------------------------------------------------------------

def test_compile_nested_structure():
    nested = pod_ring_nested(2, 4)
    assert nested.num_stages == 2
    assert nested.stage_units == (8, 2)
    assert nested.stages[0].num_sinks == 2
    assert nested.stages[1].num_sinks == 1
    assert nested.q_budget is None
    cl = nested.clustered[0]
    assert cl.num_clusters == 2 and cl.num_units == 4
    assert cl.mesh_aligned() is True and cl.uniform()
    # sink rows: stage-0 roots deliver to K + cluster index
    par = np.asarray(nested.stages[0].parent_row)
    mask = np.asarray(nested.stages[0].slot_mask) > 0
    sinks = par[mask & (par >= 8)]
    assert set(sinks.tolist()) == {8, 9}


def test_compile_nested_validation():
    with pytest.raises(ValueError, match="partition"):
        compile_nested([[((0, 1), None)], [((0,), None)]], num_clients=4)
    with pytest.raises(ValueError, match="two clusters"):
        compile_nested([[((0, 1), None), ((1, 2), None)], [((0, 1), None)]],
                       num_clients=3)
    with pytest.raises(ValueError, match="single cluster"):
        compile_nested([[((0, 1), None), ((2, 3), None)]])
    # wiring: stage-s sinks must equal stage-s+1 clients
    with pytest.raises(ValueError, match="wiring"):
        NestedPlan(stages=(pod_ring_nested(2, 2).stages[0],
                           compile_nested([[((0, 1, 2), None)]],
                                          num_clients=3).stages[0]))


def test_nested_plan_pad_bit_exact():
    cfg = AggConfig(kind=AggKind.CL_SIA, q=5)
    nested = pod_ring_nested(2, 4)
    shape = tuple(tuple(x + 1 for x in sig) if i == 0 else sig
                  for i, sig in enumerate(nested.shape))
    # grow stage 0 by one level/slot/cluster-pad everywhere applicable
    big = nested.pad(((5, 3, 2, 5, 2), (2, 1)))
    g, e, w = _inputs(8, 64)
    want = execute_nested(cfg, nested, g, e, w)
    got = execute_nested(cfg, big, g, e, w)
    np.testing.assert_array_equal(np.asarray(want.aggregate),
                                  np.asarray(got.aggregate))
    np.testing.assert_array_equal(np.asarray(want.e_new),
                                  np.asarray(got.e_new))
    for a, b in zip(want.stage_e_new, got.stage_e_new):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(np.asarray(want.stats.bits),
                                  np.asarray(got.stats.bits))


# ---------------------------------------------------------------------------
# execute_nested semantics
# ---------------------------------------------------------------------------

def test_dense_nested_is_exact_sum():
    k, d = 12, 80
    nt = cluster_routed(tg.grid_graph(3, 4), 3)
    nested = compile_nested(nt)
    g, e, w = _inputs(k, d)
    res = execute_nested(AggConfig(kind=AggKind.DENSE_IA), nested, g, e, w)
    np.testing.assert_allclose(np.asarray(res.aggregate),
                               np.asarray((g + e).sum(0)),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("kind", ALL_SPARSE)
def test_mass_conservation_per_stage(kind):
    k, d = 8, 96
    cfg = AggConfig(kind=kind, q=7)
    nested = pod_ring_nested(2, 4)
    g, e, w = _inputs(k, d)
    res = execute_nested(cfg, nested, g, e, w, global_mask=_gmask(cfg, d))
    lhs = (float(jnp.sum(res.aggregate)) + float(jnp.sum(res.e_new))
           + sum(float(jnp.sum(x)) for x in res.stage_e_new))
    rhs = float(jnp.sum(g + e))
    np.testing.assert_allclose(lhs, rhs, rtol=1e-4)
    # per-stage stats have that stage's unit count
    assert res.stats.bits.shape == (k,)
    assert res.stage_stats[0].bits.shape == (2,)


def test_stage_cfgs_override():
    k, d = 8, 64
    nested = pod_ring_nested(2, 4)
    g, e, w = _inputs(k, d)
    cfg0 = AggConfig(kind=AggKind.CL_SIA, q=4)
    cfg1 = AggConfig(kind=AggKind.CL_SIA, q=9)
    res = execute_nested(cfg0, nested, g, e, w, stage_cfgs=[cfg0, cfg1])
    # inter-stage budget is cfg1's: the relay γ carries up to 9 nonzeros
    assert int(jnp.max(res.stage_stats[0].nnz_out)) <= 9
    assert int(jnp.max(res.stage_stats[0].nnz_out)) > 4
    assert int(jnp.max(res.stats.nnz_out)) <= 4


def test_straggler_and_stub_semantics():
    k, d = 8, 64
    cfg = AggConfig(kind=AggKind.CL_SIA, q=5)
    nested = pod_ring_nested(2, 4)
    g, e, w = _inputs(k, d)
    part = jnp.ones((k,)).at[0].set(0.0)    # pod-0 chain's deepest node
    res = execute_nested(cfg, nested, g, e, w, participate=part)
    # the straggler banks its whole g̃ (weight·g + e) into EF; with no
    # incoming γ to forward it transmits nothing
    np.testing.assert_allclose(np.asarray(res.e_new[0]),
                               np.asarray(g[0] + e[0]), rtol=1e-6)
    assert float(res.stats.bits[0]) == 0.0


# ---------------------------------------------------------------------------
# Cluster-aware router
# ---------------------------------------------------------------------------

def test_partition_clusters_partitions():
    graph = tg.walker_delta(3, 4)
    clusters = partition_clusters(graph, 3)
    members = sorted(i for c in clusters for i in c)
    assert members == list(range(graph.num_clients))


def test_cluster_routed_shapes_and_heads():
    graph = tg.grid_graph(2, 4)
    nt = cluster_routed(graph, 2)
    assert nt.num_clients == graph.num_clients
    assert nt.num_clusters == 2
    assert len(nt.intra) == 2
    assert nt.inter.num_clients == 2
    # every cluster head is local-PS-rooted; every reachable unit relays
    for tree in nt.intra:
        assert any(p == PS for p in tree.parent)
    assert all(nt.inter.reachable)
    # compiles and runs
    nested = compile_nested(nt)
    g, e, w = _inputs(nt.num_clients, 40)
    res = execute_nested(AggConfig(kind=AggKind.CL_SIA, q=4), nested,
                         g, e, w)
    assert res.aggregate.shape == (40,)


def test_cluster_routed_exclude_routes_around_dead_relays():
    """Regression: ``exclude`` must keep dead relays out of the intra
    trees AND the inter-cluster quotient — a dead node is a stub, never a
    live parent carrying traffic."""
    graph = tg.path_graph(6)             # PS=0 — c0 — c1 — … — c5
    dead_node = 3                        # client index 2
    nt = cluster_routed(graph, clusters=[[0, 1, 2], [3, 4, 5]],
                        exclude=[dead_node])
    tree0 = nt.intra[0]
    assert tree0.reachable[2] is False   # the dead client is a stub
    # nobody's parent chain passes through the dead local node
    for i, p in enumerate(tree0.parent):
        assert p != 2 or tree0.reachable[i] is False
    # quotient links through the dead node are gone: on a path graph the
    # only cluster-0 ↔ cluster-1 edge is (3, 4) via the dead node
    assert nt.inter.reachable[1] is False


def test_client_alive_folds_stub_clusters():
    """A quotient-unreachable cluster forwards nothing — its clients must
    drop out of the effective aliveness (and the PS weight denominator)."""
    inter = AggTree(parent=(PS, 0), reachable=(True, False))
    nested = compile_nested(
        [[((0, 1, 2, 3), None), ((4, 5, 6, 7), None)],
         [((0, 1), inter)]])
    alive = np.asarray(nested.client_alive())
    np.testing.assert_array_equal(alive, [1, 1, 1, 1, 0, 0, 0, 0])
    # and the simulator uses it: weight denominator excludes the stub
    # cluster's clients, so a dense round still averages correctly
    g = jnp.ones((8, 16))
    res = execute_nested(AggConfig(kind=AggKind.DENSE_IA), nested, g,
                         jnp.zeros((8, 16)), jnp.ones((8,)))
    np.testing.assert_allclose(
        np.asarray(res.aggregate) / max(float(alive.sum()), 1e-9),
        np.ones((16,)), rtol=1e-6)


def test_cluster_routed_explicit_clusters():
    graph = tg.grid_graph(2, 4)
    nt = cluster_routed(graph, clusters=[[0, 1, 2, 3], [4, 5, 6, 7]])
    assert nt.clusters == ((0, 1, 2, 3), (4, 5, 6, 7))
    nested = compile_nested(nt)
    assert nested.clustered[0].mesh_aligned() is True


# ---------------------------------------------------------------------------
# Staged closed forms (§V)
# ---------------------------------------------------------------------------

def test_nested_cl_bits_match_measured():
    k_p, k_d, d = 2, 4, 256
    cfg = AggConfig(kind=AggKind.CL_SIA, q=6)
    nested = pod_ring_nested(k_p, k_d)
    g, e, w = _inputs(k_p * k_d, d, seed=3)
    res = execute_nested(cfg, nested, g, e, w)
    want = cc.nested_cl_sia_bits([k_p * k_d, k_p], d, cfg.q)
    assert float(jnp.sum(res.stats.bits)) == want[0]
    assert float(jnp.sum(res.stage_stats[0].bits)) == want[1]
    # wire split: everything before the last stage is the cheap tier
    local, scarce = cc.nested_wire_split(want)
    assert local == want[0] and scarce == want[1]


def test_nested_cl_tc_bits_match_measured():
    k_p, k_d, d = 2, 4, 256
    cfg = AggConfig(kind=AggKind.CL_TC_SIA, q=10)   # Q_L=1, Q_G=9
    nested = pod_ring_nested(k_p, k_d)
    g, e, w = _inputs(k_p * k_d, d, seed=5)
    res = execute_nested(cfg, nested, g, e, w, global_mask=_gmask(cfg, d))
    want = cc.nested_cl_tc_sia_bits([k_p * k_d, k_p], d, cfg.q_global,
                                    cfg.q_local)
    assert float(jnp.sum(res.stats.bits)) == want[0]
    assert float(jnp.sum(res.stage_stats[0].bits)) == want[1]


def test_dci_split_matches_hierarchical_model():
    k_p, k_d, d, q = 2, 16, 4096, 10
    payload = q * (cc.idx_bits(d) + 32)
    flat, hier = dci_bytes_flat_vs_hier(k_p, k_d, payload)
    flat2, nested2 = cc.dci_wire_flat_vs_nested(k_p, k_d, d, q)
    assert flat == flat2 and hier == nested2
    assert nested2 * k_d == flat2                 # K_d× DCI reduction


def test_nested_tc_bound_reduces_to_tree_bound():
    sizes0 = [list(range(1, 5)), list(range(1, 5))]   # two 4-chains
    sizes1 = [1, 2]                                   # pod chain
    per_stage = cc.nested_tc_sia_bits_bound(
        [sizes0[0] + sizes0[1], sizes1], 1000, 20, 5)
    # stage entries equal the flat tree bound with that stage's sizes
    want0 = cc.tc_sia_bits_bound_tree(sizes0[0] + sizes0[1], 1000, 20, 5)
    want1 = cc.tc_sia_bits_bound_tree(sizes1, 1000, 20, 5)
    np.testing.assert_allclose(per_stage, (want0, want1))


# ---------------------------------------------------------------------------
# jit amortization
# ---------------------------------------------------------------------------

def test_nested_plans_share_one_specialization():
    k, d = 8, 48
    cfg = AggConfig(kind=AggKind.CL_SIA, q=5)
    g, e, w = _inputs(k, d)
    traces = []

    @jax.jit
    def round_fn(nested, g, e, w):
        traces.append(1)
        return execute_nested(cfg, nested, g, e, w).aggregate

    base = pod_ring_nested(2, 4)
    alt = compile_nested([[((0, 2, 4, 6), None), ((1, 3, 5, 7), None)],
                          [((0, 1), None)]])
    assert base.shape == alt.shape
    round_fn(base, g, e, w)
    round_fn(alt, g, e, w)
    assert len(traces) == 1


def test_topology_schedule_of_nested_plans():
    from repro.agg import TopologySchedule
    nts = [cluster_routed(tg.grid_graph(2, 4), 2), pod_ring_nested(2, 4),
           cluster_routed(tg.walker_delta(2, 4), 2)]
    sched = TopologySchedule.from_topologies(nts)
    assert len(sched) == 3
    shapes = {sched.plan_at(r).shape for r in range(3)}
    assert len(shapes) == 1
    with pytest.raises(ValueError, match="mix"):
        TopologySchedule.from_topologies([pod_ring_nested(2, 4), 8])
