"""Distributed-semantics tests (subprocess with 8 fake devices).

The key equivalence proof: the rotated ring (core/ring.py) on K devices ==
K independent per-segment sequential chains (core/chain.py) — value paths,
error feedback, AND bit accounting.
"""

RING_EQUIV = r"""
import os
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro import compat
from repro.core import ring as ring_mod
from repro.core.algorithms import AggConfig, AggKind
from repro.core.chain import run_chain

K, n = 8, 8 * 64           # 8 ranks, 64-long segments
mesh = compat.make_mesh((K,), ("data",))

for kind in (AggKind.CL_SIA, AggKind.SIA, AggKind.RE_SIA, AggKind.DENSE_IA):
    cfg = AggConfig(kind=kind, q=5)
    G = jax.random.normal(jax.random.PRNGKey(0), (K, n))
    EF = 0.1 * jax.random.normal(jax.random.PRNGKey(1), (K, n))
    w = jnp.float32(1.3)

    def ring_fn(g_l, ef_l):
        final, ef_new, stats = ring_mod.rotated_ring_local(
            cfg, g_l[0], ef_l[0], w, axis="data")
        stats = jax.tree.map(lambda s: jax.lax.psum(s, "data"), stats)
        return final[None], ef_new[None], stats

    final, ef_new, stats = jax.jit(compat.shard_map(
        ring_fn, mesh=mesh, in_specs=(P("data"), P("data")),
        out_specs=(P("data"), P("data"),
                   jax.tree.map(lambda _: P(), ring_mod.RingStats(0., 0., 0.))),
        axis_names={"data"}))(G, EF)

    # reference: per-segment chains. Ring chain for segment s visits ranks
    # s, s+1, ..., s+K-1; chain.run_chain walks k=K→1, i.e. row 0 = LAST
    # visitor = rank (s-1) mod K.
    seg = n // K
    agg_ref = np.zeros((K, seg), np.float32)
    ef_ref = np.zeros((K, n), np.float32)
    bits_ref = 0.0
    for s in range(K):
        order = [(s + t) % K for t in range(K)]      # visit order
        rows = list(reversed(order))                 # run_chain row 0 = last
        g_seg = np.asarray(G)[rows, s * seg:(s + 1) * seg]
        e_seg = np.asarray(EF)[rows, s * seg:(s + 1) * seg]
        res = run_chain(cfg, jnp.asarray(g_seg), jnp.asarray(e_seg),
                        jnp.full((K,), w))
        agg_ref[s] = np.asarray(res.aggregate)
        for i, r in enumerate(rows):
            ef_ref[r, s * seg:(s + 1) * seg] = np.asarray(res.e_new[i])
        bits_ref += float(jnp.sum(res.stats.bits))

    np.testing.assert_allclose(np.asarray(final).reshape(K, seg), agg_ref,
                               rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(ef_new), ef_ref, rtol=2e-5,
                               atol=2e-5)
    np.testing.assert_allclose(float(stats.bits), bits_ref, rtol=1e-6)
    print(f"{kind.value}: ring == per-segment chains OK")
print("PASS")
"""


TRAIN_STEP = r"""
import jax, jax.numpy as jnp, numpy as np
from repro import compat
from repro.configs.base import ModelConfig
from repro.core.algorithms import AggConfig, AggKind
from repro.optim.optimizers import OptConfig
from repro.train.state import TrainConfig
from repro.train import build_train_step, init_state, state_shardings

mesh = compat.make_mesh((4, 2), ("data", "model"))
cfg = ModelConfig(name="tiny", family="dense", num_layers=2, d_model=64,
                  num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=256,
                  head_dim=16, param_dtype="float32")
toks = jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0, 256)
batch = {"tokens": toks, "labels": jnp.roll(toks, -1, axis=1)}

# 1) CL-SIA trains (loss decreases on a fixed batch)
tc = TrainConfig(agg=AggConfig(kind=AggKind.CL_SIA, q=1),
                 opt=OptConfig(name="adamw", lr=1e-3), q_frac=0.05,
                 agg_dtype="float32", ef_dtype="float32")
with compat.set_mesh(mesh):
    st = jax.device_put(init_state(cfg, tc, mesh, jax.random.PRNGKey(0)),
                        state_shardings(cfg, tc, mesh))
    step = jax.jit(build_train_step(cfg, tc, mesh))
    losses = []
    for _ in range(5):
        st, m = step(st, dict(batch))
        losses.append(float(m["loss"]))
assert losses[-1] < losses[0], losses
assert float(m["agg_bits"]) > 0

# 2) DENSE_IA == manual DP+Adam in param space
tc2 = TrainConfig(agg=AggConfig(kind=AggKind.DENSE_IA, q=1),
                  opt=OptConfig(name="adamw", lr=1e-3),
                  agg_dtype="float32", ef_dtype="float32")
with compat.set_mesh(mesh):
    st2 = jax.device_put(init_state(cfg, tc2, mesh, jax.random.PRNGKey(0)),
                         state_shardings(cfg, tc2, mesh))
    s2, _ = jax.jit(build_train_step(cfg, tc2, mesh))(st2, dict(batch))
from repro.models import model as mm
from repro.optim import optimizers as om
from repro.optim.schedule import lr_schedule
p0 = mm.init_params(cfg, jax.random.PRNGKey(0))
g = jax.grad(lambda p: mm.loss_fn(cfg, p, batch)[0])(p0)
ref_p, _ = om.apply_tree(tc2.opt, om.init_tree(tc2.opt, p0), p0, g,
                         lr_schedule(jnp.int32(0), warmup=tc2.lr_warmup,
                                     decay_steps=tc2.lr_decay_steps))
err = max(jax.tree.leaves(jax.tree.map(
    lambda a, b: float(jnp.max(jnp.abs(a - b))), s2.params, ref_p)))
assert err < 3e-5, err

# 3) TCS variant runs and produces bounded wire bits
tc3 = TrainConfig(agg=AggConfig(kind=AggKind.CL_TC_SIA, q=10),
                  opt=OptConfig(name="sgd", lr=1e-2), q_frac=0.05,
                  agg_dtype="float32", ef_dtype="float32")
with compat.set_mesh(mesh):
    st3 = jax.device_put(init_state(cfg, tc3, mesh, jax.random.PRNGKey(0)),
                         state_shardings(cfg, tc3, mesh))
    step3 = jax.jit(build_train_step(cfg, tc3, mesh))
    for _ in range(3):
        st3, m3 = step3(st3, dict(batch))
assert np.isfinite(m3["loss"]) and float(m3["agg_bits"]) > 0

# 4) straggler round: participation mask, loss still finite, EF grows
tc4 = tc
with compat.set_mesh(mesh):
    st4 = jax.device_put(init_state(cfg, tc4, mesh, jax.random.PRNGKey(0)),
                         state_shardings(cfg, tc4, mesh))
    step4 = jax.jit(build_train_step(cfg, tc4, mesh))
    b4 = dict(batch)
    b4["participate"] = jnp.asarray([1., 0., 1., 1.], jnp.float32)
    st4, m4 = step4(st4, b4)
    ef_straggler = float(jnp.sum(jnp.abs(st4.ef[1])))
    ef_active = float(jnp.sum(jnp.abs(st4.ef[0])))
assert np.isfinite(m4["loss"])
assert ef_straggler > ef_active  # straggler banked its whole gradient
print("PASS")
"""


def test_ring_equals_per_segment_chains(multidev):
    multidev(RING_EQUIV, devices=8)


def test_train_step_distributed(multidev):
    multidev(TRAIN_STEP, devices=8)
