"""Scenario engine: spec round-trip, compiler lowering, deterministic
replay (run-twice + replay-from-trace bit-identical, one jit
specialization), and the injected-event telemetry path."""

import json

import numpy as np
import pytest

from repro.scenario import (BandwidthRamp, CompiledScenario, Crash,
                            DeadlineWindow, LinkFlap, PRESETS, Scenario,
                            StragglerWindow, TopologySpec, compile_scenario,
                            preset, scenario_from_trace)


# ---------------------------------------------------------------------------
# Spec schema
# ---------------------------------------------------------------------------

def test_spec_json_roundtrip_presets():
    for name in PRESETS:
        s = preset(name)
        s2 = Scenario.from_dict(json.loads(json.dumps(s.to_dict())))
        assert s2 == s


def test_spec_roundtrip_all_fault_types(tmp_path):
    s = Scenario(
        name="everything", rounds=12, seed=5,
        topology=TopologySpec(kind="grid", clients=8,
                              params={"rows": 2, "cols": 4},
                              routing="widest"),
        agg={"kind": "cl_sia", "q": 10},
        bandwidth_aware=True,
        link_flaps=(LinkFlap(link=(2, 3), start=1, down=2, period=6),),
        crashes=(Crash(node=1, round=3, recover=7),),
        stragglers=(StragglerWindow(p_straggle=0.3, start=2, end=9,
                                    correlated=True, seed=4),),
        ramps=(BandwidthRamp(start=2, end=8, floor=0.25, recover=10,
                             links=((0, 1),)),),
        deadlines=(DeadlineWindow(deadline_s=1.5, start=4, end=8, seed=2),))
    path = tmp_path / "spec.json"
    s.to_json(str(path))
    s2 = Scenario.from_json(str(path))
    assert s2 == s
    assert s2.agg_config().q == 10


def test_spec_validation():
    chain = TopologySpec(kind="chain", clients=4)
    with pytest.raises(ValueError, match="link"):
        Scenario(name="x", rounds=4, topology=chain,
                 link_flaps=(LinkFlap(link=(1, 2)),))
    with pytest.raises(ValueError, match="routing"):
        TopologySpec(kind="grid", routing="fastest")
    with pytest.raises(ValueError, match="recover"):
        Crash(node=0, round=5, recover=5)
    with pytest.raises(ValueError, match="window"):
        BandwidthRamp(start=4, end=4)
    with pytest.raises(ValueError, match="period"):
        LinkFlap(link=(0, 1), down=4, period=2)
    with pytest.raises(ValueError, match="preset"):
        preset("no-such-preset")


def test_fault_timelines():
    fl = LinkFlap(link=(3, 1), start=2, down=2, period=5)
    assert fl.link == (1, 3)                    # canonicalized
    downs = [r for r in range(12) if fl.is_down(r)]
    assert downs == [2, 3, 7, 8]
    one = LinkFlap(link=(0, 1), start=4, down=3)
    assert [r for r in range(10) if one.is_down(r)] == [4, 5, 6]

    rp = BandwidthRamp(start=2, end=6, floor=0.2, recover=8)
    assert rp.factor(0) == 1.0 and rp.factor(2) == 1.0
    assert rp.factor(4) == 0.6                  # halfway down the ramp
    assert rp.factor(6) == 0.2 and rp.factor(7) == 0.2
    assert rp.factor(8) == 1.0                  # snapped back

    cr = Crash(node=2, round=3, recover=6)
    assert [r for r in range(8) if cr.is_dead(r)] == [3, 4, 5]


# ---------------------------------------------------------------------------
# Compiler
# ---------------------------------------------------------------------------

def test_compile_relay_cascade_lowering():
    c = compile_scenario(preset("relay-cascade"))
    s = c.spec
    assert isinstance(c, CompiledScenario)
    assert c.rounds == s.rounds and c.num_clients == 8
    # distinct dead-sets compile once; every plan shares one (L, W)
    dead_sets = {frozenset(cr.node for cr in s.crashes if cr.is_dead(r))
                 for r in range(s.rounds)}
    assert len(c.schedule.plans) == len(dead_sets) < s.rounds
    assert len({p.shape for p in c.schedule.plans}) == 1
    # crashed clients: zero participation + dead plan row
    for r in range(s.rounds):
        plan = c.schedule.plan_at(r)
        for cr in s.crashes:
            if cr.is_dead(r):
                assert c.participation[r, cr.node] == 0.0
                assert plan.alive[cr.node] == 0.0
            else:
                assert plan.alive[cr.node] == 1.0
    # realized event windows
    kinds = sorted(ev["kind"] for ev in c.events)
    assert kinds == ["crash", "crash", "crash"]
    by_node = {ev["args"]["node"]: ev for ev in c.events}
    assert by_node[2]["round"] == 8 and by_node[2]["rounds"] == 8
    assert by_node[5]["rounds"] == s.rounds - 4    # clipped at the horizon


def test_compile_flaps_share_plans_cyclically():
    c = compile_scenario(preset("orbital-eclipse"))
    # periodic flaps revisit configurations → far fewer plans than rounds
    assert len(c.schedule.plans) < c.rounds
    assert len(c.schedule.round_index) == c.rounds
    assert all(p.q_budget is None for p in c.schedule.plans)
    assert len({p.shape for p in c.schedule.plans}) == 1


def test_compile_bandwidth_aware_budgets_follow_ramp():
    s = preset("uplink-degradation")
    c = compile_scenario(s)
    # all-or-none q_budget across the schedule (one pytree structure)
    assert all(p.q_budget is not None for p in c.schedule.plans)
    before = c.schedule.plan_at(0).q_budget
    floored = c.schedule.plan_at(13).q_budget      # both ramps at floor
    assert int(floored.sum()) < int(before.sum())
    after = c.schedule.plan_at(17).q_budget        # ground link recovered
    assert int(after.sum()) > int(floored.sum())


def test_compile_is_deterministic():
    a = compile_scenario(preset("straggler-storm"))
    b = compile_scenario(preset("straggler-storm"))
    np.testing.assert_array_equal(a.participation, b.participation)
    assert a.events == b.events
    # straggling confined to the declared windows
    s = a.spec
    active = [any(w.active(r) for w in s.stragglers)
              or any(d.active(r) for d in s.deadlines)
              for r in range(s.rounds)]
    for r in range(s.rounds):
        if not active[r]:
            np.testing.assert_array_equal(a.participation[r], 1.0)
    assert a.participation.min() == 0.0            # the storm actually hits


def test_compile_rejects_bad_combinations():
    with pytest.raises(ValueError, match="bandwidth_aware"):
        compile_scenario(Scenario(
            name="x", rounds=2, bandwidth_aware=True,
            topology=TopologySpec(kind="chain", clients=4)))
    with pytest.raises(ValueError, match="widest"):
        compile_scenario(Scenario(
            name="x", rounds=2,
            topology=TopologySpec(kind="grid", clients=8,
                                  params={"rows": 2, "cols": 4},
                                  routing="widest", clusters=2)))


# ---------------------------------------------------------------------------
# Deterministic replay through the simulator
# ---------------------------------------------------------------------------

def _small_spec():
    return Scenario(
        name="small-cascade", rounds=8, seed=1,
        topology=TopologySpec(kind="chain", clients=5),
        crashes=(Crash(node=2, round=2, recover=6),),
        stragglers=(StragglerWindow(p_straggle=0.35, start=3, end=7,
                                    correlated=True, seed=9),))


def test_run_twice_and_replay_from_trace_bit_identical(tmp_path):
    from repro.scenario.run import run_scenario

    t1, t2, t3 = (str(tmp_path / f"t{i}.jsonl") for i in (1, 2, 3))
    a = run_scenario(_small_spec(), out=t1)
    b = run_scenario(_small_spec(), out=t2)
    assert a["_retraces"] == 1 and b["_retraces"] == 1
    assert a["loss"] == b["loss"]                  # bit-identical, not close
    assert a["bits"] == b["bits"]

    # a recorded trace alone reconstructs and re-runs the scenario
    spec2, meta = scenario_from_trace(t1)
    assert spec2 == _small_spec()
    assert meta["topology"] == "scenario"
    c = run_scenario(spec2, out=t3)
    assert c["loss"] == a["loss"] and c["bits"] == a["bits"]

    from repro.obs import validate_trace
    assert validate_trace(t1)["errors"] == []


def test_simulator_scenario_exclusivity():
    import dataclasses

    import jax

    from repro.configs import PAPER
    from repro.core.algorithms import AggConfig, AggKind
    from repro.data.federated import partition_iid
    from repro.data.synthetic import make_synthetic_mnist
    from repro.fed.simulator import Simulator

    k = 5
    pc = dataclasses.replace(PAPER, num_clients=k)
    train = make_synthetic_mnist(jax.random.PRNGKey(0), k * 20)
    fed = partition_iid(jax.random.PRNGKey(2), train, k)
    sim = Simulator(pc, AggConfig(kind=AggKind.CL_SIA, q=pc.q), fed)
    spec = _small_spec()
    with pytest.raises(ValueError, match="alone"):
        sim.run(2, scenario=spec, participate_fn=lambda r, s: None)
    wrong_k = Scenario(name="x", rounds=2,
                       topology=TopologySpec(kind="chain", clients=3))
    with pytest.raises(ValueError, match="clients"):
        sim.run(2, scenario=wrong_k)


# ---------------------------------------------------------------------------
# Injected-event telemetry
# ---------------------------------------------------------------------------

def test_injected_events_in_trace_report_and_chrome(tmp_path):
    from repro.obs import iter_trace
    from repro.obs.chrome import FAULT_PID, export_chrome_trace
    from repro.obs.report import summarize
    from repro.scenario.run import run_scenario

    path = str(tmp_path / "trace.jsonl")
    run_scenario(_small_spec(), out=path)

    spans = [r for r in iter_trace(path)
             if r["kind"] == "span" and r["track"] == "scenario"]
    assert len(spans) == 2                      # crash window + stragglers
    meta = next(r for r in iter_trace(path) if r["kind"] == "meta")
    assert meta["scenario_spec"]["name"] == "small-cascade"

    out = summarize(path)
    assert {ev["kind"] for ev in out["injected"]} == {"crash", "stragglers"}
    crash = next(ev for ev in out["injected"] if ev["kind"] == "crash")
    assert crash["round"] == 2 and crash["rounds"] == 4
    # fault windows are round coordinates — they must not pollute the
    # wall-clock phase totals
    assert "crash client 2" not in out.get("phases_s", {})
    assert "scenario_spec" not in out["context"]

    chrome = export_chrome_trace(path)
    events = json.load(open(chrome))["traceEvents"]
    faults = [e for e in events if e.get("cat") == "fault"]
    assert len(faults) == 2
    assert all(e["pid"] == FAULT_PID for e in faults)
    hop_ts = [e["ts"] for e in events if e.get("cat") == "hop"]
    for e in faults:                            # inside the simulated axis
        assert min(hop_ts) <= e["ts"] <= max(hop_ts)


def test_cli_run_and_replay(tmp_path):
    from repro.obs.report import diff
    from repro.scenario.run import main

    spec_path = str(tmp_path / "spec.json")
    _small_spec().to_json(spec_path)
    t1 = str(tmp_path / "a.jsonl")
    t2 = str(tmp_path / "b.jsonl")
    assert main([spec_path, "--out", t1]) == 0
    assert main([t1, "--out", t2]) == 0         # replay straight from trace
    d = diff(t1, t2)
    assert d["rounds_bits_differ"] == []
    assert d["bits_total_delta"] == 0.0
