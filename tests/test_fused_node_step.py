"""Fused (Pallas) vs unfused (jnp) node steps: bit-parity in interpret mode.

Contracts (ISSUE acceptance criteria):

* with ``kernel_mode="always"`` (Pallas-interpret off-TPU) every algorithm's
  node step — scalar and whole-level — produces **the same** aggregate, EF
  rows and §V HopStats as the unfused jnp reference (``kernel_mode="never"``)
  under jit, for chain and padded tree plans, stragglers/stubs, dynamic
  per-node budgets, threshold Top-Q, and bf16 inputs;
* threshold Top-Q keeps ≥ q survivors and §V bits charge the *realized*
  support, not q (regression for the ``topq_by_threshold`` over-selection);
* the compact (values, indices) wire refuses threshold-sparsified configs
  (≥ q survivors would overflow the q wire slots and silently drop
  coordinates);
* the batched threshold bisection (2-D ``threshold_for_topq``) is bitwise
  identical per lane to the vmapped scalar bisection.

Parity is asserted under ``jax.jit`` on both sides: XLA:CPU contracts the
``w·g + e`` multiply-add into an FMA inside any jitted computation (fused
and unfused alike), while un-jitted op-by-op dispatch does not — comparing
a jitted path against an eager one shows 1-ulp FMA noise that has nothing
to do with the kernels.

Everything §V-relevant (aggregate, EF, nnz, bits) is compared **bitwise**.
``err_sq`` — the ‖e‖² float diagnostic — is compared to 1 ulp: it is a
d-term float reduction whose accumulation order XLA picks per compiled
graph, so even two unfused graphs are not guaranteed the same last bit.
"""

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.agg import compile_plan, execute
from repro.agg.device import _use_compact
from repro.core import sparsify as sp
from repro.core.algorithms import (AggConfig, AggKind, NodeCtx, index_bits,
                                   fused_node_steps, level_step, node_step)
from repro.core.chain import run_chain
from repro.topo.tree import AggTree, PS

ALL_KINDS = [AggKind.SIA, AggKind.RE_SIA, AggKind.CL_SIA, AggKind.TC_SIA,
             AggKind.CL_TC_SIA]
IMPLS = ["exact", "threshold"]

K, D = 7, 96
TREE = AggTree(parent=(PS, 0, 1, 1, 3, 0, 5))


def _inputs(k=K, d=D, seed=0, dtype=jnp.float32):
    g = jax.random.normal(jax.random.PRNGKey(seed), (k, d)).astype(dtype)
    e = (0.1 * jax.random.normal(jax.random.PRNGKey(seed + 1),
                                 (k, d))).astype(dtype)
    w = jnp.ones((k,), jnp.float32)
    return g, e, w


def _pair(kind, impl="exact", q=11):
    unfused = AggConfig(kind=kind, q=q, topq_impl=impl, kernel_mode="never")
    return unfused, dataclasses.replace(unfused, kernel_mode="always")


def _gmask(cfg, d, dtype=jnp.float32):
    if cfg.kind in (AggKind.TC_SIA, AggKind.CL_TC_SIA):
        m = jnp.zeros((d,)).at[jnp.arange(cfg.q_global)].set(1.0)
        return m.astype(dtype)
    return None


def _assert_same_stats(a, b, msg=""):
    for field in ("nnz_out", "nnz_global", "nnz_local", "bits"):
        np.testing.assert_array_equal(np.asarray(getattr(a, field)),
                                      np.asarray(getattr(b, field)),
                                      err_msg=f"{msg}/stats.{field}")
    np.testing.assert_allclose(np.asarray(a.err_sq), np.asarray(b.err_sq),
                               rtol=1e-6, err_msg=f"{msg}/stats.err_sq")


def _assert_same_round(a, b, msg=""):
    np.testing.assert_array_equal(np.asarray(a.aggregate, np.float32),
                                  np.asarray(b.aggregate, np.float32),
                                  err_msg=f"{msg}/aggregate")
    np.testing.assert_array_equal(np.asarray(a.e_new, np.float32),
                                  np.asarray(b.e_new, np.float32),
                                  err_msg=f"{msg}/e_new")
    _assert_same_stats(a.stats, b.stats, msg)


# ---------------------------------------------------------------------------
# Scalar node_step parity (the chain / register-ring / clients-kernel path)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("impl", IMPLS)
@pytest.mark.parametrize("kind", ALL_KINDS)
def test_scalar_step_parity(kind, impl):
    cfg_u, cfg_f = _pair(kind, impl)
    g, e, _ = _inputs(k=1)
    gin = jax.random.normal(jax.random.PRNGKey(7), (D,)) * (
        jax.random.uniform(jax.random.PRNGKey(8), (D,)) < 0.1)
    gm = _gmask(cfg_u, D)
    gm = jnp.zeros((D,)) if gm is None else gm
    for p in (1.0, 0.0):
        ctx = NodeCtx(global_mask=gm, participate=jnp.float32(p))
        ru = jax.jit(lambda: node_step(cfg_u)(cfg_u, g[0], gin, e[0],
                                              jnp.float32(1.3), ctx))()
        rf = jax.jit(lambda: node_step(cfg_f)(cfg_f, g[0], gin, e[0],
                                              jnp.float32(1.3), ctx))()
        np.testing.assert_array_equal(np.asarray(ru[0]), np.asarray(rf[0]),
                                      err_msg=f"p={p}/gamma")
        np.testing.assert_array_equal(np.asarray(ru[1]), np.asarray(rf[1]),
                                      err_msg=f"p={p}/e")
        _assert_same_stats(ru[2], rf[2], f"p={p}")


# ---------------------------------------------------------------------------
# Whole-round parity through execute (level_step batched path)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("impl", IMPLS)
@pytest.mark.parametrize("kind", ALL_KINDS)
def test_execute_round_parity_chain_and_padded_tree(kind, impl):
    cfg_u, cfg_f = _pair(kind, impl)
    g, e, w = _inputs(seed=2)
    gm = _gmask(cfg_u, D)
    part = jnp.asarray([1, 0, 1, 1, 0, 1, 1], jnp.float32)
    for name, topo, pad in [("chain", K, None), ("tree", TREE, (K, 4))]:
        plan = compile_plan(topo, pad_to=pad)
        for pname, p in [("all", None), ("stragglers", part)]:
            run_u = jax.jit(functools.partial(execute, cfg_u,
                                              global_mask=gm,
                                              participate=p))
            run_f = jax.jit(functools.partial(execute, cfg_f,
                                              global_mask=gm,
                                              participate=p))
            _assert_same_round(run_u(plan, g, e, w), run_f(plan, g, e, w),
                               f"{kind.value}/{impl}/{name}/{pname}")


@pytest.mark.parametrize("kind", ALL_KINDS)
def test_execute_round_parity_dynamic_budgets(kind):
    cfg_u, cfg_f = _pair(kind)
    g, e, w = _inputs(seed=3)
    gm = _gmask(cfg_u, D)
    qb = np.asarray([5, 3, 5, 2, 5, 1, 4], np.int32)
    plan = compile_plan(TREE, q_budget=qb, pad_to=(K, 3))
    run_u = jax.jit(functools.partial(execute, cfg_u, global_mask=gm))
    run_f = jax.jit(functools.partial(execute, cfg_f, global_mask=gm))
    _assert_same_round(run_u(plan, g, e, w), run_f(plan, g, e, w),
                       f"{kind.value}/q_budget")


@pytest.mark.parametrize("kind", ALL_KINDS)
def test_execute_round_parity_bf16(kind):
    """bf16 inputs promote to f32 on both paths — parity holds bitwise."""
    cfg_u, cfg_f = _pair(kind)
    g, e, w = _inputs(seed=4, dtype=jnp.bfloat16)
    gm = _gmask(cfg_u, D, jnp.bfloat16)
    plan = compile_plan(K)
    run_u = jax.jit(functools.partial(execute, cfg_u, global_mask=gm))
    run_f = jax.jit(functools.partial(execute, cfg_f, global_mask=gm))
    _assert_same_round(run_u(plan, g, e, w), run_f(plan, g, e, w),
                       f"{kind.value}/bf16")


def test_stranded_stub_plan_parity():
    """A plan with a dead stub (alive=0) folds into participate on both
    paths identically."""
    cfg_u, cfg_f = _pair(AggKind.CL_SIA)
    g, e, w = _inputs(seed=5)
    base = compile_plan(TREE)
    alive = np.ones((K,), np.float32)
    alive[4] = 0.0
    plan = dataclasses.replace(base, alive=alive)
    run_u = jax.jit(functools.partial(execute, cfg_u))
    run_f = jax.jit(functools.partial(execute, cfg_f))
    _assert_same_round(run_u(plan, g, e, w), run_f(plan, g, e, w), "stub")


def test_level_step_unfused_is_vmapped_node_step():
    """kernel_mode='never' level_step ≡ the historic vmap of node_step."""
    cfg = AggConfig(kind=AggKind.CL_SIA, q=9, kernel_mode="never")
    g, e, w = _inputs(k=4, seed=6)
    gin = jnp.zeros_like(g)
    gm = jnp.zeros((D,))
    p = jnp.asarray([1, 1, 0, 1], jnp.float32)
    got = level_step(cfg)(g, gin, e, w, p, gm)
    step = node_step(cfg)

    def one(g_r, gin_r, e_r, w_r, p_r):
        return step(cfg, g_r, gin_r, e_r, w_r,
                    NodeCtx(global_mask=gm, participate=p_r))

    want = jax.vmap(one)(g, gin, e, w, p)
    np.testing.assert_array_equal(np.asarray(want[0]), np.asarray(got[0]))
    np.testing.assert_array_equal(np.asarray(want[1]), np.asarray(got[1]))


def test_fused_gate_trace_time():
    """The dispatch decision is static: off by default off-TPU (unless the
    REPRO_PALLAS_INTERPRET=1 CI knob forces interpret mode), on under
    kernel_mode='always', off again for an all-bf16 operand set."""
    import os
    cfg = AggConfig(kind=AggKind.CL_SIA, q=9)
    auto_on = (jax.default_backend() == "tpu"
               or os.environ.get("REPRO_PALLAS_INTERPRET") == "1")
    assert fused_node_steps(cfg) == auto_on
    cfg_f = dataclasses.replace(cfg, kernel_mode="always")
    assert fused_node_steps(cfg_f)
    g = jnp.zeros((4, D), jnp.bfloat16)
    w16 = jnp.ones((4,), jnp.bfloat16)
    assert not fused_node_steps(cfg_f, w16, g, g, g)   # bf16 compute dtype
    w32 = jnp.ones((4,), jnp.float32)
    assert fused_node_steps(cfg_f, w32, g, g, g)       # promotes to f32


def test_one_jit_trace_serves_all_same_shape_plans_fused():
    """The fused path keeps the plan/execute jit-amortization contract."""
    from repro.topo import graph as tg
    from repro.agg import TopologySchedule
    k = 8
    sched = TopologySchedule.from_topologies(
        [tg.path_graph(k), tg.star_graph(k), tg.grid_graph(2, 4)])
    cfg = AggConfig(kind=AggKind.CL_SIA, q=9, kernel_mode="always")
    g, e, w = _inputs(k=k, seed=9)
    traces = []

    @jax.jit
    def round_step(plan, g, e, w):
        traces.append(1)
        return execute(cfg, plan, g, e, w).aggregate

    outs = [round_step(sched.plan_at(r), g, e, w) for r in range(6)]
    assert len(traces) == 1
    assert all(o.shape == (D,) for o in outs)


# ---------------------------------------------------------------------------
# Threshold Top-Q: §V accounting of the realized (≥ q) support
# ---------------------------------------------------------------------------

def test_threshold_bits_charge_realized_nnz():
    """``topq_by_threshold`` keeps ≥ q survivors; HopStats must charge the
    realized support — bits == (ω+⌈log₂d⌉)·nnz_out with nnz_out ≥ q."""
    cfg = AggConfig(kind=AggKind.CL_SIA, q=11, topq_impl="threshold")
    g, e, w = _inputs(seed=10)
    res = run_chain(cfg, g, e, w)
    nnz = np.asarray(res.stats.nnz_out)
    bits = np.asarray(res.stats.bits)
    assert (nnz >= cfg.q).all(), nnz
    word = cfg.omega + index_bits(D)
    np.testing.assert_array_equal(bits, (word * nnz).astype(np.float32))

    # single-hop cross-check against the realized mask of the transmitted γ
    res1 = run_chain(cfg, g[:1], e[:1], w[:1])
    realized = int(jnp.sum(res1.aggregate != 0))
    assert realized >= cfg.q
    assert int(res1.stats.nnz_out[0]) == realized
    assert float(res1.stats.bits[0]) == word * realized


def test_threshold_bits_parity_fused():
    """Fused threshold rounds report the same realized-support bits."""
    cfg_u, cfg_f = _pair(AggKind.CL_SIA, "threshold")
    g, e, w = _inputs(seed=11)
    plan = compile_plan(K)
    ru = jax.jit(functools.partial(execute, cfg_u))(plan, g, e, w)
    rf = jax.jit(functools.partial(execute, cfg_f))(plan, g, e, w)
    np.testing.assert_array_equal(np.asarray(ru.stats.bits),
                                  np.asarray(rf.stats.bits))
    assert (np.asarray(ru.stats.nnz_out) >= cfg_u.q).all()


def test_kernel_mode_validated():
    with pytest.raises(ValueError, match="kernel_mode"):
        AggConfig(kind=AggKind.CL_SIA, q=5, kernel_mode="interpet")


def test_compact_wire_refuses_threshold_topq():
    """≥ q survivors overflow the q compact wire slots — auto must fall
    back to dense and wire='compact' must refuse."""
    plan = compile_plan(K)
    exact = AggConfig(kind=AggKind.CL_SIA, q=9)
    thresh = dataclasses.replace(exact, topq_impl="threshold")
    assert _use_compact(exact, D, plan, False, "auto")
    assert not _use_compact(thresh, D, plan, False, "auto")
    with pytest.raises(ValueError, match="exact Top-Q"):
        _use_compact(thresh, D, plan, False, "compact")


# ---------------------------------------------------------------------------
# Batched threshold bisection ≡ vmapped scalar bisection
# ---------------------------------------------------------------------------

def test_batched_threshold_matches_vmapped_scalar():
    x = jax.random.normal(jax.random.PRNGKey(12), (5, 4096))
    for q in (3, 64, 1000):
        batched = sp.threshold_for_topq(x, q)
        scalar = jax.vmap(lambda row: sp.threshold_for_topq(row, q))(x)
        np.testing.assert_array_equal(np.asarray(batched),
                                      np.asarray(scalar))
        kept = jnp.sum(jnp.abs(x) >= batched[:, None], axis=-1)
        assert (np.asarray(kept) >= q).all()
