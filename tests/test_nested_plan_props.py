"""Nested-plan property tests (hypothesis, host-side).

Randomized contracts over *arbitrary cluster partitions* (random member
assignment, random intra chains/trees, random inter tree) × the five
sparse algorithms:

* dense nested aggregation == the exact sum, whatever the clustering;
* CL mass conservation per stage: aggregate + client EF + every stage EF
  tier == Σ (w·g + e);
* the jit-amortization guard: ≥ N random nested schedules padded to one
  per-stage shape execute under exactly ONE jit specialization.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import HealthCheck, given, settings, strategies as st

from repro.agg.nested import compile_nested, execute_nested
from repro.core.algorithms import AggConfig, AggKind
from repro.topo.tree import PS, AggTree

K, D = 8, 64

ALL_SPARSE = ["sia", "re_sia", "cl_sia", "tc_sia", "cl_tc_sia"]


def _random_tree(rng, m):
    """Random local tree over m nodes: node i's parent ∈ {i+1..m−1, PS}
    (ordered parents ⇒ acyclic)."""
    parent = []
    for i in range(m - 1):
        p = int(rng.integers(i + 1, m + 1))
        parent.append(PS if p == m else p)
    parent.append(PS)
    return AggTree(parent=tuple(parent))


def _random_nested(seed, num_clusters):
    rng = np.random.default_rng(seed)
    perm = rng.permutation(K)
    cuts = sorted(rng.choice(np.arange(1, K), size=num_clusters - 1,
                             replace=False).tolist()) if num_clusters > 1 \
        else []
    members = np.split(perm, cuts)
    stage0 = [(tuple(int(i) for i in mem), _random_tree(rng, len(mem)))
              for mem in members]
    stage1 = [(tuple(range(len(members))),
               _random_tree(rng, len(members)))]
    return compile_nested([stage0, stage1], num_clients=K)


def _inputs(seed):
    g = jax.random.normal(jax.random.PRNGKey(seed), (K, D))
    e = 0.1 * jax.random.normal(jax.random.PRNGKey(seed + 1), (K, D))
    return g, e, jnp.ones((K,), jnp.float32)


@settings(max_examples=15, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(seed=st.integers(0, 10_000), clusters=st.integers(1, 4))
def test_dense_nested_is_exact_sum(seed, clusters):
    nested = _random_nested(seed, clusters)
    g, e, w = _inputs(seed % 97)
    res = execute_nested(AggConfig(kind=AggKind.DENSE_IA), nested, g, e, w)
    np.testing.assert_allclose(np.asarray(res.aggregate),
                               np.asarray((g + e).sum(0)),
                               rtol=1e-5, atol=1e-5)


@settings(max_examples=20, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(seed=st.integers(0, 10_000), clusters=st.integers(1, 4),
       kind=st.sampled_from(ALL_SPARSE), q=st.integers(1, 16))
def test_mass_conservation_per_stage(seed, clusters, kind, q):
    cfg = AggConfig(kind=AggKind(kind), q=q)
    nested = _random_nested(seed, clusters)
    g, e, w = _inputs(seed % 89)
    gm = None
    if cfg.kind in (AggKind.TC_SIA, AggKind.CL_TC_SIA):
        gm = jnp.zeros((D,)).at[jnp.arange(cfg.q_global)].set(1.0)
    res = execute_nested(cfg, nested, g, e, w, global_mask=gm)
    lhs = (float(jnp.sum(res.aggregate)) + float(jnp.sum(res.e_new))
           + sum(float(jnp.sum(x)) for x in res.stage_e_new))
    np.testing.assert_allclose(lhs, float(jnp.sum(g + e)), rtol=1e-3,
                               atol=1e-3)


def test_schedule_of_nested_plans_single_specialization():
    """≥ N random nested schedules padded to one per-stage shape run under
    exactly one jit trace — the NestedPlan pytree keeps every plan array a
    traced argument."""
    from repro.agg.schedule import common_shape

    cfg = AggConfig(kind=AggKind.CL_SIA, q=5)
    plans = [_random_nested(seed, 2) for seed in range(6)]
    shape = common_shape(plans)
    plans = [p.pad(shape) for p in plans]
    g, e, w = _inputs(0)
    traces = []

    @jax.jit
    def round_fn(nested, g, e, w):
        traces.append(1)
        return execute_nested(cfg, nested, g, e, w).aggregate

    outs = [round_fn(p, g, e, w) for p in plans]
    assert len(traces) == 1, len(traces)
    # and the plans genuinely differ (different routes → different sums)
    vals = {float(jnp.sum(o)) for o in outs}
    assert len(vals) > 1
