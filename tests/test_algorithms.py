"""Per-algorithm node-step invariants (paper §III–§IV)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import sparsify as sp
from repro.core.algorithms import (AggConfig, AggKind, NodeCtx, index_bits,
                                   node_step)

D, Q = 300, 12


def _inputs(seed=0, nnz_in=30):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    g = jax.random.normal(k1, (D,))
    e = 0.3 * jax.random.normal(k2, (D,))
    gamma_in = sp.topq(jax.random.normal(k3, (D,)), nnz_in)
    return g, gamma_in, e


def _ctx(mask=None):
    return NodeCtx(global_mask=jnp.zeros((D,)) if mask is None else mask,
                   participate=jnp.float32(1))


def test_sia_error_feedback_conservation():
    """g̃ = ḡ + e' exactly: nothing is lost, only delayed (EF invariant)."""
    cfg = AggConfig(kind=AggKind.SIA, q=Q)
    g, gamma_in, e = _inputs()
    gamma_out, e_new, st = node_step(cfg)(cfg, g, gamma_in, e, 2.0, _ctx())
    gt = 2.0 * g + e
    np.testing.assert_allclose(np.asarray(gamma_out - gamma_in + e_new),
                               np.asarray(gt), rtol=1e-5, atol=1e-6)


def test_re_sia_error_leq_sia_error():
    """Prop. 1: RE-SIA's sparsification error is ≤ SIA's, same support size."""
    for seed in range(5):
        g, gamma_in, e = _inputs(seed)
        cfg_s = AggConfig(kind=AggKind.SIA, q=Q)
        cfg_r = AggConfig(kind=AggKind.RE_SIA, q=Q)
        _, e_s, st_s = node_step(cfg_s)(cfg_s, g, gamma_in, e, 1.0, _ctx())
        _, e_r, st_r = node_step(cfg_r)(cfg_r, g, gamma_in, e, 1.0, _ctx())
        assert float(st_r.err_sq) <= float(st_s.err_sq) + 1e-6
        # identical comm cost (same outgoing support → same bits)
        assert float(st_r.bits) == pytest.approx(float(st_s.bits))


def test_cl_sia_respects_budget():
    cfg = AggConfig(kind=AggKind.CL_SIA, q=Q)
    for nnz_in in (0, 10, 100, 299):
        g, gamma_in, e = _inputs(nnz_in=max(nnz_in, 1))
        gamma_out, e_new, st = node_step(cfg)(cfg, g, gamma_in, e, 1.0,
                                              _ctx())
        assert int(sp.nnz(gamma_out)) <= Q
        assert float(st.bits) <= Q * (cfg.omega + index_bits(D)) + 1e-6


def test_cl_sia_is_topq_of_sum():
    cfg = AggConfig(kind=AggKind.CL_SIA, q=Q)
    g, gamma_in, e = _inputs()
    gamma_out, e_new, _ = node_step(cfg)(cfg, g, gamma_in, e, 1.5, _ctx())
    expect = sp.topq(1.5 * g + e + gamma_in, Q)
    np.testing.assert_allclose(np.asarray(gamma_out), np.asarray(expect),
                               rtol=1e-6)
    np.testing.assert_allclose(np.asarray(e_new),
                               np.asarray(1.5 * g + e + gamma_in - expect),
                               rtol=1e-5, atol=1e-6)


def test_sia_growth_bounds():
    """max(Q,‖γin‖₀) ≤ ‖γout‖₀ ≤ Q+‖γin‖₀ (§III)."""
    cfg = AggConfig(kind=AggKind.SIA, q=Q)
    for seed in range(5):
        g, gamma_in, e = _inputs(seed)
        nnz_in = int(sp.nnz(gamma_in))
        gamma_out, _, _ = node_step(cfg)(cfg, g, gamma_in, e, 1.0, _ctx())
        nnz_out = int(sp.nnz(gamma_out))
        assert max(Q, nnz_in) - 1 <= nnz_out <= Q + nnz_in


def test_tc_sia_mask_semantics():
    """TC-SIA transmits everything inside the global mask + Q_L local."""
    mask = sp.topq_mask(jax.random.normal(jax.random.PRNGKey(9), (D,)), 50)
    cfg = AggConfig(kind=AggKind.TC_SIA, q=Q, q_global=50, q_local=4)
    g, gamma_in, e = _inputs()
    gamma_out, e_new, st = node_step(cfg)(cfg, g, gamma_in, e, 1.0,
                                          _ctx(mask))
    # error is zero inside the global mask (those coords always transmitted)
    np.testing.assert_allclose(np.asarray(e_new * mask), 0, atol=1e-6)
    assert int(st.nnz_global) == 50


def test_cl_tc_sia_budget():
    mask = sp.topq_mask(jax.random.normal(jax.random.PRNGKey(9), (D,)), 50)
    cfg = AggConfig(kind=AggKind.CL_TC_SIA, q=Q, q_global=50, q_local=4)
    g, gamma_in, e = _inputs()
    gamma_out, e_new, st = node_step(cfg)(cfg, g, gamma_in, e, 1.0,
                                          _ctx(mask))
    off_mask = gamma_out * (1 - mask)
    assert int(sp.nnz(off_mask)) <= 4
    assert float(st.bits) == pytest.approx(
        cfg.omega * 50 + (cfg.omega + index_bits(D)) * int(sp.nnz(off_mask)))


def test_dense_ia_exact():
    cfg = AggConfig(kind=AggKind.DENSE_IA, q=1)
    g, gamma_in, e = _inputs()
    gamma_out, e_new, st = node_step(cfg)(cfg, g, gamma_in, e0 := e, 3.0,
                                          _ctx())
    np.testing.assert_allclose(np.asarray(gamma_out),
                               np.asarray(gamma_in + 3.0 * g + e0),
                               rtol=1e-5, atol=1e-6)
    assert float(jnp.sum(jnp.abs(e_new))) == 0.0


@pytest.mark.parametrize("kind", [AggKind.SIA, AggKind.RE_SIA,
                                  AggKind.CL_SIA, AggKind.TC_SIA,
                                  AggKind.CL_TC_SIA, AggKind.DENSE_IA])
def test_straggler_banks_everything(kind):
    """participate=0 → γ forwarded unchanged, full g̃ banked in EF."""
    cfg = AggConfig(kind=kind, q=Q, q_global=50, q_local=4)
    mask = sp.topq_mask(jax.random.normal(jax.random.PRNGKey(9), (D,)), 50)
    g, gamma_in, e = _inputs()
    ctx = NodeCtx(global_mask=mask, participate=jnp.float32(0))
    gamma_out, e_new, _ = node_step(cfg)(cfg, g, gamma_in, e, 2.0, ctx)
    np.testing.assert_allclose(np.asarray(gamma_out), np.asarray(gamma_in),
                               rtol=1e-6)
    np.testing.assert_allclose(np.asarray(e_new), np.asarray(2.0 * g + e),
                               rtol=1e-5, atol=1e-6)


def test_threshold_impl_close_to_exact():
    """CL-SIA with threshold Top-Q ≈ exact (≥ q survivors, same top values)."""
    cfg_e = AggConfig(kind=AggKind.CL_SIA, q=Q, topq_impl="exact")
    cfg_t = AggConfig(kind=AggKind.CL_SIA, q=Q, topq_impl="threshold")
    g, gamma_in, e = _inputs()
    out_e, _, _ = node_step(cfg_e)(cfg_e, g, gamma_in, e, 1.0, _ctx())
    out_t, _, _ = node_step(cfg_t)(cfg_t, g, gamma_in, e, 1.0, _ctx())
    # threshold keeps a superset of the exact support
    sup_e = np.asarray(out_e) != 0
    sup_t = np.asarray(out_t) != 0
    assert (sup_t | sup_e).sum() == sup_t.sum()
    assert sup_t.sum() >= Q
