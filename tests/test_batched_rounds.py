"""Multi-tenant batched rounds: the bitwise cohort contract.

Key contracts (ISSUE acceptance criteria):

* ``execute_batched`` over B cohorts is **bitwise identical**, per cohort,
  to B sequential ``execute`` calls — for all five Algorithm 1–5 node
  steps, on chain/tree/padded plans, with stragglers, in interpret mode
  (``kernel_mode="always"`` → Pallas-interpret off-TPU) as well as on the
  jnp oracle path;
* heterogeneous topologies stack (``stack_plans``) into one launch and
  stay per-cohort bit-exact to each cohort's own plan;
* :class:`repro.agg.RoundScheduler` adds **zero** jit specializations
  beyond one per shape bucket — audited by its trace counter;
* ``Simulator.run_batched`` cohorts match sequential ``run`` per seed and
  the trace collector tags every round record with its cohort id;
* the cohort-batched ``build_train_step`` state/sharding plumbing
  validates its flat-topology constraint.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.agg import (CohortRound, RoundScheduler, compile_plan, execute,
                       execute_batched, stack_plans)
from repro.core.algorithms import AggConfig, AggKind
from repro.topo.tree import PS, AggTree

ALL_KINDS = [AggKind.SIA, AggKind.RE_SIA, AggKind.CL_SIA, AggKind.TC_SIA,
             AggKind.CL_TC_SIA]

K, D, B = 6, 64, 3

TREE = AggTree(parent=(PS, 0, 1, 1, 0, 3))


def _cfg(kind, mode="never", q=9):
    return AggConfig(kind=kind, q=q, kernel_mode=mode)


def _inputs(seed, k=K, d=D):
    r = np.random.default_rng(seed)
    g = jnp.asarray(r.standard_normal((k, d)), jnp.float32)
    e = jnp.asarray(0.1 * r.standard_normal((k, d)), jnp.float32)
    w = jnp.asarray(r.uniform(0.5, 2.0, (k,)), jnp.float32)
    p = jnp.asarray(r.random((k,)) < 0.8, jnp.float32)
    gm = jnp.asarray(r.random((d,)) < 0.3, jnp.float32)
    return g, e, w, p, gm


def _stack(cohorts):
    return tuple(jnp.stack(x) for x in zip(*cohorts))


def _assert_result(got, ref):
    """The batched contract: aggregate/EF/nnz/bits bitwise; err_sq (an
    inexact f32 ‖e‖² accumulation) to float summation order — stacked-plan
    gathers let XLA re-associate it (see execute_batched docstring)."""
    np.testing.assert_array_equal(np.asarray(got.aggregate),
                                  np.asarray(ref.aggregate))
    np.testing.assert_array_equal(np.asarray(got.e_new),
                                  np.asarray(ref.e_new))
    for fld in ("nnz_out", "nnz_global", "nnz_local", "bits"):
        np.testing.assert_array_equal(np.asarray(getattr(got.stats, fld)),
                                      np.asarray(getattr(ref.stats, fld)))
    np.testing.assert_allclose(np.asarray(got.stats.err_sq),
                               np.asarray(ref.stats.err_sq),
                               rtol=1e-5, atol=1e-5)


def _assert_cohort_bitwise(res, refs):
    for i, ref in enumerate(refs):
        _assert_result(jax.tree.map(lambda x: x[i], res), ref)


@pytest.mark.parametrize("kind", ALL_KINDS)
@pytest.mark.parametrize("mode", ["never", "always"])
def test_batched_matches_sequential(kind, mode):
    """B cohorts, one shared plan == B sequential execute calls, bitwise.

    mode="always" forces the fused Pallas path (interpret off-TPU); with
    stragglers and a TCS global mask in the mix.
    """
    cfg = _cfg(kind, mode)
    plans = {"chain": compile_plan(K), "tree": compile_plan(TREE)}
    for name, plan in plans.items():
        ins = [_inputs(31 * i + 7) for i in range(B)]
        g, e, w, p, gm = _stack(ins)
        res = execute_batched(cfg, plan, g, e, w, global_mask=gm,
                              participate=p)
        refs = [execute(cfg, plan, *c[:3], global_mask=c[4],
                        participate=c[3]) for c in ins]
        _assert_cohort_bitwise(res, refs)


@pytest.mark.parametrize("kind", ALL_KINDS)
def test_batched_padded_and_heterogeneous_plans(kind):
    """chain/tree plans re-padded to one (L, W) and stacked: each cohort
    still bitwise equals sequential execute on its own unpadded plan."""
    cfg = _cfg(kind)
    chain, tree = compile_plan(K), compile_plan(TREE)
    shape = (max(chain.shape[0], tree.shape[0]) + 1,
             max(chain.shape[1], tree.shape[1]) + 2)
    plans = [chain, tree, chain]
    stacked = stack_plans([pl.pad(shape) for pl in plans])
    ins = [_inputs(17 * i + 3) for i in range(B)]
    g, e, w, p, gm = _stack(ins)
    res = execute_batched(cfg, stacked, g, e, w, global_mask=gm,
                          participate=p)
    refs = [execute(cfg, plans[i], *ins[i][:3], global_mask=ins[i][4],
                    participate=ins[i][3]) for i in range(B)]
    _assert_cohort_bitwise(res, refs)


def test_batched_interpret_heterogeneous():
    """Stacked heterogeneous plans through the fused interpret path."""
    cfg = _cfg(AggKind.CL_SIA, "always")
    chain, tree = compile_plan(K), compile_plan(TREE)
    shape = (max(chain.shape[0], tree.shape[0]),
             max(chain.shape[1], tree.shape[1]))
    plans = [tree, chain]
    stacked = stack_plans([pl.pad(shape) for pl in plans])
    ins = [_inputs(5 * i + 1) for i in range(2)]
    g, e, w, p, gm = _stack(ins)
    res = execute_batched(cfg, stacked, g, e, w, participate=p)
    refs = [execute(cfg, plans[i], *ins[i][:3], participate=ins[i][3])
            for i in range(2)]
    _assert_cohort_bitwise(res, refs)


def test_batched_rejects_shape_mismatches():
    plan = compile_plan(K)
    g, e, w, p, gm = _stack([_inputs(i) for i in range(B)])
    cfg = _cfg(AggKind.SIA)
    with pytest.raises(ValueError):
        execute_batched(cfg, plan, g[:, :-1], e[:, :-1], w[:, :-1])
    tree = compile_plan(TREE)
    shape = (max(plan.shape[0], tree.shape[0]),
             max(plan.shape[1], tree.shape[1]))
    two = stack_plans([plan.pad(shape), tree.pad(shape)])
    with pytest.raises(ValueError):
        execute_batched(cfg, two, g, e, w)    # 2 stacked plans, 3 cohorts
    with pytest.raises(ValueError):
        stack_plans([plan, tree])             # un-padded shape mismatch


# ---------------------------------------------------------------------------
# RoundScheduler: shape buckets and the jit-specialization audit
# ---------------------------------------------------------------------------

def _rounds(cfg, plans, seed0=0, d=D):
    out = []
    for i, plan in enumerate(plans):
        g, e, w, p, gm = _inputs(seed0 + 11 * i, k=plan.num_clients, d=d)
        out.append(CohortRound(cohort_id=f"t{seed0}-{i}", plan=plan,
                               grads=g, e=e, weights=w, global_mask=gm,
                               participate=p))
    return out


def test_scheduler_one_specialization_per_bucket():
    """Heterogeneous cohorts, repeated submits: results stay bitwise
    sequential and the jit trace count never exceeds one per bucket."""
    cfg = _cfg(AggKind.CL_SIA)
    sched = RoundScheduler(cfg)
    chain, tree = compile_plan(K), compile_plan(TREE)
    small = compile_plan(4)                       # different K → own bucket

    for seed0 in (0, 100, 200):                   # 3 submits, same shapes
        subs = _rounds(cfg, [chain, tree, chain], seed0)
        subs += _rounds(cfg, [small], seed0 + 50)
        res = sched.submit(subs)
        for r in subs:
            ref = execute(cfg, r.plan, r.grads, r.e, r.weights,
                          global_mask=r.global_mask,
                          participate=r.participate)
            _assert_result(res[r.cohort_id], ref)

    # two buckets (K=6 mixed-topology, K=4), each padded-B stable across
    # submits → exactly 2 specializations, and the audit passes
    assert sched.expected_specializations == 2
    assert sched.trace_counter.count == 2
    sched.assert_bucket_specializations()


def test_scheduler_retraces_only_on_shape_growth():
    cfg = _cfg(AggKind.SIA)
    sched = RoundScheduler(cfg)
    chain = compile_plan(K)
    sched.submit(_rounds(cfg, [chain, chain], 0))
    n0 = sched.trace_counter.count
    sched.submit(_rounds(cfg, [chain, chain], 7))     # same bucket: cached
    assert sched.trace_counter.count == n0
    tree = compile_plan(TREE)                          # grows (L, W)
    sched.submit(_rounds(cfg, [tree, chain], 13))
    assert sched.trace_counter.count == n0 + 1
    sched.assert_bucket_specializations()
    # cohort-count padding: 3 cohorts pad to B=4 — a NEW padded-B shape
    sched.submit(_rounds(cfg, [chain, tree, chain], 23))
    sched.assert_bucket_specializations()

    # a tampered audit trips: pretend a spec was never recorded
    sched._specs.pop()
    with pytest.raises(AssertionError):
        sched.assert_bucket_specializations()


def test_scheduler_rejects_stacked_submissions():
    cfg = _cfg(AggKind.SIA)
    sched = RoundScheduler(cfg)
    chain = compile_plan(4)
    stacked = stack_plans([chain, chain])
    g, e, w, p, gm = _inputs(0, k=4)
    with pytest.raises(ValueError):
        sched.submit([CohortRound("x", stacked, g, e, w)])


# ---------------------------------------------------------------------------
# Simulator.run_batched: cohort parity + cohort-tagged traces
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def sim_setup():
    from repro.configs import PAPER
    from repro.data.federated import partition_iid
    from repro.data.synthetic import make_synthetic_mnist
    from repro.fed.simulator import Simulator
    k = 8
    pc = dataclasses.replace(PAPER, num_clients=k)
    train = make_synthetic_mnist(jax.random.PRNGKey(0), k * 60)
    fed = partition_iid(jax.random.PRNGKey(2), train, k)
    cfg = AggConfig(kind=AggKind.CL_SIA, q=pc.q, q_global=pc.q_global,
                    q_local=pc.q_local)
    return Simulator(pc, cfg, fed)


def test_run_batched_matches_sequential_runs(sim_setup, tmp_path):
    from repro.obs.collector import TraceCollector
    from repro.obs.record import iter_trace, validate_record
    from repro.obs.report import summarize

    sim = sim_setup
    seeds = [0, 1]
    trace = str(tmp_path / "batched.jsonl")
    col = TraceCollector(trace)
    out = sim.run_batched(4, seeds=seeds, eval_every=10, collector=col)
    col.close()
    loss = np.asarray(out["loss"])                # [rounds, B]
    assert loss.shape == (4, len(seeds))
    for i, s in enumerate(seeds):
        ref = sim.run(4, seed=s, eval_every=10)
        assert [float(x) for x in ref["loss"]] == list(loss[:, i])

    recs = list(iter_trace(trace))
    errs = [e for r in recs for e in validate_record(r)]
    assert not errs, errs
    rounds = [r for r in recs if r.get("kind") == "round"]
    assert sorted({r["cohort"] for r in rounds}) == [0, 1]
    assert len(rounds) == 4 * len(seeds)
    summary = summarize(trace)
    assert summary["cohorts"] == [0, 1]
    one = summarize(trace, cohort=1)
    assert one["rounds"] == 4


def test_run_batched_straggler_masks(sim_setup):
    sim = sim_setup
    drop = jnp.ones((sim.k,)).at[2].set(0.0)
    out = sim.run_batched(3, seeds=[0, 1],
                          participate_fn=lambda r, state: drop)
    assert np.all(np.isfinite(np.asarray(out["loss"])))


# ---------------------------------------------------------------------------
# train-step plumbing: cohort guard (full parity runs in
# tests/test_ring_shardmap.py-style subprocesses; see the smoke bench)
# ---------------------------------------------------------------------------

def test_train_step_cohorts_rejects_nested_topologies():
    from repro.train.step import build_train_step, init_state
    from repro.configs.base import ModelConfig
    from repro.optim.optimizers import OptConfig
    from repro.train.state import TrainConfig
    from repro import compat

    mesh = compat.make_mesh((1, 1), ("pod", "data"))
    cfg = ModelConfig(name="tiny", family="dense", num_layers=1,
                      d_model=32, num_heads=2, num_kv_heads=2, d_ff=64,
                      vocab_size=64, head_dim=16, param_dtype="float32")
    tc = TrainConfig(agg=AggConfig(kind=AggKind.SIA, q=1),
                     opt=OptConfig(name="sgd", lr=1e-2),
                     agg_dtype="float32", ef_dtype="float32")
    with pytest.raises(ValueError, match="flat topolog"):
        build_train_step(cfg, tc, mesh, topology="hierarchical", cohorts=2)
    with pytest.raises(ValueError, match="flat topolog"):
        init_state(cfg, tc, mesh, jax.random.PRNGKey(0),
                   topology="hierarchical", cohorts=2)
