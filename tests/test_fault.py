"""Fault tolerance: stragglers recover through EF; chains heal."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import PAPER
from repro.core.algorithms import AggConfig, AggKind
from repro.core.chain import run_chain
from repro.data.federated import partition_iid
from repro.data.synthetic import make_synthetic_mnist
from repro.fed.simulator import Simulator
from repro.runtime.fault import StragglerModel, banked_mass, deadline_mask, \
    heal_chain


def test_straggler_mass_recovered_next_round():
    """Round 1: client 2 straggles → its g banked. Round 2: it participates
    → aggregate over both rounds ≈ aggregate without any straggling."""
    K, d, q = 5, 120, 120          # q=d → no sparsification loss
    cfg = AggConfig(kind=AggKind.CL_SIA, q=q)
    g1 = jax.random.normal(jax.random.PRNGKey(0), (K, d))
    g2 = jax.random.normal(jax.random.PRNGKey(1), (K, d))
    w = jnp.ones((K,))

    part = jnp.asarray([1., 1., 0., 1., 1.])
    r1 = run_chain(cfg, g1, jnp.zeros((K, d)), w, participate=part)
    r2 = run_chain(cfg, g2, r1.e_new, w)
    got = np.asarray(r1.aggregate + r2.aggregate)

    f1 = run_chain(cfg, g1, jnp.zeros((K, d)), w)
    f2 = run_chain(cfg, g2, f1.e_new, w)
    want = np.asarray(f1.aggregate + f2.aggregate)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_straggler_banked_mass_visible():
    K, d = 4, 50
    cfg = AggConfig(kind=AggKind.SIA, q=5)
    g = jax.random.normal(jax.random.PRNGKey(2), (K, d))
    part = jnp.asarray([1., 0., 1., 1.])
    r = run_chain(cfg, g, jnp.zeros((K, d)), jnp.ones((K,)),
                  participate=part)
    bm = np.asarray(banked_mass(r.e_new))
    assert bm[1] > bm[0] and bm[1] > bm[2]


def test_deadline_mask():
    times = jnp.asarray([0.5, 2.0, 0.9])
    np.testing.assert_allclose(np.asarray(deadline_mask(times, 1.0)),
                               [1., 0., 1.])


def test_straggler_model_reproducible():
    sm = StragglerModel(p_straggle=0.3)
    m1 = sm.sample(jax.random.PRNGKey(0), 100)
    m2 = sm.sample(jax.random.PRNGKey(0), 100)
    np.testing.assert_array_equal(np.asarray(m1), np.asarray(m2))
    assert 50 <= int(m1.sum()) <= 90


def test_heal_chain():
    order = np.arange(6, dtype=np.int32)
    healed = heal_chain(order, dead=3)
    np.testing.assert_array_equal(healed, [0, 1, 2, 4, 5])


def test_heal_chain_multi_node():
    order = np.asarray([2, 0, 5, 1, 4, 3], np.int32)
    # set form splices all dead nodes at once, preserving survivor order
    np.testing.assert_array_equal(heal_chain(order, {0, 4}), [2, 5, 1, 3])
    np.testing.assert_array_equal(heal_chain(order, [3]),
                                  heal_chain(order, 3))
    np.testing.assert_array_equal(heal_chain(order, ()), order)
    # single-node call stays bit-compatible (dtype included)
    assert heal_chain(order, 3).dtype == np.int32


def test_sim_with_stragglers_still_converges():
    train = make_synthetic_mnist(jax.random.PRNGKey(0), 10 * 100)
    test = make_synthetic_mnist(jax.random.PRNGKey(1), 500)
    import dataclasses
    pc = dataclasses.replace(PAPER, num_clients=10)
    fed = partition_iid(jax.random.PRNGKey(2), train, 10)
    sim = Simulator(pc, AggConfig(kind=AggKind.CL_SIA, q=pc.q), fed)
    sm = StragglerModel(p_straggle=0.3)

    def participate(r, state):
        return sm.sample(jax.random.PRNGKey(1000 + r), 10)

    out = sim.run(80, test_x=test.x, test_y=test.y, eval_every=79,
                  participate_fn=participate)
    assert out["accuracy"][-1][1] > 0.9
