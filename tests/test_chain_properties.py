"""Chain-level properties (hypothesis) + cost-model agreement."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import comm_cost as cc
from repro.core import sparsify as sp
from repro.core.algorithms import AggConfig, AggKind
from repro.core.chain import run_chain, run_chain_with_topology

K, D, Q = 7, 200, 9


def _grads(seed=0, k=K, d=D):
    return jax.random.normal(jax.random.PRNGKey(seed), (k, d))


ALL_KINDS = [AggKind.SIA, AggKind.RE_SIA, AggKind.CL_SIA, AggKind.TC_SIA,
             AggKind.CL_TC_SIA, AggKind.DENSE_IA]


@pytest.mark.parametrize("kind", ALL_KINDS)
def test_mass_conservation(kind):
    """γ₁ + Σ_k e'_k = Σ_k (D_k g_k + e_k): the chain loses nothing."""
    cfg = AggConfig(kind=kind, q=Q)
    g = _grads()
    e = 0.1 * _grads(seed=1)
    w = jnp.arange(1.0, K + 1)
    mask = (sp.topq_mask(_grads(2)[0], 20)
            if kind in (AggKind.TC_SIA, AggKind.CL_TC_SIA) else None)
    res = run_chain(cfg, g, e, w, global_mask=mask)
    lhs = np.asarray(res.aggregate + res.e_new.sum(0))
    rhs = np.asarray((w[:, None] * g + e).sum(0))
    np.testing.assert_allclose(lhs, rhs, rtol=2e-4, atol=2e-4)


def test_cl_sia_measured_bits_match_closed_form():
    cfg = AggConfig(kind=AggKind.CL_SIA, q=Q)
    res = run_chain(cfg, _grads(), jnp.zeros((K, D)), jnp.ones((K,)))
    assert float(jnp.sum(res.stats.bits)) == pytest.approx(
        cc.cl_sia_bits(K, D, Q))


def test_cl_tc_sia_measured_bits_match_closed_form():
    qg, ql = 20, 3
    cfg = AggConfig(kind=AggKind.CL_TC_SIA, q=qg + ql, q_global=qg,
                    q_local=ql)
    mask = sp.topq_mask(_grads(5)[0], qg)
    res = run_chain(cfg, _grads(), jnp.zeros((K, D)), jnp.ones((K,)),
                    global_mask=mask)
    assert float(jnp.sum(res.stats.bits)) <= cc.cl_tc_sia_bits(
        K, D, qg, ql) + 1e-6
    # exact when all Q_L slots fill (dense gradients → they do)
    assert float(jnp.sum(res.stats.bits)) == pytest.approx(
        cc.cl_tc_sia_bits(K, D, qg, ql))


def test_sia_bits_within_worst_case_and_above_cl():
    cfg = AggConfig(kind=AggKind.SIA, q=Q)
    res = run_chain(cfg, _grads(), jnp.zeros((K, D)), jnp.ones((K,)))
    bits = float(jnp.sum(res.stats.bits))
    assert bits <= cc.sia_bits_worst_case(K, D, Q)
    assert bits >= cc.cl_sia_bits(K, D, Q)


def test_prop2_bound_holds_in_expectation():
    """Prop. 2 upper-bounds Σ E‖Λ_k‖₀ for TC-SIA (average over seeds)."""
    qg, ql = 20, 3
    cfg = AggConfig(kind=AggKind.TC_SIA, q=qg + ql, q_global=qg, q_local=ql)
    totals = []
    for seed in range(8):
        mask = sp.topq_mask(_grads(100 + seed)[0], qg)
        res = run_chain(cfg, _grads(seed), jnp.zeros((K, D)),
                        jnp.ones((K,)), global_mask=mask)
        totals.append(float(jnp.sum(res.stats.nnz_local)))
    bound = cc.expected_lambda_nnz_bound(K, D, qg, ql)
    assert np.mean(totals) <= bound * 1.02


@settings(max_examples=15, deadline=None)
@given(st.integers(2, 10), st.integers(1, 30), st.integers(0, 10_000))
def test_cl_sia_hop_budget_property(k, q, seed):
    d = 150
    cfg = AggConfig(kind=AggKind.CL_SIA, q=q)
    g = jax.random.normal(jax.random.PRNGKey(seed), (k, d))
    res = run_chain(cfg, g, jnp.zeros((k, d)), jnp.ones((k,)))
    assert int(jnp.max(res.stats.nnz_out)) <= q
    assert int(sp.nnz(res.aggregate)) <= q


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10_000))
def test_dense_ia_equals_weighted_sum(seed):
    cfg = AggConfig(kind=AggKind.DENSE_IA, q=1)
    g = jax.random.normal(jax.random.PRNGKey(seed), (K, D))
    w = jnp.abs(jax.random.normal(jax.random.PRNGKey(seed + 1), (K,))) + 0.1
    res = run_chain(cfg, g, jnp.zeros((K, D)), w)
    np.testing.assert_allclose(np.asarray(res.aggregate),
                               np.asarray((w[:, None] * g).sum(0)),
                               rtol=2e-4, atol=1e-5)


def test_topology_reordering_preserves_dense_aggregate():
    cfg = AggConfig(kind=AggKind.DENSE_IA, q=1)
    g = _grads()
    w = jnp.ones((K,))
    order = jnp.asarray([3, 1, 6, 0, 2, 5, 4], jnp.int32)
    r1 = run_chain(cfg, g, jnp.zeros((K, D)), w)
    r2 = run_chain_with_topology(cfg, g, jnp.zeros((K, D)), w, order)
    np.testing.assert_allclose(np.asarray(r1.aggregate),
                               np.asarray(r2.aggregate), rtol=2e-4,
                               atol=1e-5)


def test_healed_chain_drops_only_dead_node():
    """Relay failure: chain healed to K−1 nodes ≡ chain without that row."""
    cfg = AggConfig(kind=AggKind.CL_SIA, q=Q)
    g = _grads()
    w = jnp.ones((K,))
    dead = 3
    keep = jnp.asarray([i for i in range(K) if i != dead])
    r_healed = run_chain(cfg, g[keep], jnp.zeros((K - 1, D)), w[keep])
    r_manual = run_chain(cfg, g[keep], jnp.zeros((K - 1, D)),
                         jnp.ones((K - 1,)))
    np.testing.assert_allclose(np.asarray(r_healed.aggregate),
                               np.asarray(r_manual.aggregate))
