"""Hierarchical (pod-aware) two-stage ring: mass conservation + budgets."""

HIER = r"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro import compat
from repro.core.algorithms import AggConfig, AggKind
from repro.core.hierarchical import hierarchical_ring_local, HierStats
from repro.core.ring import RingStats

KP, KD, n = 2, 4, 4 * 2 * 16      # per-rank slice length 128
mesh = compat.make_mesh((KP, KD), ("pod", "data"))

for kind in (AggKind.CL_SIA, AggKind.DENSE_IA):
    cfg = AggConfig(kind=kind, q=4)
    K = KP * KD
    G = jax.random.normal(jax.random.PRNGKey(0), (K, n))
    EF = 0.05 * jax.random.normal(jax.random.PRNGKey(1), (K, n))
    PEF = jnp.zeros((K, n // KD))
    w = jnp.float32(1.0)

    def fn(g_l, ef_l, pef_l):
        seg, ef_new, pef_new, st = hierarchical_ring_local(
            cfg, g_l[0], ef_l[0], pef_l[0], w)
        st = jax.tree.map(lambda s: jax.lax.psum(s, ("pod", "data")), st)
        return seg[None], ef_new[None], pef_new[None], st

    stats_specs = HierStats(
        intra=jax.tree.map(lambda _: P(), RingStats(0., 0., 0.)),
        inter=jax.tree.map(lambda _: P(), RingStats(0., 0., 0.)))
    seg, ef_new, pef_new, st = jax.jit(compat.shard_map(
        fn, mesh=mesh,
        in_specs=(P(("pod", "data")), P(("pod", "data")), P(("pod", "data"))),
        out_specs=(P(("pod", "data")), P(("pod", "data")),
                   P(("pod", "data")), stats_specs),
        axis_names={"pod", "data"}))(G, EF, PEF)

    # mass conservation across BOTH stages:
    #   Σ aggregate + Σ client-EF' + Σ pod-EF' = Σ (w·g + EF)
    lhs = (float(jnp.sum(seg)) + float(jnp.sum(ef_new))
           + float(jnp.sum(pef_new)))
    rhs = float(jnp.sum(w * G + EF))
    np.testing.assert_allclose(lhs, rhs, rtol=1e-4)

    if kind == AggKind.DENSE_IA:
        # dense hierarchical == exact sum, reassembled across owners.
        # stage-1 ring over `data` leaves rank (p, r) owning segment r of
        # pod p's partial; stage-2 over `pod` subdivides it into KP
        # sub-segments with rank (p, r) owning sub-segment p. Check total
        # sum instead of layout: Σ|seg| == Σ|colsums| and every coordinate
        # appears exactly once.
        want = np.asarray((w * G + EF).sum(0))
        got = np.sort(np.asarray(seg).reshape(-1))
        np.testing.assert_allclose(np.sort(want), got, rtol=2e-4, atol=1e-5)
    else:
        # CL budgets: stage-2 output ≤ q per sub-segment chain
        per_rank = np.asarray(seg)            # [K, n/(KD·KP)]
        assert (np.count_nonzero(per_rank, axis=1) <= cfg.q).all()
    print(kind.value, "hierarchical OK; DCI bits stage2:",
          float(st.inter.bits))
print("PASS")
"""


def test_hierarchical_two_stage(multidev):
    multidev(HIER, devices=8)


def test_dci_analytic_model():
    from repro.core.hierarchical import dci_bytes_flat_vs_hier
    flat, hier = dci_bytes_flat_vs_hier(2, 16, payload=1000)
    assert flat == 32_000 and hier == 2_000   # 16× DCI reduction
