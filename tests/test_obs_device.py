"""Device-backend telemetry: traces from the shard_map lowering are
bit-identical to host traces, and the train step's telemetry flag exposes
the EF fault metrics — 8 fake devices via subprocess (see conftest)."""

SIM_DEVICE_TRACE = r"""
import dataclasses, os, tempfile
import jax, numpy as np
from repro.configs import PAPER
from repro.core.algorithms import AggConfig, AggKind
from repro.data.federated import partition_iid
from repro.data.synthetic import make_synthetic_mnist
from repro.fed.simulator import Simulator
from repro.obs import TraceCollector, iter_trace, validate_trace
from repro.topo import graph as tg
from repro.topo.routing import cluster_routed

k = 8
pc = dataclasses.replace(PAPER, num_clients=k)
train = make_synthetic_mnist(jax.random.PRNGKey(0), k * 40)
fed = partition_iid(jax.random.PRNGKey(2), train, k)
cfg = AggConfig(kind=AggKind.CL_SIA, q=pc.q)
tmp = tempfile.mkdtemp()

def trace(sim, name):
    path = os.path.join(tmp, name + ".jsonl")
    sim.run(6, seed=1, collector=TraceCollector(path), flush_every=3)
    assert validate_trace(path)["errors"] == []
    assert sim.trace_counter.count == 1, sim.trace_counter.count
    return [r for r in iter_trace(path) if r["kind"] == "round"]

nt = cluster_routed(tg.grid_graph(2, 4), 2)
pairs = [
    ("flat",
     Simulator(pc, cfg, fed, local_lr=pc.lr),
     Simulator(pc, cfg, fed, local_lr=pc.lr, backend="device")),
    ("nested",
     Simulator(pc, cfg, fed, local_lr=pc.lr, nested_topology=nt),
     Simulator(pc, cfg, fed, local_lr=pc.lr, nested_topology=nt,
               backend="device")),
]
for name, host, dev in pairs:
    rh = trace(host, name + "_host")
    rd = trace(dev, name + "_dev")
    for a, b in zip(rh, rd):
        for sa, sb in zip(a["stages"], b["stages"]):
            assert sa["bits"] == sb["bits"], (name, a["round"])
            assert sa["nnz"] == sb["nnz"], (name, a["round"])
        assert a["totals"]["bits"] == b["totals"]["bits"]
    print(f"{name}: device trace bits bit-identical to host")
print("PASS")
"""


TRAIN_TELEMETRY = r"""
import os, tempfile
import jax, jax.numpy as jnp, numpy as np
from repro import compat
from repro.configs.base import ModelConfig
from repro.core.algorithms import AggConfig, AggKind
from repro.optim.optimizers import OptConfig
from repro.train.state import TrainConfig
from repro.train import build_train_step, init_state, state_shardings
from repro.obs import TraceCollector, validate_trace

mesh = compat.make_mesh((4, 2), ("data", "model"))
cfg = ModelConfig(name="tiny", family="dense", num_layers=2, d_model=64,
                  num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=256,
                  head_dim=16, param_dtype="float32")
toks = jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0, 256)
batch = {"tokens": toks, "labels": jnp.roll(toks, -1, axis=1)}
tc = TrainConfig(agg=AggConfig(kind=AggKind.CL_SIA, q=1),
                 opt=OptConfig(name="adamw", lr=1e-3), q_frac=0.05,
                 agg_dtype="float32", ef_dtype="float32")

with compat.set_mesh(mesh):
    st = jax.device_put(init_state(cfg, tc, mesh, jax.random.PRNGKey(0)),
                        state_shardings(cfg, tc, mesh))
    plain = jax.jit(build_train_step(cfg, tc, mesh))
    tele = jax.jit(build_train_step(cfg, tc, mesh, telemetry=True))

    _, m0 = plain(st, dict(batch))
    assert "ef_mass" not in m0 and "ef_dead_mass" not in m0

    b = dict(batch)
    b["participate"] = jnp.asarray([1., 0., 1., 1.], jnp.float32)
    st1, m1 = tele(st, b)
    # the straggler's bank is exactly the exposed dead mass
    dead_bank = float(jnp.sum(jnp.abs(st1.ef[1])))
    np.testing.assert_allclose(float(m1["ef_dead_mass"]), dead_bank,
                               rtol=1e-6)
    assert float(m1["ef_mass"]) >= dead_bank > 0.0

    # full participation → nothing exposed
    _, m2 = tele(st, dict(batch))
    assert float(m2["ef_dead_mass"]) == 0.0

    path = os.path.join(tempfile.mkdtemp(), "train.jsonl")
    with TraceCollector(path, d=cfg.d_model, num_clients=4) as col:
        col.record_train_metrics(0, jax.device_get(m1))
    assert validate_trace(path)["errors"] == []
print("PASS")
"""


def test_device_traces_bit_identical(multidev):
    multidev(SIM_DEVICE_TRACE, devices=8)


def test_train_step_telemetry_metrics(multidev):
    multidev(TRAIN_TELEMETRY, devices=8)
