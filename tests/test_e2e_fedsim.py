"""End-to-end paper reproduction checks (scaled-down §VI)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import PAPER
from repro.core import comm_cost as cc
from repro.core.algorithms import AggConfig, AggKind
from repro.data.federated import partition_dirichlet, partition_iid
from repro.data.synthetic import make_synthetic_mnist
from repro.fed.simulator import Simulator

K = 10
PC = dataclasses.replace(PAPER, num_clients=K)


@pytest.fixture(scope="module")
def fed_data():
    train = make_synthetic_mnist(jax.random.PRNGKey(0), K * 120)
    test = make_synthetic_mnist(jax.random.PRNGKey(1), 600)
    fed = partition_iid(jax.random.PRNGKey(2), train, K)
    return fed, test


def _agg(kind):
    return AggConfig(kind=kind, q=PC.q, q_global=PC.q_global,
                     q_local=PC.q_local)


@pytest.mark.parametrize("kind", [AggKind.SIA, AggKind.RE_SIA,
                                  AggKind.CL_SIA, AggKind.TC_SIA,
                                  AggKind.CL_TC_SIA, AggKind.DENSE_IA])
def test_all_algorithms_converge(fed_data, kind):
    fed, test = fed_data
    sim = Simulator(PC, _agg(kind), fed)
    out = sim.run(120, test_x=test.x, test_y=test.y, eval_every=119)
    acc = out["accuracy"][-1][1]
    # CL-TC-SIA converges slower (paper Fig 3) — relaxed bar
    bar = 0.75 if kind == AggKind.CL_TC_SIA else 0.9
    assert acc > bar, (kind, acc)


def test_comm_cost_ordering_matches_paper(fed_data):
    """Fig 2a ordering: CL-TC < CL < TC < SIA ≈ RE < dense IA."""
    fed, _ = fed_data
    bits = {}
    for kind in (AggKind.CL_TC_SIA, AggKind.CL_SIA, AggKind.TC_SIA,
                 AggKind.SIA, AggKind.RE_SIA, AggKind.DENSE_IA):
        sim = Simulator(PC, _agg(kind), fed)
        out = sim.run(20)
        bits[kind] = np.mean(out["bits"][5:])   # skip warmup rounds
    assert bits[AggKind.CL_TC_SIA] < bits[AggKind.CL_SIA]
    assert bits[AggKind.CL_SIA] < bits[AggKind.TC_SIA]
    assert bits[AggKind.TC_SIA] < bits[AggKind.SIA]
    assert bits[AggKind.SIA] == pytest.approx(bits[AggKind.RE_SIA],
                                              rel=0.15)
    assert bits[AggKind.SIA] < bits[AggKind.DENSE_IA]


def test_cl_sia_bits_exactly_closed_form(fed_data):
    fed, _ = fed_data
    sim = Simulator(PC, _agg(AggKind.CL_SIA), fed)
    out = sim.run(10)
    expect = cc.cl_sia_bits(K, PC.d, PC.q)
    for b in out["bits"][2:]:
        assert b == pytest.approx(expect)


def test_fig2b_normalized_efficiency(fed_data):
    """CL-SIA meets unsparsified IA's efficiency: K transmissions-equiv."""
    fed, _ = fed_data
    sim = Simulator(PC, _agg(AggKind.CL_SIA), fed)
    out = sim.run(10)
    norm = cc.normalized_efficiency(out["bits"][-1], PC.d, PC.q)
    assert norm == pytest.approx(K, rel=1e-6)
    # SIA must be strictly worse (support growth), routing worse still
    sim2 = Simulator(PC, _agg(AggKind.SIA), fed)
    out2 = sim2.run(10)
    norm2 = cc.normalized_efficiency(np.mean(out2["bits"][5:]), PC.d, PC.q)
    assert norm2 > 1.5 * K
    routing = cc.normalized_efficiency(
        cc.routing_sparse_bits(K, PC.d, PC.q), PC.d, PC.q)
    assert routing == pytest.approx((K * K + K) / 2)


def test_dirichlet_noniid_still_converges(fed_data):
    _, test = fed_data
    train = make_synthetic_mnist(jax.random.PRNGKey(5), K * 120)
    fed = partition_dirichlet(jax.random.PRNGKey(6), train, K, alpha=0.3)
    sim = Simulator(PC, _agg(AggKind.CL_SIA), fed)
    out = sim.run(150, test_x=test.x, test_y=test.y, eval_every=149)
    assert out["accuracy"][-1][1] > 0.85
