"""Round telemetry (repro.obs): trace/ground-truth consistency, schema,
report CLI, jit-neutrality, sync batching, and the ‖e_dead‖ fault metric.

The load-bearing invariants:

* per-hop bits in the trace are exactly the executor's HopStats, which on
  full-participation rounds equal the §V closed forms (CL-SIA exact, the
  Prop-2 ceiling for TC-SIA) — on chain, tree, and nested plans;
* the recorded critical path reproduces ``topo.tree.round_latency_s``;
* attaching a collector adds zero jit specializations (trace counter);
* the history loop syncs device→host once per flush, not per round;
* ``ef_dead_mass`` is Σ of non-participants' banked ‖e‖₁, driven through
  a scripted relay death.
"""

import dataclasses
import json

import jax
import numpy as np
import pytest

import repro.topo.graph as tg
from repro.configs import PAPER
from repro.core import comm_cost as cc
from repro.core.algorithms import AggConfig, AggKind
from repro.data.federated import partition_iid
from repro.data.synthetic import make_synthetic_mnist
from repro.fed import simulator as sim_mod
from repro.fed.simulator import Simulator
from repro.fed.topology import FailureSchedule, TreeTopology
from repro.obs import (TraceCollector, export_chrome_trace, iter_trace,
                       plan_meta, subtree_sizes_from_parent, validate_trace)
from repro.obs.report import main as report_main
from repro.runtime.fault import dead_banked_mass
from repro.topo.routing import cluster_routed
from repro.topo.tree import round_latency_s

K = 8
PC = dataclasses.replace(PAPER, num_clients=K)
IDX = cc.idx_bits(PC.d)


@pytest.fixture(scope="module")
def fed():
    train = make_synthetic_mnist(jax.random.PRNGKey(0), K * 40)
    return partition_iid(jax.random.PRNGKey(2), train, K)


def _cfg(kind=AggKind.CL_SIA):
    return AggConfig(kind=kind, q=PC.q, q_global=PC.q_global,
                     q_local=PC.q_local)


def _rounds(path):
    return [r for r in iter_trace(str(path)) if r["kind"] == "round"]


# ---------------------------------------------------------------------------
# Trace == HopStats == closed forms
# ---------------------------------------------------------------------------

def test_chain_trace_bits_exact(fed, tmp_path):
    path = tmp_path / "chain.jsonl"
    sim = Simulator(PC, _cfg(), fed, local_lr=PC.lr)
    with TraceCollector(str(path)) as col:
        out = sim.run(5, collector=col, flush_every=2)
    assert validate_trace(str(path))["errors"] == []
    per_hop = PC.q * (32 + IDX)          # CL-SIA constant-length uplink
    for r, rec in enumerate(_rounds(path)):
        assert rec["stages"][0]["bits"] == [per_hop] * K
        assert rec["totals"]["bits"] == cc.cl_sia_bits(K, PC.d, PC.q)
        assert rec["totals"]["bits"] == out["bits"][r]
        assert rec["totals"]["bits_global"] == 0
        assert rec["totals"]["bits_local"] == rec["totals"]["bits"]
        # chain forest: every subtree size 1..K appears exactly once
        sizes = subtree_sizes_from_parent(rec["plan"]["stages"][0]["parent"])
        assert sorted(sizes.tolist()) == list(range(1, K + 1))


def test_tree_trace_crit_path_matches_link_model(fed, tmp_path):
    path = tmp_path / "tree.jsonl"
    topo = TreeTopology(tg.walker_delta(2, K // 2, gateways=(1, K // 2)),
                        routing="widest")
    sim = Simulator(PC, _cfg(), fed, local_lr=PC.lr, tree_topology=topo)
    with TraceCollector(str(path)) as col:
        sim.run(4, collector=col)
    tree = topo.tree()
    for rec in _rounds(path):
        assert rec["totals"]["bits"] == cc.cl_sia_bits_tree(K, PC.d, PC.q)
        want = round_latency_s(tree, np.asarray(rec["stages"][0]["bits"]))
        assert rec["crit_path_s"] == pytest.approx(want, rel=1e-12)
        # timeline self-consistency: crit path is the latest delivery
        assert rec["crit_path_s"] == pytest.approx(
            max(rec["stages"][0]["t1_s"]))


def test_tc_sia_under_recorded_prop2_bound(fed, tmp_path):
    path = tmp_path / "tc.jsonl"
    sim = Simulator(PC, _cfg(AggKind.TC_SIA), fed, local_lr=PC.lr)
    with TraceCollector(str(path)) as col:
        sim.run(5, collector=col)
    for rec in _rounds(path):
        sizes = subtree_sizes_from_parent(rec["plan"]["stages"][0]["parent"])
        bound = cc.tc_sia_bits_bound_tree(sizes, PC.d, PC.q_global,
                                          PC.q_local, 32)
        # Prop-2 bounds the EXPECTED λ-nnz — individual rounds fluctuate
        # around it (random support overlaps), so allow 2%
        assert rec["totals"]["bits"] <= 1.02 * bound
        assert rec["totals"]["bits_global"] + rec["totals"]["bits_local"] \
            == pytest.approx(rec["totals"]["bits"])


def test_nested_trace_per_stage_bits(fed, tmp_path):
    path = tmp_path / "nested.jsonl"
    nt = cluster_routed(tg.grid_graph(2, K // 2), 2)
    sim = Simulator(PC, _cfg(), fed, local_lr=PC.lr, nested_topology=nt)
    with TraceCollector(str(path)) as col:
        out = sim.run(4, collector=col)
    assert validate_trace(str(path))["errors"] == []
    stage_want = cc.nested_cl_sia_bits([K, 2], PC.d, PC.q)
    for r, rec in enumerate(_rounds(path)):
        assert rec["plan"]["type"] == "nested"
        assert len(rec["stages"]) == 2
        assert [sum(s["bits"]) for s in rec["stages"]] == list(stage_want)
        assert rec["totals"]["bits"] == sum(stage_want) == out["bits"][r]
        # stage 1 has its own EF tier mass recorded
        assert len(rec["stages"][1]["ef_mass"]) == 2


# ---------------------------------------------------------------------------
# jit-neutrality + zero-cost disabled
# ---------------------------------------------------------------------------

def test_collector_adds_no_jit_specialization(fed, tmp_path):
    bare = Simulator(PC, _cfg(), fed, local_lr=PC.lr)
    bare.run(6)
    assert bare.trace_counter.count == 1
    traced = Simulator(PC, _cfg(), fed, local_lr=PC.lr)
    with TraceCollector(str(tmp_path / "t.jsonl")) as col:
        traced.run(6, collector=col, flush_every=2)
    assert traced.trace_counter.count == 1


def test_disabled_collector_is_noop(tmp_path):
    path = tmp_path / "off.jsonl"
    col = TraceCollector(str(path), enabled=False)
    assert col.record_span("x", 0.0, 1.0) is None
    assert col.record_round(0, None) is None       # never touches stats
    col.close()
    assert not path.exists()
    assert TraceCollector(None).enabled is False


# ---------------------------------------------------------------------------
# Sync batching (satellite: one device_get per flush)
# ---------------------------------------------------------------------------

def test_history_syncs_once_per_flush(fed, monkeypatch):
    fetches = []
    real = sim_mod._fetch_logs
    monkeypatch.setattr(sim_mod, "_fetch_logs",
                        lambda buf: fetches.append(len(buf)) or real(buf))
    sim = Simulator(PC, _cfg(), fed, local_lr=PC.lr)
    out = sim.run(10, flush_every=4)
    assert [n for n in fetches if n] == [4, 4, 2]
    assert len(out["bits"]) == 10


def test_flush_cadence_does_not_change_curves(fed):
    a = Simulator(PC, _cfg(), fed, local_lr=PC.lr).run(7, flush_every=1)
    b = Simulator(PC, _cfg(), fed, local_lr=PC.lr).run(7, flush_every=100)
    assert a["loss"] == b["loss"]
    assert a["bits"] == b["bits"]
    assert a["nnz"] == b["nnz"]


# ---------------------------------------------------------------------------
# ‖e_dead‖ fault metric (satellite: scripted relay death)
# ---------------------------------------------------------------------------

def test_dead_banked_mass_unit():
    ef = np.asarray([[1., -2.], [3., 4.], [0., -5.]], np.float32)
    part = np.asarray([1., 0., 0.], np.float32)
    assert float(dead_banked_mass(ef, part)) == pytest.approx(7.0 + 5.0)
    assert float(dead_banked_mass(ef, np.ones(3, np.float32))) == 0.0


def test_relay_death_exposes_ef_dead_mass(fed, tmp_path):
    path = tmp_path / "death.jsonl"
    topo = TreeTopology(tg.walker_delta(2, K // 2, gateways=(1, K // 2)),
                        routing="widest")
    sim = Simulator(PC, _cfg(), fed, local_lr=PC.lr, tree_topology=topo)
    fails = FailureSchedule(K, {2: ([0], []), 5: ([], [0])})
    with TraceCollector(str(path)) as col:
        sim.run(7, failure_schedule=fails, collector=col)
    recs = _rounds(path)
    for rec in recs:
        # defining identity: Σ of non-participants' banked ‖e‖₁
        dead = [m for m, p in zip(rec["stages"][0]["ef_mass"],
                                  rec["participation"]) if p == 0]
        assert rec["ef_dead_mass"] == pytest.approx(sum(dead), rel=1e-6)
    assert all(r["ef_dead_mass"] == 0 for r in recs[:2])
    assert all(r["ef_dead_mass"] > 0 for r in recs[2:5])      # client 0 dead
    assert all(r["ef_dead_mass"] == 0 for r in recs[5:])      # recovered


# ---------------------------------------------------------------------------
# Schema validation + report CLI + Chrome export
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def trace_file(fed, tmp_path_factory):
    path = tmp_path_factory.mktemp("obs") / "trace.jsonl"
    sim = Simulator(PC, _cfg(), fed, local_lr=PC.lr)
    with TraceCollector(str(path)) as col:
        sim.run(5, collector=col, flush_every=2)
    return str(path)


def test_validate_rejects_malformed(trace_file, tmp_path):
    bad = tmp_path / "bad.jsonl"
    lines = open(trace_file).read().splitlines()
    round_line = next(ln for ln in lines
                      if json.loads(ln)["kind"] == "round")
    rec = json.loads(round_line)
    del rec["totals"]
    rec["stages"][0]["bits"] = "oops"
    bad.write_text("\n".join([lines[0], json.dumps(rec)]) + "\n")
    res = validate_trace(str(bad))
    assert any("bits" in e for e in res["errors"])
    assert any("totals" in e for e in res["errors"])
    # and a trace without a meta head is rejected
    nometa = tmp_path / "nometa.jsonl"
    nometa.write_text(round_line + "\n")
    assert any("meta" in e for e in validate_trace(str(nometa))["errors"])


def test_report_cli(trace_file, tmp_path, capsys):
    assert report_main(["validate", trace_file]) == 0
    assert report_main(["summary", trace_file]) == 0
    txt = capsys.readouterr().out
    assert "bit-identical" in txt and "cl_sia" in txt
    assert report_main(["summary", trace_file, "--json"]) == 0
    summary = json.loads(capsys.readouterr().out)
    assert summary["closed_form"]["matches"] == 5
    assert summary["rounds"] == 5
    assert report_main(["diff", trace_file, trace_file]) == 0
    assert "identical" in capsys.readouterr().out
    bad = tmp_path / "bad.jsonl"
    bad.write_text('{"schema": "nope/9", "kind": "mystery"}\n')
    assert report_main(["validate", str(bad)]) == 1


def test_chrome_export(trace_file, tmp_path):
    out = export_chrome_trace(trace_file, str(tmp_path / "c.json"))
    doc = json.load(open(out))
    hops = [e for e in doc["traceEvents"] if e.get("cat") == "hop"]
    spans = [e for e in doc["traceEvents"] if e.get("cat") == "span"]
    assert len(hops) == 5 * K            # every hop of every round
    assert spans                          # simulator flush spans
    assert all(e["dur"] > 0 for e in hops)
    # rounds are laid head-to-tail: starts strictly increase per round
    starts = sorted({e["args"]["round"]: e["ts"] for e in hops}.items())
    assert all(a[1] < b[1] for a, b in zip(starts, starts[1:]))


def test_plan_meta_roundtrip(fed):
    from repro.agg import compile_plan
    plan = compile_plan(K)
    meta = plan_meta(plan)
    assert meta["type"] == "flat" and len(meta["stages"]) == 1
    st = meta["stages"][0]
    assert len(st["parent"]) == K
    assert sum(1 for p in st["parent"] if p < 0) == 1      # one PS uplink
    assert subtree_sizes_from_parent(st["parent"]).max() == K


def test_record_train_metrics_adapter(tmp_path):
    path = tmp_path / "train.jsonl"
    with TraceCollector(str(path), d=PC.d, num_clients=4) as col:
        for step in range(3):
            col.record_train_metrics(step, {
                "agg_bits": 1234.0, "agg_nnz": 77.0, "agg_err_sq": 0.5,
                "loss": 2.0 - step * 0.1, "ef_mass": 9.0,
                "ef_dead_mass": 0.0})
    assert validate_trace(str(path))["errors"] == []
    recs = _rounds(path)
    assert [r["totals"]["bits"] for r in recs] == [1234.0] * 3
    assert recs[-1]["loss"] == pytest.approx(1.8)
