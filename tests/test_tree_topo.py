"""repro.topo: graph builders, routing, and tree aggregation.

Key contracts (ISSUE acceptance criteria):
* path-graph ``run_tree`` is **bit-exact** to ``run_chain`` for all five
  Algorithm 1–5 node steps (aggregate, EF, and ``HopStats.bits``);
* star-graph mass conservation;
* measured tree bits equal the ``comm_cost`` tree closed forms for dense IA
  (and CL-SIA) on non-path trees;
* tree closed forms reduce to the chain closed forms on a path.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import comm_cost as cc
from repro.core.algorithms import AggConfig, AggKind
from repro.core.chain import run_chain
from repro.topo import graph as tg
from repro.topo.routing import shortest_path_tree, widest_path_tree
from repro.topo.tree import (PS, AggTree, path_tree, round_latency_s,
                             run_tree, star_tree)

ALL_KINDS = [AggKind.SIA, AggKind.RE_SIA, AggKind.CL_SIA, AggKind.TC_SIA,
             AggKind.CL_TC_SIA]

K, D = 7, 96


def _inputs(k=K, d=D, seed=0):
    g = jax.random.normal(jax.random.PRNGKey(seed), (k, d))
    e = 0.1 * jax.random.normal(jax.random.PRNGKey(seed + 1), (k, d))
    w = jnp.ones((k,), jnp.float32)
    return g, e, w


def _cfg(kind, q=11):
    return AggConfig(kind=kind, q=q)


def _gmask(cfg, d):
    if cfg.kind in (AggKind.TC_SIA, AggKind.CL_TC_SIA):
        return jnp.zeros((d,)).at[jnp.arange(cfg.q_global)].set(1.0)
    return None


# ---------------------------------------------------------------------------
# run_tree ≡ run_chain on a path graph (bit-exact)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", ALL_KINDS + [AggKind.DENSE_IA])
def test_path_tree_bit_exact_vs_chain(kind):
    cfg = _cfg(kind)
    g, e, w = _inputs()
    gm = _gmask(cfg, D)
    chain = run_chain(cfg, g, e, w, global_mask=gm)
    tree = run_tree(cfg, path_tree(K), g, e, w, global_mask=gm)
    np.testing.assert_array_equal(np.asarray(chain.aggregate),
                                  np.asarray(tree.aggregate))
    np.testing.assert_array_equal(np.asarray(chain.e_new),
                                  np.asarray(tree.e_new))
    np.testing.assert_array_equal(np.asarray(chain.stats.bits),
                                  np.asarray(tree.stats.bits))
    np.testing.assert_array_equal(np.asarray(chain.stats.nnz_out),
                                  np.asarray(tree.stats.nnz_out))


@pytest.mark.parametrize("kind", ALL_KINDS)
def test_path_tree_bit_exact_with_stragglers(kind):
    cfg = _cfg(kind)
    g, e, w = _inputs(seed=3)
    gm = _gmask(cfg, D)
    part = jnp.asarray([1, 0, 1, 1, 0, 1, 1], jnp.float32)
    chain = run_chain(cfg, g, e, w, global_mask=gm, participate=part)
    tree = run_tree(cfg, path_tree(K), g, e, w, global_mask=gm,
                    participate=part)
    np.testing.assert_array_equal(np.asarray(chain.aggregate),
                                  np.asarray(tree.aggregate))
    np.testing.assert_array_equal(np.asarray(chain.e_new),
                                  np.asarray(tree.e_new))


# ---------------------------------------------------------------------------
# Mass conservation / EF telescoping on non-path trees
# ---------------------------------------------------------------------------

def test_star_dense_mass_conservation():
    cfg = _cfg(AggKind.DENSE_IA)
    g, e, w = _inputs()
    res = run_tree(cfg, star_tree(K), g, e, w)
    want = np.asarray((w[:, None] * g + e).sum(0))
    np.testing.assert_allclose(np.asarray(res.aggregate), want, atol=1e-5)
    assert float(jnp.abs(res.e_new).max()) == 0.0


@pytest.mark.parametrize("kind", ALL_KINDS)
def test_tree_mass_conservation_with_ef(kind):
    """Σ contributions = aggregate + Σ EF (the telescoping identity that
    makes EF unbiased) on a branchy tree."""
    cfg = _cfg(kind)
    #       PS ── 0 ── 1 ─┬─ 2
    #              │      └─ 3 ── 4
    #              └─ 5 ── 6
    tree = AggTree(parent=(PS, 0, 1, 1, 3, 0, 5))
    g, e, w = _inputs(seed=5)
    gm = _gmask(cfg, D)
    res = run_tree(cfg, tree, g, e, w, global_mask=gm)
    total_in = np.asarray((w[:, None] * g + e).sum(0))
    total_out = np.asarray(res.aggregate) + np.asarray(res.e_new.sum(0))
    np.testing.assert_allclose(total_out, total_in, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# Measured bits match the tree closed forms
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("make_tree", [
    lambda: star_tree(6),
    lambda: AggTree(parent=(PS, 0, 1, 1, 3, 0, 5)),
    lambda: shortest_path_tree(tg.grid_graph(2, 3)),
])
def test_dense_ia_bits_match_closed_form(make_tree):
    tree = make_tree()
    k = tree.num_clients
    cfg = _cfg(AggKind.DENSE_IA)
    g, e, w = _inputs(k=k)
    res = run_tree(cfg, tree, g, e, w)
    got = float(jnp.sum(res.stats.bits))
    want = cc.dense_ia_bits_tree(k, D, cfg.omega)
    assert got == want, (got, want)


def test_cl_sia_bits_match_closed_form_on_tree():
    tree = shortest_path_tree(tg.walker_delta(2, 3))
    k = tree.num_clients
    cfg = _cfg(AggKind.CL_SIA, q=9)
    g, e, w = _inputs(k=k, seed=11)
    res = run_tree(cfg, tree, g, e, w)
    got = float(jnp.sum(res.stats.bits))
    want = cc.cl_sia_bits_tree(k, D, cfg.q, cfg.omega)
    assert got == want, (got, want)


def test_sia_bits_below_worst_case_on_tree():
    tree = shortest_path_tree(tg.grid_graph(2, 3))
    cfg = _cfg(AggKind.SIA, q=5)
    g, e, w = _inputs(k=tree.num_clients, seed=2)
    res = run_tree(cfg, tree, g, e, w)
    got = float(jnp.sum(res.stats.bits))
    cap = cc.sia_bits_worst_case_tree(tree.subtree_sizes(), D, cfg.q,
                                      cfg.omega)
    assert got <= cap


# ---------------------------------------------------------------------------
# Tree closed forms reduce to the chain closed forms on a path
# ---------------------------------------------------------------------------

def test_tree_closed_forms_reduce_to_chain():
    k, d, q, omega = 12, 7850, 78, 32
    tree = path_tree(k)
    depths = tree.depths()
    sub = tree.subtree_sizes()
    assert list(depths) == list(range(1, k + 1))
    assert sorted(sub) == list(range(1, k + 1))
    assert cc.routing_dense_bits_tree(depths, d, omega) == \
        cc.routing_dense_bits(k, d, omega)
    assert cc.routing_sparse_bits_tree(depths, d, q, omega) == \
        cc.routing_sparse_bits(k, d, q, omega)
    assert cc.dense_ia_bits_tree(k, d, omega) == cc.dense_ia_bits(k, d, omega)
    assert cc.cl_sia_bits_tree(k, d, q, omega) == \
        cc.cl_sia_bits(k, d, q, omega)
    qg, ql = 70, 8
    np.testing.assert_allclose(
        cc.expected_lambda_nnz_bound_tree(sub, d, qg, ql),
        cc.expected_lambda_nnz_bound(k, d, qg, ql), rtol=1e-9)
    assert cc.sia_bits_worst_case_tree(sub, d, q, omega) == \
        cc.sia_bits_worst_case(k, d, q, omega)


# ---------------------------------------------------------------------------
# Graph builders + routing
# ---------------------------------------------------------------------------

def test_walker_delta_is_torus():
    g = tg.walker_delta(3, 4)
    assert g.num_clients == 12
    assert g.is_connected()
    # torus: every satellite has degree 4 (+ gateway's ground link)
    deg = np.zeros(g.num_nodes, int)
    for u, v in g.edges:
        deg[u] += 1
        deg[v] += 1
    sats = [v for v in range(g.num_nodes) if v != g.ps]
    assert all(deg[v] in (4, 5) for v in sats)
    assert deg[g.ps] == 1


def test_walker_star_has_seam():
    delta = tg.walker_delta(3, 4)
    star = tg.walker_star(3, 4)
    assert star.edges.shape[0] == delta.edges.shape[0] - 4  # seam links gone
    assert star.is_connected()


def test_shortest_path_tree_depths_are_graph_distances():
    g = tg.grid_graph(3, 3)
    tree = shortest_path_tree(g, metric="hops")
    # grid with PS at corner (0,0): client (r,c) is r+c+1 hops from PS
    depths = tree.depths()
    nodes = g.client_nodes()
    for i, v in enumerate(nodes):
        r, c = divmod(int(v) - 1, 3)
        assert depths[i] == r + c + 1


def test_widest_path_tree_maximizes_bottleneck():
    # PS —(thin)— a, PS —(wide)— b —(wide)— a: widest tree routes a via b
    edges = np.asarray([[0, 1], [0, 2], [1, 2]])
    g = tg.ConstellationGraph(num_nodes=3, edges=edges,
                              bandwidth_bps=[1e6, 100e6, 100e6],
                              latency_s=[0.01, 0.01, 0.01], ps=0)
    tree = widest_path_tree(g)
    # client 0 = node 1 (a), client 1 = node 2 (b)
    assert tree.parent == (1, PS)
    assert tree.uplink_bw_bps[0] == 100e6
    # shortest-path (hops) takes the thin direct link instead
    spt = shortest_path_tree(g, metric="hops")
    assert spt.parent == (PS, PS)


def test_rerouting_around_dead_relay():
    g = tg.grid_graph(2, 3)
    full = shortest_path_tree(g)
    # kill the relay at grid position (0, 1) — node 2, client index 1; its
    # downstream column re-roots through row 1
    dead_node = int(g.client_nodes()[1])
    healed = shortest_path_tree(g, exclude=[dead_node])
    assert healed.reachable is not None
    alive = [i for i, v in enumerate(g.client_nodes()) if int(v) != dead_node]
    assert all(healed.reachable[i] for i in alive)
    assert not healed.reachable[1]
    assert healed.parent[1] == PS          # stub parked at the PS
    assert healed.max_depth() >= full.max_depth()


def test_gateway_loss_strands_single_uplink_grid():
    """The grid has one ground link; losing that gateway strands everyone."""
    g = tg.grid_graph(2, 3)
    gateway = int(g.client_nodes()[0])
    healed = shortest_path_tree(g, exclude=[gateway])
    assert not any(healed.reachable)


def test_disconnected_clients_become_stubs():
    # two clients, one only reachable through the other
    edges = np.asarray([[0, 1], [1, 2]])
    g = tg.ConstellationGraph(num_nodes=3, edges=edges,
                              bandwidth_bps=1e6, latency_s=0.01, ps=0)
    healed = shortest_path_tree(g, exclude=[1])
    assert healed.parent == (PS, PS)
    assert healed.reachable == (False, False)


def test_cycle_detection():
    with pytest.raises(ValueError, match="cycle"):
        AggTree(parent=(1, 0))


def test_round_latency_depth_scaling():
    """Critical path shrinks with tree depth at equal per-hop payload."""
    bits = [1e6] * 12
    # equal link classes so only the topology differs
    chain = shortest_path_tree(tg.path_graph(12, bandwidth_bps=50e6,
                                             latency_s=10e-3))
    star = shortest_path_tree(tg.star_graph(12, bandwidth_bps=50e6,
                                            latency_s=10e-3))
    # 12 serialized hops vs 1: exactly 12× the per-hop time
    np.testing.assert_allclose(round_latency_s(chain, bits),
                               12 * round_latency_s(star, bits))


# ---------------------------------------------------------------------------
# Simulator wiring (tree mode + failure re-rooting)
# ---------------------------------------------------------------------------

def test_simulator_tree_mode_and_failure():
    from repro.configs import PAPER
    from repro.data.federated import partition_iid
    from repro.data.synthetic import make_synthetic_mnist
    from repro.fed.simulator import Simulator
    from repro.fed.topology import FailureSchedule, TreeTopology

    g = tg.walker_delta(2, 3, gateways=(1, 4))
    k = g.num_clients
    pc = dataclasses.replace(PAPER, num_clients=k)
    train = make_synthetic_mnist(jax.random.PRNGKey(0), k * 40)
    fed = partition_iid(jax.random.PRNGKey(2), train, k)
    topo = TreeTopology(g, routing="widest")
    sim = Simulator(pc, AggConfig(kind=AggKind.CL_SIA, q=pc.q), fed,
                    local_lr=pc.lr, tree_topology=topo)
    fails = FailureSchedule(k, {3: ([0], []), 6: ([], [0])})
    out = sim.run(8, failure_schedule=fails)
    assert out["loss"][-1] < out["loss"][0]
    # CL-SIA constant-length: exactly Q(ω+⌈log₂d⌉) per live uplink — the
    # re-rooted tree drops the dead node from the route entirely
    full = cc.cl_sia_bits_tree(k, pc.d, pc.q, 32)
    healed = cc.cl_sia_bits_tree(k - 1, pc.d, pc.q, 32)
    assert [b for b in out["bits"]] == \
        [full] * 3 + [healed] * 3 + [full] * 2
