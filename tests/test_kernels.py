"""Pallas kernels vs pure-jnp oracles: shape × dtype sweeps (interpret)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

SHAPES = [63, 1024, 8192, 8192 + 17, 65536 + 3]
DTYPES = [jnp.float32, jnp.bfloat16]


def _vec(key, d, dtype):
    return jax.random.normal(key, (d,), jnp.float32).astype(dtype)


def _tols(dtype):
    # bf16 outputs differ by one quantum when ref/kernel f32 intermediates
    # round to adjacent bf16 values
    if dtype == jnp.bfloat16:
        return dict(rtol=1e-2, atol=2e-3)
    return dict(rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("d", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_count_ge_sweep(d, dtype):
    x = _vec(jax.random.PRNGKey(d), d, dtype)
    taus = jnp.linspace(0.01, 2.5, 32)
    np.testing.assert_array_equal(
        np.asarray(ops.count_ge(x, taus, mode="always")),
        np.asarray(ref.ref_count_ge(x, taus)))


@pytest.mark.parametrize("d", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_sparsify_ef_sweep(d, dtype):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(d + 1), 3)
    g = _vec(k1, d, dtype)
    e = (0.1 * jax.random.normal(k2, (d,))).astype(dtype)
    mask = (jax.random.uniform(k3, (d,)) < 0.02).astype(jnp.float32)
    w, tau = jnp.float32(1.7), jnp.float32(1.2)
    r = ref.ref_sparsify_ef(g, e, mask, w, tau)
    p = ops.sparsify_ef(g, e, mask, w, tau, mode="always")
    np.testing.assert_allclose(np.asarray(r[0], np.float32),
                               np.asarray(p[0], np.float32), **_tols(dtype))
    np.testing.assert_allclose(np.asarray(r[1], np.float32),
                               np.asarray(p[1], np.float32), **_tols(dtype))
    assert abs(int(r[2]) - int(p[2])) <= (2 if dtype == jnp.bfloat16 else 0)


@pytest.mark.parametrize("d", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_chain_accum_sweep(d, dtype):
    k1, k2 = jax.random.split(jax.random.PRNGKey(d + 2))
    gamma = _vec(k1, d, dtype) * (jax.random.uniform(k2, (d,)) < 0.05)
    gbar = _vec(k2, d, dtype) * (jax.random.uniform(k1, (d,)) < 0.05)
    r = ref.ref_chain_accum(gamma, gbar)
    p = ops.chain_accum(gamma, gbar, mode="always")
    np.testing.assert_allclose(np.asarray(r[0], np.float32),
                               np.asarray(p[0], np.float32), **_tols(dtype))
    assert int(r[1]) == int(p[1])


@pytest.mark.parametrize("d", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_cl_fuse_sweep(d, dtype):
    ks = jax.random.split(jax.random.PRNGKey(d + 3), 3)
    g, e, gi = (_vec(k, d, dtype) for k in ks)
    w, tau = jnp.float32(0.8), jnp.float32(1.4)
    r = ref.ref_cl_fuse(g, e, gi, w, tau)
    p = ops.cl_fuse(g, e, gi, w, tau, mode="always")
    for a, b in zip(r[:2], p[:2]):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), **_tols(dtype))
    assert abs(int(r[2]) - int(p[2])) <= (2 if dtype == jnp.bfloat16 else 0)


def test_threshold_pipeline_with_pallas_counts():
    """End-to-end: bisection with the Pallas count kernel hits the budget."""
    from repro.core import sparsify as sp
    x = jax.random.normal(jax.random.PRNGKey(7), (50_000,))
    for q in (10, 500, 5000):
        tau = sp.threshold_for_topq(
            x, q, count_fn=lambda m, t: ops.count_ge(m, t, mode="always"))
        kept = int(jnp.sum(jnp.abs(x) >= tau))
        assert q <= kept <= q + max(2, int(0.02 * x.size))


def test_mode_never_uses_ref():
    x = jnp.ones((100,))
    taus = jnp.asarray([0.5, 1.5])
    out = ops.count_ge(x, taus, mode="never")
    np.testing.assert_array_equal(np.asarray(out), [100, 0])


# ---------------------------------------------------------------------------
# Batched W-lane level kernels (repro.kernels.level)
#
# Refs are jitted: XLA:CPU contracts w·g+e into an FMA inside any compiled
# graph (interpret-mode Pallas included); an eager ref differs by 1 ulp.
# ---------------------------------------------------------------------------

LEVEL_SHAPES = [(1, 63), (3, 1024), (2, 8192 + 17), (5, 4096)]


def _level_inputs(w, d, seed=0):
    key = jax.random.PRNGKey(seed)
    g = jax.random.normal(key, (w, d))
    e = 0.1 * jax.random.normal(jax.random.fold_in(key, 1), (w, d))
    gin = jax.random.normal(jax.random.fold_in(key, 2), (w, d)) * (
        jax.random.uniform(jax.random.fold_in(key, 3), (w, d)) < 0.05)
    mask = (jax.random.uniform(jax.random.fold_in(key, 4), (w, d))
            < 0.02).astype(jnp.float32)
    gmask = (jax.random.uniform(jax.random.fold_in(key, 5), (w, d))
             < 0.05).astype(jnp.float32)
    ws = jnp.linspace(0.5, 1.9, w)
    tau = jnp.linspace(0.6, 2.0, w)
    p = (jnp.arange(w) % 2).astype(jnp.float32)        # stragglers mixed in
    valid = jnp.where(jnp.arange(w) == w - 1, 0.0, 1.0)  # last lane padded
    return g, e, gin, mask, gmask, ws, tau, p, valid


@pytest.mark.parametrize("w,d", LEVEL_SHAPES)
def test_sparsify_ef_level_sweep(w, d):
    import functools
    g, e, gin, mask, gmask, ws, tau, p, valid = _level_inputs(w, d, d)
    for mi in (None, mask):
        r = jax.jit(functools.partial(ops.sparsify_ef_level,
                                      mode="never"))(g, e, mi, ws, tau,
                                                     valid)
        k = ops.sparsify_ef_level(g, e, mi, ws, tau, valid, mode="always")
        for a, b in zip(r, k):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # invalid (padding) lanes output zeros and count nothing
    assert not np.asarray(k[0][-1]).any()
    assert int(k[2][-1]) == 0


@pytest.mark.parametrize("w,d", LEVEL_SHAPES)
def test_chain_accum_level_sweep(w, d):
    import functools
    g, e, gin, mask, gmask, ws, tau, p, valid = _level_inputs(w, d, d + 1)
    for gm in (None, gmask):
        r = jax.jit(functools.partial(ops.chain_accum_level,
                                      mode="never"))(gin, g, valid, gm)
        k = ops.chain_accum_level(gin, g, valid, gm, mode="always")
        for a, b in zip(r, k):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # off-mask count never exceeds the total
    assert (np.asarray(k[2]) <= np.asarray(k[1])).all()


@pytest.mark.parametrize("w,d", LEVEL_SHAPES)
def test_cl_fuse_level_sweep(w, d):
    import functools
    g, e, gin, mask, gmask, ws, tau, p, valid = _level_inputs(w, d, d + 2)
    for gm in (None, gmask):
        for mi in (None, mask):
            r = jax.jit(functools.partial(
                ops.cl_fuse_level, mode="never"))(g, e, gin, ws, tau, p,
                                                  valid, gm, mi)
            k = ops.cl_fuse_level(g, e, gin, ws, tau, p, valid, gm, mi,
                                  mode="always")
            for a, b in zip(r, k):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("w,d", LEVEL_SHAPES)
def test_count_ge_level_sweep(w, d):
    key = jax.random.PRNGKey(d + 3)
    x = jax.random.normal(key, (w, d))
    taus = jnp.abs(jax.random.normal(jax.random.fold_in(key, 1),
                                     (w, 32))) + 0.01
    np.testing.assert_array_equal(
        np.asarray(ops.count_ge_level(x, taus, mode="never")),
        np.asarray(ops.count_ge_level(x, taus, mode="always")))


def test_cl_fuse_level_straggler_semantics():
    """p=0 lanes forward γ_in unchanged and bank g̃ = w·g+e into EF."""
    w, d = 2, 1024
    g, e, gin, mask, gmask, ws, tau, p, valid = _level_inputs(w, d, 9)
    p = jnp.asarray([0.0, 1.0])
    valid = jnp.ones((w,))
    gout, e_new, nnz, _ = ops.cl_fuse_level(g, e, gin, ws, tau, p, valid,
                                            mode="always")
    np.testing.assert_array_equal(np.asarray(gout[0]), np.asarray(gin[0]))
    np.testing.assert_allclose(np.asarray(e_new[0]),
                               np.asarray(ws[0] * g[0] + e[0]), rtol=1e-6)
    assert int(nnz[0]) == int(jnp.sum(gin[0] != 0))
