"""Pallas kernels vs pure-jnp oracles: shape × dtype sweeps (interpret)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

SHAPES = [63, 1024, 8192, 8192 + 17, 65536 + 3]
DTYPES = [jnp.float32, jnp.bfloat16]


def _vec(key, d, dtype):
    return jax.random.normal(key, (d,), jnp.float32).astype(dtype)


def _tols(dtype):
    # bf16 outputs differ by one quantum when ref/kernel f32 intermediates
    # round to adjacent bf16 values
    if dtype == jnp.bfloat16:
        return dict(rtol=1e-2, atol=2e-3)
    return dict(rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("d", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_count_ge_sweep(d, dtype):
    x = _vec(jax.random.PRNGKey(d), d, dtype)
    taus = jnp.linspace(0.01, 2.5, 32)
    np.testing.assert_array_equal(
        np.asarray(ops.count_ge(x, taus, mode="always")),
        np.asarray(ref.ref_count_ge(x, taus)))


@pytest.mark.parametrize("d", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_sparsify_ef_sweep(d, dtype):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(d + 1), 3)
    g = _vec(k1, d, dtype)
    e = (0.1 * jax.random.normal(k2, (d,))).astype(dtype)
    mask = (jax.random.uniform(k3, (d,)) < 0.02).astype(jnp.float32)
    w, tau = jnp.float32(1.7), jnp.float32(1.2)
    r = ref.ref_sparsify_ef(g, e, mask, w, tau)
    p = ops.sparsify_ef(g, e, mask, w, tau, mode="always")
    np.testing.assert_allclose(np.asarray(r[0], np.float32),
                               np.asarray(p[0], np.float32), **_tols(dtype))
    np.testing.assert_allclose(np.asarray(r[1], np.float32),
                               np.asarray(p[1], np.float32), **_tols(dtype))
    assert abs(int(r[2]) - int(p[2])) <= (2 if dtype == jnp.bfloat16 else 0)


@pytest.mark.parametrize("d", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_chain_accum_sweep(d, dtype):
    k1, k2 = jax.random.split(jax.random.PRNGKey(d + 2))
    gamma = _vec(k1, d, dtype) * (jax.random.uniform(k2, (d,)) < 0.05)
    gbar = _vec(k2, d, dtype) * (jax.random.uniform(k1, (d,)) < 0.05)
    r = ref.ref_chain_accum(gamma, gbar)
    p = ops.chain_accum(gamma, gbar, mode="always")
    np.testing.assert_allclose(np.asarray(r[0], np.float32),
                               np.asarray(p[0], np.float32), **_tols(dtype))
    assert int(r[1]) == int(p[1])


@pytest.mark.parametrize("d", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_cl_fuse_sweep(d, dtype):
    ks = jax.random.split(jax.random.PRNGKey(d + 3), 3)
    g, e, gi = (_vec(k, d, dtype) for k in ks)
    w, tau = jnp.float32(0.8), jnp.float32(1.4)
    r = ref.ref_cl_fuse(g, e, gi, w, tau)
    p = ops.cl_fuse(g, e, gi, w, tau, mode="always")
    for a, b in zip(r[:2], p[:2]):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), **_tols(dtype))
    assert abs(int(r[2]) - int(p[2])) <= (2 if dtype == jnp.bfloat16 else 0)


def test_threshold_pipeline_with_pallas_counts():
    """End-to-end: bisection with the Pallas count kernel hits the budget."""
    from repro.core import sparsify as sp
    x = jax.random.normal(jax.random.PRNGKey(7), (50_000,))
    for q in (10, 500, 5000):
        tau = sp.threshold_for_topq(
            x, q, count_fn=lambda m, t: ops.count_ge(m, t, mode="always"))
        kept = int(jnp.sum(jnp.abs(x) >= tau))
        assert q <= kept <= q + max(2, int(0.02 * x.size))


def test_mode_never_uses_ref():
    x = jnp.ones((100,))
    taus = jnp.asarray([0.5, 1.5])
    out = ops.count_ge(x, taus, mode="never")
    np.testing.assert_array_equal(np.asarray(out), [100, 0])
