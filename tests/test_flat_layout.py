"""FlatLayout: shard-aligned flatten/unflatten roundtrip (multi-device)."""

LAYOUT_ROUNDTRIP = r"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro import compat
from repro.core.flat_layout import FlatLayout
from repro.configs.base import ModelConfig
from repro.models import model as model_mod
from repro.models import partition

mesh = compat.make_mesh((2, 4), ("data", "model"))
# num_heads=6 NOT divisible by model=4 → exercises the replicated-leaf path
cfg = ModelConfig(name="t", family="dense", num_layers=2, d_model=32,
                  num_heads=6, num_kv_heads=2, d_ff=64, vocab_size=128,
                  head_dim=8, param_dtype="float32")
params = model_mod.init_params(cfg, jax.random.PRNGKey(0))
specs = partition.param_pspecs(cfg, mesh)
layout = FlatLayout(model_mod.param_specs(cfg), specs, mesh)
assert layout.n_local % layout.k_dp == 0

def roundtrip(p):
    m_idx = jax.lax.axis_index("model")
    col = layout.local_flatten(jax.tree.leaves(p), m_idx, jnp.float32)
    leaves = layout.local_unflatten(col, m_idx)
    return layout.treedef.unflatten(leaves)

f = compat.shard_map(roundtrip, mesh=mesh,
                     in_specs=(layout.param_in_specs(),),
                     out_specs=layout.param_out_specs(),
                     axis_names={"data", "model"})
with compat.set_mesh(mesh):
    out = jax.jit(f)(params)
for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(out)):
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32), rtol=1e-6)
print("roundtrip OK; d_flat =", layout.d_flat)

# master init path agrees with a host-side flatten of the same layout
from repro.train.step import make_layout, _master_from_params
from repro.train.state import TrainConfig
master = _master_from_params(cfg, mesh, layout, params)
assert master.shape == (layout.d_flat,)
# total parameter mass preserved
tot_master = float(jnp.sum(jnp.abs(master)))
tot_params = float(sum(jnp.sum(jnp.abs(l.astype(jnp.float32)))
                       for l in jax.tree.leaves(params)))
np.testing.assert_allclose(tot_master, tot_params, rtol=1e-5)
print("PASS")
"""


def test_flat_layout_roundtrip(multidev):
    multidev(LAYOUT_ROUNDTRIP, devices=8)
