"""End-to-end CLI test: train → checkpoint → restart resumes (restart-
anywhere posture, DESIGN §6)."""

import os
import subprocess
import sys

from conftest import SRC


def _run_train(tmp, steps):
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    return subprocess.run(
        [sys.executable, "-m", "repro.launch.train",
         "--arch", "mamba2-130m", "--smoke", "--steps", str(steps),
         "--batch", "4", "--seq", "32", "--ckpt-dir", str(tmp),
         "--ckpt-every", "2", "--straggle-p", "0.3"],
        env=env, capture_output=True, text=True, timeout=600)


def test_train_checkpoint_resume(tmp_path):
    p1 = _run_train(tmp_path, 4)
    assert p1.returncode == 0, p1.stderr[-2000:]
    assert "checkpointed step 4" in p1.stdout
    ckpts = [d for d in os.listdir(tmp_path) if d.startswith("step_")]
    assert ckpts, p1.stdout

    p2 = _run_train(tmp_path, 3)
    assert p2.returncode == 0, p2.stderr[-2000:]
    assert "resumed from step 4" in p2.stdout
    assert "step    7" in p2.stdout or "checkpointed step 7" in p2.stdout
