"""Device-plan lowering (repro.agg.device): multi-device equivalence.

The acceptance contracts of the device-plan ISSUE:

* ``run_plan_clients_local`` (one device per client) is **bit-exact** to
  host ``agg.execute()`` for all five algorithms over a routed tree, a
  permuted chain, and a padded ``TopologySchedule`` plan — and one jit
  specialization serves every same-shape plan (trace counter);
* ``run_plan_segments_local`` (the rotated-segment ring generalization) is
  bit-exact *per segment* to ``agg.execute()`` under the segment's client
  relabeling, with static (per-slot ppermute) and butterfly (traced plan)
  transports agreeing bitwise;
* the refactored ring reproduces the historic ``rotated_ring_local``
  outputs exactly — covered by tests/test_ring_shardmap.py, which runs
  unmodified;
* ``Simulator(backend="device")`` training curves match the host backend
  (float tolerance only: XLA fuses the identical gradient math differently
  when a shard_map consumes it);
* ``segment_budget`` §V regression: summed per-segment budgets never
  exceed the global budget (the old ``max(1, ·)`` floor inflated bits
  K-fold when ``q_total < num_segments``).
"""

import numpy as np

from repro.core.ring import segment_budget


CLIENTS_EQUIV = r"""
import jax, jax.numpy as jnp, numpy as np
from repro.agg import TopologySchedule, compile_plan, execute, execute_sharded
from repro.core.algorithms import AggConfig, AggKind
from repro.topo import graph as tg
from repro.topo.routing import shortest_path_tree
from repro.topo.tree import AggTree, PS

K, D = 8, 97
g = jax.random.normal(jax.random.PRNGKey(0), (K, D))
e = 0.1 * jax.random.normal(jax.random.PRNGKey(1), (K, D))
w = jnp.ones((K,), jnp.float32)
part = jnp.asarray([1, 0, 1, 1, 1, 0, 1, 1], jnp.float32)

tree = AggTree(parent=(PS, 0, 1, 1, 3, 0, 5, 2))
routed = shortest_path_tree(tg.grid_graph(2, 4))
order = np.asarray([3, 1, 0, 6, 4, 2, 5, 7], np.int32)
sched = TopologySchedule.from_topologies([K, routed, tree])
pad = sched.shape
topos = [("chain", K), ("perm", order), ("routed", routed), ("hand", tree)]

ALL = [AggKind.SIA, AggKind.RE_SIA, AggKind.CL_SIA, AggKind.TC_SIA,
       AggKind.CL_TC_SIA, AggKind.DENSE_IA]
for kind in ALL:
    cfg = AggConfig(kind=kind, q=9)
    gm = jnp.zeros((D,))
    if kind in (AggKind.TC_SIA, AggKind.CL_TC_SIA):
        gm = gm.at[jnp.arange(cfg.q_global)].set(1.0)
    traces = []

    @jax.jit
    def dev_round(plan, g, e, w, gm, part):
        traces.append(1)                       # runs at trace time only
        return execute_sharded(cfg, plan, g, e, w, global_mask=gm,
                               participate=part)

    for name, topo in topos:
        plan = compile_plan(topo, pad_to=pad)  # one shared (L, W)
        want = execute(cfg, plan, g, e, w, global_mask=gm, participate=part)
        got = dev_round(plan, g, e, w, gm, part)
        for field in ("aggregate", "e_new"):
            np.testing.assert_array_equal(
                np.asarray(getattr(want, field)),
                np.asarray(getattr(got, field)),
                err_msg=f"{name}/{kind.value}/{field}")
        for field in ("bits", "nnz_out", "nnz_local", "err_sq"):
            np.testing.assert_array_equal(
                np.asarray(getattr(want.stats, field)),
                np.asarray(getattr(got.stats, field)),
                err_msg=f"{name}/{kind.value}/stats.{field}")
    # one XLA executable served the whole padded schedule — the device
    # path keeps the plan/execute jit-amortization contract
    assert len(traces) == 1, (kind, len(traces))
    print(f"{kind.value}: device == host execute, 1 trace / {len(topos)} plans")

    # compact (values, indices) wire transport: traced plans default to the
    # dense segment (a straggler's forwarded γ can exceed q on trees), but
    # an all-alive no-straggler round may assert safety — still bit-exact
    if kind in (AggKind.CL_SIA, AggKind.CL_TC_SIA):
        plan = compile_plan(routed, pad_to=pad)
        want = execute(cfg, plan, g, e, w, global_mask=gm)
        got = jax.jit(lambda p, a, b, c: execute_sharded(
            cfg, p, a, b, c, global_mask=gm, wire="compact"))(plan, g, e, w)
        np.testing.assert_array_equal(np.asarray(want.aggregate),
                                      np.asarray(got.aggregate))
        np.testing.assert_array_equal(np.asarray(want.e_new),
                                      np.asarray(got.e_new))
        print(f"{kind.value}: compact wire bit-exact on the routed tree")

# dtype faithfulness: the kernel mirrors the host executor's dtypes, so
# bf16 gradients/EF stay bit-exact too
cfg = AggConfig(kind=AggKind.CL_SIA, q=9)
g16, e16 = g.astype(jnp.bfloat16), e.astype(jnp.bfloat16)
plan = compile_plan(routed, pad_to=pad)
want = execute(cfg, plan, g16, e16, w)
got = jax.jit(lambda p, a, b, c: execute_sharded(cfg, p, a, b, c))(
    plan, g16, e16, w)
np.testing.assert_array_equal(
    np.asarray(want.aggregate, np.float32), np.asarray(got.aggregate, np.float32))
np.testing.assert_array_equal(
    np.asarray(want.e_new, np.float32), np.asarray(got.e_new, np.float32))
print("bf16: device == host execute")
print("PASS")
"""


SEGMENTS_EQUIV = r"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro import compat
from repro.agg import compile_plan, execute
from repro.agg.device import run_plan_segments_local
from repro.core.ring import RingStats
from repro.core.algorithms import AggConfig, AggKind
from repro.topo.tree import AggTree, PS

K, n = 8, 8 * 48
seg = n // K
mesh = compat.make_mesh((K,), ("data",))
G = jax.random.normal(jax.random.PRNGKey(0), (K, n))
EF = 0.1 * jax.random.normal(jax.random.PRNGKey(1), (K, n))
w = jnp.float32(1.3)

tree = AggTree(parent=(PS, 0, 1, 1, 3, 0, 5, 2))
order = np.asarray([3, 1, 0, 6, 4, 2, 5, 7], np.int32)
stats_specs = jax.tree.map(lambda _: P(), RingStats(0., 0., 0.))

for topo, name in [(tree, "tree"), (order, "perm")]:
    plan = compile_plan(topo)
    for kind in (AggKind.CL_SIA, AggKind.SIA, AggKind.CL_TC_SIA):
        cfg = AggConfig(kind=kind, q=5)
        gm = None
        if kind in (AggKind.TC_SIA, AggKind.CL_TC_SIA):
            gm = jnp.zeros((n,)).at[::50].set(1.0)

        def body(g_l, ef_l, pl, transport):
            final, ef_new, st = run_plan_segments_local(
                cfg, pl, g_l[0], ef_l[0], w, axis="data",
                global_mask_local=gm, transport=transport)
            return final[None], ef_new[None], jax.tree.map(
                lambda s: jax.lax.psum(s, "data"), st)

        # traced plan → butterfly routing, one specialization per shape
        fb = jax.jit(compat.shard_map(
            lambda g_l, ef_l, pl: body(g_l, ef_l, pl, "butterfly"),
            mesh=mesh,
            in_specs=(P("data"), P("data"), jax.tree.map(lambda _: P(), plan)),
            out_specs=(P("data"), P("data"), stats_specs),
            axis_names={"data"}))
        final, ef_new, stats = fb(G, EF, plan)

        # constant plan → per-slot static ppermutes (the ring's program)
        fs = jax.jit(compat.shard_map(
            lambda g_l, ef_l: body(g_l, ef_l, plan, "static"),
            mesh=mesh, in_specs=(P("data"), P("data")),
            out_specs=(P("data"), P("data"), stats_specs),
            axis_names={"data"}))
        final_s, ef_s, stats_s = fs(G, EF)
        np.testing.assert_array_equal(np.asarray(final), np.asarray(final_s))
        np.testing.assert_array_equal(np.asarray(ef_new), np.asarray(ef_s))
        np.testing.assert_allclose(float(stats.bits), float(stats_s.bits))

        # host reference: segment s runs the plan with tree positions
        # relabeled +s — position k is played by client (k+s) mod K, the
        # "rotated start ranks" that make every link busy each level.
        bits_ref = 0.0
        for s in range(K):
            rot = [(k + s) % K for k in range(K)]
            g_s = jnp.asarray(np.asarray(G)[rot, s*seg:(s+1)*seg])
            e_s = jnp.asarray(np.asarray(EF)[rot, s*seg:(s+1)*seg])
            gm_s = None if gm is None else gm[s*seg:(s+1)*seg]
            res = execute(cfg, plan, g_s, e_s, jnp.full((K,), w),
                          global_mask=gm_s)
            np.testing.assert_array_equal(
                np.asarray(final)[s], np.asarray(res.aggregate),
                err_msg=f"{name}/{kind.value} segment {s} aggregate")
            for k in range(K):
                np.testing.assert_array_equal(
                    np.asarray(ef_new)[rot[k], s*seg:(s+1)*seg],
                    np.asarray(res.e_new[k]),
                    err_msg=f"{name}/{kind.value} segment {s} EF pos {k}")
            bits_ref += float(jnp.sum(res.stats.bits))
        np.testing.assert_allclose(float(stats.bits), bits_ref, rtol=1e-6)
        print(f"{name}/{kind.value}: segments kernel == per-segment execute")

# plan.alive (stranded stub) and q_budget are PHYSICAL-RANK properties on
# the segments kernel: rank j is dead / narrow-uplinked in every segment,
# whatever position it plays. The host reference is an all-alive plan with
# participation and budgets relabeled by the segment rotation.
import dataclasses
cfg = AggConfig(kind=AggKind.CL_SIA, q=5)
base = compile_plan(tree)
alive = np.ones((K,), np.float32); alive[5] = 0.0
qb = np.asarray([5, 3, 5, 2, 5, 1, 4, 5], np.int32)
plan = dataclasses.replace(base, alive=alive, q_budget=qb)

def body_s(g_l, ef_l):
    final, ef_new, st = run_plan_segments_local(
        cfg, plan, g_l[0], ef_l[0], w, axis="data", transport="static")
    return final[None], ef_new[None], jax.tree.map(
        lambda s: jax.lax.psum(s, "data"), st)

final, ef_new, _ = jax.jit(compat.shard_map(
    body_s, mesh=mesh, in_specs=(P("data"), P("data")),
    out_specs=(P("data"), P("data"), stats_specs),
    axis_names={"data"}))(G, EF)
for s in range(K):
    rot = [(k + s) % K for k in range(K)]
    ref_plan = dataclasses.replace(base, q_budget=qb[rot])
    res = execute(cfg, ref_plan,
                  jnp.asarray(np.asarray(G)[rot, s*seg:(s+1)*seg]),
                  jnp.asarray(np.asarray(EF)[rot, s*seg:(s+1)*seg]),
                  jnp.full((K,), w), participate=jnp.asarray(alive[rot]))
    np.testing.assert_array_equal(np.asarray(final)[s],
                                  np.asarray(res.aggregate),
                                  err_msg=f"stub/budget segment {s}")
    for k in range(K):
        np.testing.assert_array_equal(
            np.asarray(ef_new)[rot[k], s*seg:(s+1)*seg],
            np.asarray(res.e_new[k]),
            err_msg=f"stub/budget segment {s} EF pos {k}")
print("stub + q_budget: rank-indexed semantics == relabeled host reference")
print("PASS")
"""


SIM_BACKEND = r"""
import dataclasses
import jax, numpy as np
from repro.agg import TopologySchedule
from repro.configs import PAPER
from repro.core.algorithms import AggConfig, AggKind
from repro.data.federated import partition_iid
from repro.data.synthetic import make_synthetic_mnist
from repro.fed.simulator import Simulator
from repro.fed.topology import TreeTopology
from repro.topo import graph as tg

k = 6
pc = dataclasses.replace(PAPER, num_clients=k)
train = make_synthetic_mnist(jax.random.PRNGKey(0), k * 40)
fed = partition_iid(jax.random.PRNGKey(2), train, k)

for kind in (AggKind.CL_SIA, AggKind.TC_SIA):
    topo = TreeTopology(tg.grid_graph(2, 3), routing="widest")
    cfg = AggConfig(kind=kind, q=pc.q)
    host = Simulator(pc, cfg, fed, local_lr=pc.lr,
                     tree_topology=topo).run(5, seed=1)
    dev = Simulator(pc, cfg, fed, local_lr=pc.lr, tree_topology=topo,
                    backend="device").run(5, seed=1)
    # float tolerance: XLA fuses the identical per-client gradient math
    # differently when a shard_map consumes it (the aggregation round
    # itself is bit-exact on identical inputs — CLIENTS_EQUIV above)
    np.testing.assert_allclose(host["loss"], dev["loss"], rtol=1e-5)
    np.testing.assert_allclose(host["bits"], dev["bits"], rtol=1e-6)
    np.testing.assert_allclose(np.asarray(host["state"].flat_w),
                               np.asarray(dev["state"].flat_w),
                               rtol=1e-4, atol=1e-6)
    print(f"{kind.value}: device backend matches host curves")

# a time-varying schedule still trains through the device backend
sched = TopologySchedule.from_topologies(
    [tg.path_graph(k), tg.star_graph(k), tg.grid_graph(2, 3)])
out = Simulator(pc, AggConfig(kind=AggKind.CL_SIA, q=pc.q), fed,
                local_lr=pc.lr, backend="device").run(
    6, seed=1, topology_schedule=sched)
assert out["loss"][-1] < out["loss"][0]
print("PASS")
"""


TRAIN_TOPOLOGY = r"""
import jax, jax.numpy as jnp, numpy as np
from repro import compat
from repro.configs.base import ModelConfig
from repro.core.algorithms import AggConfig, AggKind
from repro.launch.mesh import dp_clients, make_agg_plan
from repro.optim.optimizers import OptConfig
from repro.topo import graph as tg
from repro.topo.tree import star_tree
from repro.train.state import TrainConfig
from repro.train import build_train_step, init_state, state_shardings

mesh = compat.make_mesh((4, 2), ("data", "model"))
assert dp_clients(mesh) == 4
cfg = ModelConfig(name="tiny", family="dense", num_layers=2, d_model=64,
                  num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=256,
                  head_dim=16, param_dtype="float32")
toks = jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0, 256)
batch = {"tokens": toks, "labels": jnp.roll(toks, -1, axis=1)}
tc = TrainConfig(agg=AggConfig(kind=AggKind.CL_SIA, q=1),
                 opt=OptConfig(name="adamw", lr=1e-3), q_frac=0.05,
                 agg_dtype="float32", ef_dtype="float32")

# the DP clients aggregate over a routed constellation tree instead of the
# ring — same 3-phase step, the tree plan lowered inside phase 2
for name, topo in [("star", star_tree(4)),
                   ("grid", tg.grid_graph(2, 2))]:
    plan = make_agg_plan(mesh, topo)
    with compat.set_mesh(mesh):
        st = jax.device_put(init_state(cfg, tc, mesh, jax.random.PRNGKey(0)),
                            state_shardings(cfg, tc, mesh))
        step = jax.jit(build_train_step(cfg, tc, mesh, topology=plan))
        losses = []
        for _ in range(5):
            st, m = step(st, dict(batch))
            losses.append(float(m["loss"]))
    assert losses[-1] < losses[0], (name, losses)
    assert float(m["agg_bits"]) > 0
    print(f"{name}: tree-topology train step converges "
          f"(loss {losses[0]:.3f} -> {losses[-1]:.3f})")

# the default (topology=None) is still the rotated ring — identical
# metrics to the explicit ring chain plan
with compat.set_mesh(mesh):
    st0 = jax.device_put(init_state(cfg, tc, mesh, jax.random.PRNGKey(0)),
                         state_shardings(cfg, tc, mesh))
    s_ring, m_ring = jax.jit(build_train_step(cfg, tc, mesh))(st0, dict(batch))
    st0 = jax.device_put(init_state(cfg, tc, mesh, jax.random.PRNGKey(0)),
                         state_shardings(cfg, tc, mesh))
    s_plan, m_plan = jax.jit(build_train_step(
        cfg, tc, mesh, topology=make_agg_plan(mesh)))(st0, dict(batch))
np.testing.assert_array_equal(np.asarray(m_ring["loss"]),
                              np.asarray(m_plan["loss"]))
np.testing.assert_array_equal(np.asarray(s_ring.master),
                              np.asarray(s_plan.master))
print("PASS")
"""


BUDGET_ACCOUNTING = r"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro import compat
from repro.core import ring as ring_mod
from repro.core.algorithms import AggConfig, AggKind, index_bits

K, n = 8, 8 * 64
seg = n // K
mesh = compat.make_mesh((K,), ("data",))
G = jax.random.normal(jax.random.PRNGKey(0), (K, n))
EF = jnp.zeros((K, n))
w = jnp.float32(1.0)

def total_bits(q_total):
    q_seg = ring_mod.segment_budget(q_total, K)
    cfg = AggConfig(kind=AggKind.CL_SIA, q=q_seg)
    def ring_fn(g_l, ef_l):
        final, ef_new, stats = ring_mod.rotated_ring_local(
            cfg, g_l[0], ef_l[0], w, axis="data")
        return final[None], ef_new[None], jax.tree.map(
            lambda s: jax.lax.psum(s, "data"), stats)
    _, _, stats = jax.jit(compat.shard_map(
        ring_fn, mesh=mesh, in_specs=(P("data"), P("data")),
        out_specs=(P("data"), P("data"),
                   jax.tree.map(lambda _: P(), ring_mod.RingStats(0., 0., 0.))),
        axis_names={"data"}))(G, EF)
    return float(stats.bits), q_seg

# q_total < num_segments: the old max(1, ·) floor gave every segment one
# coordinate → K·K hops · (ω+log₂seg) bits from a 5-coordinate budget.
# Clamped, nothing is transmitted.
bits, q_seg = total_bits(5)
assert q_seg == 0, q_seg
assert bits == 0.0, bits

# q_total ≥ num_segments: per-hop payload ≤ q_seg nonzeros, and the §V
# budget bound holds round-wide: K segments × K hops × q_seg coordinates.
bits, q_seg = total_bits(24)
assert q_seg == 3
cap = K * K * q_seg * (32 + index_bits(seg))
assert 0 < bits <= cap, (bits, cap)
print("PASS")
"""


def test_device_plan_matches_host_execute(multidev):
    """Routed tree / permuted chain / padded schedule plans, 5 algorithms,
    bit-exact, one jit trace for all same-shape plans."""
    multidev(CLIENTS_EQUIV, devices=8)


def test_segment_plan_matches_per_segment_execute(multidev):
    """Rotated-segment kernel ≡ per-segment host execute (both
    transports), trees and permuted chains."""
    multidev(SEGMENTS_EQUIV, devices=8)


def test_simulator_device_backend(multidev):
    multidev(SIM_BACKEND, devices=8)


def test_train_step_tree_topology(multidev):
    """build_train_step aggregates over a routed tree instead of the ring;
    topology=None stays bit-identical to the historic ring step."""
    multidev(TRAIN_TOPOLOGY, devices=8)


def test_ring_segment_budget_accounting(multidev):
    multidev(BUDGET_ACCOUNTING, devices=8)


def test_segment_budget_never_exceeds_global():
    """Regression: Σ per-segment budgets ≤ global Top-Q budget (§V)."""
    for q_total in (0, 1, 5, 7, 8, 9, 64, 1000):
        for n_seg in (1, 2, 7, 8, 64):
            q_seg = segment_budget(q_total, n_seg)
            assert q_seg * n_seg <= q_total, (q_total, n_seg, q_seg)
            # and no pathological under-use when divisible
            if q_total % n_seg == 0:
                assert q_seg * n_seg == q_total
    # q == 0 is a representable AggConfig (degenerate transmit-nothing)
    from repro.core.algorithms import AggConfig, AggKind
    cfg = AggConfig(kind=AggKind.CL_SIA, q=0)
    assert cfg.q == 0
    np.testing.assert_equal(segment_budget(5, 8), 0)
