"""Checkpoint: atomic roundtrip, keep-N GC, EF state preservation, elastic."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint as ckpt
from repro.runtime.elastic import rebalance_weights, resize_ef


def _state(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "params": {"w": jax.random.normal(k, (8, 4)),
                   "b": jnp.zeros((4,), jnp.bfloat16)},
        "ef": jax.random.normal(jax.random.fold_in(k, 1), (3, 32)),
        "step": jnp.int32(7),
    }


def test_roundtrip(tmp_path):
    s = _state()
    ckpt.save(str(tmp_path), 7, s)
    template = jax.tree.map(
        lambda l: jax.ShapeDtypeStruct(l.shape, l.dtype), s)
    r = ckpt.restore(str(tmp_path), template)
    for a, b in zip(jax.tree.leaves(s), jax.tree.leaves(r)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_latest_and_keep_n(tmp_path):
    for step in (1, 2, 3, 4):
        ckpt.save(str(tmp_path), step, _state(step), keep_n=2)
    assert ckpt.latest_step(str(tmp_path)) == 4
    kept = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert kept == ["step_00000003", "step_00000004"]


def test_no_partial_checkpoint_visible(tmp_path):
    """A leftover .tmp dir is never considered a checkpoint."""
    os.makedirs(tmp_path / "step_00000009.tmp")
    assert ckpt.latest_step(str(tmp_path)) is None
    ckpt.save(str(tmp_path), 1, _state())
    assert ckpt.latest_step(str(tmp_path)) == 1


def test_leaf_count_mismatch_raises(tmp_path):
    ckpt.save(str(tmp_path), 1, _state())
    bad_template = {"params": jax.ShapeDtypeStruct((8, 4), jnp.float32)}
    with pytest.raises(ValueError, match="leaves"):
        ckpt.restore(str(tmp_path), bad_template)


def test_ef_survives_restart(tmp_path):
    """The EF memory (paper's convergence state) must roundtrip exactly."""
    s = _state()
    ckpt.save(str(tmp_path), 3, s)
    template = jax.tree.map(
        lambda l: jax.ShapeDtypeStruct(l.shape, l.dtype), s)
    r = ckpt.restore(str(tmp_path), template)
    np.testing.assert_array_equal(np.asarray(s["ef"]), np.asarray(r["ef"]))


def test_elastic_resize_ef_conserves_mass():
    ef = jnp.ones((4, 10))
    shrunk = resize_ef(ef, 2, redistribute=True)
    assert shrunk.shape == (2, 10)
    np.testing.assert_allclose(float(shrunk.sum()), float(ef.sum()))
    grown = resize_ef(ef, 6)
    assert grown.shape == (6, 10)
    np.testing.assert_allclose(float(grown.sum()), float(ef.sum()))


def test_rebalance_weights():
    w = rebalance_weights(4)
    np.testing.assert_allclose(np.asarray(w), 0.25)
    w2 = rebalance_weights(2, [30, 10])
    np.testing.assert_allclose(np.asarray(w2), [0.75, 0.25])
