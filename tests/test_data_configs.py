"""Data pipeline + config registry tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, SHAPES, get_config, shape_cells
from repro.data.federated import (client_minibatch, partition_dirichlet,
                                  partition_iid)
from repro.data.synthetic import (lm_batch, make_bigram_lm,
                                  make_synthetic_mnist, sample_bigram)

# ---------------------------------------------------------------------------
# synthetic data
# ---------------------------------------------------------------------------


def test_mnist_like_shapes_and_determinism():
    d1 = make_synthetic_mnist(jax.random.PRNGKey(0), 100)
    d2 = make_synthetic_mnist(jax.random.PRNGKey(0), 100)
    assert d1.x.shape == (100, 784) and d1.y.shape == (100,)
    np.testing.assert_array_equal(np.asarray(d1.x), np.asarray(d2.x))


def test_mnist_like_templates_shared_across_splits():
    """Train/test linear separability: the regression learns templates from
    train that transfer to test (the bug class this guards: per-split
    templates)."""
    tr = make_synthetic_mnist(jax.random.PRNGKey(0), 2000)
    te = make_synthetic_mnist(jax.random.PRNGKey(9), 500)
    # nearest-template classification via per-class means from TRAIN
    means = jnp.stack([tr.x[tr.y == c].mean(0) for c in range(10)])
    pred = jnp.argmax(te.x @ means.T, axis=1)
    acc = float((pred == te.y).mean())
    assert acc > 0.8, acc


def test_bigram_has_learnable_structure():
    lm = make_bigram_lm(jax.random.PRNGKey(0), 64)
    toks = sample_bigram(lm, jax.random.PRNGKey(1), 64, 128)
    assert toks.shape == (64, 129)
    # empirical conditional entropy ≪ uniform entropy
    joint = np.zeros((64, 64))
    t = np.asarray(toks)
    for b in range(t.shape[0]):
        for i in range(t.shape[1] - 1):
            joint[t[b, i], t[b, i + 1]] += 1
    cond = joint / np.maximum(joint.sum(1, keepdims=True), 1)
    ent = -np.nansum(np.where(cond > 0, cond * np.log(cond), 0), axis=1)
    assert np.nanmean(ent) < 0.7 * np.log(64)


def test_lm_batch_shapes():
    lm = make_bigram_lm(jax.random.PRNGKey(0), 32)
    b = lm_batch(lm, jax.random.PRNGKey(1), 4, 16)
    assert b["tokens"].shape == (4, 16) and b["labels"].shape == (4, 16)
    np.testing.assert_array_equal(np.asarray(b["tokens"][:, 1:]),
                                  np.asarray(b["labels"][:, :-1]))


# ---------------------------------------------------------------------------
# federated partitioning
# ---------------------------------------------------------------------------


def test_partition_iid_shapes():
    data = make_synthetic_mnist(jax.random.PRNGKey(0), 1000)
    fed = partition_iid(jax.random.PRNGKey(1), data, 7)
    assert fed.x.shape == (7, 142, 784)
    bx, by = client_minibatch(fed, jax.random.PRNGKey(2), 20)
    assert bx.shape == (7, 20, 784) and by.shape == (7, 20)


def test_partition_dirichlet_skews_labels():
    data = make_synthetic_mnist(jax.random.PRNGKey(0), 4000)
    fed = partition_dirichlet(jax.random.PRNGKey(1), data, 8, alpha=0.1)
    assert fed.x.shape[0] == 8
    # low alpha → at least one client heavily skewed toward few classes
    maxfrac = 0.0
    for k in range(8):
        counts = np.bincount(np.asarray(fed.y[k]), minlength=10)
        maxfrac = max(maxfrac, counts.max() / counts.sum())
    assert maxfrac > 0.5


# ---------------------------------------------------------------------------
# config registry
# ---------------------------------------------------------------------------


def test_registry_complete():
    assert len(ARCHS) == 10
    for a in ARCHS:
        cfg = get_config(a)
        assert cfg.name == a
        smoke = get_config(a, smoke=True)
        assert smoke.family == cfg.family


PUBLISHED_N = {  # billions, loose tolerance (head/frontend conventions vary)
    "granite-34b": (34, 0.05), "codeqwen1.5-7b": (7.25, 0.15),
    "glm4-9b": (9.4, 0.1), "phi4-mini-3.8b": (3.84, 0.1),
    "mixtral-8x7b": (46.7, 0.05), "zamba2-1.2b": (1.22, 0.1),
    "mamba2-130m": (0.13, 0.1), "musicgen-medium": (1.5, 0.25),
}


@pytest.mark.parametrize("arch", list(PUBLISHED_N))
def test_param_counts_near_published(arch):
    n, tol = PUBLISHED_N[arch]
    got = get_config(arch).param_count() / 1e9
    assert abs(got - n) / n <= tol, (arch, got)


def test_moe_active_counts():
    mix = get_config("mixtral-8x7b")
    assert 12.0 < mix.active_param_count() / 1e9 < 14.0


def test_shape_cells_assignment():
    total = sum(len(shape_cells(get_config(a))) for a in ARCHS)
    assert total == 33  # 10×3 + 3 sub-quadratic long_500k
    assert "long_500k" in shape_cells(get_config("mamba2-130m"))
    assert "long_500k" in shape_cells(get_config("zamba2-1.2b"))
    assert "long_500k" in shape_cells(get_config("mixtral-8x7b"))
    assert "long_500k" not in shape_cells(get_config("granite-34b"))
    assert set(SHAPES) == {"train_4k", "prefill_32k", "decode_32k",
                           "long_500k"}


def test_vocab_padding():
    iv = get_config("internvl2-26b")
    assert iv.padded_vocab % 256 == 0 and iv.padded_vocab >= iv.vocab_size
    assert get_config("mixtral-8x7b").padded_vocab == 32000
