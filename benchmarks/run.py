"""Run every paper-table benchmark. ``name,us_per_call,derived`` CSV rows
plus one CSV block per paper figure.

    PYTHONPATH=src python -m benchmarks.run            # fast versions
    PYTHONPATH=src python -m benchmarks.run --full     # paper-scale K=28
"""

from __future__ import annotations

import argparse
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale rounds/K (slower)")
    ap.add_argument("--dim", type=int, default=1_000_000,
                    help="kernel-bench vector length d")
    ap.add_argument("--reps", type=int, default=3,
                    help="kernel-bench timing repetitions")
    ap.add_argument("--cohorts", type=int, default=8,
                    help="multi-tenant batched-round cap forwarded to "
                         "bench_round (0 disables the section)")
    ap.add_argument("--hist-branch", type=int, default=64,
                    help="tau_search bisection branch factor forwarded to "
                         "bench_round")
    ap.add_argument("--hist-rounds", type=int, default=2,
                    help="tau_search bisection rounds (1 or 2) forwarded "
                         "to bench_round")
    args = ap.parse_args()

    import bench_kernels
    import bench_round
    import fig2a_comm_cost
    import fig2b_efficiency
    import fig3_convergence
    import fig4_equal_bandwidth

    print("== kernels ==")
    bench_kernels.main(dim=args.dim, reps=args.reps)
    print("\n== aggregation round (BENCH_agg_round.json) ==")
    # device section auto-skips unless this process was launched with
    # XLA_FLAGS=--xla_force_host_platform_device_count=8
    bench_round.main(["--reps", str(args.reps), "--nested",
                      "--cohorts", str(args.cohorts),
                      "--hist-branch", str(args.hist_branch),
                      "--hist-rounds", str(args.hist_rounds)])
    print("\n== fig2a: transmitted bits vs K ==")
    fig2a_comm_cost.main()
    print("\n== fig2b: normalized efficiency vs K ==")
    fig2b_efficiency.main()
    rounds = 150 if args.full else 60
    k = 28 if args.full else 12
    print(f"\n== fig3: convergence (K={k}, rounds={rounds}) ==")
    fig3_convergence.main(k=k, rounds=rounds)
    print(f"\n== fig4: equal-bandwidth convergence (K={k}) ==")
    fig4_equal_bandwidth.main(k=k, rounds=rounds)
    print("\n== roofline (from dry-run artifacts, if present) ==")
    dr = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "dryrun_results.json")
    if os.path.exists(dr):
        import roofline
        sys.argv = ["roofline", "--dryrun-json", dr]
        roofline.main()
    else:
        print("(run repro.launch.dryrun --all first)")


if __name__ == "__main__":
    main()
