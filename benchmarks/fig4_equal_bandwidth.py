"""Paper Fig. 4: test accuracy under (approximately) equal bandwidth.

Q is re-tuned per algorithm so each transmits ≈ the same bits/iteration as
CL-SIA at Q=78 (98 kbit for K=28). Paper result: CL-SIA, RE-SIA and TC-SIA
converge much faster than SIA, with CL-SIA best.
"""

from __future__ import annotations

import dataclasses

from repro.configs import PAPER
from repro.core import comm_cost as cc
from repro.core.algorithms import AggKind
from repro.fed.simulator import Simulator

from common import ALGS, agg_config, paper_data

ROUNDS = 150
EVAL_EVERY = 25


def tune_q(kind: AggKind, target_bits: float, pc, fed) -> int:
    """Bisect Q so measured bits/iteration ≈ target (paper's procedure)."""
    lo, hi = 1, pc.d
    for _ in range(10):
        mid = (lo + hi) // 2
        sim = Simulator(pc, agg_config(kind, q=mid), fed, local_lr=pc.lr)
        bits = sim.run(6)["bits"][-1]
        if bits > target_bits:
            hi = mid
        else:
            lo = mid + 1
    return max(1, lo - 1)


def main(k: int = PAPER.num_clients, rounds: int = ROUNDS) -> list[str]:
    pc = dataclasses.replace(PAPER, num_clients=k)
    fed, test = paper_data(k, per_client=120)
    target = cc.cl_sia_bits(k, pc.d, pc.q, pc.omega)   # ≈98 kbit at K=28
    lines = [f"fig4,algorithm,q,round,test_accuracy  # target_bits={target:.0f}"]
    finals = {}
    for name, kind in ALGS.items():
        q = pc.q if kind == AggKind.CL_SIA else tune_q(kind, target, pc, fed)
        sim = Simulator(pc, agg_config(kind, q=q), fed, local_lr=pc.lr)
        out = sim.run(rounds, test_x=test.x, test_y=test.y,
                      eval_every=EVAL_EVERY)
        for r, acc in out["accuracy"]:
            lines.append(f"fig4,{name},{q},{r},{acc:.4f}")
        finals[name] = out["accuracy"][-1][1]
    print("\n".join(lines))
    print(f"# equal-bandwidth finals: "
          f"{ {k: round(v, 3) for k, v in finals.items()} } "
          f"(paper: CL-SIA best, SIA slowest)")
    return lines


if __name__ == "__main__":
    main()
