"""Emit the EXPERIMENTS.md §Roofline table from dryrun_results.json."""

import json
import sys


def main(path="dryrun_results.json", mesh="16x16"):
    with open(path) as f:
        cells = json.load(f)
    rows = [c for c in cells if c.get("mesh") == mesh
            and c.get("status") == "ok"]
    print(f"| arch | shape | t_comp (s) | t_mem (s) | t_coll (s) | "
          f"bottleneck | useful | roofline | peak GB/dev |")
    print("|---|---|---|---|---|---|---|---|---|")
    for c in rows:
        r = c["roofline"]
        peak = c["memory_analysis"]["peak_bytes_estimate"] / 1e9
        print(f"| {c['arch']} | {c['shape']} | {r['t_compute_s']:.3g} "
              f"| {r['t_memory_s']:.3g} | {r['t_collective_s']:.3g} "
              f"| {r['bottleneck']} | {r['useful_flops_ratio']:.2f} "
              f"| {r['roofline_fraction']:.4f} | {peak:.1f} |")
    fails = [c for c in cells if c.get("status") != "ok"]
    print(f"\n{len(rows)} cells on {mesh}; {len(fails)} failures total.")


if __name__ == "__main__":
    main(*sys.argv[1:])
