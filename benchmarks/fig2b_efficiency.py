"""Paper Fig. 2b: normalized communication efficiency vs K.

Total transmitted data divided by the size of one (sparse) gradient
transmission. The paper's headline: CL-SIA / CL-TC-SIA sit on the dense-IA
line (K transmissions) — sparsification no longer erodes IA's gain — while
SIA/RE-SIA drift toward conventional routing's (K²+K)/2.
"""

from __future__ import annotations

import dataclasses

from repro.configs import PAPER
from repro.core import comm_cost as cc
from repro.fed.simulator import Simulator

from common import ALGS, agg_config, paper_data

KS = (4, 8, 16, 28)
ROUNDS = 12


def main() -> list[str]:
    lines = ["fig2b,K,algorithm,normalized_transmissions"]
    for k in KS:
        pc = dataclasses.replace(PAPER, num_clients=k)
        fed, _ = paper_data(k, per_client=60)
        for name, kind in ALGS.items():
            sim = Simulator(pc, agg_config(kind), fed, local_lr=pc.lr)
            res = sim.run(ROUNDS)
            bits = sum(res["bits"][4:]) / len(res["bits"][4:])
            norm = cc.normalized_efficiency(bits, pc.d, pc.q, pc.omega)
            lines.append(f"fig2b,{k},{name},{norm:.2f}")
        lines.append(f"fig2b,{k},IA (no sparsification),{k}")
        lines.append(f"fig2b,{k},routing,{(k*k+k)/2:.1f}")
    print("\n".join(lines))
    # headline: CL-SIA ratio to K is 1.0 (full IA efficiency under sparsif.)
    last = [l for l in lines if l.startswith(f"fig2b,{KS[-1]},CL-SIA")][0]
    ratio = float(last.split(",")[-1]) / KS[-1]
    print(f"# CL-SIA normalized/K = {ratio:.3f} (paper: 1.0)")
    return lines


if __name__ == "__main__":
    main()
