"""Paper Fig. 3: test accuracy vs iteration, fixed Q = 78, K = 28.

Expected qualitative result (paper §VI): SIA/RE-SIA best (most data sent),
CL-SIA and TC-SIA only slightly worse, CL-TC-SIA severely impaired.
"""

from __future__ import annotations

import dataclasses

from repro.configs import PAPER
from repro.fed.simulator import Simulator

from common import ALGS, agg_config, paper_data

ROUNDS = 150
EVAL_EVERY = 25


def main(k: int = PAPER.num_clients, rounds: int = ROUNDS) -> list[str]:
    pc = dataclasses.replace(PAPER, num_clients=k)
    fed, test = paper_data(k, per_client=120)
    lines = ["fig3,algorithm,round,test_accuracy"]
    finals = {}
    for name, kind in ALGS.items():
        sim = Simulator(pc, agg_config(kind), fed, local_lr=pc.lr)
        out = sim.run(rounds, test_x=test.x, test_y=test.y,
                      eval_every=EVAL_EVERY)
        for r, acc in out["accuracy"]:
            lines.append(f"fig3,{name},{r},{acc:.4f}")
        finals[name] = out["accuracy"][-1][1]
    print("\n".join(lines))
    order = sorted(finals, key=finals.get, reverse=True)
    print(f"# final-accuracy order: {order} "
          f"(paper: CL-TC-SIA last)")
    return lines


if __name__ == "__main__":
    main()
