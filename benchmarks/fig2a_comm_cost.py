"""Paper Fig. 2a: total transmitted data per global iteration vs K.

Measured from the simulator's exact §V bit accounting (averaged over
training rounds), plus the analytic curves (routing, dense IA, Prop-2
bound) the paper plots alongside.
"""

from __future__ import annotations

import dataclasses

from repro.configs import PAPER
from repro.core import comm_cost as cc
from repro.core.algorithms import AggKind
from repro.fed.simulator import Simulator

from common import ALGS, agg_config, paper_data

KS = (4, 8, 16, 28)
ROUNDS = 12


def measure(k: int) -> dict:
    pc = dataclasses.replace(PAPER, num_clients=k)
    fed, _ = paper_data(k, per_client=60)
    out = {}
    for name, kind in ALGS.items():
        sim = Simulator(pc, agg_config(kind), fed, local_lr=pc.lr)
        res = sim.run(ROUNDS)
        # skip warmup rounds (support still correlating)
        out[name] = sum(res["bits"][4:]) / len(res["bits"][4:])
    out["IA (dense)"] = cc.dense_ia_bits(k, pc.d, pc.omega)
    out["routing (dense)"] = cc.routing_dense_bits(k, pc.d, pc.omega)
    out["routing (sparse)"] = cc.routing_sparse_bits(k, pc.d, pc.q,
                                                     pc.omega)
    out["TC-SIA Prop2 bound"] = cc.tc_sia_bits_bound(
        k, pc.d, pc.q - max(1, round(0.1 * pc.q)),
        max(1, round(0.1 * pc.q)), pc.omega)
    return out


def main(csv: bool = True) -> list[str]:
    lines = ["fig2a,K,algorithm,bits_per_iteration"]
    for k in KS:
        res = measure(k)
        for name, bits in res.items():
            lines.append(f"fig2a,{k},{name},{bits:.0f}")
    if csv:
        print("\n".join(lines))
        # headline check (paper §VI): CL-SIA is K·Q·(ω+⌈log2 d⌉) exactly
        k = KS[-1]
        got = measure(k)["CL-SIA"]
        want = cc.cl_sia_bits(k, PAPER.d, PAPER.q, PAPER.omega)
        print(f"# CL-SIA@K={k}: measured {got:.0f} vs closed-form "
              f"{want:.0f} ({'OK' if abs(got-want) < 1 else 'MISMATCH'})")
    return lines


if __name__ == "__main__":
    main()
