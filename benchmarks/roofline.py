"""Roofline analysis from compiled dry-run artifacts (no real hardware).

Three terms per (arch × shape × mesh), seconds per step on TPU v5e:

    compute    = HLO_FLOPs / peak_FLOPs            (197 TFLOP/s bf16 /chip)
    memory     = HLO_bytes / HBM_bw                (819 GB/s /chip)
    collective = wire_bytes / link_bw              (~50 GB/s per ICI link)

``cost_analysis()`` supplies FLOPs and bytes (per device — SPMD-partitioned
module). Collective bytes are NOT in cost_analysis: we parse the compiled
HLO text and sum operand/result sizes of every collective op, using the
bytes-on-the-wire convention per op type (ring algorithms):

    all-reduce       2·(K−1)/K · operand   ≈ 2 · operand
    all-gather       (K−1)/K · result      ≈ result
    reduce-scatter   (K−1)/K · operand     ≈ operand
    all-to-all       (K−1)/K · operand     ≈ operand
    collective-permute  operand            (exact)

Shapes in compiled (post-SPMD) HLO are already per-device.
"""

from __future__ import annotations

import dataclasses
import json
import re
from typing import Optional

PEAK_FLOPS = 197e12          # bf16 per chip (TPU v5e)
HBM_BW = 819e9               # bytes/s per chip
LINK_BW = 50e9               # bytes/s per ICI link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes(shape_str: str) -> int:
    """'f32[128,1024]' → bytes."""
    m = _SHAPE_RE.match(shape_str)
    if not m:
        return 0
    dt, dims = m.groups()
    nbytes = _DTYPE_BYTES.get(dt)
    if nbytes is None:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * nbytes


def _tuple_or_single_bytes(rhs: str) -> int:
    """Result type may be a tuple '(f32[..], f32[..])' or single shape."""
    total = 0
    for m in _SHAPE_RE.finditer(rhs):
        total += _shape_bytes(m.group(0))
    return total


@dataclasses.dataclass
class CollectiveBytes:
    all_reduce: float = 0.0
    all_gather: float = 0.0
    reduce_scatter: float = 0.0
    all_to_all: float = 0.0
    collective_permute: float = 0.0
    count: int = 0

    @property
    def total(self) -> float:
        return (self.all_reduce + self.all_gather + self.reduce_scatter
                + self.all_to_all + self.collective_permute)

    def as_dict(self) -> dict:
        return {"all_reduce": self.all_reduce, "all_gather": self.all_gather,
                "reduce_scatter": self.reduce_scatter,
                "all_to_all": self.all_to_all,
                "collective_permute": self.collective_permute,
                "total": self.total, "count": self.count}


def _line_collective(stripped: str) -> Optional[tuple[str, float]]:
    """→ (type, wire_bytes) if this HLO line is a collective, else None."""
    m = re.match(r"%?[\w.\-]+\s*=\s*(.*?)\s+([a-z\-]+)\(", stripped)
    if not m:
        return None
    result_part, op = m.groups()
    base = op.removesuffix("-start")
    if base not in _COLLECTIVES or op.endswith("-done"):
        return None
    paren = stripped[stripped.index(op) + len(op):]
    operands = _tuple_or_single_bytes(paren.split("),", 1)[0]
                                      if ")," in paren else paren)
    result = _tuple_or_single_bytes(result_part)
    if base == "all-reduce":
        return base, 2 * operands
    if base == "all-gather":
        return base, result
    return base, operands


_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*(?:\([^)]*\))?\s*->")
_WHILE_RE = re.compile(
    r"while\(.*?condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)")
_CONST_RE = re.compile(r"s32\[\]\s+constant\((\d+)\)")


def _split_computations(hlo_text: str) -> dict:
    """HLO text → {computation_name: [lines]} (brace-delimited blocks)."""
    comps: dict = {}
    name, buf = None, []
    for line in hlo_text.splitlines():
        s = line.strip()
        if name is None:
            m = _COMP_HDR.match(s)
            if m and s.endswith("{"):
                name, buf = m.group(1), []
        else:
            if s == "}":
                comps[name] = buf
                name, buf = None, []
            else:
                buf.append(s)
    return comps


def parse_collective_bytes(hlo_text: str) -> CollectiveBytes:
    """Sum wire bytes per collective type from compiled HLO text,
    **trip-count aware**: collectives inside a `while` body (layer scans,
    KV-chunk loops) are multiplied by the loop's trip count, recursively.
    Trip counts are taken as the max s32[] constant in the loop condition —
    exact for lax.scan-lowered loops (compare iv < N). ``-start``/``-done``
    async pairs are counted once.
    """
    comps = _split_computations(hlo_text)

    def cost_of(comp_name: str, seen: frozenset) -> CollectiveBytes:
        acc = CollectiveBytes()
        if comp_name in seen:          # safety vs pathological recursion
            return acc
        for s in comps.get(comp_name, []):
            wm = _WHILE_RE.search(s)
            if wm:
                cond, body = wm.groups()
                trip = 1
                consts = [int(x) for ln in comps.get(cond, [])
                          for x in _CONST_RE.findall(ln)]
                if consts:
                    trip = max(consts)
                sub = cost_of(body, seen | {comp_name})
                acc.all_reduce += trip * sub.all_reduce
                acc.all_gather += trip * sub.all_gather
                acc.reduce_scatter += trip * sub.reduce_scatter
                acc.all_to_all += trip * sub.all_to_all
                acc.collective_permute += trip * sub.collective_permute
                acc.count += trip * sub.count
                continue
            got = _line_collective(s)
            if got is None:
                continue
            base, nbytes = got
            if base == "all-reduce":
                acc.all_reduce += nbytes
            elif base == "all-gather":
                acc.all_gather += nbytes
            elif base == "reduce-scatter":
                acc.reduce_scatter += nbytes
            elif base == "all-to-all":
                acc.all_to_all += nbytes
            elif base == "collective-permute":
                acc.collective_permute += nbytes
            acc.count += 1
        return acc

    entry = None
    for line in hlo_text.splitlines():
        if line.startswith("ENTRY"):
            m = _COMP_HDR.match(line.strip())
            if m:
                entry = m.group(1)
            break
    if entry is None:
        # fall back: flat scan (no loop awareness)
        out = CollectiveBytes()
        for line in hlo_text.splitlines():
            got = _line_collective(line.strip())
            if got:
                base, nbytes = got
                setattr(out, base.replace("-", "_"),
                        getattr(out, base.replace("-", "_")) + nbytes)
                out.count += 1
        return out
    return cost_of(entry, frozenset())


@dataclasses.dataclass
class Roofline:
    flops: float
    bytes_accessed: float
    wire_bytes: float
    model_flops: float           # 6·N(_active)·tokens — useful-compute ref
    chips: int

    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.bytes_accessed / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.wire_bytes / LINK_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / (HLO_FLOPs × chips): remat/redundancy waste."""
        total = self.flops * self.chips
        return self.model_flops / total if total else 0.0

    @property
    def roofline_fraction(self) -> float:
        """useful-compute time / dominant-term time (≤1; the score)."""
        t_useful = self.model_flops / (self.chips * PEAK_FLOPS)
        t_dom = max(self.t_compute, self.t_memory, self.t_collective)
        return t_useful / t_dom if t_dom else 0.0

    def as_dict(self) -> dict:
        return {
            "flops_per_chip": self.flops,
            "bytes_per_chip": self.bytes_accessed,
            "wire_bytes_per_chip": self.wire_bytes,
            "model_flops": self.model_flops,
            "chips": self.chips,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "useful_flops_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
        }


def model_flops_for(cfg, shape, kind: str) -> float:
    """6·N_active·tokens (train), 2·N_active·tokens (fwd-only prefill),
    2·N_active·batch (one decode token)."""
    n = cfg.active_param_count()
    if kind == "train":
        return 6.0 * n * shape.global_batch * shape.seq_len
    if kind == "prefill":
        return 2.0 * n * shape.global_batch * shape.seq_len
    return 2.0 * n * shape.global_batch          # decode: 1 token/seq


def main() -> None:
    """Summarize a dry-run JSON into the §Roofline table (markdown)."""
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--dryrun-json", default="dryrun_results.json")
    args = ap.parse_args()
    with open(args.dryrun_json) as f:
        cells = json.load(f)
    hdr = ("| arch | shape | mesh | t_comp | t_mem | t_coll | bottleneck | "
           "useful | roofline |")
    print(hdr)
    print("|" + "---|" * 9)
    for c in cells:
        if "roofline" not in c:
            continue
        r = c["roofline"]
        print(f"| {c['arch']} | {c['shape']} | {c['mesh']} "
              f"| {r['t_compute_s']:.2e} | {r['t_memory_s']:.2e} "
              f"| {r['t_collective_s']:.2e} | {r['bottleneck']} "
              f"| {r['useful_flops_ratio']:.2f} "
              f"| {r['roofline_fraction']:.3f} |")


if __name__ == "__main__":
    main()
