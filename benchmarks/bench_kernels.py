"""Kernel micro-benchmarks: wall-time of jnp-ref paths on this host CPU
(indicative only) + the structural metric that transfers to TPU — HBM
sweeps per aggregation node step (fused Pallas vs unfused jnp ops; the
per-algorithm table lives in ``bench_round.vector_passes``).

Emits ``bench,name,us_per_call,derived`` CSV rows and writes the
machine-readable ``BENCH_kernels.json`` (name → {us_per_call, passes}) at
the repo root.

    PYTHONPATH=src python -m benchmarks.run --dim 4000000 --reps 5
    PYTHONPATH=src python benchmarks/bench_kernels.py --dim 100000
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp

from repro.core import sparsify as sp
from repro.kernels import ops, ref

from common import provenance, timed

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

#: streaming sweeps over the d-vector per call (the bench_round counting
#: rule: one grid walk = one sweep, however many operand streams)
PASSES = {
    "ref_sparsify_ef": 1,        # fused select+EF (2 unfused)
    "ref_chain_accum": 1,        # combine + support count (2 unfused)
    "ref_cl_fuse": 1,            # whole CL node step given τ (4 unfused)
    "exact_topq_1pct": 3,        # lax.top_k sort ≈3 sweeps
    "threshold_topq_1pct": 3,    # hist_rounds streaming count sweeps
    "count_ge_64": 1,
}


def main(dim: int = 1_000_000, reps: int = 3) -> list[str]:
    lines = ["bench,name,us_per_call,derived"]
    key = jax.random.PRNGKey(0)
    g = jax.random.normal(key, (dim,))
    e = 0.1 * jax.random.normal(jax.random.fold_in(key, 1), (dim,))
    gi = jax.random.normal(jax.random.fold_in(key, 2), (dim,)) * (
        jax.random.uniform(jax.random.fold_in(key, 3), (dim,)) < 0.01)
    mask = jnp.zeros((dim,))
    w, tau = jnp.float32(1.0), jnp.float32(2.3)
    q = max(1, dim // 100)

    fns = {
        "ref_sparsify_ef": jax.jit(lambda: ref.ref_sparsify_ef(
            g, e, mask, w, tau)),
        "ref_chain_accum": jax.jit(lambda: ref.ref_chain_accum(gi, g)),
        "ref_cl_fuse": jax.jit(lambda: ref.ref_cl_fuse(g, e, gi, w, tau)),
        "exact_topq_1pct": jax.jit(lambda: sp.topq(g, q)),
        "threshold_topq_1pct": jax.jit(
            lambda: sp.topq_by_threshold(g, q)),
        "count_ge_64": jax.jit(lambda: ref.ref_count_ge(
            g, jnp.linspace(0.01, 3, 64))),
    }
    from repro.obs.timing import PhaseTimer
    timer = PhaseTimer()
    results = {}
    for name, fn in fns.items():
        with timer.phase(name, track="bench"):
            _, us = timed(fn, reps=reps)
        lines.append(f"bench,{name},{us:.0f},d={dim}")
        results[name] = {"us_per_call": round(us, 1),
                         "passes": PASSES[name]}

    # structural metric: HBM sweeps per CL-SIA node step (see
    # bench_round.vector_passes for the rule and the per-algorithm table)
    from bench_round import vector_passes
    unfused, fused = vector_passes("cl_sia", False), vector_passes(
        "cl_sia", True)
    lines.append(f"bench,cl_node_passes_unfused,{unfused},vector-passes")
    lines.append(f"bench,cl_node_passes_fused,{fused},vector-passes")
    results["cl_node_passes"] = {"unfused": unfused, "fused": fused}

    out = os.path.join(REPO, "BENCH_kernels.json")
    with open(out, "w") as f:
        json.dump({"meta": {"d": dim, "reps": reps, **provenance(),
                            "phases_s": {name: round(secs, 4) for name, secs
                                         in timer.totals().items()}},
                   "kernels": results}, f, indent=1, sort_keys=True)
        f.write("\n")
    print("\n".join(lines))
    print(f"wrote {out}")
    return lines


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--dim", type=int, default=1_000_000)
    ap.add_argument("--reps", type=int, default=3)
    a = ap.parse_args()
    main(dim=a.dim, reps=a.reps)
