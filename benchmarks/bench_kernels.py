"""Kernel micro-benchmarks: wall-time of jnp-ref paths on this host CPU
(indicative only) + the structural metric that transfers to TPU — HBM pass
counts per aggregation node step (fused Pallas vs unfused jnp ops).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import sparsify as sp
from repro.kernels import ops, ref

from common import timed

D = 1_000_000


def main() -> list[str]:
    lines = ["bench,name,us_per_call,derived"]
    key = jax.random.PRNGKey(0)
    g = jax.random.normal(key, (D,))
    e = 0.1 * jax.random.normal(jax.random.fold_in(key, 1), (D,))
    gi = jax.random.normal(jax.random.fold_in(key, 2), (D,)) * (
        jax.random.uniform(jax.random.fold_in(key, 3), (D,)) < 0.01)
    mask = jnp.zeros((D,))
    w, tau = jnp.float32(1.0), jnp.float32(2.3)

    fns = {
        "ref_sparsify_ef": jax.jit(lambda: ref.ref_sparsify_ef(
            g, e, mask, w, tau)),
        "ref_chain_accum": jax.jit(lambda: ref.ref_chain_accum(gi, g)),
        "ref_cl_fuse": jax.jit(lambda: ref.ref_cl_fuse(g, e, gi, w, tau)),
        "exact_topq_1pct": jax.jit(lambda: sp.topq(g, D // 100)),
        "threshold_topq_1pct": jax.jit(
            lambda: sp.topq_by_threshold(g, D // 100)),
        "count_ge_64": jax.jit(lambda: ref.ref_count_ge(
            g, jnp.linspace(0.01, 3, 64))),
    }
    for name, fn in fns.items():
        _, us = timed(fn, reps=3)
        lines.append(f"bench,{name},{us:.0f},d={D}")

    # structural metric: HBM passes per CL-SIA node step
    #   unfused jnp: read g,e,γ; write g̃; read g̃ (topk/sort multi-pass ≈3);
    #                write γ,e' ⇒ ≥8 vector passes
    #   fused cl_fuse + 3-round threshold: 3 count passes + 1 fused pass
    #                reading (g,e,γ) writing (γ,e') ⇒ 4 passes
    lines.append("bench,cl_node_passes_unfused,8,vector-passes")
    lines.append("bench,cl_node_passes_fused,4,vector-passes")
    print("\n".join(lines))
    return lines


if __name__ == "__main__":
    main()
