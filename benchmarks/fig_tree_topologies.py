"""Tree-topology sweep: bits/round and critical-path latency across
constellation shapes (beyond-paper figure; chain = paper baseline).

For each topology (chain, star, grid, Walker-delta, Walker-star, random
geometric) and each Algorithm 1–5 we measure exact §V bits from the tree
simulator and compare with the `comm_cost` tree closed forms / bounds. A
second table reports the aggregation critical path (serialize + propagate
over per-link bandwidth/latency) — the quantity tree routing actually
optimizes: CL-SIA bits are topology-invariant, but a Walker tree finishes
the round ~depth/K sooner than the chain.

A final section sweeps the *device* path: the chain ring vs routed tree
plans lowered onto an 8-fake-device shard_map mesh
(`repro.agg.device.run_plan_segments_local`), reporting exact §V bits, the
modeled `round_latency_s` critical path, and measured wall-clock per round.

    PYTHONPATH=src python benchmarks/fig_tree_topologies.py
"""

from __future__ import annotations

import os

# must precede the first jax import: the device sweep runs the lowered
# plans on 8 fake host devices
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

import dataclasses
import time

from repro.agg import TopologySchedule, bandwidth_budgets, compile_plan, execute
from repro.configs import PAPER
from repro.core import comm_cost as cc
from repro.fed.simulator import Simulator
from repro.fed.topology import TreeTopology
from repro.topo import graph as tg
from repro.topo.routing import widest_path_tree
from repro.topo.tree import round_latency_s

from common import ALGS, agg_config, paper_data

ROUNDS = 10
WARMUP = 4

TOPOLOGIES = {
    "chain-12": tg.path_graph(12),
    "star-12": tg.star_graph(12),
    "grid-3x4": tg.grid_graph(3, 4),
    "walker-delta-3x4": tg.walker_delta(3, 4),
    "walker-star-4x3": tg.walker_star(4, 3),
    "geo-12": tg.random_geometric(12, seed=7),
}


def measure(name: str, g: tg.ConstellationGraph) -> list[str]:
    k = g.num_clients
    pc = dataclasses.replace(PAPER, num_clients=k)
    fed, _ = paper_data(k, per_client=60)
    topo = TreeTopology(g, routing="widest")
    tree = topo.tree()
    sub = tree.subtree_sizes()
    depths = tree.depths()
    lines = []
    for alg, kind in ALGS.items():
        sim = Simulator(pc, agg_config(kind), fed, local_lr=pc.lr,
                        tree_topology=topo)
        res = sim.run(ROUNDS)
        bits = sum(res["bits"][WARMUP:]) / len(res["bits"][WARMUP:])
        lines.append(f"tree,{name},{alg},{bits:.0f},{depths.max()}")
    lines.append(f"tree,{name},IA (dense),"
                 f"{cc.dense_ia_bits_tree(k, pc.d, pc.omega):.0f},"
                 f"{depths.max()}")
    lines.append(f"tree,{name},routing (sparse),"
                 f"{cc.routing_sparse_bits_tree(depths, pc.d, pc.q, pc.omega):.0f},"
                 f"{depths.max()}")
    ql = max(1, round(0.1 * pc.q))
    lines.append(f"tree,{name},TC-SIA Prop2 bound,"
                 f"{cc.tc_sia_bits_bound_tree(sub, pc.d, pc.q - ql, ql, pc.omega):.0f},"
                 f"{depths.max()}")
    # critical path: CL-SIA constant payload per uplink
    per_hop = [cc.cl_sia_bits(1, pc.d, pc.q, pc.omega)] * k
    lat = round_latency_s(tree, per_hop)
    lines.append(f"tree,{name},CL-SIA critical-path ms,{lat * 1e3:.2f},"
                 f"{depths.max()}")
    return lines


def measure_time_varying() -> list[str]:
    """All six topologies cycled round-robin through ONE jitted round.

    The schedule pads every routed tree to a common (L, W), so the sweep
    triggers a single trace; per-round bits/latency follow whichever graph
    the constellation offers that round.
    """
    k = 12
    pc = dataclasses.replace(PAPER, num_clients=k)
    fed, _ = paper_data(k, per_client=60)
    sched = TopologySchedule.from_topologies(
        [TreeTopology(g, routing="widest").tree() for g in TOPOLOGIES.values()])
    sim = Simulator(pc, agg_config(ALGS["CL-SIA"]), fed, local_lr=pc.lr)
    res = sim.run(2 * len(TOPOLOGIES), topology_schedule=sched)
    lines = [f"schedule,common-LxW,{sched.shape[0]}x{sched.shape[1]},"
             f"{len(sched.plans)} plans,1 specialization"]
    for (name, _), b in zip(list(TOPOLOGIES.items()) * 2, res["bits"]):
        lines.append(f"schedule,{name},CL-SIA,{b:.0f},-")
    return lines


def measure_bandwidth_aware() -> list[str]:
    """Uniform vs bandwidth-scaled Top-Q budgets on a heterogeneous shell."""
    import jax
    import jax.numpy as jnp

    g = tg.walker_delta(3, 4)          # intra 200M / inter 100M / ground 50M
    tree = widest_path_tree(g)
    k = tree.num_clients
    pc = dataclasses.replace(PAPER, num_clients=k)
    cfg = agg_config(ALGS["CL-SIA"])
    grads = jax.random.normal(jax.random.PRNGKey(0), (k, pc.d))
    e = jnp.zeros((k, pc.d))
    w = jnp.ones((k,), jnp.float32)
    uni = execute(cfg, compile_plan(tree), grads, e, w)
    bwa = execute(cfg, compile_plan(tree, q_budget=bandwidth_budgets(cfg, tree)),
                  grads, e, w)
    return [f"bw_budget,walker-delta-3x4,uniform,{float(uni.stats.bits.sum()):.0f},-",
            f"bw_budget,walker-delta-3x4,bw-scaled,{float(bwa.stats.bits.sum()):.0f},-"]


def measure_device_plans() -> list[str]:
    """Chain ring vs routed tree plans on the device (shard_map) path.

    Every plan runs through ``run_plan_segments_local`` on an 8-device
    mesh: the chain plan IS the historic rotated ring; the tree plans are
    the new multi-device topologies. CL-SIA §V bits are topology-invariant,
    so what the tree buys is the critical path — ``round_latency_s`` drops
    with depth while the measured per-round wall clock stays flat (same
    node-step count, same number of level collectives per level).
    """
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    from repro import compat
    from repro.agg.device import ring_chain_plan, run_plan_segments_local
    from repro.agg import compile_plan
    from repro.core.ring import RingStats, segment_budget

    K = 8
    if len(jax.devices()) < K:
        return [f"device,unavailable,needs {K} devices,-,-"]
    n = K * 4096
    pc = dataclasses.replace(PAPER, num_clients=K)
    mesh = compat.make_mesh((K,), ("data",))
    G = jax.random.normal(jax.random.PRNGKey(0), (K, n))
    EF = jnp.zeros((K, n))
    cfg = dataclasses.replace(agg_config(ALGS["CL-SIA"]),
                              q=segment_budget(pc.q * K, K))

    graphs = {"chain-ring": None,
              "grid-2x4": tg.grid_graph(2, 4),
              "walker-delta-2x4": tg.walker_delta(2, 4)}
    lines = []
    for name, g in graphs.items():
        if g is None:
            plan, tree = ring_chain_plan(K), None
        else:
            tree = widest_path_tree(g)
            plan = compile_plan(tree)

        def ring_fn(g_l, ef_l):
            final, ef_new, st = run_plan_segments_local(
                cfg, plan, g_l[0], ef_l[0], jnp.float32(1.0), axis="data",
                transport="static")
            return final[None], ef_new[None], jax.tree.map(
                lambda s: jax.lax.psum(s, "data"), st)

        step = jax.jit(compat.shard_map(
            ring_fn, mesh=mesh, in_specs=(P("data"), P("data")),
            out_specs=(P("data"), P("data"),
                       jax.tree.map(lambda _: P(), RingStats(0., 0., 0.))),
            axis_names={"data"}))
        final, ef, st = step(G, EF)
        jax.block_until_ready(final)
        t0 = time.time()
        reps = 10
        for _ in range(reps):
            final, ef, st = step(G, EF)
        jax.block_until_ready(final)
        ms = (time.time() - t0) / reps * 1e3

        if tree is not None:
            per_hop = [cc.cl_sia_bits(1, n, cfg.q * K, pc.omega)] * K
            lat = round_latency_s(tree, per_hop) * 1e3
            depth = tree.max_depth()
        else:
            chain = widest_path_tree(tg.path_graph(K))
            per_hop = [cc.cl_sia_bits(1, n, cfg.q * K, pc.omega)] * K
            lat = round_latency_s(chain, per_hop) * 1e3
            depth = K
        lines.append(f"device,{name},CL-SIA,{float(st.bits):.0f} bits,"
                     f"depth {depth}, crit-path {lat:.2f} ms, "
                     f"measured {ms:.1f} ms/round")
    return lines


def main() -> list[str]:
    lines = ["fig_tree,topology,algorithm,bits_per_round_or_ms,depth"]
    for name, g in TOPOLOGIES.items():
        lines.extend(measure(name, g))
    lines.extend(measure_time_varying())
    lines.extend(measure_bandwidth_aware())
    lines.extend(measure_device_plans())
    print("\n".join(lines))
    # headline: CL-SIA bits are topology-invariant (closed form holds on
    # every tree), while critical-path latency tracks tree depth; the
    # schedule section shows all six topologies served by one specialization
    # and bandwidth-scaled budgets undercutting the uniform-q bit cost; the
    # device section runs the same plans on the 8-device shard_map ring —
    # chain vs tree bits match, the tree wins the critical path.
    return lines


if __name__ == "__main__":
    main()
