"""Shared helpers for the paper-figure benchmarks."""

from __future__ import annotations

import datetime
import subprocess
import time

import jax

from repro.configs import PAPER
from repro.core.algorithms import AggConfig, AggKind
from repro.data.federated import partition_iid
from repro.data.synthetic import make_synthetic_mnist

ALGS = {
    "SIA": AggKind.SIA,
    "RE-SIA": AggKind.RE_SIA,
    "CL-SIA": AggKind.CL_SIA,
    "TC-SIA": AggKind.TC_SIA,
    "CL-TC-SIA": AggKind.CL_TC_SIA,
}


def agg_config(kind: AggKind, q: int | None = None) -> AggConfig:
    q = PAPER.q if q is None else q
    ql = max(1, round(0.1 * q))
    return AggConfig(kind=kind, q=q, q_global=q - ql, q_local=ql,
                     omega=PAPER.omega)


def paper_data(num_clients: int, per_client: int = 200, seed: int = 0):
    train = make_synthetic_mnist(jax.random.PRNGKey(seed),
                                 num_clients * per_client)
    test = make_synthetic_mnist(jax.random.PRNGKey(seed + 1), 2000)
    fed = partition_iid(jax.random.PRNGKey(seed + 2), train, num_clients)
    return fed, test


def provenance() -> dict:
    """Run provenance for BENCH_*.json meta blocks — enough to answer
    "which commit, when, on what" for any committed number."""
    try:
        sha = subprocess.run(["git", "rev-parse", "--short", "HEAD"],
                             capture_output=True, text=True,
                             timeout=10).stdout.strip() or None
    except (OSError, subprocess.SubprocessError):
        sha = None
    return {
        "git_sha": sha,
        "ts_utc": datetime.datetime.now(
            datetime.timezone.utc).isoformat(timespec="seconds"),
        "device_kind": jax.devices()[0].device_kind,
        "backend": jax.default_backend(),
        "jax": jax.__version__,
    }


def timed(fn, *args, reps: int = 3):
    fn(*args)                      # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return out, (time.perf_counter() - t0) / reps * 1e6   # µs
