"""Whole-round aggregation benchmark → ``BENCH_agg_round.json``.

Measures one full aggregation round — every node step of the padded
``(L, W)`` schedule — for all five algorithms over a chain plan and a
routed-tree plan, on the host executor (``repro.agg.execute``) and on the
8-device shard_map lowering (``repro.agg.execute_sharded``), with both the
exact ``lax.top_k`` sparsifier and the streaming threshold sparsifier.
Wall-times are what this machine can honestly measure; the metric that
transfers to TPU is structural — **HBM sweeps per node step** — where the
fused Pallas path is strictly smaller for every algorithm (the aggregation
round is memory-bound, so sweep count bounds achievable wall-time).

Sweep counting rule (one "sweep" = one streaming pass over a d-length
vector, however many operand streams ride along — a fused kernel reading
(g, e, γ_in) and writing (γ_out, e') in one grid walk is ONE sweep):

    stage            unfused  fused  note
    g̃/γ̃ materialize       1      1  sparsifier state needs it jnp-side
    sparsifier (τ/mask)    3      3  top_k sort ≈3 sweeps; threshold =
                                     hist_rounds count sweeps (kernel)
    select + EF            2      1  γ̄=keep(g̃) and e'=g̃−γ̄ fuse into the
                                     sparsify_ef / cl_fuse kernel
    IA combine          1 (0)  1 (0)  γ_out=γ_in+γ̄; 0 for the CL family
                                     (already inside γ̃ / cl_fuse)
    §V support counts   1 (2)      0  nnz (+ off-mask nnz for TC) fuse
                                     into the kernels' accumulators

The fused table assumes the TC global mask *streams* — since the
lane-shared block spec in ``kernels/level.py`` the 1-D mask rides into the
kernels once per block with no ``[W, d]`` HBM broadcast, so the counted
sweeps are what actually executes (the broadcast was an uncounted extra
write + W-fold read before).

Run ``PYTHONPATH=src python benchmarks/bench_round.py`` (add ``--smoke``
for the CI-sized instant version; ``--dim/--clients/--reps`` to scale;
``--nested`` for the pod×data staged round and its DCI-wire split;
``--cohorts B`` caps the multi-tenant ``batched_round`` section — B
cohorts as one launch vs a B-sequential loop, host and 8-device).
The JSON lands at the repo root so every future PR diffs against it.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import tempfile
import time

# 8 fake host devices for the device-round section — must precede the jax
# import, so importing this module from an already-running jax process
# (benchmarks/run.py) skips the device section instead of forcing flags.
if "jax" not in sys.modules and "xla_force_host_platform_device_count" not \
        in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=8"
                               ).strip()

import jax                                                    # noqa: E402
import jax.numpy as jnp                                       # noqa: E402

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DEVICES = 8

ALG_NAMES = ["sia", "re_sia", "cl_sia", "tc_sia", "cl_tc_sia"]


def vector_passes(kind: str, fused: bool) -> int:
    """HBM sweeps per node step under the counting rule in the docstring."""
    cl = kind in ("cl_sia", "cl_tc_sia")
    tc = kind in ("tc_sia", "cl_tc_sia")
    materialize = 1
    sparsifier = 3
    select_ef = 1 if fused else 2
    combine = 0 if cl else 1
    counts = 0 if fused else (2 if tc else 1)
    return materialize + sparsifier + select_ef + combine + counts


def _timed(fn, reps: int):
    out = jax.block_until_ready(fn())          # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn()
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6       # µs


def _plans(k: int):
    from repro.agg import compile_plan
    from repro.topo import graph as tg
    from repro.topo.routing import shortest_path_tree
    tree = shortest_path_tree(tg.grid_graph(2, k // 2))
    pad = (max(k, tree.max_depth() + 1), max(1, k // 2))
    return {"chain": compile_plan(k, pad_to=pad),
            "tree": compile_plan(tree, pad_to=pad)}


def _round_inputs(k: int, d: int):
    g = jax.random.normal(jax.random.PRNGKey(0), (k, d))
    e = 0.1 * jax.random.normal(jax.random.PRNGKey(1), (k, d))
    w = jnp.ones((k,), jnp.float32)
    return g, e, w


def _cfg(name: str, q: int, impl: str, kernel_mode: str = "auto", **extra):
    from repro.core.algorithms import AggConfig, AggKind
    return AggConfig(kind=AggKind(name), q=q, topq_impl=impl,
                     kernel_mode=kernel_mode, **extra)


def _gmask(cfg, d):
    from repro.core.algorithms import AggKind
    if cfg.kind in (AggKind.TC_SIA, AggKind.CL_TC_SIA):
        return jnp.zeros((d,)).at[jnp.arange(cfg.q_global)].set(1.0)
    return None


def bench_host(k, d, q, reps, impls, kernel_mode="never"):
    """µs per jitted host round, per algorithm × plan × sparsifier.

    ``kernel_mode="never"`` pins the unfused jnp baseline regardless of
    the caller's ``REPRO_PALLAS_INTERPRET`` environment — otherwise a
    shell that still exports the parity-test knob would silently record
    interpret-mode timings into the baseline JSON.
    """
    import functools
    from repro.agg import execute
    plans = _plans(k)
    g, e, w = _round_inputs(k, d)
    out = {}
    for name in ALG_NAMES:
        out[name] = {}
        for plan_name, plan in plans.items():
            out[name][plan_name] = {}
            for impl in impls:
                cfg = _cfg(name, q, impl, kernel_mode)
                fn = jax.jit(functools.partial(
                    execute, cfg, global_mask=_gmask(cfg, d)))
                out[name][plan_name][impl] = round(
                    _timed(lambda: fn(plan, g, e, w).aggregate, reps), 1)
    return out


TAU_VARIANTS = (
    # (name, topq_impl, kernel_mode, extra AggConfig kwargs)
    ("exact", "exact", "never", {}),
    ("threshold_scan", "threshold", "never", {}),
    ("threshold_hist", "threshold", "never", {"tau_impl": "hist"}),
    ("fused_operand", "threshold", "ref", {}),
)


def bench_tau_search(k, d, q, reps, hist_branch, hist_rounds):
    """µs per jitted round across the four τ-search implementations.

    * ``exact``           — ``lax.top_k`` sparsifier (the O(d log d)
      oracle the threshold path is racing).
    * ``threshold_scan``  — branch-and-bisect with per-round
      ``count_ge_sorted`` counts over the materialized operand.
    * ``threshold_hist``  — ONE joint digit histogram replaces the
      ``hist_rounds`` count sweeps; bracket integers are bit-identical
      to the scan (``tau_impl="hist"``, rounds ∈ {1, 2}).
    * ``fused_operand``   — the scan's counts consume the bisection
      operand rebuilt on the fly from the raw node inputs
      (``kernel_mode="ref"``: fused structure, jnp kernel bodies — the
      honest host number without Pallas-interpret overhead).

    Host runs every algorithm × {chain, tree}; the 8-device shard_map
    round runs every algorithm on the chain plan (the per-rank lowering
    reads ``tau_impl`` off the same config, so the hist variant there is
    one psum'd histogram instead of ``hist_rounds`` count+psum rounds).
    """
    import functools
    from repro.agg import execute, execute_sharded
    from repro.agg.device import client_mesh
    plans = _plans(k)
    g, e, w = _round_inputs(k, d)

    def cfgs(name):
        for vname, impl, kmode, extra in TAU_VARIANTS:
            kw = dict(extra)
            if kw.get("tau_impl") == "hist":
                kw["hist_branch"] = hist_branch
                kw["hist_rounds"] = hist_rounds
            yield vname, _cfg(name, q, impl, kmode, **kw)

    host = {}
    for name in ALG_NAMES:
        host[name] = {}
        for plan_name, plan in plans.items():
            row = {}
            for vname, cfg in cfgs(name):
                fn = jax.jit(functools.partial(
                    execute, cfg, global_mask=_gmask(cfg, d)))
                row[vname] = round(
                    _timed(lambda: fn(plan, g, e, w).aggregate, reps), 1)
            host[name][plan_name] = row

    if jax.device_count() < k:
        device = {"skipped": f"needs {k} devices, have "
                             f"{jax.device_count()}"}
    else:
        mesh = client_mesh(k)
        plan = plans["chain"]
        device = {}
        for name in ALG_NAMES:
            row = {}
            for vname, cfg in cfgs(name):
                fn = jax.jit(functools.partial(
                    execute_sharded, cfg, mesh=mesh,
                    global_mask=_gmask(cfg, d)))
                row[vname] = round(
                    _timed(lambda: fn(plan, g, e, w).aggregate, reps), 1)
            device[name] = {"chain": row}

    return {"hist_branch": hist_branch, "hist_rounds": hist_rounds,
            "host": host, "device": device}


def bench_device(k, d, q, reps):
    """µs per jitted 8-device shard_map round (client-per-rank kernel)."""
    import functools
    from repro.agg import execute_sharded
    from repro.agg.device import client_mesh
    if jax.device_count() < k:
        return {"skipped": f"needs {k} devices, have {jax.device_count()} "
                           f"(set XLA_FLAGS before importing jax)"}
    mesh = client_mesh(k)
    plans = _plans(k)
    g, e, w = _round_inputs(k, d)
    out = {}
    for name in ALG_NAMES:
        out[name] = {}
        for plan_name, plan in plans.items():
            cfg = _cfg(name, q, "exact", "never")
            fn = jax.jit(functools.partial(
                execute_sharded, cfg, mesh=mesh,
                global_mask=_gmask(cfg, d)))
            out[name][plan_name] = round(
                _timed(lambda: fn(plan, g, e, w).aggregate, reps), 1)
    return out


def bench_nested(k_pod, k_data, d, q, reps):
    """Nested (pod×data) staged round vs the flat ring on the same ranks.

    Runs the chain×chain :class:`~repro.agg.nested.NestedPlan` through
    ``run_nested_segments_local`` on a (pod, data) mesh and the flat
    rotated ring over the combined (pod, data) axis, per algorithm.
    Records per-stage §V bits — stage 1 is the scarce-link (pod-seam DCI)
    wire — plus the analytic flat-vs-staged DCI split: the flat ring
    crosses the seam K_p·K_d times per round, the staged schedule K_p
    (``core.comm_cost.dci_wire_flat_vs_nested``), so the measured stage-1
    bits are the flat ring's seam traffic ÷ K_d.
    """
    import functools
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro import compat
    from repro.agg.device import run_nested_segments_local
    from repro.agg.nested import pod_ring_nested
    from repro.core import comm_cost as cc
    from repro.core.ring import RingStats, rotated_ring_local

    k = k_pod * k_data
    if jax.device_count() < k:
        return {"skipped": f"needs {k} devices, have {jax.device_count()}"}
    mesh = compat.make_mesh((k_pod, k_data), ("pod", "data"))
    n = d - d % (k * k)            # divisible by both stage segmentations
    nested = pod_ring_nested(k_pod, k_data)
    G = jax.random.normal(jax.random.PRNGKey(0), (k, n))
    EF = 0.1 * jax.random.normal(jax.random.PRNGKey(1), (k, n))
    PEF = jnp.zeros((k, n // k_data))
    w = jnp.float32(1.0)
    sspec = jax.tree.map(lambda _: P(), (RingStats(0., 0., 0.),
                                         RingStats(0., 0., 0.)))

    out = {"k_pod": k_pod, "k_data": k_data, "n": n, "alg": {}}
    for name in ALG_NAMES:
        cfg = _cfg(name, q, "exact", "never")
        gm = _gmask(cfg, n)

        def nested_fn(g_l, ef_l, pef_l):
            seg, ef_new, (pef_new,), sts = run_nested_segments_local(
                cfg, nested, g_l[0], ef_l[0], (pef_l[0],), w,
                axes=("data", "pod"), global_mask_local=gm)
            sts = jax.tree.map(
                lambda s: jax.lax.psum(s, ("pod", "data")), sts)
            return seg[None], ef_new[None], pef_new[None], sts

        def flat_fn(g_l, ef_l):
            seg, ef_new, st = rotated_ring_local(
                cfg, g_l[0], ef_l[0], w, axis=("pod", "data"),
                global_mask_local=gm)
            st = jax.tree.map(
                lambda s: jax.lax.psum(s, ("pod", "data")), st)
            return seg[None], ef_new[None], st

        run_n = jax.jit(compat.shard_map(
            nested_fn, mesh=mesh, in_specs=(P(("pod", "data")),) * 3,
            out_specs=(P(("pod", "data")),) * 3 + (sspec,),
            axis_names={"pod", "data"}))
        run_f = jax.jit(compat.shard_map(
            flat_fn, mesh=mesh, in_specs=(P(("pod", "data")),) * 2,
            out_specs=(P(("pod", "data")),) * 2 + (
                jax.tree.map(lambda _: P(), RingStats(0., 0., 0.)),),
            axis_names={"pod", "data"}))

        _, _, _, sts = jax.block_until_ready(run_n(G, EF, PEF))
        _, _, st_f = jax.block_until_ready(run_f(G, EF))
        out["alg"][name] = {
            "nested_round_us": round(
                _timed(lambda: run_n(G, EF, PEF)[0], reps), 1),
            "flat_round_us": round(
                _timed(lambda: run_f(G, EF)[0], reps), 1),
            "stage_bits": [float(sts[0].bits), float(sts[1].bits)],
            "flat_bits": float(st_f.bits),
            # seam traffic: the flat ring carries every hop's payload
            # across the pod seam K_p·K_d times/round, the staged
            # schedule K_p — measured stage-1 bits ARE the staged seam wire
            "dci_bits_nested": float(sts[1].bits),
            "dci_bits_flat_model": float(sts[1].bits) * k_data,
        }
    flat_m, nested_m = cc.dci_wire_flat_vs_nested(k_pod, k_data, d, q)
    out["dci_packet_model"] = {"flat": flat_m, "nested": nested_m,
                               "reduction_x": flat_m / nested_m}
    # cross-check the measured staged DCI wire against the closed-form
    # CEILING: stage 1 runs K_p segments × K_p hops per data column, each
    # carrying ≤ q CL coordinates over a sub-segment of n/(K_d·K_p). It
    # can genuinely undershoot — stage 0 already Top-Q'd the pod partials,
    # so a sub-segment's γ̃ may hold fewer than q nonzeros (that is the
    # staged schedule's second saving on top of the K_d× fewer crossings).
    seg2 = n // (k_data * k_pod)
    cap = k_data * k_pod * k_pod * q * (32 + cc.idx_bits(seg2))
    got = out["alg"]["cl_sia"]["dci_bits_nested"]
    assert 0 < got <= cap, (got, cap)
    out["dci_bits_cl_sia_cap"] = cap
    return out


def bench_batched(k, d, reps, cohort_sizes, wave_dim=512):
    """Multi-tenant batched rounds: B cohorts as ONE launch vs B sequential
    rounds, on the host executor (``execute_batched`` vs an ``execute``
    loop) and the 8-device shard_map lowering (``execute_sharded_batched``
    vs an ``execute_sharded`` loop). Records per-cohort round latency and
    aggregate rounds/s for each B, plus the speedup over the sequential
    loop, in TWO regimes:

    * ``wavefront`` (d = ``wave_dim``): per-hop payloads are small (the
      multi-hop constellation case — q ≈ d/100 compact coordinates per
      ISL hop), so the round is dominated by the launch + per-level
      collective wavefront the batched path amortizes — B cohorts cost
      one L-level wavefront instead of B. This is the headline: the
      term that dominates real multi-hop rounds shrinks ~B×.
    * ``compute`` (d = the caller's ``--dim``): per-element work
      dominates. The forced-host-device CPU backend serializes lanes and
      the B-wide working set ([K, B, d] gathers) falls out of cache, so
      batching can go *below* 1× here — recorded deliberately, so the
      crossover is visible instead of hidden by a flattering dim choice.

    Also audits the scheduler contract: cohorts are submitted through one
    :class:`repro.agg.RoundScheduler` and the trace counter must not
    exceed one jit specialization per (bucket, shape, padded-B) — the
    batched path adds zero specializations beyond the bucket set.
    """
    import functools
    from repro.agg import (CohortRound, RoundScheduler, compile_plan,
                           execute, execute_batched, execute_sharded,
                           execute_sharded_batched)
    from repro.agg.device import client_mesh
    plan = compile_plan(k)
    have_dev = jax.device_count() >= k
    mesh = client_mesh(k) if have_dev else None

    out = {"alg": "cl_sia", "plan": "chain", "regimes": {}}
    for regime, dd in (("wavefront", wave_dim), ("compute", d)):
        q = max(1, dd // 100)
        cfg = _cfg("cl_sia", q, "exact", "never")
        seq_h = jax.jit(functools.partial(execute, cfg))
        bat_h = jax.jit(functools.partial(execute_batched, cfg))
        if have_dev:
            seq_d = jax.jit(functools.partial(execute_sharded, cfg,
                                              mesh=mesh))
            bat_d = jax.jit(functools.partial(execute_sharded_batched, cfg,
                                              mesh=mesh))
        cohorts = {}
        for b in cohort_sizes:
            key = jax.random.PRNGKey(b)
            g = jax.random.normal(key, (b, k, dd))
            e = 0.1 * jax.random.normal(jax.random.fold_in(key, 1),
                                        (b, k, dd))
            w = jnp.ones((b, k), jnp.float32)

            def seq_loop(fn):
                return [fn(plan, g[i], e[i], w[i]).aggregate
                        for i in range(b)]

            entry = {}
            for backend, ok, seq_fn, bat_fn in (
                    ("host", True, seq_h, bat_h),
                    ("device", have_dev,
                     seq_d if have_dev else None,
                     bat_d if have_dev else None)):
                if not ok:
                    entry[backend] = {"skipped": f"needs {k} devices"}
                    continue
                us_seq = _timed(lambda: seq_loop(seq_fn), reps)
                # the shared [L, W] plan keeps the compact wire live on
                # the batched path, same as the sequential baseline;
                # stacked [B, L, W] plans are the scheduler's business
                us_bat = _timed(lambda: bat_fn(plan, g, e, w).aggregate,
                                reps)
                entry[backend] = {
                    "sequential_us": round(us_seq, 1),
                    "batched_us": round(us_bat, 1),
                    "per_cohort_us": round(us_bat / b, 1),
                    "rounds_per_s": round(b / (us_bat * 1e-6), 1),
                    "rounds_per_s_sequential": round(b / (us_seq * 1e-6),
                                                     1),
                    "speedup_x": round(us_seq / us_bat, 2),
                }
            cohorts[str(b)] = entry
        out["regimes"][regime] = {"d": dd, "q": q, "cohorts": cohorts}

    # scheduler audit (wavefront dim): two passes over every B — the
    # second pass hits warm buckets, so traces must not grow past the
    # (bucket, shape, padded-B) set
    q = max(1, wave_dim // 100)
    cfg = _cfg("cl_sia", q, "exact", "never")
    sched = RoundScheduler(cfg)
    for rnd in range(2):
        for b in cohort_sizes:
            key = jax.random.PRNGKey(100 * rnd + b)
            g = jax.random.normal(key, (b, k, wave_dim))
            sched.submit([CohortRound(cohort_id=i, plan=plan, grads=g[i],
                                      e=0.1 * g[i],
                                      weights=jnp.ones((k,)))
                          for i in range(b)])
    sched.assert_bucket_specializations()
    out["scheduler"] = {
        "submits": 2 * len(cohort_sizes),
        "shape_buckets": sched.expected_specializations,
        "jit_traces": sched.trace_counter.count,
    }
    return out


def bench_scenario(name: str):
    """Run a fault-injection preset through the simulator and record the
    realized per-round §V bits (the curve a relay-cascade / link-flap /
    degradation scenario actually produces), plus wall-clock per round.

    The whole scenario runs inside one jit specialization (asserted), so
    the per-round wall time is an honest steady-state number — the trace
    is written to a temp file and validated like the CI smoke gate.
    """
    from repro.scenario import preset
    from repro.scenario.run import run_scenario

    spec = preset(name)
    trace = os.path.join(tempfile.gettempdir(),
                         f"bench_scenario_{name}.jsonl")
    t0 = time.perf_counter()
    curves = run_scenario(spec, backend="host", out=trace)
    wall = time.perf_counter() - t0
    assert curves["_retraces"] == 1, curves["_retraces"]
    from repro.obs import validate_trace
    assert validate_trace(trace)["errors"] == []
    compiled = curves["_scenario"]
    return {
        "preset": name, "rounds": spec.rounds,
        "clients": spec.num_clients,
        "distinct_plans": len(compiled.schedule.plans),
        "injected_events": len(compiled.events),
        "retraces": curves["_retraces"],
        "round_us": round(wall / spec.rounds * 1e6, 1),
        "bits_per_round": [round(b, 1) for b in curves["bits"]],
        "bits_total": round(float(sum(curves["bits"])), 1),
        "loss_first": round(float(curves["loss"][0]), 6),
        "loss_last": round(float(curves["loss"][-1]), 6),
    }


def smoke_fused_interpret(k, d, q):
    """Run one fused (Pallas-interpret) round per algorithm and check it
    against the unfused oracle — keeps the kernel path exercised by CI on
    machines with no TPU. Returns µs per round (interpret overhead
    included — NOT comparable to the compiled timings)."""
    import functools
    import numpy as np
    from repro.agg import execute
    plan = _plans(k)["tree"]
    g, e, w = _round_inputs(k, d)
    out = {}
    for name in ALG_NAMES:
        cfg_f = _cfg(name, q, "threshold", "always")
        cfg_u = _cfg(name, q, "threshold", "never")
        gm = _gmask(cfg_f, d)
        run_f = jax.jit(functools.partial(execute, cfg_f, global_mask=gm))
        run_u = jax.jit(functools.partial(execute, cfg_u, global_mask=gm))
        rf, ru = run_f(plan, g, e, w), run_u(plan, g, e, w)
        np.testing.assert_array_equal(np.asarray(rf.aggregate),
                                      np.asarray(ru.aggregate),
                                      err_msg=f"{name} fused != unfused")
        out[name] = round(_timed(lambda: run_f(plan, g, e, w).aggregate,
                                 1), 1)
    return out


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--dim", type=int, default=1 << 15,
                    help="flat gradient length d per client")
    ap.add_argument("--clients", type=int, default=DEVICES)
    ap.add_argument("--q", type=int, default=None,
                    help="per-hop Top-Q budget (default d // 100)")
    ap.add_argument("--reps", type=int, default=5)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny instant run (CI harness check); writes to a "
                         "temp file so the recorded baseline is not "
                         "clobbered")
    ap.add_argument("--nested", action="store_true",
                    help="add the pod×data staged round (2 pods × 4 ranks "
                         "on the 8 fake devices): per-stage §V bits and "
                         "the DCI-wire reduction vs the flat ring")
    ap.add_argument("--cohorts", type=int, default=8, metavar="B",
                    help="multi-tenant batched-round section: bench B in "
                         "{1, 4, 8} up to this cap (batched single-launch "
                         "vs B-sequential, host + 8-device); 0 disables")
    ap.add_argument("--hist", action="store_true",
                    help="run the tau_search section even under --smoke "
                         "(the full run always includes it): exact vs "
                         "threshold-scan vs threshold-hist vs "
                         "fused-operand, host + 8-device")
    ap.add_argument("--hist-branch", type=int, default=64, metavar="B",
                    help="bisection branch factor for the threshold_hist "
                         "variant (<= 1024)")
    ap.add_argument("--hist-rounds", type=int, default=2,
                    help="bisection rounds for the threshold_hist variant "
                         "(1 or 2 — the joint histogram covers two)")
    ap.add_argument("--scenario", default=None, metavar="PRESET",
                    help="also run a repro.scenario preset (e.g. "
                         "relay-cascade) through the simulator and record "
                         "its realized per-round SS V bits")
    ap.add_argument("--out", default=None,
                    help="output path (default: repo-root "
                         "BENCH_agg_round.json; temp file under --smoke)")
    ap.add_argument("--trace", default=None,
                    help="also write the section wall-clock spans as a "
                         "repro.obs JSONL trace (Perfetto-exportable via "
                         "python -m repro.obs.report export)")
    args = ap.parse_args(argv)
    if args.smoke:
        args.dim, args.reps = 2048, 1
    if args.out is None:
        args.out = (os.path.join(tempfile.gettempdir(),
                                 "BENCH_agg_round.smoke.json")
                    if args.smoke
                    else os.path.join(REPO, "BENCH_agg_round.json"))
    d, k = args.dim, args.clients
    q = args.q if args.q is not None else max(1, d // 100)

    from common import provenance
    from repro.obs.timing import PhaseTimer

    passes = {name: {"unfused": vector_passes(name, False),
                     "fused": vector_passes(name, True)}
              for name in ALG_NAMES}
    assert all(p["fused"] < p["unfused"] for p in passes.values())

    timer = PhaseTimer()
    with timer.phase("host_rounds", track="bench"):
        host_rounds = bench_host(k, d, q, args.reps, ["exact", "threshold"])
    with timer.phase("device_rounds", track="bench"):
        device_rounds = bench_device(k, d, q, args.reps)
    with timer.phase("fused_interpret", track="bench"):
        fused_interpret = smoke_fused_interpret(
            k, min(d, 4096), max(1, min(d, 4096) // 100))

    result = {
        "meta": {
            "device_count": jax.device_count(),
            "d": d, "clients": k, "q": q, "reps": args.reps,
            "smoke": bool(args.smoke),
            "repro_pallas_interpret": os.environ.get(
                "REPRO_PALLAS_INTERPRET", ""),
            **provenance(),
        },
        # The structural metric that transfers to TPU: HBM sweeps per node
        # step (memory-bound round ⇒ sweeps bound wall-time). Fused is
        # strictly smaller for every algorithm.
        "vector_passes_per_node": passes,
        "host_rounds_us": host_rounds,
        "device_rounds_us": device_rounds,
        # fused path correctness + interpret-mode smoke (see docstring)
        "fused_interpret_rounds_us": fused_interpret,
    }
    if args.hist or not args.smoke:
        with timer.phase("tau_search", track="bench"):
            result["tau_search"] = bench_tau_search(
                k, d, q, args.reps, args.hist_branch, args.hist_rounds)
    if args.cohorts:
        sizes = sorted({b for b in (1, 4, 8) if b <= args.cohorts}
                       | {args.cohorts})
        with timer.phase("batched_round", track="bench"):
            result["batched_round"] = bench_batched(k, d, args.reps, sizes)
    if args.nested:
        with timer.phase("nested_round", track="bench"):
            result["nested_round"] = bench_nested(2, 4, d, q, args.reps)
    if args.scenario:
        with timer.phase("scenario_round", track="bench"):
            result["scenario_round"] = bench_scenario(args.scenario)
    result["meta"]["phases_s"] = {name: round(secs, 4) for name, secs
                                  in timer.totals().items()}
    if args.trace:
        from repro.obs.collector import TraceCollector
        with TraceCollector(args.trace, meta=dict(result["meta"])) as col:
            timer.emit(col)
    with open(args.out, "w") as f:
        json.dump(result, f, indent=1, sort_keys=True)
        f.write("\n")
    print(f"wrote {args.out}")
    for name in ALG_NAMES:
        h = result["host_rounds_us"][name]["chain"]
        print(f"round,{name},host_chain_exact_us,{h['exact']}")
        print(f"round,{name},host_chain_threshold_us,{h['threshold']}")
        print(f"round,{name},passes_unfused,{passes[name]['unfused']}")
        print(f"round,{name},passes_fused,{passes[name]['fused']}")
    if "tau_search" in result:
        for name in ALG_NAMES:
            row = result["tau_search"]["host"][name]["chain"]
            for vname, _, _, _ in TAU_VARIANTS:
                print(f"tau,{name},host_chain_{vname}_us,{row[vname]}")
    if args.cohorts:
        for regime, rg in result["batched_round"]["regimes"].items():
            for b, entry in rg["cohorts"].items():
                for backend in ("host", "device"):
                    be = entry[backend]
                    if "skipped" in be:
                        continue
                    print(f"batched,{regime},B={b},{backend}_rounds_per_s,"
                          f"{be['rounds_per_s']}")
                    print(f"batched,{regime},B={b},{backend}_speedup_x,"
                          f"{be['speedup_x']}")
    return result


if __name__ == "__main__":
    main()
