"""Trip-count-aware analysis of compiled (post-SPMD, post-fusion) HLO text.

Why: ``compiled.cost_analysis()`` visits each computation once — a
``lax.scan`` over 88 layers reports 1/88th of the real FLOPs/bytes, and the
same applies to collectives (measured in this repo; see EXPERIMENTS §Perf
iteration 0). XLA's ``while`` ops carry ``known_trip_count`` in their
backend_config, and every HLO instruction prints its result type, so an
exact static execution-count analysis is possible from the text alone.

Produces, per executable:
  flops            — dot/convolution FLOPs × execution counts
  hbm_bytes        — Σ (operand + result bytes) of top-level (post-fusion)
                     ops × execution counts ≈ HBM traffic
  collectives      — wire bytes per collective type (ring conventions:
                     AR 2·op, AG result, RS/A2A operand, CP operand)

Known approximations (documented for §Roofline):
  * conditional branches contribute their max-cost branch;
  * dynamic trip counts (none in this repo's models) default to 1;
  * CPU-backend fusion boundaries may differ from TPU's — byte totals are
    an HBM-traffic *model*, flagged as such in EXPERIMENTS.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Optional

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1, "token": 0,
    "opaque": 0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COLLECTIVE_BASES = ("all-reduce", "all-gather", "reduce-scatter",
                     "all-to-all", "collective-permute")
# ops whose result/operands don't represent real HBM traffic
_FREE_OPS = {"parameter", "constant", "get-tuple-element", "tuple", "bitcast",
             "after-all", "partition-id", "replica-id", "iota",
             "get-dimension-size", "copy-start", "copy-done"}


def _parse_shape(s: str):
    """'f32[128,256]' → ('f32', (128, 256))."""
    m = _SHAPE_RE.match(s)
    if not m:
        return None
    dt, dims = m.groups()
    shape = tuple(int(d) for d in dims.split(",") if d)
    return dt, shape


def _shape_bytes(dt: str, shape) -> int:
    n = 1
    for d in shape:
        n *= d
    return n * _DTYPE_BYTES.get(dt, 4)


def _type_bytes(type_str: str) -> int:
    """bytes of a (possibly tuple) HLO type string."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, shape = _parse_shape(m.group(0))
        total += _shape_bytes(dt, shape)
    return total


def _split_top_commas(s: str) -> list[str]:
    out, depth, cur = [], 0, []
    for ch in s:
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            depth -= 1
        if ch == "," and depth == 0:
            out.append("".join(cur).strip())
            cur = []
        else:
            cur.append(ch)
    if cur:
        out.append("".join(cur).strip())
    return out


_ASSIGN_RE = re.compile(r"^(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*)$")
_COMMENT_RE = re.compile(r"/\*[^*]*\*/")


def parse_instr(line: str):
    """'%n = TYPE op(args...), attrs' → (name, type_str, op, rest) | None.

    Handles tuple types with nested parens/braces and /*index=k*/ comments
    (regexes break on those — measured on real while-loop tuples)."""
    m = _ASSIGN_RE.match(line)
    if not m:
        return None
    name, rhs = m.groups()
    rhs = _COMMENT_RE.sub("", rhs)
    i = 0
    if rhs.startswith("("):                   # tuple type: balanced parens
        depth = 0
        for i, ch in enumerate(rhs):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
        type_str, rest = rhs[:i + 1], rhs[i + 1:]
    else:                                      # scalar/array type token
        sp = rhs.find(" ")
        if sp < 0:
            return None
        type_str, rest = rhs[:sp], rhs[sp:]
    rest = rest.lstrip()
    om = re.match(r"([\w\-]+)\(", rest)
    if not om:
        return None
    return name, type_str, om.group(1), rest[om.end():]
_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_TRIP_RE = re.compile(r'known_trip_count[^0-9]*?(\d+)')
_BRANCH_RE = re.compile(r"(?:true_computation|false_computation|"
                        r"branch_computations)=\{?%?([\w.\-,%\s]+)\}?")
_GTE_IDX_RE = re.compile(r"index=(\d+)")
_LHS_CDIMS = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    all_reduce: float = 0.0
    all_gather: float = 0.0
    reduce_scatter: float = 0.0
    all_to_all: float = 0.0
    collective_permute: float = 0.0
    collective_count: float = 0.0

    def __iadd__(self, o: "Cost"):
        for f in dataclasses.fields(self):
            setattr(self, f.name, getattr(self, f.name) + getattr(o, f.name))
        return self

    def scaled(self, k: float) -> "Cost":
        return Cost(**{f.name: getattr(self, f.name) * k
                       for f in dataclasses.fields(self)})

    @property
    def wire_bytes(self) -> float:
        return (self.all_reduce + self.all_gather + self.reduce_scatter
                + self.all_to_all + self.collective_permute)

    def collective_dict(self) -> dict:
        return {"all_reduce": self.all_reduce, "all_gather": self.all_gather,
                "reduce_scatter": self.reduce_scatter,
                "all_to_all": self.all_to_all,
                "collective_permute": self.collective_permute,
                "total": self.wire_bytes, "count": self.collective_count}


class HloModule:
    """Parsed computations: name → list of (name, type_str, op, rest)."""

    def __init__(self, hlo_text: str):
        self.comps: dict[str, list] = {}
        self.entry: Optional[str] = None
        self._parse(hlo_text)
        self._cache: dict[tuple[str, bool], Cost] = {}

    def _parse(self, text: str):
        cur_name, cur = None, []
        for raw in text.splitlines():
            line = raw.strip()
            if cur_name is None:
                if line.endswith("{") and ("->" in line or line.startswith(
                        "ENTRY")):
                    m = re.match(r"(?:ENTRY\s+)?%?([\w.\-]+)\s*[(\s]", line)
                    if m:
                        cur_name = m.group(1)
                        cur = []
                        if raw.startswith("ENTRY"):
                            self.entry = cur_name
                continue
            if line == "}":
                self.comps[cur_name] = cur
                cur_name = None
                continue
            got = parse_instr(line)
            if got:
                cur.append(got)

    # ------------------------------------------------------------------
    def _dot_flops(self, instrs_types: dict, type_str: str, rest: str
                   ) -> float:
        res = _parse_shape(re.sub(r"\{[^}]*\}", "", type_str))
        if res is None:
            return 0.0
        _, rshape = res
        out_elems = 1
        for d in rshape:
            out_elems *= d
        # contracted size from the lhs operand's shape
        cd = _LHS_CDIMS.search(rest)
        args = _split_top_commas(rest.split("),", 1)[0].rstrip(")"))
        lhs = args[0].lstrip("%").split(" ")[-1].lstrip("%") if args else ""
        lhs_t = instrs_types.get(lhs)
        contracted = 1
        if cd and lhs_t:
            p = _parse_shape(re.sub(r"\{[^}]*\}", "", lhs_t))
            if p:
                _, lshape = p
                for idx in cd.group(1).split(","):
                    if idx and int(idx) < len(lshape):
                        contracted *= lshape[int(idx)]
        return 2.0 * out_elems * contracted

    def _matmul_cc_flops(self, instrs_types: dict, type_str: str,
                         rest: str) -> float:
        res = _parse_shape(re.sub(r"\{[^}]*\}", "", type_str))
        if res is None:
            return 0.0
        _, rshape = res
        out_elems = 1
        for d in rshape:
            out_elems *= d
        args = _split_top_commas(rest.split("),", 1)[0].rstrip(")"))
        lhs = args[0].split(" ")[-1].lstrip("%") if args else ""
        lhs_t = instrs_types.get(lhs)
        contracted = 1
        if lhs_t:
            p = _parse_shape(re.sub(r"\{[^}]*\}", "", lhs_t))
            if p and p[1]:
                contracted = p[1][-1]
        return 2.0 * out_elems * contracted

    def comp_cost(self, name: str, *, top_level: bool = True,
                  _stack: frozenset = frozenset()) -> Cost:
        key = (name, top_level)
        if key in self._cache:
            return self._cache[key]
        if name in _stack or name not in self.comps:
            return Cost()
        acc = Cost()
        instrs = self.comps[name]
        types = {n: t for n, t, _, _ in instrs}
        for n, type_str, op, rest in instrs:
            base = op.removesuffix("-start")
            # -- control flow ------------------------------------------------
            if op == "while":
                body = _BODY_RE.search(rest)
                trip_m = _TRIP_RE.search(rest)
                trip = int(trip_m.group(1)) if trip_m else 1
                if body:
                    sub = self.comp_cost(body.group(1), top_level=top_level,
                                         _stack=_stack | {name})
                    acc += sub.scaled(trip)
                continue
            if op == "fusion":
                calls = _CALLS_RE.search(rest)
                if calls:
                    sub = self.comp_cost(calls.group(1), top_level=False,
                                         _stack=_stack | {name})
                    acc.flops += sub.flops      # dots inside fusions count
                    # fused internals don't touch HBM; the fusion op does:
                    acc += sub.scaled(0).scaled(0)  # no-op, clarity
                if top_level:
                    acc.hbm_bytes += self._op_bytes(types, type_str, rest)
                continue
            if op in ("call", "custom-call", "conditional"):
                # CPU backend lowers large dots to oneDNN matmul
                # custom-calls — count them as dots (contracted dim = lhs
                # last dim, the [.., m, k] × [.., k, n] convention).
                if op == "custom-call" and re.search(
                        r'custom_call_target="[^"]*(matmul|gemm|dot|conv)',
                        rest):
                    acc.flops += self._matmul_cc_flops(types, type_str, rest)
                for cm in _CALLS_RE.finditer(rest):
                    sub = self.comp_cost(cm.group(1), top_level=top_level,
                                         _stack=_stack | {name})
                    acc += sub
                if op == "custom-call" and top_level:
                    acc.hbm_bytes += self._op_bytes(types, type_str, rest)
                continue
            # -- collectives -------------------------------------------------
            if base in _COLLECTIVE_BASES and not op.endswith("-done"):
                operands = self._operand_bytes(types, rest)
                result = _type_bytes(type_str)
                if base == "all-reduce":
                    acc.all_reduce += 2 * operands
                elif base == "all-gather":
                    acc.all_gather += result
                elif base == "reduce-scatter":
                    acc.reduce_scatter += operands
                elif base == "all-to-all":
                    acc.all_to_all += operands
                else:
                    acc.collective_permute += operands
                acc.collective_count += 1
                if top_level:
                    acc.hbm_bytes += operands + result
                continue
            # -- compute -----------------------------------------------------
            if op in ("dot", "convolution"):
                acc.flops += self._dot_flops(types, type_str, rest)
                if top_level:
                    acc.hbm_bytes += self._op_bytes(types, type_str, rest)
                continue
            if top_level and op not in _FREE_OPS:
                acc.hbm_bytes += self._op_bytes(types, type_str, rest)
        self._cache[key] = acc
        return acc

    def _operand_bytes_list(self, types: dict, rest: str) -> list:
        args = _split_top_commas(rest.split("),", 1)[0].rstrip(")"))
        out = []
        for a in args:
            nm = a.split(" ")[-1].lstrip("%")
            t = types.get(nm)
            if t:
                out.append(_type_bytes(t))
            else:
                p = _SHAPE_RE.search(a)
                if p:
                    out.append(_type_bytes(p.group(0)))
        return out

    def _operand_bytes(self, types: dict, rest: str) -> int:
        return sum(self._operand_bytes_list(types, rest))

    def _op_bytes(self, types: dict, type_str: str, rest: str) -> int:
        """HBM-traffic model for one top-level op.

        Slice/accumulate heuristics (scan-over-layers reality): a fusion
        reading a whole stacked [L, …] buffer but producing one layer's
        slice touches ~result bytes, not L× that; a dynamic-update writing
        one slice into the stacked buffer (detectable: one operand with
        size == result size) touches ~the update's bytes. Without these
        caps an 88-layer scan miscounts by ~88× (measured, granite-34b).
        """
        res = _type_bytes(type_str)
        ops = self._operand_bytes_list(types, rest)
        aliased = [b for b in ops if b == res and res > 0]
        if aliased and res > 4 * max(
                [b for b in ops if b != res] + [1]):
            small = sum(min(b, res) for b in ops if b != res)
            return 3 * max(small, 1)          # read+write slice + operands
        return res + sum(min(b, 4 * res) if res > 0 else b for b in ops)

    def total(self) -> Cost:
        if self.entry is None:
            return Cost()
        return self.comp_cost(self.entry)


def analyze(hlo_text: str) -> Cost:
    return HloModule(hlo_text).total()
