"""Chrome trace-event export — open a whole simulation in Perfetto.

Converts a ``repro.obs`` JSONL trace into the Chrome trace-event JSON
format (https://ui.perfetto.dev loads it directly, as does
``chrome://tracing``):

* every **round** record becomes a block of complete ("X") events on the
  simulated time axis — one process per stage, one thread per client/unit,
  one event per hop (duration = the hop's simulated transmit time from
  the record's ``t0_s``/``t1_s``, args = its §V accounting), with rounds
  laid head-to-tail separated by a small gap so the per-level wavefront
  structure of the ``(L, W)`` schedule is visible;
* every **span** record becomes an "X" event on a host wall-clock process
  (one thread per ``track`` name) — the benchmark/simulator phase hooks;
* ``track="scenario"`` spans are special: their ``t0_s``/``dur_s`` are
  *round* coordinates (the scenario engine's injected-fault windows), so
  they render on the simulated axis as an "injected faults" process whose
  events stretch across the rounds they cover — crash/flap/degradation
  windows line up under the hop wavefronts they perturb.

Units: the simulated axis is scaled so 1 second → 1 ms of trace time when
a link model was recorded (critical paths are tens of ms), and 1 unit hop
→ 1 ms otherwise; host spans are real microseconds. The two axes live in
separate processes, so the scaling never mixes.
"""

from __future__ import annotations

import json
from typing import Iterable, Optional

from repro.obs.record import iter_trace

#: pid of the host wall-clock process; stage s uses pid = s + 1.
HOST_PID = 0

#: pid of the injected-fault process (scenario event windows, simulated
#: axis). Large so it sorts after any realistic stage count.
FAULT_PID = 99

#: simulated seconds → trace µs (1 s → 1 ms of trace time)
SIM_SCALE_US = 1e3


def _thread_meta(pid: int, tid: int, name: str) -> dict:
    return {"ph": "M", "name": "thread_name", "pid": pid, "tid": tid,
            "args": {"name": name}}


def _process_meta(pid: int, name: str) -> dict:
    return {"ph": "M", "name": "process_name", "pid": pid,
            "args": {"name": name}}


def chrome_events(records: Iterable[dict], *, gap_frac: float = 0.1) -> list:
    """Trace records → list of Chrome trace events (see module doc)."""
    events: list = []
    procs: dict = {}
    threads: dict = {}
    tracks: dict = {}

    def ensure_proc(pid: int, name: str):
        if pid not in procs:
            procs[pid] = True
            events.append(_process_meta(pid, name))

    def ensure_thread(pid: int, tid: int, name: str):
        if (pid, tid) not in threads:
            threads[(pid, tid)] = True
            events.append(_thread_meta(pid, tid, name))

    cursor = 0.0          # simulated-axis cursor (seconds/units)
    scenario_spans: list = []
    round_windows: dict = {}     # round → (sim start, sim end)
    for rec in records:
        kind = rec.get("kind")
        if kind == "span":
            track = rec.get("track", "host")
            if track == "scenario":
                # round-coordinate windows; rendered on the simulated axis
                # once the rounds they span have been laid out
                scenario_spans.append(rec)
                continue
            tid = tracks.setdefault(track, len(tracks))
            ensure_proc(HOST_PID, "host wall-clock")
            ensure_thread(HOST_PID, tid, track)
            ev = {"ph": "X", "name": rec["name"], "pid": HOST_PID,
                  "tid": tid, "ts": rec["t0_s"] * 1e6,
                  "dur": max(rec["dur_s"] * 1e6, 0.01), "cat": "span"}
            if rec.get("args"):
                ev["args"] = rec["args"]
            events.append(ev)
        elif kind == "round":
            rnd = rec.get("round", 0)
            t_end = cursor
            for s, st in enumerate(rec.get("stages", [])):
                t0s, t1s = st.get("t0_s"), st.get("t1_s")
                if t0s is None or t1s is None:
                    continue
                pid = s + 1
                ensure_proc(pid, f"aggregation stage {s}")
                pst = (rec.get("plan", {}).get("stages", [{}] * (s + 1)))[s]
                levels = pst.get("level", [0] * len(t0s))
                for i, (a, b) in enumerate(zip(t0s, t1s)):
                    if b <= a:
                        continue          # skipped hop (stub / zero bw)
                    ensure_thread(pid, i,
                                  f"{'client' if s == 0 else 'unit'} {i}")
                    ev_args = {"round": rnd, "bits": st["bits"][i],
                               "nnz": st["nnz"][i],
                               "err_sq": st["err_sq"][i]}
                    if "cohort" in rec:        # multi-tenant batched round
                        ev_args["cohort"] = rec["cohort"]
                    events.append({
                        "ph": "X", "cat": "hop",
                        "name": f"r{rnd} L{levels[i]} hop {i}",
                        "pid": pid, "tid": i,
                        "ts": (cursor + a) * SIM_SCALE_US,
                        "dur": max((b - a) * SIM_SCALE_US, 0.01),
                        "args": ev_args,
                    })
                    t_end = max(t_end, cursor + b)
            # round boundary marker (instant event on stage 0)
            ensure_proc(1, "aggregation stage 0")
            events.append({"ph": "i", "s": "p", "name": f"round {rnd}",
                           "pid": 1, "tid": 0,
                           "ts": cursor * SIM_SCALE_US,
                           "args": {"round": rnd,
                                    "bits": rec.get("totals", {}).get(
                                        "bits"),
                                    "retraces": rec.get("retraces")}})
            dur = max(t_end - cursor, 1e-9)
            round_windows[rnd] = (cursor, t_end if t_end > cursor
                                  else cursor + dur)
            cursor = t_end + gap_frac * dur

    if scenario_spans and round_windows:
        ensure_proc(FAULT_PID, "injected faults")
        kinds: dict = {}
        last_round = max(round_windows)
        for rec in scenario_spans:
            r0 = int(rec["t0_s"])
            r1 = min(r0 + max(int(rec["dur_s"]), 1) - 1, last_round)
            covered = [round_windows[r] for r in range(r0, r1 + 1)
                       if r in round_windows]
            if not covered:
                continue
            t_start = covered[0][0]
            t_stop = max(b for _, b in covered)
            fkind = (rec.get("args") or {}).get("kind", "event")
            tid = kinds.setdefault(fkind, len(kinds))
            ensure_thread(FAULT_PID, tid, fkind)
            events.append({
                "ph": "X", "cat": "fault", "name": rec["name"],
                "pid": FAULT_PID, "tid": tid,
                "ts": t_start * SIM_SCALE_US,
                "dur": max((t_stop - t_start) * SIM_SCALE_US, 0.01),
                "args": {**(rec.get("args") or {}),
                         "round": r0, "rounds": int(rec["dur_s"])},
            })
    return events


def export_chrome_trace(trace_path: str, out_path: Optional[str] = None,
                        *, gap_frac: float = 0.1) -> str:
    """Convert a JSONL trace file to a Chrome trace JSON file.

    Returns the output path (default: ``<trace>.chrome.json``). Open it at
    https://ui.perfetto.dev (or ``chrome://tracing``).
    """
    if out_path is None:
        base = trace_path[:-6] if trace_path.endswith(".jsonl") \
            else trace_path
        out_path = base + ".chrome.json"
    events = chrome_events(iter_trace(trace_path), gap_frac=gap_frac)
    with open(out_path, "w") as f:
        json.dump({"traceEvents": events, "displayTimeUnit": "ms"}, f)
        f.write("\n")
    return out_path
