"""Queryable round telemetry (``repro.obs``).

Every execution path of the repo — host ``execute``/``execute_nested``,
the device shard_map lowerings, the federated :class:`~repro.fed.simulator.
Simulator`, the train step, the benchmarks — already computes rich per-hop
accounting (:class:`~repro.core.algorithms.HopStats`). This package turns
those traced arrays into a structured, queryable trace *without touching
jitted math*: nothing inside jit changes; a host-side
:class:`TraceCollector` consumes the round outputs after each round and
emits versioned :data:`~repro.obs.record.SCHEMA` records to a JSONL file.

* :mod:`repro.obs.record` — the trace schema (round/span/meta records),
  plan introspection (forest reconstruction, levels, subtree sizes), the
  simulated per-hop timeline and its validation helpers;
* :mod:`repro.obs.collector` — :class:`TraceCollector` (JSONL emitter),
  :class:`RoundBuffer` (device→host sync batching) and
  :class:`TraceCounter` (jit retrace accounting);
* :mod:`repro.obs.timing` — :class:`PhaseTimer` wall-clock phase hooks
  (benchmarks, simulator round phases);
* :mod:`repro.obs.chrome` — Chrome trace-event export (open in Perfetto);
* :mod:`repro.obs.report` — ``python -m repro.obs.report`` CLI
  (``summary`` / ``diff`` / ``validate``);
* :mod:`repro.obs.smoke` — the CI smoke driver (host + device backends,
  flat + nested topologies).
"""

from repro.obs.chrome import chrome_events, export_chrome_trace
from repro.obs.collector import RoundBuffer, TraceCollector, TraceCounter
from repro.obs.record import (SCHEMA, hop_timeline, iter_trace, plan_meta,
                              subtree_sizes_from_parent, validate_record,
                              validate_trace)
from repro.obs.timing import PhaseTimer

__all__ = [
    "SCHEMA", "TraceCollector", "RoundBuffer", "TraceCounter", "PhaseTimer",
    "plan_meta", "hop_timeline", "subtree_sizes_from_parent", "iter_trace",
    "validate_record", "validate_trace", "chrome_events",
    "export_chrome_trace",
]
