"""Telemetry smoke driver — ``python -m repro.obs.smoke --out DIR``.

Runs short :class:`~repro.fed.simulator.Simulator` experiments across the
execution paths the trace subsystem must cover — flat chain, routed
constellation tree (link model → critical path), nested two-stage plan,
and (with ``--device``) the device-backend lowering of flat and nested —
writing one JSONL trace + Chrome export per scenario, then validates
every trace and cross-checks its totals against the per-hop stats. CI
runs this (host and 8-fake-device) and uploads the directory as an
artifact, so every green build carries an openable Perfetto trace.
"""

from __future__ import annotations

import argparse
import dataclasses
import os
import sys


def _sims(pc, fed, device: bool):
    """→ [(name, Simulator)] covering the execution paths."""
    import repro.topo.graph as tg
    from repro.core.algorithms import AggConfig, AggKind
    from repro.fed.simulator import Simulator
    from repro.fed.topology import TreeTopology
    from repro.topo.routing import cluster_routed

    cfg = AggConfig(kind=AggKind.CL_SIA, q=pc.q)
    k = pc.num_clients
    tree = TreeTopology(tg.walker_delta(2, k // 2, gateways=(1, k // 2)),
                        routing="widest")
    nested = cluster_routed(tg.grid_graph(2, k // 2), 2)
    out = [
        ("host_chain", Simulator(pc, cfg, fed, local_lr=pc.lr)),
        ("host_tree", Simulator(pc, cfg, fed, local_lr=pc.lr,
                                tree_topology=tree)),
        ("host_nested", Simulator(pc, cfg, fed, local_lr=pc.lr,
                                  nested_topology=nested)),
    ]
    if device:
        out += [
            ("device_chain", Simulator(pc, cfg, fed, local_lr=pc.lr,
                                       backend="device")),
            ("device_nested", Simulator(pc, cfg, fed, local_lr=pc.lr,
                                        nested_topology=nested,
                                        backend="device")),
        ]
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.obs.smoke",
                                 description=__doc__.split("\n")[0])
    ap.add_argument("--out", default="traces", help="output directory")
    ap.add_argument("--rounds", type=int, default=10)
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--device", action="store_true",
                    help="also run backend='device' scenarios (needs "
                         "jax.device_count() >= --clients)")
    args = ap.parse_args(argv)

    import jax
    if args.device and jax.device_count() < args.clients:
        print(f"--device needs {args.clients} devices, have "
              f"{jax.device_count()} (set XLA_FLAGS="
              f"--xla_force_host_platform_device_count={args.clients})")
        return 2

    from repro.configs import PAPER
    from repro.data.federated import partition_iid
    from repro.data.synthetic import make_synthetic_mnist
    from repro.obs import (TraceCollector, export_chrome_trace, iter_trace,
                           validate_trace)
    from repro.obs.report import print_summary, summarize

    pc = dataclasses.replace(PAPER, num_clients=args.clients)
    train = make_synthetic_mnist(jax.random.PRNGKey(0), args.clients * 40)
    fed = partition_iid(jax.random.PRNGKey(2), train, args.clients)
    os.makedirs(args.out, exist_ok=True)

    failed = False
    for name, sim in _sims(pc, fed, args.device):
        path = os.path.join(args.out, f"{name}.jsonl")
        with TraceCollector(path, meta={"scenario": name}) as col:
            out = sim.run(args.rounds, collector=col, flush_every=4)
        res = validate_trace(path)
        errs = list(res.pop("errors"))
        # the returned curves must reduce from the recorded per-hop stats
        rounds = [r for r in iter_trace(path) if r["kind"] == "round"]
        for r, rec in enumerate(rounds):
            if abs(rec["totals"]["bits"] - out["bits"][r]) > 0.5:
                errs.append(f"round {r}: trace bits "
                            f"{rec['totals']['bits']} != curve "
                            f"{out['bits'][r]}")
        if sim.trace_counter.count != 1:
            errs.append(f"{sim.trace_counter.count} jit specializations "
                        f"(want 1)")
        chrome = export_chrome_trace(path)
        status = "OK" if not errs else "FAIL"
        print(f"[{status}] {name}: {res} → {path}, {chrome}")
        for e in errs[:10]:
            print(f"    {e}")
        failed = failed or bool(errs)
        print_summary(summarize(path))
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
