"""``python -m repro.obs.report`` — summarize, diff, validate traces.

Subcommands:

* ``summary TRACE``  — round counts, §V bit totals (global/local split),
  closed-form cross-check against :mod:`repro.core.comm_cost` (CL-SIA
  exact, the Prop-2 ceiling for the stochastic algorithms — subtree sizes
  come from the recorded forest, no topology object needed), critical-path
  histogram, EF-mass growth, retrace events, phase wall-clock totals;
* ``diff A B``       — per-round bits/loss/crit-path deltas between two
  traces (e.g. host vs device backend, or before/after a change);
* ``validate TRACE [TRACE ...]`` — schema validation (CI gate; exit 1 on
  any error);
* ``export TRACE``   — Chrome trace-event conversion
  (:func:`repro.obs.chrome.export_chrome_trace`).
"""

from __future__ import annotations

import argparse
import json
import sys
from typing import Optional

import numpy as np

from repro.obs.record import (iter_trace, subtree_sizes_from_parent,
                              validate_trace)


def load_trace(path: str) -> tuple:
    """→ (meta record | None, [round records], [span records])."""
    meta, rounds, spans = None, [], []
    for rec in iter_trace(path):
        kind = rec.get("kind")
        if kind == "meta" and meta is None:
            meta = rec
        elif kind == "round":
            rounds.append(rec)
        elif kind == "span":
            spans.append(rec)
    return meta, rounds, spans


# ---------------------------------------------------------------------------
# Closed-form cross-check
# ---------------------------------------------------------------------------

def closed_form_check(meta: Optional[dict], rounds: list) -> Optional[dict]:
    """Measured §V bits vs the :mod:`repro.core.comm_cost` closed forms.

    CL-SIA / CL-TC-SIA carry exactly Q (resp. Q_G + Q_L) per hop on any
    tree → equality is expected on full-participation rounds with dense
    inputs; SIA / RE-SIA / TC-SIA are bounded by the tree Prop-2 form with
    the recorded per-stage subtree sizes. Returns None when the trace
    lacks the needed metadata (no cfg, or no recorded plan).
    """
    from repro.core import comm_cost as cc

    if not meta or not meta.get("cfg") or not rounds:
        return None
    cfg, d = meta["cfg"], meta.get("d")
    if d is None or not cfg.get("kind"):
        return None
    kind, omega = cfg["kind"], cfg.get("omega", 32)
    q, qg, ql = cfg.get("q", 0), cfg.get("q_global", 0), cfg.get("q_local", 0)
    exact = kind in ("cl_sia", "cl_tc_sia")
    checked, matches, bounded = 0, 0, 0
    worst = 0.0
    for rec in rounds:
        plan = rec.get("plan")
        if plan is None:
            continue
        part = rec.get("participation")
        full = part is None or all(p > 0 for p in part)
        measured = rec["totals"]["bits"]
        expected = 0.0
        for st in plan["stages"]:
            k_alive = int(round(sum(st.get("alive", [1] * len(st["parent"])))))
            sizes = subtree_sizes_from_parent(st["parent"])
            if kind == "cl_sia":
                expected += cc.cl_sia_bits_tree(k_alive, d, q, omega)
            elif kind == "cl_tc_sia":
                expected += cc.cl_tc_sia_bits_tree(k_alive, d, qg, ql, omega)
            elif kind == "tc_sia":
                expected += cc.tc_sia_bits_bound_tree(sizes, d, qg, ql,
                                                      omega)
            elif kind in ("sia", "re_sia"):
                expected += cc.tc_sia_bits_bound_tree(sizes, d, 0, q, omega)
            elif kind == "dense_ia":
                expected += cc.dense_ia_bits_tree(k_alive, d, omega)
            else:
                return None
        checked += 1
        if exact or kind == "dense_ia":
            if full and abs(measured - expected) < 0.5:
                matches += 1
            worst = max(worst, abs(measured - expected))
        else:
            # Prop-2 bounds the EXPECTED λ-nnz; rounds fluctuate around
            # it, so count as bounded within 2%
            if measured <= 1.02 * expected:
                bounded += 1
            worst = max(worst, measured - expected)
    if not checked:
        return None
    return {"kind": kind, "mode": "exact" if exact or kind == "dense_ia"
            else "ceiling", "rounds_checked": checked, "matches": matches,
            "bounded": bounded, "worst_abs_gap_bits": worst}


# ---------------------------------------------------------------------------
# Summaries
# ---------------------------------------------------------------------------

def _hist(values: list, bins: int = 8, width: int = 40) -> list:
    """ASCII histogram lines."""
    if not values:
        return []
    vals = np.asarray(values, np.float64)
    lo, hi = float(vals.min()), float(vals.max())
    if hi <= lo:
        return [f"  [{lo:.4g}] {'#' * width}  ({len(values)} rounds)"]
    counts, edges = np.histogram(vals, bins=bins)
    peak = max(1, int(counts.max()))
    return [f"  [{edges[i]:.4g}, {edges[i + 1]:.4g}) "
            f"{'#' * max(1, int(width * c / peak)) if c else ''}  {c}"
            for i, c in enumerate(counts)]


def summarize(path: str, *, cohort=None) -> dict:
    """Build the summary dict (the ``summary`` subcommand prints it).

    ``cohort`` restricts the round records to one tenant of a batched
    multi-tenant trace (records tagged ``cohort`` by
    :meth:`~repro.obs.collector.TraceCollector.record_round`).
    """
    meta, rounds, spans = load_trace(path)
    cohorts = sorted({r["cohort"] for r in rounds if "cohort" in r},
                     key=str)
    if cohort is not None:
        rounds = [r for r in rounds
                  if str(r.get("cohort")) == str(cohort)]
    out: dict = {"trace": path, "rounds": len(rounds), "spans": len(spans)}
    if cohorts:
        out["cohorts"] = cohorts
    if cohort is not None:
        out["cohort"] = cohort
    if meta:
        out["cfg"] = meta.get("cfg", {})
        out["d"] = meta.get("d")
        out["num_clients"] = meta.get("num_clients")
        out["context"] = {k: v for k, v in meta.items()
                          if k not in ("schema", "kind", "cfg", "d",
                                       "num_clients", "ts_unix",
                                       "scenario_spec")}
        if meta.get("scenario_spec") is not None:
            out["scenario_spec"] = meta["scenario_spec"]
    if rounds:
        bits = [r["totals"]["bits"] for r in rounds]
        out["bits"] = {"total": float(sum(bits)),
                       "mean_per_round": float(np.mean(bits)),
                       "min": float(min(bits)), "max": float(max(bits))}
        if "bits_global" in rounds[0]["totals"]:
            out["bits"]["global"] = float(
                sum(r["totals"]["bits_global"] for r in rounds))
            out["bits"]["local"] = float(
                sum(r["totals"]["bits_local"] for r in rounds))
        crit = [r["crit_path_s"] for r in rounds
                if r.get("crit_path_s") is not None]
        if crit:
            out["crit_path_s"] = {"min": min(crit), "max": max(crit),
                                  "mean": float(np.mean(crit)),
                                  "values": crit}
        ef = [float(sum(r["stages"][0].get("ef_mass", [0.0])))
              for r in rounds if r["stages"]]
        if any(ef):
            out["ef_mass"] = {"first": ef[0], "last": ef[-1],
                              "peak": max(ef)}
        dead = [r.get("ef_dead_mass") for r in rounds
                if r.get("ef_dead_mass") is not None]
        if dead:
            out["ef_dead_mass"] = {"peak": max(dead), "last": dead[-1],
                                   "rounds_nonzero": sum(1 for v in dead
                                                         if v > 0)}
        retr = [r.get("retraces") for r in rounds
                if r.get("retraces") is not None]
        if retr:
            events = [rounds[i]["round"] for i in range(len(retr))
                      if retr[i] > (retr[i - 1] if i else 0)]
            out["retraces"] = {"total": retr[-1], "events_at_rounds": events}
        losses = [r["loss"] for r in rounds if r.get("loss") is not None]
        if losses:
            out["loss"] = {"first": losses[0], "last": losses[-1]}
        phases: dict = {}
        for r in rounds:
            for name, secs in (r.get("phases") or {}).items():
                phases[name] = phases.get(name, 0.0) + secs
        for sp in spans:
            if sp.get("track") == "scenario":
                continue     # round-coordinate fault windows, not seconds
            phases[sp["name"]] = phases.get(sp["name"], 0.0) + sp["dur_s"]
        if phases:
            out["phases_s"] = phases
        check = closed_form_check(meta, rounds)
        if check:
            out["closed_form"] = check
    injected = [{"name": sp["name"],
                 "kind": (sp.get("args") or {}).get("kind", "event"),
                 "round": int(sp["t0_s"]), "rounds": int(sp["dur_s"])}
                for sp in spans if sp.get("track") == "scenario"]
    if injected:
        out["injected"] = injected
    return out


def print_summary(out: dict) -> None:
    print(f"trace: {out['trace']}")
    if out.get("cohorts"):
        sel = (f" (showing cohort {out['cohort']})"
               if out.get("cohort") is not None else "")
        print(f"cohorts: {', '.join(str(c) for c in out['cohorts'])}{sel}")
    cfg = out.get("cfg") or {}
    if cfg:
        print(f"  algorithm {cfg.get('kind')}  K={out.get('num_clients')}"
              f"  d={out.get('d')}  q={cfg.get('q')}"
              f"  (Q_G={cfg.get('q_global')}, Q_L={cfg.get('q_local')})"
              f"  ω={cfg.get('omega')}")
    ctx = out.get("context") or {}
    if ctx:
        print("  context " + " ".join(f"{k}={v}" for k, v in ctx.items()))
    print(f"  rounds={out['rounds']}  spans={out['spans']}")
    bits = out.get("bits")
    if bits:
        line = (f"  bits: total={bits['total']:.6g}"
                f"  mean/round={bits['mean_per_round']:.6g}")
        if "global" in bits:
            line += (f"  split global={bits['global']:.6g}"
                     f" local={bits['local']:.6g}")
        print(line)
    check = out.get("closed_form")
    if check:
        if check["mode"] == "exact":
            print(f"  closed form ({check['kind']}, exact): "
                  f"{check['matches']}/{check['rounds_checked']} rounds "
                  f"bit-identical (worst gap "
                  f"{check['worst_abs_gap_bits']:.3g} bits)")
        else:
            print(f"  closed form ({check['kind']}, Prop-2 ceiling): "
                  f"{check['bounded']}/{check['rounds_checked']} rounds "
                  f"under the bound (worst overshoot "
                  f"{max(0.0, check['worst_abs_gap_bits']):.3g} bits)")
    crit = out.get("crit_path_s")
    if crit:
        print(f"  crit path s: min={crit['min']:.4g} "
              f"mean={crit['mean']:.4g} max={crit['max']:.4g}")
        for line in _hist(crit["values"]):
            print(line)
    ef = out.get("ef_mass")
    if ef:
        print(f"  EF mass ‖e‖₁: first={ef['first']:.6g} "
              f"last={ef['last']:.6g} peak={ef['peak']:.6g}")
    dead = out.get("ef_dead_mass")
    if dead:
        print(f"  banked EF of dead clients: peak={dead['peak']:.6g} "
              f"last={dead['last']:.6g} "
              f"({dead['rounds_nonzero']} rounds nonzero)")
    retr = out.get("retraces")
    if retr:
        print(f"  jit traces: {retr['total']} "
              f"(events at rounds {retr['events_at_rounds']})")
    injected = out.get("injected")
    if injected:
        print(f"  injected events: {len(injected)}")
        for ev in injected:
            span = (f"round {ev['round']}" if ev["rounds"] <= 1 else
                    f"rounds {ev['round']}–{ev['round'] + ev['rounds'] - 1}")
            print(f"    [{ev['kind']}] {ev['name']} ({span})")
    loss = out.get("loss")
    if loss:
        print(f"  loss: {loss['first']:.6g} → {loss['last']:.6g}")
    phases = out.get("phases_s")
    if phases:
        print("  phases (s): " + "  ".join(
            f"{k}={v:.4g}" for k, v in sorted(phases.items())))


def diff(path_a: str, path_b: str, *, limit: int = 10) -> dict:
    """Per-round deltas between two traces (keyed by round number)."""
    _, rounds_a, _ = load_trace(path_a)
    _, rounds_b, _ = load_trace(path_b)
    by_a = {r["round"]: r for r in rounds_a}
    by_b = {r["round"]: r for r in rounds_b}
    common = sorted(set(by_a) & set(by_b))
    deltas = []
    for r in common:
        a, b = by_a[r], by_b[r]
        entry = {"round": r,
                 "bits": b["totals"]["bits"] - a["totals"]["bits"]}
        if a.get("loss") is not None and b.get("loss") is not None:
            entry["loss"] = b["loss"] - a["loss"]
        if (a.get("crit_path_s") is not None
                and b.get("crit_path_s") is not None):
            entry["crit_path_s"] = b["crit_path_s"] - a["crit_path_s"]
        deltas.append(entry)
    out = {"a": path_a, "b": path_b,
           "rounds_a": len(rounds_a), "rounds_b": len(rounds_b),
           "common": len(common),
           "only_a": sorted(set(by_a) - set(by_b)),
           "only_b": sorted(set(by_b) - set(by_a)),
           "bits_total_delta": float(sum(d["bits"] for d in deltas)),
           "rounds_bits_differ": [d["round"] for d in deltas
                                  if abs(d["bits"]) > 0.5][:limit],
           "deltas": deltas}
    return out


def print_diff(out: dict, *, limit: int = 10) -> None:
    print(f"diff: {out['a']}  vs  {out['b']}")
    print(f"  rounds: {out['rounds_a']} vs {out['rounds_b']} "
          f"({out['common']} common"
          + (f", only-a {out['only_a']}" if out["only_a"] else "")
          + (f", only-b {out['only_b']}" if out["only_b"] else "") + ")")
    print(f"  Σ bits delta (b − a): {out['bits_total_delta']:.6g}")
    differing = out["rounds_bits_differ"]
    if differing:
        print(f"  bits differ at rounds {differing}")
    else:
        print("  per-round bits identical")
    shown = 0
    for d in out["deltas"]:
        if shown >= limit:
            break
        extras = "  ".join(f"Δ{k}={v:+.6g}" for k, v in d.items()
                           if k != "round")
        print(f"    round {d['round']}: {extras}")
        shown += 1


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.report",
        description=__doc__.split("\n")[0])
    sub = ap.add_subparsers(dest="cmd", required=True)
    p_sum = sub.add_parser("summary", help="summarize one trace")
    p_sum.add_argument("trace")
    p_sum.add_argument("--json", action="store_true",
                       help="machine-readable output")
    p_sum.add_argument("--cohort", default=None,
                       help="restrict to one tenant of a batched trace")
    p_diff = sub.add_parser("diff", help="per-round deltas of two traces")
    p_diff.add_argument("trace_a")
    p_diff.add_argument("trace_b")
    p_diff.add_argument("--json", action="store_true")
    p_diff.add_argument("--limit", type=int, default=10)
    p_val = sub.add_parser("validate", help="schema-validate traces")
    p_val.add_argument("traces", nargs="+")
    p_exp = sub.add_parser("export", help="Chrome trace-event export")
    p_exp.add_argument("trace")
    p_exp.add_argument("--out", default=None)
    args = ap.parse_args(argv)

    if args.cmd == "summary":
        out = summarize(args.trace, cohort=args.cohort)
        if args.json:
            print(json.dumps(out, indent=1, sort_keys=True))
        else:
            print_summary(out)
        return 0
    if args.cmd == "diff":
        out = diff(args.trace_a, args.trace_b, limit=args.limit)
        if args.json:
            out = dict(out)
            out.pop("deltas")
            print(json.dumps(out, indent=1, sort_keys=True))
        else:
            print_diff(out, limit=args.limit)
        return 0
    if args.cmd == "validate":
        failed = False
        for path in args.traces:
            res = validate_trace(path)
            errs = res.pop("errors")
            status = "OK" if not errs else f"{len(errs)} ERRORS"
            print(f"{path}: {status}  "
                  + " ".join(f"{k}={v}" for k, v in res.items()))
            for e in errs[:20]:
                print(f"  {e}")
            failed = failed or bool(errs)
        return 1 if failed else 0
    if args.cmd == "export":
        from repro.obs.chrome import export_chrome_trace
        out_path = export_chrome_trace(args.trace, args.out)
        print(f"wrote {out_path}")
        return 0
    return 2


if __name__ == "__main__":
    sys.exit(main())
