"""Host-side trace collection: JSONL emitter + sync batching + retraces.

:class:`TraceCollector` is the single write path for round telemetry. It
is deliberately dumb about execution: callers hand it the *outputs* of a
round (HopStats pytrees, EF-mass vectors, a plan for structure) and it
serializes versioned records (:mod:`repro.obs.record`). It never touches
anything inside jit, so attaching a collector cannot add a jit
specialization (tested), and a disabled collector is a no-op returning
immediately from every method.

:class:`RoundBuffer` is the device→host sync discipline: per-round device
pytrees are appended without fetching (the dispatched round stays async on
the accelerator) and materialized with **one** ``jax.device_get`` per
flush — the simulator's history loop uses it so a device-backend run no
longer blocks every round.

:class:`TraceCounter` counts jit (re)traces: call :meth:`TraceCounter.bump`
inside a jitted function body — it runs at trace time only, so the count
is exactly the number of specializations XLA compiled.
"""

from __future__ import annotations

import json
import time
from typing import Any, Optional, Sequence

import numpy as np

from repro.obs.record import SCHEMA, hop_timeline, plan_meta


class TraceCounter:
    """Counts jit trace events (``bump()`` from inside a jitted body)."""

    def __init__(self):
        self.count = 0

    def bump(self) -> int:
        self.count += 1
        return self.count


class RoundBuffer:
    """Buffers per-round device pytrees; one host sync per :meth:`flush`."""

    def __init__(self):
        self._pending: list = []

    def __len__(self) -> int:
        return len(self._pending)

    def push(self, payload: Any) -> None:
        """Append a device pytree *without* fetching it."""
        self._pending.append(payload)

    def flush(self) -> list:
        """Materialize everything buffered with a single ``device_get``."""
        if not self._pending:
            return []
        import jax
        out = jax.device_get(self._pending)
        self._pending = []
        return out


def _tolist(x) -> list:
    return np.asarray(x, np.float64).reshape(-1).tolist()


class TraceCollector:
    """Emit round/span telemetry records to a JSONL trace file.

    ``enabled=False`` (or ``path=None``) turns every method into an
    immediate no-op — the zero-cost-when-disabled contract. ``cfg``/``d``
    (an :class:`~repro.core.algorithms.AggConfig` and the flat model
    dimension) feed the meta record and the global/local bit split;
    either may also be supplied later via :meth:`configure` (the
    simulator fills them in when the caller did not).
    """

    def __init__(self, path: Optional[str], *, cfg=None, d: Optional[int]
                 = None, num_clients: Optional[int] = None,
                 meta: Optional[dict] = None, enabled: bool = True):
        self.path = path
        self.enabled = bool(enabled) and path is not None
        self.cfg = cfg
        self.d = d
        self.num_clients = num_clients
        self.meta = dict(meta or {})
        self.records_written = 0
        self._f = None

    # -- lifecycle ----------------------------------------------------------

    def configure(self, *, cfg=None, d: Optional[int] = None,
                  num_clients: Optional[int] = None, **meta) -> None:
        """Fill in missing context before the first record (idempotent —
        never overwrites values the constructor already set)."""
        if self._f is not None:
            return
        if self.cfg is None:
            self.cfg = cfg
        if self.d is None:
            self.d = d
        if self.num_clients is None:
            self.num_clients = num_clients
        for key, val in meta.items():
            self.meta.setdefault(key, val)

    def _write(self, obj: dict) -> None:
        if self._f is None:
            self._f = open(self.path, "w")
            self._f.write(json.dumps(self._meta_record()) + "\n")
            self.records_written += 1
        self._f.write(json.dumps(obj, separators=(",", ":"),
                                  allow_nan=False) + "\n")
        self.records_written += 1

    def _meta_record(self) -> dict:
        cfg = {}
        if self.cfg is not None:
            cfg = {"kind": str(getattr(self.cfg.kind, "value", self.cfg.kind)),
                   "q": self.cfg.q, "q_global": self.cfg.q_global,
                   "q_local": self.cfg.q_local, "omega": self.cfg.omega,
                   "topq_impl": self.cfg.topq_impl,
                   "kernel_mode": self.cfg.kernel_mode}
        out = {"schema": SCHEMA, "kind": "meta", "ts_unix": time.time(),
               "cfg": cfg, **self.meta}
        if self.d is not None:
            out["d"] = int(self.d)
        if self.num_clients is not None:
            out["num_clients"] = int(self.num_clients)
        return out

    def close(self) -> None:
        if self._f is not None:
            self._f.close()
            self._f = None

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    # -- records ------------------------------------------------------------

    def record_round(self, rnd: int, stats, *, plan=None, tree=None,
                     loss=None, participate=None, ef_mass=None,
                     stage_ef_mass: Sequence = (), ef_dead_mass=None,
                     retraces: Optional[int] = None,
                     phases: Optional[dict] = None,
                     cohort=None) -> Optional[dict]:
        """Record one aggregation round.

        ``stats`` is a :class:`~repro.core.algorithms.HopStats` (leaves
        [K]) or a per-stage sequence of them (stage 0 first — the
        :class:`~repro.agg.nested.NestedResult` layout). All array inputs
        may be host numpy or (already-fetched) jax arrays. ``plan``
        contributes structure (forest + levels + the simulated timeline);
        ``tree`` (an :class:`~repro.topo.tree.AggTree` with link
        attributes) upgrades stage 0's timeline to the
        :func:`~repro.topo.tree.round_latency_s` link model, which defines
        ``crit_path_s``. ``cohort`` tags the record with its tenant id
        when the round came out of a batched multi-tenant launch
        (:meth:`repro.fed.simulator.Simulator.run_batched`,
        :class:`repro.agg.batching.RoundScheduler`) — per-cohort records
        of one batched round share a ``round`` number and differ only in
        ``cohort``, so traces stay queryable per tenant.
        """
        if not self.enabled:
            return None
        if hasattr(stats, "bits"):
            stats = (stats,)
        stats = tuple(stats)
        stage_ef_mass = tuple(stage_ef_mass)

        stages = []
        for s, st in enumerate(stats):
            entry = {
                "bits": _tolist(st.bits),
                "nnz": _tolist(st.nnz_out),
                "nnz_global": _tolist(st.nnz_global),
                "nnz_local": _tolist(st.nnz_local),
                "err_sq": _tolist(st.err_sq),
            }
            if s == 0 and ef_mass is not None:
                entry["ef_mass"] = _tolist(ef_mass)
            elif s >= 1 and s - 1 < len(stage_ef_mass):
                entry["ef_mass"] = _tolist(stage_ef_mass[s - 1])
            stages.append(entry)

        pmeta = None
        crit_path = None
        if plan is not None:
            pmeta = plan_meta(plan)
            if len(pmeta["stages"]) != len(stages):
                raise ValueError(
                    f"plan has {len(pmeta['stages'])} stages, stats "
                    f"{len(stages)}")
            t_cursor = 0.0
            for s, (pst, entry) in enumerate(zip(pmeta["stages"], stages)):
                bw = lat = None
                if (s == 0 and tree is not None
                        and tree.uplink_bw_bps is not None):
                    bw, lat = tree.uplink_bw_bps, tree.uplink_latency_s
                t0, t1, crit = hop_timeline(
                    pst["parent"], pst["level"], entry["bits"],
                    bw_bps=bw, latency_s=lat, t_start=t_cursor)
                entry["t0_s"] = t0.tolist()
                entry["t1_s"] = t1.tolist()
                t_cursor = t_cursor + crit
                if s == 0 and bw is not None:
                    crit_path = crit

        totals = {
            "bits": float(sum(sum(e["bits"]) for e in stages)),
            "nnz": float(sum(sum(e["nnz"]) for e in stages)),
            "err_sq": float(sum(sum(e["err_sq"]) for e in stages)),
        }
        if self.cfg is not None and self.d is not None:
            from repro.core.comm_cost import idx_bits
            ng = sum(sum(e["nnz_global"]) for e in stages)
            nl = sum(sum(e["nnz_local"]) for e in stages)
            totals["bits_global"] = float(self.cfg.omega * ng)
            totals["bits_local"] = float(
                (self.cfg.omega + idx_bits(self.d)) * nl)

        out = {"schema": SCHEMA, "kind": "round", "round": int(rnd),
               "stages": stages, "totals": totals}
        if pmeta is not None:
            out["plan"] = pmeta
        if participate is not None:
            out["participation"] = _tolist(participate)
        if ef_dead_mass is not None:
            out["ef_dead_mass"] = float(np.asarray(ef_dead_mass))
        if crit_path is not None:
            out["crit_path_s"] = float(crit_path)
        if loss is not None:
            out["loss"] = float(np.asarray(loss))
        if retraces is not None:
            out["retraces"] = int(retraces)
        if phases:
            out["phases"] = {k: float(v) for k, v in phases.items()}
        if cohort is not None:
            out["cohort"] = (cohort if isinstance(cohort, (int, str))
                             else str(cohort))
        self._write(out)
        return out

    def record_span(self, name: str, t0_s: float, dur_s: float, *,
                    track: str = "host",
                    args: Optional[dict] = None) -> Optional[dict]:
        """Record one host wall-clock interval (a benchmark/loop phase)."""
        if not self.enabled:
            return None
        out = {"schema": SCHEMA, "kind": "span", "name": str(name),
               "track": str(track), "t0_s": float(t0_s),
               "dur_s": float(dur_s)}
        if args:
            out["args"] = args
        self._write(out)
        return out

    def record_train_metrics(self, step: int, metrics: dict,
                             **kwargs) -> Optional[dict]:
        """Adapter for :func:`repro.train.step.build_train_step` metrics.

        The train step reduces wire accounting to scalars
        (``agg_bits``/``agg_nnz``/``agg_err_sq``, ± ``agg_bits_relay``
        and the telemetry EF masses) — record them as a single-hop round
        so train runs and simulator runs share one trace schema.
        """
        if not self.enabled:
            return None
        from repro.core.algorithms import HopStats
        bits = np.asarray([float(np.asarray(metrics["agg_bits"]))])
        nnz = np.asarray([float(np.asarray(metrics["agg_nnz"]))])
        stats = HopStats(nnz_out=nnz, nnz_global=np.zeros_like(nnz),
                         nnz_local=nnz, bits=bits,
                         err_sq=np.asarray(
                             [float(np.asarray(metrics["agg_err_sq"]))]))
        return self.record_round(
            step, stats, loss=metrics.get("loss"),
            ef_mass=(None if "ef_mass" not in metrics
                     else [float(np.asarray(metrics["ef_mass"]))]),
            ef_dead_mass=metrics.get("ef_dead_mass"), **kwargs)
