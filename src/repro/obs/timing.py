"""Wall-clock phase hooks for benchmarks and host loops.

:class:`PhaseTimer` measures *host* intervals: jitted dispatch, compile,
flush/sync, plan lookup. It deliberately lives outside jit — what it
times on an async backend is the dispatch (plus any blocking the caller
does), which is exactly the honest host-side quantity; per-op device
timings belong to the profiler, not the trace.

Spans accumulate in memory; :meth:`PhaseTimer.emit` writes them to a
:class:`~repro.obs.collector.TraceCollector` as ``span`` records (one
Perfetto track per ``track`` name) and :meth:`PhaseTimer.totals` folds
them into the per-phase seconds a round record carries.
"""

from __future__ import annotations

import contextlib
import time
from typing import Optional


class PhaseTimer:
    """Accumulates named host wall-clock spans.

    >>> timer = PhaseTimer()
    >>> with timer.phase("compile", track="bench"):
    ...     run_once()
    >>> timer.totals()["compile"]
    """

    def __init__(self):
        self.spans: list = []          # (name, track, t0_s, dur_s, args)
        self._origin = time.perf_counter()

    @contextlib.contextmanager
    def phase(self, name: str, *, track: str = "host",
              args: Optional[dict] = None):
        t0 = time.perf_counter()
        try:
            yield self
        finally:
            t1 = time.perf_counter()
            self.spans.append((name, track, t0 - self._origin, t1 - t0,
                               args))

    def add(self, name: str, dur_s: float, *, track: str = "host",
            args: Optional[dict] = None) -> None:
        """Record an externally-measured duration at the current cursor."""
        self.spans.append((name, track,
                           time.perf_counter() - self._origin - dur_s,
                           dur_s, args))

    def totals(self) -> dict:
        """Summed seconds per phase name."""
        out: dict = {}
        for name, _, _, dur, _ in self.spans:
            out[name] = out.get(name, 0.0) + dur
        return out

    def take(self) -> dict:
        """:meth:`totals` then reset — the per-round phases dict."""
        out = self.totals()
        self.spans = []
        return out

    def emit(self, collector) -> int:
        """Write every span to ``collector`` as ``span`` records."""
        n = 0
        for name, track, t0, dur, args in self.spans:
            if collector.record_span(name, t0, dur, track=track,
                                     args=args) is not None:
                n += 1
        return n
