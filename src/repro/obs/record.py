"""Trace schema + plan introspection for round telemetry.

A trace is a JSONL file: one JSON object per line, every line carrying
``"schema": SCHEMA`` and a ``"kind"``:

* ``meta`` — written once at the head: aggregation config (algorithm, Q
  split, ω), model dimension d, client count, free-form context (backend,
  topology name, git provenance, …);
* ``round`` — one aggregation round: per-stage per-hop §V accounting
  (bits split global/local, nnz, err_sq), the plan shape and its
  reconstructed forest (parent/level per client), participation mask,
  per-client EF mass, the dead-client banked-EF metric, the simulated
  per-hop timeline + critical-path latency (the
  :func:`repro.topo.tree.round_latency_s` model when link attributes are
  known, unit hop times otherwise), the cumulative jit retrace count,
  host wall-clock per phase, and — for multi-tenant batched rounds
  (schema ≥ 1.1) — the ``cohort`` id the record belongs to, so one trace
  stays queryable per tenant;
* ``span`` — a host wall-clock interval (benchmark/simulator phase hooks:
  compile, dispatch, flush, …).

Everything here is host-side numpy/python — records are built *after* the
jitted round returns, so collection can never add a jit specialization.
"""

from __future__ import annotations

import json
from typing import Optional, Sequence

import numpy as np

#: Versioned schema tag carried by every trace line. Bump the suffix when
#: a record field changes meaning; readers reject unknown majors.
#: 1.1: round records may carry a ``cohort`` tenant id (batched rounds).
SCHEMA = "repro.obs.trace/1.1"

_KINDS = ("meta", "round", "span")


# ---------------------------------------------------------------------------
# Plan introspection (host-side, numpy)
# ---------------------------------------------------------------------------

def _stage_forest(plan) -> tuple:
    """Reconstruct one stage's forest from its level schedule.

    Returns ``(parent, level)``, both ``[K]`` int arrays: ``parent[i]`` is
    the client receiving i's γ, or ``-(sink+1)`` for hops that deliver to
    sink row *sink* (single-sink plans: -1 = the PS); ``level[i]`` is i's
    schedule level index (0 = deepest level, runs first).
    """
    k = plan.num_clients
    node_id = np.asarray(plan.node_id)
    parent_row = np.asarray(plan.parent_row)
    slot_mask = np.asarray(plan.slot_mask)
    flat_pos = np.asarray(plan.flat_pos)
    w = node_id.shape[1] if node_id.ndim == 2 else 1
    parent = np.full((k,), -1, np.int64)
    for li in range(node_id.shape[0]):
        for wi in range(node_id.shape[1]):
            if slot_mask[li, wi] > 0:
                n = int(node_id[li, wi])
                p = int(parent_row[li, wi])
                if n < k:
                    parent[n] = p if p < k else -(p - k + 1)
    level = (np.asarray(flat_pos, np.int64) // max(1, w))
    return parent, level


def plan_meta(plan) -> dict:
    """Host-side snapshot of a plan's structure for a round record.

    Accepts an :class:`~repro.agg.plan.AggPlan` or a
    :class:`~repro.agg.nested.NestedPlan`; returns ``{"type": "flat" |
    "nested", "stages": [...]}`` where each stage entry carries the padded
    ``(L, W)``, unit/sink counts, aliveness, and the reconstructed
    ``parent``/``level`` arrays (see :func:`_stage_forest`).
    """
    stages = getattr(plan, "stages", None)
    if stages is None:
        stages, ptype = (plan,), "flat"
    else:
        ptype = "nested"
    out = []
    for st in stages:
        parent, level = _stage_forest(st)
        out.append({
            "L": int(np.asarray(st.node_id).shape[0]),
            "W": int(np.asarray(st.node_id).shape[1]),
            "num_clients": int(st.num_clients),
            "num_sinks": int(st.num_sinks),
            "alive": np.asarray(st.alive, np.float64).tolist(),
            "parent": parent.tolist(),
            "level": level.tolist(),
        })
    return {"type": ptype, "stages": out}


def subtree_sizes_from_parent(parent: Sequence[int]) -> np.ndarray:
    """``size[i]`` = #units in the subtree rooted at i (incl. i), from a
    record's ``parent`` array (negatives = sink/PS). The tree Prop-2 bound
    (:func:`repro.core.comm_cost.expected_lambda_nnz_bound_tree`) takes
    exactly these — so a trace is self-sufficient for the closed-form
    cross-checks, no topology object needed."""
    parent = np.asarray(parent, np.int64)
    k = len(parent)
    depth = np.zeros((k,), np.int64)
    for i in range(k):
        n, d = i, 1
        while parent[n] >= 0:
            n = int(parent[n])
            d += 1
            if d > k + 1:
                raise ValueError("cycle in recorded forest")
        depth[i] = d
    size = np.ones((k,), np.int64)
    for i in np.argsort(-depth):
        p = parent[int(i)]
        if p >= 0:
            size[p] += size[int(i)]
    return size


# ---------------------------------------------------------------------------
# Simulated per-hop timeline
# ---------------------------------------------------------------------------

def hop_timeline(parent: Sequence[int], level: Sequence[int],
                 bits: Sequence[float], *,
                 bw_bps: Optional[Sequence[float]] = None,
                 latency_s: Optional[Sequence[float]] = None,
                 t_start: float = 0.0) -> tuple:
    """Dataflow start/end times per hop → ``(t0, t1, crit_path)``.

    Hop i starts when all of its children have delivered (``max`` over
    children t1 — the same recurrence as
    :func:`repro.topo.tree.round_latency_s`, whose critical path this
    reproduces exactly when ``bw_bps``/``latency_s`` come from the routed
    tree; asserted in tests). Without a link model every hop costs one
    time unit. Zero-bandwidth hops (stranded stubs) are skipped:
    ``t0 == t1 == t_start`` and they never extend the critical path.
    """
    parent = np.asarray(parent, np.int64)
    level = np.asarray(level, np.int64)
    bits = np.asarray(bits, np.float64)
    k = len(parent)
    if bw_bps is not None:
        bw = np.asarray(bw_bps, np.float64)
        lat = (np.zeros((k,)) if latency_s is None
               else np.asarray(latency_s, np.float64))
        tx = np.where(bw > 0, bits / np.maximum(bw, 1e-30) + lat, 0.0)
        skip = bw <= 0
    else:
        tx = np.ones((k,), np.float64)
        skip = np.zeros((k,), bool)
    t0 = np.full((k,), t_start, np.float64)
    t1 = np.full((k,), t_start, np.float64)
    ready = np.zeros((k,), np.float64)
    for i in np.argsort(level, kind="stable"):      # deepest level first
        i = int(i)
        if skip[i]:
            continue
        t0[i] = t_start + ready[i]
        t1[i] = t0[i] + tx[i]
        p = parent[i]
        if p >= 0:
            ready[p] = max(ready[p], t1[i] - t_start)
    sinks = [i for i in range(k) if parent[i] < 0 and not skip[i]]
    crit = max((t1[i] - t_start for i in sinks), default=0.0)
    return t0, t1, crit


# ---------------------------------------------------------------------------
# Validation
# ---------------------------------------------------------------------------

def _is_num(x) -> bool:
    return isinstance(x, (int, float)) and not isinstance(x, bool)


def _num_list(x, n: Optional[int] = None) -> bool:
    return (isinstance(x, list) and all(_is_num(v) for v in x)
            and (n is None or len(x) == n))


def validate_record(obj) -> list:
    """Schema-validate one trace line → list of error strings (empty = ok)."""
    errs = []
    if not isinstance(obj, dict):
        return [f"record is {type(obj).__name__}, not an object"]
    schema = obj.get("schema", "")
    if (not isinstance(schema, str)
            or schema.split("/")[0] != SCHEMA.split("/")[0]):
        errs.append(f"unknown schema {schema!r}")
    kind = obj.get("kind")
    if kind not in _KINDS:
        return errs + [f"unknown kind {kind!r}"]
    if kind == "meta":
        if not isinstance(obj.get("cfg", {}), dict):
            errs.append("meta.cfg must be an object")
        for key in ("d", "num_clients"):
            if key in obj and not _is_num(obj[key]):
                errs.append(f"meta.{key} must be a number")
    elif kind == "span":
        for key in ("name", "track"):
            if not isinstance(obj.get(key), str):
                errs.append(f"span.{key} must be a string")
        for key in ("t0_s", "dur_s"):
            if not _is_num(obj.get(key)):
                errs.append(f"span.{key} must be a number")
    elif kind == "round":
        if not _is_num(obj.get("round")):
            errs.append("round.round must be a number")
        stages = obj.get("stages")
        if not isinstance(stages, list) or not stages:
            errs.append("round.stages must be a non-empty list")
            stages = []
        for s, st in enumerate(stages):
            if not isinstance(st, dict):
                errs.append(f"stages[{s}] must be an object")
                continue
            n = None
            for key in ("bits", "nnz", "nnz_global", "nnz_local", "err_sq"):
                v = st.get(key)
                if not _num_list(v, n):
                    errs.append(f"stages[{s}].{key} must be a numeric list "
                                f"of the stage's unit count")
                elif n is None:
                    n = len(v)
            for key in ("t0_s", "t1_s", "ef_mass"):
                if key in st and not _num_list(st[key], n):
                    errs.append(f"stages[{s}].{key} length mismatch")
        plan = obj.get("plan")
        if plan is not None:
            if (not isinstance(plan, dict)
                    or plan.get("type") not in ("flat", "nested")
                    or not isinstance(plan.get("stages"), list)):
                errs.append("round.plan malformed")
            else:
                for s, st in enumerate(plan["stages"]):
                    for key in ("parent", "level"):
                        if not _num_list(st.get(key)):
                            errs.append(f"plan.stages[{s}].{key} must be a "
                                        f"numeric list")
        if "participation" in obj and not _num_list(obj["participation"]):
            errs.append("round.participation must be a numeric list")
        for key in ("ef_dead_mass", "crit_path_s", "loss", "retraces"):
            if obj.get(key) is not None and not _is_num(obj[key]):
                errs.append(f"round.{key} must be a number or null")
        cohort = obj.get("cohort")
        if cohort is not None and not (_is_num(cohort)
                                       or isinstance(cohort, str)):
            errs.append("round.cohort must be a number or string")
        tot = obj.get("totals")
        if not isinstance(tot, dict) or not all(
                _is_num(tot.get(key)) for key in ("bits", "nnz", "err_sq")):
            errs.append("round.totals must carry numeric bits/nnz/err_sq")
        phases = obj.get("phases")
        if phases is not None and (
                not isinstance(phases, dict)
                or not all(_is_num(v) for v in phases.values())):
            errs.append("round.phases must map names to seconds")
    return errs


def iter_trace(path: str):
    """Yield parsed records of a JSONL trace (raises on malformed JSON)."""
    with open(path) as f:
        for ln, line in enumerate(f, 1):
            line = line.strip()
            if not line:
                continue
            try:
                yield json.loads(line)
            except json.JSONDecodeError as exc:
                raise ValueError(f"{path}:{ln}: not valid JSON: {exc}")


def validate_trace(path: str) -> dict:
    """Validate a whole trace file.

    Returns ``{"meta": n, "round": n, "span": n, "errors": [...]}`` where
    errors are ``"line N: message"`` strings. A valid trace has at least
    one meta record, and it comes first.
    """
    counts = {k: 0 for k in _KINDS}
    errors = []
    first_kind = None
    for ln, rec in enumerate(iter_trace(path), 1):
        errs = validate_record(rec)
        kind = rec.get("kind") if isinstance(rec, dict) else None
        if kind in counts:
            counts[kind] += 1
            if first_kind is None:
                first_kind = kind
        errors.extend(f"line {ln}: {e}" for e in errs)
    if counts["meta"] == 0:
        errors.append("trace has no meta record")
    elif first_kind != "meta":
        errors.append("meta record must come first")
    counts["errors"] = errors
    return counts
