"""Learning-rate schedules (scale factors multiplied onto OptConfig.lr)."""

from __future__ import annotations

import jax.numpy as jnp


def lr_schedule(step, *, warmup: int = 100, decay_steps: int = 10_000,
                kind: str = "cosine", min_ratio: float = 0.1):
    """Warmup-then-decay scale in [min_ratio, 1]."""
    s = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
    warm = jnp.minimum(1.0, (s + 1) / max(warmup, 1))
    if kind == "constant":
        return warm
    frac = jnp.clip((s - warmup) / max(decay_steps - warmup, 1), 0.0, 1.0)
    if kind == "cosine":
        decay = min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(
            jnp.pi * frac))
    elif kind == "linear":
        decay = 1 - (1 - min_ratio) * frac
    else:
        raise ValueError(kind)
    return warm * decay
