"""Optimizers, implemented twice:

* flat-space — operates on the [D_pad] flattened master params (fp32,
  sharded over every mesh axis = ZeRO-1); used by the production train step.
  Purely elementwise → zero collectives in the update itself.
* pytree — convenience for the FL simulator / examples.

No optax dependency (container is offline); implementations are the
standard textbook ones and are unit-tested against hand-rolled numpy.
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class OptConfig:
    name: str = "adamw"              # sgd | momentum | adamw
    lr: float = 1e-3
    momentum: float = 0.9
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.0
    grad_clip: float = 0.0           # 0 = off; global-norm clip


class FlatOptState(NamedTuple):
    step: Array                      # int32 scalar
    m: Optional[Array]               # [D] or None (sgd)
    v: Optional[Array]               # [D] or None (sgd/momentum)


def init_flat(cfg: OptConfig, d: int, like: Optional[Array] = None
              ) -> FlatOptState:
    zeros = (jnp.zeros((d,), jnp.float32) if like is None
             else jnp.zeros_like(like, jnp.float32))
    if cfg.name == "sgd":
        return FlatOptState(jnp.int32(0), None, None)
    if cfg.name == "momentum":
        return FlatOptState(jnp.int32(0), zeros, None)
    if cfg.name == "adamw":
        return FlatOptState(jnp.int32(0), zeros, jnp.zeros_like(zeros))
    raise ValueError(cfg.name)


def apply_flat(cfg: OptConfig, state: FlatOptState, params: Array,
               grad: Array, lr_scale: Array | float = 1.0
               ) -> tuple[Array, FlatOptState]:
    """One elementwise update in flat fp32 space."""
    g = grad.astype(jnp.float32)
    p = params.astype(jnp.float32)
    if cfg.grad_clip > 0:
        gn = jnp.sqrt(jnp.sum(g * g))
        g = g * jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gn, 1e-12))
    step = state.step + 1
    lr = cfg.lr * lr_scale
    if cfg.name == "sgd":
        new_p = p - lr * g
        return new_p, FlatOptState(step, None, None)
    if cfg.name == "momentum":
        m = cfg.momentum * state.m + g
        new_p = p - lr * m
        return new_p, FlatOptState(step, m, None)
    if cfg.name == "adamw":
        m = cfg.b1 * state.m + (1 - cfg.b1) * g
        v = cfg.b2 * state.v + (1 - cfg.b2) * g * g
        t = step.astype(jnp.float32)
        mh = m / (1 - cfg.b1 ** t)
        vh = v / (1 - cfg.b2 ** t)
        upd = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p
        new_p = p - lr * upd
        return new_p, FlatOptState(step, m, v)
    raise ValueError(cfg.name)


# ---------------------------------------------------------------------------
# Pytree variants (simulator / examples)
# ---------------------------------------------------------------------------

class TreeOptState(NamedTuple):
    step: Array
    m: Any
    v: Any


def init_tree(cfg: OptConfig, params: Any) -> TreeOptState:
    zeros = lambda: jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32),
                                 params)
    if cfg.name == "sgd":
        return TreeOptState(jnp.int32(0), None, None)
    if cfg.name == "momentum":
        return TreeOptState(jnp.int32(0), zeros(), None)
    return TreeOptState(jnp.int32(0), zeros(), zeros())


def apply_tree(cfg: OptConfig, state: TreeOptState, params: Any, grads: Any,
               lr_scale: Array | float = 1.0) -> tuple[Any, TreeOptState]:
    if cfg.grad_clip > 0:
        gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                          for g in jax.tree.leaves(grads)))
        scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gn, 1e-12))
        grads = jax.tree.map(lambda g: g * scale, grads)
    step = state.step + 1
    lr = cfg.lr * lr_scale
    if cfg.name == "sgd":
        new_p = jax.tree.map(
            lambda p, g: (p.astype(jnp.float32) - lr * g).astype(p.dtype),
            params, grads)
        return new_p, TreeOptState(step, None, None)
    if cfg.name == "momentum":
        m = jax.tree.map(lambda mm, g: cfg.momentum * mm + g, state.m, grads)
        new_p = jax.tree.map(
            lambda p, mm: (p.astype(jnp.float32) - lr * mm).astype(p.dtype),
            params, m)
        return new_p, TreeOptState(step, m, None)
    t = step.astype(jnp.float32)
    m = jax.tree.map(lambda mm, g: cfg.b1 * mm + (1 - cfg.b1) * g,
                     state.m, grads)
    v = jax.tree.map(lambda vv, g: cfg.b2 * vv + (1 - cfg.b2) * g * g,
                     state.v, grads)

    def upd(p, mm, vv):
        mh = mm / (1 - cfg.b1 ** t)
        vh = vv / (1 - cfg.b2 ** t)
        u = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(
            jnp.float32)
        return (p.astype(jnp.float32) - lr * u).astype(p.dtype)

    new_p = jax.tree.map(upd, params, m, v)
    return new_p, TreeOptState(step, m, v)
