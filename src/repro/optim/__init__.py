from repro.optim.optimizers import (FlatOptState, OptConfig, TreeOptState,
                                    apply_flat, apply_tree, init_flat,
                                    init_tree)
from repro.optim.schedule import lr_schedule

__all__ = ["FlatOptState", "OptConfig", "TreeOptState", "apply_flat",
           "apply_tree", "init_flat", "init_tree", "lr_schedule"]
