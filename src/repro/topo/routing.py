"""Spanning-tree extraction: graph + PS → aggregation tree.

Incremental aggregation needs each client to forward exactly one partial
aggregate toward the PS, i.e. a spanning tree of the (surviving) constellation
graph rooted at the PS. Two extraction policies:

* :func:`shortest_path_tree` — Dijkstra from the PS under a ``latency`` or
  ``hops`` metric. Minimizes per-round aggregation latency (tree depth).
* :func:`widest_path_tree` — maximize the *bottleneck bandwidth* of every
  client's path to the PS (max-min Dijkstra). With CL-SIA's constant
  per-hop payload, round time is dominated by the narrowest link on the
  deepest path, which this policy widens.

Both return a parent map over *graph node ids*; :func:`extract_tree`
relabels into client index space (:class:`repro.topo.tree.AggTree`),
attaching per-client uplink bandwidth/latency for the cost model. Dead
relays (``exclude``) are routed around; if removal disconnects the graph,
the stranded clients are parked at depth 1 with zero bandwidth so the
simulator can mark them non-participating while keeping array shapes static.

**Cluster-aware routing** (:func:`cluster_routed`) is the staged variant:
partition the clients into pods/clusters (:func:`partition_clusters`,
farthest-point seeded multi-source BFS), route an intra-cluster tree to
each cluster's relay head, and route a relay tree over the heads — the
:class:`NestedTopology` that ``repro.agg.compile_nested`` lowers into a
staged :class:`~repro.agg.nested.NestedPlan` (satellite deployments:
aggregate inside each orbital plane/cluster over wide ISLs, then relay
per-cluster partials over the scarce inter-cluster/ground links).
"""

from __future__ import annotations

import heapq
import math
from typing import Iterable, NamedTuple, Optional, Sequence

import numpy as np

from repro.topo.graph import ConstellationGraph
from repro.topo.tree import PS, AggTree


def _dijkstra(graph: ConstellationGraph, cost_of_edge, combine,
              exclude: Iterable[int],
              start_cost: float = 0.0) -> tuple[dict, dict]:
    """Generic best-path tree from the PS.

    ``cost_of_edge(idx) -> float`` and ``combine(path_cost, edge_cost)``
    define the metric; smaller is better. ``start_cost`` is the PS's own
    path cost — the identity of ``combine`` (0 for sums, −inf for max-min).
    Returns ({node: parent_node}, {node: edge_idx to parent}) for every
    reachable non-excluded node.
    """
    dead = set(exclude)
    if graph.ps in dead:
        raise ValueError("cannot exclude the PS node")
    adj = graph.adjacency(exclude=dead)
    dist = {graph.ps: start_cost}
    parent: dict = {}
    via_edge: dict = {}
    heap = [(start_cost, graph.ps)]
    while heap:
        du, u = heapq.heappop(heap)
        if du > dist.get(u, math.inf):
            continue
        for v, idx in adj[u]:
            dv = combine(du, cost_of_edge(idx))
            if dv < dist.get(v, math.inf):
                dist[v] = dv
                parent[v] = u
                via_edge[v] = idx
                heapq.heappush(heap, (dv, v))
    return parent, via_edge


def shortest_path_tree(graph: ConstellationGraph, *, metric: str = "latency",
                       exclude: Iterable[int] = ()) -> AggTree:
    """Dijkstra tree from the PS. ``metric``: "latency" (Σ link latency)
    or "hops" (unweighted BFS)."""
    if metric == "latency":
        cost = lambda idx: float(graph.latency_s[idx])
    elif metric == "hops":
        cost = lambda idx: 1.0
    else:
        raise ValueError(f"unknown metric {metric!r}")
    parent, via = _dijkstra(graph, cost, lambda a, b: a + b, exclude)
    return extract_tree(graph, parent, via)


def widest_path_tree(graph: ConstellationGraph,
                     exclude: Iterable[int] = ()) -> AggTree:
    """Max-bottleneck-bandwidth tree (widest-path Dijkstra).

    Path cost = −min(link bandwidth along path); ties broken by discovery
    order. Every client gets the maximum achievable bottleneck bandwidth to
    the PS among all its paths.
    """
    parent, via = _dijkstra(
        graph,
        lambda idx: -float(graph.bandwidth_bps[idx]),
        lambda path_cost, edge_cost: max(path_cost, edge_cost),
        exclude, start_cost=-math.inf)
    return extract_tree(graph, parent, via)


def route_tree(graph: ConstellationGraph, routing: str = "latency",
               exclude: Iterable[int] = ()) -> AggTree:
    """Route by policy name: ``latency``/``hops`` (shortest-path) or
    ``widest`` (max-bottleneck-bandwidth). The string dispatch the schedule
    and scenario compilers share."""
    if routing == "widest":
        return widest_path_tree(graph, exclude=exclude)
    if routing in ("latency", "hops"):
        return shortest_path_tree(graph, metric=routing, exclude=exclude)
    raise ValueError(f"unknown routing {routing!r}")


def healed_chain_tree(num_clients: int, dead: Iterable[int] = (),
                      order: Optional[Sequence] = None) -> AggTree:
    """The paper's chain with dead clients spliced out, as an
    :class:`AggTree`.

    ``order`` lists client indices PS-outward (default 0..K−1); ``dead``
    clients are removed via :func:`repro.runtime.fault.heal_chain` and the
    survivors chained in healed order (``order[0]`` adjacent to the PS).
    The dead clients stay in the tree as unreachable stubs (parent = PS,
    ``reachable`` False) so the [K]-shaped arrays keep their rows — the
    plan's ``alive`` mask zeros them. This keeps multi-node crash healing
    inside ``compile_plan``'s full-permutation contract.
    """
    from repro.runtime.fault import heal_chain
    if order is None:
        order = np.arange(num_clients, dtype=np.int32)
    healed = heal_chain(np.asarray(order, np.int32), tuple(dead))
    parent = np.full((num_clients,), PS, np.int64)
    reach = np.zeros((num_clients,), bool)
    prev = PS
    for o in healed:
        parent[int(o)] = prev
        reach[int(o)] = True
        prev = int(o)
    return AggTree(parent=tuple(int(p) for p in parent),
                   reachable=tuple(bool(r) for r in reach))


def extract_tree(graph: ConstellationGraph, parent_of_node: dict,
                 via_edge: Optional[dict] = None) -> AggTree:
    """Relabel a {node: parent_node} map into client index space.

    Clients are the non-PS nodes of the *full* graph in ascending node-id
    order (stable across failures, matching the simulator's [K, d] rows).
    Unreachable clients (dead or disconnected) become depth-1 stubs with
    parent = PS and zero uplink bandwidth; callers must zero their
    ``participate`` mask.
    """
    nodes = graph.client_nodes()
    index_of = {int(v): i for i, v in enumerate(nodes)}
    k = len(nodes)
    parent = np.full((k,), PS, np.int64)
    bw = np.zeros((k,), np.float64)
    lat = np.zeros((k,), np.float64)
    reachable = np.zeros((k,), bool)
    for i, v in enumerate(nodes):
        v = int(v)
        if v in parent_of_node:
            p = int(parent_of_node[v])
            parent[i] = PS if p == graph.ps else index_of[p]
            reachable[i] = True
            if via_edge is not None and v in via_edge:
                idx = via_edge[v]
                bw[i] = float(graph.bandwidth_bps[idx])
                lat[i] = float(graph.latency_s[idx])
        else:
            parent[i] = PS       # stranded stub; participate must be 0
    return AggTree(parent=tuple(int(p) for p in parent),
                   uplink_bw_bps=tuple(float(b) for b in bw),
                   uplink_latency_s=tuple(float(l) for l in lat),
                   reachable=tuple(bool(r) for r in reachable))


# ---------------------------------------------------------------------------
# Cluster-aware routing (pods/clusters → staged NestedTopology)
# ---------------------------------------------------------------------------

class NestedTopology(NamedTuple):
    """Staged aggregation route: clusters + intra trees + inter relay tree.

    ``clusters[c]`` are the global client indices of cluster c (together a
    partition of 0..K−1); ``intra[c]`` is an :class:`AggTree` over cluster
    c's members in listed order, rooted at the cluster's relay head (local
    ``PS``); ``inter`` is an :class:`AggTree` over the C cluster units.
    Consumed by ``repro.agg.compile_nested`` (via :meth:`nested_stages`)
    and accepted everywhere a nested topology is (``make_agg_plan``,
    ``build_train_step``, ``Simulator``).
    """

    clusters: tuple           # tuple[tuple[int, ...], ...]
    intra: tuple              # tuple[AggTree, ...] (local index space)
    inter: AggTree            # tree over the C cluster units

    @property
    def num_clients(self) -> int:
        return sum(len(c) for c in self.clusters)

    @property
    def num_clusters(self) -> int:
        return len(self.clusters)

    def nested_stages(self) -> list:
        """The two-stage spec ``compile_nested`` consumes."""
        return [list(zip(self.clusters, self.intra)),
                [(tuple(range(len(self.clusters))), self.inter)]]


def _hop_dists(adj: list, start: int, num_nodes: int) -> np.ndarray:
    dist = np.full((num_nodes,), np.inf)
    dist[start] = 0.0
    frontier = [start]
    while frontier:
        nxt = []
        for u in frontier:
            for v, _ in adj[u]:
                if not np.isfinite(dist[v]):
                    dist[v] = dist[u] + 1
                    nxt.append(v)
        frontier = nxt
    return dist


def partition_clusters(graph: ConstellationGraph, num_clusters: int, *,
                       exclude: Iterable[int] = ()) -> list:
    """Partition the clients into ``num_clusters`` connected-ish clusters.

    Farthest-point seeding (hop metric) followed by balanced multi-source
    BFS growth: seeds claim unassigned neighbors one ring at a time,
    smallest cluster first, so cluster sizes stay within one BFS ring of
    each other on regular graphs. Unreachable clients are appended to
    cluster 0 (they become stubs downstream). Returns a list of sorted
    client-index lists.
    """
    nodes = [int(v) for v in graph.client_nodes()]
    index_of = {v: i for i, v in enumerate(nodes)}
    dead = set(int(v) for v in exclude)
    adj = graph.adjacency(exclude=dead)
    k = len(nodes)
    if not 1 <= num_clusters <= k:
        raise ValueError(f"num_clusters must be in 1..{k}")

    # farthest-point seeds, starting from the client farthest from the PS
    d_ps = _hop_dists(adj, graph.ps, graph.num_nodes)
    alive = [v for v in nodes if v not in dead and np.isfinite(d_ps[v])]
    if not alive:
        return [sorted(index_of[v] for v in nodes)] + \
            [[] for _ in range(num_clusters - 1)]
    seeds = [max(alive, key=lambda v: d_ps[v])]
    min_d = _hop_dists(adj, seeds[0], graph.num_nodes)
    while len(seeds) < num_clusters:
        cand = max(alive, key=lambda v: min_d[v])
        seeds.append(cand)
        min_d = np.minimum(min_d, _hop_dists(adj, cand, graph.num_nodes))

    owner = {v: c for c, v in enumerate(seeds)}
    frontiers = [[v] for v in seeds]
    remaining = set(alive) - set(seeds)
    while remaining and any(frontiers):
        # smallest cluster grows first — balance
        order = np.argsort([sum(1 for v in owner if owner[v] == c)
                            for c in range(num_clusters)])
        progress = False
        for c in order:
            nxt = []
            for u in frontiers[c]:
                for v, _ in adj[u]:
                    if v in remaining:
                        owner[v] = c
                        remaining.discard(v)
                        nxt.append(v)
                        progress = True
            frontiers[c] = nxt
        if not progress:
            break
    clusters = [[] for _ in range(num_clusters)]
    for v, c in owner.items():
        clusters[c].append(index_of[v])
    for v in nodes:        # dead / disconnected → cluster 0 stubs
        if v not in owner:
            clusters[0].append(index_of[v])
    return [sorted(c) for c in clusters]


def _subgraph_tree(graph: ConstellationGraph, members_nodes: list,
                   head: int, metric: str,
                   exclude: Iterable[int] = ()) -> AggTree:
    """Route a tree over ``members_nodes`` (graph ids) inside their induced
    subgraph, rooted at ``head``. Local client order = listed order. Dead
    nodes (``exclude``) are never relayed through — they end up as local
    stubs (``reachable`` False)."""
    dead = set(exclude)
    allowed = set(members_nodes) - dead
    local = {v: i for i, v in enumerate(members_nodes)}
    cost = ((lambda idx: float(graph.latency_s[idx])) if metric == "latency"
            else (lambda idx: 1.0))
    adj = graph.adjacency(exclude=dead)
    dist = {head: 0.0}
    parent: dict = {}
    via: dict = {}
    heap = [(0.0, head)]
    while heap:
        du, u = heapq.heappop(heap)
        if du > dist.get(u, math.inf):
            continue
        for v, idx in adj[u]:
            if v not in allowed:
                continue
            dv = du + cost(idx)
            if dv < dist.get(v, math.inf):
                dist[v] = dv
                parent[v] = u
                via[v] = idx
                heapq.heappush(heap, (dv, v))
    m = len(members_nodes)
    par = np.full((m,), PS, np.int64)
    bw = np.zeros((m,))
    lat = np.zeros((m,))
    reach = np.zeros((m,), bool)
    for v in members_nodes:
        i = local[v]
        if v == head:
            reach[i] = v not in dead
        elif v in parent:
            par[i] = local[parent[v]]
            reach[i] = True
            bw[i] = float(graph.bandwidth_bps[via[v]])
            lat[i] = float(graph.latency_s[via[v]])
    return AggTree(parent=tuple(int(p) for p in par),
                   uplink_bw_bps=tuple(float(b) for b in bw),
                   uplink_latency_s=tuple(float(l) for l in lat),
                   reachable=tuple(bool(r) for r in reach))


def cluster_routed(graph: ConstellationGraph, num_clusters: Optional[int]
                   = None, *, metric: str = "latency",
                   clusters: Optional[Sequence] = None,
                   exclude: Iterable[int] = ()) -> NestedTopology:
    """Cluster-aware route: pods/clusters → intra trees + inter relay tree.

    Partitions the constellation into ``num_clusters`` clusters (default
    ≈√K; or pass explicit ``clusters`` of client indices), picks each
    cluster's *relay head* (the member nearest the PS under ``metric``),
    routes an intra-cluster tree to the head inside the cluster's induced
    subgraph, and routes the relay tree over the heads in the quotient
    graph (best inter-cluster link per cluster pair; the PS keeps its
    ground links). Members a cluster's subgraph cannot reach become local
    stubs; clusters the quotient cannot reach become stub units — both are
    zeroed via the plans' ``alive`` masks downstream.
    """
    nodes = [int(v) for v in graph.client_nodes()]
    k = len(nodes)
    if clusters is None:
        if num_clusters is None:
            num_clusters = max(1, int(round(math.sqrt(k))))
        clusters = partition_clusters(graph, num_clusters, exclude=exclude)
    clusters = [list(c) for c in clusters if len(c)]
    c_of = {}
    for c, mem in enumerate(clusters):
        for i in mem:
            c_of[int(i)] = c

    # relay heads: nearest-to-PS member under the full-graph metric
    # (dead relays excluded — a head must be a live node)
    dead = set(int(v) for v in exclude)
    cost = ((lambda idx: float(graph.latency_s[idx])) if metric == "latency"
            else (lambda idx: 1.0))
    parent_ps, via_ps = _dijkstra(graph, cost, lambda a, b: a + b, dead)
    dist_ps = {}
    for v in nodes:
        d, node, ok = 0.0, v, v in parent_ps
        while ok and node != graph.ps:
            d += cost(via_ps[node])
            node = parent_ps[node]
        dist_ps[v] = d if ok else math.inf
    heads = []
    for mem in clusters:
        mem_nodes = [nodes[i] for i in mem]
        heads.append(min(mem_nodes, key=lambda v: dist_ps[v]))

    intra = tuple(_subgraph_tree(graph, [nodes[i] for i in mem], head,
                                 metric, exclude=dead)
                  for mem, head in zip(clusters, heads))

    # quotient graph over cluster units (+ PS): best link per pair
    c_of_node = {nodes[i]: c for i, c in
                 ((i, c_of[i]) for mem in clusters for i in mem)}
    best: dict = {}
    for idx, (u, v) in enumerate(graph.edges):
        u, v = int(u), int(v)
        if u in dead or v in dead:
            continue
        cu = -1 if u == graph.ps else c_of_node.get(u)
        cv = -1 if v == graph.ps else c_of_node.get(v)
        if cu is None or cv is None or cu == cv:
            continue
        key = (min(cu, cv), max(cu, cv))
        w = cost(idx)
        if key not in best or w < best[key][0]:
            best[key] = (w, idx)
    c = len(clusters)
    par = np.full((c,), PS, np.int64)
    bw = np.zeros((c,))
    lat = np.zeros((c,))
    reach = np.zeros((c,), bool)
    dist = {-1: 0.0}
    heap = [(0.0, -1)]
    qadj: dict = {}
    for (a, b), (w, idx) in best.items():
        qadj.setdefault(a, []).append((b, w, idx))
        qadj.setdefault(b, []).append((a, w, idx))
    qparent: dict = {}
    qvia: dict = {}
    while heap:
        du, u = heapq.heappop(heap)
        if du > dist.get(u, math.inf):
            continue
        for v, w, idx in qadj.get(u, []):
            dv = du + w
            if dv < dist.get(v, math.inf):
                dist[v] = dv
                qparent[v] = u
                qvia[v] = idx
                heapq.heappush(heap, (dv, v))
    for ci in range(c):
        if ci in qparent:
            p = qparent[ci]
            par[ci] = PS if p == -1 else p
            reach[ci] = True
            bw[ci] = float(graph.bandwidth_bps[qvia[ci]])
            lat[ci] = float(graph.latency_s[qvia[ci]])
    inter = AggTree(parent=tuple(int(p) for p in par),
                    uplink_bw_bps=tuple(float(b) for b in bw),
                    uplink_latency_s=tuple(float(l) for l in lat),
                    reachable=tuple(bool(r) for r in reach))
    return NestedTopology(clusters=tuple(tuple(int(i) for i in mem)
                                         for mem in clusters),
                          intra=intra, inter=inter)
