"""Spanning-tree extraction: graph + PS → aggregation tree.

Incremental aggregation needs each client to forward exactly one partial
aggregate toward the PS, i.e. a spanning tree of the (surviving) constellation
graph rooted at the PS. Two extraction policies:

* :func:`shortest_path_tree` — Dijkstra from the PS under a ``latency`` or
  ``hops`` metric. Minimizes per-round aggregation latency (tree depth).
* :func:`widest_path_tree` — maximize the *bottleneck bandwidth* of every
  client's path to the PS (max-min Dijkstra). With CL-SIA's constant
  per-hop payload, round time is dominated by the narrowest link on the
  deepest path, which this policy widens.

Both return a parent map over *graph node ids*; :func:`extract_tree`
relabels into client index space (:class:`repro.topo.tree.AggTree`),
attaching per-client uplink bandwidth/latency for the cost model. Dead
relays (``exclude``) are routed around; if removal disconnects the graph,
the stranded clients are parked at depth 1 with zero bandwidth so the
simulator can mark them non-participating while keeping array shapes static.
"""

from __future__ import annotations

import heapq
import math
from typing import Iterable, Optional

import numpy as np

from repro.topo.graph import ConstellationGraph
from repro.topo.tree import PS, AggTree


def _dijkstra(graph: ConstellationGraph, cost_of_edge, combine,
              exclude: Iterable[int],
              start_cost: float = 0.0) -> tuple[dict, dict]:
    """Generic best-path tree from the PS.

    ``cost_of_edge(idx) -> float`` and ``combine(path_cost, edge_cost)``
    define the metric; smaller is better. ``start_cost`` is the PS's own
    path cost — the identity of ``combine`` (0 for sums, −inf for max-min).
    Returns ({node: parent_node}, {node: edge_idx to parent}) for every
    reachable non-excluded node.
    """
    dead = set(exclude)
    if graph.ps in dead:
        raise ValueError("cannot exclude the PS node")
    adj = graph.adjacency(exclude=dead)
    dist = {graph.ps: start_cost}
    parent: dict = {}
    via_edge: dict = {}
    heap = [(start_cost, graph.ps)]
    while heap:
        du, u = heapq.heappop(heap)
        if du > dist.get(u, math.inf):
            continue
        for v, idx in adj[u]:
            dv = combine(du, cost_of_edge(idx))
            if dv < dist.get(v, math.inf):
                dist[v] = dv
                parent[v] = u
                via_edge[v] = idx
                heapq.heappush(heap, (dv, v))
    return parent, via_edge


def shortest_path_tree(graph: ConstellationGraph, *, metric: str = "latency",
                       exclude: Iterable[int] = ()) -> AggTree:
    """Dijkstra tree from the PS. ``metric``: "latency" (Σ link latency)
    or "hops" (unweighted BFS)."""
    if metric == "latency":
        cost = lambda idx: float(graph.latency_s[idx])
    elif metric == "hops":
        cost = lambda idx: 1.0
    else:
        raise ValueError(f"unknown metric {metric!r}")
    parent, via = _dijkstra(graph, cost, lambda a, b: a + b, exclude)
    return extract_tree(graph, parent, via)


def widest_path_tree(graph: ConstellationGraph,
                     exclude: Iterable[int] = ()) -> AggTree:
    """Max-bottleneck-bandwidth tree (widest-path Dijkstra).

    Path cost = −min(link bandwidth along path); ties broken by discovery
    order. Every client gets the maximum achievable bottleneck bandwidth to
    the PS among all its paths.
    """
    parent, via = _dijkstra(
        graph,
        lambda idx: -float(graph.bandwidth_bps[idx]),
        lambda path_cost, edge_cost: max(path_cost, edge_cost),
        exclude, start_cost=-math.inf)
    return extract_tree(graph, parent, via)


def extract_tree(graph: ConstellationGraph, parent_of_node: dict,
                 via_edge: Optional[dict] = None) -> AggTree:
    """Relabel a {node: parent_node} map into client index space.

    Clients are the non-PS nodes of the *full* graph in ascending node-id
    order (stable across failures, matching the simulator's [K, d] rows).
    Unreachable clients (dead or disconnected) become depth-1 stubs with
    parent = PS and zero uplink bandwidth; callers must zero their
    ``participate`` mask.
    """
    nodes = graph.client_nodes()
    index_of = {int(v): i for i, v in enumerate(nodes)}
    k = len(nodes)
    parent = np.full((k,), PS, np.int64)
    bw = np.zeros((k,), np.float64)
    lat = np.zeros((k,), np.float64)
    reachable = np.zeros((k,), bool)
    for i, v in enumerate(nodes):
        v = int(v)
        if v in parent_of_node:
            p = int(parent_of_node[v])
            parent[i] = PS if p == graph.ps else index_of[p]
            reachable[i] = True
            if via_edge is not None and v in via_edge:
                idx = via_edge[v]
                bw[i] = float(graph.bandwidth_bps[idx])
                lat[i] = float(graph.latency_s[idx])
        else:
            parent[i] = PS       # stranded stub; participate must be 0
    return AggTree(parent=tuple(int(p) for p in parent),
                   uplink_bw_bps=tuple(float(b) for b in bw),
                   uplink_latency_s=tuple(float(l) for l in lat),
                   reachable=tuple(bool(r) for r in reachable))
