"""Constellation-graph topology engine (graph → route → tree → aggregate).

The paper's motivating scenario is a satellite constellation with
inter-satellite links (ISLs). This subsystem generalizes the linear chain of
:mod:`repro.core.chain` to arbitrary connected graphs:

1. :mod:`repro.topo.graph` — constellation graph builders (Walker-delta /
   Walker-star planes, grid ISL meshes, random geometric graphs) with
   per-link bandwidth/latency attributes;
2. :mod:`repro.topo.routing` — shortest-path and bandwidth-aware
   spanning-tree extraction turning any graph + PS node into an aggregation
   tree, plus the cluster-aware router (``cluster_routed``: partition →
   intra-cluster trees + inter-cluster relay tree → a staged
   ``NestedTopology`` for ``repro.agg.compile_nested``);
3. :mod:`repro.topo.tree` — ``run_tree``, the level-scheduled generalization
   of ``run_chain`` to arbitrary trees (all five Algorithm 1–5 node steps,
   error feedback, and §V bit accounting preserved; a path graph is
   bit-exact to the chain).

Closed-form tree communication costs live in :mod:`repro.core.comm_cost`
(``*_tree`` variants); federated-simulator wiring (tree scenarios, relay
failure → re-rooting) in :mod:`repro.fed.topology` / :mod:`repro.fed.simulator`.
Trees (and chains, and graphs) compile into canonical padded level-schedule
plans via :mod:`repro.agg` — ``run_tree`` is a thin wrapper over
``compile_plan`` + ``execute`` there.
"""

from repro.topo.graph import (ConstellationGraph, grid_graph, path_graph,
                              random_geometric, star_graph, walker_delta,
                              walker_star)
from repro.topo.routing import (NestedTopology, cluster_routed,
                                extract_tree, partition_clusters,
                                shortest_path_tree, widest_path_tree)
from repro.topo.tree import AggTree, TreeResult, TreeSchedule, run_tree

__all__ = [
    "ConstellationGraph", "path_graph", "star_graph", "grid_graph",
    "random_geometric", "walker_delta", "walker_star",
    "shortest_path_tree", "widest_path_tree", "extract_tree",
    "NestedTopology", "cluster_routed", "partition_clusters",
    "AggTree", "TreeSchedule", "TreeResult", "run_tree",
]
