"""Constellation graph builders with per-link bandwidth/latency attributes.

A :class:`ConstellationGraph` is an undirected connected graph over nodes
``0..num_nodes-1`` where one node (``ps``) is the parameter server (a ground
station or gateway). All other nodes are FL clients. Edges model
inter-satellite links (ISLs) or ground links and carry ``bandwidth_bps`` and
``latency_s`` attributes used by the routing layer to pick aggregation trees.

Builders are deterministic (seeded where stochastic) and host-side numpy —
nothing here is traced; the jit boundary is :func:`repro.topo.tree.run_tree`.
"""

from __future__ import annotations

import dataclasses
from typing import Iterable, Sequence

import numpy as np

# Default link classes, loosely after LEO ISL literature (arXiv:2307.08346):
# intra-plane ISLs are stable & wide; inter-plane ISLs are narrower; the
# ground (PS) link is the scarcest.
INTRA_PLANE_BW = 200e6    # bits/s
INTER_PLANE_BW = 100e6
GROUND_BW = 50e6
ISL_LATENCY = 10e-3       # s, one hop
GROUND_LATENCY = 30e-3


@dataclasses.dataclass(frozen=True)
class ConstellationGraph:
    """Undirected graph with link attributes; node ``ps`` is the server.

    ``edges`` is [E, 2] int (u < v canonical order); ``bandwidth_bps`` and
    ``latency_s`` are [E] floats aligned with ``edges``.
    """

    num_nodes: int
    edges: np.ndarray
    bandwidth_bps: np.ndarray
    latency_s: np.ndarray
    ps: int = 0

    def __post_init__(self):
        e = np.asarray(self.edges, np.int64).reshape(-1, 2)
        e = np.sort(e, axis=1)
        object.__setattr__(self, "edges", e)
        object.__setattr__(
            self, "bandwidth_bps",
            np.broadcast_to(np.asarray(self.bandwidth_bps, np.float64),
                            (e.shape[0],)).copy())
        object.__setattr__(
            self, "latency_s",
            np.broadcast_to(np.asarray(self.latency_s, np.float64),
                            (e.shape[0],)).copy())
        if e.size and (e.min() < 0 or e.max() >= self.num_nodes):
            raise ValueError("edge endpoint out of range")
        if not 0 <= self.ps < self.num_nodes:
            raise ValueError(f"ps={self.ps} out of range")

    @property
    def num_clients(self) -> int:
        return self.num_nodes - 1

    def client_nodes(self) -> np.ndarray:
        """Graph node ids of the clients, in client-index order.

        Client ``i`` (the row index of the simulator's [K, d] arrays) is the
        i-th non-PS node in ascending node-id order.
        """
        return np.asarray([v for v in range(self.num_nodes) if v != self.ps],
                          np.int64)

    def adjacency(self, exclude: Iterable[int] = ()) -> list:
        """Adjacency list: ``adj[u] = [(v, edge_idx), ...]``.

        ``exclude`` drops nodes (dead relays) and their incident links.
        """
        dead = set(exclude)
        adj: list = [[] for _ in range(self.num_nodes)]
        for idx, (u, v) in enumerate(self.edges):
            u, v = int(u), int(v)
            if u in dead or v in dead:
                continue
            adj[u].append((v, idx))
            adj[v].append((u, idx))
        return adj

    def without_links(self, links: Iterable[tuple]) -> "ConstellationGraph":
        """Copy of the graph with the given ``(u, v)`` links removed.

        Link endpoints are canonicalized (order-insensitive); unknown links
        are ignored. This is the LEO link-outage primitive: a handover or
        occlusion drops an ISL while both satellites stay up (contrast
        ``adjacency(exclude=...)``, which drops whole nodes).
        """
        down = {(min(int(u), int(v)), max(int(u), int(v))) for u, v in links}
        keep = [i for i, (u, v) in enumerate(self.edges)
                if (int(u), int(v)) not in down]
        return ConstellationGraph(num_nodes=self.num_nodes,
                                  edges=self.edges[keep],
                                  bandwidth_bps=self.bandwidth_bps[keep],
                                  latency_s=self.latency_s[keep],
                                  ps=self.ps)

    def with_bandwidth_scaled(self, factor: float,
                              links: Iterable[tuple] = None
                              ) -> "ConstellationGraph":
        """Copy with link bandwidths multiplied by ``factor``.

        ``links`` restricts the scaling to the given ``(u, v)`` pairs
        (canonicalized; unknown pairs ignored); None scales every link.
        This is the bandwidth-degradation primitive: rain fade or a
        contended gateway shrinks capacity while the link stays up, so
        routing (widest-path) and bandwidth-aware Top-Q budgets shift.
        """
        if factor <= 0:
            raise ValueError("bandwidth factor must be positive")
        bw = self.bandwidth_bps.copy()
        if links is None:
            bw *= factor
        else:
            sel = {(min(int(u), int(v)), max(int(u), int(v)))
                   for u, v in links}
            for i, (u, v) in enumerate(self.edges):
                if (int(u), int(v)) in sel:
                    bw[i] *= factor
        return ConstellationGraph(num_nodes=self.num_nodes, edges=self.edges,
                                  bandwidth_bps=bw, latency_s=self.latency_s,
                                  ps=self.ps)

    def is_connected(self, exclude: Iterable[int] = ()) -> bool:
        dead = set(exclude)
        alive = [v for v in range(self.num_nodes) if v not in dead]
        if not alive:
            return True
        adj = self.adjacency(exclude)
        seen = {alive[0]}
        stack = [alive[0]]
        while stack:
            u = stack.pop()
            for v, _ in adj[u]:
                if v not in seen:
                    seen.add(v)
                    stack.append(v)
        return len(seen) == len(alive)


def _build(num_nodes: int, edge_list: Sequence[tuple], ps: int
           ) -> ConstellationGraph:
    """edge_list entries: (u, v, bandwidth, latency). De-dups parallel edges
    (keeps the best bandwidth)."""
    best: dict = {}
    for u, v, bw, lat in edge_list:
        key = (min(u, v), max(u, v))
        if key not in best or bw > best[key][0]:
            best[key] = (bw, lat)
    keys = sorted(best)
    edges = np.asarray(keys, np.int64).reshape(-1, 2)
    bw = np.asarray([best[k][0] for k in keys], np.float64)
    lat = np.asarray([best[k][1] for k in keys], np.float64)
    return ConstellationGraph(num_nodes=num_nodes, edges=edges,
                              bandwidth_bps=bw, latency_s=lat, ps=ps)


# ---------------------------------------------------------------------------
# Elementary topologies (tests / baselines)
# ---------------------------------------------------------------------------

def path_graph(num_clients: int, *, bandwidth_bps: float = INTRA_PLANE_BW,
               latency_s: float = ISL_LATENCY) -> ConstellationGraph:
    """PS — c0 — c1 — … — c(K−1): the paper's K-hop chain as a graph.

    Node 0 is the PS; node ``i+1`` is client ``i`` (paper client k = i+1,
    matching ``run_chain``'s row indexing).
    """
    k = num_clients
    edges = [(i, i + 1, bandwidth_bps, latency_s) for i in range(k)]
    return _build(k + 1, edges, ps=0)


def star_graph(num_clients: int, *, bandwidth_bps: float = GROUND_BW,
               latency_s: float = GROUND_LATENCY) -> ConstellationGraph:
    """Every client directly linked to the PS (classic FedAvg topology)."""
    k = num_clients
    edges = [(0, i + 1, bandwidth_bps, latency_s) for i in range(k)]
    return _build(k + 1, edges, ps=0)


def grid_graph(rows: int, cols: int, *,
               bandwidth_bps: float = INTER_PLANE_BW,
               latency_s: float = ISL_LATENCY,
               ground_bw: float = GROUND_BW,
               ground_latency: float = GROUND_LATENCY) -> ConstellationGraph:
    """rows×cols ISL mesh; PS (node 0) uplinks to the (0, 0) corner sat.

    Satellite (r, c) is node ``1 + r*cols + c``.
    """
    def nid(r, c):
        return 1 + r * cols + c

    edges = [(0, nid(0, 0), ground_bw, ground_latency)]
    for r in range(rows):
        for c in range(cols):
            if c + 1 < cols:
                edges.append((nid(r, c), nid(r, c + 1),
                              bandwidth_bps, latency_s))
            if r + 1 < rows:
                edges.append((nid(r, c), nid(r + 1, c),
                              bandwidth_bps, latency_s))
    return _build(1 + rows * cols, edges, ps=0)


# ---------------------------------------------------------------------------
# Walker constellations
# ---------------------------------------------------------------------------

def _walker(num_planes: int, sats_per_plane: int, *, close_seam: bool,
            intra_bw: float, inter_bw: float, ground_bw: float,
            gateways: Sequence[int]) -> ConstellationGraph:
    """Shared Walker builder. Node 0 = PS (ground station); satellite j of
    plane p is node ``1 + p*sats_per_plane + j``. Intra-plane ISLs form a
    ring within each plane; inter-plane ISLs connect same-slot satellites of
    adjacent planes (wrapping plane P−1 → 0 only when ``close_seam``)."""
    P, S = num_planes, sats_per_plane
    if P < 1 or S < 2:
        raise ValueError("need ≥1 plane of ≥2 satellites")

    def nid(p, j):
        return 1 + p * S + j

    edges = []
    for p in range(P):
        for j in range(S):
            edges.append((nid(p, j), nid(p, (j + 1) % S),
                          intra_bw, ISL_LATENCY))
    pmax = P if close_seam else P - 1
    for p in range(pmax):
        for j in range(S):
            edges.append((nid(p, j), nid((p + 1) % P, j),
                          inter_bw, ISL_LATENCY))
    for g in gateways:
        if not 1 <= g <= P * S:
            raise ValueError(f"gateway node {g} out of range")
        edges.append((0, g, ground_bw, GROUND_LATENCY))
    return _build(1 + P * S, edges, ps=0)


def walker_delta(num_planes: int, sats_per_plane: int, *,
                 intra_bw: float = INTRA_PLANE_BW,
                 inter_bw: float = INTER_PLANE_BW,
                 ground_bw: float = GROUND_BW,
                 gateways: Sequence[int] = (1,)) -> ConstellationGraph:
    """Walker-delta (e.g. Starlink-like): inter-plane links wrap around —
    the plane graph itself is a ring, so the ISL mesh is a torus."""
    return _walker(num_planes, sats_per_plane, close_seam=True,
                   intra_bw=intra_bw, inter_bw=inter_bw, ground_bw=ground_bw,
                   gateways=gateways)


def walker_star(num_planes: int, sats_per_plane: int, *,
                intra_bw: float = INTRA_PLANE_BW,
                inter_bw: float = INTER_PLANE_BW,
                ground_bw: float = GROUND_BW,
                gateways: Sequence[int] = (1,)) -> ConstellationGraph:
    """Walker-star (e.g. Iridium-like): polar planes spanning ~180° — no
    inter-plane ISLs across the counter-rotating seam."""
    return _walker(num_planes, sats_per_plane, close_seam=False,
                   intra_bw=intra_bw, inter_bw=inter_bw, ground_bw=ground_bw,
                   gateways=gateways)


# ---------------------------------------------------------------------------
# Random geometric graphs (ad-hoc / aerial scenarios)
# ---------------------------------------------------------------------------

def random_geometric(num_clients: int, radius: float = 0.35, *,
                     seed: int = 0, bandwidth_bps: float = INTER_PLANE_BW,
                     latency_s: float = ISL_LATENCY) -> ConstellationGraph:
    """Random geometric graph on the unit square; PS at the node nearest the
    centroid. Link bandwidth decays with squared distance (free-space-loss
    flavored); the radius is grown until the graph is connected so the
    builder always returns a usable topology.
    """
    rng = np.random.default_rng(seed)
    pts = rng.uniform(size=(num_clients + 1, 2))
    ps = int(np.argmin(np.linalg.norm(pts - pts.mean(0), axis=1)))

    r = radius
    for _ in range(32):
        edges = []
        for u in range(num_clients + 1):
            for v in range(u + 1, num_clients + 1):
                dist = float(np.linalg.norm(pts[u] - pts[v]))
                if dist <= r:
                    bw = bandwidth_bps / (1.0 + (dist / max(r, 1e-9)) ** 2)
                    edges.append((u, v, bw, latency_s * (0.5 + dist)))
        g = _build(num_clients + 1, edges, ps=ps) if edges else None
        if g is not None and g.is_connected():
            return g
        r *= 1.3
    raise RuntimeError("could not build a connected geometric graph")
