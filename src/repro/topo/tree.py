"""Tree-structured sparse incremental aggregation (``run_chain`` → trees).

An :class:`AggTree` is an aggregation tree over clients ``0..K-1`` rooted at
the parameter server (parent sentinel :data:`PS`). Aggregation semantics are
the natural generalization of the paper's chain recursion: node k receives
the *sum* of its children's partial aggregates γ_c as its incoming γ, applies
the configured Algorithm 1–5 node step (EF included), and forwards γ_k to
its parent; the PS receives the sum over its children.

On a path graph this is exactly the chain: one child per node, incoming sum
degenerates to pass-through, and :func:`run_tree` is **bit-exact** against
:func:`repro.core.chain.run_chain` for all five algorithms (tested).

Execution: nodes are grouped by depth into levels; a ``lax.scan`` walks
levels deepest-first while a ``vmap`` over the level width runs every node of
the level concurrently — the tree-parallel analogue of the chain's
``reverse=True`` scan (wall-clock O(depth) node steps instead of O(K)).
:func:`run_tree` is a thin wrapper over :mod:`repro.agg` — the level
schedule becomes an :class:`~repro.agg.plan.AggPlan` whose arrays are traced
jit arguments, so jit specializations are keyed by the padded ``(L, W)``
shape, not by tree identity: rebuilding after a relay failure reuses the
compiled round whenever the healed schedule fits the same shape.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional, Sequence

import jax
import numpy as np

from repro.core.algorithms import AggConfig, HopStats

Array = jax.Array

#: ``parent[i] == PS`` marks a client whose parent is the parameter server.
PS = -1


@dataclasses.dataclass(frozen=True)
class AggTree:
    """Aggregation tree over clients 0..K−1 (hashable → jit-cache friendly).

    ``parent[i]`` is the client index of i's parent, or :data:`PS`.
    ``uplink_bw_bps`` / ``uplink_latency_s`` describe client i's link to its
    parent (0 when unknown); ``reachable[i]`` is False for stranded stubs
    parked at the PS after a partition (their ``participate`` must be 0).
    """

    parent: tuple
    uplink_bw_bps: Optional[tuple] = None
    uplink_latency_s: Optional[tuple] = None
    reachable: Optional[tuple] = None

    def __post_init__(self):
        # compute depths eagerly: validates acyclicity/range at build time
        # and avoids caching (trees are built per round under failures)
        k = len(self.parent)
        depth = [0] * k
        for i, p in enumerate(self.parent):
            d, node, hops = 1, i, 0
            while self.parent[node] != PS:
                node = self.parent[node]
                if not 0 <= node < k:
                    raise ValueError(f"parent index {node} out of range")
                d += 1
                hops += 1
                if hops > k:
                    raise ValueError("cycle in aggregation tree")
            depth[i] = d
        object.__setattr__(self, "_depth", tuple(depth))

    @property
    def num_clients(self) -> int:
        return len(self.parent)

    def depths(self) -> np.ndarray:
        """depth[i] = #links from client i to the PS (≥ 1)."""
        return np.asarray(self._depth, np.int64)

    def children(self) -> list:
        """children[i] = client indices whose parent is i."""
        ch: list = [[] for _ in range(self.num_clients)]
        for i, p in enumerate(self.parent):
            if p != PS:
                ch[p].append(i)
        return ch

    def ps_children(self) -> list:
        return [i for i, p in enumerate(self.parent) if p == PS]

    def subtree_sizes(self) -> np.ndarray:
        """size[i] = #clients in the subtree rooted at i (incl. i itself).

        On a path graph this is (K, K−1, …, 1) from the PS outward — the
        per-hop aggregate counts of the chain cost model.
        """
        k = self.num_clients
        size = np.ones((k,), np.int64)
        order = np.argsort(-self.depths())        # deepest first
        for i in order:
            p = self.parent[i]
            if p != PS:
                size[p] += size[i]
        return size

    def max_depth(self) -> int:
        return int(self.depths().max()) if self.num_clients else 0


def path_tree(num_clients: int) -> AggTree:
    """The paper chain as a tree: client 0 at the PS, i's parent is i−1."""
    return AggTree(parent=tuple([PS] + list(range(num_clients - 1))))


def star_tree(num_clients: int) -> AggTree:
    """Every client a direct child of the PS (depth-1 FedAvg topology)."""
    return AggTree(parent=(PS,) * num_clients)


# ---------------------------------------------------------------------------
# Level schedule
# ---------------------------------------------------------------------------

class TreeSchedule(NamedTuple):
    """Static level schedule: L levels × W slots, deepest level first.

    ``node_id[l, w]`` is the client run in slot w of level l (padding slots
    hold K, a zero dummy row); ``slot_mask`` is 1.0 for real slots;
    ``parent_row[l, w]`` is the inbox row receiving that slot's γ (client
    index, K for the PS, K+1 trash row for padding). ``flat_pos[k]`` is
    client k's flattened (level, slot) position, for mapping scan outputs
    back to client index order.
    """

    node_id: np.ndarray       # [L, W] int32
    slot_mask: np.ndarray     # [L, W] float32
    parent_row: np.ndarray    # [L, W] int32
    flat_pos: np.ndarray      # [K] int64


def build_schedule(tree: AggTree) -> TreeSchedule:
    k = tree.num_clients
    depth = tree.depths()
    lmax = tree.max_depth()
    levels = [np.where(depth == l)[0] for l in range(lmax, 0, -1)]
    w = max((len(lv) for lv in levels), default=1)

    node_id = np.full((lmax, w), k, np.int32)             # pad → dummy row K
    slot_mask = np.zeros((lmax, w), np.float32)
    parent_row = np.full((lmax, w), k + 1, np.int32)      # pad → trash row
    flat_pos = np.zeros((k,), np.int64)
    for li, members in enumerate(levels):
        for wi, node in enumerate(members):
            node_id[li, wi] = node
            slot_mask[li, wi] = 1.0
            p = tree.parent[node]
            parent_row[li, wi] = k if p == PS else p
            flat_pos[node] = li * w + wi
    return TreeSchedule(node_id=node_id, slot_mask=slot_mask,
                        parent_row=parent_row, flat_pos=flat_pos)


# ---------------------------------------------------------------------------
# run_tree
# ---------------------------------------------------------------------------

class TreeResult(NamedTuple):
    aggregate: Array      # what the PS receives (Σ over its children), [d]
    e_new: Array          # updated EF memory, [K, d] (client index order)
    stats: HopStats       # per-hop stats, leaves [K] (client index order)


def run_tree(
    cfg: AggConfig,
    tree: AggTree,
    grads: Array,                  # [K, d] per-client effective gradients g_k
    e: Array,                      # [K, d] EF memory
    weights: Array,                # [K]    D_k
    *,
    global_mask: Optional[Array] = None,   # [d] TCS mask m^t (TC algorithms)
    participate: Optional[Array] = None,   # [K] 0/1 straggler mask
) -> TreeResult:
    """One aggregation round over an arbitrary tree (chain generalization).

    Same contract as :func:`repro.core.chain.run_chain` plus the ``tree``
    argument; ``run_tree(cfg, path_tree(K), ...)`` is bit-exact to
    ``run_chain(cfg, ...)``.

    Thin wrapper over the plan/execute API (:mod:`repro.agg`): the tree is
    compiled to its canonical level-schedule plan and run through the single
    ``execute`` entry point. Note ``execute`` folds the tree's stranded-stub
    mask (``reachable``) into ``participate`` automatically.
    """
    # function-level import: repro.agg.plan imports AggTree from this module
    from repro.agg.plan import compile_plan, execute

    res = execute(cfg, compile_plan(tree), grads, e, weights,
                  global_mask=global_mask, participate=participate)
    return TreeResult(aggregate=res.aggregate, e_new=res.e_new,
                      stats=res.stats)


# ---------------------------------------------------------------------------
# Latency model (per-link attributes → round time)
# ---------------------------------------------------------------------------

def round_latency_s(tree: AggTree, bits_per_hop: Sequence[float]) -> float:
    """Critical-path aggregation latency of one round.

    Node i becomes ready at ``max(children ready) + serialize + propagate``
    over its uplink; the round ends when the last PS child arrives. Uses the
    tree's per-link attributes (zero-bandwidth stubs are skipped).
    """
    if tree.uplink_bw_bps is None or tree.uplink_latency_s is None:
        raise ValueError("tree has no link attributes (built by hand?)")
    ready = [0.0] * tree.num_clients
    order = np.argsort(-tree.depths())
    for i in order:
        i = int(i)
        bw = tree.uplink_bw_bps[i]
        if bw <= 0:
            continue
        tx = float(bits_per_hop[i]) / bw + tree.uplink_latency_s[i]
        ready[i] += tx
        p = tree.parent[i]
        if p != PS:
            ready[p] = max(ready[p], ready[i])
    ps_kids = [i for i in tree.ps_children()
               if (tree.uplink_bw_bps[i] or 0) > 0]
    return max((ready[i] for i in ps_kids), default=0.0)
