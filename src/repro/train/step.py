"""The assembled distributed train step (and serve steps).

One jit, three phases (DESIGN §5):

  1. per-client grads — shard_map manual over the DP axes (pod, data), the
     model axis stays *auto* so GSPMD runs TP inside; the loss is averaged
     over the local shard only, so gradients come out per-client
     (stacked [K_dp, …]), NOT psum'd;
  2. sparse incremental aggregation — the rotated ring (core/ring.py) over
     the combined (pod, data) ring — the paper's K-client multi-hop chain,
     one chain per segment — operating in the *shard-aligned flat space*
     (core/flat_layout.py): gradients are flattened locally inside the
     manual shard_map, so no resharding collectives ever touch the
     gradient-sized buffers (EXPERIMENTS §Perf it.4);
  3. ZeRO optimizer — flat fp32 master, fully sharded, elementwise update;
     the downlink shard_map rebuilds the param pytree (dp all-gather per
     model column = the paper's w^{t+1} broadcast, counted separately from
     the uplink cost model).

``build_serve_step``/``build_prefill_step`` produce the inference
entrypoints the decode/prefill dry-run cells lower.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.configs.base import ModelConfig
from repro.core import ring as ring_mod
from repro.core import sparsify as sp
from repro.core.algorithms import AggConfig
from repro.core.flat_layout import FlatLayout
from repro.models import model as model_mod
from repro.models import partition
from repro.optim import optimizers as opt_mod
from repro.optim.schedule import lr_schedule
from repro.train.state import TrainConfig, TrainState

Array = jax.Array


def dp_axes(mesh) -> tuple:
    return partition.batch_axes(mesh)


def dp_size(mesh) -> int:
    n = 1
    for a in dp_axes(mesh):
        n *= mesh.shape[a]
    return n


def model_size(mesh) -> int:
    return mesh.shape.get("model", 1)


def flat_spec(mesh) -> P:
    """Sharding of the flat master/opt/aggregate: model-major, then ring."""
    return P(("model",) + dp_axes(mesh))


# ---------------------------------------------------------------------------
# Nested (staged) aggregation topology plumbing
# ---------------------------------------------------------------------------

def nested_stage_axes(mesh, n_stages: int) -> tuple:
    """Per-stage mesh axes for a nested plan over this mesh's DP ring.

    Stage 0 runs on the *minor* DP axis (client k = pod·K_d + data ⇒
    mesh-aligned clusters), each later stage one axis up; the last stage
    takes whatever DP axes remain as one flattened ring. For the
    (pod, data) production mesh and a 2-stage plan this is
    ``("data", "pod")`` — exactly ``core/hierarchical.py``'s mapping.
    """
    dp = dp_axes(mesh)
    if len(dp) < n_stages:
        raise ValueError(f"a {n_stages}-stage nested plan needs ≥"
                         f"{n_stages} DP axes; mesh has {dp}")
    axes = [dp[len(dp) - 1 - s] for s in range(n_stages - 1)]
    rest = dp[:len(dp) - (n_stages - 1)]
    axes.append(rest[0] if len(rest) == 1 else tuple(rest))
    return tuple(axes)


def _stage_order(axes) -> tuple:
    """Flatten per-stage axes into one name tuple, stage order."""
    out: list = []
    for a in axes:
        out.extend(a if isinstance(a, tuple) else (a,))
    return tuple(out)


def nested_flat_spec(mesh, axes) -> P:
    """Flat master/opt/aggregate sharding under staged aggregation: rank
    coords own [stage-0 segment, stage-1 sub-segment, …] — the dp axes in
    *stage* order (reversed), the hierarchical P(("model","data","pod"))
    layout generalized."""
    return P(("model",) + _stage_order(axes))


def _resolve_topology(mesh, topology):
    """→ (flat topology | None, NestedPlan | None, stage axes | None)."""
    from repro.agg.nested import NestedPlan, compile_nested, pod_ring_nested

    nested = None
    if isinstance(topology, str) and topology == "hierarchical":
        dp = dp_axes(mesh)
        if len(dp) < 2:
            raise ValueError(f"'hierarchical' needs ≥2 DP axes (pod, "
                             f"data); mesh has {dp}")
        k_minor = mesh.shape[dp[-1]]
        nested = pod_ring_nested(dp_size(mesh) // k_minor, k_minor)
    elif isinstance(topology, NestedPlan):
        nested = topology
    elif hasattr(topology, "nested_stages"):
        nested = compile_nested(topology, num_clients=dp_size(mesh))
    if nested is None:
        return topology, None, None
    if nested.num_clients != dp_size(mesh):
        raise ValueError(f"nested topology has {nested.num_clients} "
                         f"clients but the mesh provides "
                         f"{dp_size(mesh)} DP ranks")
    return None, nested, nested_stage_axes(mesh, nested.num_stages)


def _stage_ef_dims(mesh, axes, d_flat: int) -> tuple:
    """Flat length of each upper EF tier: stage s's tier covers one
    stage-(s−1) output segment per rank column."""
    dims = []
    prefix = 1
    for a in axes[:-1]:
        names = a if isinstance(a, tuple) else (a,)
        for n in names:
            prefix *= mesh.shape[n]
        dims.append(d_flat // prefix)
    return tuple(dims)


@functools.lru_cache(maxsize=None)
def _layout_cached(cfg: ModelConfig, mesh) -> FlatLayout:
    template = model_mod.param_specs(cfg)
    return FlatLayout(template, partition.param_pspecs(cfg, mesh), mesh)


def make_layout(cfg: ModelConfig, mesh) -> FlatLayout:
    try:
        return _layout_cached(cfg, mesh)
    except TypeError:                      # unhashable mesh fallback
        template = model_mod.param_specs(cfg)
        return FlatLayout(template, partition.param_pspecs(cfg, mesh), mesh)


def global_q(tc: TrainConfig, d_flat: int) -> int:
    return max(1, int(tc.q_frac * d_flat))


def _segment_agg_cfg(tc: TrainConfig, mesh, d_flat: int) -> AggConfig:
    """Per-segment AggConfig: the global budget split over all segments."""
    n_segments = dp_size(mesh) * model_size(mesh)
    q = global_q(tc, d_flat)
    q_seg = ring_mod.segment_budget(q, n_segments)
    kw = dict(q=q_seg)
    if tc.needs_tcs():
        if q_seg == 0:
            # global budget smaller than the segment count: nothing to
            # split — the sub-budgets must not re-inflate §V bits
            kw.update(q_local=0, q_global=0)
        else:
            ql = max(1, round(q_seg * tc.agg.q_local / max(tc.agg.q, 1))
                     ) if tc.agg.q_local else max(1, q_seg // 10)
            kw.update(q_local=ql, q_global=max(q_seg - ql, 1))
    return dataclasses.replace(tc.agg, **kw)


def _model_axis_index(mesh):
    if "model" in mesh.axis_names:
        return jax.lax.axis_index("model")
    return jnp.int32(0)


# ---------------------------------------------------------------------------
# State init
# ---------------------------------------------------------------------------

def _master_from_params(cfg: ModelConfig, mesh, layout: FlatLayout, params,
                        order=None):
    """Flat fp32 master from the param pytree (shard-aligned, in-shard_map).

    ``order`` overrides the rank→slice mapping (a flattened axis-name
    tuple): nested topologies own the flat space in stage order (reversed
    dp), see :func:`nested_flat_spec`.
    """
    dp = dp_axes(mesh)
    k_dp = dp_size(mesh)
    seg = layout.n_local // k_dp
    manual = set(mesh.axis_names)
    idx_axes = dp if order is None else order
    out_spec = (flat_spec(mesh) if order is None
                else P(("model",) + tuple(order)))

    def fn(p):
        m_idx = _model_axis_index(mesh)
        col = layout.local_flatten(jax.tree.leaves(p), m_idx, jnp.float32)
        if k_dp > 1:
            r = jax.lax.axis_index(idx_axes)
            return jax.lax.dynamic_slice(col, (r * seg,), (seg,))
        return col

    return compat.shard_map(
        fn, mesh=mesh, in_specs=(layout.param_in_specs(),),
        out_specs=out_spec, axis_names=manual,
    )(params)


def init_state(cfg: ModelConfig, tc: TrainConfig, mesh, rng,
               topology: Any = None, cohorts: int = 1) -> TrainState:
    """Materializing init (small models / tests). Dry-run uses eval_shape.

    ``topology`` must match the one later given to
    :func:`build_train_step`: a nested topology adds the upper EF tiers
    (``stage_ef``) and lays the flat master out in stage order.
    ``cohorts=B`` stacks B independently-initialized tenant states (one
    rng split each) with a leading cohort axis on every leaf — the state
    :func:`build_train_step` with the same ``cohorts`` consumes.
    """
    if cohorts > 1:
        if _resolve_topology(mesh, topology)[1] is not None:
            raise ValueError("cohort batches run flat topologies; nested "
                             "plans train per tenant")
        states = [init_state(cfg, tc, mesh, k, topology)
                  for k in jax.random.split(rng, cohorts)]
        return jax.tree.map(lambda *xs: jnp.stack(xs), *states)
    layout = make_layout(cfg, mesh)
    k_dp = dp_size(mesh)
    _, nested, n_axes = _resolve_topology(mesh, topology)
    params = model_mod.init_params(cfg, rng)
    order = None if nested is None else _stage_order(n_axes)
    master = _master_from_params(cfg, mesh, layout, params, order=order)
    opt = opt_mod.init_flat(tc.opt, layout.d_flat, like=master)
    ef = jnp.zeros((k_dp, layout.d_flat), jnp.dtype(tc.ef_dtype))
    stage_ef = None
    if nested is not None:
        stage_ef = tuple(
            jnp.zeros((k_dp, dim), jnp.dtype(tc.ef_dtype))
            for dim in _stage_ef_dims(mesh, n_axes, layout.d_flat))
    tcs_prev = None
    if tc.needs_tcs():
        tcs_prev = jax.tree.map(lambda p: p.astype(jnp.dtype(tc.agg_dtype)),
                                params)
    return TrainState(step=jnp.int32(0), params=params, master=master,
                      opt=opt, ef=ef, tcs_prev=tcs_prev, stage_ef=stage_ef)


def _cohort_spec(spec: P) -> P:
    """Prepend an unsharded leading cohort axis to a PartitionSpec."""
    return P(*((None,) + tuple(spec)))


def state_shardings(cfg: ModelConfig, tc: TrainConfig, mesh,
                    topology: Any = None, cohorts: int = 1):
    """NamedSharding pytree matching TrainState (pass the same
    ``topology``/``cohorts`` as :func:`build_train_step` — cohort batches
    keep every per-tenant leaf replicated along the leading cohort
    axis)."""
    _, nested, n_axes = _resolve_topology(mesh, topology)
    fs = flat_spec(mesh) if nested is None else nested_flat_spec(mesh,
                                                                 n_axes)
    dp = dp_axes(mesh)
    coh = _cohort_spec if cohorts > 1 else (lambda s: s)
    ns = lambda s: NamedSharding(mesh, coh(s))
    p_specs = jax.tree.map(ns, partition.param_pspecs(cfg, mesh),
                           is_leaf=lambda x: isinstance(x, P))
    opt_m = None if tc.opt.name == "sgd" else ns(fs)
    opt_v = ns(fs) if tc.opt.name == "adamw" else None
    tcs = (jax.tree.map(ns, partition.param_pspecs(cfg, mesh),
                        is_leaf=lambda x: isinstance(x, P))
           if tc.needs_tcs() else None)
    stage_ef = None
    if nested is not None:
        stage_ef = tuple(ns(P(dp, "model"))
                         for _ in range(nested.num_stages - 1))
    return TrainState(
        step=ns(P()),
        params=p_specs,
        master=ns(fs),
        opt=opt_mod.FlatOptState(step=ns(P()), m=opt_m, v=opt_v),
        ef=ns(P(dp, "model")),
        tcs_prev=tcs,
        stage_ef=stage_ef,
    )


# ---------------------------------------------------------------------------
# Train step
# ---------------------------------------------------------------------------

def build_train_step(cfg: ModelConfig, tc: TrainConfig, mesh,
                     topology: Any = None, telemetry: bool = False,
                     cohorts: int = 1):
    """Returns train_step(state, batch) → (state, metrics). jit-ready.

    ``cohorts=B`` builds the multi-tenant batched step: ``state`` carries
    a leading cohort axis on every leaf (:func:`init_state` with the same
    ``cohorts``), ``batch`` leaves carry ``[B, global_batch, …]``, and the
    B tenants share one compiled step — phase 1 vmaps the per-client
    grads, phase 2 rides
    :func:`repro.agg.device.run_plan_segments_batched` (one ppermute
    wavefront per level for all cohorts), phase 3 vmaps the flat
    optimizer. Metrics leaves come back per cohort (``[B]``). Flat
    topologies only; per cohort the math is the sequential step's.

    ``telemetry=True`` adds the fault-exposure metrics the trace
    subsystem records (``ef_mass`` = Σ_k ‖e_k‖₁ over every EF tier,
    ``ef_dead_mass`` = :func:`repro.runtime.fault.dead_banked_mass` over
    the round's non-participants); off by default so the historic metrics
    pytree — and the compiled step — are unchanged.

    ``topology`` selects the aggregation route over the K_dp clients:
    ``None`` keeps the rotated ring (the paper chain, bit-exact to the
    historic path), everything else — an :class:`repro.agg.AggPlan`, an
    ``AggTree``, a chain order, or a ``ConstellationGraph`` — is compiled
    via :func:`repro.agg.compile_plan` and lowered onto the same shard_map
    ring by :func:`repro.agg.device.run_plan_segments_local`, so routed
    constellation trees run multi-device with the ring's wire format and
    §V accounting.

    Nested (staged) topologies — ``"hierarchical"``, a
    :class:`~repro.agg.nested.NestedPlan`, or a routed
    :class:`~repro.topo.routing.NestedTopology` — lower through
    :func:`repro.agg.device.run_nested_segments_local` instead: stage 0
    aggregates on the minor DP axis (pod-internal ICI), later stages
    relay per-cluster partials up the remaining axes (pod-seam DCI), the
    upper EF tiers persist in ``state.stage_ef``, and the flat
    master/optimizer own the stage-order layout
    (:func:`nested_flat_spec`) — pass the same ``topology`` to
    :func:`init_state`/:func:`state_shardings`. Metrics gain
    ``agg_bits_relay``, the last stage's (scarce-link) §V bits.
    """
    from repro.agg.device import (ring_chain_plan,
                                  run_nested_segments_local,
                                  run_plan_segments_batched,
                                  run_plan_segments_local)
    from repro.agg.plan import AggPlan, compile_plan

    layout = make_layout(cfg, mesh)
    dp = dp_axes(mesh)
    k_dp = dp_size(mesh)
    seg = layout.n_local // k_dp
    agg_cfg = _segment_agg_cfg(tc, mesh, layout.d_flat)
    _, nested_plan, n_axes = _resolve_topology(mesh, topology)
    if cohorts > 1 and nested_plan is not None:
        raise ValueError("cohort batches run flat topologies; nested "
                         "plans train per tenant")
    if nested_plan is not None:
        agg_plan = nested_plan
        fs = nested_flat_spec(mesh, n_axes)
        gather_axes = _stage_order(n_axes)
    else:
        if topology is None:
            agg_plan = ring_chain_plan(k_dp)
        elif isinstance(topology, AggPlan):
            agg_plan = topology
        else:
            agg_plan = compile_plan(topology, num_clients=k_dp)
        if agg_plan.num_clients != k_dp:
            raise ValueError(f"topology has {agg_plan.num_clients} clients "
                             f"but the mesh provides {k_dp} DP ranks")
        fs = flat_spec(mesh)
        gather_axes = dp
    agg_dt = jnp.dtype(tc.agg_dtype)
    manual_axes = set(mesh.axis_names)
    needs_tcs = tc.needs_tcs()
    qg_total = 0
    if needs_tcs:
        qg_total = max(1, int(
            global_q(tc, layout.d_flat) * agg_cfg.q_global
            / max(agg_cfg.q_global + agg_cfg.q_local, 1)))

    # SSM/hybrid params are model-replicated (mixed-group in_proj; DESIGN
    # §5) — without help the TP axis recomputes every mamba block M×
    # (measured: 16× FLOPs on mamba2-130m, EXPERIMENTS §Perf it.3). Shard
    # the local batch over `model` instead: the TP axis becomes a second
    # DP axis for compute; the ring in_specs then insert one model-axis
    # all-reduce per grad leaf (2·|grads| wire ≪ 16× compute).
    # tc.fsdp_compute extends the same layout to dense archs (weights stay
    # model-sharded → GSPMD gathers them per layer, FSDP-style).
    batch_over_model = cfg.family in ("ssm", "hybrid") or tc.fsdp_compute

    # ---- phase 1: per-client gradients ------------------------------------
    def per_client(params, batch):
        if batch_over_model and "model" in mesh.axis_names:
            m = mesh.shape["model"]
            batch = jax.tree.map(
                lambda x: jax.lax.with_sharding_constraint(
                    x, P("model", *([None] * (x.ndim - 1))))
                if x.shape[0] % m == 0 else x, batch)

        def local_loss(p):
            return model_mod.loss_fn(cfg, p, batch)
        (loss, metrics), grads = jax.value_and_grad(
            local_loss, has_aux=True)(params)
        loss = jax.lax.pmean(loss, dp)
        grads = jax.tree.map(lambda g: g[None], grads)   # stack client axis
        return grads, loss

    # ---- phase 2: sparse incremental aggregation (flat, local layout) -----
    def _col_and_mask(grads_tree, params_tree, prev_tree):
        m_idx = _model_axis_index(mesh)
        g_leaves = [l[0] for l in jax.tree.leaves(grads_tree)]
        col = layout.local_flatten(g_leaves, m_idx, agg_dt)

        mask_col = None
        if needs_tcs:
            p_col = layout.local_flatten(jax.tree.leaves(params_tree),
                                         m_idx, jnp.float32)
            q_col = layout.local_flatten(jax.tree.leaves(prev_tree),
                                         m_idx, jnp.float32)
            delta = p_col - q_col
            # identical global threshold on every column: counts psum over
            # `model` only (columns partition coordinates; dp replicates).
            # tau_impl="hist" collapses the search to ONE psum'd histogram
            # (D2, F) instead of hist_rounds sequential count+psum rounds —
            # fewer collective round-trips on the device path, same τ bits.
            axis = "model" if "model" in mesh.axis_names else None
            tau_g = sp.threshold_for_topq(
                delta, qg_total, branch=agg_cfg.hist_branch,
                rounds=agg_cfg.hist_rounds, axis_name=axis,
                tau_impl=agg_cfg.tau_impl)
            mask_col = jnp.where(jnp.any(delta != 0),
                                 (jnp.abs(delta) >= tau_g).astype(agg_dt),
                                 jnp.zeros_like(delta, agg_dt))
        return col, mask_col

    def ring_fn(grads_tree, ef_l, w_l, part_l, params_tree, prev_tree):
        col, mask_col = _col_and_mask(grads_tree, params_tree, prev_tree)
        final, ef_new, stats = run_plan_segments_local(
            agg_cfg, agg_plan, col, ef_l[0], w_l[0], axis=dp,
            global_mask_local=mask_col, participate=part_l[0],
            transport="static")
        stats = jax.tree.map(
            lambda s: jax.lax.psum(s, tuple(manual_axes)), stats)
        return final, ef_new[None], stats

    def nested_ring_fn(grads_tree, ef_l, se_l, w_l, part_l, params_tree,
                       prev_tree):
        col, mask_col = _col_and_mask(grads_tree, params_tree, prev_tree)
        final, ef_new, se_new, sts = run_nested_segments_local(
            agg_cfg, agg_plan, col, ef_l[0],
            tuple(x[0] for x in se_l), w_l[0], axes=n_axes,
            global_mask_local=mask_col, participate=part_l[0])
        total = ring_mod.RingStats(
            bits=sum(s.bits for s in sts),
            nnz=sum(s.nnz for s in sts),
            err_sq=sum(s.err_sq for s in sts))
        total, relay_bits = jax.tree.map(
            lambda s: jax.lax.psum(s, tuple(manual_axes)),
            (total, sts[-1].bits))
        return (final, ef_new[None], tuple(x[None] for x in se_new),
                total, relay_bits)

    # ---- phase 3b: downlink (flat master → param pytree) -------------------
    def downlink_fn(master_l):
        m_idx = _model_axis_index(mesh)
        # nested topologies own the flat space in stage order — gather in
        # that order so the column reassembles coordinate-contiguously
        col = (jax.lax.all_gather(master_l, gather_axes, axis=0, tiled=True)
               if k_dp > 1 else master_l)
        leaves = layout.local_unflatten(col, m_idx)
        return layout.treedef.unflatten(leaves)

    empty_param_specs = jax.tree.map(lambda _: P(), model_mod.param_specs(cfg))

    def train_step(state: TrainState, batch: dict):
        batch = dict(batch)
        weights = batch.pop("weights", None)
        participate = batch.pop("participate", None)
        if weights is None:
            weights = jnp.full((k_dp,), 1.0 / k_dp, jnp.float32)
        if participate is None:
            participate = jnp.ones((k_dp,), jnp.float32)

        # phase 1 — per-client grads (model axis auto inside)
        grads_stacked, loss = compat.shard_map(
            per_client,
            mesh=mesh,
            in_specs=(jax.tree.map(lambda _: P(), state.params),
                      jax.tree.map(lambda l: P(dp, *([None] * (l.ndim - 1))),
                                   batch)),
            out_specs=(jax.tree.map(
                lambda l: P(dp, *([None] * l.ndim)), state.params), P()),
            axis_names=set(dp),
        )(state.params, batch)

        # phase 2 — ring aggregation (manual over every axis; the in_specs
        # reshard grads to their param-aligned shardings, which is also the
        # model-axis grad all-reduce for model-replicated leaves)
        params_in = state.params
        prev_in = state.tcs_prev if needs_tcs else state.params
        stats_specs = jax.tree.map(lambda _: P(),
                                   ring_mod.RingStats(0., 0., 0.))
        stage_ef_new = state.stage_ef
        relay_bits = None
        if nested_plan is None:
            agg_flat, ef_new, stats = compat.shard_map(
                ring_fn,
                mesh=mesh,
                in_specs=(layout.grads_in_specs(dp), P(dp, "model"), P(dp),
                          P(dp), layout.param_in_specs(),
                          layout.param_in_specs()),
                out_specs=(fs, P(dp, "model"), stats_specs),
                axis_names=manual_axes,
            )(grads_stacked, state.ef, weights, participate, params_in,
              prev_in)
        else:
            se_specs = tuple(P(dp, "model") for _ in state.stage_ef)
            agg_flat, ef_new, stage_ef_new, stats, relay_bits = \
                compat.shard_map(
                    nested_ring_fn,
                    mesh=mesh,
                    in_specs=(layout.grads_in_specs(dp), P(dp, "model"),
                              se_specs, P(dp), P(dp),
                              layout.param_in_specs(),
                              layout.param_in_specs()),
                    out_specs=(fs, P(dp, "model"), se_specs, stats_specs,
                               P()),
                    axis_names=manual_axes,
                )(grads_stacked, state.ef, state.stage_ef, weights,
                  participate, params_in, prev_in)

        # phase 3 — ZeRO flat optimizer
        total_w = jnp.maximum(jnp.sum(weights * participate), 1e-9)
        grad_est = agg_flat.astype(jnp.float32) / total_w
        lr_scale = lr_schedule(state.step, warmup=tc.lr_warmup,
                               decay_steps=tc.lr_decay_steps)
        master_new, opt_new = opt_mod.apply_flat(
            tc.opt, state.opt, state.master, grad_est, lr_scale)
        master_new = jax.lax.with_sharding_constraint(
            master_new, NamedSharding(mesh, fs))

        # downlink — w^{t+1} broadcast
        params_new = compat.shard_map(
            downlink_fn, mesh=mesh, in_specs=(fs,),
            out_specs=layout.param_out_specs(), axis_names=manual_axes,
        )(master_new)

        tcs_prev_new = state.tcs_prev
        if needs_tcs:
            tcs_prev_new = jax.tree.map(
                lambda p: p.astype(jnp.dtype(tc.agg_dtype)), state.params)

        metrics = {
            "loss": loss,
            "agg_bits": stats.bits,
            "agg_nnz": stats.nnz,
            "agg_err_sq": stats.err_sq,
            "lr_scale": lr_scale,
        }
        if relay_bits is not None:
            # the scarce-link tier (pod-seam DCI / inter-cluster relay)
            metrics["agg_bits_relay"] = relay_bits
        if telemetry:
            from repro.runtime.fault import dead_banked_mass
            metrics["ef_mass"] = (
                jnp.sum(jnp.abs(ef_new))
                + sum(jnp.sum(jnp.abs(se)) for se in stage_ef_new or ()))
            metrics["ef_dead_mass"] = dead_banked_mass(
                ef_new.reshape(k_dp, -1), participate)
        new_state = TrainState(step=state.step + 1, params=params_new,
                               master=master_new, opt=opt_new, ef=ef_new,
                               tcs_prev=tcs_prev_new, stage_ef=stage_ef_new)
        return new_state, metrics

    if cohorts == 1:
        return train_step

    # ---- cohort-batched step (B tenants, one compiled program) -------------
    b_coh = cohorts

    def _coh_specs(tree):
        return jax.tree.map(_cohort_spec, tree,
                            is_leaf=lambda x: isinstance(x, P))

    fs_b = _cohort_spec(fs)

    def ring_fn_b(grads_tree, ef_l, w_l, part_l, params_tree, prev_tree):
        col, mask_col = jax.vmap(_col_and_mask)(grads_tree, params_tree,
                                                prev_tree)
        final, ef_new, stats = run_plan_segments_batched(
            agg_cfg, agg_plan, col, ef_l[:, 0], w_l[:, 0], axis=dp,
            global_mask_local=mask_col, participate=part_l[:, 0],
            transport="static")
        stats = jax.tree.map(
            lambda s: jax.lax.psum(s, tuple(manual_axes)), stats)
        return final, ef_new[:, None], stats

    def downlink_fn_b(master_l):
        m_idx = _model_axis_index(mesh)
        col = (jax.lax.all_gather(master_l, gather_axes, axis=1, tiled=True)
               if k_dp > 1 else master_l)
        return jax.vmap(lambda c: layout.treedef.unflatten(
            layout.local_unflatten(c, m_idx)))(col)

    def train_step_cohorts(state: TrainState, batch: dict):
        batch = dict(batch)
        weights = batch.pop("weights", None)
        participate = batch.pop("participate", None)
        if weights is None:
            weights = jnp.full((k_dp,), 1.0 / k_dp, jnp.float32)
        if participate is None:
            participate = jnp.ones((k_dp,), jnp.float32)
        weights = jnp.broadcast_to(weights, (b_coh, k_dp))
        participate = jnp.broadcast_to(participate, (b_coh, k_dp))

        # phase 1 — per-client grads, one partial-manual shard_map per
        # cohort (the model axis stays auto inside, which XLA only supports
        # without a vmapped batch dim; grads are embarrassingly parallel so
        # looping loses nothing — phase 2 is where cohorts share the wire)
        g_list, l_list = [], []
        for i in range(b_coh):
            params_i = jax.tree.map(lambda p: p[i], state.params)
            batch_i = jax.tree.map(lambda x: x[i], batch)
            g_i, l_i = compat.shard_map(
                per_client,
                mesh=mesh,
                in_specs=(jax.tree.map(lambda _: P(), params_i),
                          jax.tree.map(
                              lambda l: P(dp, *([None] * (l.ndim - 1))),
                              batch_i)),
                out_specs=(jax.tree.map(
                    lambda l: P(dp, *([None] * l.ndim)), params_i), P()),
                axis_names=set(dp),
            )(params_i, batch_i)
            g_list.append(g_i)
            l_list.append(l_i)
        grads_stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *g_list)
        loss = jnp.stack(l_list)

        # phase 2 — batched ring aggregation: B cohorts, one wavefront
        params_in = state.params
        prev_in = state.tcs_prev if needs_tcs else state.params
        stats_specs = jax.tree.map(lambda _: P(),
                                   ring_mod.RingStats(0., 0., 0.))
        agg_flat, ef_new, stats = compat.shard_map(
            ring_fn_b,
            mesh=mesh,
            in_specs=(_coh_specs(layout.grads_in_specs(dp)),
                      P(None, dp, "model"), P(None, dp), P(None, dp),
                      _coh_specs(layout.param_in_specs()),
                      _coh_specs(layout.param_in_specs())),
            out_specs=(fs_b, P(None, dp, "model"), stats_specs),
            axis_names=manual_axes,
        )(grads_stacked, state.ef, weights, participate, params_in,
          prev_in)

        # phase 3 — ZeRO flat optimizer, vmapped per cohort
        total_w = jnp.maximum(jnp.sum(weights * participate, axis=-1),
                              1e-9)
        grad_est = agg_flat.astype(jnp.float32) / total_w[:, None]
        lr_scale = lr_schedule(state.step, warmup=tc.lr_warmup,
                               decay_steps=tc.lr_decay_steps)
        master_new, opt_new = jax.vmap(
            lambda o, ms, gr, ls: opt_mod.apply_flat(tc.opt, o, ms, gr,
                                                     ls))(
            state.opt, state.master, grad_est, lr_scale)
        master_new = jax.lax.with_sharding_constraint(
            master_new, NamedSharding(mesh, fs_b))

        params_new = compat.shard_map(
            downlink_fn_b, mesh=mesh, in_specs=(fs_b,),
            out_specs=_coh_specs(layout.param_out_specs()),
            axis_names=manual_axes,
        )(master_new)

        tcs_prev_new = state.tcs_prev
        if needs_tcs:
            tcs_prev_new = jax.tree.map(
                lambda p: p.astype(jnp.dtype(tc.agg_dtype)), state.params)

        metrics = {
            "loss": loss,
            "agg_bits": stats.bits,
            "agg_nnz": stats.nnz,
            "agg_err_sq": stats.err_sq,
            "lr_scale": lr_scale,
        }
        if telemetry:
            from repro.runtime.fault import dead_banked_mass
            metrics["ef_mass"] = jnp.sum(jnp.abs(ef_new), axis=(1, 2))
            metrics["ef_dead_mass"] = jax.vmap(dead_banked_mass)(
                ef_new.reshape(b_coh, k_dp, -1), participate)
        new_state = TrainState(step=state.step + 1, params=params_new,
                               master=master_new, opt=opt_new, ef=ef_new,
                               tcs_prev=tcs_prev_new,
                               stage_ef=state.stage_ef)
        return new_state, metrics

    return train_step_cohorts


# ---------------------------------------------------------------------------
# Serving steps
# ---------------------------------------------------------------------------

def build_serve_step(cfg: ModelConfig, mesh):
    """decode: (params, cache, token [B], pos) → (next_token [B], cache)."""

    def serve_step(params, cache, token, pos):
        logits, cache = model_mod.decode_step(cfg, params, cache, token, pos)
        return jnp.argmax(logits, axis=-1).astype(jnp.int32), cache

    return serve_step


def build_prefill_step(cfg: ModelConfig, mesh):
    def prefill_step(params, cache, tokens, extra=None):
        kw = {}
        if extra is not None:
            kw = {k: v for k, v in extra.items()}
        logits, cache = model_mod.prefill(cfg, params, tokens, cache, **kw)
        return jnp.argmax(logits, axis=-1).astype(jnp.int32), cache

    return prefill_step
