"""TrainState: everything that must survive a restart (checkpointed whole).

The aggregation state (per-client error feedback, TCS previous params) is
*training state*, exactly like optimizer moments — losing it silently
changes convergence (the paper's EF banks untransmitted gradient mass).
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.algorithms import AggConfig, AggKind
from repro.optim.optimizers import FlatOptState, OptConfig

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    """Distributed-training configuration (aggregation + optimizer)."""

    agg: AggConfig = AggConfig(kind=AggKind.CL_SIA, q=1)
    opt: OptConfig = OptConfig()
    q_frac: float = 0.01            # global Q = q_frac · D_pad per round
    agg_dtype: str = "bfloat16"     # storage dtype of G / EF buffers
    ef_dtype: str = "bfloat16"
    lr_warmup: int = 100
    lr_decay_steps: int = 10_000
    # FSDP-style compute: shard the local batch over `model` too (weights
    # stay model-sharded and are gathered per layer) instead of TP
    # activation all-reduces. Wins when 2·activations·layers ≫ params
    # (EXPERIMENTS §Perf pair A). SSM/hybrid archs do this regardless.
    fsdp_compute: bool = False

    def needs_tcs(self) -> bool:
        return self.agg.kind in (AggKind.TC_SIA, AggKind.CL_TC_SIA)


class TrainState(NamedTuple):
    step: Array                     # int32 scalar
    params: Any                     # working pytree (model dtype, TP-sharded)
    master: Array                   # [D_pad] fp32, fully sharded (ZeRO)
    opt: FlatOptState               # flat, sharded like master
    ef: Array                       # [K_dp, D_pad] per-client error feedback
    tcs_prev: Optional[Any]         # params-shaped pytree (TC algorithms)
    # upper-tier EF of a nested (staged) aggregation topology: one
    # [K_dp, D_pad // prod(K_0..K_{s-1})] array per stage ≥ 1 (rank
    # (dp, model) holds its stage-s EF slice) — None for flat topologies,
    # keeping the historic pytree structure and checkpoints unchanged
    stage_ef: Optional[tuple] = None


def abstract_like(tree: Any) -> Any:
    return jax.tree.map(
        lambda l: jax.ShapeDtypeStruct(l.shape, l.dtype), tree)
