from repro.train.state import TrainConfig, TrainState
from repro.train.step import (build_prefill_step, build_serve_step,
                              build_train_step, init_state, state_shardings)

__all__ = ["TrainConfig", "TrainState", "build_prefill_step",
           "build_serve_step", "build_train_step", "init_state",
           "state_shardings"]
