"""glm4-9b — GLM-4 (RoPE, GQA kv=2) [hf:THUDM/glm-4-9b]."""

from repro.configs.base import ModelConfig

FULL = ModelConfig(
    name="glm4-9b", family="dense",
    num_layers=40, d_model=4096, num_heads=32, num_kv_heads=2,
    d_ff=13696, vocab_size=151552, head_dim=128,
    source="hf:THUDM/glm-4-9b [hf]",
)

SMOKE = ModelConfig(
    name="glm4-9b-smoke", family="dense",
    num_layers=3, d_model=96, num_heads=6, num_kv_heads=2,
    d_ff=256, vocab_size=512, head_dim=16, param_dtype="float32",
)
