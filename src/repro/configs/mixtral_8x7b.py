"""mixtral-8x7b — Mixtral 8×7B (MoE 8e top-2, SWA 4096) [arXiv:2401.04088; hf].

SWA makes the decode KV cache O(window) → long_500k runs for this arch.
"""

from repro.configs.base import ModelConfig

FULL = ModelConfig(
    name="mixtral-8x7b", family="moe",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8,
    d_ff=14336, vocab_size=32000, head_dim=128,
    num_experts=8, num_experts_per_tok=2, sliding_window=4096,
    source="arXiv:2401.04088; hf:mistralai/Mixtral-8x7B-v0.1 [hf]",
)

SMOKE = ModelConfig(
    name="mixtral-8x7b-smoke", family="moe",
    num_layers=2, d_model=96, num_heads=6, num_kv_heads=2,
    d_ff=192, vocab_size=512, head_dim=16,
    num_experts=4, num_experts_per_tok=2, sliding_window=32,
    capacity_factor=4.0, param_dtype="float32",
)
