"""phi4-mini-3.8b — Phi-4-mini (RoPE SwiGLU GQA) [arXiv:2412.08905; hf].

24 query heads do not divide the 16-way model axis; partition.py falls back
to replicated attention projections for this arch (DESIGN §5).
"""

from repro.configs.base import ModelConfig

FULL = ModelConfig(
    name="phi4-mini-3.8b", family="dense",
    num_layers=32, d_model=3072, num_heads=24, num_kv_heads=8,
    d_ff=8192, vocab_size=200064, head_dim=128, tie_embeddings=True,
    source="arXiv:2412.08905; hf:microsoft/Phi-4-mini-instruct [hf]",
)

SMOKE = ModelConfig(
    name="phi4-mini-3.8b-smoke", family="dense",
    num_layers=3, d_model=96, num_heads=6, num_kv_heads=2,
    d_ff=256, vocab_size=512, head_dim=16, param_dtype="float32",
)
