"""Model/shape/run configuration dataclasses + the arch registry."""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Architecture hyperparameters (one instance per assigned arch)."""

    name: str
    family: str                      # dense | moe | hybrid | vlm | ssm | audio
    num_layers: int
    d_model: int
    num_heads: int                   # query heads; 0 for attention-free
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 → d_model // num_heads

    # attention details
    rope_theta: float = 1e4
    attn_bias: bool = False          # qwen1.5-style QKV bias
    sliding_window: int = 0          # 0 = full attention; >0 = SWA width

    # MLP / head variants
    mlp_type: str = "swiglu"         # swiglu (3 mats) | gelu (2 mats)
    tie_embeddings: bool = False     # lm_head = embedᵀ

    # MoE
    num_experts: int = 0
    num_experts_per_tok: int = 0
    capacity_factor: float = 1.25

    # SSM (Mamba2 / SSD)
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 64
    ssm_conv: int = 4

    # hybrid (zamba2-style shared attention block cadence)
    attn_every: int = 0              # 0 = no shared block

    # modality frontend stub
    frontend: str = "none"           # none | vision | audio

    norm_eps: float = 1e-5
    param_dtype: str = "bfloat16"    # big configs; smoke tests use float32
    remat: bool = True               # checkpoint the layer-scan body
    # √L nested remat: outer scan over G groups × inner scan over L/G
    # layers, both checkpointed → G + L/G live boundary activations instead
    # of L (88-layer granite: 74 GB → ~16 GB/device; EXPERIMENTS §Perf
    # it.6) at the cost of one extra forward recompute.
    nested_remat: bool = True

    # provenance
    source: str = ""                 # citation / hf id [tier]

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.num_heads, 1)

    @property
    def padded_vocab(self) -> int:
        """Vocab padded to 256 so embed/lm_head always TP-shard (standard
        production practice; padded logits are masked to −inf in the loss).
        param_count() stays unpadded — the pad is honest compute overhead
        visible in the MODEL_FLOPS/HLO ratio."""
        return -(-self.vocab_size // 256) * 256

    @property
    def d_inner(self) -> int:        # mamba2 inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_headdim

    @property
    def is_subquadratic(self) -> bool:
        """Eligible for the long_500k cell (DESIGN §4 skip rationale)."""
        return self.family in ("ssm", "hybrid") or self.sliding_window > 0

    @property
    def dtype(self):
        return jnp.dtype(self.param_dtype)

    def param_count(self) -> int:
        """Analytic parameter count (embedding + layers + head)."""
        D, F, V = self.d_model, self.d_ff, self.vocab_size
        Hq, Hkv, Dh = self.num_heads, self.num_kv_heads, self.resolved_head_dim
        n = V * D                                    # embed
        attn = D * Hq * Dh + 2 * D * Hkv * Dh + Hq * Dh * D
        if self.attn_bias:
            attn += (Hq + 2 * Hkv) * Dh
        mats = 3 if self.mlp_type == "swiglu" else 2
        mlp = mats * D * F
        moe_mlp = self.num_experts * mats * D * F + D * self.num_experts
        ssm = 0
        if self.family in ("ssm", "hybrid"):
            di, N, H = self.d_inner, self.ssm_state, self.ssm_heads
            ssm = (D * (2 * di + 2 * N + H)          # in_proj
                   + self.ssm_conv * (di + 2 * N)    # depthwise conv
                   + 3 * H + di + di * D)            # A_log, D, dt_bias, norm, out_proj
        per_layer = 2 * D  # norms
        if self.family == "moe":
            per_layer += attn + moe_mlp
        elif self.family == "ssm":
            per_layer = D + ssm
        elif self.family == "hybrid":
            per_layer = D + ssm
        else:
            per_layer += attn + mlp
        n += self.num_layers * per_layer
        if self.family == "hybrid" and self.attn_every:
            n += attn + mlp + 2 * D                  # one shared block
        n += D                                       # final norm
        if not self.tie_embeddings:
            n += D * V                               # untied lm head
        return n

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top-k of E experts)."""
        if self.family != "moe" or not self.num_experts:
            return self.param_count()
        D, F = self.d_model, self.d_ff
        mats = 3 if self.mlp_type == "swiglu" else 2
        inactive = (self.num_experts - self.num_experts_per_tok) * mats * D * F
        return self.param_count() - self.num_layers * inactive


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    """One input-shape cell from the assignment."""

    name: str                        # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str                        # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def shape_cells(cfg: ModelConfig) -> list[str]:
    """The shape cells this arch runs (long_500k needs sub-quadratic attn)."""
    cells = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.is_subquadratic:
        cells.append("long_500k")
    return cells
