"""granite-34b — IBM Granite-34B-Code (MQA, 4·d GELU MLP) [arXiv:2405.04324; hf]."""

from repro.configs.base import ModelConfig

FULL = ModelConfig(
    name="granite-34b", family="dense",
    num_layers=88, d_model=6144, num_heads=48, num_kv_heads=1,
    d_ff=24576, vocab_size=49152, head_dim=128, mlp_type="gelu",
    source="arXiv:2405.04324; hf:ibm-granite/granite-34b-code-base [hf]",
)

SMOKE = ModelConfig(
    name="granite-34b-smoke", family="dense",
    num_layers=3, d_model=96, num_heads=6, num_kv_heads=1,
    d_ff=256, vocab_size=512, head_dim=16, param_dtype="float32",
)
