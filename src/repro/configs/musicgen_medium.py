"""musicgen-medium — decoder-only over EnCodec tokens [arXiv:2306.05284; hf].

EnCodec frontend is a stub (conditioning embeddings added to token
embeddings); 4-codebook heads collapsed to one vocab-2048 head
(backbone-only per assignment, DESIGN §4).
"""

from repro.configs.base import ModelConfig

FULL = ModelConfig(
    name="musicgen-medium", family="audio",
    num_layers=48, d_model=1536, num_heads=24, num_kv_heads=24,
    d_ff=6144, vocab_size=2048, head_dim=64, frontend="audio",
    source="arXiv:2306.05284; hf:facebook/musicgen-medium [hf]",
)

SMOKE = ModelConfig(
    name="musicgen-medium-smoke", family="audio",
    num_layers=3, d_model=64, num_heads=4, num_kv_heads=4,
    d_ff=128, vocab_size=256, head_dim=16, frontend="audio",
    param_dtype="float32",
)
