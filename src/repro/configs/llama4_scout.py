"""llama4-scout-17b-a16e — Llama-4 Scout (MoE 16e top-1)
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified].

Treated as full attention (iRoPE chunked attention not reproduced) →
long_500k cell skipped; see DESIGN §4.
"""

from repro.configs.base import ModelConfig

FULL = ModelConfig(
    name="llama4-scout-17b-a16e", family="moe",
    num_layers=48, d_model=5120, num_heads=40, num_kv_heads=8,
    d_ff=8192, vocab_size=202048, head_dim=128,
    num_experts=16, num_experts_per_tok=1,
    source="hf:meta-llama/Llama-4-Scout-17B-16E [unverified]",
)

SMOKE = ModelConfig(
    name="llama4-scout-smoke", family="moe",
    num_layers=2, d_model=96, num_heads=6, num_kv_heads=2,
    d_ff=192, vocab_size=512, head_dim=16,
    num_experts=4, num_experts_per_tok=1, capacity_factor=4.0, param_dtype="float32",
)
