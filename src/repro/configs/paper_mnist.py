"""The paper's own experimental setup (§VI): logistic regression on
(synthetic-)MNIST, d = 7850 trainable parameters, SGD batch 20, lr 0.1,
Q = 78 (1% of d), Q_L = 8, Q_G = 70, K = 28 clients.
"""

import dataclasses


@dataclasses.dataclass(frozen=True)
class PaperConfig:
    input_dim: int = 784
    num_classes: int = 10
    d: int = 7850                    # 784·10 + 10
    num_clients: int = 28
    batch_size: int = 20
    lr: float = 0.1
    q: int = 78                      # 1% of d
    q_local: int = 8                 # 10% of Q (paper follows [10])
    q_global: int = 70               # Q − Q_L
    omega: int = 32


PAPER = PaperConfig()
