"""Architecture registry: --arch <id> → (FULL, SMOKE) ModelConfigs."""

from repro.configs import (codeqwen15_7b, glm4_9b, granite_34b,
                           internvl2_26b, llama4_scout, mamba2_130m,
                           mixtral_8x7b, musicgen_medium, paper_mnist,
                           phi4_mini_38b, zamba2_12b)
from repro.configs.base import SHAPES, ModelConfig, ShapeSpec, shape_cells

_MODULES = {
    "granite-34b": granite_34b,
    "codeqwen1.5-7b": codeqwen15_7b,
    "glm4-9b": glm4_9b,
    "phi4-mini-3.8b": phi4_mini_38b,
    "mixtral-8x7b": mixtral_8x7b,
    "llama4-scout-17b-a16e": llama4_scout,
    "zamba2-1.2b": zamba2_12b,
    "internvl2-26b": internvl2_26b,
    "mamba2-130m": mamba2_130m,
    "musicgen-medium": musicgen_medium,
}

ARCHS = list(_MODULES)
PAPER = paper_mnist.PAPER


def get_config(arch: str, *, smoke: bool = False) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; choose from {ARCHS}")
    mod = _MODULES[arch]
    return mod.SMOKE if smoke else mod.FULL


__all__ = ["ARCHS", "PAPER", "SHAPES", "ModelConfig", "ShapeSpec",
           "get_config", "shape_cells"]
