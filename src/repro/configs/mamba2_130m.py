"""mamba2-130m — Mamba2 SSD, attention-free [arXiv:2405.21060; unverified].

d_inner = 2·768 = 1536, headdim 64 → 24 SSD heads, state 128.
Attention-free → runs long_500k.
"""

from repro.configs.base import ModelConfig

FULL = ModelConfig(
    name="mamba2-130m", family="ssm",
    num_layers=24, d_model=768, num_heads=0, num_kv_heads=0,
    d_ff=0, vocab_size=50280, tie_embeddings=True,
    ssm_state=128, ssm_headdim=64, ssm_expand=2, ssm_chunk=256,
    source="arXiv:2405.21060; hf:state-spaces/mamba2-130m [unverified]",
)

SMOKE = ModelConfig(
    name="mamba2-130m-smoke", family="ssm",
    num_layers=3, d_model=64, num_heads=0, num_kv_heads=0,
    d_ff=0, vocab_size=512,
    ssm_state=16, ssm_headdim=16, ssm_chunk=8, param_dtype="float32",
)
