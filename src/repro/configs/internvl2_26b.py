"""internvl2-26b — InternVL2 (InternViT + InternLM2-20B backbone)
[arXiv:2404.16821; hf]. ViT frontend is a stub: input_specs supplies
precomputed patch embeddings + mask (backbone-only per assignment).
"""

from repro.configs.base import ModelConfig

FULL = ModelConfig(
    name="internvl2-26b", family="vlm",
    num_layers=48, d_model=6144, num_heads=48, num_kv_heads=8,
    d_ff=16384, vocab_size=92553, head_dim=128, frontend="vision",
    source="arXiv:2404.16821; hf:OpenGVLab/InternVL2-26B [hf]",
)

SMOKE = ModelConfig(
    name="internvl2-26b-smoke", family="vlm",
    num_layers=3, d_model=96, num_heads=6, num_kv_heads=2,
    d_ff=256, vocab_size=512, head_dim=16, frontend="vision",
    param_dtype="float32",
)
