"""codeqwen1.5-7b — Qwen1.5 arch (MHA kv=32, QKV bias) [hf:Qwen/CodeQwen1.5-7B]."""

from repro.configs.base import ModelConfig

FULL = ModelConfig(
    name="codeqwen1.5-7b", family="dense",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=32,
    d_ff=13440, vocab_size=92416, head_dim=128, attn_bias=True,
    source="hf:Qwen/CodeQwen1.5-7B [hf]",
)

SMOKE = ModelConfig(
    name="codeqwen1.5-7b-smoke", family="dense",
    num_layers=3, d_model=96, num_heads=6, num_kv_heads=6,
    d_ff=256, vocab_size=512, head_dim=16, attn_bias=True,
    param_dtype="float32",
)
