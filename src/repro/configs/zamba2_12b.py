"""zamba2-1.2b — Zamba2 hybrid: Mamba2 backbone + shared attention block
[arXiv:2411.15242; hf]. Shared block cadence attn_every=6 (approximation of
Zamba2's shared-block scheme; DESIGN §4). Sub-quadratic → runs long_500k.
"""

from repro.configs.base import ModelConfig

FULL = ModelConfig(
    name="zamba2-1.2b", family="hybrid",
    num_layers=38, d_model=2048, num_heads=32, num_kv_heads=32,
    d_ff=8192, vocab_size=32000, head_dim=64,
    ssm_state=64, ssm_headdim=64, ssm_expand=2, ssm_chunk=256,
    attn_every=6,
    source="arXiv:2411.15242; hf:Zyphra/Zamba2-1.2B [hf]",
)

SMOKE = ModelConfig(
    name="zamba2-1.2b-smoke", family="hybrid",
    num_layers=5, d_model=64, num_heads=4, num_kv_heads=4,
    d_ff=128, vocab_size=512, head_dim=16,
    ssm_state=16, ssm_headdim=16, ssm_chunk=8, attn_every=2,
    param_dtype="float32",
)
