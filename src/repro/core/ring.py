"""Rotated sparse ring reduce-scatter — the TPU-native mapping of the
paper's multi-hop incremental aggregation (DESIGN §2).

The flattened per-rank gradient is split into K segments; segment j's K-hop
chain starts at rank j and walks the ring, every hop folding that rank's
contribution with the configured node step (Alg 1–5). All K ICI links are
busy every step (a faithful sequential chain would use one), and after the
final shift rank r owns the fully-aggregated segment r — feeding the
ZeRO-sharded flat optimizer directly.

Semantics: per segment, the value path is *identical* to
``chain.run_chain`` on that segment with per-segment budget q_seg
(tested in tests/test_ring_shardmap.py). The Top-Q budget is divided across
segments (block-wise Top-Q — the standard distributed adaptation; DESIGN
§2.5).

Since the device-plan lowering (:mod:`repro.agg.device`) the ring is the
*chain specialization* of the plan-driven kernel:
``rotated_ring_local`` compiles the ring's visiting order to an
:class:`~repro.agg.plan.AggPlan` (every transport offset +1) and runs
:func:`repro.agg.device.run_plan_segments_local`, which emits the same
per-level ``ppermute`` + compact ``(values, indices)`` wire program the
historic hand-written loop did — bit-exact, and generalizing to routed
trees/graphs/schedules. This module keeps the flat layout helpers;
train/step.py assembles the full 3-phase step.
"""

from __future__ import annotations

import functools
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro import compat

Array = jax.Array


class RingStats(NamedTuple):
    """Wire accounting, summed over this device's hops (psum later)."""

    bits: Array        # exact paper-§V bits transmitted by this rank
    nnz: Array         # total nonzeros transmitted (float32 to avoid ovf)
    err_sq: Array      # Σ‖e‖² after the round (local sparsification error)


def ring_hops(num_ranks: int) -> int:
    """Wire transmissions per rank per round (K−1 ring + 1 ownership shift)."""
    return num_ranks


def rotated_ring_local(
    cfg,
    flat_local: Array,                # [n] this rank's gradient slice
    ef_local: Array,                  # [n] this rank's EF memory
    weight: Array,                    # scalar D_k
    *,
    axis,                             # mesh axis name or tuple (ring order)
    global_mask_local: Optional[Array] = None,   # [n] TCS mask slice
    participate: Optional[Array] = None,         # scalar 0/1
) -> tuple[Array, Array, RingStats]:
    """Run the rotated ring. Returns (final segment [n//K], new EF [n], stats).

    Must be called inside shard_map with ``axis`` manual. ``n % K == 0``
    (train/step.py pads the flat layout). After return, rank r holds the
    fully-aggregated segment r.

    Chain specialization of the plan-driven kernel: segment rotation is the
    path plan with rotated start ranks, so this lowers the ring's chain
    plan (:func:`repro.agg.device.ring_chain_plan` — every transport offset
    +1) through :func:`repro.agg.device.run_plan_segments_local`, emitting
    one ``ppermute(+1)`` per level exactly as the historic loop did.
    """
    # function-level import: repro.agg.device imports RingStats from here
    from repro.agg.device import ring_chain_plan, run_plan_segments_local

    K = compat.axis_size(axis)
    return run_plan_segments_local(
        cfg, ring_chain_plan(K), flat_local, ef_local, weight, axis=axis,
        global_mask_local=global_mask_local, participate=participate,
        transport="static")


# ---------------------------------------------------------------------------
# Flat layout helpers (pjit-land)
# ---------------------------------------------------------------------------

def padded_flat_dim(tree_or_specs: Any, multiple: int) -> int:
    """Σ leaf sizes, padded up to ``multiple`` (= model×data×pod sizes)."""
    total = sum(int(jnp.size(l)) if isinstance(l, jax.Array)
                else int(functools.reduce(lambda a, b: a * b, l.shape, 1))
                for l in jax.tree.leaves(tree_or_specs))
    return -(-total // multiple) * multiple


def flatten_tree(tree: Any, d_pad: int, dtype=jnp.float32,
                 aligned_axis: Optional[Any] = None) -> Array:
    """Pytree → flat [d_pad] (row-major per leaf, fixed tree order).

    ``aligned_axis`` is reserved for the shard-aligned layout optimization
    (each leaf transposed so its model-sharded dim leads; see EXPERIMENTS
    §Perf) — None gives the naive paper-faithful layout.
    """
    leaves = jax.tree.leaves(tree)
    flat = jnp.concatenate([l.reshape(-1).astype(dtype) for l in leaves])
    return jnp.pad(flat, (0, d_pad - flat.shape[0]))


def flatten_stacked(tree: Any, d_pad: int, dtype=jnp.float32) -> Array:
    """Pytree with leading stack dim K on every leaf → [K, d_pad]."""
    leaves = jax.tree.leaves(tree)
    k = leaves[0].shape[0]
    flat = jnp.concatenate(
        [l.reshape(k, -1).astype(dtype) for l in leaves], axis=1)
    return jnp.pad(flat, ((0, 0), (0, d_pad - flat.shape[1])))


def unflatten_tree(template: Any, flat: Array) -> Any:
    """Inverse of flatten_tree (template supplies shapes/dtypes)."""
    leaves, treedef = jax.tree.flatten(template)
    out, off = [], 0
    for l in leaves:
        size = int(jnp.size(l)) if isinstance(l, jax.Array) else int(
            functools.reduce(lambda a, b: a * b, l.shape, 1))
        shape = l.shape
        dtype = l.dtype
        out.append(jax.lax.dynamic_slice_in_dim(flat, off, size, 0)
                   .reshape(shape).astype(dtype))
        off += size
    return jax.tree.unflatten(treedef, out)


def segment_budget(q_total: int, num_segments: int) -> int:
    """Per-segment per-hop budget (block-wise Top-Q).

    Floor division, so summed per-segment budgets never exceed the global
    §V budget: ``num_segments · segment_budget(q, n) ≤ q``. When
    ``q_total < num_segments`` the budget is 0 — those segments transmit
    nothing (the old ``max(1, ·)`` floor silently inflated the global
    budget K-fold in that regime).
    """
    if num_segments <= 0:
        raise ValueError(f"num_segments must be positive, got {num_segments}")
    return max(0, q_total) // num_segments
