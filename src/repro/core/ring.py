"""Rotated sparse ring reduce-scatter — the TPU-native mapping of the
paper's multi-hop incremental aggregation (DESIGN §2).

The flattened per-rank gradient is split into K segments; segment j's K-hop
chain starts at rank j and walks the ring, every hop folding that rank's
contribution with the configured node step (Alg 1–5). All K ICI links are
busy every step (a faithful sequential chain would use one), and after the
final shift rank r owns the fully-aggregated segment r — feeding the
ZeRO-sharded flat optimizer directly.

Semantics: per segment, the value path is *identical* to
``chain.run_chain`` on that segment with per-segment budget q_seg
(tested in tests/test_ring_shardmap.py). The Top-Q budget is divided across
segments (block-wise Top-Q — the standard distributed adaptation; DESIGN
§2.5).

This module provides the *local* (inside-shard_map) function plus the flat
layout helpers; train/step.py assembles the full 3-phase step.
"""

from __future__ import annotations

import functools
from typing import Any, NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp

from repro import compat
from repro.core import sparsify as sp
from repro.core.algorithms import AggConfig, AggKind, NodeCtx, node_step

Array = jax.Array

# Algorithms whose per-hop payload is bounded by the budget → eligible for
# compact (values, indices) wire transport, the paper's ω+⌈log₂d⌉ format.
_COMPACT_KINDS = (AggKind.CL_SIA, AggKind.CL_TC_SIA)


class RingStats(NamedTuple):
    """Wire accounting, summed over this device's hops (psum later)."""

    bits: Array        # exact paper-§V bits transmitted by this rank
    nnz: Array         # total nonzeros transmitted (float32 to avoid ovf)
    err_sq: Array      # Σ‖e‖² after the round (local sparsification error)


def ring_hops(num_ranks: int) -> int:
    """Wire transmissions per rank per round (K−1 ring + 1 ownership shift)."""
    return num_ranks


def rotated_ring_local(
    cfg: AggConfig,
    flat_local: Array,                # [n] this rank's gradient slice
    ef_local: Array,                  # [n] this rank's EF memory
    weight: Array,                    # scalar D_k
    *,
    axis,                             # mesh axis name or tuple (ring order)
    global_mask_local: Optional[Array] = None,   # [n] TCS mask slice
    participate: Optional[Array] = None,         # scalar 0/1
) -> tuple[Array, Array, RingStats]:
    """Run the rotated ring. Returns (final segment [n//K], new EF [n], stats).

    Must be called inside shard_map with ``axis`` manual. ``n % K == 0``
    (train/step.py pads the flat layout). After return, rank r holds the
    fully-aggregated segment r.
    """
    K = compat.axis_size(axis)
    r = jax.lax.axis_index(axis)
    n = flat_local.shape[0]
    assert n % K == 0, (n, K)
    seg = n // K

    # Keep the full-size buffers in their storage dtype (bf16 by default —
    # a full f32 upcast here would materialize 2× the gradient shard);
    # per-segment slices are upcast to f32 inside the loop.
    x = flat_local.reshape(K, seg)
    ef = ef_local.reshape(K, seg)
    gm = (None if global_mask_local is None
          else global_mask_local.reshape(K, seg))
    p = jnp.float32(1) if participate is None else participate.astype(
        jnp.float32)

    step_fn = node_step(cfg)
    perm = None  # filled lazily (needs K)

    gamma = jnp.zeros((seg,), jnp.float32)
    bits = jnp.float32(0)
    nnz = jnp.float32(0)
    err = jnp.float32(0)

    for t in range(K):
        s = (r - t) % K
        g_seg = jax.lax.dynamic_slice(x, (s, 0), (1, seg))[0].astype(
            jnp.float32)
        e_seg = jax.lax.dynamic_slice(ef, (s, 0), (1, seg))[0].astype(
            jnp.float32)
        m_seg = (jnp.zeros((seg,), jnp.float32) if gm is None else
                 jax.lax.dynamic_slice(gm, (s, 0), (1, seg))[0].astype(
                     jnp.float32))
        ctx = NodeCtx(global_mask=m_seg, participate=p)
        gamma_out, e_new, st = step_fn(cfg, g_seg, gamma, e_seg, weight, ctx)
        ef = jax.lax.dynamic_update_slice(
            ef, e_new.astype(ef.dtype)[None], (s, 0))
        bits = bits + st.bits
        nnz = nnz + st.nnz_out.astype(jnp.float32)
        err = err + st.err_sq
        if perm is None:
            perm = [(i, (i + 1) % K) for i in range(K)]
        if t < K - 1:
            gamma = _send(cfg, gamma_out, seg, axis, perm)
        else:
            gamma = gamma_out

    # ownership shift: rank r currently holds segment (r+1) mod K
    final = _send(cfg, gamma, seg, axis, perm)
    return final, ef.reshape(n), RingStats(bits=bits, nnz=nnz, err_sq=err)


def _wire_budget(cfg: AggConfig) -> int:
    if cfg.kind == AggKind.CL_TC_SIA:
        return cfg.q_global + cfg.q_local
    return cfg.q


def _send(cfg: AggConfig, gamma: Array, seg: int, axis, perm) -> Array:
    """One ring hop. CL algorithms guarantee ‖γ‖₀ ≤ budget, so the wire
    carries compact (values[q], indices[q]) — the paper's ω+⌈log₂d⌉ payload
    — instead of the dense segment (d/Q ≈ 100× wire reduction; this is the
    paper-faithful transport, see EXPERIMENTS §Perf it.1). Unbounded
    algorithms (SIA/RE-SIA/TC-SIA) ship the dense segment, which is
    precisely the degradation the paper proves for them."""
    q = _wire_budget(cfg)
    if cfg.kind not in _COMPACT_KINDS or q >= seg // 2:
        return jax.lax.ppermute(gamma, axis, perm)
    vals, idx, _ = sp.compact(gamma, q)
    vals = jax.lax.ppermute(vals.astype(jnp.dtype(cfg.wire_dtype)), axis,
                            perm)
    idx = jax.lax.ppermute(idx, axis, perm)
    return sp.scatter(vals.astype(jnp.float32), idx, seg)


# ---------------------------------------------------------------------------
# Flat layout helpers (pjit-land)
# ---------------------------------------------------------------------------

def padded_flat_dim(tree_or_specs: Any, multiple: int) -> int:
    """Σ leaf sizes, padded up to ``multiple`` (= model×data×pod sizes)."""
    total = sum(int(jnp.size(l)) if isinstance(l, jax.Array)
                else int(functools.reduce(lambda a, b: a * b, l.shape, 1))
                for l in jax.tree.leaves(tree_or_specs))
    return -(-total // multiple) * multiple


def flatten_tree(tree: Any, d_pad: int, dtype=jnp.float32,
                 aligned_axis: Optional[Any] = None) -> Array:
    """Pytree → flat [d_pad] (row-major per leaf, fixed tree order).

    ``aligned_axis`` is reserved for the shard-aligned layout optimization
    (each leaf transposed so its model-sharded dim leads; see EXPERIMENTS
    §Perf) — None gives the naive paper-faithful layout.
    """
    leaves = jax.tree.leaves(tree)
    flat = jnp.concatenate([l.reshape(-1).astype(dtype) for l in leaves])
    return jnp.pad(flat, (0, d_pad - flat.shape[0]))


def flatten_stacked(tree: Any, d_pad: int, dtype=jnp.float32) -> Array:
    """Pytree with leading stack dim K on every leaf → [K, d_pad]."""
    leaves = jax.tree.leaves(tree)
    k = leaves[0].shape[0]
    flat = jnp.concatenate(
        [l.reshape(k, -1).astype(dtype) for l in leaves], axis=1)
    return jnp.pad(flat, ((0, 0), (0, d_pad - flat.shape[1])))


def unflatten_tree(template: Any, flat: Array) -> Any:
    """Inverse of flatten_tree (template supplies shapes/dtypes)."""
    leaves, treedef = jax.tree.flatten(template)
    out, off = [], 0
    for l in leaves:
        size = int(jnp.size(l)) if isinstance(l, jax.Array) else int(
            functools.reduce(lambda a, b: a * b, l.shape, 1))
        shape = l.shape
        dtype = l.dtype
        out.append(jax.lax.dynamic_slice_in_dim(flat, off, size, 0)
                   .reshape(shape).astype(dtype))
        off += size
    return jax.tree.unflatten(treedef, out)


def segment_budget(q_total: int, num_segments: int) -> int:
    """Per-segment per-hop budget (block-wise Top-Q; ≥1)."""
    return max(1, q_total // num_segments)
