"""Shard-aligned flat parameter space (zero-resharding by construction).

The naive path — ravel every gradient leaf globally, concat, then constrain
to P(dp, "model") — makes GSPMD reshard every TP-sharded leaf through a
replicated intermediate (measured: 280 GB/device temp on granite-34b;
EXPERIMENTS §Perf it.4). Instead, the flat space is defined *locally*:

  global flat vector := concat over model columns m of
      concat over leaves of (leaf's column-m piece, padded)

* model-sharded leaves: the column-m piece is the leaf's own TP shard —
  already resident on the device, raveled as-is;
* model-replicated leaves (non-divisible heads, mamba in_proj, norms):
  every device holds the full leaf; column m deterministically takes the
  m-th slice of its (padded) ravel — a free local slice.

All flat-space state (master, optimizer moments, EF, TCS masks, ring
segments) uses this one layout, so nothing is ever resharded. The layout
is mesh-dependent; checkpoints record it via TrainConfig+mesh (restoring
onto a different mesh goes through the pytree params, not the flat state).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

Array = jax.Array


def _prod(xs) -> int:
    n = 1
    for x in xs:
        n *= int(x)
    return n


@dataclasses.dataclass(frozen=True)
class LeafPlan:
    global_shape: tuple
    local_shape: tuple          # shape of the per-device (column) shard
    model_dim: Optional[int]    # which dim is model-sharded (None = repl.)
    local_size: int             # flat length this leaf contributes per column
    pad: int                    # zeros appended to the raveled piece
    dtype: Any


class FlatLayout:
    """Layout plan for one (param template, param specs, mesh) triple."""

    def __init__(self, template: Any, specs: Any, mesh):
        self.mesh = mesh
        self.m = mesh.shape.get("model", 1)
        dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
        self.k_dp = _prod(mesh.shape[a] for a in dp) if dp else 1
        self.treedef = jax.tree.structure(template)
        t_leaves = jax.tree.leaves(template)
        s_leaves = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
        assert len(t_leaves) == len(s_leaves), "template/specs mismatch"
        plans = []
        for leaf, spec in zip(t_leaves, s_leaves):
            shape = tuple(int(d) for d in leaf.shape)
            model_dim = None
            for i, ax in enumerate(spec):
                names = ax if isinstance(ax, tuple) else (ax,)
                if "model" in names:
                    model_dim = i
            if model_dim is not None and shape[model_dim] % self.m == 0:
                local_shape = list(shape)
                local_shape[model_dim] //= self.m
                local_size = _prod(local_shape)
                pad = 0
            else:
                model_dim = None
                local_shape = list(shape)
                full = _prod(shape)
                padded = -(-full // self.m) * self.m
                local_size = padded // self.m
                pad = padded - full
            plans.append(LeafPlan(shape, tuple(local_shape), model_dim,
                                  local_size, pad, leaf.dtype))
        self.plans: Sequence[LeafPlan] = tuple(plans)
        raw = sum(p.local_size for p in plans)
        # ring needs n_local % k_dp == 0; pad the column tail
        self.n_local = -(-raw // max(self.k_dp, 1)) * max(self.k_dp, 1)
        self.tail_pad = self.n_local - raw
        self.d_flat = self.n_local * self.m        # global flat length

    # ------------------------------------------------------------------
    # Inside-shard_map (manual over model [+ dp]) local transforms
    # ------------------------------------------------------------------

    def local_flatten(self, leaves_local: Sequence[Array], m_idx,
                      dtype=jnp.float32) -> Array:
        """Per-device leaf shards → this column's [n_local] flat piece.

        ``leaves_local``: leaf values as seen inside the manual shard_map —
        model-sharded leaves arrive as their local shard, replicated leaves
        arrive whole. ``m_idx`` = lax.axis_index("model") (traced OK).
        """
        parts = []
        for plan, leaf in zip(self.plans, leaves_local):
            flat = leaf.reshape(-1).astype(dtype)
            if plan.model_dim is None:
                if plan.pad:
                    flat = jnp.pad(flat, (0, plan.pad))
                piece = jax.lax.dynamic_slice(
                    flat, (m_idx * plan.local_size,), (plan.local_size,))
            else:
                piece = flat                      # already the column piece
            parts.append(piece)
        col = jnp.concatenate(parts) if parts else jnp.zeros((0,), dtype)
        if self.tail_pad:
            col = jnp.pad(col, (0, self.tail_pad))
        return col

    def local_unflatten(self, col: Array, m_idx, *,
                        model_axis: str = "model") -> list:
        """Column flat piece [n_local] → local leaf shards.

        Model-sharded leaves reconstruct from this column alone;
        replicated leaves all-gather their pieces across ``model_axis``
        (small leaves only, by construction).
        """
        out, off = [], 0
        for plan in self.plans:
            piece = jax.lax.dynamic_slice_in_dim(col, off, plan.local_size)
            off += plan.local_size
            if plan.model_dim is None:
                if self.m > 1:
                    full = jax.lax.all_gather(piece, model_axis, tiled=True)
                else:
                    full = piece
                full = full[: _prod(plan.global_shape)]
                out.append(full.reshape(plan.global_shape).astype(plan.dtype))
            else:
                out.append(piece.reshape(plan.local_shape).astype(plan.dtype))
        return out

    # ------------------------------------------------------------------
    def grads_in_specs(self, dp_axes: tuple) -> Any:
        """in_specs for stacked grad leaves entering the ring shard_map."""
        specs = []
        for plan in self.plans:
            inner = [None] * len(plan.global_shape)
            if plan.model_dim is not None:
                inner[plan.model_dim] = "model"
            specs.append(P(dp_axes, *inner))
        return self.treedef.unflatten(specs)

    def param_in_specs(self) -> Any:
        """in_specs for (unstacked) param leaves (replicated over dp)."""
        specs = []
        for plan in self.plans:
            inner = [None] * len(plan.global_shape)
            if plan.model_dim is not None:
                inner[plan.model_dim] = "model"
            specs.append(P(*inner))
        return self.treedef.unflatten(specs)

    def param_out_specs(self) -> Any:
        return self.param_in_specs()
