"""Time-correlated sparsification (TCS, Ozfatura et al. 2021) machinery.

TCS computes a *global* Top-Q_G mask from the global model's own motion,
``m^t = s(w^t − w^{t−1}, Q_G)`` — identical at every client because every
client holds ``w^t`` and ``w^{t−1}``. The paper's Algorithms 4/5 combine this
mask with small local additions.

The state carried between rounds is the previous parameter vector (flat).
It is part of TrainState and is checkpointed.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import sparsify as sp

Array = jax.Array


class TCSState(NamedTuple):
    prev_flat: Array   # w^{t-1}, flattened, same dtype as params


def init_tcs(flat_params: Array) -> TCSState:
    """At t=0 there is no motion yet; m^0 is empty (all-local round)."""
    return TCSState(prev_flat=flat_params)


def global_mask(state: TCSState, flat_params: Array, q_global: int,
                *, topq_mask_fn=None) -> Array:
    """``m^t = s(w^t − w^{t−1}, Q_G)`` — 0/1 float mask of shape [d]."""
    if topq_mask_fn is None:
        topq_mask_fn = sp.topq_mask
    delta = flat_params - state.prev_flat
    # Degenerate first round (w^t == w^{t-1}): top_k of zeros picks arbitrary
    # slots, which is harmless (they contribute dense-cost slots only), but we
    # zero the mask for cleanliness.
    m = topq_mask_fn(delta, q_global)
    any_motion = jnp.any(delta != 0)
    return jnp.where(any_motion, m, jnp.zeros_like(m))


def update(state: TCSState, flat_params: Array) -> TCSState:
    return TCSState(prev_flat=flat_params)
