"""Public API shims: the pytree-aware aggregator object now lives in
:mod:`repro.agg` (topology-polymorphic plan/execute).

``Aggregator`` accepts any topology ``compile_plan`` understands; the names
kept here — :class:`ChainAggregator` and :func:`make_aggregator` — are
deprecated thin wrappers that pin the paper's identity chain, preserved so
old call sites keep working. The distributed (mesh) counterpart with
identical semantics is ``repro.core.ring.ring_aggregate`` — see
``tests/test_ring_shardmap.py`` for the equivalence proof.
"""

from __future__ import annotations

import warnings

from repro.agg.aggregator import (AggState, Aggregator, RoundOut,  # noqa: F401
                                  flat_dim)
from repro.core.algorithms import AggConfig


class ChainAggregator(Aggregator):
    """Deprecated: use :class:`repro.agg.Aggregator` (chain is its default
    topology)."""

    def __init__(self, cfg: AggConfig, num_clients: int, dim: int):
        warnings.warn(
            "ChainAggregator is deprecated; use repro.agg.Aggregator, which "
            "defaults to the chain topology and also takes trees/graphs",
            DeprecationWarning, stacklevel=2)
        super().__init__(cfg, num_clients, dim)


def make_aggregator(cfg: AggConfig, num_clients: int, dim: int) -> Aggregator:
    """Deprecated: construct :class:`repro.agg.Aggregator` directly."""
    warnings.warn(
        "make_aggregator is deprecated; construct repro.agg.Aggregator "
        "directly (pass topology=... for non-chain aggregation)",
        DeprecationWarning, stacklevel=2)
    return Aggregator(cfg, num_clients, dim)
