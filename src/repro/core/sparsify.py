"""Top-Q sparsification primitives.

Notation follows the paper: ``S(x, Q)`` returns the Top-Q (by magnitude)
sparsification of ``x`` (all other entries zeroed); ``s(x, Q)`` returns the
corresponding 0/1 mask.  Everything here is pure-functional, jit-safe, and
operates on flat 1-D vectors; pytree plumbing lives in :mod:`repro.core.api`.

Two implementations are provided:

* exact: ``jax.lax.top_k`` based — the oracle used by the simulator, tests
  and small models;
* threshold: histogram + bisection (distributable; composes with sharding via
  a single ``psum`` of the histogram) — the production path, with the
  perf-critical histogram implemented as a Pallas kernel in
  :mod:`repro.kernels`.
"""

from __future__ import annotations

import functools
import math
from typing import Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


# ---------------------------------------------------------------------------
# Exact Top-Q (oracle)
# ---------------------------------------------------------------------------

def topq(x: Array, q: int) -> Array:
    """``S(x, Q)``: keep the Q largest-magnitude entries of ``x``, zero the rest.

    Ties are broken arbitrarily but deterministically (lax.top_k order).
    ``q`` must be a static Python int (shapes are static under jit).
    """
    if q <= 0:
        return jnp.zeros_like(x)
    d = x.shape[-1]
    if q >= d:
        return x
    mag = jnp.abs(x)
    _, idx = jax.lax.top_k(mag, q)
    mask = jnp.zeros_like(x, dtype=bool).at[idx].set(True)
    return jnp.where(mask, x, 0)


def topq_mask(x: Array, q: int) -> Array:
    """``s(x, Q)``: the 0/1 float mask of the Top-Q support of ``x``."""
    if q <= 0:
        return jnp.zeros_like(x)
    d = x.shape[-1]
    if q >= d:
        return jnp.ones_like(x)
    mag = jnp.abs(x)
    _, idx = jax.lax.top_k(mag, q)
    return jnp.zeros_like(x).at[idx].set(1.0)


def support(x: Array) -> Array:
    """``1(x)``: indicator vector of the nonzero entries of ``x`` (float 0/1)."""
    return (x != 0).astype(x.dtype)


def mask_union(*masks: Array) -> Array:
    """``1(m_a + m_b + …)``: union of 0/1 masks, returned as float 0/1."""
    acc = masks[0]
    for m in masks[1:]:
        acc = acc + m
    return (acc > 0).astype(acc.dtype)


def nnz(x: Array) -> Array:
    """``‖x‖₀`` as an int32 scalar (traced, not static)."""
    return jnp.sum(x != 0).astype(jnp.int32)


# ---------------------------------------------------------------------------
# Dynamic-budget Top-Q (traced q — per-node bandwidth-aware budgets)
# ---------------------------------------------------------------------------

def _dynamic_keep(x: Array, q: Array) -> Array:
    """Boolean Top-q support of ``x`` for a *traced* budget ``q``.

    Single source of truth for both the value and mask sparsifiers: τ = the
    q-th largest magnitude by full sort, keep |x| ≥ τ. Ties at τ may keep
    slightly more than q entries (same over-selection contract as
    :func:`topq_by_threshold`); q ≤ 0 keeps nothing, q ≥ d everything.
    """
    d = x.shape[-1]
    qc = jnp.clip(jnp.asarray(q, jnp.int32), 0, d)
    mag = jnp.abs(x)
    tau = jnp.sort(mag)[::-1][jnp.maximum(qc - 1, 0)]
    return (mag >= tau) & (mag > 0) & (qc > 0)


def topq_dynamic(x: Array, q: Array) -> Array:
    """``S(x, q)`` with a traced scalar budget ``q`` (int32).

    ``lax.top_k`` needs a static k, so per-node budgets (one vmapped lane
    per aggregation-tree slot) go through :func:`_dynamic_keep` instead.
    """
    return jnp.where(_dynamic_keep(x, q), x, 0)


def topq_mask_dynamic(x: Array, q: Array) -> Array:
    """``s(x, q)`` 0/1 mask counterpart of :func:`topq_dynamic`."""
    return _dynamic_keep(x, q).astype(x.dtype)


# ---------------------------------------------------------------------------
# Threshold-based Top-Q (distributable)
# ---------------------------------------------------------------------------

def count_ge(mag: Array, taus: Array) -> Array:
    """``counts[j] = #{i : mag_i >= taus_j}`` — int32 [B].

    Pure-jnp reference; the Pallas kernel in
    ``repro.kernels.topq_threshold`` matches this contract and is swapped in
    via the ``count_fn`` argument of :func:`threshold_for_topq`.
    """
    return jnp.sum(mag[:, None] >= taus[None, :], axis=0).astype(jnp.int32)


def count_ge_batch(mag: Array, taus: Array) -> Array:
    """Batched :func:`count_ge`: ``counts[w, b] = #{i : mag_{w,i} >= taus_{w,b}}``.

    mag: [W, d]; taus: [W, B] → int32 [W, B]. Pure-jnp reference; the Pallas
    kernel ``repro.kernels.level.count_ge_level_pallas`` matches this
    contract (swapped in via ``count_fn``).
    """
    return jnp.sum(mag[:, :, None] >= taus[:, None, :],
                   axis=1).astype(jnp.int32)


def count_ge_presorted(smag: Array, taus: Array) -> Array:
    """Candidate counts against an already-sorted magnitude vector.

    ``counts[j] = #{i : smag_i >= taus_j} = d − #{i : smag_i < taus_j}``,
    resolved by B binary searches — exact float comparisons, so the
    returned integers are bit-identical to the O(d·B) broadcast of
    :func:`count_ge`. O(B·log d) per call: the whole multi-round bisection
    costs one O(d·log d) sort (hoisted out of the round scan by
    :func:`tau_operand`) plus rounds·B searches. This is the default host
    count for :func:`threshold_for_topq` — it replaces the per-round
    O(d·B) sweep (and the scatter-add rank histogram that XLA:CPU
    serializes) that dominated the threshold sparsifier's CPU round time.
    """
    d = smag.shape[-1]
    return (d - jnp.searchsorted(smag, taus, side="left")
            ).astype(jnp.int32)


def count_ge_sorted(mag: Array, taus: Array) -> Array:
    """:func:`count_ge` via sort + binary search (any ``taus`` order)."""
    return count_ge_presorted(jnp.sort(mag), taus)


def count_ge_sorted_batch(mag: Array, taus: Array) -> Array:
    """Batched :func:`count_ge_sorted`: [W, d] × [W, B] → int32 [W, B]."""
    return jax.vmap(count_ge_sorted)(mag, taus)


class TauOperand(NamedTuple):
    """The bisection operand of :func:`threshold_for_topq`, as callbacks.

    Decouples the τ search from a *materialized* magnitude vector: the
    fused node-step path (``repro.core.algorithms``) builds one of these
    from the raw node inputs ``(g, e, γ_in, w, participate[, m])`` so the
    count kernels reconstruct ``|…·(w·g + e) + …|`` tile-by-tile in VMEM —
    no HBM round-trip of the operand before (or during) the search.

    * ``count(taus)``  → int32 candidate counts ([B] or [W, B]); ``taus``
      are always nondecreasing per lane.
    * ``max_abs()``    → max |operand| (f32 scalar or [W]) — the initial
      bracket top. Implementations must use the same float expression as a
      materialized ``jnp.max(jnp.abs(x))`` so the two paths stay bitwise
      identical.
    * ``batched``      → whether the operand carries a [W] lane axis.
    * ``hist(tables)`` → one-pass joint digit histogram ``(D2, F)`` for
      ``tau_impl="hist"`` (see :func:`_hist_digits` for the contract);
      None disables the hist implementation for this operand.
    * ``materialize()`` → the dense operand itself (the exact/dynamic
      sparsifier paths need the full sort anyway).
    """

    count: Callable[[Array], Array]
    max_abs: Callable[[], Array]
    batched: bool
    hist: Optional[Callable] = None
    materialize: Optional[Callable[[], Array]] = None


def tau_operand(x: Array, count_fn=None) -> TauOperand:
    """Wrap a materialized ``x`` ([d] or [W, d]) as a :class:`TauOperand`."""
    batched = x.ndim == 2
    mag = jnp.abs(x.astype(jnp.float32))
    if count_fn is None:
        # sort ONCE at operand construction — a loop constant of the
        # bisection scan, so every round's counts are B binary searches
        smag = jnp.sort(mag, axis=-1)
        count_fn = (jax.vmap(count_ge_presorted) if batched
                    else count_ge_presorted)
        count = lambda taus: count_fn(smag, taus)           # noqa: E731
    else:
        count = lambda taus: count_fn(mag, taus)            # noqa: E731

    def max_abs():
        if not mag.size:
            return (jnp.zeros(mag.shape[:-1], jnp.float32) if batched
                    else jnp.float32(0))
        return jnp.max(mag, axis=-1) if batched else jnp.max(mag)

    def hist(tables):
        fn = jax.vmap(_hist_digits) if batched else _hist_digits
        return fn(mag, *tables)

    return TauOperand(count=count, max_abs=max_abs, batched=batched,
                      hist=hist, materialize=lambda: x)


# -- one-pass histogram bisection (tau_impl="hist") -------------------------
#
# The scan evaluates `rounds` sequential streaming passes. For rounds ≤ 2
# one pass suffices: bin every element by its round-1 digit d1 (which of
# the branch+1 round-1 brackets it falls in) and its round-2 digit d2
# (candidate count *within its own bracket*), accumulate the joint
# histogram D2[d1, d2], and reconstruct both rounds' candidate-count
# integers exactly:
#
#   counts1[j] = #{d1 >= j}                                (j = 1..branch)
#   counts2[j] = #{d1 = B, d2 >= j} + #{d1 >= B+2}
#              + (j < branch ? #{d1 = B+1} : F[B+1])       (B = jstar1)
#
# The cross-bracket terms are exact theorems about the f32 bracket
# arithmetic, not approximations: an element one bracket above B clears
# every round-2 candidate except possibly the top one (margin ≈ w2 =
# (hi-lo)/branch² versus rounding noise ≈ 2⁻²⁴·hi — safe for
# branch ≤ 1024), and that top comparison is resolved exactly by the
# per-element flag F (|x| >= tau_top of its own bracket). Elements below
# bracket B clear nothing (their magnitude is < new_lo(B), the smallest
# candidate). Zero padding lands in the never-read bin D2[0, 0].

_F32_MAX = float(jnp.finfo(jnp.float32).max)


def _hist_tables(lo: Array, hi: Array, branch: int):
    """Per-bracket round-2 tables, mirroring the scan's float ops exactly.

    Returns ``(tau1 [.., b], new_lo [.., b+1], w2 [.., b+1],
    top_shift [.., b+1])`` where entry ``b'`` of the per-bracket tables is
    what the scan would compute had round 1 selected ``jstar1 = b'``;
    ``top_shift[d] = tau_top[d-1]`` (the top round-2 candidate of the
    bracket *below* digit d; f32 max for d = 0, which no magnitude
    reaches) feeds the per-element flag F.
    """
    steps = jnp.arange(1, branch + 1, dtype=jnp.float32)
    bf = jnp.arange(0, branch + 1, dtype=jnp.float32)
    lo_e = jnp.expand_dims(lo, -1)
    w1 = (hi - lo) / branch
    w1_e = jnp.expand_dims(w1, -1)
    tau1 = lo_e + w1_e * steps                     # [.., b]
    new_lo = lo_e + bf * w1_e                      # [.., b+1]
    new_hi = new_lo + w1_e
    w2 = (new_hi - new_lo) / branch                # [.., b+1]
    tau_top = new_lo + w2 * jnp.float32(branch)    # [.., b+1]
    top_shift = jnp.concatenate(
        [jnp.full_like(tau_top[..., :1], _F32_MAX), tau_top[..., :branch]],
        axis=-1)
    return tau1, new_lo, w2, top_shift


def _hist_digits(mag: Array, tau1: Array, new_lo: Array, w2: Array,
                 top_shift: Array):
    """Digit histogram of a materialized 1-D ``mag`` (jnp reference).

    Returns ``(D2 [b+1, b+1] i32, F [b+1] i32)``: ``D2[r, c] = #{d1 = r,
    d2 = c}`` and ``F[r] = #{d1 = r, mag >= top_shift[r]}``. d1 is the
    round-1 candidate count per element (searchsorted — exact, taus
    nondecreasing); d2 the round-2 candidate count *within the element's
    own bracket* (binary search over the candidate index, valid because
    ``new_lo + w2·j`` is nondecreasing in j).
    """
    branch = tau1.shape[-1]
    d1 = jnp.searchsorted(tau1, mag, side="right").astype(jnp.int32)
    nl = new_lo[d1]
    w2e = w2[d1]
    te = top_shift[d1]
    # d2 = largest j in 0..b with mag >= nl + w2e·j (j = 0 vacuously true)
    lo_i = jnp.zeros_like(d1)
    hi_i = jnp.full_like(d1, branch + 1)
    for _ in range(max(1, math.ceil(math.log2(branch + 1)))):
        mid = (lo_i + hi_i) // 2
        pred = mag >= nl + w2e * mid.astype(jnp.float32)
        take = hi_i - lo_i > 1
        lo_i = jnp.where(take & pred, mid, lo_i)
        hi_i = jnp.where(take & ~pred, mid, hi_i)
    d2 = lo_i
    flag = (mag >= te).astype(jnp.int32)
    D2 = jnp.zeros((branch + 1, branch + 1), jnp.int32).at[d1, d2].add(1)
    F = jnp.zeros((branch + 1,), jnp.int32).at[d1].add(flag)
    return D2, F


def _hist_bisect(new_lo: Array, w2: Array, D2: Array, F: Array, q: int,
                 branch: int, rounds: int):
    """Reconstruct the scan's per-round counts and τ from ``(D2, F)``.

    Returns ``(tau, [counts_round1, ...])`` with the same integers and the
    same final float ops as the streaming scan (``new_lo``/``w2`` are the
    bracket tables of :func:`_hist_tables`).
    """
    A = jnp.sum(D2, axis=-1)                                 # #{d1 = r}
    zeros2 = jnp.zeros(A.shape[:-1] + (2,), A.dtype)
    suffA = jnp.cumsum(
        jnp.concatenate([A, zeros2], -1)[..., ::-1], axis=-1)[..., ::-1]
    c1 = suffA[..., 1:branch + 1]                            # [.., b]
    jstar1 = jnp.sum((c1 >= q).astype(jnp.int32), axis=-1)   # [..] 0..b
    counts = [c1]
    B = jstar1[..., None]
    nl_B = jnp.take_along_axis(new_lo, B, axis=-1)[..., 0]
    w2_B = jnp.take_along_axis(w2, B, axis=-1)[..., 0]
    if rounds == 1:
        return jnp.maximum(nl_B, 1e-30), counts
    S2 = jnp.cumsum(D2[..., ::-1], axis=-1)[..., ::-1]       # #{d1=r, d2>=c}
    rowS2 = jnp.take_along_axis(S2, B[..., None], axis=-2)[..., 0, :]
    zeros1 = jnp.zeros(A.shape[:-1] + (1,), A.dtype)
    a_next = jnp.take_along_axis(
        jnp.concatenate([A, zeros1], -1), B + 1, axis=-1)[..., 0]
    f_next = jnp.take_along_axis(
        jnp.concatenate([F, zeros1], -1), B + 1, axis=-1)[..., 0]
    s_next2 = jnp.take_along_axis(suffA, B + 2, axis=-1)[..., 0]
    is_top = jnp.arange(1, branch + 1) == branch
    c2 = (rowS2[..., 1:branch + 1] + s_next2[..., None]
          + jnp.where(is_top, f_next[..., None], a_next[..., None]))
    counts.append(c2)
    jstar2 = jnp.sum((c2 >= q).astype(jnp.int32), axis=-1)
    tau = nl_B + jstar2.astype(jnp.float32) * w2_B
    return jnp.maximum(tau, 1e-30), counts


def threshold_for_topq(
    x: Optional[Array],
    q: int,
    *,
    branch: int = 64,
    rounds: int = 3,
    axis_name: str | None = None,
    count_fn=None,
    operand_fn: Optional[TauOperand] = None,
    tau_impl: str = "scan",
    with_counts: bool = False,
) -> Array:
    """Magnitude threshold ``τ`` with ``count(|x| >= τ) ≈ q`` (always ≥ q).

    Branch-and-bisect: each round evaluates ``branch`` candidate thresholds
    inside the current bracket (one streaming pass over x) and narrows the
    bracket ``branch``-fold → resolution ``branch**rounds`` bins after
    ``rounds`` passes.

    When ``axis_name`` is given, candidate counts (and the bracket top) are
    ``psum``/``pmax``-reduced over that mesh axis so every shard computes the
    identical *global* threshold — this is how the paper's global Top-Q
    survives sharding (``q`` is then the global budget).

    Invariant maintained: ``count(|x| >= lo) >= q`` — the returned ``lo``
    therefore keeps at least q survivors (over-selection bounded by the ties
    inside one final-resolution bin; tests measure it).

    ``x`` may also be batched ``[W, d]`` (the fused whole-level node-step
    path): every lane runs its own bracket, ``count_fn`` then takes
    ``(mag [W, d], taus [W, B]) → [W, B]``, and a ``[W]`` vector of
    thresholds is returned — bitwise identical per lane to the 1-D path
    (same bracket arithmetic, integer candidate counts).

    ``operand_fn`` (a :class:`TauOperand`) replaces the materialized ``x``
    entirely — counts, bracket top and histogram all stream through its
    callbacks (the fused-operand kernel path); ``x`` may then be None.

    ``tau_impl``: "scan" (the streaming multi-pass oracle) or "hist"
    (rounds ≤ 2 only — one joint digit histogram replaces the sequential
    passes; per-round candidate counts and the returned τ are bit-identical
    to the scan, see :func:`_hist_bisect`).

    ``with_counts=True`` additionally returns the per-round candidate
    counts (post-``psum``), stacked [rounds, .., branch] — the hist-vs-scan
    parity tests key on these integers.

    On a single host (no ``axis_name``/``count_fn``/``operand_fn``/
    ``with_counts``) the scan runs count-free: one ``top_k(q)`` resolves
    the ``count >= q`` predicate for every candidate of every round, with
    bitwise-identical τ (see the inline comment).
    """
    if tau_impl not in ("scan", "hist"):
        raise ValueError(f"unknown tau_impl {tau_impl!r}")
    # Single-host shortcut: the bisection consumes counts ONLY through the
    # predicate count(τ_j) >= q, and #{|x| >= t} >= q  ⟺  t <= the q-th
    # largest |x| (exact float comparisons, ties included) — so one
    # ``lax.top_k(q)`` replaces every per-round count sweep (and the
    # operand construction entirely). The jstar integers, and therefore τ,
    # are bitwise identical to the counting scan. Invalid whenever counts
    # are observable (``with_counts``), mesh-reduced (per-shard q-th
    # values do not compose into the global predicate), or routed through
    # a caller-specified count path.
    kth = None
    if (tau_impl == "scan" and axis_name is None and operand_fn is None
            and count_fn is None and not with_counts):
        operand = None
        mag = jnp.abs(x.astype(jnp.float32))
        batched = x.ndim == 2
        d = mag.shape[-1]
        if not mag.size:
            hi = jnp.zeros(mag.shape[:-1], jnp.float32)
        else:
            hi = jnp.max(mag, axis=-1) if batched else jnp.max(mag)
        if q <= 0:
            kth = jnp.full(hi.shape, jnp.inf)        # count >= q always
        elif q > d:
            kth = jnp.full(hi.shape, -jnp.inf)       # count < q always
        else:
            # min over the top-q block == the q-th largest; NOT
            # ``[..., -1]`` — XLA:CPU rewrites topk+slice into a full
            # stable sort (30× slower than its TopK custom call)
            kth = jnp.min(jax.lax.top_k(mag, q)[0], axis=-1)
    else:
        operand = (tau_operand(x, count_fn) if operand_fn is None
                   else operand_fn)
        batched = operand.batched
        hi = operand.max_abs()
    if axis_name is not None:
        hi = jax.lax.pmax(hi, axis_name)
    # strictly above max ⇒ count(hi) = 0 < q; tiny floor handles all-zero x
    hi = jnp.maximum(hi, 1e-30) * jnp.float32(1 + 1e-6)
    lo = jnp.zeros_like(hi)

    if tau_impl == "hist":
        if rounds not in (1, 2):
            raise ValueError("tau_impl='hist' folds the whole search into "
                             "one histogram pass; rounds must be 1 or 2, "
                             f"got {rounds}")
        if branch > 1024:
            raise ValueError("tau_impl='hist' cross-bracket count exactness "
                             f"needs branch <= 1024, got {branch}")
        if operand.hist is None:
            raise ValueError("operand_fn has no hist implementation")
        tables = _hist_tables(lo, hi, branch)
        D2, F = operand.hist(tables)
        if axis_name is not None:
            D2 = jax.lax.psum(D2, axis_name)
            F = jax.lax.psum(F, axis_name)
        tau, counts = _hist_bisect(tables[1], tables[2], D2, F, q, branch,
                                   rounds)
        return (tau, jnp.stack(counts)) if with_counts else tau

    def round_body(carry, _):
        lo, hi = carry
        w = (hi - lo) / branch
        steps = jnp.arange(1, branch + 1, dtype=jnp.float32)
        taus = (lo[:, None] + w[:, None] * steps if batched
                else lo + w * steps)
        if kth is not None:
            keeps_q = (kth[..., None] if batched else kth) >= taus
            counts = None
        else:
            counts = operand.count(taus)
            if axis_name is not None:
                counts = jax.lax.psum(counts, axis_name)
            keeps_q = counts >= q
        # counts is non-increasing in tau; jstar = #{j : counts_j >= q} is
        # the largest candidate index (1-based) still keeping >= q.
        jstar = jnp.sum(keeps_q.astype(jnp.int32), axis=-1)
        new_lo = lo + jstar.astype(jnp.float32) * w
        new_hi = new_lo + w
        return (new_lo, new_hi), counts if with_counts else None

    (lo, hi), ys = jax.lax.scan(round_body, (lo, hi), None, length=rounds)
    tau = jnp.maximum(lo, 1e-30)
    return (tau, ys) if with_counts else tau


def topq_by_threshold(
    x: Array, q: int, *, branch: int = 64, rounds: int = 3,
    axis_name: str | None = None, count_fn=None, tau_impl: str = "scan",
) -> Array:
    """Approximate ``S(x, Q)`` via the bisection threshold (≥ q survivors)."""
    tau = threshold_for_topq(
        x, q, branch=branch, rounds=rounds, axis_name=axis_name,
        count_fn=count_fn, tau_impl=tau_impl)
    return jnp.where(jnp.abs(x) >= tau, x, 0)


# ---------------------------------------------------------------------------
# Compact sparse representation (static shapes)
# ---------------------------------------------------------------------------

def compact(x: Array, q: int) -> Tuple[Array, Array, Array]:
    """Dense → compact ``(values[q], indices[q], count)``.

    The q slots hold the nonzero entries of ``x`` (which must have ≤ q
    nonzeros for lossless round-trip — the CL algorithms guarantee this).
    Unused slots carry value 0 and index d (one-past-end sentinel), so a
    scatter-add of the padding is a no-op via drop semantics.
    """
    d = x.shape[-1]
    is_nz = x != 0
    # Order: nonzeros first (stable), then padding.
    order = jnp.argsort(~is_nz, stable=True)
    take = order[:q]
    vals = x[take]
    valid = is_nz[take]
    idx = jnp.where(valid, take, d).astype(jnp.int32)
    vals = jnp.where(valid, vals, 0)
    return vals, idx, jnp.sum(is_nz).astype(jnp.int32)


def scatter(vals: Array, idx: Array, d: int) -> Array:
    """Compact ``(values, indices)`` → dense length-d vector.

    Out-of-range (sentinel) indices are dropped.
    """
    out = jnp.zeros((d,), vals.dtype)
    return out.at[idx].add(vals, mode="drop")
