"""Top-Q sparsification primitives.

Notation follows the paper: ``S(x, Q)`` returns the Top-Q (by magnitude)
sparsification of ``x`` (all other entries zeroed); ``s(x, Q)`` returns the
corresponding 0/1 mask.  Everything here is pure-functional, jit-safe, and
operates on flat 1-D vectors; pytree plumbing lives in :mod:`repro.core.api`.

Two implementations are provided:

* exact: ``jax.lax.top_k`` based — the oracle used by the simulator, tests
  and small models;
* threshold: histogram + bisection (distributable; composes with sharding via
  a single ``psum`` of the histogram) — the production path, with the
  perf-critical histogram implemented as a Pallas kernel in
  :mod:`repro.kernels`.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


# ---------------------------------------------------------------------------
# Exact Top-Q (oracle)
# ---------------------------------------------------------------------------

def topq(x: Array, q: int) -> Array:
    """``S(x, Q)``: keep the Q largest-magnitude entries of ``x``, zero the rest.

    Ties are broken arbitrarily but deterministically (lax.top_k order).
    ``q`` must be a static Python int (shapes are static under jit).
    """
    if q <= 0:
        return jnp.zeros_like(x)
    d = x.shape[-1]
    if q >= d:
        return x
    mag = jnp.abs(x)
    _, idx = jax.lax.top_k(mag, q)
    mask = jnp.zeros_like(x, dtype=bool).at[idx].set(True)
    return jnp.where(mask, x, 0)


def topq_mask(x: Array, q: int) -> Array:
    """``s(x, Q)``: the 0/1 float mask of the Top-Q support of ``x``."""
    if q <= 0:
        return jnp.zeros_like(x)
    d = x.shape[-1]
    if q >= d:
        return jnp.ones_like(x)
    mag = jnp.abs(x)
    _, idx = jax.lax.top_k(mag, q)
    return jnp.zeros_like(x).at[idx].set(1.0)


def support(x: Array) -> Array:
    """``1(x)``: indicator vector of the nonzero entries of ``x`` (float 0/1)."""
    return (x != 0).astype(x.dtype)


def mask_union(*masks: Array) -> Array:
    """``1(m_a + m_b + …)``: union of 0/1 masks, returned as float 0/1."""
    acc = masks[0]
    for m in masks[1:]:
        acc = acc + m
    return (acc > 0).astype(acc.dtype)


def nnz(x: Array) -> Array:
    """``‖x‖₀`` as an int32 scalar (traced, not static)."""
    return jnp.sum(x != 0).astype(jnp.int32)


# ---------------------------------------------------------------------------
# Dynamic-budget Top-Q (traced q — per-node bandwidth-aware budgets)
# ---------------------------------------------------------------------------

def _dynamic_keep(x: Array, q: Array) -> Array:
    """Boolean Top-q support of ``x`` for a *traced* budget ``q``.

    Single source of truth for both the value and mask sparsifiers: τ = the
    q-th largest magnitude by full sort, keep |x| ≥ τ. Ties at τ may keep
    slightly more than q entries (same over-selection contract as
    :func:`topq_by_threshold`); q ≤ 0 keeps nothing, q ≥ d everything.
    """
    d = x.shape[-1]
    qc = jnp.clip(jnp.asarray(q, jnp.int32), 0, d)
    mag = jnp.abs(x)
    tau = jnp.sort(mag)[::-1][jnp.maximum(qc - 1, 0)]
    return (mag >= tau) & (mag > 0) & (qc > 0)


def topq_dynamic(x: Array, q: Array) -> Array:
    """``S(x, q)`` with a traced scalar budget ``q`` (int32).

    ``lax.top_k`` needs a static k, so per-node budgets (one vmapped lane
    per aggregation-tree slot) go through :func:`_dynamic_keep` instead.
    """
    return jnp.where(_dynamic_keep(x, q), x, 0)


def topq_mask_dynamic(x: Array, q: Array) -> Array:
    """``s(x, q)`` 0/1 mask counterpart of :func:`topq_dynamic`."""
    return _dynamic_keep(x, q).astype(x.dtype)


# ---------------------------------------------------------------------------
# Threshold-based Top-Q (distributable)
# ---------------------------------------------------------------------------

def count_ge(mag: Array, taus: Array) -> Array:
    """``counts[j] = #{i : mag_i >= taus_j}`` — int32 [B].

    Pure-jnp reference; the Pallas kernel in
    ``repro.kernels.topq_threshold`` matches this contract and is swapped in
    via the ``count_fn`` argument of :func:`threshold_for_topq`.
    """
    return jnp.sum(mag[:, None] >= taus[None, :], axis=0).astype(jnp.int32)


def count_ge_batch(mag: Array, taus: Array) -> Array:
    """Batched :func:`count_ge`: ``counts[w, b] = #{i : mag_{w,i} >= taus_{w,b}}``.

    mag: [W, d]; taus: [W, B] → int32 [W, B]. Pure-jnp reference; the Pallas
    kernel ``repro.kernels.level.count_ge_level_pallas`` matches this
    contract (swapped in via ``count_fn``).
    """
    return jnp.sum(mag[:, :, None] >= taus[:, None, :],
                   axis=1).astype(jnp.int32)


def threshold_for_topq(
    x: Array,
    q: int,
    *,
    branch: int = 64,
    rounds: int = 3,
    axis_name: str | None = None,
    count_fn=None,
) -> Array:
    """Magnitude threshold ``τ`` with ``count(|x| >= τ) ≈ q`` (always ≥ q).

    Branch-and-bisect: each round evaluates ``branch`` candidate thresholds
    inside the current bracket (one streaming pass over x) and narrows the
    bracket ``branch``-fold → resolution ``branch**rounds`` bins after
    ``rounds`` passes.

    When ``axis_name`` is given, candidate counts (and the bracket top) are
    ``psum``/``pmax``-reduced over that mesh axis so every shard computes the
    identical *global* threshold — this is how the paper's global Top-Q
    survives sharding (``q`` is then the global budget).

    Invariant maintained: ``count(|x| >= lo) >= q`` — the returned ``lo``
    therefore keeps at least q survivors (over-selection bounded by the ties
    inside one final-resolution bin; tests measure it).

    ``x`` may also be batched ``[W, d]`` (the fused whole-level node-step
    path): every lane runs its own bracket, ``count_fn`` then takes
    ``(mag [W, d], taus [W, B]) → [W, B]`` (default
    :func:`count_ge_batch`), and a ``[W]`` vector of thresholds is
    returned — bitwise identical per lane to the 1-D path (same bracket
    arithmetic, integer candidate counts).
    """
    batched = x.ndim == 2
    if count_fn is None:
        count_fn = count_ge_batch if batched else count_ge
    mag = jnp.abs(x.astype(jnp.float32))
    if mag.size:
        hi = jnp.max(mag, axis=-1) if batched else jnp.max(mag)
    else:
        hi = (jnp.zeros(mag.shape[:-1], jnp.float32) if batched
              else jnp.float32(0))
    if axis_name is not None:
        hi = jax.lax.pmax(hi, axis_name)
    # strictly above max ⇒ count(hi) = 0 < q; tiny floor handles all-zero x
    hi = jnp.maximum(hi, 1e-30) * jnp.float32(1 + 1e-6)
    lo = jnp.zeros_like(hi)

    def round_body(carry, _):
        lo, hi = carry
        w = (hi - lo) / branch
        steps = jnp.arange(1, branch + 1, dtype=jnp.float32)
        taus = (lo[:, None] + w[:, None] * steps if batched
                else lo + w * steps)
        counts = count_fn(mag, taus)
        if axis_name is not None:
            counts = jax.lax.psum(counts, axis_name)
        # counts is non-increasing in tau; jstar = #{j : counts_j >= q} is
        # the largest candidate index (1-based) still keeping >= q.
        jstar = jnp.sum((counts >= q).astype(jnp.int32), axis=-1)
        new_lo = lo + jstar.astype(jnp.float32) * w
        new_hi = new_lo + w
        return (new_lo, new_hi), None

    (lo, hi), _ = jax.lax.scan(round_body, (lo, hi), None, length=rounds)
    return jnp.maximum(lo, 1e-30)


def topq_by_threshold(
    x: Array, q: int, *, branch: int = 64, rounds: int = 3,
    axis_name: str | None = None, count_fn=None,
) -> Array:
    """Approximate ``S(x, Q)`` via the bisection threshold (≥ q survivors)."""
    tau = threshold_for_topq(
        x, q, branch=branch, rounds=rounds, axis_name=axis_name,
        count_fn=count_fn)
    return jnp.where(jnp.abs(x) >= tau, x, 0)


# ---------------------------------------------------------------------------
# Compact sparse representation (static shapes)
# ---------------------------------------------------------------------------

def compact(x: Array, q: int) -> Tuple[Array, Array, Array]:
    """Dense → compact ``(values[q], indices[q], count)``.

    The q slots hold the nonzero entries of ``x`` (which must have ≤ q
    nonzeros for lossless round-trip — the CL algorithms guarantee this).
    Unused slots carry value 0 and index d (one-past-end sentinel), so a
    scatter-add of the padding is a no-op via drop semantics.
    """
    d = x.shape[-1]
    is_nz = x != 0
    # Order: nonzeros first (stable), then padding.
    order = jnp.argsort(~is_nz, stable=True)
    take = order[:q]
    vals = x[take]
    valid = is_nz[take]
    idx = jnp.where(valid, take, d).astype(jnp.int32)
    vals = jnp.where(valid, vals, 0)
    return vals, idx, jnp.sum(is_nz).astype(jnp.int32)


def scatter(vals: Array, idx: Array, d: int) -> Array:
    """Compact ``(values, indices)`` → dense length-d vector.

    Out-of-range (sentinel) indices are dropped.
    """
    out = jnp.zeros((d,), vals.dtype)
    return out.at[idx].add(vals, mode="drop")
