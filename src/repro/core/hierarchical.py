"""Hierarchical (pod-aware) sparse incremental aggregation.

The flat production ring treats (pod, data) as one K=32 chain — the
paper's exact topology. DCI links between pods are scarcer than intra-pod
ICI, so the *optimized* schedule aggregates in two stages:

  stage 1: rotated ring over `data` inside each pod (K_d hops on ICI);
  stage 2: rotated ring over `pod` on the stage-1 partial aggregates
           (K_p hops on DCI, payload already CL-sparsified).

Since the nested-plan lowering (:mod:`repro.agg.nested` +
:func:`repro.agg.device.run_nested_segments_local`) this module is the
**chain×chain specialization**: the two-stage schedule compiles to a
:class:`~repro.agg.nested.NestedPlan` (one rotated-ring chain per pod,
then the ring chain over pod partials — :func:`pod_ring_nested`) and runs
through the staged segments kernel, which emits the identical per-level
``ppermute(+1)`` program the historic hand-composed pair of
``rotated_ring_local`` calls did — bit-exact, and generalizing to
arbitrary intra-pod/inter-pod trees. Stage 2's "gradient" is the
pod-local partial aggregate (weight 1), with its own error-feedback
buffer (the pod-edge EF), exactly the paper's multi-hop recursion one
level up. DCI traffic per step drops from K_p·K_d·(segment payload)
(flat ring crosses the pod seam every wrap-around) to
K_p·(segment payload) — the staged closed forms live in
:mod:`repro.core.comm_cost` (``nested_cl_sia_bits``,
``dci_wire_flat_vs_nested``).

Semantics note (documented trade): two-stage CL-SIA applies Top-Q twice
(per-pod then cross-pod) — the composition is *not* bit-identical to the
flat 32-hop chain, but both are instances of the paper's algorithm on a
2-level tree topology; EF at both levels keeps the estimator unbiased in
the same telescoping sense, and mass conservation holds (tested).
"""

from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax

from repro import compat
from repro.core.algorithms import AggConfig
from repro.core.ring import RingStats

Array = jax.Array


@functools.lru_cache(maxsize=None)
def _pod_ring_nested_cached(k_pod: int, k_data: int):
    from repro.agg.nested import pod_ring_nested
    return pod_ring_nested(k_pod, k_data)


class HierStats(NamedTuple):
    intra: RingStats          # ICI (data-axis) accounting
    inter: RingStats          # DCI (pod-axis) accounting


def hierarchical_ring_local(
    cfg: AggConfig,
    flat_local: Array,                # [n] this rank's gradient slice
    ef_local: Array,                  # [n] client-level EF
    pod_ef_local: Array,              # [n // K_data] pod-edge EF
    weight: Array,
    *,
    data_axis: str = "data",
    pod_axis: str = "pod",
    global_mask_local: Optional[Array] = None,
    participate: Optional[Array] = None,
) -> tuple[Array, Array, Array, HierStats]:
    """Two-stage ring. Must run inside shard_map with both axes manual.

    Returns (final segment [n/(K_d·K_p)], new client EF [n],
    new pod EF [n/K_d], stats per stage). Rank (p, r, m) ends owning
    sub-segment p of segment r of its model column — matching the flat
    master sharding P(("model", "pod", "data")) after the caller's
    reordering (train/step.py uses P(("model",)+dp) with dp=(pod,data);
    the hierarchical variant owns P(("model", "data", "pod"))).

    Thin delegate: the chain×chain :class:`~repro.agg.nested.NestedPlan`
    through :func:`repro.agg.device.run_nested_segments_local` — bit-exact
    to the historic pair of ``rotated_ring_local`` calls (stage 0 is the
    ring's chain plan on ``data``, stage 1 the ring's chain plan on
    ``pod``, both on the static register path).
    """
    from repro.agg.device import run_nested_segments_local

    nested = _pod_ring_nested_cached(compat.axis_size(pod_axis),
                                     compat.axis_size(data_axis))
    seg2, ef_new, (pod_ef_new,), (st1, st2) = run_nested_segments_local(
        cfg, nested, flat_local, ef_local, (pod_ef_local,), weight,
        axes=(data_axis, pod_axis), global_mask_local=global_mask_local,
        participate=participate)
    return seg2, ef_new, pod_ef_new, HierStats(intra=st1, inter=st2)


def dci_bytes_flat_vs_hier(k_pod: int, k_data: int, payload: int) -> tuple:
    """Analytic DCI (pod-seam) wire per round: flat ring vs hierarchical.

    Flat ring over (pod, data): each of the K_p·K_d hops crosses the pod
    seam for the ranks at pod boundaries → K_p seam crossings per step ×
    K_p·K_d steps / (K_p·K_d ranks) = one seam payload per rank-step pair
    on the boundary; total seam traffic = K_p·K_d·payload per round.
    Hierarchical: only stage 2 uses DCI = K_p·payload.
    """
    return k_pod * k_data * payload, k_pod * payload
