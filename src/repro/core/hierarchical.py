"""Hierarchical (pod-aware) sparse incremental aggregation.

The flat production ring treats (pod, data) as one K=32 chain — the
paper's exact topology. DCI links between pods are scarcer than intra-pod
ICI, so the *optimized* schedule aggregates in two stages:

  stage 1: rotated ring over `data` inside each pod (K_d hops on ICI);
  stage 2: rotated ring over `pod` on the stage-1 partial aggregates
           (K_p hops on DCI, payload already CL-sparsified).

Both stages reuse :func:`repro.core.ring.rotated_ring_local` — stage 2's
"gradient" is the pod-local partial aggregate (weight 1), with its own
error-feedback buffer (the pod-edge EF), exactly the paper's multi-hop
recursion one level up. DCI traffic per step drops from
K_p·K_d·(segment payload) (flat ring crosses the pod seam every
wrap-around) to K_p·(segment payload).

Semantics note (documented trade): two-stage CL-SIA applies Top-Q twice
(per-pod then cross-pod) — the composition is *not* bit-identical to the
flat 32-hop chain, but both are instances of the paper's algorithm on a
2-level tree topology; EF at both levels keeps the estimator unbiased in
the same telescoping sense, and mass conservation holds (tested).
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro import compat
from repro.core.algorithms import AggConfig
from repro.core.ring import RingStats, rotated_ring_local

Array = jax.Array


class HierStats(NamedTuple):
    intra: RingStats          # ICI (data-axis) accounting
    inter: RingStats          # DCI (pod-axis) accounting


def hierarchical_ring_local(
    cfg: AggConfig,
    flat_local: Array,                # [n] this rank's gradient slice
    ef_local: Array,                  # [n] client-level EF
    pod_ef_local: Array,              # [n // K_data] pod-edge EF
    weight: Array,
    *,
    data_axis: str = "data",
    pod_axis: str = "pod",
    global_mask_local: Optional[Array] = None,
    participate: Optional[Array] = None,
) -> tuple[Array, Array, Array, HierStats]:
    """Two-stage ring. Must run inside shard_map with both axes manual.

    Returns (final segment [n/(K_d·K_p)], new client EF [n],
    new pod EF [n/K_d], stats per stage). Rank (p, r, m) ends owning
    sub-segment p of segment r of its model column — matching the flat
    master sharding P(("model", "pod", "data")) after the caller's
    reordering (train/step.py uses P(("model",)+dp) with dp=(pod,data);
    the hierarchical variant owns P(("model", "data", "pod"))).
    """
    # stage 1 — intra-pod ring over `data`
    seg1, ef_new, st1 = rotated_ring_local(
        cfg, flat_local, ef_local, weight, axis=data_axis,
        global_mask_local=global_mask_local, participate=participate)

    # stage 2 — inter-pod ring over `pod`, folding pod partials with the
    # same node step; weight 1 (client weights already applied in stage 1)
    mask2 = None
    if global_mask_local is not None:
        k_d = compat.axis_size(data_axis)
        n = global_mask_local.shape[0]
        seg = n // k_d
        r = jax.lax.axis_index(data_axis)
        mask2 = jax.lax.dynamic_slice(global_mask_local, (r * seg,), (seg,))
    seg2, pod_ef_new, st2 = rotated_ring_local(
        cfg, seg1, pod_ef_local, jnp.float32(1), axis=pod_axis,
        global_mask_local=mask2)
    return seg2, ef_new, pod_ef_new, HierStats(intra=st1, inter=st2)


def dci_bytes_flat_vs_hier(k_pod: int, k_data: int, payload: int) -> tuple:
    """Analytic DCI (pod-seam) wire per round: flat ring vs hierarchical.

    Flat ring over (pod, data): each of the K_p·K_d hops crosses the pod
    seam for the ranks at pod boundaries → K_p seam crossings per step ×
    K_p·K_d steps / (K_p·K_d ranks) = one seam payload per rank-step pair
    on the boundary; total seam traffic = K_p·K_d·payload per round.
    Hierarchical: only stage 2 uses DCI = K_p·payload.
    """
    return k_pod * k_data * payload, k_pod * payload
