"""Core library: the paper's sparse incremental-aggregation algorithms."""

from repro.core.algorithms import (AggConfig, AggKind, HopStats, NodeCtx,
                                   fused_node_steps, level_step, node_step)
from repro.core.chain import ChainResult, run_chain, run_chain_with_topology

# The aggregator object API lives in repro.agg (which itself builds on
# repro.core.algorithms); resolve its re-exports lazily (PEP 562) so
# `import repro.agg` and `import repro.core` can bootstrap in either order.
_AGG_API = ("AggState", "Aggregator", "ChainAggregator", "RoundOut",
            "flat_dim", "make_aggregator")

__all__ = [
    "AggConfig", "AggKind", "HopStats", "NodeCtx", "fused_node_steps",
    "level_step", "node_step",
    "ChainResult", "run_chain", "run_chain_with_topology",
    *_AGG_API,
]


def __getattr__(name):
    if name in _AGG_API:
        from repro.core import api
        return getattr(api, name)
    raise AttributeError(f"module 'repro.core' has no attribute {name!r}")
