"""Core library: the paper's sparse incremental-aggregation algorithms."""

from repro.core.algorithms import AggConfig, AggKind, HopStats, NodeCtx, node_step
from repro.core.api import (AggState, ChainAggregator, RoundOut, flat_dim,
                            make_aggregator)
from repro.core.chain import ChainResult, run_chain, run_chain_with_topology

__all__ = [
    "AggConfig", "AggKind", "HopStats", "NodeCtx", "node_step",
    "AggState", "ChainAggregator", "RoundOut", "flat_dim", "make_aggregator",
    "ChainResult", "run_chain", "run_chain_with_topology",
]
