"""Error-feedback state for sparse aggregation.

Every node k keeps ``e_k`` — the mass it has not yet managed to transmit.
The paper's algorithms all start with ``g̃_k = D_k·g_k + e_k^{t-1}`` and end
by banking whatever was cut: ``e_k^t = (pre-sparsification) − (transmitted)``.

The state is a plain flat vector per node. For the chain simulator it is a
``[K, d]`` array; for the distributed ring it is the per-rank shard. The
trainer owns it as part of TrainState and the checkpointer persists it —
losing EF state silently changes convergence (tests cover the round-trip).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

Array = jax.Array


class EFState(NamedTuple):
    """Error-feedback memory. ``e`` has shape [K, d] (sim) or [d] (per rank)."""

    e: Array

    @property
    def dim(self) -> int:
        return self.e.shape[-1]


def init_ef(num_clients: int, dim: int, dtype=jnp.float32) -> EFState:
    return EFState(e=jnp.zeros((num_clients, dim), dtype))


def init_ef_rank(dim: int, dtype=jnp.float32) -> EFState:
    """Per-rank EF state (used inside shard_map where K is implicit)."""
    return EFState(e=jnp.zeros((dim,), dtype))


def apply_feedback(g: Array, e: Array, weight: Array | float) -> Array:
    """``g̃ = D_k·g + e`` (paper line 2 of every algorithm)."""
    return weight * g + e


def residual(pre: Array, sent: Array) -> Array:
    """``e' = pre − sent``: bank the untransmitted mass."""
    return pre - sent


def total_banked(ef: EFState) -> Array:
    """Diagnostic: total |mass| currently banked across clients."""
    return jnp.sum(jnp.abs(ef.e))


def rescale_clients(ef: EFState, keep: Array) -> EFState:
    """Elastic membership change: zero EF rows of departed clients.

    ``keep`` is a bool [K] mask; new clients join with empty memory, which is
    exactly a zeroed row.
    """
    return EFState(e=jnp.where(keep[:, None], ef.e, 0))
