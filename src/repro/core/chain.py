"""Faithful sequential multi-hop chain aggregation (paper Fig. 1 semantics).

Clients are indexed 1..K with client 1 adjacent to the PS; arrays are indexed
``i = k-1`` (row 0 = client 1). The partial aggregate starts at node K
(γ_{K+1} = 0) and flows down the chain; ``lax.scan`` with ``reverse=True``
walks k = K → 1. The PS receives γ_1.

This module is the *semantics oracle*: the distributed ring (``ring.py``)
must agree with it segment-by-segment (tested).
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.algorithms import AggConfig, HopStats, NodeCtx, node_step

Array = jax.Array


class ChainResult(NamedTuple):
    aggregate: Array      # γ_1 — what the PS receives, shape [d]
    e_new: Array          # updated EF memory, [K, d]
    stats: HopStats       # stacked per-hop stats, leaves [K] (row i = client i+1)


def run_chain(
    cfg: AggConfig,
    grads: Array,                  # [K, d] per-client effective gradients g_k
    e: Array,                      # [K, d] EF memory
    weights: Array,                # [K]    D_k
    *,
    global_mask: Optional[Array] = None,   # [d] TCS mask m^t (TC algorithms)
    participate: Optional[Array] = None,   # [K] 0/1 straggler mask
) -> ChainResult:
    """One aggregation round over the K-hop chain."""
    K, d = grads.shape
    if global_mask is None:
        global_mask = jnp.zeros((d,), grads.dtype)
    if participate is None:
        participate = jnp.ones((K,), grads.dtype)
    step = node_step(cfg)

    def body(gamma, xs):
        g_k, e_k, w_k, p_k = xs
        ctx = NodeCtx(global_mask=global_mask, participate=p_k)
        gamma_out, e_new, stats = step(cfg, g_k, gamma, e_k, w_k, ctx)
        return gamma_out, (e_new, stats)

    gamma0 = jnp.zeros((d,), grads.dtype)
    gamma_final, (e_new, stats) = jax.lax.scan(
        body, gamma0, (grads, e, weights, participate), reverse=True)
    return ChainResult(aggregate=gamma_final, e_new=e_new, stats=stats)


def run_chain_with_topology(
    cfg: AggConfig,
    grads: Array,
    e: Array,
    weights: Array,
    order: Array,                  # [K] int32 — visiting order, farthest first
    *,
    global_mask: Optional[Array] = None,
    participate: Optional[Array] = None,
) -> ChainResult:
    """Chain aggregation over an arbitrary (healed) node ordering.

    ``order[j]`` is the client index visited at position j counting from the
    far end of the chain. Chain healing after a relay failure = the same K-1
    surviving clients in the same order with the dead node removed — the
    caller expresses that by setting ``participate[dead]=0`` (compute
    straggler) or by passing a shortened/permuted ``order`` (relay failure).
    EF rows and stats are returned in *client* index space.
    """
    K, d = grads.shape
    perm = order
    inv = jnp.argsort(perm)
    res = run_chain(
        cfg,
        grads[perm], e[perm], weights[perm],
        global_mask=global_mask,
        participate=None if participate is None else participate[perm],
    )
    # scan walked positions K→1; map per-position outputs back to client ids
    e_new = res.e_new[inv]
    stats = jax.tree.map(lambda s: s[inv] if s.ndim >= 1 and s.shape[0] == K else s,
                         res.stats)
    return ChainResult(aggregate=res.aggregate, e_new=e_new, stats=stats)
