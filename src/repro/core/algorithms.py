"""The paper's five sparse incremental-aggregation algorithms.

Each algorithm is a *node step*: what client k does with its own effective
gradient ``g_k`` and the incoming partial aggregate ``γ_{k+1}`` before
forwarding ``γ_k`` toward the parameter server.

All five are implemented over **dense d-vectors** (the sparse structure is in
the zero pattern) with *bit-exact* communication accounting per §V — this is
the semantics layer used by the simulator, the tests, and (per-shard) by the
distributed ring. Static-shape compact transport lives in ``ring.py``.

Two execution forms share the semantics: the scalar :func:`node_step`
(one node, one d-vector — the chain scan, the ring's register loop, the
client-per-rank device kernel) and the batched :func:`level_step` (all W
slots of a padded schedule level at once — the plan executors). Both
dispatch their sparsify+EF and IA-combine stages through the Pallas
kernels of :mod:`repro.kernels` when ``AggConfig.kernel_mode`` resolves to
them (TPU, or interpret mode under ``REPRO_PALLAS_INTERPRET=1``);
otherwise the unfused jnp bodies below run unchanged and remain the
bit-exact oracle.

Naming (paper §VI): Alg1=SIA, Alg2=RE-SIA, Alg3=CL-SIA, Alg4=TC-SIA,
Alg5=CL-TC-SIA.
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import sparsify as sp
from repro.kernels import ops as kops
from repro.kernels import ref as kref

Array = jax.Array


class AggKind(str, enum.Enum):
    SIA = "sia"                # Alg 1 (SoA baseline, [1])
    RE_SIA = "re_sia"          # Alg 2
    CL_SIA = "cl_sia"          # Alg 3
    TC_SIA = "tc_sia"          # Alg 4
    CL_TC_SIA = "cl_tc_sia"    # Alg 5
    DENSE_IA = "dense_ia"      # IA without sparsification (upper baseline)
    ROUTING = "routing"        # conventional routing (no IA; cost model only)


@dataclasses.dataclass(frozen=True)
class AggConfig:
    """Static configuration of a sparse-IA aggregator.

    ``q`` is the per-hop budget. For time-correlated variants, ``q_global``
    and ``q_local`` split it (paper: Q_L = 0.1·Q, Q_G = Q − Q_L).
    ``omega`` is the payload word size in bits (ω); index cost is
    ⌈log₂ d⌉ bits per locally-indexed nonzero.
    """

    kind: AggKind = AggKind.CL_SIA
    q: int = 78
    q_global: int = 0
    q_local: int = 0
    omega: int = 32
    # Top-Q implementation: "exact" (lax.top_k oracle) or "threshold"
    # (branch-and-bisect counting; distributable, kernel-accelerated).
    topq_impl: str = "exact"
    hist_branch: int = 64
    hist_rounds: int = 3
    # τ-search implementation for the threshold sparsifier: "scan" (the
    # streaming multi-pass branch-and-bisect) or "hist" (one joint digit
    # histogram replaces the hist_rounds sequential passes; requires
    # hist_rounds ∈ {1, 2} — per-round candidate counts and τ stay
    # bit-identical to the scan, see sparsify._hist_bisect).
    tau_impl: str = "scan"
    # ‖e'‖² reduction: "jnp" (the historic vmapped row-sum — HopStats stay
    # bit-comparable with the unfused bodies) or "kernel" (in-kernel
    # pinned pairwise-tree order, see kernels.level._pinned_tile_err — no
    # separate jnp pass over e', but a *different* documented summation
    # order).
    err_sq_mode: str = "jnp"
    # Wire dtype for compact ring transport values (f32 matches ω=32;
    # bfloat16 is the beyond-paper ω=16 quantization knob).
    wire_dtype: str = "float32"
    # Fused-kernel dispatch for the node-step hot path (repro.kernels):
    # "auto" = compiled Pallas on TPU, Pallas-interpret off-TPU only when
    # REPRO_PALLAS_INTERPRET=1, pure-jnp otherwise (the host executors stay
    # the bit-exact oracle); "always" = force the kernels (interpret mode
    # off-TPU — parity tests); "never" = force the unfused jnp reference;
    # "ref" = fused structure (whole-level steps, fused-operand τ search)
    # with the jnp reference kernel bodies — the honest host benchmark of
    # the fused data flow.
    kernel_mode: str = "auto"

    def __post_init__(self):
        if self.kind in (AggKind.TC_SIA, AggKind.CL_TC_SIA):
            if self.q_global <= 0 and self.q_local <= 0 and self.q > 0:
                # paper's default split
                ql = max(1, round(0.1 * self.q))
                object.__setattr__(self, "q_local", ql)
                object.__setattr__(self, "q_global", self.q - ql)
        # q == 0 is a degenerate-but-valid budget (nothing transmitted,
        # everything banks into EF) — it arises when a global budget is
        # split over more ring segments than it has coordinates
        # (core.ring.segment_budget clamps rather than inflate §V bits).
        # Warn loudly: a hand-built q=0 config trains a flat loss curve.
        if self.kernel_mode not in ("auto", "always", "never", "ref"):
            raise ValueError(f"unknown kernel_mode {self.kernel_mode!r} "
                             f"(expected 'auto', 'always', 'never' or "
                             f"'ref')")
        if self.tau_impl not in ("scan", "hist"):
            raise ValueError(f"unknown tau_impl {self.tau_impl!r} "
                             f"(expected 'scan' or 'hist')")
        if self.tau_impl == "hist" and self.hist_rounds not in (1, 2):
            raise ValueError(
                "tau_impl='hist' folds the whole τ search into one "
                f"histogram pass; hist_rounds must be 1 or 2, got "
                f"{self.hist_rounds}")
        if self.err_sq_mode not in ("jnp", "kernel"):
            raise ValueError(f"unknown err_sq_mode {self.err_sq_mode!r} "
                             f"(expected 'jnp' or 'kernel')")
        if self.kind not in (AggKind.DENSE_IA, AggKind.ROUTING):
            if self.q < 0:
                raise ValueError("q must be non-negative for sparsified "
                                 "aggregation")
            if self.q == 0:
                import warnings
                warnings.warn(
                    "AggConfig q=0: nothing will be transmitted and the "
                    "model will not update (valid only as the clamped "
                    "too-small-global-budget edge case)", stacklevel=2)

    def topq_fn(self) -> Callable[[Array, int], Array]:
        if self.topq_impl == "exact":
            return sp.topq
        if self.topq_impl == "threshold":
            return lambda x, q: sp.topq_by_threshold(
                x, q, branch=self.hist_branch, rounds=self.hist_rounds,
                tau_impl=self.tau_impl)
        raise ValueError(f"unknown topq_impl {self.topq_impl!r}")

    def topq_mask_fn(self) -> Callable[[Array, int], Array]:
        if self.topq_impl == "exact":
            return sp.topq_mask
        if self.topq_impl == "threshold":
            def mask(x, q):
                tau = sp.threshold_for_topq(
                    x, q, branch=self.hist_branch, rounds=self.hist_rounds,
                    tau_impl=self.tau_impl)
                return (jnp.abs(x) >= tau).astype(x.dtype)
            return mask
        raise ValueError(f"unknown topq_impl {self.topq_impl!r}")


class HopStats(NamedTuple):
    """Per-hop accounting (all traced scalars).

    ``bits`` follows §V exactly: globally-masked values cost ω each (indices
    implicit), locally-indexed nonzeros cost ω + ⌈log₂ d⌉ each.
    """

    nnz_out: Array          # ‖γ_k‖₀ transmitted by this hop
    nnz_global: Array       # ‖Γ_k‖₀ part (0 for non-TC algorithms)
    nnz_local: Array        # ‖Λ_k‖₀ part (= nnz_out for non-TC)
    bits: Array             # exact transmitted bits for this hop
    err_sq: Array           # ‖e_k^t‖² sparsification error after this hop


class NodeCtx(NamedTuple):
    """Round-constant context shared by all hops.

    ``global_mask`` is the TCS mask m^t = s(w^t − w^{t−1}, Q_G) (zeros for
    non-TC algorithms). ``participate`` ∈ {0.,1.}: straggler/failure mask —
    a non-participating node forwards γ unchanged and banks its entire g̃
    into error feedback (see DESIGN §6). ``q_budget`` (optional, traced
    int32) overrides the node's *local* Top-Q budget (``q`` / ``q_local``) —
    the bandwidth-aware path where narrow uplinks get smaller budgets; None
    keeps the static-``q`` exact Top-Q, bit-identical to the paper setting.
    """

    global_mask: Array
    participate: Array
    q_budget: Optional[Array] = None


def index_bits(d: int) -> int:
    """⌈log₂ d⌉ — bits to address one coordinate of a length-d vector."""
    import math
    return max(1, math.ceil(math.log2(d)))


def _bits(cfg: AggConfig, d: int, nnz_global: Array, nnz_local: Array) -> Array:
    # float32: bit counts for billion-parameter models overflow int32; the
    # ~2^-24 relative rounding is irrelevant for accounting.
    ib = index_bits(d)
    return (cfg.omega * nnz_global.astype(jnp.float32)
            + (cfg.omega + ib) * nnz_local.astype(jnp.float32))


def _topq_local(cfg: AggConfig, ctx: NodeCtx, x: Array, q: int) -> Array:
    """Local Top-Q values under the node's effective budget."""
    if ctx.q_budget is None:
        return cfg.topq_fn()(x, q)
    return sp.topq_dynamic(x, ctx.q_budget)


def _topq_mask_local(cfg: AggConfig, ctx: NodeCtx, x: Array, q: int) -> Array:
    """Local Top-Q mask under the node's effective budget."""
    if ctx.q_budget is None:
        return cfg.topq_mask_fn()(x, q)
    return sp.topq_mask_dynamic(x, ctx.q_budget)


# ---------------------------------------------------------------------------
# Fused whole-level node steps (the repro.kernels hot path)
#
# Each `_fused_level_*` runs one schedule level — up to W concurrent tree
# nodes, inputs [W, d] — through the batched Pallas kernels: the EF +
# sparsify and IA-combine stages stream HBM once per level instead of once
# per jnp op (per-algorithm sweep table: benchmarks/bench_round.py::
# vector_passes — e.g. CL-SIA 7 unfused → 5 fused). The
# dispatch is trace-time (`cfg.kernel_mode` × backend, see
# :func:`repro.kernels.ops.resolve`): off-TPU without
# REPRO_PALLAS_INTERPRET=1 the unfused jnp bodies below run unchanged, so
# the host executors remain the bit-exact oracle. In interpret mode the
# fused outputs are bit-exact to the unfused bodies under jit (both sides
# see XLA's FMA contraction of w·g+e; eager unfused differs by 1 ulp —
# tests/test_fused_node_step.py pins this).
#
# All five sparsified algorithms are covered. Per-lane sparsifier state
# (exact Top-Q masks, dynamic-budget sort masks, threshold-bisection τ) is
# computed through a TauOperand built from the raw node inputs
# (`_tau_operand`): the exact/dynamic paths materialize the operand (they
# need the full sort anyway), while the threshold path never does — its
# candidate counts stream through the fused-operand kernels
# (`count_ge_fused_level`, or one `hist_topq_level` pass under
# tau_impl="hist"), reconstructing |…·(w·g + e) + …| tile-by-tile in VMEM.
# ---------------------------------------------------------------------------

#: Bit counts, error-feedback rows, aggregates and nnz/bits stats of the
#: fused paths are bit-exact to the unfused bodies; err_sq defaults to the
#: same vmapped jnp reduction on both paths (err_sq_mode="jnp") to keep the
#: full HopStats comparable bitwise — err_sq_mode="kernel" swaps in the
#: in-kernel pinned pairwise-tree reduction (no extra pass over e', a
#: documented *different* summation order).

_FUSED_KINDS = (AggKind.SIA, AggKind.RE_SIA, AggKind.CL_SIA, AggKind.TC_SIA,
                AggKind.CL_TC_SIA)


def fused_node_steps(cfg: AggConfig, *operands) -> bool:
    """True when ``cfg`` dispatches node steps through the fused level path.

    Trace-time decision: the algorithm has a fused form, the resolved
    backend uses Pallas (see :func:`repro.kernels.ops.resolve`) — or
    ``kernel_mode="ref"``, which keeps the fused *structure* with the jnp
    reference kernel bodies — and the promoted compute dtype is float32
    (the kernels compute in f32; an all-bf16 operand set would change
    rounding, so it falls back to the unfused jnp path).
    """
    if cfg.kind not in _FUSED_KINDS:
        return False
    if cfg.kernel_mode != "ref" and not kops.resolve(cfg.kernel_mode)[0]:
        return False
    return (not operands
            or jnp.result_type(*operands) == jnp.float32)


def _f32(x: Array) -> Array:
    return jnp.asarray(x, jnp.float32)


def _lane_inf(w: int) -> Array:
    return jnp.full((w,), jnp.inf, jnp.float32)


def _tau_operand(cfg: AggConfig, g, e, gam, w, p, gm=None, cohorts=0, *,
                 include_gamma: bool = False) -> sp.TauOperand:
    """Build the level's bisection operand from the raw node inputs.

    The returned :class:`repro.core.sparsify.TauOperand` streams candidate
    counts (and the tau_impl="hist" digit histogram) through the
    fused-operand kernels — ``|…·(w·g + e) + …|`` is reconstructed
    tile-by-tile in VMEM, never materialized to HBM for the τ search.
    ``materialize()`` (the exact/dynamic sparsifier paths, which need the
    full sort anyway) and ``max_abs()`` use the identical float expression
    (:func:`repro.kernels.ref.fused_operand`), so every path stays bitwise
    interchangeable with the historic materialized-x search.
    """
    mode = cfg.kernel_mode

    def materialize():
        return kref.fused_operand(g, e, gam, w, p, gm,
                                  include_gamma=include_gamma,
                                  gmask_cohorts=cohorts)

    def count(taus):
        return kops.count_ge_fused_level(
            g, e, gam, w, p, taus, gm, include_gamma=include_gamma,
            gmask_cohorts=cohorts, mode=mode)

    def max_abs():
        # XLA fuses the elementwise operand into the reduce — one streaming
        # pass, no [W, d] landing in HBM; bitwise equal to a materialized
        # jnp.max(jnp.abs(x)) (same expression, same reduction)
        mag = jnp.abs(materialize())
        if not mag.size:
            return jnp.zeros(mag.shape[:-1], jnp.float32)
        return jnp.max(mag, axis=-1)

    def hist(tables):
        return kops.hist_topq_level(
            g, e, gam, w, p, tables, gm, include_gamma=include_gamma,
            gmask_cohorts=cohorts, mode=mode)

    return sp.TauOperand(count=count, max_abs=max_abs, batched=True,
                         hist=hist, materialize=materialize)


def _lane_sparsifier_state(cfg: AggConfig, operand: sp.TauOperand, q: int,
                           p: Array, qb: Optional[Array]):
    """Per-lane sparsifier state for a batched [W, d] bisection operand.

    Returns ``(mask_in, tau)`` such that ``keep = (|x| >= tau) | mask_in``
    reproduces the unfused ``_topq_local`` keep set lane by lane:

    * dynamic budgets → the sort-threshold keep mask, τ = +inf;
    * exact Top-Q     → the ``lax.top_k`` support mask, τ = +inf;
    * threshold Top-Q → mask None, τ from the batched branch-and-bisect
      over the *unmaterialized* operand (fused-operand count kernels; one
      histogram pass under ``cfg.tau_impl="hist"``).

    Non-participating lanes (p = 0) are zeroed out of mask/τ — the
    sparsify_ef stage then banks the whole g̃ into error feedback, exactly
    the unfused straggler algebra. (The CL kernels override stragglers
    internally, where this zeroing is a harmless no-op.)
    """
    w = p.shape[0]
    if qb is not None:
        mask = jax.vmap(sp.topq_mask_dynamic)(operand.materialize(), qb)
        return mask * p[:, None], _lane_inf(w)
    if cfg.topq_impl == "threshold":
        tau = sp.threshold_for_topq(
            None, q, branch=cfg.hist_branch, rounds=cfg.hist_rounds,
            operand_fn=operand, tau_impl=cfg.tau_impl)
        return None, jnp.where(p > 0, tau, jnp.inf)
    x = operand.materialize()
    mask = jax.vmap(lambda row: sp.topq_mask(row, q))(x)
    return mask * p[:, None], _lane_inf(w)


def _lane_err_sq(e_new: Array) -> Array:
    return jax.vmap(lambda v: jnp.sum(v.astype(jnp.float32) ** 2))(e_new)


def _stats_no_gmask(cfg: AggConfig, d: int, nnz: Array, e_new: Array,
                    err: Optional[Array] = None) -> HopStats:
    zeros = jnp.zeros_like(nnz)
    return HopStats(nnz_out=nnz, nnz_global=zeros, nnz_local=nnz,
                    bits=_bits(cfg, d, zeros, nnz),
                    err_sq=_lane_err_sq(e_new) if err is None else err)


def _stats_gmask(cfg: AggConfig, d: int, gm: Array, nnz: Array,
                 nnz_off: Array, e_new: Array, cohorts: int = 0,
                 err: Optional[Array] = None) -> HopStats:
    if gm.ndim == 1:       # lane-shared mask: one count, broadcast
        nz_g = jnp.broadcast_to(jnp.sum(gm > 0).astype(jnp.int32),
                                nnz.shape)
    elif cohorts and gm.shape[0] != nnz.shape[0]:
        # cohort-shared [B, d] mask over B*W cohort-major lanes: one count
        # per cohort, tiled to its W lanes — the same per-row reduction the
        # sequential lane-shared branch runs, so bitwise comparable
        nz_gc = jnp.sum(gm > 0, axis=-1).astype(jnp.int32)
        nz_g = jnp.repeat(nz_gc, nnz.shape[0] // cohorts)
    else:
        nz_g = jax.vmap(
            lambda m: jnp.sum(m > 0).astype(jnp.int32))(gm)
    return HopStats(nnz_out=nnz, nnz_global=nz_g, nnz_local=nnz_off,
                    bits=_bits(cfg, d, nz_g, nnz_off),
                    err_sq=_lane_err_sq(e_new) if err is None else err)


def _gm_rows(gm: Array, lanes: int, cohorts: int) -> Array:
    """Per-lane-broadcastable view of the gmask for jnp-side level math.

    A cohort-shared [B, d] mask (``cohorts=B``, lanes cohort-major) is
    expanded lazily to [lanes, d] — XLA fuses the equal-repeat broadcast,
    nothing lands in HBM; the kernels keep streaming the compact [B, d]
    form through their cohort-shared block spec.
    """
    if cohorts and gm.ndim == 2 and gm.shape[0] != lanes:
        return jnp.repeat(gm, lanes // cohorts, axis=0)
    return gm


def _fused_level_sia(cfg, g, gam, e, w, p, gm, qb, valid, cohorts=0):
    d = g.shape[-1]
    op = _tau_operand(cfg, g, e, None, w, p)
    mask, tau = _lane_sparsifier_state(cfg, op, cfg.q, p, qb)
    we = cfg.err_sq_mode == "kernel"
    out = kops.sparsify_ef_level(g, e, mask, w, tau, valid, with_err=we,
                                 mode=cfg.kernel_mode)
    gbar, e_new = out[0], out[1]
    gout, nnz, _ = kops.chain_accum_level(gam, gbar, valid,
                                          mode=cfg.kernel_mode)
    return gout, e_new, _stats_no_gmask(cfg, d, nnz, e_new,
                                        out[3] if we else None)


def _fused_level_re_sia(cfg, g, gam, e, w, p, gm, qb, valid, cohorts=0):
    d = g.shape[-1]
    op = _tau_operand(cfg, g, e, None, w, p)
    m_in = sp.support(gam)
    if qb is None and cfg.topq_impl == "threshold":
        _, tau = _lane_sparsifier_state(cfg, op, cfg.q, p, qb)
        mask = m_in * p[:, None]
    else:
        m_l, tau = _lane_sparsifier_state(cfg, op, cfg.q,
                                          jnp.ones_like(p), qb)
        mask = sp.mask_union(m_l, m_in) * p[:, None]
    we = cfg.err_sq_mode == "kernel"
    out = kops.sparsify_ef_level(g, e, mask, w, tau, valid, with_err=we,
                                 mode=cfg.kernel_mode)
    gbar, e_new = out[0], out[1]
    gout, nnz, _ = kops.chain_accum_level(gam, gbar, valid,
                                          mode=cfg.kernel_mode)
    return gout, e_new, _stats_no_gmask(cfg, d, nnz, e_new,
                                        out[3] if we else None)


def _fused_level_tc_sia(cfg, g, gam, e, w, p, gm, qb, valid, cohorts=0):
    d = g.shape[-1]
    gme = _gm_rows(gm, g.shape[0], cohorts)
    op = _tau_operand(cfg, g, e, None, w, p, gm, cohorts)
    m_k, tau = _lane_sparsifier_state(cfg, op, cfg.q_local,
                                      jnp.ones_like(p), qb)
    m_in = jnp.clip(sp.support(gam) - gme, 0, 1)
    if m_k is None:
        # threshold impl: materialize the local mask to union it with the
        # global/incoming masks (matches the unfused topq_mask_fn exactly)
        x = op.materialize()
        m_k = (jnp.abs(x) >= tau[:, None]).astype(x.dtype)
        tau = _lane_inf(g.shape[0])
    mm = sp.mask_union(gme, m_k, m_in)
    mask = mm * p[:, None]
    we = cfg.err_sq_mode == "kernel"
    out = kops.sparsify_ef_level(g, e, mask, w, tau, valid, with_err=we,
                                 mode=cfg.kernel_mode)
    gbar, e_new = out[0], out[1]
    gout, nnz, nnz_off = kops.chain_accum_level(gam, gbar, valid, gm,
                                                gmask_cohorts=cohorts,
                                                mode=cfg.kernel_mode)
    return gout, e_new, _stats_gmask(cfg, d, gm, nnz, nnz_off, e_new,
                                     cohorts, out[3] if we else None)


def _fused_level_cl_sia(cfg, g, gam, e, w, p, gm, qb, valid, cohorts=0):
    d = g.shape[-1]
    op = _tau_operand(cfg, g, e, gam, w, p, include_gamma=True)
    mask, tau = _lane_sparsifier_state(cfg, op, cfg.q, jnp.ones_like(p),
                                       qb)
    we = cfg.err_sq_mode == "kernel"
    out = kops.cl_fuse_level(g, e, gam, w, tau, p, valid, mask_in=mask,
                             with_err=we, mode=cfg.kernel_mode)
    gout, e_new, nnz = out[0], out[1], out[2]
    return gout, e_new, _stats_no_gmask(cfg, d, nnz, e_new,
                                        out[4] if we else None)


def _fused_level_cl_tc_sia(cfg, g, gam, e, w, p, gm, qb, valid, cohorts=0):
    d = g.shape[-1]
    op = _tau_operand(cfg, g, e, gam, w, p, gm, cohorts,
                      include_gamma=True)
    mask, tau = _lane_sparsifier_state(cfg, op, cfg.q_local,
                                       jnp.ones_like(p), qb)
    we = cfg.err_sq_mode == "kernel"
    out = kops.cl_fuse_level(
        g, e, gam, w, tau, p, valid, gmask=gm, mask_in=mask,
        gmask_cohorts=cohorts, with_err=we, mode=cfg.kernel_mode)
    gout, e_new, nnz, nnz_off = out[0], out[1], out[2], out[3]
    return gout, e_new, _stats_gmask(cfg, d, gm, nnz, nnz_off, e_new,
                                     cohorts, out[4] if we else None)


_FUSED_LEVEL = {
    AggKind.SIA: _fused_level_sia,
    AggKind.RE_SIA: _fused_level_re_sia,
    AggKind.CL_SIA: _fused_level_cl_sia,
    AggKind.TC_SIA: _fused_level_tc_sia,
    AggKind.CL_TC_SIA: _fused_level_cl_tc_sia,
}


def _run_fused_level(cfg, g, gamma_in, e, weight, participate, global_mask,
                     q_budget, valid, cohorts=0):
    w_lanes = g.shape[0]
    # a 1-D (lane-shared) TCS mask stays 1-D all the way into the kernels:
    # the level kernels stream it once per block (shared block spec)
    # instead of materializing a [W, d] broadcast in HBM; a cohort-shared
    # [B, d] mask (``cohorts=B``, lanes cohort-major) likewise streams
    # through the cohort block spec
    gm = _f32(global_mask)
    qb = None if q_budget is None else jnp.asarray(q_budget, jnp.int32)
    v = (jnp.ones((w_lanes,), jnp.float32) if valid is None
         else _f32(valid))
    gout, e_new, stats = _FUSED_LEVEL[cfg.kind](
        cfg, _f32(g), _f32(gamma_in), _f32(e), _f32(weight),
        _f32(participate), gm, qb, v, cohorts)
    # padding lanes count nothing — the kernels already zero their outputs
    # and nnz accumulators, but the jnp-side global-mask word count
    # (nnz_global → bits) is lane-agnostic and must be masked to keep the
    # fused and unfused modes interchangeable (see the unfused branch of
    # level_step)
    ok = v > 0
    stats = jax.tree.map(lambda s: jnp.where(ok, s, jnp.zeros_like(s)),
                         stats)
    return gout, e_new, stats


def _fused_scalar(cfg: AggConfig, g, gamma_in, e, weight, ctx: NodeCtx):
    """Scalar-lane (d-vector) entry into the fused level path, or None.

    Used by the per-node consumers — the sequential chain, the ring's
    register fast path, the client-per-rank device kernel — which step one
    node at a time: the node becomes a W=1 level.
    """
    if getattr(g, "ndim", 1) != 1:
        return None
    if not fused_node_steps(cfg, weight, g, e, gamma_in):
        return None
    qb = (None if ctx.q_budget is None
          else jnp.asarray(ctx.q_budget, jnp.int32).reshape(1))
    gout, e_new, stats = _run_fused_level(
        cfg, g[None], gamma_in[None], e[None],
        jnp.asarray(weight, jnp.float32).reshape(1),
        jnp.asarray(ctx.participate, jnp.float32).reshape(1),
        _f32(ctx.global_mask), qb, None)
    stats = jax.tree.map(lambda s: s[0], stats)
    if cfg.err_sq_mode == "jnp":
        # scalar-form err reduction: a vmapped row-sum accumulates in a
        # different order than the unfused scalar `_finalize` sum (1 ulp) —
        # recompute it the scalar way so HopStats stay fully bit-comparable
        # (err_sq_mode="kernel" keeps the pinned in-kernel value instead:
        # its tile-tree order is already lane-layout invariant)
        stats = stats._replace(
            err_sq=jnp.sum(e_new[0].astype(jnp.float32) ** 2))
    return gout[0], e_new[0], stats


# ---------------------------------------------------------------------------
# Node steps. Signature:  (cfg, g, gamma_in, e, weight, ctx) ->
#                         (gamma_out, e_new, HopStats)
# ---------------------------------------------------------------------------

def _finalize(cfg: AggConfig, d: int, gamma_out: Array, e_new: Array,
              global_mask: Array) -> tuple[Array, Array, HopStats]:
    lam = gamma_out * (1 - global_mask)
    nz_l = sp.nnz(lam)
    # Γ part is transmitted densely in the Q_G known slots → costs Q_G words
    # whenever a global mask is active, regardless of zero values inside it.
    nz_g = jnp.sum(global_mask > 0).astype(jnp.int32)
    stats = HopStats(
        nnz_out=sp.nnz(gamma_out),
        nnz_global=nz_g,
        nnz_local=nz_l,
        bits=_bits(cfg, d, nz_g, nz_l),
        err_sq=jnp.sum(e_new.astype(jnp.float32) ** 2),
    )
    return gamma_out, e_new, stats


def step_sia(cfg: AggConfig, g: Array, gamma_in: Array, e: Array,
             weight: Array, ctx: NodeCtx) -> tuple[Array, Array, HopStats]:
    """Alg 1 — SoA sparse IA: local Top-Q then add."""
    fused = _fused_scalar(cfg, g, gamma_in, e, weight, ctx)
    if fused is not None:
        return fused
    d = g.shape[-1]
    gt = weight * g + e                               # line 2
    gbar = _topq_local(cfg, ctx, gt, cfg.q)           # line 3
    gbar = gbar * ctx.participate
    e_new = gt - gbar                                 # line 4
    gamma_out = gbar + gamma_in                       # line 5
    return _finalize(cfg, d, gamma_out, e_new, jnp.zeros_like(g))


def step_re_sia(cfg: AggConfig, g: Array, gamma_in: Array, e: Array,
                weight: Array, ctx: NodeCtx) -> tuple[Array, Array, HopStats]:
    """Alg 2 — reduced-error: transmit inside union(local Top-Q, incoming)."""
    fused = _fused_scalar(cfg, g, gamma_in, e, weight, ctx)
    if fused is not None:
        return fused
    d = g.shape[-1]
    gt = weight * g + e                               # line 2
    m_local = _topq_mask_local(cfg, ctx, gt, cfg.q)   # line 3
    m_in = sp.support(gamma_in)                       # line 4
    m = sp.mask_union(m_local, m_in)                  # line 5
    gbar = m * gt * ctx.participate
    e_new = gt - gbar                                 # line 6
    gamma_out = gbar + gamma_in                       # line 7
    return _finalize(cfg, d, gamma_out, e_new, jnp.zeros_like(g))


def step_cl_sia(cfg: AggConfig, g: Array, gamma_in: Array, e: Array,
                weight: Array, ctx: NodeCtx) -> tuple[Array, Array, HopStats]:
    """Alg 3 — constant-length: aggregate then Top-Q. ‖γ_out‖₀ ≤ Q."""
    fused = _fused_scalar(cfg, g, gamma_in, e, weight, ctx)
    if fused is not None:
        return fused
    d = g.shape[-1]
    gt = weight * g + e                               # line 2
    gamma_tilde = ctx.participate * gt + gamma_in     # line 3
    gamma_out = _topq_local(cfg, ctx, gamma_tilde, cfg.q)   # line 4
    e_new = gamma_tilde - gamma_out                   # line 5
    # Straggler semantics (model (a), DESIGN §6): the node computed g but
    # missed the transmit deadline → γ forwarded unchanged, the *entire*
    # effective gradient g̃ banks into error feedback for later rounds.
    gamma_out = jnp.where(ctx.participate > 0, gamma_out, gamma_in)
    e_new = jnp.where(ctx.participate > 0, e_new, gt)
    return _finalize(cfg, d, gamma_out, e_new, jnp.zeros_like(g))


def step_tc_sia(cfg: AggConfig, g: Array, gamma_in: Array, e: Array,
                weight: Array, ctx: NodeCtx) -> tuple[Array, Array, HopStats]:
    """Alg 4 — time-correlated sparse IA (global mask + Q_L local + incoming)."""
    fused = _fused_scalar(cfg, g, gamma_in, e, weight, ctx)
    if fused is not None:
        return fused
    d = g.shape[-1]
    m = ctx.global_mask                                # line 3 (precomputed)
    gt = weight * g + e                                # line 2
    m_k = _topq_mask_local(cfg, ctx, (1 - m) * gt, cfg.q_local)   # line 4
    m_in = jnp.clip(sp.support(gamma_in) - m, 0, 1)    # line 5
    mm = sp.mask_union(m, m_k, m_in)                   # line 6
    gbar = mm * gt * ctx.participate
    e_new = gt - gbar                                  # line 7
    gamma_out = gamma_in + gbar                        # line 8 / eq (6)
    return _finalize(cfg, d, gamma_out, e_new, m)


def step_cl_tc_sia(cfg: AggConfig, g: Array, gamma_in: Array, e: Array,
                   weight: Array, ctx: NodeCtx) -> tuple[Array, Array, HopStats]:
    """Alg 5 — constant-length time-correlated: CL-SIA on the off-mask part.

    Γ is aggregated densely inside the global mask (cost ω·Q_G, no indices);
    the off-mask part is CL-sparsified to Q_L. See DESIGN §1 for the printed
    listing's line-5 typo and the reading used here.
    """
    fused = _fused_scalar(cfg, g, gamma_in, e, weight, ctx)
    if fused is not None:
        return fused
    d = g.shape[-1]
    m = ctx.global_mask                                # line 3
    gt = weight * g + e                                # line 2
    contrib = ctx.participate * gt
    gamma_g = m * (gamma_in + contrib)                 # line 4: Γ_k
    lam_tilde = (1 - m) * (gamma_in + contrib)         # line 5: Λ̃_k
    lam = _topq_local(cfg, ctx, lam_tilde, cfg.q_local)  # line 5: Λ_k = S(Λ̃,Q_L)
    e_new = lam_tilde - lam                            # line 6
    gamma_out = gamma_g + lam
    gamma_out = jnp.where(ctx.participate > 0, gamma_out, gamma_in)
    e_new = jnp.where(ctx.participate > 0, e_new, gt)
    return _finalize(cfg, d, gamma_out, e_new, m)


def step_dense_ia(cfg: AggConfig, g: Array, gamma_in: Array, e: Array,
                  weight: Array, ctx: NodeCtx) -> tuple[Array, Array, HopStats]:
    """IA without sparsification — the efficiency upper baseline (Fig 2b)."""
    d = g.shape[-1]
    gt = weight * g + e
    gamma_out = gamma_in + ctx.participate * gt
    e_new = jnp.where(ctx.participate > 0, jnp.zeros_like(e), gt)
    # dense transmission: d words, no index overhead
    bits = jnp.asarray(cfg.omega * d, jnp.float32)
    stats = HopStats(nnz_out=jnp.asarray(d, jnp.int32),
                     nnz_global=jnp.asarray(d, jnp.int32),
                     nnz_local=jnp.asarray(0, jnp.int32),
                     bits=bits,
                     err_sq=jnp.sum(e_new.astype(jnp.float32) ** 2))
    return gamma_out, e_new, stats


NODE_STEPS = {
    AggKind.SIA: step_sia,
    AggKind.RE_SIA: step_re_sia,
    AggKind.CL_SIA: step_cl_sia,
    AggKind.TC_SIA: step_tc_sia,
    AggKind.CL_TC_SIA: step_cl_tc_sia,
    AggKind.DENSE_IA: step_dense_ia,
}


def node_step(cfg: AggConfig):
    """Return the node-step function for ``cfg.kind``."""
    if cfg.kind == AggKind.ROUTING:
        raise ValueError(
            "ROUTING has no node step: it is a cost model (every client's "
            "sparse gradient is forwarded unmodified through all hops); use "
            "comm_cost.routing_bits / chain.run_chain with SIA for values.")
    return NODE_STEPS[cfg.kind]


def level_step(cfg: AggConfig):
    """Return the whole-level node-step function for ``cfg.kind``.

    Signature::

        fn(g [W,d], gamma_in [W,d], e [W,d], weight [W], participate [W],
           global_mask ([d] shared or [W,d] per-lane), q_budget ([W]|None),
           valid ([W]|None)) -> (gamma_out [W,d], e_new [W,d], HopStats [W])

    One call runs all W slots of a padded level schedule concurrently —
    this is what the plan executors (:func:`repro.agg.plan.execute`, the
    device lowering's level loop) step with. When the fused kernel path is
    on (:func:`fused_node_steps`) the level goes through the batched
    Pallas kernels of :mod:`repro.kernels.level`, skipping ``valid == 0``
    padding lanes; otherwise it is exactly the historic ``vmap`` of the
    scalar node step (bit-identical to the pre-fusion executors).
    """
    step = node_step(cfg)

    def run(g, gamma_in, e, weight, participate, global_mask,
            q_budget=None, valid=None):
        if fused_node_steps(cfg, weight, g, e, gamma_in):
            return _run_fused_level(cfg, g, gamma_in, e, weight,
                                    participate, global_mask, q_budget,
                                    valid)
        shared_mask = getattr(global_mask, "ndim", 1) == 1

        def one(g_r, gam_r, e_r, w_r, p_r, *rest):
            i = 0
            gm_r = global_mask
            if not shared_mask:
                gm_r = rest[i]
                i += 1
            qb_r = rest[i] if q_budget is not None else None
            ctx = NodeCtx(global_mask=gm_r, participate=p_r, q_budget=qb_r)
            return step(cfg, g_r, gam_r, e_r, w_r, ctx)

        args = [g, gamma_in, e, weight, participate]
        if not shared_mask:
            args.append(global_mask)
        if q_budget is not None:
            args.append(q_budget)
        gamma_out, e_new, stats = jax.vmap(one)(*args)
        if valid is not None:
            # same contract as the fused kernels: valid == 0 (padding)
            # lanes output zeros and count nothing, whatever garbage their
            # input rows hold — keeps the two modes interchangeable for
            # callers that don't route padding through zero dummy rows
            ok = valid > 0
            gamma_out = jnp.where(ok[:, None], gamma_out,
                                  jnp.zeros_like(gamma_out))
            e_new = jnp.where(ok[:, None], e_new, jnp.zeros_like(e_new))
            stats = jax.tree.map(
                lambda s: jnp.where(ok, s, jnp.zeros_like(s)), stats)
        return gamma_out, e_new, stats

    return run


def level_step_batched(cfg: AggConfig):
    """Whole-level node step over a cohort batch — one launch for B levels.

    Signature::

        fn(g [B,W,d], gamma_in [B,W,d], e [B,W,d], weight [B,W],
           participate [B,W],
           global_mask ([B,d] cohort-shared or [B,W,d] per-lane),
           q_budget ([B,W]|None), valid ([B,W]|None))
          -> (gamma_out [B,W,d], e_new [B,W,d], HopStats [B,W])

    B shape-identical cohorts flatten **cohort-major** to ``B*W`` lanes
    (cohort b owns lanes ``b*W .. (b+1)*W-1``) and run through a single
    :func:`level_step` launch — on the fused path that is ONE
    ``pallas_call`` per kernel stage for all cohorts, with per-cohort TC
    global masks streamed compact ([B, d], cohort-shared block spec)
    rather than vmapping the pallas_call. Every lane's math is row
    independent, so the result is bitwise identical, per cohort, to B
    sequential ``level_step`` calls (tests/test_batched_rounds.py pins
    this in interpret mode).
    """
    run1 = level_step(cfg)

    def run(g, gamma_in, e, weight, participate, global_mask,
            q_budget=None, valid=None):
        b, w, d = g.shape
        lanes = b * w

        def fl(x):
            return None if x is None else x.reshape((lanes,) + x.shape[2:])

        cohort_gm = getattr(global_mask, "ndim", 2) == 2   # [B, d]
        gf, gamf, ef = fl(g), fl(gamma_in), fl(e)
        wf, pf = fl(weight), fl(participate)
        qbf, vf = fl(q_budget), fl(valid)
        if cohort_gm and fused_node_steps(cfg, weight, g, e, gamma_in):
            gout, e_new, stats = _run_fused_level(
                cfg, gf, gamf, ef, wf, pf, _f32(global_mask), qbf, vf,
                cohorts=b)
        else:
            gm = (jnp.repeat(global_mask, w, axis=0) if cohort_gm
                  else fl(global_mask))
            gout, e_new, stats = run1(gf, gamf, ef, wf, pf, gm, qbf, vf)

        def unfl(x):
            return x.reshape((b, w) + x.shape[1:])

        return unfl(gout), unfl(e_new), jax.tree.map(unfl, stats)

    return run
