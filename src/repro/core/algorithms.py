"""The paper's five sparse incremental-aggregation algorithms.

Each algorithm is a *node step*: what client k does with its own effective
gradient ``g_k`` and the incoming partial aggregate ``γ_{k+1}`` before
forwarding ``γ_k`` toward the parameter server.

All five are implemented over **dense d-vectors** (the sparse structure is in
the zero pattern) with *bit-exact* communication accounting per §V — this is
the semantics layer used by the simulator, the tests, and (per-shard) by the
distributed ring. Static-shape compact transport lives in ``ring.py``.

Naming (paper §VI): Alg1=SIA, Alg2=RE-SIA, Alg3=CL-SIA, Alg4=TC-SIA,
Alg5=CL-TC-SIA.
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import sparsify as sp

Array = jax.Array


class AggKind(str, enum.Enum):
    SIA = "sia"                # Alg 1 (SoA baseline, [1])
    RE_SIA = "re_sia"          # Alg 2
    CL_SIA = "cl_sia"          # Alg 3
    TC_SIA = "tc_sia"          # Alg 4
    CL_TC_SIA = "cl_tc_sia"    # Alg 5
    DENSE_IA = "dense_ia"      # IA without sparsification (upper baseline)
    ROUTING = "routing"        # conventional routing (no IA; cost model only)


@dataclasses.dataclass(frozen=True)
class AggConfig:
    """Static configuration of a sparse-IA aggregator.

    ``q`` is the per-hop budget. For time-correlated variants, ``q_global``
    and ``q_local`` split it (paper: Q_L = 0.1·Q, Q_G = Q − Q_L).
    ``omega`` is the payload word size in bits (ω); index cost is
    ⌈log₂ d⌉ bits per locally-indexed nonzero.
    """

    kind: AggKind = AggKind.CL_SIA
    q: int = 78
    q_global: int = 0
    q_local: int = 0
    omega: int = 32
    # Top-Q implementation: "exact" (lax.top_k oracle) or "threshold"
    # (branch-and-bisect counting; distributable, kernel-accelerated).
    topq_impl: str = "exact"
    hist_branch: int = 64
    hist_rounds: int = 3
    # Wire dtype for compact ring transport values (f32 matches ω=32;
    # bfloat16 is the beyond-paper ω=16 quantization knob).
    wire_dtype: str = "float32"

    def __post_init__(self):
        if self.kind in (AggKind.TC_SIA, AggKind.CL_TC_SIA):
            if self.q_global <= 0 and self.q_local <= 0 and self.q > 0:
                # paper's default split
                ql = max(1, round(0.1 * self.q))
                object.__setattr__(self, "q_local", ql)
                object.__setattr__(self, "q_global", self.q - ql)
        # q == 0 is a degenerate-but-valid budget (nothing transmitted,
        # everything banks into EF) — it arises when a global budget is
        # split over more ring segments than it has coordinates
        # (core.ring.segment_budget clamps rather than inflate §V bits).
        # Warn loudly: a hand-built q=0 config trains a flat loss curve.
        if self.kind not in (AggKind.DENSE_IA, AggKind.ROUTING):
            if self.q < 0:
                raise ValueError("q must be non-negative for sparsified "
                                 "aggregation")
            if self.q == 0:
                import warnings
                warnings.warn(
                    "AggConfig q=0: nothing will be transmitted and the "
                    "model will not update (valid only as the clamped "
                    "too-small-global-budget edge case)", stacklevel=2)

    def topq_fn(self) -> Callable[[Array, int], Array]:
        if self.topq_impl == "exact":
            return sp.topq
        if self.topq_impl == "threshold":
            return lambda x, q: sp.topq_by_threshold(
                x, q, branch=self.hist_branch, rounds=self.hist_rounds)
        raise ValueError(f"unknown topq_impl {self.topq_impl!r}")

    def topq_mask_fn(self) -> Callable[[Array, int], Array]:
        if self.topq_impl == "exact":
            return sp.topq_mask
        if self.topq_impl == "threshold":
            def mask(x, q):
                tau = sp.threshold_for_topq(
                    x, q, branch=self.hist_branch, rounds=self.hist_rounds)
                return (jnp.abs(x) >= tau).astype(x.dtype)
            return mask
        raise ValueError(f"unknown topq_impl {self.topq_impl!r}")


class HopStats(NamedTuple):
    """Per-hop accounting (all traced scalars).

    ``bits`` follows §V exactly: globally-masked values cost ω each (indices
    implicit), locally-indexed nonzeros cost ω + ⌈log₂ d⌉ each.
    """

    nnz_out: Array          # ‖γ_k‖₀ transmitted by this hop
    nnz_global: Array       # ‖Γ_k‖₀ part (0 for non-TC algorithms)
    nnz_local: Array        # ‖Λ_k‖₀ part (= nnz_out for non-TC)
    bits: Array             # exact transmitted bits for this hop
    err_sq: Array           # ‖e_k^t‖² sparsification error after this hop


class NodeCtx(NamedTuple):
    """Round-constant context shared by all hops.

    ``global_mask`` is the TCS mask m^t = s(w^t − w^{t−1}, Q_G) (zeros for
    non-TC algorithms). ``participate`` ∈ {0.,1.}: straggler/failure mask —
    a non-participating node forwards γ unchanged and banks its entire g̃
    into error feedback (see DESIGN §6). ``q_budget`` (optional, traced
    int32) overrides the node's *local* Top-Q budget (``q`` / ``q_local``) —
    the bandwidth-aware path where narrow uplinks get smaller budgets; None
    keeps the static-``q`` exact Top-Q, bit-identical to the paper setting.
    """

    global_mask: Array
    participate: Array
    q_budget: Optional[Array] = None


def index_bits(d: int) -> int:
    """⌈log₂ d⌉ — bits to address one coordinate of a length-d vector."""
    import math
    return max(1, math.ceil(math.log2(d)))


def _bits(cfg: AggConfig, d: int, nnz_global: Array, nnz_local: Array) -> Array:
    # float32: bit counts for billion-parameter models overflow int32; the
    # ~2^-24 relative rounding is irrelevant for accounting.
    ib = index_bits(d)
    return (cfg.omega * nnz_global.astype(jnp.float32)
            + (cfg.omega + ib) * nnz_local.astype(jnp.float32))


def _topq_local(cfg: AggConfig, ctx: NodeCtx, x: Array, q: int) -> Array:
    """Local Top-Q values under the node's effective budget."""
    if ctx.q_budget is None:
        return cfg.topq_fn()(x, q)
    return sp.topq_dynamic(x, ctx.q_budget)


def _topq_mask_local(cfg: AggConfig, ctx: NodeCtx, x: Array, q: int) -> Array:
    """Local Top-Q mask under the node's effective budget."""
    if ctx.q_budget is None:
        return cfg.topq_mask_fn()(x, q)
    return sp.topq_mask_dynamic(x, ctx.q_budget)


# ---------------------------------------------------------------------------
# Node steps. Signature:  (cfg, g, gamma_in, e, weight, ctx) ->
#                         (gamma_out, e_new, HopStats)
# ---------------------------------------------------------------------------

def _finalize(cfg: AggConfig, d: int, gamma_out: Array, e_new: Array,
              global_mask: Array) -> tuple[Array, Array, HopStats]:
    lam = gamma_out * (1 - global_mask)
    nz_l = sp.nnz(lam)
    # Γ part is transmitted densely in the Q_G known slots → costs Q_G words
    # whenever a global mask is active, regardless of zero values inside it.
    nz_g = jnp.sum(global_mask > 0).astype(jnp.int32)
    stats = HopStats(
        nnz_out=sp.nnz(gamma_out),
        nnz_global=nz_g,
        nnz_local=nz_l,
        bits=_bits(cfg, d, nz_g, nz_l),
        err_sq=jnp.sum(e_new.astype(jnp.float32) ** 2),
    )
    return gamma_out, e_new, stats


def step_sia(cfg: AggConfig, g: Array, gamma_in: Array, e: Array,
             weight: Array, ctx: NodeCtx) -> tuple[Array, Array, HopStats]:
    """Alg 1 — SoA sparse IA: local Top-Q then add."""
    d = g.shape[-1]
    gt = weight * g + e                               # line 2
    gbar = _topq_local(cfg, ctx, gt, cfg.q)           # line 3
    gbar = gbar * ctx.participate
    e_new = gt - gbar                                 # line 4
    gamma_out = gbar + gamma_in                       # line 5
    return _finalize(cfg, d, gamma_out, e_new, jnp.zeros_like(g))


def step_re_sia(cfg: AggConfig, g: Array, gamma_in: Array, e: Array,
                weight: Array, ctx: NodeCtx) -> tuple[Array, Array, HopStats]:
    """Alg 2 — reduced-error: transmit inside union(local Top-Q, incoming)."""
    d = g.shape[-1]
    gt = weight * g + e                               # line 2
    m_local = _topq_mask_local(cfg, ctx, gt, cfg.q)   # line 3
    m_in = sp.support(gamma_in)                       # line 4
    m = sp.mask_union(m_local, m_in)                  # line 5
    gbar = m * gt * ctx.participate
    e_new = gt - gbar                                 # line 6
    gamma_out = gbar + gamma_in                       # line 7
    return _finalize(cfg, d, gamma_out, e_new, jnp.zeros_like(g))


def step_cl_sia(cfg: AggConfig, g: Array, gamma_in: Array, e: Array,
                weight: Array, ctx: NodeCtx) -> tuple[Array, Array, HopStats]:
    """Alg 3 — constant-length: aggregate then Top-Q. ‖γ_out‖₀ ≤ Q."""
    d = g.shape[-1]
    gt = weight * g + e                               # line 2
    gamma_tilde = ctx.participate * gt + gamma_in     # line 3
    gamma_out = _topq_local(cfg, ctx, gamma_tilde, cfg.q)   # line 4
    e_new = gamma_tilde - gamma_out                   # line 5
    # Straggler semantics (model (a), DESIGN §6): the node computed g but
    # missed the transmit deadline → γ forwarded unchanged, the *entire*
    # effective gradient g̃ banks into error feedback for later rounds.
    gamma_out = jnp.where(ctx.participate > 0, gamma_out, gamma_in)
    e_new = jnp.where(ctx.participate > 0, e_new, gt)
    return _finalize(cfg, d, gamma_out, e_new, jnp.zeros_like(g))


def step_tc_sia(cfg: AggConfig, g: Array, gamma_in: Array, e: Array,
                weight: Array, ctx: NodeCtx) -> tuple[Array, Array, HopStats]:
    """Alg 4 — time-correlated sparse IA (global mask + Q_L local + incoming)."""
    d = g.shape[-1]
    m = ctx.global_mask                                # line 3 (precomputed)
    gt = weight * g + e                                # line 2
    m_k = _topq_mask_local(cfg, ctx, (1 - m) * gt, cfg.q_local)   # line 4
    m_in = jnp.clip(sp.support(gamma_in) - m, 0, 1)    # line 5
    mm = sp.mask_union(m, m_k, m_in)                   # line 6
    gbar = mm * gt * ctx.participate
    e_new = gt - gbar                                  # line 7
    gamma_out = gamma_in + gbar                        # line 8 / eq (6)
    return _finalize(cfg, d, gamma_out, e_new, m)


def step_cl_tc_sia(cfg: AggConfig, g: Array, gamma_in: Array, e: Array,
                   weight: Array, ctx: NodeCtx) -> tuple[Array, Array, HopStats]:
    """Alg 5 — constant-length time-correlated: CL-SIA on the off-mask part.

    Γ is aggregated densely inside the global mask (cost ω·Q_G, no indices);
    the off-mask part is CL-sparsified to Q_L. See DESIGN §1 for the printed
    listing's line-5 typo and the reading used here.
    """
    d = g.shape[-1]
    m = ctx.global_mask                                # line 3
    gt = weight * g + e                                # line 2
    contrib = ctx.participate * gt
    gamma_g = m * (gamma_in + contrib)                 # line 4: Γ_k
    lam_tilde = (1 - m) * (gamma_in + contrib)         # line 5: Λ̃_k
    lam = _topq_local(cfg, ctx, lam_tilde, cfg.q_local)  # line 5: Λ_k = S(Λ̃,Q_L)
    e_new = lam_tilde - lam                            # line 6
    gamma_out = gamma_g + lam
    gamma_out = jnp.where(ctx.participate > 0, gamma_out, gamma_in)
    e_new = jnp.where(ctx.participate > 0, e_new, gt)
    return _finalize(cfg, d, gamma_out, e_new, m)


def step_dense_ia(cfg: AggConfig, g: Array, gamma_in: Array, e: Array,
                  weight: Array, ctx: NodeCtx) -> tuple[Array, Array, HopStats]:
    """IA without sparsification — the efficiency upper baseline (Fig 2b)."""
    d = g.shape[-1]
    gt = weight * g + e
    gamma_out = gamma_in + ctx.participate * gt
    e_new = jnp.where(ctx.participate > 0, jnp.zeros_like(e), gt)
    # dense transmission: d words, no index overhead
    bits = jnp.asarray(cfg.omega * d, jnp.float32)
    stats = HopStats(nnz_out=jnp.asarray(d, jnp.int32),
                     nnz_global=jnp.asarray(d, jnp.int32),
                     nnz_local=jnp.asarray(0, jnp.int32),
                     bits=bits,
                     err_sq=jnp.sum(e_new.astype(jnp.float32) ** 2))
    return gamma_out, e_new, stats


NODE_STEPS = {
    AggKind.SIA: step_sia,
    AggKind.RE_SIA: step_re_sia,
    AggKind.CL_SIA: step_cl_sia,
    AggKind.TC_SIA: step_tc_sia,
    AggKind.CL_TC_SIA: step_cl_tc_sia,
    AggKind.DENSE_IA: step_dense_ia,
}


def node_step(cfg: AggConfig):
    """Return the node-step function for ``cfg.kind``."""
    if cfg.kind == AggKind.ROUTING:
        raise ValueError(
            "ROUTING has no node step: it is a cost model (every client's "
            "sparse gradient is forwarded unmodified through all hops); use "
            "comm_cost.routing_bits / chain.run_chain with SIA for values.")
    return NODE_STEPS[cfg.kind]
