"""Communication-cost models (paper §V) — host-side closed forms.

These are the analytical curves the paper plots in Fig. 2; the simulator's
measured per-hop ``HopStats.bits`` must match them (tests assert it for the
deterministic algorithms and bound the stochastic ones by Prop. 2).

All functions return **bits per global iteration** for the aggregation
(uplink) phase, as Python floats.
"""

from __future__ import annotations

import math


def idx_bits(d: int) -> int:
    """⌈log₂ d⌉."""
    return max(1, math.ceil(math.log2(d)))


# ---------------------------------------------------------------------------
# Baselines
# ---------------------------------------------------------------------------

def routing_dense_bits(K: int, d: int, omega: int = 32) -> float:
    """Conventional routing, no sparsification: (K²+K)/2 dense transmissions."""
    return (K * K + K) / 2 * d * omega


def routing_sparse_bits(K: int, d: int, q: int, omega: int = 32) -> float:
    """Conventional routing of per-client Top-Q gradients.

    Client k's packet (q nonzeros, value+index each) traverses k links.
    """
    return (K * K + K) / 2 * q * (omega + idx_bits(d))


def dense_ia_bits(K: int, d: int, omega: int = 32) -> float:
    """IA without sparsification: K dense transmissions (Fig 2b upper ref)."""
    return K * d * omega


# ---------------------------------------------------------------------------
# Paper algorithms
# ---------------------------------------------------------------------------

def cl_sia_bits(K: int, d: int, q: int, omega: int = 32) -> float:
    """Alg 3: exactly Q (value+index) per hop → K·Q·(ω+⌈log₂d⌉)."""
    return K * q * (omega + idx_bits(d))


def cl_tc_sia_bits(K: int, d: int, q_global: int, q_local: int,
                   omega: int = 32) -> float:
    """Alg 5: K·ω·Q_G + K·Q_L·(ω+⌈log₂d⌉)  (§V, E‖Λ_k‖₀ = Q_L)."""
    return K * omega * q_global + K * q_local * (omega + idx_bits(d))


def expected_lambda_nnz_bound(K: int, d: int, q_global: int,
                              q_local: int) -> float:
    """Prop. 2: upper bound on Σ_k E‖Λ_k‖₀ for Alg 4 (TC-SIA).

    With Q_G=0, Q_L=Q this also bounds SIA/RE-SIA total nnz (they are
    cost-equivalent to Alg 4 with that setting, §V).
    """
    if q_local <= 0:
        return 0.0
    dp = d - q_global          # Λ lives in the off-mask coordinates
    if dp <= 0:
        return 0.0
    p = 1.0 - q_local / dp
    return dp * (K + 1 - (dp / q_local) * (1.0 - p ** (K + 1)))


def tc_sia_bits_bound(K: int, d: int, q_global: int, q_local: int,
                      omega: int = 32) -> float:
    """Eq. (7) with Prop. 2 plugged in: upper bound for Alg 4."""
    return (K * omega * q_global
            + (omega + idx_bits(d)) * expected_lambda_nnz_bound(
                K, d, q_global, q_local))


def sia_bits_bound(K: int, d: int, q: int, omega: int = 32) -> float:
    """Upper bound for Alg 1/2 (= Alg 4 with Q_G = 0, Q_L = Q)."""
    return tc_sia_bits_bound(K, d, 0, q, omega)


def sia_bits_worst_case(K: int, d: int, q: int, omega: int = 32) -> float:
    """Deterministic worst case for Alg 1/2: ‖γ_k‖₀ = min(d, (K−k+1)·Q)."""
    total_nnz = sum(min(d, j * q) for j in range(1, K + 1))
    return total_nnz * (omega + idx_bits(d))


# ---------------------------------------------------------------------------
# Tree generalizations (repro.topo) — the chain forms are the special case
# of a path graph, where depths = (1..K) and subtree sizes = (1..K).
# ---------------------------------------------------------------------------

def routing_dense_bits_tree(depths, d: int, omega: int = 32) -> float:
    """Conventional routing on a tree: client k's dense packet traverses
    ``depths[k]`` links to the PS → Σ_k depth_k · d·ω.

    On a path graph depths = (1..K) and this reduces to (K²+K)/2·d·ω.
    """
    return float(sum(depths)) * d * omega


def routing_sparse_bits_tree(depths, d: int, q: int, omega: int = 32) -> float:
    """Conventional routing of per-client Top-Q packets on a tree."""
    return float(sum(depths)) * q * (omega + idx_bits(d))


def dense_ia_bits_tree(K: int, d: int, omega: int = 32) -> float:
    """IA without sparsification on *any* tree: every client transmits its
    partial aggregate exactly once over its uplink → K·d·ω, topology
    invariant — the core IA advantage carries over from chains to trees.
    """
    return K * d * omega


def cl_sia_bits_tree(K: int, d: int, q: int, omega: int = 32) -> float:
    """Alg 3 on a tree: every uplink carries exactly Q (value+index) —
    topology invariant like the chain form."""
    return K * q * (omega + idx_bits(d))


def cl_tc_sia_bits_tree(K: int, d: int, q_global: int, q_local: int,
                        omega: int = 32) -> float:
    """Alg 5 on a tree: K·ω·Q_G + K·Q_L·(ω+⌈log₂d⌉), topology invariant."""
    return K * omega * q_global + K * q_local * (omega + idx_bits(d))


def expected_lambda_nnz_bound_tree(subtree_sizes, d: int, q_global: int,
                                   q_local: int) -> float:
    """Tree generalization of Prop. 2: Σ_k E‖Λ_k‖₀ ≤ Σ_k d′·(1 − p^{s_k}).

    ``s_k`` is the subtree size of client k (number of Top-Q_L supports
    unioned into γ_k), d′ = d − Q_G, p = 1 − Q_L/d′ — each of the s_k
    independent supports misses a given off-mask coordinate w.p. p, so
    E‖γ_k‖₀ ≤ d′(1 − p^{s_k}). With path subtree sizes (1..K) this equals
    the chain closed form :func:`expected_lambda_nnz_bound` exactly.
    """
    if q_local <= 0:
        return 0.0
    dp = d - q_global
    if dp <= 0:
        return 0.0
    p = 1.0 - q_local / dp
    return float(sum(dp * (1.0 - p ** int(s)) for s in subtree_sizes))


def tc_sia_bits_bound_tree(subtree_sizes, d: int, q_global: int,
                           q_local: int, omega: int = 32) -> float:
    """Eq. (7) with the tree Prop.-2 bound plugged in (Alg 4 on a tree)."""
    K = len(subtree_sizes)
    return (K * omega * q_global
            + (omega + idx_bits(d)) * expected_lambda_nnz_bound_tree(
                subtree_sizes, d, q_global, q_local))


def sia_bits_worst_case_tree(subtree_sizes, d: int, q: int,
                             omega: int = 32) -> float:
    """Deterministic worst case for Alg 1/2 on a tree:
    ‖γ_k‖₀ ≤ min(d, s_k·Q)."""
    total_nnz = sum(min(d, int(s) * q) for s in subtree_sizes)
    return total_nnz * (omega + idx_bits(d))


# ---------------------------------------------------------------------------
# Staged (nested/hierarchical) closed forms — repro.agg.nested plans.
# Stage 0 aggregates inside clusters over the cheap local links (pod ICI /
# intra-plane ISLs); later stages relay per-cluster partials over the
# scarce links (pod-seam DCI / inter-cluster ISLs / ground). Each stage is
# the paper's algorithm one level up, so each stage gets the §V form with
# that stage's unit count / subtree sizes. The wire SPLIT is the point:
# the flat (pod, data) ring crosses the pod seam K_p·K_d times per round,
# the staged schedule only K_p times (stage 1's hop count).
# ---------------------------------------------------------------------------

def nested_cl_sia_bits(stage_unit_counts, d: int, q: int,
                       omega: int = 32) -> tuple:
    """Alg 3 staged: stage s carries up to Q (value+index) per unit
    uplink → ``K_s·Q·(ω+⌈log₂d⌉)`` per stage. Returns per-stage bits,
    stage 0 (intra/ICI) first, last entry = the scarce-link (DCI) wire.
    Exact while every hop's γ̃ holds ≥ Q nonzeros (dense inputs, Q ≤ the
    previous stage's delivered support); an upper bound otherwise —
    stage s ≥ 1 inputs were already Top-Q'd by stage s−1, so segmented
    device rounds can undershoot (see ``bench_round.py --nested``).
    Σ over stages on a chain×chain equals the flat chain form with
    K = K_p·K_d + K_p (the extra K_p relays are the price of the split)."""
    return tuple(int(k) * q * (omega + idx_bits(d))
                 for k in stage_unit_counts)


def nested_cl_tc_sia_bits(stage_unit_counts, d: int, q_global: int,
                          q_local: int, omega: int = 32) -> tuple:
    """Alg 5 staged: per stage ``K_s·ω·Q_G + K_s·Q_L·(ω+⌈log₂d⌉)``."""
    return tuple(int(k) * omega * q_global
                 + int(k) * q_local * (omega + idx_bits(d))
                 for k in stage_unit_counts)


def nested_tc_sia_bits_bound(stage_subtree_sizes, d: int, q_global: int,
                             q_local: int, omega: int = 32) -> tuple:
    """Per-stage Prop-2 bound for the staged Alg 4 (and Alg 1/2 with
    Q_G = 0): stage s's units union Top-Q_L supports down that stage's
    subtrees, so :func:`expected_lambda_nnz_bound_tree` applies per stage
    with that stage's subtree sizes. (Across stages the supports are
    treated as independent Q_L draws — each stage re-sparsifies its
    fresh input to Q_L per hop, the same independence Prop. 2 assumes
    along one chain.)"""
    return tuple(
        float(len(sizes)) * omega * q_global
        + (omega + idx_bits(d)) * expected_lambda_nnz_bound_tree(
            sizes, d, q_global, q_local)
        for sizes in stage_subtree_sizes)


def nested_wire_split(stage_bits) -> tuple:
    """(local_bits, scarce_bits): every stage but the last rides the cheap
    intra-cluster links; the last stage is the scarce relay tier."""
    bits = [float(b) for b in stage_bits]
    return sum(bits[:-1]), bits[-1]


def dci_wire_flat_vs_nested(k_pod: int, k_data: int, d: int, q: int,
                            omega: int = 32) -> tuple:
    """Scarce-link (pod-seam DCI) §V bits per round, flat ring vs staged.

    Flat ring over (pod, data): the chain crosses the pod seam on every
    wrap-around → K_p·K_d seam payloads per round. Staged: only stage 1
    rides DCI → K_p payloads. With the CL payload ``Q·(ω+⌈log₂d⌉)`` this
    is exactly :func:`repro.core.hierarchical.dci_bytes_flat_vs_hier`
    instantiated with the §V packet size (asserted in tests)."""
    payload = q * (omega + idx_bits(d))
    return float(k_pod * k_data * payload), float(k_pod * payload)


# ---------------------------------------------------------------------------
# Normalization used in Fig. 2b
# ---------------------------------------------------------------------------

def single_transmission_bits(d: int, q: int, omega: int = 32,
                             sparse: bool = True) -> float:
    """Size of *one* gradient transmission, the Fig-2b normalizer.

    Sparse algorithms are normalized by one sparse packet (Q value+index
    pairs); dense ones by one dense vector.
    """
    if sparse:
        return q * (omega + idx_bits(d))
    return d * omega


def normalized_efficiency(total_bits: float, d: int, q: int, omega: int = 32,
                          sparse: bool = True) -> float:
    """Total transmitted data in units of single-gradient transmissions."""
    return total_bits / single_transmission_bits(d, q, omega, sparse=sparse)
