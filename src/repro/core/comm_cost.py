"""Communication-cost models (paper §V) — host-side closed forms.

These are the analytical curves the paper plots in Fig. 2; the simulator's
measured per-hop ``HopStats.bits`` must match them (tests assert it for the
deterministic algorithms and bound the stochastic ones by Prop. 2).

All functions return **bits per global iteration** for the aggregation
(uplink) phase, as Python floats.
"""

from __future__ import annotations

import math


def idx_bits(d: int) -> int:
    """⌈log₂ d⌉."""
    return max(1, math.ceil(math.log2(d)))


# ---------------------------------------------------------------------------
# Baselines
# ---------------------------------------------------------------------------

def routing_dense_bits(K: int, d: int, omega: int = 32) -> float:
    """Conventional routing, no sparsification: (K²+K)/2 dense transmissions."""
    return (K * K + K) / 2 * d * omega


def routing_sparse_bits(K: int, d: int, q: int, omega: int = 32) -> float:
    """Conventional routing of per-client Top-Q gradients.

    Client k's packet (q nonzeros, value+index each) traverses k links.
    """
    return (K * K + K) / 2 * q * (omega + idx_bits(d))


def dense_ia_bits(K: int, d: int, omega: int = 32) -> float:
    """IA without sparsification: K dense transmissions (Fig 2b upper ref)."""
    return K * d * omega


# ---------------------------------------------------------------------------
# Paper algorithms
# ---------------------------------------------------------------------------

def cl_sia_bits(K: int, d: int, q: int, omega: int = 32) -> float:
    """Alg 3: exactly Q (value+index) per hop → K·Q·(ω+⌈log₂d⌉)."""
    return K * q * (omega + idx_bits(d))


def cl_tc_sia_bits(K: int, d: int, q_global: int, q_local: int,
                   omega: int = 32) -> float:
    """Alg 5: K·ω·Q_G + K·Q_L·(ω+⌈log₂d⌉)  (§V, E‖Λ_k‖₀ = Q_L)."""
    return K * omega * q_global + K * q_local * (omega + idx_bits(d))


def expected_lambda_nnz_bound(K: int, d: int, q_global: int,
                              q_local: int) -> float:
    """Prop. 2: upper bound on Σ_k E‖Λ_k‖₀ for Alg 4 (TC-SIA).

    With Q_G=0, Q_L=Q this also bounds SIA/RE-SIA total nnz (they are
    cost-equivalent to Alg 4 with that setting, §V).
    """
    if q_local <= 0:
        return 0.0
    dp = d - q_global          # Λ lives in the off-mask coordinates
    if dp <= 0:
        return 0.0
    p = 1.0 - q_local / dp
    return dp * (K + 1 - (dp / q_local) * (1.0 - p ** (K + 1)))


def tc_sia_bits_bound(K: int, d: int, q_global: int, q_local: int,
                      omega: int = 32) -> float:
    """Eq. (7) with Prop. 2 plugged in: upper bound for Alg 4."""
    return (K * omega * q_global
            + (omega + idx_bits(d)) * expected_lambda_nnz_bound(
                K, d, q_global, q_local))


def sia_bits_bound(K: int, d: int, q: int, omega: int = 32) -> float:
    """Upper bound for Alg 1/2 (= Alg 4 with Q_G = 0, Q_L = Q)."""
    return tc_sia_bits_bound(K, d, 0, q, omega)


def sia_bits_worst_case(K: int, d: int, q: int, omega: int = 32) -> float:
    """Deterministic worst case for Alg 1/2: ‖γ_k‖₀ = min(d, (K−k+1)·Q)."""
    total_nnz = sum(min(d, j * q) for j in range(1, K + 1))
    return total_nnz * (omega + idx_bits(d))


# ---------------------------------------------------------------------------
# Normalization used in Fig. 2b
# ---------------------------------------------------------------------------

def single_transmission_bits(d: int, q: int, omega: int = 32,
                             sparse: bool = True) -> float:
    """Size of *one* gradient transmission, the Fig-2b normalizer.

    Sparse algorithms are normalized by one sparse packet (Q value+index
    pairs); dense ones by one dense vector.
    """
    if sparse:
        return q * (omega + idx_bits(d))
    return d * omega


def normalized_efficiency(total_bits: float, d: int, q: int, omega: int = 32,
                          sparse: bool = True) -> float:
    """Total transmitted data in units of single-gradient transmissions."""
    return total_bits / single_transmission_bits(d, q, omega, sparse=sparse)
