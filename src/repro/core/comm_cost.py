"""Communication-cost models (paper §V) — host-side closed forms.

These are the analytical curves the paper plots in Fig. 2; the simulator's
measured per-hop ``HopStats.bits`` must match them (tests assert it for the
deterministic algorithms and bound the stochastic ones by Prop. 2).

All functions return **bits per global iteration** for the aggregation
(uplink) phase, as Python floats.
"""

from __future__ import annotations

import math


def idx_bits(d: int) -> int:
    """⌈log₂ d⌉."""
    return max(1, math.ceil(math.log2(d)))


# ---------------------------------------------------------------------------
# Baselines
# ---------------------------------------------------------------------------

def routing_dense_bits(K: int, d: int, omega: int = 32) -> float:
    """Conventional routing, no sparsification: (K²+K)/2 dense transmissions."""
    return (K * K + K) / 2 * d * omega


def routing_sparse_bits(K: int, d: int, q: int, omega: int = 32) -> float:
    """Conventional routing of per-client Top-Q gradients.

    Client k's packet (q nonzeros, value+index each) traverses k links.
    """
    return (K * K + K) / 2 * q * (omega + idx_bits(d))


def dense_ia_bits(K: int, d: int, omega: int = 32) -> float:
    """IA without sparsification: K dense transmissions (Fig 2b upper ref)."""
    return K * d * omega


# ---------------------------------------------------------------------------
# Paper algorithms
# ---------------------------------------------------------------------------

def cl_sia_bits(K: int, d: int, q: int, omega: int = 32) -> float:
    """Alg 3: exactly Q (value+index) per hop → K·Q·(ω+⌈log₂d⌉)."""
    return K * q * (omega + idx_bits(d))


def cl_tc_sia_bits(K: int, d: int, q_global: int, q_local: int,
                   omega: int = 32) -> float:
    """Alg 5: K·ω·Q_G + K·Q_L·(ω+⌈log₂d⌉)  (§V, E‖Λ_k‖₀ = Q_L)."""
    return K * omega * q_global + K * q_local * (omega + idx_bits(d))


def expected_lambda_nnz_bound(K: int, d: int, q_global: int,
                              q_local: int) -> float:
    """Prop. 2: upper bound on Σ_k E‖Λ_k‖₀ for Alg 4 (TC-SIA).

    With Q_G=0, Q_L=Q this also bounds SIA/RE-SIA total nnz (they are
    cost-equivalent to Alg 4 with that setting, §V).
    """
    if q_local <= 0:
        return 0.0
    dp = d - q_global          # Λ lives in the off-mask coordinates
    if dp <= 0:
        return 0.0
    p = 1.0 - q_local / dp
    return dp * (K + 1 - (dp / q_local) * (1.0 - p ** (K + 1)))


def tc_sia_bits_bound(K: int, d: int, q_global: int, q_local: int,
                      omega: int = 32) -> float:
    """Eq. (7) with Prop. 2 plugged in: upper bound for Alg 4."""
    return (K * omega * q_global
            + (omega + idx_bits(d)) * expected_lambda_nnz_bound(
                K, d, q_global, q_local))


def sia_bits_bound(K: int, d: int, q: int, omega: int = 32) -> float:
    """Upper bound for Alg 1/2 (= Alg 4 with Q_G = 0, Q_L = Q)."""
    return tc_sia_bits_bound(K, d, 0, q, omega)


def sia_bits_worst_case(K: int, d: int, q: int, omega: int = 32) -> float:
    """Deterministic worst case for Alg 1/2: ‖γ_k‖₀ = min(d, (K−k+1)·Q)."""
    total_nnz = sum(min(d, j * q) for j in range(1, K + 1))
    return total_nnz * (omega + idx_bits(d))


# ---------------------------------------------------------------------------
# Tree generalizations (repro.topo) — the chain forms are the special case
# of a path graph, where depths = (1..K) and subtree sizes = (1..K).
# ---------------------------------------------------------------------------

def routing_dense_bits_tree(depths, d: int, omega: int = 32) -> float:
    """Conventional routing on a tree: client k's dense packet traverses
    ``depths[k]`` links to the PS → Σ_k depth_k · d·ω.

    On a path graph depths = (1..K) and this reduces to (K²+K)/2·d·ω.
    """
    return float(sum(depths)) * d * omega


def routing_sparse_bits_tree(depths, d: int, q: int, omega: int = 32) -> float:
    """Conventional routing of per-client Top-Q packets on a tree."""
    return float(sum(depths)) * q * (omega + idx_bits(d))


def dense_ia_bits_tree(K: int, d: int, omega: int = 32) -> float:
    """IA without sparsification on *any* tree: every client transmits its
    partial aggregate exactly once over its uplink → K·d·ω, topology
    invariant — the core IA advantage carries over from chains to trees.
    """
    return K * d * omega


def cl_sia_bits_tree(K: int, d: int, q: int, omega: int = 32) -> float:
    """Alg 3 on a tree: every uplink carries exactly Q (value+index) —
    topology invariant like the chain form."""
    return K * q * (omega + idx_bits(d))


def cl_tc_sia_bits_tree(K: int, d: int, q_global: int, q_local: int,
                        omega: int = 32) -> float:
    """Alg 5 on a tree: K·ω·Q_G + K·Q_L·(ω+⌈log₂d⌉), topology invariant."""
    return K * omega * q_global + K * q_local * (omega + idx_bits(d))


def expected_lambda_nnz_bound_tree(subtree_sizes, d: int, q_global: int,
                                   q_local: int) -> float:
    """Tree generalization of Prop. 2: Σ_k E‖Λ_k‖₀ ≤ Σ_k d′·(1 − p^{s_k}).

    ``s_k`` is the subtree size of client k (number of Top-Q_L supports
    unioned into γ_k), d′ = d − Q_G, p = 1 − Q_L/d′ — each of the s_k
    independent supports misses a given off-mask coordinate w.p. p, so
    E‖γ_k‖₀ ≤ d′(1 − p^{s_k}). With path subtree sizes (1..K) this equals
    the chain closed form :func:`expected_lambda_nnz_bound` exactly.
    """
    if q_local <= 0:
        return 0.0
    dp = d - q_global
    if dp <= 0:
        return 0.0
    p = 1.0 - q_local / dp
    return float(sum(dp * (1.0 - p ** int(s)) for s in subtree_sizes))


def tc_sia_bits_bound_tree(subtree_sizes, d: int, q_global: int,
                           q_local: int, omega: int = 32) -> float:
    """Eq. (7) with the tree Prop.-2 bound plugged in (Alg 4 on a tree)."""
    K = len(subtree_sizes)
    return (K * omega * q_global
            + (omega + idx_bits(d)) * expected_lambda_nnz_bound_tree(
                subtree_sizes, d, q_global, q_local))


def sia_bits_worst_case_tree(subtree_sizes, d: int, q: int,
                             omega: int = 32) -> float:
    """Deterministic worst case for Alg 1/2 on a tree:
    ‖γ_k‖₀ ≤ min(d, s_k·Q)."""
    total_nnz = sum(min(d, int(s) * q) for s in subtree_sizes)
    return total_nnz * (omega + idx_bits(d))


# ---------------------------------------------------------------------------
# Normalization used in Fig. 2b
# ---------------------------------------------------------------------------

def single_transmission_bits(d: int, q: int, omega: int = 32,
                             sparse: bool = True) -> float:
    """Size of *one* gradient transmission, the Fig-2b normalizer.

    Sparse algorithms are normalized by one sparse packet (Q value+index
    pairs); dense ones by one dense vector.
    """
    if sparse:
        return q * (omega + idx_bits(d))
    return d * omega


def normalized_efficiency(total_bits: float, d: int, q: int, omega: int = 32,
                          sparse: bool = True) -> float:
    """Total transmitted data in units of single-gradient transmissions."""
    return total_bits / single_transmission_bits(d, q, omega, sparse=sparse)
