"""Modality-frontend stubs (per assignment: backbone-only for [vlm]/[audio]).

The assignment specifies the transformer BACKBONE for internvl2-26b and
musicgen-medium; the modality frontends (InternViT-6B / EnCodec) are
represented by *precomputed* embeddings supplied through ``input_specs()``:

* vision: ``frontend_embeds [B, S, D]`` + ``frontend_mask [B, S]`` — mask
  marks image-patch positions whose embeddings come from the (stub) ViT;
  text positions keep their token embeddings.
* audio: ``frontend_embeds [B, S, D]`` added to EnCodec-token embeddings
  (conditioning path). MusicGen's 4-codebook delay-pattern heads are
  collapsed to the single vocab-2048 head (backbone-only, DESIGN §4).

For smoke tests/examples we generate the stub embeddings deterministically.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


def vision_stub_embeds(cfg: ModelConfig, key, batch: int, seq: int,
                       num_patches: int):
    """Deterministic fake patch embeddings occupying the first positions."""
    fe = jax.random.normal(key, (batch, seq, cfg.d_model), jnp.float32) * 0.02
    mask = (jnp.arange(seq)[None, :] < num_patches) & jnp.ones(
        (batch, 1), bool)
    return fe.astype(cfg.dtype), mask


def audio_stub_embeds(cfg: ModelConfig, key, batch: int, seq: int):
    """Deterministic fake conditioning-frame embeddings (added to tokens)."""
    fe = jax.random.normal(key, (batch, seq, cfg.d_model), jnp.float32) * 0.02
    return fe.astype(cfg.dtype)
