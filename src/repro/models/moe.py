"""Mixture-of-Experts FFN: grouped GShard-style einsum dispatch.

Two dispatch implementations were measured (EXPERIMENTS §Perf, pair B):

* scatter (``.at[e, pos].add``): memory-optimal single-device but opaque
  to GSPMD — the data-dependent scatter forces replication + 5.6 TB/step
  of gathers on mixtral prefill_32k;
* grouped one-hot einsum (this implementation, the GShard formulation):
  tokens are split into groups of ``group_size``; each group routes into
  per-group capacity buffers via one-hot einsums whose batch dims GSPMD
  shards cleanly. Dispatch-tensor memory is
  O(T × E × capacity/group) — bounded by the group size, not by T².

Token-dropping capacity semantics per group; active FLOPs ∝ top_k
(MODEL_FLOPS uses 6·N_active·D). Aux load-balancing loss = E·Σ f_e·p_e.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array

GROUP_SIZE = 1024


def moe_capacity(group: int, num_experts: int, top_k: int,
                 capacity_factor: float) -> int:
    cap = int(group * top_k * capacity_factor / num_experts)
    return max(4, (cap + 3) // 4 * 4)


def moe_ffn(params, x: Array, *, num_experts: int, top_k: int,
            capacity_factor: float, group_size: int = GROUP_SIZE):
    """x: [B, S, D] → (y [B, S, D], aux_loss scalar).

    params: router [D, E]; w_gate, w_up [E, D, F]; w_down [E, F, D].
    """
    b, s, d = x.shape
    t = b * s
    e = num_experts
    g = min(group_size, t)
    ng = t // g
    assert t % g == 0, (t, g)
    xg = x.reshape(ng, g, d)

    logits = jnp.einsum("ngd,de->nge", xg,
                        params["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    topv, topi = jax.lax.top_k(probs, top_k)              # [ng, g, k]
    topv = topv / jnp.maximum(jnp.sum(topv, -1, keepdims=True), 1e-9)

    # aux load-balance loss (Switch/Mixtral), over the pre-drop assignment
    assign = jax.nn.one_hot(topi, e, dtype=jnp.float32)   # [ng, g, k, E]
    frac_tokens = jnp.mean(assign.sum(2), axis=(0, 1))
    frac_probs = jnp.mean(probs, axis=(0, 1))
    aux = e * jnp.sum(frac_tokens * frac_probs)

    cap = moe_capacity(g, e, top_k, capacity_factor)

    # position of each assignment inside its (group, expert) buffer:
    # priority = slot order (k-major within token, tokens in order)
    flat_assign = assign.reshape(ng, g * top_k, e)        # [ng, gk, E]
    pos = jnp.cumsum(flat_assign, axis=1) - flat_assign   # exclusive prefix
    pos = jnp.sum(pos * flat_assign, axis=-1)             # [ng, gk]
    keep = (pos < cap) & (jnp.sum(flat_assign, -1) > 0)
    pos_oh = jax.nn.one_hot(jnp.where(keep, pos, cap), cap,
                            dtype=xg.dtype)               # [ng, gk, cap]
    disp = (flat_assign.astype(xg.dtype)[..., None]
            * pos_oh[..., None, :])                       # [ng, gk, E, cap]

    # dispatch: [ng, gk, E, cap] × [ng, g(k-broadcast), D] → [ng, E, cap, D]
    x_rep = jnp.repeat(xg, top_k, axis=1)                 # [ng, gk, D]
    buf = jnp.einsum("ntec,ntd->necd", disp, x_rep)

    # expert SwiGLU over [E, ng·cap, D]
    hin = jnp.moveaxis(buf, 1, 0).reshape(e, ng * cap, d)
    gate = jax.nn.silu(jnp.einsum("ecd,edf->ecf", hin, params["w_gate"]))
    up = jnp.einsum("ecd,edf->ecf", hin, params["w_up"])
    hout = jnp.einsum("ecf,efd->ecd", gate * up, params["w_down"])
    hout = jnp.moveaxis(hout.reshape(e, ng, cap, d), 0, 1)  # [ng, E, cap, D]

    # combine with router weights on kept slots
    w = (topv.reshape(ng, g * top_k) * keep).astype(hout.dtype)
    y = jnp.einsum("ntec,nt,necd->ntd", disp, w, hout)    # [ng, gk, D]
    y = y.reshape(ng, g, top_k, d).sum(axis=2)
    return y.reshape(b, s, d).astype(x.dtype), aux
