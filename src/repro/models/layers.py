"""Shared neural-net building blocks (plain-pytree, framework-free JAX)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def rms_norm(x: Array, scale: Array, eps: float = 1e-5) -> Array:
    """RMSNorm, computed in f32 regardless of input dtype."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32)).astype(x.dtype)


def swiglu(x: Array, w_gate: Array, w_up: Array, w_down: Array) -> Array:
    """SwiGLU MLP: down( silu(x·Wg) ⊙ (x·Wu) )."""
    g = jax.nn.silu(jnp.einsum("...d,df->...f", x, w_gate))
    u = jnp.einsum("...d,df->...f", x, w_up)
    return jnp.einsum("...f,fd->...d", g * u, w_down)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> Array:
    """Inverse frequencies [head_dim//2] (f32)."""
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: Array, positions: Array, theta: float) -> Array:
    """Rotate pairs. x: [..., S, H, Dh]; positions: broadcastable to [..., S]."""
    dh = x.shape[-1]
    inv = rope_freqs(dh, theta)                       # [dh/2]
    ang = positions[..., None].astype(jnp.float32) * inv  # [..., S, dh/2]
    sin = jnp.sin(ang)[..., None, :]                  # [..., S, 1, dh/2]
    cos = jnp.cos(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------

def dense_init(key, d_in: int, d_out: int, dtype) -> Array:
    scale = (2.0 / (d_in + d_out)) ** 0.5
    return (scale * jax.random.normal(key, (d_in, d_out), jnp.float32)
            ).astype(dtype)


def embed_init(key, vocab: int, dim: int, dtype) -> Array:
    return (jax.random.normal(key, (vocab, dim), jnp.float32) * 0.02
            ).astype(dtype)


def causal_conv1d(x: Array, w: Array, cache: Array | None = None):
    """Depthwise causal conv. x: [B, L, C]; w: [k, C].

    Returns (y [B, L, C], new_cache [B, k-1, C]). ``cache`` holds the last
    k−1 inputs from the previous segment (zeros at t=0).
    """
    k, c = w.shape
    b, l, _ = x.shape
    if cache is None:
        cache = jnp.zeros((b, k - 1, c), x.dtype)
    xx = jnp.concatenate([cache, x], axis=1)          # [B, L+k-1, C]
    y = sum(xx[:, i:i + l, :] * w[i][None, None, :] for i in range(k))
    new_cache = xx[:, l:l + k - 1, :]
    return y.astype(x.dtype), new_cache
