"""Sharding rules: PartitionSpecs for params, caches and batches.

Rules are divisibility-aware (DESIGN §5): a dim is sharded over the
``model`` axis only when it divides evenly AND the sharding is head-aligned
where heads matter; otherwise the leaf stays replicated over ``model`` and
GSPMD shards the *computation* along batch/seq instead. Batch shards over
(``pod``, ``data``); long-context decode (batch 1) shards the KV-cache
sequence dim over ``data`` (split-K decode).

Everything here returns specs for **pjit auto mode** — the manual ring in
``core/ring.py`` has its own flat-space layout and never consumes these.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig


def _div(n: int, k: int) -> bool:
    return k > 0 and n % k == 0


def batch_axes(mesh) -> tuple:
    """Mesh axes used for data parallelism ((pod, data) when present)."""
    names = mesh.axis_names
    return tuple(a for a in ("pod", "data") if a in names)


def _model_size(mesh) -> int:
    return mesh.shape.get("model", 1)


def param_pspecs(cfg: ModelConfig, mesh) -> Any:
    """PartitionSpec pytree matching ``transformer.init_params`` output."""
    m = _model_size(mesh)
    hq, hkv, dh = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim

    def attn_specs():
        # head-aligned TP: shard projections only if the head count divides
        q_ok = _div(hq, m)
        kv_ok = _div(hkv, m)
        s = {
            "wq": P(None, "model") if q_ok else P(None, None),
            "wk": P(None, "model") if kv_ok else P(None, None),
            "wv": P(None, "model") if kv_ok else P(None, None),
            "wo": P("model", None) if q_ok else P(None, None),
        }
        if cfg.attn_bias:
            s["bq"] = P("model") if q_ok else P(None)
            s["bk"] = P("model") if kv_ok else P(None)
            s["bv"] = P("model") if kv_ok else P(None)
        return s

    def mlp_specs():
        f_ok = _div(cfg.d_ff, m)
        s = {
            "w_up": P(None, "model") if f_ok else P(None, None),
            "w_down": P("model", None) if f_ok else P(None, None),
        }
        if cfg.mlp_type == "swiglu":
            s["w_gate"] = s["w_up"]
        return s

    def moe_specs():
        f_ok = _div(cfg.d_ff, m)
        return {
            "router": P(None, None),
            "w_gate": P(None, None, "model") if f_ok else P(None, None, None),
            "w_up": P(None, None, "model") if f_ok else P(None, None, None),
            "w_down": P(None, "model", None) if f_ok else P(None, None, None),
        }

    def mamba_specs():
        # mixed-group in_proj concat dim → replicated over model (DESIGN §5)
        return {
            "in_proj": P(None, None), "conv_w": P(None, None),
            "dt_bias": P(None), "a_log": P(None), "d_skip": P(None),
            "norm": P(None), "out_proj": P(None, None),
        }

    def stack(spec, extra_lead=1):
        return jax.tree.map(
            lambda s: P(*([None] * extra_lead), *s), spec,
            is_leaf=lambda x: isinstance(x, P))

    v_ok = _div(cfg.padded_vocab, m)
    specs: dict = {
        "embed": P("model", None) if v_ok else P(None, None),
        "final_norm": P(None),
    }
    if not cfg.tie_embeddings:
        specs["lm_head"] = P(None, "model") if v_ok else P(None, None)

    if cfg.family == "ssm":
        specs["layers"] = stack({"mamba": mamba_specs(), "ln": P(None)})
    elif cfg.family == "hybrid":
        specs["layers"] = stack({"mamba": mamba_specs(), "ln": P(None)},
                                extra_lead=2)
        trailing = cfg.num_layers % cfg.attn_every
        if trailing:
            specs["trailing"] = stack({"mamba": mamba_specs(), "ln": P(None)})
        specs["shared_attn"] = {
            "attn": attn_specs(), "mlp": mlp_specs(),
            "ln1": P(None), "ln2": P(None),
        }
    else:
        layer = {
            "attn": attn_specs(),
            "mlp": moe_specs() if cfg.family == "moe" else mlp_specs(),
            "ln1": P(None), "ln2": P(None),
        }
        specs["layers"] = stack(layer)
    return specs


def batch_pspecs(cfg: ModelConfig, mesh, global_batch: int) -> Any:
    """Specs for {tokens, labels, frontend_*} train/prefill inputs."""
    dp = batch_axes(mesh)
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]
    b_spec = dp if _div(global_batch, dp_size) else None
    out = {"tokens": P(b_spec, None), "labels": P(b_spec, None)}
    if cfg.frontend == "vision":
        out["frontend_embeds"] = P(b_spec, None, None)
        out["frontend_mask"] = P(b_spec, None)
    elif cfg.frontend == "audio":
        out["frontend_embeds"] = P(b_spec, None, None)
    return out


def cache_pspecs(cfg: ModelConfig, mesh, global_batch: int) -> Any:
    """Specs for the decode cache. Batch shards over (pod, data) when it
    divides; otherwise (long_500k, batch 1) the *sequence* dim shards over
    data (split-K decode) and SSM states replicate over data."""
    m = _model_size(mesh)
    dp = batch_axes(mesh)
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]
    b_ok = _div(global_batch, dp_size)
    kv_ok = _div(cfg.num_kv_heads, m)
    # long-context (batch 1): cache seq shards over `data` (split-K decode).
    # Non-divisible KV heads: cache seq shards over `model` instead of
    # replicating a 32k-deep cache per chip (musicgen decode: 317 GB/dev
    # before this; EXPERIMENTS §Perf it.7).
    seq_axis = None if b_ok else "data"
    if b_ok and not kv_ok:
        seq_axis = "model"

    # leaves carry 1 or 2 leading stacking dims (layers / sites×layers)
    def attn_kv(lead):
        pre = [None] * lead
        return P(*pre, dp if b_ok else None, seq_axis,
                 "model" if kv_ok else None, None)

    def conv(lead):
        pre = [None] * lead
        return P(*pre, dp if b_ok else None, None, None)

    def state(lead):
        pre = [None] * lead
        return P(*pre, dp if b_ok else None, None, None, None)

    if cfg.family == "ssm":
        return {"layers": {"conv": conv(1), "state": state(1)}}
    if cfg.family == "hybrid":
        out = {
            "layers": {"conv": conv(2), "state": state(2)},
            "shared": {"k": attn_kv(1), "v": attn_kv(1)},
        }
        if cfg.num_layers % cfg.attn_every:
            out["trailing"] = {"conv": conv(1), "state": state(1)}
        return out
    return {"layers": {"k": attn_kv(1), "v": attn_kv(1)}}
