"""Mamba2 (SSD — state-space duality) block, chunked scan + decode step.

Implements the single-group SSD recurrence
    h_t = exp(Δ_t·A) · h_{t-1} + Δ_t · B_t ⊗ x_t        (h: [H, P, N])
    y_t = C_t · h_t + D ⊙ x_t
with the chunked dual form (intra-chunk quadratic + inter-chunk state scan),
following Dao & Gu 2024 [arXiv:2405.21060]. ``naive_ssd`` is the
step-by-step recurrence oracle used by tests.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import causal_conv1d, rms_norm

Array = jax.Array


def _segsum(z: Array) -> Array:
    """Lower-triangular pairwise cumulative sums.

    z: [..., C] → out[..., i, j] = Σ_{k=j+1..i} z_k  (−inf above diagonal).
    """
    c = z.shape[-1]
    cs = jnp.cumsum(z, axis=-1)
    out = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((c, c), bool), 0)
    return jnp.where(mask, out, -jnp.inf)


def ssd_chunked(x: Array, dt: Array, a_log: Array, b: Array, c: Array,
                chunk: int):
    """Chunked SSD. Shapes:
    x:  [B, L, H, P]   (pre-discretization input)
    dt: [B, L, H]      (positive step sizes, post-softplus)
    a_log: [H]         (A = −exp(a_log) < 0)
    b, c: [B, L, N]    (single group, shared across heads)

    Returns (y [B, L, H, P], final_state [B, H, P, N]). L % chunk == 0.
    """
    bsz, l, h, p = x.shape
    n = b.shape[-1]
    nc = l // chunk
    a = -jnp.exp(a_log.astype(jnp.float32))                    # [H]

    xf = x.astype(jnp.float32) * dt[..., None]                 # Δx
    da = dt.astype(jnp.float32) * a                            # [B, L, H]

    xc = xf.reshape(bsz, nc, chunk, h, p)
    dac = da.reshape(bsz, nc, chunk, h)
    bc = b.astype(jnp.float32).reshape(bsz, nc, chunk, n)
    cc = c.astype(jnp.float32).reshape(bsz, nc, chunk, n)

    da_cum = jnp.cumsum(dac, axis=2)                           # [B,nc,C,H]

    # --- intra-chunk (diagonal blocks): y_ij = C_i·B_j · exp(Σ_{j<k<=i} da)
    ldec = jnp.exp(_segsum(jnp.moveaxis(dac, 3, 2)))           # [B,nc,H,C,C]
    scores = jnp.einsum("bzin,bzjn->bzij", cc, bc)             # [B,nc,C,C]
    att = scores[:, :, None] * ldec                            # [B,nc,H,C,C]
    y_diag = jnp.einsum("bzhij,bzjhp->bzihp", att, xc)

    # --- chunk summary states: S_z = Σ_j exp(da_cum[-1]−da_cum[j])·B_j⊗x_j
    decay_states = jnp.exp(da_cum[:, :, -1:, :] - da_cum)      # [B,nc,C,H]
    states = jnp.einsum("bzcn,bzch,bzchp->bzhpn", bc, decay_states, xc)

    # --- inter-chunk recurrence
    chunk_decay = jnp.exp(da_cum[:, :, -1])                    # [B,nc,H]

    def step(s_prev, inp):
        dec, st = inp                                          # [B,H], [B,H,P,N]
        s = s_prev * dec[..., None, None] + st
        return s, s_prev

    s0 = jnp.zeros((bsz, h, p, n), jnp.float32)
    s_final, s_prevs = jax.lax.scan(
        step, s0, (jnp.moveaxis(chunk_decay, 1, 0),
                   jnp.moveaxis(states, 1, 0)))
    s_prevs = jnp.moveaxis(s_prevs, 0, 1)                      # [B,nc,H,P,N]

    # --- inter-chunk contribution: y_i += C_i · exp(da_cum[i]) · S_prev
    state_decay = jnp.exp(da_cum)                              # [B,nc,C,H]
    y_off = jnp.einsum("bzcn,bzhpn,bzch->bzchp", cc, s_prevs, state_decay)

    y = (y_diag + y_off).reshape(bsz, l, h, p)
    return y.astype(x.dtype), s_final


def naive_ssd(x: Array, dt: Array, a_log: Array, b: Array, c: Array):
    """Step-by-step recurrence oracle (tests only; O(L) sequential)."""
    bsz, l, h, p = x.shape
    n = b.shape[-1]
    a = -jnp.exp(a_log.astype(jnp.float32))

    def step(s, inp):
        xt, dtt, bt, ct = inp
        dec = jnp.exp(dtt * a)                                 # [B,H]
        s = (s * dec[..., None, None]
             + jnp.einsum("bh,bhp,bn->bhpn", dtt, xt, bt))
        y = jnp.einsum("bn,bhpn->bhp", ct, s)
        return s, y

    s0 = jnp.zeros((bsz, h, p, n), jnp.float32)
    xs = (jnp.moveaxis(x.astype(jnp.float32), 1, 0),
          jnp.moveaxis(dt.astype(jnp.float32), 1, 0),
          jnp.moveaxis(b.astype(jnp.float32), 1, 0),
          jnp.moveaxis(c.astype(jnp.float32), 1, 0))
    s_final, ys = jax.lax.scan(step, s0, xs)
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype), s_final


def ssd_decode_step(state: Array, xt: Array, dtt: Array, a_log: Array,
                    bt: Array, ct: Array):
    """One-token SSD update. state: [B,H,P,N]; xt: [B,H,P]; dtt: [B,H];
    bt, ct: [B,N]. Returns (y [B,H,P], new_state)."""
    a = -jnp.exp(a_log.astype(jnp.float32))
    dec = jnp.exp(dtt.astype(jnp.float32) * a)
    state = (state * dec[..., None, None]
             + jnp.einsum("bh,bhp,bn->bhpn", dtt.astype(jnp.float32),
                          xt.astype(jnp.float32), bt.astype(jnp.float32)))
    y = jnp.einsum("bn,bhpn->bhp", ct.astype(jnp.float32), state)
    return y.astype(xt.dtype), state


# ---------------------------------------------------------------------------
# Full Mamba2 block (projections + conv + SSD + gated norm)
# ---------------------------------------------------------------------------

def mamba2_split(cfg, zxbcdt: Array):
    di, n, h = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    z, x, b, c, dt = jnp.split(
        zxbcdt, [di, 2 * di, 2 * di + n, 2 * di + 2 * n], axis=-1)
    return z, x, b, c, dt


def mamba2_block(params, cfg, u: Array, cache=None):
    """u: [B, L, D] → (y [B, L, D], new_cache).

    cache = {"conv": [B, k-1, d_conv], "state": [B, H, P, N]} for decode
    (L == 1) and prefill seeding; None for pure training forward.
    """
    bsz, l, _ = u.shape
    di, n, h, p = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_headdim

    zxbcdt = jnp.einsum("bld,de->ble", u, params["in_proj"])
    z, x, b, c, dt = mamba2_split(cfg, zxbcdt)

    xbc = jnp.concatenate([x, b, c], axis=-1)
    conv_cache = None if cache is None else cache["conv"]
    xbc, new_conv = causal_conv1d(xbc, params["conv_w"], conv_cache)
    xbc = jax.nn.silu(xbc)
    x, b, c = jnp.split(xbc, [di, di + n], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32))  # [B,L,H]
    xh = x.reshape(bsz, l, h, p)

    if cache is not None and l == 1:
        y, new_state = ssd_decode_step(
            cache["state"], xh[:, 0], dt[:, 0], params["a_log"],
            b[:, 0], c[:, 0])
        y = y[:, None]                                     # [B,1,H,P]
    else:
        # pad L to a chunk multiple with dt=0 steps: exp(0·A)=1 decay and
        # 0·B·x input leave the final state exact; padded outputs sliced off
        pad = (-l) % cfg.ssm_chunk
        if pad:
            xh_p = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
            dt_p = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
            b_p = jnp.pad(b, ((0, 0), (0, pad), (0, 0)))
            c_p = jnp.pad(c, ((0, 0), (0, pad), (0, 0)))
            y, new_state = ssd_chunked(xh_p, dt_p, params["a_log"], b_p,
                                       c_p, cfg.ssm_chunk)
            y = y[:, :l]
        else:
            y, new_state = ssd_chunked(xh, dt, params["a_log"], b, c,
                                       cfg.ssm_chunk)

    y = y + xh * params["d_skip"].astype(y.dtype)[None, None, :, None]
    y = y.reshape(bsz, l, di)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                 params["norm"], cfg.norm_eps)
    out = jnp.einsum("ble,ed->bld", y, params["out_proj"])
    new_cache = None
    if cache is not None:
        new_cache = {"conv": new_conv, "state": new_state}
    return out, new_cache


def mamba2_init(key, cfg, dtype):
    from repro.models.layers import dense_init
    d, di, n, h = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    keys = jax.random.split(key, 3)
    e_out = 2 * di + 2 * n + h
    return {
        "in_proj": dense_init(keys[0], d, e_out, dtype),
        "conv_w": (jax.random.normal(keys[1], (cfg.ssm_conv, di + 2 * n),
                                     jnp.float32) * 0.2).astype(dtype),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "a_log": jnp.zeros((h,), jnp.float32),             # A = −1
        "d_skip": jnp.ones((h,), jnp.float32),
        "norm": jnp.ones((di,), dtype),
        "out_proj": dense_init(keys[2], di, d, dtype),
    }


def mamba2_cache_init(cfg, batch: int, dtype):
    di, n = cfg.d_inner, cfg.ssm_state
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, di + 2 * n), dtype),
        "state": jnp.zeros((batch, cfg.ssm_heads, cfg.ssm_headdim, n),
                           jnp.float32),
    }
