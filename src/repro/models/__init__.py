"""Model substrate: decoder stacks for all assigned architecture families."""

from repro.models import model, transformer

__all__ = ["model", "transformer"]
