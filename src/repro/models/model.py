"""Model facade: embeddings + stack + head, loss, prefill/decode entrypoints."""

from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import transformer

Array = jax.Array

MOE_AUX_WEIGHT = 0.01


def embed_inputs(cfg: ModelConfig, params, tokens: Array,
                 frontend_embeds: Optional[Array] = None,
                 frontend_mask: Optional[Array] = None) -> Array:
    """Token embeddings, with the modality-stub injection points.

    vision (internvl2): positions where ``frontend_mask`` is set take the
    precomputed patch embeddings instead of the token embedding.
    audio (musicgen): precomputed frame/conditioning embeddings are *added*
    to the EnCodec-token embeddings.
    """
    h = params["embed"][tokens]
    if frontend_embeds is not None:
        fe = frontend_embeds.astype(h.dtype)
        if cfg.frontend == "vision":
            assert frontend_mask is not None
            h = jnp.where(frontend_mask[..., None], fe, h)
        elif cfg.frontend == "audio":
            h = h + fe
        else:
            raise ValueError(f"{cfg.name} has no frontend but got embeds")
    return h


def lm_logits(cfg: ModelConfig, params, h: Array) -> Array:
    """Project to the (padded) vocabulary; pad slots masked to −inf."""
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", h, params["embed"])
    else:
        logits = jnp.einsum("bsd,dv->bsv", h, params["lm_head"])
    if cfg.padded_vocab > cfg.vocab_size:
        pad_mask = jnp.arange(cfg.padded_vocab) < cfg.vocab_size
        logits = jnp.where(pad_mask, logits, -1e30)
    return logits


def forward(cfg: ModelConfig, params, tokens: Array,
            frontend_embeds=None, frontend_mask=None) -> tuple[Array, Array]:
    """Teacher-forcing forward. tokens [B, S] → (logits [B, S, V], aux)."""
    h = embed_inputs(cfg, params, tokens, frontend_embeds, frontend_mask)
    h, _, aux = transformer.run_stack(cfg, params, h)
    h = transformer.rms_norm(h, params["final_norm"], cfg.norm_eps)
    return lm_logits(cfg, params, h), aux


def loss_fn(cfg: ModelConfig, params, batch: dict) -> tuple[Array, dict]:
    """Mean next-token cross-entropy (f32) + MoE aux. batch: tokens, labels."""
    logits, aux = forward(cfg, params, batch["tokens"],
                          batch.get("frontend_embeds"),
                          batch.get("frontend_mask"))
    logits = logits.astype(jnp.float32)
    logp = jax.nn.log_softmax(logits, axis=-1)
    ll = jnp.take_along_axis(logp, batch["labels"][..., None], axis=-1)
    ce = -jnp.mean(ll)
    total = ce + MOE_AUX_WEIGHT * aux
    return total, {"ce": ce, "aux": aux}


def prefill(cfg: ModelConfig, params, tokens: Array, cache,
            frontend_embeds=None, frontend_mask=None):
    """Process a full prompt, seeding the cache. → (last_logits [B,V], cache)."""
    h = embed_inputs(cfg, params, tokens, frontend_embeds, frontend_mask)
    h, new_cache, _ = transformer.run_stack(cfg, params, h, cache=cache)
    h = transformer.rms_norm(h[:, -1:], params["final_norm"], cfg.norm_eps)
    return lm_logits(cfg, params, h)[:, 0], new_cache


def decode_step(cfg: ModelConfig, params, cache, token: Array, pos: Array):
    """One decode step. token [B] int32, pos scalar → (logits [B,V], cache)."""
    h = params["embed"][token][:, None, :]               # [B, 1, D]
    h, new_cache, _ = transformer.run_stack(cfg, params, h, cache=cache,
                                            pos=pos)
    h = transformer.rms_norm(h, params["final_norm"], cfg.norm_eps)
    return lm_logits(cfg, params, h)[:, 0], new_cache


init_params = transformer.init_params
param_specs = transformer.param_specs
init_cache = transformer.init_cache
cache_specs = transformer.cache_specs
