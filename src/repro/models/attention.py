"""Attention: GQA/MQA with RoPE; full, blocked (flash-style), SWA, decode.

Pure-jnp implementations — GSPMD shards them (heads→model, batch→data,
cache-seq→data for long-context decode; see models/partition.py). The
blocked path is the memory-bounded O(S²) streaming softmax used for ≥8k
sequences (tiles never materialize the full score matrix); tests prove
blocked ≡ plain.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.layers import apply_rope

Array = jax.Array

NEG_INF = -1e30


def _split_gqa(q: Array, num_kv: int) -> Array:
    """[B, S, Hq, Dh] → [B, S, Hkv, G, Dh]."""
    b, s, hq, dh = q.shape
    return q.reshape(b, s, num_kv, hq // num_kv, dh)


def _mesh_auto() -> dict:
    """{axis_name: size} for the *auto* axes of the current abstract mesh.

    Manual axes (e.g. `data` inside the train step's phase-1 shard_map)
    must never appear in a sharding constraint; auto axes (pjit-land
    serve/prefill paths) must be pinned explicitly or GSPMD will
    un-shard the batch inside attention loops (measured: 36 TB/step of
    batch all-gathers on granite prefill_32k; EXPERIMENTS §Perf it.8)."""
    from repro import compat
    mesh = compat.abstract_mesh()
    names = getattr(mesh, "axis_names", ()) if mesh is not None else ()
    if not names:
        return {}
    try:
        types = dict(zip(names, mesh.axis_types))
    except Exception:
        types = {n: "Auto" for n in names}
    # 0.4.x meshes carry no axis types → treat every axis as Auto. Inside a
    # partial-auto shard_map this names manual axes in constraints, which
    # 0.4.x lowers as valid manual subgroups; suppressing those constraints
    # instead crashes XLA (`Check failed: sharding.IsManualSubgroup()`,
    # reproduced on the distributed train step), so the all-Auto fallback
    # is load-bearing, not an approximation to tighten.
    return {n: mesh.shape[n] for n in names if "Auto" in str(types[n])}


def _head_axes(hkv: int, g: int):
    """Pick which of (kv, group) head dims shards over `model` (divisible
    one wins; None if neither). GSPMD drops head sharding through the GQA
    reshape in the attention backward — without an explicit constraint the
    S×S score tensors materialize with heads replicated (measured 51 GB/op
    on granite-34b; EXPERIMENTS §Perf it.5)."""
    m = _mesh_auto().get("model", 1)
    if m <= 1:
        return None, None
    if hkv % m == 0:
        return "model", None
    if g % m == 0:
        return None, "model"
    return None, None


def _batch_ax(b: int):
    """Auto DP axes to pin the batch dim to (None inside manual-dp code)."""
    auto = _mesh_auto()
    dp = tuple(a for a in ("pod", "data") if auto.get(a, 1) > 1)
    if not dp:
        return None
    tot = 1
    for a in dp:
        tot *= auto[a]
    return dp if b % tot == 0 else None


def _constrain_scores(s: Array) -> Array:
    """s: [B, Hkv, G, Sq, Sk] — pin batch + head sharding; when no head dim
    divides the model axis (phi4 24H, musicgen 24H, llama4 40H), fall back
    to sharding the query-sequence dim (sequence-parallel attention) so the
    S×S score tensors never replicate (peak 130–340 GB/dev before this;
    EXPERIMENTS §Perf it.7)."""
    b_ax = _batch_ax(s.shape[0])
    kv_ax, g_ax = _head_axes(s.shape[1], s.shape[2])
    sq_ax = None
    if kv_ax is None and g_ax is None:
        m = _mesh_auto().get("model", 1)
        if m > 1 and s.shape[3] % m == 0:
            sq_ax = "model"
    if b_ax is None and kv_ax is None and g_ax is None and sq_ax is None:
        return s
    return jax.lax.with_sharding_constraint(
        s, P(b_ax, kv_ax, g_ax, sq_ax, None))


def plain_attention(q: Array, k: Array, v: Array, *, causal: bool = True,
                    window: int = 0, q_offset: int = 0,
                    k_offset: int | Array = 0) -> Array:
    """Materialized-scores attention (used for S ≤ ~4k and as the oracle).

    q: [B, Sq, Hq, Dh]; k,v: [B, Sk, Hkv, Dh]. ``q_offset``/``k_offset``
    are the absolute positions of q[0]/k[0] (cached decoding, chunked
    prefill, SWA-sliced K spans).
    """
    b, sq, hq, dh = q.shape
    _, sk, hkv, _ = k.shape
    qg = _split_gqa(q, hkv)
    kv_ax, g_ax = _head_axes(hkv, hq // hkv)
    b_ax = _batch_ax(b)
    if b_ax is not None or kv_ax is not None or g_ax is not None:
        qg = jax.lax.with_sharding_constraint(
            qg, P(b_ax, None, kv_ax, g_ax, None))
    scale = dh ** -0.5
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg.astype(jnp.float32) * scale,
                   k.astype(jnp.float32))
    s = _constrain_scores(s)
    qpos = q_offset + jnp.arange(sq)[:, None]
    kpos = k_offset + jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= qpos >= kpos
    if window > 0:
        mask &= qpos - kpos < window
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = _constrain_scores(jax.nn.softmax(s, axis=-1))
    out = jnp.einsum("bkgqs,bskd->bqkgd", p, v.astype(jnp.float32))
    return out.reshape(b, sq, hq, dh).astype(q.dtype)


def blocked_attention(q: Array, k: Array, v: Array, *, causal: bool = True,
                      window: int = 0, q_chunk: int = 1024,
                      k_chunk: int = 1024, q_offset: int = 0) -> Array:
    """Q-blocked attention: scan over query chunks, each attending to the
    full K/V with materialized [qc × Sk] scores (O(qc·Sk) memory).

    Matches :func:`plain_attention` to f32 accuracy. A doubly-blocked
    flash-style inner KV loop was tried first and abandoned: GSPMD reshards
    the streaming-softmax carries on every inner step (measured 90112 ×
    score-sized all-gathers = 36 TB/step on granite prefill_32k;
    EXPERIMENTS §Perf it.8) — one loop level keeps shardings stable, and
    the true VMEM-tiled flash form belongs in a Pallas kernel, not XLA
    loops. ``k_chunk`` is accepted for API compatibility.
    """
    b, sq, hq, dh = q.shape
    sk = k.shape[1]
    nq = sq // q_chunk
    qb = jnp.moveaxis(q.reshape(b, nq, q_chunk, hq, dh), 1, 0)

    # SWA: each q block only sees the last (window + q_chunk) keys — slice
    # that span instead of scoring all Sk (6.4× attention-FLOP cut on
    # mixtral prefill_32k; EXPERIMENTS §Perf it.B2).
    span = window + q_chunk if window > 0 else sk
    span = min(span, sk)

    def q_block(_, xs):
        qi, qblk = xs
        q_off = q_offset + qi * q_chunk
        if span < sk:
            start = jnp.clip(q_off + q_chunk - span, 0, sk - span)
            kblk = jax.lax.dynamic_slice_in_dim(k, start, span, 1)
            vblk = jax.lax.dynamic_slice_in_dim(v, start, span, 1)
            out = plain_attention(qblk, kblk, vblk, causal=causal,
                                  window=window, q_offset=q_off,
                                  k_offset=start)
        else:
            out = plain_attention(qblk, k, v, causal=causal, window=window,
                                  q_offset=q_off)
        return None, out

    outs = jax.lax.scan(q_block, None, (jnp.arange(nq), qb))[1]
    return jnp.moveaxis(outs, 0, 1).reshape(b, sq, hq, dh).astype(q.dtype)


def decode_attention(q: Array, k_cache: Array, v_cache: Array, pos: Array,
                     *, window: int = 0, ring: bool = False) -> Array:
    """One-token attention against a cache.

    q: [B, 1, Hq, Dh]; caches: [B, Smax, Hkv, Dh]; ``pos``: current absolute
    position (scalar int32). Plain cache: entries at index ≤ pos are valid.
    Ring cache (``ring=True``): slot j holds absolute position
    pos − ((pos − j) mod Smax); valid iff j ≤ pos (warmup) — window bound is
    implicit.
    """
    b, _, hq, dh = q.shape
    _, smax, hkv, _ = k_cache.shape
    qg = _split_gqa(q, hkv).astype(jnp.float32) * dh ** -0.5
    s = _constrain_scores(
        jnp.einsum("bqkgd,bskd->bkgqs", qg, k_cache.astype(jnp.float32)))
    kpos = jnp.arange(smax)
    valid = kpos <= pos
    if window > 0 and not ring:
        valid &= kpos > pos - window
    s = jnp.where(valid[None, None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", p, v_cache.astype(jnp.float32))
    return out.reshape(b, 1, hq, dh).astype(q.dtype)


# ---------------------------------------------------------------------------
# Full attention sub-layer (projections + RoPE + cache plumbing)
# ---------------------------------------------------------------------------

def attn_project_qkv(params, x: Array, *, num_heads: int, num_kv: int,
                     head_dim: int, rope_theta: float, positions: Array):
    """x: [B, S, D] → q [B,S,Hq,Dh], k,v [B,S,Hkv,Dh], RoPE applied."""
    b, s, _ = x.shape
    q = jnp.einsum("bsd,dh->bsh", x, params["wq"])
    k = jnp.einsum("bsd,dh->bsh", x, params["wk"])
    v = jnp.einsum("bsd,dh->bsh", x, params["wv"])
    if "bq" in params:
        q = q + params["bq"]
        k = k + params["bk"]
        v = v + params["bv"]
    q = q.reshape(b, s, num_heads, head_dim)
    k = k.reshape(b, s, num_kv, head_dim)
    v = v.reshape(b, s, num_kv, head_dim)
    q = apply_rope(q, positions, rope_theta)
    k = apply_rope(k, positions, rope_theta)
    return q, k, v


def attn_out(params, o: Array) -> Array:
    b, s, h, dh = o.shape
    return jnp.einsum("bsh,hd->bsd", o.reshape(b, s, h * dh), params["wo"])


def run_attention(params, x: Array, *, cfg_heads: int, cfg_kv: int,
                  head_dim: int, rope_theta: float, window: int,
                  cache=None, pos=None, blocked_threshold: int = 8192,
                  q_chunk: int = 1024, k_chunk: int = 1024):
    """Full attention sub-layer.

    Modes:
    * train/prefill: ``cache is None`` → causal self-attention over x; if a
      cache dict is passed with ``pos is None`` the new K/V are returned for
      cache seeding (prefill).
    * decode: ``cache`` + scalar ``pos`` → one-token step, cache updated.

    Returns (out [B,S,D], new_cache_or_None).
    """
    b, s, _ = x.shape
    if pos is None:
        positions = jnp.arange(s)[None, :]
    else:
        positions = jnp.full((b, s), pos)[..., :]
    q, k, v = attn_project_qkv(
        params, x, num_heads=cfg_heads, num_kv=cfg_kv, head_dim=head_dim,
        rope_theta=rope_theta, positions=positions)

    if cache is not None and pos is not None:
        # decode step. SWA caches are ring buffers of length == window:
        # slot = pos % smax; validity slot_pos <= pos covers both the warmup
        # and the steady state, and the window bound is implicit for ring
        # buffers (only the last `window` tokens are retained).
        smax = cache["k"].shape[1]
        slot = pos % smax
        k_cache = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, slot, 1)
        v_cache = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, slot, 1)
        eff_window = window if (window == 0 or smax > window) else 0
        o = decode_attention(q, k_cache, v_cache, pos, window=eff_window,
                             ring=smax <= max(window, 0) and window > 0)
        return attn_out(params, o), {"k": k_cache, "v": v_cache}

    if s >= blocked_threshold:
        o = blocked_attention(q, k, v, causal=True, window=window,
                              q_chunk=q_chunk, k_chunk=k_chunk)
    else:
        o = plain_attention(q, k, v, causal=True, window=window)
    new_cache = None
    if cache is not None:
        smax = cache["k"].shape[1]
        if smax < s:
            # SWA ring cache shorter than the prompt: keep the last smax
            # tokens; slot alignment requires s % smax == 0 (configs comply).
            assert s % smax == 0, (s, smax)
            kc, vc = k[:, -smax:], v[:, -smax:]
        else:
            kc = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, 0, 1)
            vc = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, 0, 1)
        new_cache = {"k": kc.astype(cache["k"].dtype),
                     "v": vc.astype(cache["v"].dtype)}
    return attn_out(params, o), new_cache
