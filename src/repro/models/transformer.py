"""Decoder stacks for all assigned families (dense/moe/ssm/hybrid/vlm/audio).

Scan-over-layers with stacked parameters keeps the HLO size O(1) in depth —
essential for 40-cell × 2-mesh dry-run compile times — with optional remat
of the scan body. The hybrid (zamba2-style) stack is structured as
``n_sites`` super-blocks (attn_every mamba layers + one *shared* attention
block) plus trailing mamba layers, so the shared block's KV cache is
per-site, not per-layer (DESIGN §4).
"""

from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import ssm
from repro.models.attention import run_attention
from repro.models.layers import dense_init, embed_init, rms_norm, swiglu
from repro.models.moe import moe_ffn

Array = jax.Array
Params = Any


# ---------------------------------------------------------------------------
# Per-layer init
# ---------------------------------------------------------------------------

def _attn_init(key, cfg: ModelConfig, dtype):
    d, hq, hkv, dh = (cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
                      cfg.resolved_head_dim)
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], d, hq * dh, dtype),
        "wk": dense_init(ks[1], d, hkv * dh, dtype),
        "wv": dense_init(ks[2], d, hkv * dh, dtype),
        "wo": dense_init(ks[3], hq * dh, d, dtype),
    }
    if cfg.attn_bias:
        p["bq"] = jnp.zeros((hq * dh,), dtype)
        p["bk"] = jnp.zeros((hkv * dh,), dtype)
        p["bv"] = jnp.zeros((hkv * dh,), dtype)
    return p


def _mlp_init(key, cfg: ModelConfig, dtype):
    ks = jax.random.split(key, 3)
    d, f = cfg.d_model, cfg.d_ff
    p = {
        "w_up": dense_init(ks[1], d, f, dtype),
        "w_down": dense_init(ks[2], f, d, dtype),
    }
    if cfg.mlp_type == "swiglu":
        p["w_gate"] = dense_init(ks[0], d, f, dtype)
    return p


def _mlp_apply(cfg: ModelConfig, params, x):
    if cfg.mlp_type == "swiglu":
        return swiglu(x, params["w_gate"], params["w_up"], params["w_down"])
    h = jax.nn.gelu(jnp.einsum("...d,df->...f", x, params["w_up"]))
    return jnp.einsum("...f,fd->...d", h, params["w_down"])


def _moe_init(key, cfg: ModelConfig, dtype):
    ks = jax.random.split(key, 4)
    d, f, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    def experts(k, din, dout):
        return (jax.vmap(lambda kk: dense_init(kk, din, dout, dtype))
                (jax.random.split(k, e)))
    return {
        "router": dense_init(ks[0], d, e, jnp.float32),
        "w_gate": experts(ks[1], d, f),
        "w_up": experts(ks[2], d, f),
        "w_down": experts(ks[3], f, d),
    }


def _dense_layer_init(key, cfg: ModelConfig, dtype):
    ks = jax.random.split(key, 2)
    return {
        "attn": _attn_init(ks[0], cfg, dtype),
        "mlp": (_moe_init(ks[1], cfg, dtype) if cfg.family == "moe"
                else _mlp_init(ks[1], cfg, dtype)),
        "ln1": jnp.ones((cfg.d_model,), dtype),
        "ln2": jnp.ones((cfg.d_model,), dtype),
    }


def _mamba_layer_init(key, cfg: ModelConfig, dtype):
    return {
        "mamba": ssm.mamba2_init(key, cfg, dtype),
        "ln": jnp.ones((cfg.d_model,), dtype),
    }


# ---------------------------------------------------------------------------
# Per-layer apply
# ---------------------------------------------------------------------------

def _dense_layer(cfg: ModelConfig, params, h, cache=None, pos=None):
    """Pre-LN transformer layer; returns (h, new_cache, aux)."""
    x = rms_norm(h, params["ln1"], cfg.norm_eps)
    o, new_cache = run_attention(
        params["attn"], x, cfg_heads=cfg.num_heads, cfg_kv=cfg.num_kv_heads,
        head_dim=cfg.resolved_head_dim, rope_theta=cfg.rope_theta,
        window=cfg.sliding_window, cache=cache, pos=pos)
    h = h + o
    x = rms_norm(h, params["ln2"], cfg.norm_eps)
    if cfg.family == "moe":
        y, aux = moe_ffn(params["mlp"], x, num_experts=cfg.num_experts,
                         top_k=cfg.num_experts_per_tok,
                         capacity_factor=cfg.capacity_factor)
    else:
        y = _mlp_apply(cfg, params["mlp"], x)
        aux = jnp.float32(0)
    return h + y, new_cache, aux


def _mamba_layer(cfg: ModelConfig, params, h, cache=None):
    x = rms_norm(h, params["ln"], cfg.norm_eps)
    y, new_cache = ssm.mamba2_block(params["mamba"], cfg, x, cache)
    return h + y, new_cache


# ---------------------------------------------------------------------------
# Stack init
# ---------------------------------------------------------------------------

def init_params(cfg: ModelConfig, key) -> Params:
    dtype = cfg.dtype
    k_embed, k_layers, k_shared, k_head = jax.random.split(key, 4)
    params: dict = {
        "embed": embed_init(k_embed, cfg.padded_vocab, cfg.d_model, dtype),
        "final_norm": jnp.ones((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(k_head, cfg.d_model,
                                       cfg.padded_vocab, dtype)
    L = cfg.num_layers
    if cfg.family in ("ssm",):
        params["layers"] = jax.vmap(
            lambda k: _mamba_layer_init(k, cfg, dtype))(
                jax.random.split(k_layers, L))
    elif cfg.family == "hybrid":
        n_sites = L // cfg.attn_every
        trailing = L - n_sites * cfg.attn_every
        site_keys = jax.random.split(k_layers, n_sites * cfg.attn_every)
        site_params = jax.vmap(lambda k: _mamba_layer_init(k, cfg, dtype))(
            site_keys)
        params["layers"] = jax.tree.map(
            lambda a: a.reshape(n_sites, cfg.attn_every, *a.shape[1:]),
            site_params)
        if trailing:
            params["trailing"] = jax.vmap(
                lambda k: _mamba_layer_init(k, cfg, dtype))(
                    jax.random.split(jax.random.fold_in(k_layers, 1),
                                     trailing))
        ks = jax.random.split(k_shared, 2)
        params["shared_attn"] = {
            "attn": _attn_init(ks[0], cfg, dtype),
            "mlp": _mlp_init(ks[1], cfg, dtype),
            "ln1": jnp.ones((cfg.d_model,), dtype),
            "ln2": jnp.ones((cfg.d_model,), dtype),
        }
    else:  # dense / moe / vlm / audio share the dense-stack structure
        params["layers"] = jax.vmap(
            lambda k: _dense_layer_init(k, cfg, dtype))(
                jax.random.split(k_layers, L))
    return params


def param_specs(cfg: ModelConfig) -> Params:
    """ShapeDtypeStruct pytree of the params (dry-run: no allocation)."""
    return jax.eval_shape(
        lambda: init_params(cfg, jax.random.PRNGKey(0)))


# ---------------------------------------------------------------------------
# Caches
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Any:
    dtype = cfg.dtype
    hkv, dh = cfg.num_kv_heads, cfg.resolved_head_dim
    # SWA needs only the last `window` tokens → ring buffer (attention.py)
    eff_len = (min(max_len, cfg.sliding_window) if cfg.sliding_window > 0
               else max_len)

    def attn_cache():
        return {"k": jnp.zeros((batch, eff_len, hkv, dh), dtype),
                "v": jnp.zeros((batch, eff_len, hkv, dh), dtype)}

    if cfg.family == "ssm":
        one = ssm.mamba2_cache_init(cfg, batch, dtype)
        return {"layers": jax.tree.map(
            lambda a: jnp.broadcast_to(a, (cfg.num_layers, *a.shape)), one)}
    if cfg.family == "hybrid":
        n_sites = cfg.num_layers // cfg.attn_every
        trailing = cfg.num_layers - n_sites * cfg.attn_every
        one = ssm.mamba2_cache_init(cfg, batch, dtype)
        cache = {
            "layers": jax.tree.map(
                lambda a: jnp.broadcast_to(
                    a, (n_sites, cfg.attn_every, *a.shape)), one),
            "shared": jax.tree.map(
                lambda a: jnp.broadcast_to(a, (n_sites, *a.shape)),
                attn_cache()),
        }
        if trailing:
            cache["trailing"] = jax.tree.map(
                lambda a: jnp.broadcast_to(a, (trailing, *a.shape)), one)
        return cache
    return {"layers": jax.tree.map(
        lambda a: jnp.broadcast_to(a, (cfg.num_layers, *a.shape)),
        attn_cache())}


def cache_specs(cfg: ModelConfig, batch: int, max_len: int):
    return jax.eval_shape(lambda: init_cache(cfg, batch, max_len))


# ---------------------------------------------------------------------------
# Stack apply
# ---------------------------------------------------------------------------

def _maybe_remat(fn, cfg: ModelConfig):
    return jax.checkpoint(fn) if cfg.remat else fn


def _remat_groups(cfg: ModelConfig, num_layers: int) -> int:
    """Largest divisor of L that is ≤ ⌈√L⌉ (√L checkpointing group count)."""
    if not (cfg.remat and cfg.nested_remat) or num_layers < 4:
        return 1
    import math
    cap = math.isqrt(num_layers - 1) + 1
    best = 1
    for g in range(2, cap + 1):
        if num_layers % g == 0:
            best = g
    return best


def _scan_layers(cfg: ModelConfig, body, carry, stacked):
    """Scan over stacked layer params with optional √L nested remat.

    ``body(carry, layer_params) → carry`` (no per-layer outputs — used by
    the no-cache training path where only the carry matters).
    """
    leaves = jax.tree.leaves(stacked)
    num_layers = leaves[0].shape[0]
    g = _remat_groups(cfg, num_layers)

    def body_scan(c, p_i):
        return body(c, p_i), None

    if g == 1:
        carry, _ = jax.lax.scan(_maybe_remat(body_scan, cfg), carry, stacked)
        return carry

    grouped = jax.tree.map(
        lambda a: a.reshape(g, num_layers // g, *a.shape[1:]), stacked)

    def group_body(c, group_params):
        c, _ = jax.lax.scan(_maybe_remat(body_scan, cfg), c, group_params)
        return c, None

    carry, _ = jax.lax.scan(_maybe_remat(group_body, cfg), carry, grouped)
    return carry


def _shared_block(cfg: ModelConfig, params, h, cache=None, pos=None):
    """Zamba2-style shared transformer block (attn + MLP)."""
    x = rms_norm(h, params["ln1"], cfg.norm_eps)
    o, new_cache = run_attention(
        params["attn"], x, cfg_heads=cfg.num_heads, cfg_kv=cfg.num_kv_heads,
        head_dim=cfg.resolved_head_dim, rope_theta=cfg.rope_theta,
        window=cfg.sliding_window, cache=cache, pos=pos)
    h = h + o
    x = rms_norm(h, params["ln2"], cfg.norm_eps)
    y = _mlp_apply(cfg, params["mlp"], x)
    return h + y, new_cache


def run_stack(cfg: ModelConfig, params, h: Array, cache=None,
              pos: Optional[Array] = None):
    """h: [B, S, D] embeddings → (h, new_cache, aux). cache/pos per decode."""
    aux_total = jnp.float32(0)

    if cfg.family == "ssm":
        def body(carry, xs):
            hh = carry
            p_i, c_i = xs
            hh, c_new = _mamba_layer(cfg, p_i, hh, c_i)
            return hh, c_new
        caches = None if cache is None else cache["layers"]
        if caches is None:
            h = _scan_layers(
                cfg, lambda hh, p_i: _mamba_layer(cfg, p_i, hh, None)[0],
                h, params["layers"])
            return h, None, aux_total
        h, new_caches = jax.lax.scan(_maybe_remat(body, cfg), h,
                                     (params["layers"], caches))
        return h, {"layers": new_caches}, aux_total

    if cfg.family == "hybrid":
        n_sites = cfg.num_layers // cfg.attn_every
        trailing = cfg.num_layers - n_sites * cfg.attn_every
        new_cache = {"layers": [], "shared": []} if cache is not None else None

        def mamba_scan(hh, stacked, caches):
            if caches is None:
                def body(hh, p_i):
                    hh, _ = _mamba_layer(cfg, p_i, hh, None)
                    return hh, None
                hh, _ = jax.lax.scan(_maybe_remat(body, cfg), hh, stacked)
                return hh, None
            def body(hh, xs):
                p_i, c_i = xs
                hh, c_new = _mamba_layer(cfg, p_i, hh, c_i)
                return hh, c_new
            hh, c_new = jax.lax.scan(_maybe_remat(body, cfg), hh,
                                     (stacked, caches))
            return hh, c_new

        for site in range(n_sites):
            site_params = jax.tree.map(lambda a: a[site], params["layers"])
            site_cache = (None if cache is None else
                          jax.tree.map(lambda a: a[site], cache["layers"]))
            h, c_new = mamba_scan(h, site_params, site_cache)
            sh_cache = (None if cache is None else
                        jax.tree.map(lambda a: a[site], cache["shared"]))
            h, sh_new = _shared_block(cfg, params["shared_attn"], h,
                                      sh_cache, pos)
            if cache is not None:
                new_cache["layers"].append(c_new)
                new_cache["shared"].append(sh_new)
        if trailing:
            tr_cache = None if cache is None else cache["trailing"]
            h, tr_new = mamba_scan(h, params["trailing"], tr_cache)
            if cache is not None:
                new_cache["trailing"] = tr_new
        if cache is not None:
            new_cache["layers"] = jax.tree.map(
                lambda *xs: jnp.stack(xs), *new_cache["layers"])
            new_cache["shared"] = jax.tree.map(
                lambda *xs: jnp.stack(xs), *new_cache["shared"])
        return h, new_cache, aux_total

    # dense / moe / vlm / audio
    if cache is None:
        def body(carry, p_i):
            hh, aux = carry
            hh, _, a = _dense_layer(cfg, p_i, hh, None, None)
            return (hh, aux + a)
        h, aux_total = _scan_layers(cfg, body, (h, aux_total),
                                    params["layers"])
        return h, None, aux_total

    def body(carry, xs):
        hh, aux = carry
        p_i, c_i = xs
        hh, c_new, a = _dense_layer(cfg, p_i, hh, c_i, pos)
        return (hh, aux + a), c_new
    (h, aux_total), new_caches = jax.lax.scan(
        _maybe_remat(body, cfg), (h, aux_total),
        (params["layers"], cache["layers"]))
    return h, {"layers": new_caches}, aux_total
