"""Federated data partitioning: IID and Dirichlet non-IID client splits."""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.synthetic import Dataset

Array = jax.Array


class FederatedData(NamedTuple):
    x: Array          # [K, n_k, 784]
    y: Array          # [K, n_k]

    @property
    def num_clients(self) -> int:
        return self.x.shape[0]

    @property
    def samples_per_client(self) -> int:
        return self.x.shape[1]


def partition_iid(key, data: Dataset, num_clients: int) -> FederatedData:
    n = data.x.shape[0]
    n_k = n // num_clients
    perm = jax.random.permutation(key, n)[: n_k * num_clients]
    x = data.x[perm].reshape(num_clients, n_k, -1)
    y = data.y[perm].reshape(num_clients, n_k)
    return FederatedData(x=x, y=y)


def partition_dirichlet(key, data: Dataset, num_clients: int,
                        alpha: float = 0.5,
                        num_classes: int = 10) -> FederatedData:
    """Label-skewed split: class proportions per client ~ Dir(alpha).

    Equal client sizes (n//K) for static shapes; within each client, sample
    indices are drawn (with replacement where a class runs short) according
    to the client's class mixture. Host-side numpy (data-prep, not hot).
    """
    n = int(data.x.shape[0])
    n_k = n // num_clients
    rng = np.random.default_rng(
        int(jax.random.randint(key, (), 0, 2**31 - 1)))
    y = np.asarray(data.y)
    by_class = [np.where(y == c)[0] for c in range(num_classes)]
    props = rng.dirichlet([alpha] * num_classes, size=num_clients)
    xs, ys = [], []
    for k in range(num_clients):
        counts = rng.multinomial(n_k, props[k])
        idx = []
        for c, cnt in enumerate(counts):
            if cnt == 0:
                continue
            pool = by_class[c]
            take = rng.choice(pool, size=cnt, replace=cnt > len(pool))
            idx.append(take)
        idx = np.concatenate(idx) if idx else np.zeros((0,), np.int64)
        if len(idx) < n_k:   # degenerate dirichlet draw — pad uniformly
            extra = rng.integers(0, n, n_k - len(idx))
            idx = np.concatenate([idx, extra])
        rng.shuffle(idx)
        xs.append(np.asarray(data.x)[idx])
        ys.append(y[idx])
    return FederatedData(x=jnp.asarray(np.stack(xs)),
                         y=jnp.asarray(np.stack(ys)))


def client_minibatch(fed: FederatedData, key, batch_size: int):
    """Sample one minibatch per client (vmapped). → (x [K,b,784], y [K,b])."""
    k = fed.num_clients
    keys = jax.random.split(key, k)

    def pick(kk, cx, cy):
        idx = jax.random.randint(kk, (batch_size,), 0, cx.shape[0])
        return cx[idx], cy[idx]

    return jax.vmap(pick)(keys, fed.x, fed.y)
