"""Deterministic synthetic datasets (the container has no downloads).

* ``make_synthetic_mnist`` — a 10-class, 784-dim image-like dataset with
  MNIST's exact dimensionality so the paper's d=7850 logistic-regression
  setup is reproduced bit-for-bit in structure. Classes are smooth random
  templates + per-sample noise + random shifts; linear separability is
  partial (top-1 linear accuracy plateaus ≈ 90–97%), giving convergence
  curves with the same qualitative shape as MNIST's.
* ``BigramLM`` — a random (but fixed) bigram language: sequences carry
  real mutual information, so LM training losses measurably decrease —
  unlike uniform random tokens.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

Array = jax.Array


class Dataset(NamedTuple):
    x: Array      # [N, 784] float32
    y: Array      # [N] int32


def _templates(key, num_classes: int = 10, dim: int = 784) -> Array:
    """Smooth class templates: low-frequency random images, unit-ish norm."""
    side = int(dim ** 0.5)
    k1, k2 = jax.random.split(key)
    coarse = jax.random.normal(k1, (num_classes, 7, 7))
    up = jax.image.resize(coarse, (num_classes, side, side), "bilinear")
    t = up.reshape(num_classes, dim)
    t = t / jnp.linalg.norm(t, axis=1, keepdims=True) * 6.0
    return t + 0.1 * jax.random.normal(k2, (num_classes, dim))


def make_synthetic_mnist(key, n: int, *, num_classes: int = 10,
                         dim: int = 784, noise: float = 1.0,
                         template_seed: int = 42) -> Dataset:
    """``key`` draws the samples; the class templates are dataset-level
    constants fixed by ``template_seed`` (train/test must share them)."""
    ky, kn, ks = jax.random.split(key, 3)
    t = _templates(jax.random.PRNGKey(template_seed), num_classes, dim)
    y = jax.random.randint(ky, (n,), 0, num_classes)
    x = t[y] + noise * jax.random.normal(kn, (n, dim))
    # per-sample random intensity scaling (mimics stroke-thickness variance)
    scale = 0.7 + 0.6 * jax.random.uniform(ks, (n, 1))
    x = x * scale
    return Dataset(x=x.astype(jnp.float32), y=y.astype(jnp.int32))


class BigramLM(NamedTuple):
    trans: Array   # [V, V] row-stochastic transition logits


def make_bigram_lm(key, vocab: int, *, concentration: float = 3.0
                   ) -> BigramLM:
    """Random sparse-ish bigram transition table (fixed by seed)."""
    logits = jax.random.normal(key, (vocab, vocab)) * concentration
    return BigramLM(trans=logits)


def sample_bigram(lm: BigramLM, key, batch: int, seq: int) -> Array:
    """Sample token sequences [B, S+1] from the bigram chain."""
    v = lm.trans.shape[0]
    k0, kseq = jax.random.split(key)
    first = jax.random.randint(k0, (batch,), 0, v)

    def step(tok, k):
        nxt = jax.random.categorical(k, lm.trans[tok], axis=-1)
        return nxt, nxt

    keys = jax.random.split(kseq, seq)
    _, toks = jax.lax.scan(step, first, keys)
    out = jnp.concatenate([first[None], toks], axis=0)      # [S+1, B]
    return jnp.moveaxis(out, 0, 1).astype(jnp.int32)         # [B, S+1]


def lm_batch(lm: BigramLM, key, batch: int, seq: int) -> dict:
    toks = sample_bigram(lm, key, batch, seq)
    return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
