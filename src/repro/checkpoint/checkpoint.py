"""Fault-tolerant checkpointing: atomic, sharded-friendly, keep-N GC.

Design (restart-anywhere posture, DESIGN §6):

* a checkpoint is a directory ``step_<n>/`` holding one ``.npz`` per
  top-level TrainState field plus a JSON manifest (tree structure, shapes,
  dtypes, step);
* writes go to ``step_<n>.tmp/`` then ``os.replace`` → readers never see a
  partial checkpoint (atomicity on POSIX rename);
* ``keep_n`` oldest checkpoints are garbage-collected after a successful
  commit (never before);
* error-feedback / TCS state are ordinary fields — they ride along, which
  is the point (the paper's convergence depends on them).

On a real multi-host pod each host writes only its addressable shards and
the manifest records the global shape; in this single-process container we
write full arrays but keep the same layout, so the format carries over.
"""

from __future__ import annotations

import json
import os
import shutil
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import compat


_NP_SAVABLE = {"float64", "float32", "float16", "int64", "int32", "int16",
               "int8", "uint8", "uint16", "uint32", "uint64", "bool"}


def _savable(arr: np.ndarray) -> np.ndarray:
    """npz can't serialize ml_dtypes (bfloat16/f8); upcast losslessly to
    f32 — restore() casts back to the template's dtype."""
    if arr.dtype.name in _NP_SAVABLE:
        return arr
    return arr.astype(np.float32)


def _flatten_with_paths(tree: Any):
    flat, treedef = compat.tree_flatten_with_path(tree)
    paths = ["/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                      for k in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return paths, leaves, treedef


def save(ckpt_dir: str, step: int, state: Any, *, keep_n: int = 3) -> str:
    """Atomically write ``state`` under ``ckpt_dir/step_<step>``."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    paths, leaves, _ = _flatten_with_paths(state)
    arrays = {f"a{i}": _savable(np.asarray(l)) for i, l in enumerate(leaves)}
    np.savez(os.path.join(tmp, "leaves.npz"), **arrays)
    manifest = {
        "step": step,
        "paths": paths,
        "shapes": [list(np.shape(a)) for a in arrays.values()],
        "dtypes": [str(np.asarray(a).dtype) for a in arrays.values()],
        "num_leaves": len(leaves),
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)                      # atomic commit

    # GC after commit
    ckpts = sorted(d for d in os.listdir(ckpt_dir)
                   if d.startswith("step_") and not d.endswith(".tmp"))
    for old in ckpts[:-keep_n] if keep_n > 0 else []:
        shutil.rmtree(os.path.join(ckpt_dir, old))
    return final


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
             if d.startswith("step_") and not d.endswith(".tmp")
             and os.path.exists(os.path.join(ckpt_dir, d, "manifest.json"))]
    return max(steps) if steps else None


def restore(ckpt_dir: str, template: Any, *, step: Optional[int] = None,
            shardings: Any = None) -> Any:
    """Restore into the structure of ``template`` (validates leaf count).

    ``shardings``: optional NamedSharding pytree — leaves are device_put
    accordingly (restart onto a different mesh layout = elastic restore).
    """
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(d, "leaves.npz"))
    leaves = [data[f"a{i}"] for i in range(manifest["num_leaves"])]

    t_leaves, treedef = jax.tree.flatten(template)
    if len(t_leaves) != len(leaves):
        raise ValueError(
            f"checkpoint has {len(leaves)} leaves, template expects "
            f"{len(t_leaves)} — incompatible TrainConfig?")
    out = []
    s_leaves = (jax.tree.leaves(shardings) if shardings is not None
                else [None] * len(leaves))
    for tl, arr, sh in zip(t_leaves, leaves, s_leaves):
        a = jnp.asarray(arr, dtype=tl.dtype)
        if sh is not None:
            a = jax.device_put(a, sh)
        out.append(a)
    return jax.tree.unflatten(treedef, out)
