"""Pure-jnp oracles for every Pallas kernel in this package.

Each ``ref_*`` function defines the exact contract its kernel must meet
(``tests/test_kernels.py`` sweeps shapes × dtypes and asserts allclose).
These are also the implementations used on non-TPU backends and inside the
dry-run/roofline path, where XLA-native HLO keeps ``cost_analysis()``
meaningful (see DESIGN §3).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def ref_count_ge(x: Array, taus: Array) -> Array:
    """counts[j] = #{i : |x_i| >= taus_j}. x: [d] any float dtype; taus: [B] f32."""
    mag = jnp.abs(x.astype(jnp.float32))
    return jnp.sum(mag[:, None] >= taus[None, :], axis=0).astype(jnp.int32)


def ref_sparsify_ef(g: Array, e: Array, mask_in: Array, weight: Array,
                    tau: Array):
    """Fused error-feedback + threshold/mask sparsification.

    g̃ = weight·g + e
    keep = (|g̃| >= tau) | (mask_in > 0)
    ḡ = keep ? g̃ : 0 ;  e' = g̃ − ḡ ;  nnz = #{ḡ ≠ 0}

    Returns (ḡ, e', nnz:int32 scalar). Compute in f32, outputs cast back to
    g.dtype (except nnz).
    """
    gt = (weight.astype(jnp.float32) * g.astype(jnp.float32)
          + e.astype(jnp.float32))
    keep = (jnp.abs(gt) >= tau.astype(jnp.float32)) | (mask_in > 0)
    gbar = jnp.where(keep, gt, 0.0)
    e_new = gt - gbar
    nnz = jnp.sum(gbar != 0).astype(jnp.int32)
    return gbar.astype(g.dtype), e_new.astype(e.dtype), nnz


def ref_chain_accum(gamma_in: Array, gbar: Array):
    """γ_out = γ_in + ḡ ; nnz(γ_out). Returns (γ_out, nnz:int32 scalar)."""
    gamma = (gamma_in.astype(jnp.float32) + gbar.astype(jnp.float32))
    nnz = jnp.sum(gamma != 0).astype(jnp.int32)
    return gamma.astype(gamma_in.dtype), nnz


def ref_cl_fuse(g: Array, e: Array, gamma_in: Array, weight: Array,
                tau: Array):
    """Fused CL-SIA hot path (Alg 3 lines 2–5) in one pass.

    γ̃ = weight·g + e + γ_in
    γ_out = |γ̃| >= tau ? γ̃ : 0 ;  e' = γ̃ − γ_out ;  nnz(γ_out)

    Returns (γ_out, e', nnz:int32 scalar).
    """
    gt = (weight.astype(jnp.float32) * g.astype(jnp.float32)
          + e.astype(jnp.float32) + gamma_in.astype(jnp.float32))
    keep = jnp.abs(gt) >= tau.astype(jnp.float32)
    gamma = jnp.where(keep, gt, 0.0)
    e_new = gt - gamma
    nnz = jnp.sum(gamma != 0).astype(jnp.int32)
    return gamma.astype(gamma_in.dtype), e_new.astype(e.dtype), nnz
