"""Pure-jnp oracles for every Pallas kernel in this package.

Each ``ref_*`` function defines the exact contract its kernel must meet
(``tests/test_kernels.py`` sweeps shapes × dtypes and asserts allclose).
These are also the implementations used on non-TPU backends and inside the
dry-run/roofline path, where XLA-native HLO keeps ``cost_analysis()``
meaningful (see DESIGN §3).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import sparsify as sp

Array = jax.Array

SUBLANES = 8
LANES = 1024
BLOCK = SUBLANES * LANES


def ref_count_ge(x: Array, taus: Array) -> Array:
    """counts[j] = #{i : |x_i| >= taus_j}. x: [d] any float dtype; taus: [B] f32."""
    mag = jnp.abs(x.astype(jnp.float32))
    return jnp.sum(mag[:, None] >= taus[None, :], axis=0).astype(jnp.int32)


def ref_sparsify_ef(g: Array, e: Array, mask_in: Array, weight: Array,
                    tau: Array):
    """Fused error-feedback + threshold/mask sparsification.

    g̃ = weight·g + e
    keep = (|g̃| >= tau) | (mask_in > 0)
    ḡ = keep ? g̃ : 0 ;  e' = g̃ − ḡ ;  nnz = #{ḡ ≠ 0}

    Returns (ḡ, e', nnz:int32 scalar). Compute in f32, outputs cast back to
    g.dtype (except nnz).
    """
    gt = (weight.astype(jnp.float32) * g.astype(jnp.float32)
          + e.astype(jnp.float32))
    keep = (jnp.abs(gt) >= tau.astype(jnp.float32)) | (mask_in > 0)
    gbar = jnp.where(keep, gt, 0.0)
    e_new = gt - gbar
    nnz = jnp.sum(gbar != 0).astype(jnp.int32)
    return gbar.astype(g.dtype), e_new.astype(e.dtype), nnz


def ref_chain_accum(gamma_in: Array, gbar: Array):
    """γ_out = γ_in + ḡ ; nnz(γ_out). Returns (γ_out, nnz:int32 scalar)."""
    gamma = (gamma_in.astype(jnp.float32) + gbar.astype(jnp.float32))
    nnz = jnp.sum(gamma != 0).astype(jnp.int32)
    return gamma.astype(gamma_in.dtype), nnz


def ref_cl_fuse(g: Array, e: Array, gamma_in: Array, weight: Array,
                tau: Array):
    """Fused CL-SIA hot path (Alg 3 lines 2–5) in one pass.

    γ̃ = weight·g + e + γ_in
    γ_out = |γ̃| >= tau ? γ̃ : 0 ;  e' = γ̃ − γ_out ;  nnz(γ_out)

    Returns (γ_out, e', nnz:int32 scalar).
    """
    gt = (weight.astype(jnp.float32) * g.astype(jnp.float32)
          + e.astype(jnp.float32) + gamma_in.astype(jnp.float32))
    keep = jnp.abs(gt) >= tau.astype(jnp.float32)
    gamma = jnp.where(keep, gt, 0.0)
    e_new = gt - gamma
    nnz = jnp.sum(gamma != 0).astype(jnp.int32)
    return gamma.astype(gamma_in.dtype), e_new.astype(e.dtype), nnz


# ---------------------------------------------------------------------------
# Batched W-lane level variants (contracts for repro.kernels.level)
# ---------------------------------------------------------------------------

def _apply_valid(valid: Array, *arrays):
    v = (valid > 0)
    out = tuple(jnp.where(v[:, None], a, jnp.zeros_like(a)) for a in arrays)
    return out if len(out) > 1 else out[0]


def ref_err_sq_level(e_new: Array) -> Array:
    """Pinned-order ‖e'‖² per lane — the ``err_sq_mode="kernel"`` contract.

    Summation order (documented, bit-reproducible across backends): each
    zero-padded (SUBLANES, LANES) f32 tile is squared elementwise, folded
    pairwise over lanes (1024 → 512 → … → 1: ``x[:, :n] + x[:, n:2n]``),
    then pairwise over sublanes (8 → 4 → 2 → 1); tile scalars accumulate
    left-to-right in block order. The zero padding is exact (+0 adds are
    identities), but the pairing of real elements depends on the tile
    geometry — this is a *different* (better-conditioned) order than the
    jnp row-sum, hence the opt-in config flag.
    """
    w_lanes, d = e_new.shape
    n_blocks = max(1, -(-d // BLOCK))
    pad = n_blocks * BLOCK - d
    tiles = jnp.pad(e_new.astype(jnp.float32), ((0, 0), (0, pad))).reshape(
        w_lanes, n_blocks, SUBLANES, LANES)
    sq = tiles * tiles
    n = LANES
    while n > 1:
        n //= 2
        sq = sq[..., :n] + sq[..., n:2 * n]
    m = SUBLANES
    while m > 1:
        m //= 2
        sq = sq[..., :m, :] + sq[..., m:2 * m, :]
    per_block = sq[..., 0, 0]                       # [W, n_blocks]
    acc = per_block[:, 0]
    for j in range(1, n_blocks):
        acc = acc + per_block[:, j]
    return acc


def ref_sparsify_ef_level(g, e, mask_in, weight, tau, valid, *,
                          with_err: bool = False):
    """Batched :func:`ref_sparsify_ef`; lanes with ``valid == 0`` output
    zeros (the level schedule's padding slots). ``mask_in`` may be None
    (pure-threshold keep). All counts are int32 [W]. ``with_err`` appends
    the pinned-order ‖e'‖² (:func:`ref_err_sq_level`) as a final [W] f32
    output — the in-kernel ``err_sq_mode="kernel"`` reduction."""
    gt = (weight[:, None].astype(jnp.float32) * g.astype(jnp.float32)
          + e.astype(jnp.float32))
    keep = jnp.abs(gt) >= tau[:, None].astype(jnp.float32)
    if mask_in is not None:
        keep = keep | (mask_in > 0)
    gbar = jnp.where(keep, gt, 0.0)
    e_new = gt - gbar
    gbar, e_new = _apply_valid(valid, gbar, e_new)
    nnz = jnp.sum(gbar != 0, axis=-1).astype(jnp.int32)
    out = (gbar.astype(g.dtype), e_new.astype(e.dtype), nnz)
    return out + (ref_err_sq_level(e_new),) if with_err else out


def _expand_gmask(gmask, lanes: int, gmask_cohorts: int):
    """Cohort-shared [B, d] gmask → per-lane [lanes, d] (cohort-major).

    Broadcast semantics only — values are replicated, so results are
    bitwise identical to the sequential per-cohort [d]-shared call.
    """
    if gmask is None or not gmask_cohorts or gmask.ndim != 2:
        return gmask
    if gmask.shape[0] == lanes:
        return gmask
    return jnp.repeat(gmask, lanes // gmask.shape[0], axis=0)


def ref_chain_accum_level(gamma_in, gbar, valid, gmask=None, *,
                          gmask_cohorts: int = 0):
    """Batched :func:`ref_chain_accum` + off-global-mask support count."""
    gmask = _expand_gmask(gmask, gamma_in.shape[0], gmask_cohorts)
    gamma = gamma_in.astype(jnp.float32) + gbar.astype(jnp.float32)
    gamma = _apply_valid(valid, gamma)
    nz = gamma != 0
    nnz = jnp.sum(nz, axis=-1).astype(jnp.int32)
    if gmask is None:
        nnz_off = nnz
    else:
        nnz_off = jnp.sum(nz & (gmask <= 0), axis=-1).astype(jnp.int32)
    return gamma.astype(gamma_in.dtype), nnz, nnz_off


def ref_cl_fuse_level(g, e, gamma_in, weight, tau, participate, valid,
                      gmask=None, mask_in=None, *, gmask_cohorts: int = 0,
                      with_err: bool = False):
    """Batched complete CL node step (Algorithms 3/5 with stragglers).

    See :func:`repro.kernels.level.cl_fuse_level_pallas` for the math.
    Returns (γ_out [W,d], e' [W,d], nnz [W] i32, nnz_off [W] i32)
    (+ pinned-order ‖e'‖² [W] f32 when ``with_err``).
    """
    gmask = _expand_gmask(gmask, g.shape[0], gmask_cohorts)
    w = weight[:, None].astype(jnp.float32)
    p = participate[:, None].astype(jnp.float32)
    gt = w * g.astype(jnp.float32) + e.astype(jnp.float32)
    gin = gamma_in.astype(jnp.float32)
    s = p * gt + gin
    lam_t = (1.0 - gmask) * s if gmask is not None else s
    keep = jnp.abs(lam_t) >= tau[:, None].astype(jnp.float32)
    if mask_in is not None:
        keep = keep | (mask_in > 0)
    lam = jnp.where(keep, lam_t, 0.0)
    e_new = lam_t - lam
    gamma = (gmask * s + lam) if gmask is not None else lam
    alive = p > 0
    gamma = jnp.where(alive, gamma, gin)
    e_new = jnp.where(alive, e_new, gt)
    gamma, e_new = _apply_valid(valid, gamma, e_new)
    nz = gamma != 0
    nnz = jnp.sum(nz, axis=-1).astype(jnp.int32)
    if gmask is None:
        nnz_off = nnz
    else:
        nnz_off = jnp.sum(nz & (gmask <= 0), axis=-1).astype(jnp.int32)
    out = (gamma.astype(gamma_in.dtype), e_new.astype(e.dtype), nnz,
           nnz_off)
    return out + (ref_err_sq_level(e_new),) if with_err else out


def ref_count_ge_level(x: Array, taus: Array) -> Array:
    """counts[w, b] = #{i : |x_{w,i}| >= taus_{w,b}}; x [W,d], taus [W,B]."""
    mag = jnp.abs(x.astype(jnp.float32))
    return jnp.sum(mag[:, :, None] >= taus[:, None, :],
                   axis=1).astype(jnp.int32)


# ---------------------------------------------------------------------------
# Fused-operand τ search (contracts for the count_ge_fused / hist kernels)
# ---------------------------------------------------------------------------

def fused_operand(g, e, gamma_in, weight, participate, gmask=None, *,
                  include_gamma: bool = False, gmask_cohorts: int = 0):
    """The bisection operand reconstructed from raw node inputs (f32 [W,d]).

    Covers all five algorithms' sparsifier operands:

    * SIA / RE-SIA:  ``w·g + e``                  (include_gamma=False)
    * CL-SIA:        ``p·(w·g + e) + γ_in``       (include_gamma=True)
    * TC-SIA:        ``(1−m)·(w·g + e)``          (gmask given)
    * CL-TC-SIA:     ``(1−m)·(p·(w·g + e) + γ_in)``

    Same float expressions as the materialized jnp path in
    ``repro.core.algorithms`` — the kernels mirror these per tile.
    """
    w = weight[:, None].astype(jnp.float32)
    s = w * g.astype(jnp.float32) + e.astype(jnp.float32)
    if include_gamma:
        s = (participate[:, None].astype(jnp.float32) * s
             + gamma_in.astype(jnp.float32))
    if gmask is not None:
        gm = _expand_gmask(gmask, g.shape[0], gmask_cohorts)
        s = (1.0 - gm) * s
    return s


def ref_count_ge_fused(g, e, gamma_in, weight, participate, taus, *,
                       include_gamma: bool = False) -> Array:
    """Scalar fused-operand counts: 1-D node inputs, taus [B] → i32 [B].

    ``taus`` must be nondecreasing (the bisection brackets always are) —
    the reference counts via :func:`repro.core.sparsify.count_ge_sorted`,
    whose integers are bit-identical to the O(d·B) broadcast.
    """
    op = fused_operand(g[None], e[None],
                       None if gamma_in is None else gamma_in[None],
                       jnp.asarray(weight, jnp.float32).reshape(1),
                       jnp.asarray(participate, jnp.float32).reshape(1),
                       include_gamma=include_gamma)
    return sp.count_ge_sorted(jnp.abs(op[0]), taus)


def ref_count_ge_fused_level(g, e, gamma_in, weight, participate, taus,
                             gmask=None, *, include_gamma: bool = False,
                             gmask_cohorts: int = 0) -> Array:
    """Batched fused-operand counts ([W,d] inputs, taus [W,B] → i32 [W,B]).

    Per-lane ``taus`` must be nondecreasing (see :func:`ref_count_ge_fused`).
    """
    op = fused_operand(g, e, gamma_in, weight, participate, gmask,
                       include_gamma=include_gamma,
                       gmask_cohorts=gmask_cohorts)
    return sp.count_ge_sorted_batch(jnp.abs(op), taus)


def ref_hist_topq_level(g, e, gamma_in, weight, participate, tables,
                        gmask=None, *, include_gamma: bool = False,
                        gmask_cohorts: int = 0):
    """Fused-operand joint digit histogram (tau_impl="hist") reference.

    ``tables = (tau1, new_lo, w2, top_shift)`` per lane ([W, ·] each, from
    ``repro.core.sparsify._hist_tables``); returns ``(D2 [W, b+1, b+1],
    F [W, b+1])`` int32 — see :func:`repro.core.sparsify._hist_digits`.
    """
    op = fused_operand(g, e, gamma_in, weight, participate, gmask,
                       include_gamma=include_gamma,
                       gmask_cohorts=gmask_cohorts)
    return jax.vmap(sp._hist_digits)(jnp.abs(op), *tables)
