"""Pallas TPU kernels for the aggregation hot path (see DESIGN §3).

Scalar kernels: count_ge (Top-Q threshold search), sparsify_ef (fused EF +
sparsify), chain_accum (fused IA combine), cl_fuse (whole CL-SIA node step).
Batched W-lane level variants (one ``pallas_call`` per schedule level,
padding lanes skipped) live in :mod:`repro.kernels.level` and power the
fused node-step paths of :mod:`repro.core.algorithms`.
Dispatch through :mod:`repro.kernels.ops`; oracles in
:mod:`repro.kernels.ref`.
"""

from repro.kernels import ops, ref

__all__ = ["ops", "ref"]
