"""Pallas TPU kernels for the aggregation hot path (see DESIGN §3).

Kernels: count_ge (Top-Q threshold search), sparsify_ef (fused EF +
sparsify), chain_accum (fused IA combine), cl_fuse (whole CL-SIA node step).
Dispatch through :mod:`repro.kernels.ops`; oracles in
:mod:`repro.kernels.ref`.
"""

from repro.kernels import ops, ref

__all__ = ["ops", "ref"]
