"""Pallas TPU kernels: fused IA combine steps (one HBM pass each).

``chain_accum``: γ_out = γ_in + ḡ with a fused support count — the IA line
of Algs 1/2/4.

``cl_fuse``: the whole CL-SIA node step (Alg 3 lines 2–5) given the
threshold: γ̃ = w·g + e + γ_in; γ_out = threshold(γ̃); e' = γ̃ − γ_out; nnz.
Reads (g, e, γ_in), writes (γ_out, e') — a single pass for the paper's
best algorithm's entire hot path.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

SUBLANES = 8
LANES = 1024
BLOCK = SUBLANES * LANES


def _chain_accum_kernel(gin_ref, gbar_ref, gout_ref, nnz_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        nnz_ref[0] = jnp.int32(0)

    gamma = (gin_ref[...].astype(jnp.float32)
             + gbar_ref[...].astype(jnp.float32))
    gout_ref[...] = gamma.astype(gout_ref.dtype)
    nnz_ref[0] += jnp.sum(gamma != 0).astype(jnp.int32)


def _cl_fuse_kernel(g_ref, e_ref, gin_ref, w_ref, tau_ref,
                    gout_ref, enew_ref, nnz_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        nnz_ref[0] = jnp.int32(0)

    w = w_ref[0]
    tau = tau_ref[0]
    gt = (w * g_ref[...].astype(jnp.float32)
          + e_ref[...].astype(jnp.float32)
          + gin_ref[...].astype(jnp.float32))
    keep = jnp.abs(gt) >= tau
    gamma = jnp.where(keep, gt, 0.0)
    gout_ref[...] = gamma.astype(gout_ref.dtype)
    enew_ref[...] = (gt - gamma).astype(enew_ref.dtype)
    nnz_ref[0] += jnp.sum(gamma != 0).astype(jnp.int32)


def _pad_blocks(v: jax.Array, n_blocks: int, pad: int):
    return jnp.pad(v, (0, pad)).reshape(n_blocks, SUBLANES, LANES)


@functools.partial(jax.jit, static_argnames=("interpret",))
def chain_accum_pallas(gamma_in: jax.Array, gbar: jax.Array, *,
                       interpret: bool = False):
    """γ_out = γ_in + ḡ, fused nnz. Returns (γ_out [d], nnz i32 scalar)."""
    (d,) = gamma_in.shape
    n_blocks = max(1, -(-d // BLOCK))
    pad = n_blocks * BLOCK - d
    gi = _pad_blocks(gamma_in.astype(jnp.float32), n_blocks, pad)
    gb = _pad_blocks(gbar.astype(jnp.float32), n_blocks, pad)

    blk = pl.BlockSpec((1, SUBLANES, LANES), lambda i: (i, 0, 0))
    scal = pl.BlockSpec((1,), lambda i: (0,))
    gout, nnz = pl.pallas_call(
        _chain_accum_kernel,
        grid=(n_blocks,),
        in_specs=[blk, blk],
        out_specs=[blk, scal],
        out_shape=[
            jax.ShapeDtypeStruct(gi.shape, gamma_in.dtype),
            jax.ShapeDtypeStruct((1,), jnp.int32),
        ],
        interpret=interpret,
    )(gi, gb)
    return gout.reshape(-1)[:d], nnz[0]


@functools.partial(jax.jit, static_argnames=("interpret",))
def cl_fuse_pallas(g: jax.Array, e: jax.Array, gamma_in: jax.Array,
                   weight: jax.Array, tau: jax.Array, *,
                   interpret: bool = False):
    """Fused CL-SIA node step given τ. Returns (γ_out, e', nnz i32 scalar)."""
    (d,) = g.shape
    n_blocks = max(1, -(-d // BLOCK))
    pad = n_blocks * BLOCK - d
    gp = _pad_blocks(g.astype(jnp.float32), n_blocks, pad)
    ep = _pad_blocks(e.astype(jnp.float32), n_blocks, pad)
    gi = _pad_blocks(gamma_in.astype(jnp.float32), n_blocks, pad)

    blk = pl.BlockSpec((1, SUBLANES, LANES), lambda i: (i, 0, 0))
    scal = pl.BlockSpec((1,), lambda i: (0,))
    gout, e_new, nnz = pl.pallas_call(
        _cl_fuse_kernel,
        grid=(n_blocks,),
        in_specs=[blk, blk, blk, scal, scal],
        out_specs=[blk, blk, scal],
        out_shape=[
            jax.ShapeDtypeStruct(gi.shape, gamma_in.dtype),
            jax.ShapeDtypeStruct(ep.shape, e.dtype),
            jax.ShapeDtypeStruct((1,), jnp.int32),
        ],
        interpret=interpret,
    )(gp, ep, gi, jnp.reshape(weight, (1,)).astype(jnp.float32),
      jnp.reshape(tau, (1,)).astype(jnp.float32))
    return gout.reshape(-1)[:d], e_new.reshape(-1)[:d], nnz[0]
