"""Backend-dispatching wrappers around the Pallas kernels.

``use_pallas``: "auto" (Pallas compiled on TPU, Pallas-interpret off-TPU
when ``REPRO_PALLAS_INTERPRET=1``, else jnp ref), "always" (interpret mode
off-TPU — used by kernel tests), "never" (pure-jnp ref — used by the
dry-run/roofline path so ``cost_analysis`` sees native HLO).
"""

from __future__ import annotations

import os
from typing import Literal

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.chain_accum import chain_accum_pallas, cl_fuse_pallas
from repro.kernels.level import (chain_accum_level_pallas,
                                 cl_fuse_level_pallas,
                                 count_ge_level_pallas,
                                 sparsify_ef_level_pallas)
from repro.kernels.sparsify_ef import sparsify_ef_pallas
from repro.kernels.topq_threshold import count_ge_pallas

Mode = Literal["auto", "always", "never"]


def resolve(mode: Mode) -> tuple[bool, bool]:
    """Resolve a dispatch mode → ``(use_pallas, interpret)``.

    Trace-time (Python-level) decision: compiled Pallas on TPU,
    Pallas-interpret off-TPU when forced (``mode="always"`` or
    ``REPRO_PALLAS_INTERPRET=1``), pure-jnp reference otherwise — the
    fused node-step paths in :mod:`repro.core.algorithms` key off this, so
    the host executors stay the bit-exact jnp oracle off-TPU by default.
    """
    if mode == "never":
        return False, False
    on_tpu = jax.default_backend() == "tpu"
    if mode == "always":
        return True, not on_tpu
    # auto
    if on_tpu:
        return True, False
    if os.environ.get("REPRO_PALLAS_INTERPRET") == "1":
        return True, True
    return False, False


_resolve = resolve          # historic private alias


def count_ge(x: jax.Array, taus: jax.Array, *, mode: Mode = "auto"):
    use, interp = _resolve(mode)
    if use:
        return count_ge_pallas(x, taus, interpret=interp)
    return ref.ref_count_ge(x, taus)


def sparsify_ef(g, e, mask_in, weight, tau, *, mode: Mode = "auto"):
    use, interp = _resolve(mode)
    if use:
        return sparsify_ef_pallas(g, e, mask_in, jnp.asarray(weight),
                                  jnp.asarray(tau), interpret=interp)
    return ref.ref_sparsify_ef(g, e, mask_in, jnp.asarray(weight),
                               jnp.asarray(tau))


def chain_accum(gamma_in, gbar, *, mode: Mode = "auto"):
    use, interp = _resolve(mode)
    if use:
        return chain_accum_pallas(gamma_in, gbar, interpret=interp)
    return ref.ref_chain_accum(gamma_in, gbar)


def cl_fuse(g, e, gamma_in, weight, tau, *, mode: Mode = "auto"):
    use, interp = _resolve(mode)
    if use:
        return cl_fuse_pallas(g, e, gamma_in, jnp.asarray(weight),
                              jnp.asarray(tau), interpret=interp)
    return ref.ref_cl_fuse(g, e, gamma_in, jnp.asarray(weight),
                           jnp.asarray(tau))


# ---------------------------------------------------------------------------
# Batched W-lane level variants (the (L, W) schedule hot path)
# ---------------------------------------------------------------------------

def sparsify_ef_level(g, e, mask_in, weight, tau, valid, *,
                      mode: Mode = "auto"):
    """Batched fused EF+sparsify over a level's W lanes ([W, d] inputs)."""
    use, interp = _resolve(mode)
    if use:
        return sparsify_ef_level_pallas(g, e, mask_in, jnp.asarray(weight),
                                        jnp.asarray(tau),
                                        jnp.asarray(valid),
                                        interpret=interp)
    return ref.ref_sparsify_ef_level(g, e, mask_in, jnp.asarray(weight),
                                     jnp.asarray(tau), jnp.asarray(valid))


def chain_accum_level(gamma_in, gbar, valid, gmask=None, *,
                      gmask_cohorts: int = 0, mode: Mode = "auto"):
    """Batched IA combine with fused (total, off-global-mask) counts.

    ``gmask_cohorts=B`` marks a cohort-shared [B, d] gmask for lanes laid
    out cohort-major (the multi-tenant batched round path).
    """
    use, interp = _resolve(mode)
    if use:
        return chain_accum_level_pallas(gamma_in, gbar, jnp.asarray(valid),
                                        gmask, gmask_cohorts=gmask_cohorts,
                                        interpret=interp)
    return ref.ref_chain_accum_level(gamma_in, gbar, jnp.asarray(valid),
                                     gmask, gmask_cohorts=gmask_cohorts)


def cl_fuse_level(g, e, gamma_in, weight, tau, participate, valid,
                  gmask=None, mask_in=None, *, gmask_cohorts: int = 0,
                  mode: Mode = "auto"):
    """Batched complete CL node step (Algs 3/5, stragglers included)."""
    use, interp = _resolve(mode)
    if use:
        return cl_fuse_level_pallas(g, e, gamma_in, jnp.asarray(weight),
                                    jnp.asarray(tau),
                                    jnp.asarray(participate),
                                    jnp.asarray(valid), gmask, mask_in,
                                    gmask_cohorts=gmask_cohorts,
                                    interpret=interp)
    return ref.ref_cl_fuse_level(g, e, gamma_in, jnp.asarray(weight),
                                 jnp.asarray(tau), jnp.asarray(participate),
                                 jnp.asarray(valid), gmask, mask_in,
                                 gmask_cohorts=gmask_cohorts)


def count_ge_level(x: jax.Array, taus: jax.Array, *, mode: Mode = "auto"):
    """Per-lane candidate-threshold counts ([W, d] × [W, B] → [W, B])."""
    use, interp = _resolve(mode)
    if use:
        return count_ge_level_pallas(x, taus, interpret=interp)
    return ref.ref_count_ge_level(x, taus)
