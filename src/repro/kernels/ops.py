"""Backend-dispatching wrappers around the Pallas kernels.

``use_pallas``: "auto" (Pallas compiled on TPU, Pallas-interpret off-TPU
when ``REPRO_PALLAS_INTERPRET=1``, else jnp ref), "always" (interpret mode
off-TPU — used by kernel tests), "never" (pure-jnp ref — used by the
dry-run/roofline path so ``cost_analysis`` sees native HLO).
"""

from __future__ import annotations

import os
from typing import Literal

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.chain_accum import chain_accum_pallas, cl_fuse_pallas
from repro.kernels.sparsify_ef import sparsify_ef_pallas
from repro.kernels.topq_threshold import count_ge_pallas

Mode = Literal["auto", "always", "never"]


def _resolve(mode: Mode) -> tuple[bool, bool]:
    """→ (use_pallas, interpret)."""
    if mode == "never":
        return False, False
    on_tpu = jax.default_backend() == "tpu"
    if mode == "always":
        return True, not on_tpu
    # auto
    if on_tpu:
        return True, False
    if os.environ.get("REPRO_PALLAS_INTERPRET") == "1":
        return True, True
    return False, False


def count_ge(x: jax.Array, taus: jax.Array, *, mode: Mode = "auto"):
    use, interp = _resolve(mode)
    if use:
        return count_ge_pallas(x, taus, interpret=interp)
    return ref.ref_count_ge(x, taus)


def sparsify_ef(g, e, mask_in, weight, tau, *, mode: Mode = "auto"):
    use, interp = _resolve(mode)
    if use:
        return sparsify_ef_pallas(g, e, mask_in, jnp.asarray(weight),
                                  jnp.asarray(tau), interpret=interp)
    return ref.ref_sparsify_ef(g, e, mask_in, jnp.asarray(weight),
                               jnp.asarray(tau))


def chain_accum(gamma_in, gbar, *, mode: Mode = "auto"):
    use, interp = _resolve(mode)
    if use:
        return chain_accum_pallas(gamma_in, gbar, interpret=interp)
    return ref.ref_chain_accum(gamma_in, gbar)


def cl_fuse(g, e, gamma_in, weight, tau, *, mode: Mode = "auto"):
    use, interp = _resolve(mode)
    if use:
        return cl_fuse_pallas(g, e, gamma_in, jnp.asarray(weight),
                              jnp.asarray(tau), interpret=interp)
    return ref.ref_cl_fuse(g, e, gamma_in, jnp.asarray(weight),
                           jnp.asarray(tau))
