"""Backend-dispatching wrappers around the Pallas kernels.

``use_pallas``: "auto" (Pallas compiled on TPU, Pallas-interpret off-TPU
when ``REPRO_PALLAS_INTERPRET=1``, else jnp ref), "always" (interpret mode
off-TPU — used by kernel tests), "never" (pure-jnp ref — used by the
dry-run/roofline path so ``cost_analysis`` sees native HLO), "ref" (jnp
ref kernels like "never", but the fused *structure* — operand-on-the-fly
τ search, fused node steps — stays on; the honest host benchmark of the
fused data flow without Pallas interpret overhead).
"""

from __future__ import annotations

import os
from typing import Literal

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.chain_accum import chain_accum_pallas, cl_fuse_pallas
from repro.kernels.level import (chain_accum_level_pallas,
                                 cl_fuse_level_pallas,
                                 count_ge_fused_level_pallas,
                                 count_ge_level_pallas,
                                 hist_topq_level_pallas,
                                 sparsify_ef_level_pallas)
from repro.kernels.sparsify_ef import sparsify_ef_pallas
from repro.kernels.topq_threshold import (count_ge_fused_pallas,
                                          count_ge_pallas)

Mode = Literal["auto", "always", "never", "ref"]


def resolve(mode: Mode) -> tuple[bool, bool]:
    """Resolve a dispatch mode → ``(use_pallas, interpret)``.

    Trace-time (Python-level) decision: compiled Pallas on TPU,
    Pallas-interpret off-TPU when forced (``mode="always"`` or
    ``REPRO_PALLAS_INTERPRET=1``), pure-jnp reference otherwise — the
    fused node-step paths in :mod:`repro.core.algorithms` key off this, so
    the host executors stay the bit-exact jnp oracle off-TPU by default.

    ``"ref"`` resolves to the jnp reference kernels too; what distinguishes
    it from ``"never"`` is *structural*: ``fused_node_steps`` treats it as
    fused, so the whole-level fused paths (and the fused-operand τ search)
    run with jnp kernel bodies.
    """
    if mode in ("never", "ref"):
        return False, False
    on_tpu = jax.default_backend() == "tpu"
    if mode == "always":
        return True, not on_tpu
    # auto
    if on_tpu:
        return True, False
    if os.environ.get("REPRO_PALLAS_INTERPRET") == "1":
        return True, True
    return False, False


_resolve = resolve          # historic private alias


def count_ge(x: jax.Array, taus: jax.Array, *, mode: Mode = "auto"):
    use, interp = _resolve(mode)
    if use:
        return count_ge_pallas(x, taus, interpret=interp)
    return ref.ref_count_ge(x, taus)


def sparsify_ef(g, e, mask_in, weight, tau, *, mode: Mode = "auto"):
    use, interp = _resolve(mode)
    if use:
        return sparsify_ef_pallas(g, e, mask_in, jnp.asarray(weight),
                                  jnp.asarray(tau), interpret=interp)
    return ref.ref_sparsify_ef(g, e, mask_in, jnp.asarray(weight),
                               jnp.asarray(tau))


def chain_accum(gamma_in, gbar, *, mode: Mode = "auto"):
    use, interp = _resolve(mode)
    if use:
        return chain_accum_pallas(gamma_in, gbar, interpret=interp)
    return ref.ref_chain_accum(gamma_in, gbar)


def cl_fuse(g, e, gamma_in, weight, tau, *, mode: Mode = "auto"):
    use, interp = _resolve(mode)
    if use:
        return cl_fuse_pallas(g, e, gamma_in, jnp.asarray(weight),
                              jnp.asarray(tau), interpret=interp)
    return ref.ref_cl_fuse(g, e, gamma_in, jnp.asarray(weight),
                           jnp.asarray(tau))


# ---------------------------------------------------------------------------
# Batched W-lane level variants (the (L, W) schedule hot path)
# ---------------------------------------------------------------------------

def sparsify_ef_level(g, e, mask_in, weight, tau, valid, *,
                      with_err: bool = False, mode: Mode = "auto"):
    """Batched fused EF+sparsify over a level's W lanes ([W, d] inputs).

    ``with_err=True`` appends the in-kernel pinned-order ‖e'‖² ([W] f32) —
    the ``err_sq_mode="kernel"`` path; both backends use the identical
    pairwise-tree fold.
    """
    use, interp = _resolve(mode)
    if use:
        return sparsify_ef_level_pallas(g, e, mask_in, jnp.asarray(weight),
                                        jnp.asarray(tau),
                                        jnp.asarray(valid),
                                        with_err=with_err,
                                        interpret=interp)
    return ref.ref_sparsify_ef_level(g, e, mask_in, jnp.asarray(weight),
                                     jnp.asarray(tau), jnp.asarray(valid),
                                     with_err=with_err)


def chain_accum_level(gamma_in, gbar, valid, gmask=None, *,
                      gmask_cohorts: int = 0, mode: Mode = "auto"):
    """Batched IA combine with fused (total, off-global-mask) counts.

    ``gmask_cohorts=B`` marks a cohort-shared [B, d] gmask for lanes laid
    out cohort-major (the multi-tenant batched round path).
    """
    use, interp = _resolve(mode)
    if use:
        return chain_accum_level_pallas(gamma_in, gbar, jnp.asarray(valid),
                                        gmask, gmask_cohorts=gmask_cohorts,
                                        interpret=interp)
    return ref.ref_chain_accum_level(gamma_in, gbar, jnp.asarray(valid),
                                     gmask, gmask_cohorts=gmask_cohorts)


def cl_fuse_level(g, e, gamma_in, weight, tau, participate, valid,
                  gmask=None, mask_in=None, *, gmask_cohorts: int = 0,
                  with_err: bool = False, mode: Mode = "auto"):
    """Batched complete CL node step (Algs 3/5, stragglers included).

    ``with_err=True`` appends the in-kernel pinned-order ‖e'‖² ([W] f32).
    """
    use, interp = _resolve(mode)
    if use:
        return cl_fuse_level_pallas(g, e, gamma_in, jnp.asarray(weight),
                                    jnp.asarray(tau),
                                    jnp.asarray(participate),
                                    jnp.asarray(valid), gmask, mask_in,
                                    gmask_cohorts=gmask_cohorts,
                                    with_err=with_err, interpret=interp)
    return ref.ref_cl_fuse_level(g, e, gamma_in, jnp.asarray(weight),
                                 jnp.asarray(tau), jnp.asarray(participate),
                                 jnp.asarray(valid), gmask, mask_in,
                                 gmask_cohorts=gmask_cohorts,
                                 with_err=with_err)


def count_ge_level(x: jax.Array, taus: jax.Array, *, mode: Mode = "auto"):
    """Per-lane candidate-threshold counts ([W, d] × [W, B] → [W, B])."""
    use, interp = _resolve(mode)
    if use:
        return count_ge_level_pallas(x, taus, interpret=interp)
    return ref.ref_count_ge_level(x, taus)


# ---------------------------------------------------------------------------
# Fused-operand τ search (no materialized bisection operand)
# ---------------------------------------------------------------------------

def count_ge_fused(g, e, gamma_in, weight, participate, taus, *,
                   include_gamma: bool = False, mode: Mode = "auto"):
    """Candidate counts of the 1-D bisection operand rebuilt on the fly.

    Operand ``w·g + e`` (``p·(w·g + e) + γ_in`` when ``include_gamma``)
    is reconstructed tile-by-tile from the raw node inputs — no HBM
    materialization before the τ search. taus [B] nondecreasing → [B] i32.
    """
    use, interp = _resolve(mode)
    if use:
        return count_ge_fused_pallas(g, e, gamma_in, jnp.asarray(weight),
                                     jnp.asarray(participate), taus,
                                     include_gamma=include_gamma,
                                     interpret=interp)
    return ref.ref_count_ge_fused(g, e, gamma_in, jnp.asarray(weight),
                                  jnp.asarray(participate), taus,
                                  include_gamma=include_gamma)


def count_ge_fused_level(g, e, gamma_in, weight, participate, taus,
                         gmask=None, *, include_gamma: bool = False,
                         gmask_cohorts: int = 0, mode: Mode = "auto"):
    """Per-lane candidate counts of the fused bisection operand.

    Full operand family ``(1−m)·(p·(w·g + e) + γ_in)`` with the γ/mask
    factors dropped per flags; [W, d] inputs, taus [W, B] → [W, B] i32.
    """
    use, interp = _resolve(mode)
    if use:
        return count_ge_fused_level_pallas(
            g, e, gamma_in, jnp.asarray(weight), jnp.asarray(participate),
            taus, gmask, include_gamma=include_gamma,
            gmask_cohorts=gmask_cohorts, interpret=interp)
    return ref.ref_count_ge_fused_level(
        g, e, gamma_in, jnp.asarray(weight), jnp.asarray(participate),
        taus, gmask, include_gamma=include_gamma,
        gmask_cohorts=gmask_cohorts)


def hist_topq_level(g, e, gamma_in, weight, participate, tables, gmask=None,
                    *, include_gamma: bool = False, gmask_cohorts: int = 0,
                    mode: Mode = "auto"):
    """One-pass joint digit histogram of the fused operand (tau_impl="hist").

    ``tables`` per ``repro.core.sparsify._hist_tables``; returns
    ``(D2 [W, b+1, b+1] i32, F [W, b+1] i32)``.
    """
    use, interp = _resolve(mode)
    if use:
        return hist_topq_level_pallas(
            g, e, gamma_in, jnp.asarray(weight), jnp.asarray(participate),
            tables, gmask, include_gamma=include_gamma,
            gmask_cohorts=gmask_cohorts, interpret=interp)
    return ref.ref_hist_topq_level(
        g, e, gamma_in, jnp.asarray(weight), jnp.asarray(participate),
        tables, gmask, include_gamma=include_gamma,
        gmask_cohorts=gmask_cohorts)
