"""Pallas TPU kernels: batched W-lane node-step stages (one pass per level).

The aggregation executors run up to W tree nodes concurrently per level
(the padded ``(L, W)`` schedule of :class:`repro.agg.plan.AggPlan`). The
scalar kernels in :mod:`sparsify_ef` / :mod:`chain_accum` fuse one node's
stage; these variants fuse a **whole level**: inputs carry a leading lane
axis ``[W, d]``, per-lane scalars (weight, τ, participate) ride in as
``[W]`` vectors, and the grid is ``(W, blocks)`` so every lane streams its
d-vector tile by tile in one ``pallas_call`` — no ``vmap`` over scalar
kernels, no per-lane dispatch overhead.

Padding lanes (``valid == 0`` — the schedule's no-op slots) skip the
elementwise math entirely (``pl.when``) and write zeros, which keeps the
executors' masked scatter-adds no-ops. The DMA for a skipped lane still
runs (block specs are static); the saved work is the VPU math and the
output traffic semantics stay identical to computing on the zero dummy row.

Cohort batching (multi-tenant rounds) flattens B shape-identical levels
into one launch: lanes are laid out cohort-major (``[B*W, d]`` — cohort b
owns lanes ``b*W .. (b+1)*W-1``) so the same ``(lanes, blocks)`` grid
serves all B cohorts in a **single** ``pallas_call``. Per-cohort TC global
masks stay compact ``[B, d]`` in HBM: ``gmask_cohorts=B`` selects a
cohort-shared block spec whose index map sends lane ``w`` to tile
``w // (lanes // B)`` — no ``[B*W, d]`` broadcast, no vmap-of-pallas_call.

``cl_fuse_level`` is the whole CL-family node step (Algorithms 3 and 5,
stragglers included) in a single pass:

    g̃   = w·g + e
    s    = p·g̃ + γ_in            (p ∈ {0,1}: participation)
    Γ    = m·s                    (m: TCS global mask; 0 for Alg 3)
    Λ̃   = (1−m)·s
    keep = |Λ̃| ≥ τ  ∨  mask_in   (τ-sparsifier or precomputed exact mask)
    Λ    = keep ? Λ̃ : 0
    e′   = Λ̃ − Λ
    γ    = Γ + Λ                  (Alg 3: γ = Λ)
    γ_out, e′ = p>0 ? (γ, e′) : (γ_in, g̃)     (straggler forwarding)
    nnz  = #{γ_out ≠ 0};  nnz_off = #{γ_out ≠ 0 ∧ m = 0}

reading (g, e, γ_in[, m, mask_in]) and writing (γ_out, e′) in a single
sweep — the unfused jnp chain takes one sweep per op (per-algorithm
totals: ``benchmarks/bench_round.py::vector_passes``).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

SUBLANES = 8
LANES = 1024
BLOCK = SUBLANES * LANES


def _pad_lanes(v: jax.Array, n_blocks: int, pad: int):
    """[W, d] → [W, n_blocks, SUBLANES, LANES] (zero padded)."""
    w = v.shape[0]
    return jnp.pad(v, ((0, 0), (0, pad))).reshape(
        w, n_blocks, SUBLANES, LANES)


def _pad_shared(v: jax.Array, n_blocks: int, pad: int):
    """[d] → [n_blocks, SUBLANES, LANES] (zero padded) — a lane-shared
    operand streamed once per block instead of once per (lane, block)."""
    return jnp.pad(v, (0, pad)).reshape(n_blocks, SUBLANES, LANES)


def _geometry(d: int):
    n_blocks = max(1, -(-d // BLOCK))
    return n_blocks, n_blocks * BLOCK - d


def _blk():
    return pl.BlockSpec((1, 1, SUBLANES, LANES), lambda w, j: (w, j, 0, 0))


def _blk_shared():
    # block index ignores the lane axis w: every lane of a level reads the
    # SAME [SUBLANES, LANES] tile — the TC global mask is stored once, [d],
    # never broadcast to [W, d] in HBM (ROADMAP open-item tail)
    return pl.BlockSpec((1, SUBLANES, LANES), lambda w, j: (j, 0, 0))


def _blk_cohort(lanes_per_cohort: int):
    # block index maps lane w to its cohort w // lanes_per_cohort: with
    # lanes flattened cohort-major, every lane of a cohort reads the SAME
    # tile of that cohort's [d] mask — stored once per cohort as [B, d],
    # never broadcast to [B*W, d] in HBM
    return pl.BlockSpec((1, 1, SUBLANES, LANES),
                        lambda w, j: (w // lanes_per_cohort, j, 0, 0))


def _lane():
    return pl.BlockSpec((1,), lambda w, j: (w,))


def _gmask_operand(gmask, w_lanes: int, gmask_cohorts: int, n_blocks: int,
                   pad: int):
    """Pick the (padded operand, block spec) for a TC global mask.

    [d] → lane-shared; [B, d] with ``gmask_cohorts == B`` → cohort-shared
    (requires ``w_lanes % B == 0``); [W, d] → per-lane.
    """
    if gmask.ndim == 1:
        return _pad_shared(gmask.astype(jnp.float32), n_blocks, pad), \
            _blk_shared()
    if gmask_cohorts:
        if gmask.shape[0] != gmask_cohorts or w_lanes % gmask_cohorts:
            raise ValueError(
                f"cohort gmask {gmask.shape} incompatible with "
                f"{w_lanes} lanes / {gmask_cohorts} cohorts")
        return _pad_lanes(gmask.astype(jnp.float32), n_blocks, pad), \
            _blk_cohort(w_lanes // gmask_cohorts)
    return _pad_lanes(gmask.astype(jnp.float32), n_blocks, pad), _blk()


# ---------------------------------------------------------------------------
# sparsify_ef_level — Algs 1/2/4 EF + sparsify stage, one pass per level
# ---------------------------------------------------------------------------

def _sparsify_ef_level_kernel(g_ref, e_ref, w_ref, tau_ref, v_ref, *rest,
                              has_mask: bool):
    if has_mask:
        m_ref, gbar_ref, enew_ref, nnz_ref = rest
    else:
        gbar_ref, enew_ref, nnz_ref = rest
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        nnz_ref[0] = jnp.int32(0)

    ok = v_ref[0] > 0

    @pl.when(ok)
    def _compute():
        w = w_ref[0]
        tau = tau_ref[0]
        gt = (w * g_ref[...].astype(jnp.float32)
              + e_ref[...].astype(jnp.float32))
        keep = jnp.abs(gt) >= tau
        if has_mask:
            keep = keep | (m_ref[...] > 0)
        gbar = jnp.where(keep, gt, 0.0)
        gbar_ref[...] = gbar.astype(gbar_ref.dtype)
        enew_ref[...] = (gt - gbar).astype(enew_ref.dtype)
        nnz_ref[0] += jnp.sum(gbar != 0).astype(jnp.int32)

    @pl.when(jnp.logical_not(ok))
    def _skip():
        gbar_ref[...] = jnp.zeros_like(gbar_ref)
        enew_ref[...] = jnp.zeros_like(enew_ref)


@functools.partial(jax.jit, static_argnames=("interpret",))
def sparsify_ef_level_pallas(g, e, mask_in, weight, tau, valid, *,
                             interpret: bool = False):
    """Batched fused EF+sparsify. g,e: [W,d]; weight,tau,valid: [W];
    mask_in (optional [W,d]): keep mask OR-ed with the τ test (None skips
    the mask stream entirely — the pure-threshold sparsifier path).

    Returns (ḡ [W,d] g.dtype, e' [W,d] e.dtype, nnz [W] int32).
    """
    w_lanes, d = g.shape
    n_blocks, pad = _geometry(d)
    gp = _pad_lanes(g.astype(jnp.float32), n_blocks, pad)
    ep = _pad_lanes(e.astype(jnp.float32), n_blocks, pad)
    has_mask = mask_in is not None
    operands = [gp, ep, weight.astype(jnp.float32), tau.astype(jnp.float32),
                valid.astype(jnp.float32)]
    in_specs = [_blk(), _blk(), _lane(), _lane(), _lane()]
    if has_mask:
        operands.append(_pad_lanes(mask_in.astype(jnp.float32), n_blocks,
                                   pad))
        in_specs.append(_blk())

    gbar, e_new, nnz = pl.pallas_call(
        functools.partial(_sparsify_ef_level_kernel, has_mask=has_mask),
        grid=(w_lanes, n_blocks),
        in_specs=in_specs,
        out_specs=[_blk(), _blk(), _lane()],
        out_shape=[
            jax.ShapeDtypeStruct(gp.shape, g.dtype),
            jax.ShapeDtypeStruct(ep.shape, e.dtype),
            jax.ShapeDtypeStruct((w_lanes,), jnp.int32),
        ],
        interpret=interpret,
    )(*operands)
    return (gbar.reshape(w_lanes, -1)[:, :d],
            e_new.reshape(w_lanes, -1)[:, :d], nnz)


# ---------------------------------------------------------------------------
# chain_accum_level — Algs 1/2/4 IA combine, fused support counts
# ---------------------------------------------------------------------------

def _chain_accum_level_kernel(gin_ref, gbar_ref, v_ref, *rest,
                              has_gmask: bool):
    if has_gmask:
        gm_ref, gout_ref, nnz_ref, off_ref = rest
    else:
        gout_ref, nnz_ref, off_ref = rest
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        nnz_ref[0] = jnp.int32(0)
        off_ref[0] = jnp.int32(0)

    ok = v_ref[0] > 0

    @pl.when(ok)
    def _compute():
        gamma = (gin_ref[...].astype(jnp.float32)
                 + gbar_ref[...].astype(jnp.float32))
        gout_ref[...] = gamma.astype(gout_ref.dtype)
        nz = gamma != 0
        nnz_ref[0] += jnp.sum(nz).astype(jnp.int32)
        if has_gmask:
            off_ref[0] += jnp.sum(nz & (gm_ref[...] <= 0)).astype(jnp.int32)
        else:
            off_ref[0] += jnp.sum(nz).astype(jnp.int32)

    @pl.when(jnp.logical_not(ok))
    def _skip():
        gout_ref[...] = jnp.zeros_like(gout_ref)


@functools.partial(jax.jit, static_argnames=("gmask_cohorts", "interpret"))
def chain_accum_level_pallas(gamma_in, gbar, valid, gmask=None, *,
                             gmask_cohorts: int = 0,
                             interpret: bool = False):
    """Batched γ_out = γ_in + ḡ with fused counts.

    gamma_in, gbar: [W,d]; valid: [W]; gmask (optional): the TCS global
    mask — per-lane [W,d], lane-shared [d] (streamed once per block, not
    broadcast), or cohort-shared [B,d] with ``gmask_cohorts=B`` (lanes
    flattened cohort-major); when given, ``nnz_off`` counts the off-mask
    support ``#{γ_out ≠ 0 ∧ m = 0}`` (the §V locally-indexed part);
    without it, ``nnz_off == nnz``.
    Returns (γ_out [W,d], nnz [W] i32, nnz_off [W] i32).
    """
    w_lanes, d = gamma_in.shape
    n_blocks, pad = _geometry(d)
    gi = _pad_lanes(gamma_in.astype(jnp.float32), n_blocks, pad)
    gb = _pad_lanes(gbar.astype(jnp.float32), n_blocks, pad)
    has_gmask = gmask is not None
    operands = [gi, gb, valid.astype(jnp.float32)]
    in_specs = [_blk(), _blk(), _lane()]
    if has_gmask:
        op, spec = _gmask_operand(gmask, w_lanes, gmask_cohorts, n_blocks,
                                  pad)
        operands.append(op)
        in_specs.append(spec)

    gout, nnz, nnz_off = pl.pallas_call(
        functools.partial(_chain_accum_level_kernel, has_gmask=has_gmask),
        grid=(w_lanes, n_blocks),
        in_specs=in_specs,
        out_specs=[_blk(), _lane(), _lane()],
        out_shape=[
            jax.ShapeDtypeStruct(gi.shape, gamma_in.dtype),
            jax.ShapeDtypeStruct((w_lanes,), jnp.int32),
            jax.ShapeDtypeStruct((w_lanes,), jnp.int32),
        ],
        interpret=interpret,
    )(*operands)
    return gout.reshape(w_lanes, -1)[:, :d], nnz, nnz_off


# ---------------------------------------------------------------------------
# cl_fuse_level — Algs 3/5 complete node step in one pass
# ---------------------------------------------------------------------------

def _cl_fuse_level_kernel(g_ref, e_ref, gin_ref, w_ref, tau_ref, p_ref,
                          v_ref, *rest, has_gmask: bool, has_mask: bool):
    idx = 0
    gm_ref = mask_ref = None
    if has_gmask:
        gm_ref = rest[idx]
        idx += 1
    if has_mask:
        mask_ref = rest[idx]
        idx += 1
    gout_ref, enew_ref, nnz_ref, off_ref = rest[idx:]
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        nnz_ref[0] = jnp.int32(0)
        off_ref[0] = jnp.int32(0)

    ok = v_ref[0] > 0

    @pl.when(ok)
    def _compute():
        w = w_ref[0]
        tau = tau_ref[0]
        p = p_ref[0]
        gt = (w * g_ref[...].astype(jnp.float32)
              + e_ref[...].astype(jnp.float32))
        gin = gin_ref[...].astype(jnp.float32)
        s = p * gt + gin
        if has_gmask:
            m = gm_ref[...]
            lam_t = (1.0 - m) * s
        else:
            lam_t = s
        keep = jnp.abs(lam_t) >= tau
        if has_mask:
            keep = keep | (mask_ref[...] > 0)
        lam = jnp.where(keep, lam_t, 0.0)
        e_new = lam_t - lam
        gamma = (m * s + lam) if has_gmask else lam
        alive = p > 0
        gamma = jnp.where(alive, gamma, gin)
        e_new = jnp.where(alive, e_new, gt)
        gout_ref[...] = gamma.astype(gout_ref.dtype)
        enew_ref[...] = e_new.astype(enew_ref.dtype)
        nz = gamma != 0
        nnz_ref[0] += jnp.sum(nz).astype(jnp.int32)
        if has_gmask:
            off_ref[0] += jnp.sum(nz & (gm_ref[...] <= 0)).astype(jnp.int32)
        else:
            off_ref[0] += jnp.sum(nz).astype(jnp.int32)

    @pl.when(jnp.logical_not(ok))
    def _skip():
        gout_ref[...] = jnp.zeros_like(gout_ref)
        enew_ref[...] = jnp.zeros_like(enew_ref)


@functools.partial(jax.jit, static_argnames=("gmask_cohorts", "interpret"))
def cl_fuse_level_pallas(g, e, gamma_in, weight, tau, participate, valid,
                         gmask=None, mask_in=None, *,
                         gmask_cohorts: int = 0,
                         interpret: bool = False):
    """Batched complete CL node step (Algs 3/5, stragglers included).

    g, e, gamma_in: [W,d]; weight, tau, participate, valid: [W];
    gmask (optional): TCS global mask m (Alg 5; None = Alg 3) — per-lane
    [W,d], lane-shared [d] (streamed once per block, not broadcast), or
    cohort-shared [B,d] with ``gmask_cohorts=B`` (lanes cohort-major);
    mask_in (optional, [W,d]): precomputed keep mask OR-ed with the τ test
    (pass τ=+inf for a pure-mask exact sparsifier).

    Returns (γ_out [W,d], e' [W,d], nnz [W] i32, nnz_off [W] i32) where
    ``nnz_off`` is the off-global-mask support (= nnz when gmask is None).
    """
    w_lanes, d = g.shape
    n_blocks, pad = _geometry(d)
    gp = _pad_lanes(g.astype(jnp.float32), n_blocks, pad)
    ep = _pad_lanes(e.astype(jnp.float32), n_blocks, pad)
    gi = _pad_lanes(gamma_in.astype(jnp.float32), n_blocks, pad)
    has_gmask = gmask is not None
    has_mask = mask_in is not None
    operands = [gp, ep, gi, weight.astype(jnp.float32),
                tau.astype(jnp.float32), participate.astype(jnp.float32),
                valid.astype(jnp.float32)]
    in_specs = [_blk(), _blk(), _blk(), _lane(), _lane(), _lane(), _lane()]
    if has_gmask:
        op, spec = _gmask_operand(gmask, w_lanes, gmask_cohorts, n_blocks,
                                  pad)
        operands.append(op)
        in_specs.append(spec)
    if has_mask:
        operands.append(_pad_lanes(mask_in.astype(jnp.float32), n_blocks,
                                   pad))
        in_specs.append(_blk())

    gout, e_new, nnz, nnz_off = pl.pallas_call(
        functools.partial(_cl_fuse_level_kernel, has_gmask=has_gmask,
                          has_mask=has_mask),
        grid=(w_lanes, n_blocks),
        in_specs=in_specs,
        out_specs=[_blk(), _blk(), _lane(), _lane()],
        out_shape=[
            jax.ShapeDtypeStruct(gi.shape, gamma_in.dtype),
            jax.ShapeDtypeStruct(ep.shape, e.dtype),
            jax.ShapeDtypeStruct((w_lanes,), jnp.int32),
            jax.ShapeDtypeStruct((w_lanes,), jnp.int32),
        ],
        interpret=interpret,
    )(*operands)
    return (gout.reshape(w_lanes, -1)[:, :d],
            e_new.reshape(w_lanes, -1)[:, :d], nnz, nnz_off)


# ---------------------------------------------------------------------------
# count_ge_level — per-lane candidate-threshold counting (batched bisection)
# ---------------------------------------------------------------------------

def _count_ge_level_kernel(x_ref, taus_ref, out_ref, *, branch: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    mag = jnp.abs(x_ref[...].astype(jnp.float32))

    def body(b, _):
        tau = taus_ref[0, b]
        out_ref[0, b] += jnp.sum(mag >= tau).astype(jnp.int32)
        return ()

    jax.lax.fori_loop(0, branch, body, ())


@functools.partial(jax.jit, static_argnames=("interpret",))
def count_ge_level_pallas(x: jax.Array, taus: jax.Array, *,
                          interpret: bool = False) -> jax.Array:
    """counts[w, b] = #{i : |x_{w,i}| >= taus_{w,b}}; x [W,d], taus [W,B].

    Per-lane brackets of the batched branch-and-bisect Top-Q threshold
    search. Zero padding is excluded by construction when taus > 0 (the
    bisection brackets always are).
    """
    w_lanes, d = x.shape
    branch = taus.shape[-1]
    n_blocks, pad = _geometry(d)
    xp = _pad_lanes(x.astype(jnp.float32), n_blocks, pad)

    out = pl.pallas_call(
        functools.partial(_count_ge_level_kernel, branch=branch),
        grid=(w_lanes, n_blocks),
        in_specs=[_blk(),
                  pl.BlockSpec((1, branch), lambda w, j: (w, 0))],
        out_specs=pl.BlockSpec((1, branch), lambda w, j: (w, 0)),
        out_shape=jax.ShapeDtypeStruct((w_lanes, branch), jnp.int32),
        interpret=interpret,
    )(xp, taus.astype(jnp.float32))
    return out
