"""Pallas TPU kernels: batched W-lane node-step stages (one pass per level).

The aggregation executors run up to W tree nodes concurrently per level
(the padded ``(L, W)`` schedule of :class:`repro.agg.plan.AggPlan`). The
scalar kernels in :mod:`sparsify_ef` / :mod:`chain_accum` fuse one node's
stage; these variants fuse a **whole level**: inputs carry a leading lane
axis ``[W, d]``, per-lane scalars (weight, τ, participate) ride in as
``[W]`` vectors, and the grid is ``(W, blocks)`` so every lane streams its
d-vector tile by tile in one ``pallas_call`` — no ``vmap`` over scalar
kernels, no per-lane dispatch overhead.

Padding lanes (``valid == 0`` — the schedule's no-op slots) skip the
elementwise math entirely (``pl.when``) and write zeros, which keeps the
executors' masked scatter-adds no-ops. The DMA for a skipped lane still
runs (block specs are static); the saved work is the VPU math and the
output traffic semantics stay identical to computing on the zero dummy row.

Cohort batching (multi-tenant rounds) flattens B shape-identical levels
into one launch: lanes are laid out cohort-major (``[B*W, d]`` — cohort b
owns lanes ``b*W .. (b+1)*W-1``) so the same ``(lanes, blocks)`` grid
serves all B cohorts in a **single** ``pallas_call``. Per-cohort TC global
masks stay compact ``[B, d]`` in HBM: ``gmask_cohorts=B`` selects a
cohort-shared block spec whose index map sends lane ``w`` to tile
``w // (lanes // B)`` — no ``[B*W, d]`` broadcast, no vmap-of-pallas_call.

``cl_fuse_level`` is the whole CL-family node step (Algorithms 3 and 5,
stragglers included) in a single pass:

    g̃   = w·g + e
    s    = p·g̃ + γ_in            (p ∈ {0,1}: participation)
    Γ    = m·s                    (m: TCS global mask; 0 for Alg 3)
    Λ̃   = (1−m)·s
    keep = |Λ̃| ≥ τ  ∨  mask_in   (τ-sparsifier or precomputed exact mask)
    Λ    = keep ? Λ̃ : 0
    e′   = Λ̃ − Λ
    γ    = Γ + Λ                  (Alg 3: γ = Λ)
    γ_out, e′ = p>0 ? (γ, e′) : (γ_in, g̃)     (straggler forwarding)
    nnz  = #{γ_out ≠ 0};  nnz_off = #{γ_out ≠ 0 ∧ m = 0}

reading (g, e, γ_in[, m, mask_in]) and writing (γ_out, e′) in a single
sweep — the unfused jnp chain takes one sweep per op (per-algorithm
totals: ``benchmarks/bench_round.py::vector_passes``).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

SUBLANES = 8
LANES = 1024
BLOCK = SUBLANES * LANES


def _pad_lanes(v: jax.Array, n_blocks: int, pad: int):
    """[W, d] → [W, n_blocks, SUBLANES, LANES] (zero padded)."""
    w = v.shape[0]
    return jnp.pad(v, ((0, 0), (0, pad))).reshape(
        w, n_blocks, SUBLANES, LANES)


def _pad_shared(v: jax.Array, n_blocks: int, pad: int):
    """[d] → [n_blocks, SUBLANES, LANES] (zero padded) — a lane-shared
    operand streamed once per block instead of once per (lane, block)."""
    return jnp.pad(v, (0, pad)).reshape(n_blocks, SUBLANES, LANES)


def _geometry(d: int):
    n_blocks = max(1, -(-d // BLOCK))
    return n_blocks, n_blocks * BLOCK - d


def _blk():
    return pl.BlockSpec((1, 1, SUBLANES, LANES), lambda w, j: (w, j, 0, 0))


def _blk_shared():
    # block index ignores the lane axis w: every lane of a level reads the
    # SAME [SUBLANES, LANES] tile — the TC global mask is stored once, [d],
    # never broadcast to [W, d] in HBM (ROADMAP open-item tail)
    return pl.BlockSpec((1, SUBLANES, LANES), lambda w, j: (j, 0, 0))


def _blk_cohort(lanes_per_cohort: int):
    # block index maps lane w to its cohort w // lanes_per_cohort: with
    # lanes flattened cohort-major, every lane of a cohort reads the SAME
    # tile of that cohort's [d] mask — stored once per cohort as [B, d],
    # never broadcast to [B*W, d] in HBM
    return pl.BlockSpec((1, 1, SUBLANES, LANES),
                        lambda w, j: (w // lanes_per_cohort, j, 0, 0))


def _lane():
    return pl.BlockSpec((1,), lambda w, j: (w,))


def _gmask_operand(gmask, w_lanes: int, gmask_cohorts: int, n_blocks: int,
                   pad: int):
    """Pick the (padded operand, block spec) for a TC global mask.

    [d] → lane-shared; [B, d] with ``gmask_cohorts == B`` → cohort-shared
    (requires ``w_lanes % B == 0``); [W, d] → per-lane.
    """
    if gmask.ndim == 1:
        return _pad_shared(gmask.astype(jnp.float32), n_blocks, pad), \
            _blk_shared()
    if gmask_cohorts:
        if gmask.shape[0] != gmask_cohorts or w_lanes % gmask_cohorts:
            raise ValueError(
                f"cohort gmask {gmask.shape} incompatible with "
                f"{w_lanes} lanes / {gmask_cohorts} cohorts")
        return _pad_lanes(gmask.astype(jnp.float32), n_blocks, pad), \
            _blk_cohort(w_lanes // gmask_cohorts)
    return _pad_lanes(gmask.astype(jnp.float32), n_blocks, pad), _blk()


def _drop_pad_level(counts, taus, pad: int):
    """Subtract the zero-padding contribution from per-lane counts.

    Pad elements reconstruct to exactly 0.0 under every operand form
    (g = e = γ_in = m = 0 ⇒ w·0+0 = 0, p·0+0 = 0, (1−0)·0 = 0), so they
    inflate ``counts[w, b]`` by ``pad`` iff ``taus[w, b] <= 0``. The
    bisection brackets are strictly positive (no-op there), but the
    exclusion is enforced here, not just asserted in tests."""
    if pad == 0:
        return counts
    return counts - jnp.where(taus <= 0, jnp.int32(pad), jnp.int32(0))


def _pinned_tile_err(sq):
    """Pairwise-fold an (SUBLANES, LANES) tile of squares to a scalar.

    The documented ``err_sq_mode="kernel"`` summation order: lanes fold
    pairwise 1024 → 512 → … → 1 (``x[:, :n] + x[:, n:2n]``), then sublanes
    8 → 4 → 2 → 1; block scalars accumulate left-to-right in grid order.
    """
    sq = sq.reshape(SUBLANES, LANES)
    n = LANES
    while n > 1:
        n //= 2
        sq = sq[:, :n] + sq[:, n:2 * n]
    m = SUBLANES
    while m > 1:
        m //= 2
        sq = sq[:m, :] + sq[m:2 * m, :]
    return sq[0, 0]


# ---------------------------------------------------------------------------
# sparsify_ef_level — Algs 1/2/4 EF + sparsify stage, one pass per level
# ---------------------------------------------------------------------------

def _sparsify_ef_level_kernel(g_ref, e_ref, w_ref, tau_ref, v_ref, *rest,
                              has_mask: bool, with_err: bool):
    if has_mask:
        m_ref, *rest = rest
    if with_err:
        *rest, err_ref = rest
    gbar_ref, enew_ref, nnz_ref = rest
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        nnz_ref[0] = jnp.int32(0)
        if with_err:
            err_ref[0] = jnp.float32(0)

    ok = v_ref[0] > 0

    @pl.when(ok)
    def _compute():
        w = w_ref[0]
        tau = tau_ref[0]
        gt = (w * g_ref[...].astype(jnp.float32)
              + e_ref[...].astype(jnp.float32))
        keep = jnp.abs(gt) >= tau
        if has_mask:
            keep = keep | (m_ref[...] > 0)
        gbar = jnp.where(keep, gt, 0.0)
        e_new = gt - gbar
        gbar_ref[...] = gbar.astype(gbar_ref.dtype)
        enew_ref[...] = e_new.astype(enew_ref.dtype)
        nnz_ref[0] += jnp.sum(gbar != 0).astype(jnp.int32)
        if with_err:
            err_ref[0] += _pinned_tile_err(e_new * e_new)

    @pl.when(jnp.logical_not(ok))
    def _skip():
        gbar_ref[...] = jnp.zeros_like(gbar_ref)
        enew_ref[...] = jnp.zeros_like(enew_ref)


@functools.partial(jax.jit, static_argnames=("with_err", "interpret"))
def sparsify_ef_level_pallas(g, e, mask_in, weight, tau, valid, *,
                             with_err: bool = False,
                             interpret: bool = False):
    """Batched fused EF+sparsify. g,e: [W,d]; weight,tau,valid: [W];
    mask_in (optional [W,d]): keep mask OR-ed with the τ test (None skips
    the mask stream entirely — the pure-threshold sparsifier path).

    Returns (ḡ [W,d] g.dtype, e' [W,d] e.dtype, nnz [W] int32); with
    ``with_err``, appends the in-kernel pinned-order ‖e'‖² ([W] f32, see
    :func:`_pinned_tile_err`) — no separate jnp pass over e'.
    """
    w_lanes, d = g.shape
    n_blocks, pad = _geometry(d)
    gp = _pad_lanes(g.astype(jnp.float32), n_blocks, pad)
    ep = _pad_lanes(e.astype(jnp.float32), n_blocks, pad)
    has_mask = mask_in is not None
    operands = [gp, ep, weight.astype(jnp.float32), tau.astype(jnp.float32),
                valid.astype(jnp.float32)]
    in_specs = [_blk(), _blk(), _lane(), _lane(), _lane()]
    if has_mask:
        operands.append(_pad_lanes(mask_in.astype(jnp.float32), n_blocks,
                                   pad))
        in_specs.append(_blk())
    out_specs = [_blk(), _blk(), _lane()]
    out_shape = [
        jax.ShapeDtypeStruct(gp.shape, g.dtype),
        jax.ShapeDtypeStruct(ep.shape, e.dtype),
        jax.ShapeDtypeStruct((w_lanes,), jnp.int32),
    ]
    if with_err:
        out_specs.append(_lane())
        out_shape.append(jax.ShapeDtypeStruct((w_lanes,), jnp.float32))

    out = pl.pallas_call(
        functools.partial(_sparsify_ef_level_kernel, has_mask=has_mask,
                          with_err=with_err),
        grid=(w_lanes, n_blocks),
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(*operands)
    gbar, e_new, nnz = out[:3]
    res = (gbar.reshape(w_lanes, -1)[:, :d],
           e_new.reshape(w_lanes, -1)[:, :d], nnz)
    return res + (out[3],) if with_err else res


# ---------------------------------------------------------------------------
# chain_accum_level — Algs 1/2/4 IA combine, fused support counts
# ---------------------------------------------------------------------------

def _chain_accum_level_kernel(gin_ref, gbar_ref, v_ref, *rest,
                              has_gmask: bool):
    if has_gmask:
        gm_ref, gout_ref, nnz_ref, off_ref = rest
    else:
        gout_ref, nnz_ref, off_ref = rest
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        nnz_ref[0] = jnp.int32(0)
        off_ref[0] = jnp.int32(0)

    ok = v_ref[0] > 0

    @pl.when(ok)
    def _compute():
        gamma = (gin_ref[...].astype(jnp.float32)
                 + gbar_ref[...].astype(jnp.float32))
        gout_ref[...] = gamma.astype(gout_ref.dtype)
        nz = gamma != 0
        nnz_ref[0] += jnp.sum(nz).astype(jnp.int32)
        if has_gmask:
            off_ref[0] += jnp.sum(nz & (gm_ref[...] <= 0)).astype(jnp.int32)
        else:
            off_ref[0] += jnp.sum(nz).astype(jnp.int32)

    @pl.when(jnp.logical_not(ok))
    def _skip():
        gout_ref[...] = jnp.zeros_like(gout_ref)


@functools.partial(jax.jit, static_argnames=("gmask_cohorts", "interpret"))
def chain_accum_level_pallas(gamma_in, gbar, valid, gmask=None, *,
                             gmask_cohorts: int = 0,
                             interpret: bool = False):
    """Batched γ_out = γ_in + ḡ with fused counts.

    gamma_in, gbar: [W,d]; valid: [W]; gmask (optional): the TCS global
    mask — per-lane [W,d], lane-shared [d] (streamed once per block, not
    broadcast), or cohort-shared [B,d] with ``gmask_cohorts=B`` (lanes
    flattened cohort-major); when given, ``nnz_off`` counts the off-mask
    support ``#{γ_out ≠ 0 ∧ m = 0}`` (the §V locally-indexed part);
    without it, ``nnz_off == nnz``.
    Returns (γ_out [W,d], nnz [W] i32, nnz_off [W] i32).
    """
    w_lanes, d = gamma_in.shape
    n_blocks, pad = _geometry(d)
    gi = _pad_lanes(gamma_in.astype(jnp.float32), n_blocks, pad)
    gb = _pad_lanes(gbar.astype(jnp.float32), n_blocks, pad)
    has_gmask = gmask is not None
    operands = [gi, gb, valid.astype(jnp.float32)]
    in_specs = [_blk(), _blk(), _lane()]
    if has_gmask:
        op, spec = _gmask_operand(gmask, w_lanes, gmask_cohorts, n_blocks,
                                  pad)
        operands.append(op)
        in_specs.append(spec)

    gout, nnz, nnz_off = pl.pallas_call(
        functools.partial(_chain_accum_level_kernel, has_gmask=has_gmask),
        grid=(w_lanes, n_blocks),
        in_specs=in_specs,
        out_specs=[_blk(), _lane(), _lane()],
        out_shape=[
            jax.ShapeDtypeStruct(gi.shape, gamma_in.dtype),
            jax.ShapeDtypeStruct((w_lanes,), jnp.int32),
            jax.ShapeDtypeStruct((w_lanes,), jnp.int32),
        ],
        interpret=interpret,
    )(*operands)
    return gout.reshape(w_lanes, -1)[:, :d], nnz, nnz_off


# ---------------------------------------------------------------------------
# cl_fuse_level — Algs 3/5 complete node step in one pass
# ---------------------------------------------------------------------------

def _cl_fuse_level_kernel(g_ref, e_ref, gin_ref, w_ref, tau_ref, p_ref,
                          v_ref, *rest, has_gmask: bool, has_mask: bool,
                          with_err: bool):
    idx = 0
    gm_ref = mask_ref = err_ref = None
    if has_gmask:
        gm_ref = rest[idx]
        idx += 1
    if has_mask:
        mask_ref = rest[idx]
        idx += 1
    if with_err:
        gout_ref, enew_ref, nnz_ref, off_ref, err_ref = rest[idx:]
    else:
        gout_ref, enew_ref, nnz_ref, off_ref = rest[idx:]
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        nnz_ref[0] = jnp.int32(0)
        off_ref[0] = jnp.int32(0)
        if with_err:
            err_ref[0] = jnp.float32(0)

    ok = v_ref[0] > 0

    @pl.when(ok)
    def _compute():
        w = w_ref[0]
        tau = tau_ref[0]
        p = p_ref[0]
        gt = (w * g_ref[...].astype(jnp.float32)
              + e_ref[...].astype(jnp.float32))
        gin = gin_ref[...].astype(jnp.float32)
        s = p * gt + gin
        if has_gmask:
            m = gm_ref[...]
            lam_t = (1.0 - m) * s
        else:
            lam_t = s
        keep = jnp.abs(lam_t) >= tau
        if has_mask:
            keep = keep | (mask_ref[...] > 0)
        lam = jnp.where(keep, lam_t, 0.0)
        e_new = lam_t - lam
        gamma = (m * s + lam) if has_gmask else lam
        alive = p > 0
        gamma = jnp.where(alive, gamma, gin)
        e_new = jnp.where(alive, e_new, gt)
        gout_ref[...] = gamma.astype(gout_ref.dtype)
        enew_ref[...] = e_new.astype(enew_ref.dtype)
        nz = gamma != 0
        nnz_ref[0] += jnp.sum(nz).astype(jnp.int32)
        if has_gmask:
            off_ref[0] += jnp.sum(nz & (gm_ref[...] <= 0)).astype(jnp.int32)
        else:
            off_ref[0] += jnp.sum(nz).astype(jnp.int32)
        if with_err:
            err_ref[0] += _pinned_tile_err(e_new * e_new)

    @pl.when(jnp.logical_not(ok))
    def _skip():
        gout_ref[...] = jnp.zeros_like(gout_ref)
        enew_ref[...] = jnp.zeros_like(enew_ref)


@functools.partial(jax.jit, static_argnames=("gmask_cohorts", "with_err",
                                             "interpret"))
def cl_fuse_level_pallas(g, e, gamma_in, weight, tau, participate, valid,
                         gmask=None, mask_in=None, *,
                         gmask_cohorts: int = 0, with_err: bool = False,
                         interpret: bool = False):
    """Batched complete CL node step (Algs 3/5, stragglers included).

    g, e, gamma_in: [W,d]; weight, tau, participate, valid: [W];
    gmask (optional): TCS global mask m (Alg 5; None = Alg 3) — per-lane
    [W,d], lane-shared [d] (streamed once per block, not broadcast), or
    cohort-shared [B,d] with ``gmask_cohorts=B`` (lanes cohort-major);
    mask_in (optional, [W,d]): precomputed keep mask OR-ed with the τ test
    (pass τ=+inf for a pure-mask exact sparsifier).

    Returns (γ_out [W,d], e' [W,d], nnz [W] i32, nnz_off [W] i32) where
    ``nnz_off`` is the off-global-mask support (= nnz when gmask is None);
    with ``with_err``, appends the in-kernel pinned-order ‖e'‖² ([W] f32,
    see :func:`_pinned_tile_err`).
    """
    w_lanes, d = g.shape
    n_blocks, pad = _geometry(d)
    gp = _pad_lanes(g.astype(jnp.float32), n_blocks, pad)
    ep = _pad_lanes(e.astype(jnp.float32), n_blocks, pad)
    gi = _pad_lanes(gamma_in.astype(jnp.float32), n_blocks, pad)
    has_gmask = gmask is not None
    has_mask = mask_in is not None
    operands = [gp, ep, gi, weight.astype(jnp.float32),
                tau.astype(jnp.float32), participate.astype(jnp.float32),
                valid.astype(jnp.float32)]
    in_specs = [_blk(), _blk(), _blk(), _lane(), _lane(), _lane(), _lane()]
    if has_gmask:
        op, spec = _gmask_operand(gmask, w_lanes, gmask_cohorts, n_blocks,
                                  pad)
        operands.append(op)
        in_specs.append(spec)
    if has_mask:
        operands.append(_pad_lanes(mask_in.astype(jnp.float32), n_blocks,
                                   pad))
        in_specs.append(_blk())
    out_specs = [_blk(), _blk(), _lane(), _lane()]
    out_shape = [
        jax.ShapeDtypeStruct(gi.shape, gamma_in.dtype),
        jax.ShapeDtypeStruct(ep.shape, e.dtype),
        jax.ShapeDtypeStruct((w_lanes,), jnp.int32),
        jax.ShapeDtypeStruct((w_lanes,), jnp.int32),
    ]
    if with_err:
        out_specs.append(_lane())
        out_shape.append(jax.ShapeDtypeStruct((w_lanes,), jnp.float32))

    out = pl.pallas_call(
        functools.partial(_cl_fuse_level_kernel, has_gmask=has_gmask,
                          has_mask=has_mask, with_err=with_err),
        grid=(w_lanes, n_blocks),
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(*operands)
    gout, e_new, nnz, nnz_off = out[:4]
    res = (gout.reshape(w_lanes, -1)[:, :d],
           e_new.reshape(w_lanes, -1)[:, :d], nnz, nnz_off)
    return res + (out[4],) if with_err else res


# ---------------------------------------------------------------------------
# count_ge_level — per-lane candidate-threshold counting (batched bisection)
# ---------------------------------------------------------------------------

def _count_ge_level_kernel(x_ref, taus_ref, out_ref, *, branch: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    mag = jnp.abs(x_ref[...].astype(jnp.float32))

    def body(b, _):
        tau = taus_ref[0, b]
        out_ref[0, b] += jnp.sum(mag >= tau).astype(jnp.int32)
        return ()

    jax.lax.fori_loop(0, branch, body, ())


@functools.partial(jax.jit, static_argnames=("interpret",))
def count_ge_level_pallas(x: jax.Array, taus: jax.Array, *,
                          interpret: bool = False) -> jax.Array:
    """counts[w, b] = #{i : |x_{w,i}| >= taus_{w,b}}; x [W,d], taus [W,B].

    Per-lane brackets of the batched branch-and-bisect Top-Q threshold
    search. The zero padding's contribution is subtracted in the wrapper
    (:func:`_drop_pad_level`) — exact for any taus, including
    non-positive ones.
    """
    w_lanes, d = x.shape
    branch = taus.shape[-1]
    n_blocks, pad = _geometry(d)
    xp = _pad_lanes(x.astype(jnp.float32), n_blocks, pad)
    taus = taus.astype(jnp.float32)

    out = pl.pallas_call(
        functools.partial(_count_ge_level_kernel, branch=branch),
        grid=(w_lanes, n_blocks),
        in_specs=[_blk(),
                  pl.BlockSpec((1, branch), lambda w, j: (w, 0))],
        out_specs=pl.BlockSpec((1, branch), lambda w, j: (w, 0)),
        out_shape=jax.ShapeDtypeStruct((w_lanes, branch), jnp.int32),
        interpret=interpret,
    )(xp, taus)
    return _drop_pad_level(out, taus, pad)


# ---------------------------------------------------------------------------
# count_ge_fused_level — operand-on-the-fly candidate counting per lane
# ---------------------------------------------------------------------------

def _fused_operand_tile(g_ref, e_ref, gin_ref, gm_ref, w_ref, p_ref, *,
                        include_gamma: bool, has_gmask: bool):
    """Reconstruct one (8, LANES) tile of the bisection operand in VMEM.

    Same float expression per element as the cl_fuse/sparsify_ef kernels
    (and the materialized jnp path): ``(1−m)·(p·(w·g + e) + γ_in)`` with
    the γ/mask factors dropped per the static flags.
    """
    op = (w_ref[0] * g_ref[...].astype(jnp.float32)
          + e_ref[...].astype(jnp.float32))
    if include_gamma:
        op = p_ref[0] * op + gin_ref[...].astype(jnp.float32)
    if has_gmask:
        op = (1.0 - gm_ref[...]) * op
    return op


def _count_ge_fused_level_kernel(g_ref, e_ref, *rest, branch: int,
                                 include_gamma: bool, has_gmask: bool):
    idx = 0
    gin_ref = gm_ref = None
    if include_gamma:
        gin_ref = rest[idx]
        idx += 1
    w_ref, p_ref = rest[idx:idx + 2]
    idx += 2
    if has_gmask:
        gm_ref = rest[idx]
        idx += 1
    taus_ref, out_ref = rest[idx:]
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    mag = jnp.abs(_fused_operand_tile(
        g_ref, e_ref, gin_ref, gm_ref, w_ref, p_ref,
        include_gamma=include_gamma, has_gmask=has_gmask))

    def body(b, _):
        out_ref[0, b] += jnp.sum(mag >= taus_ref[0, b]).astype(jnp.int32)
        return ()

    jax.lax.fori_loop(0, branch, body, ())


@functools.partial(jax.jit, static_argnames=("include_gamma",
                                             "gmask_cohorts", "interpret"))
def count_ge_fused_level_pallas(g, e, gamma_in, weight, participate, taus,
                                gmask=None, *, include_gamma: bool = False,
                                gmask_cohorts: int = 0,
                                interpret: bool = False) -> jax.Array:
    """Per-lane candidate counts of the fused bisection operand.

    The τ-search operand (see :func:`_fused_operand_tile`) is rebuilt
    tile-by-tile from the raw node inputs — the materialized-g̃ HBM
    round-trip before ``threshold_for_topq`` disappears. g, e[, γ_in]:
    [W, d]; weight, participate: [W]; taus: [W, B]; gmask per
    :func:`_gmask_operand`. Returns counts [W, B] i32; zero padding
    reconstructs to exactly 0.0 and is subtracted in the wrapper.
    """
    w_lanes, d = g.shape
    branch = taus.shape[-1]
    n_blocks, pad = _geometry(d)
    has_gmask = gmask is not None
    taus = taus.astype(jnp.float32)
    operands = [_pad_lanes(g.astype(jnp.float32), n_blocks, pad),
                _pad_lanes(e.astype(jnp.float32), n_blocks, pad)]
    in_specs = [_blk(), _blk()]
    if include_gamma:
        operands.append(_pad_lanes(gamma_in.astype(jnp.float32), n_blocks,
                                   pad))
        in_specs.append(_blk())
    operands += [weight.astype(jnp.float32),
                 participate.astype(jnp.float32)]
    in_specs += [_lane(), _lane()]
    if has_gmask:
        op, spec = _gmask_operand(gmask, w_lanes, gmask_cohorts, n_blocks,
                                  pad)
        operands.append(op)
        in_specs.append(spec)
    operands.append(taus)
    in_specs.append(pl.BlockSpec((1, branch), lambda w, j: (w, 0)))

    out = pl.pallas_call(
        functools.partial(_count_ge_fused_level_kernel, branch=branch,
                          include_gamma=include_gamma, has_gmask=has_gmask),
        grid=(w_lanes, n_blocks),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, branch), lambda w, j: (w, 0)),
        out_shape=jax.ShapeDtypeStruct((w_lanes, branch), jnp.int32),
        interpret=interpret,
    )(*operands)
    return _drop_pad_level(out, taus, pad)


# ---------------------------------------------------------------------------
# hist_topq_level — one-pass joint digit histogram (tau_impl="hist")
# ---------------------------------------------------------------------------

def _hist_topq_level_kernel(g_ref, e_ref, *rest, branch: int,
                            include_gamma: bool, has_gmask: bool):
    idx = 0
    gin_ref = gm_ref = None
    if include_gamma:
        gin_ref = rest[idx]
        idx += 1
    w_ref, p_ref = rest[idx:idx + 2]
    idx += 2
    if has_gmask:
        gm_ref = rest[idx]
        idx += 1
    tau1_ref, nl_ref, w2_ref, ts_ref, d2_ref, f_ref = rest[idx:]
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        d2_ref[...] = jnp.zeros_like(d2_ref)
        f_ref[...] = jnp.zeros_like(f_ref)

    mag = jnp.abs(_fused_operand_tile(
        g_ref, e_ref, gin_ref, gm_ref, w_ref, p_ref,
        include_gamma=include_gamma, has_gmask=has_gmask))

    # round-1 digit: #{candidates <= |x|} — one vectorized compare per
    # candidate (the whole search's streaming passes collapse to this one)
    def cnt1(b, acc):
        return acc + (mag >= tau1_ref[0, b]).astype(jnp.int32)

    d1 = jax.lax.fori_loop(0, branch, cnt1, jnp.zeros_like(mag, jnp.int32))

    # per-element bracket tables via one-hot select-sums (gathers are
    # hostile to the VPU; d1 is exact so exactly one term fires)
    def gather(bb, carry):
        nl, w2e, te = carry
        sel = d1 == bb
        nl = nl + jnp.where(sel, nl_ref[0, bb], 0.0)
        w2e = w2e + jnp.where(sel, w2_ref[0, bb], 0.0)
        te = te + jnp.where(sel, ts_ref[0, bb], 0.0)
        return nl, w2e, te

    zeros = jnp.zeros_like(mag)
    nl, w2e, te = jax.lax.fori_loop(0, branch + 1, gather,
                                    (zeros, zeros, zeros))

    # round-2 digit within the element's own bracket — same candidate
    # expression fl(nl + fl(w2·j)) as the scan's second round
    def cnt2(b, acc):
        cand = nl + w2e * (b + 1).astype(jnp.float32)
        return acc + (mag >= cand).astype(jnp.int32)

    d2 = jax.lax.fori_loop(0, branch, cnt2, jnp.zeros_like(mag, jnp.int32))
    flag = (mag >= te).astype(jnp.float32)

    # joint histogram via one-hot contraction: D2[r, c] = Σ 1[d1=r]·1[d2=c]
    # — one dot_general on the MXU, exact in f32 (counts < 2²⁴)
    iota = jax.lax.broadcasted_iota(jnp.int32,
                                    (SUBLANES, LANES, branch + 1), 2)
    oh1 = (d1[0, 0][..., None] == iota).astype(jnp.float32)
    oh2 = (d2[0, 0][..., None] == iota).astype(jnp.float32)
    dnums = (((0, 1), (0, 1)), ((), ()))
    d2_ref[0] += jax.lax.dot_general(
        oh1, oh2, dnums, preferred_element_type=jnp.float32
    ).astype(jnp.int32)
    f_ref[0] += jax.lax.dot_general(
        oh1, flag[0, 0], dnums, preferred_element_type=jnp.float32
    ).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("include_gamma",
                                             "gmask_cohorts", "interpret"))
def hist_topq_level_pallas(g, e, gamma_in, weight, participate, tables,
                           gmask=None, *, include_gamma: bool = False,
                           gmask_cohorts: int = 0,
                           interpret: bool = False):
    """One-pass joint digit histogram of the fused bisection operand.

    Collapses the `hist_rounds` sequential streaming passes of the
    branch-and-bisect scan into a single sweep: each element is binned by
    its round-1 digit d1 (which of the branch+1 round-1 brackets it falls
    in) and its round-2 digit d2 (candidate count within its *own*
    bracket), plus an exact flag for the bracket-top candidate. The bin
    edges are the scan's own bracket arithmetic
    (``repro.core.sparsify._hist_tables``), so the reconstructed per-round
    candidate counts are bit-identical integers to the scan
    (``_hist_bisect`` — branch=64, rounds=2 ⇒ 64² final resolution).

    ``tables = (tau1 [W,b], new_lo [W,b+1], w2 [W,b+1], top_shift [W,b+1])``;
    returns ``(D2 [W, b+1, b+1] i32, F [W, b+1] i32)``. Zero padding
    reconstructs to operand 0.0 → bin D2[·, 0, 0], which the
    reconstruction never reads (all candidates are strictly positive).
    """
    w_lanes, d = g.shape
    tau1, new_lo, w2, top_shift = tables
    branch = tau1.shape[-1]
    n_blocks, pad = _geometry(d)
    has_gmask = gmask is not None
    operands = [_pad_lanes(g.astype(jnp.float32), n_blocks, pad),
                _pad_lanes(e.astype(jnp.float32), n_blocks, pad)]
    in_specs = [_blk(), _blk()]
    if include_gamma:
        operands.append(_pad_lanes(gamma_in.astype(jnp.float32), n_blocks,
                                   pad))
        in_specs.append(_blk())
    operands += [weight.astype(jnp.float32),
                 participate.astype(jnp.float32)]
    in_specs += [_lane(), _lane()]
    if has_gmask:
        op, spec = _gmask_operand(gmask, w_lanes, gmask_cohorts, n_blocks,
                                  pad)
        operands.append(op)
        in_specs.append(spec)
    row = lambda n: pl.BlockSpec((1, n), lambda w, j: (w, 0))
    operands += [tau1.astype(jnp.float32), new_lo.astype(jnp.float32),
                 w2.astype(jnp.float32), top_shift.astype(jnp.float32)]
    in_specs += [row(branch), row(branch + 1), row(branch + 1),
                 row(branch + 1)]

    D2, F = pl.pallas_call(
        functools.partial(_hist_topq_level_kernel, branch=branch,
                          include_gamma=include_gamma, has_gmask=has_gmask),
        grid=(w_lanes, n_blocks),
        in_specs=in_specs,
        out_specs=[pl.BlockSpec((1, branch + 1, branch + 1),
                                lambda w, j: (w, 0, 0)),
                   row(branch + 1)],
        out_shape=[
            jax.ShapeDtypeStruct((w_lanes, branch + 1, branch + 1),
                                 jnp.int32),
            jax.ShapeDtypeStruct((w_lanes, branch + 1), jnp.int32),
        ],
        interpret=interpret,
    )(*operands)
    return D2, F
