"""Pallas TPU kernel: fused error-feedback + sparsification (one HBM pass).

Computes, tile by tile:

    g̃    = weight·g + e
    keep = (|g̃| >= tau) | (mask_in > 0)
    ḡ    = keep ? g̃ : 0
    e'   = g̃ − ḡ
    nnz += #{ḡ ≠ 0}

Unfused, this is 4 elementwise HLO ops = 4+ HBM round-trips over a
d = O(10⁹/chips) gradient shard; fused it is one read of (g, e, mask) and
one write of (ḡ, e′) — the aggregation path is memory-bound, so pass count
is the whole game (DESIGN §3). Covers Alg 1 (mask_in=0), Alg 2
(mask_in=supp γ_in) and Alg 4 (mask_in=m ∪ m_k ∪ m̃) node steps.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

SUBLANES = 8
LANES = 1024
BLOCK = SUBLANES * LANES


def _sparsify_ef_kernel(g_ref, e_ref, m_ref, w_ref, tau_ref,
                        gbar_ref, enew_ref, nnz_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        nnz_ref[0] = jnp.int32(0)

    w = w_ref[0]
    tau = tau_ref[0]
    gt = w * g_ref[...].astype(jnp.float32) + e_ref[...].astype(jnp.float32)
    keep = (jnp.abs(gt) >= tau) | (m_ref[...] > 0)
    gbar = jnp.where(keep, gt, 0.0)
    gbar_ref[...] = gbar.astype(gbar_ref.dtype)
    enew_ref[...] = (gt - gbar).astype(enew_ref.dtype)
    nnz_ref[0] += jnp.sum(gbar != 0).astype(jnp.int32)


def _pad_blocks(v: jax.Array, n_blocks: int, pad: int):
    return jnp.pad(v, (0, pad)).reshape(n_blocks, SUBLANES, LANES)


@functools.partial(jax.jit, static_argnames=("interpret",))
def sparsify_ef_pallas(g: jax.Array, e: jax.Array, mask_in: jax.Array,
                       weight: jax.Array, tau: jax.Array, *,
                       interpret: bool = False):
    """Fused EF+sparsify. g,e,mask_in: [d]; weight,tau: scalars.

    Returns (ḡ [d] g.dtype, e' [d] e.dtype, nnz int32 scalar).
    """
    (d,) = g.shape
    n_blocks = max(1, -(-d // BLOCK))
    pad = n_blocks * BLOCK - d
    gp = _pad_blocks(g.astype(jnp.float32), n_blocks, pad)
    ep = _pad_blocks(e.astype(jnp.float32), n_blocks, pad)
    mp = _pad_blocks(mask_in.astype(jnp.float32), n_blocks, pad)

    blk = pl.BlockSpec((1, SUBLANES, LANES), lambda i: (i, 0, 0))
    scal = pl.BlockSpec((1,), lambda i: (0,))
    gbar, e_new, nnz = pl.pallas_call(
        _sparsify_ef_kernel,
        grid=(n_blocks,),
        in_specs=[blk, blk, blk, scal, scal],
        out_specs=[blk, blk, scal],
        out_shape=[
            jax.ShapeDtypeStruct(gp.shape, g.dtype),
            jax.ShapeDtypeStruct(ep.shape, e.dtype),
            jax.ShapeDtypeStruct((1,), jnp.int32),
        ],
        interpret=interpret,
    )(gp, ep, mp, jnp.reshape(weight, (1,)).astype(jnp.float32),
      jnp.reshape(tau, (1,)).astype(jnp.float32))
    gbar = gbar.reshape(-1)[:d]
    e_new = e_new.reshape(-1)[:d]
    return gbar, e_new, nnz[0]
