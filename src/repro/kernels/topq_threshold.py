"""Pallas TPU kernel: streaming candidate-threshold counting for Top-Q.

One pass over the gradient shard computes, for B candidate thresholds,
``counts[j] = #{i : |x_i| >= tau_j}``. The branch-and-bisect wrapper in
``repro.core.sparsify.threshold_for_topq`` calls this once per round
(3 rounds × 1 streaming pass replaces a full O(d log d) sort whose layout is
hostile to the VPU; see DESIGN §3).

Tiling: x is viewed as [n_blocks, 8, 128·LANES] rows; each grid step streams
one (8, BLK) tile HBM→VMEM, compares against the B taus (held in VMEM, tiny)
with a fori_loop over B (each iteration is a fully-vectorized (8, BLK)
compare+reduce on the VPU), and accumulates into the int32 [B] output —
TPU grid steps run sequentially, so output accumulation is race-free.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Tile geometry: (8, 1024) f32 = 32 KiB — 8 sublanes × 8 lane-groups of 128.
SUBLANES = 8
LANES = 1024
BLOCK = SUBLANES * LANES


def _count_ge_kernel(x_ref, taus_ref, out_ref, *, branch: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    mag = jnp.abs(x_ref[...].astype(jnp.float32))     # (8, LANES)

    def body(j, _):
        tau = taus_ref[j]
        cnt = jnp.sum(mag >= tau).astype(jnp.int32)
        out_ref[j] += cnt
        return ()

    jax.lax.fori_loop(0, branch, body, ())


def _drop_pad(counts: jax.Array, taus: jax.Array, pad: int) -> jax.Array:
    """Remove the zero-padding contribution from streamed counts.

    Every pad element compares as exactly 0.0, so it inflates ``counts[j]``
    by ``pad`` iff ``taus_j <= 0``. The bisection brackets are strictly
    positive, where this is a no-op — but the exclusion is enforced *here*
    rather than merely asserted in tests, so a caller with a zero (or
    negative) candidate can't silently over-count.
    """
    if pad == 0:
        return counts
    return counts - jnp.where(taus <= 0, jnp.int32(pad), jnp.int32(0))


@functools.partial(jax.jit, static_argnames=("interpret",))
def count_ge_pallas(x: jax.Array, taus: jax.Array, *,
                    interpret: bool = False) -> jax.Array:
    """counts[j] = #{i : |x_i| >= taus_j}; x [d] float, taus [B] f32 → [B] i32.

    Zero-pads x up to a BLOCK multiple; the padding's contribution is
    subtracted in the wrapper (:func:`_drop_pad`), so the counts are exact
    for any taus, including non-positive ones.
    """
    (d,) = x.shape
    (branch,) = taus.shape
    n_blocks = max(1, -(-d // BLOCK))
    pad = n_blocks * BLOCK - d
    xp = jnp.pad(x.astype(jnp.float32), (0, pad)).reshape(
        n_blocks, SUBLANES, LANES)
    taus = taus.astype(jnp.float32)

    out = pl.pallas_call(
        functools.partial(_count_ge_kernel, branch=branch),
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((1, SUBLANES, LANES), lambda i: (i, 0, 0)),
            pl.BlockSpec((branch,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((branch,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((branch,), jnp.int32),
        interpret=interpret,
    )(xp, taus)
    return _drop_pad(out, taus, pad)


# ---------------------------------------------------------------------------
# count_ge_fused — operand-on-the-fly candidate counting
# ---------------------------------------------------------------------------

def _count_ge_fused_kernel(g_ref, e_ref, *rest, branch: int,
                           include_gamma: bool):
    if include_gamma:
        gin_ref, w_ref, p_ref, taus_ref, out_ref = rest
    else:
        w_ref, p_ref, taus_ref, out_ref = rest
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    w = w_ref[0]
    op = (w * g_ref[...].astype(jnp.float32)
          + e_ref[...].astype(jnp.float32))
    if include_gamma:
        op = p_ref[0] * op + gin_ref[...].astype(jnp.float32)
    mag = jnp.abs(op)

    def body(j, _):
        out_ref[j] += jnp.sum(mag >= taus_ref[j]).astype(jnp.int32)
        return ()

    jax.lax.fori_loop(0, branch, body, ())


@functools.partial(jax.jit, static_argnames=("include_gamma", "interpret"))
def count_ge_fused_pallas(g, e, gamma_in, weight, participate, taus, *,
                          include_gamma: bool = False,
                          interpret: bool = False) -> jax.Array:
    """Candidate counts of the bisection operand, reconstructed in VMEM.

    The τ search's operand (``w·g + e``, or ``p·(w·g + e) + γ_in`` when
    ``include_gamma`` — the CL family) is rebuilt tile-by-tile from the raw
    node inputs instead of being materialized to HBM first: g, e[, γ_in]
    [d]; weight, participate scalars; taus [B] f32 → counts [B] i32.
    Zero padding reconstructs to exactly 0.0 and is subtracted in the
    wrapper (:func:`_drop_pad`).
    """
    (d,) = g.shape
    (branch,) = taus.shape
    n_blocks = max(1, -(-d // BLOCK))
    pad = n_blocks * BLOCK - d

    def tile(v):
        return jnp.pad(v.astype(jnp.float32), (0, pad)).reshape(
            n_blocks, SUBLANES, LANES)

    blk = pl.BlockSpec((1, SUBLANES, LANES), lambda i: (i, 0, 0))
    one = pl.BlockSpec((1,), lambda i: (0,))
    taus = taus.astype(jnp.float32)
    operands = [tile(g), tile(e)]
    in_specs = [blk, blk]
    if include_gamma:
        operands.append(tile(gamma_in))
        in_specs.append(blk)
    operands += [jnp.asarray(weight, jnp.float32).reshape(1),
                 jnp.asarray(participate, jnp.float32).reshape(1), taus]
    in_specs += [one, one, pl.BlockSpec((branch,), lambda i: (0,))]

    out = pl.pallas_call(
        functools.partial(_count_ge_fused_kernel, branch=branch,
                          include_gamma=include_gamma),
        grid=(n_blocks,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((branch,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((branch,), jnp.int32),
        interpret=interpret,
    )(*operands)
    return _drop_pad(out, taus, pad)
