"""Pallas TPU kernel: streaming candidate-threshold counting for Top-Q.

One pass over the gradient shard computes, for B candidate thresholds,
``counts[j] = #{i : |x_i| >= tau_j}``. The branch-and-bisect wrapper in
``repro.core.sparsify.threshold_for_topq`` calls this once per round
(3 rounds × 1 streaming pass replaces a full O(d log d) sort whose layout is
hostile to the VPU; see DESIGN §3).

Tiling: x is viewed as [n_blocks, 8, 128·LANES] rows; each grid step streams
one (8, BLK) tile HBM→VMEM, compares against the B taus (held in VMEM, tiny)
with a fori_loop over B (each iteration is a fully-vectorized (8, BLK)
compare+reduce on the VPU), and accumulates into the int32 [B] output —
TPU grid steps run sequentially, so output accumulation is race-free.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Tile geometry: (8, 1024) f32 = 32 KiB — 8 sublanes × 8 lane-groups of 128.
SUBLANES = 8
LANES = 1024
BLOCK = SUBLANES * LANES


def _count_ge_kernel(x_ref, taus_ref, out_ref, *, branch: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    mag = jnp.abs(x_ref[...].astype(jnp.float32))     # (8, LANES)

    def body(j, _):
        tau = taus_ref[j]
        cnt = jnp.sum(mag >= tau).astype(jnp.int32)
        out_ref[j] += cnt
        return ()

    jax.lax.fori_loop(0, branch, body, ())


@functools.partial(jax.jit, static_argnames=("interpret",))
def count_ge_pallas(x: jax.Array, taus: jax.Array, *,
                    interpret: bool = False) -> jax.Array:
    """counts[j] = #{i : |x_i| >= taus_j}; x [d] float, taus [B] f32 → [B] i32.

    Zero-pads x up to a BLOCK multiple; padding is excluded by construction
    when taus > 0 (the wrapper's brackets always are) — asserted in tests.
    """
    (d,) = x.shape
    (branch,) = taus.shape
    n_blocks = max(1, -(-d // BLOCK))
    pad = n_blocks * BLOCK - d
    xp = jnp.pad(x.astype(jnp.float32), (0, pad)).reshape(
        n_blocks, SUBLANES, LANES)

    out = pl.pallas_call(
        functools.partial(_count_ge_kernel, branch=branch),
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((1, SUBLANES, LANES), lambda i: (i, 0, 0)),
            pl.BlockSpec((branch,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((branch,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((branch,), jnp.int32),
        interpret=interpret,
    )(xp, taus.astype(jnp.float32))
    return out
