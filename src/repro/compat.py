"""Compatibility shims for jax API drift (0.4.x ↔ 0.6+).

The repo targets the modern surface (``jax.shard_map``,
``jax.sharding.AxisType``, ``check_vma``); older runtimes (0.4.x) expose
``jax.experimental.shard_map`` with ``check_rep`` and meshes without axis
types. These helpers pick whichever exists so tests and examples run on
both.
"""

from __future__ import annotations

import jax


def make_mesh(shape, axes):
    """``jax.make_mesh`` with Auto axis types where supported."""
    shape, axes = tuple(shape), tuple(axes)
    try:
        from jax.sharding import AxisType
        return jax.make_mesh(shape, axes,
                             axis_types=(AxisType.Auto,) * len(axes))
    except (ImportError, TypeError):
        return jax.make_mesh(shape, axes)


def axis_size(axis) -> int:
    """Static mesh-axis size inside shard_map on any jax version.

    ``lax.psum(1, axis)`` of the literal 1 constant-folds to the axis size
    as a Python int on versions predating ``jax.lax.axis_size``.
    """
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis)
    return jax.lax.psum(1, axis)


def shard_map(f, *, mesh, in_specs, out_specs, axis_names=None):
    """Fully-manual shard_map (replication checking off) on any jax."""
    if hasattr(jax, "shard_map"):
        kwargs = dict(mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_vma=False)
        if axis_names is not None:
            kwargs["axis_names"] = axis_names
        try:
            return jax.shard_map(f, **kwargs)
        except TypeError:
            kwargs.pop("axis_names", None)
            return jax.shard_map(f, **kwargs)
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=False)
