"""Compatibility shims for jax API drift (0.4.x ↔ 0.6+).

The repo targets the modern surface (``jax.shard_map``,
``jax.sharding.AxisType``, ``check_vma``); older runtimes (0.4.x) expose
``jax.experimental.shard_map`` with ``check_rep`` and meshes without axis
types. These helpers pick whichever exists so tests and examples run on
both.
"""

from __future__ import annotations

import jax


def make_mesh(shape, axes):
    """``jax.make_mesh`` with Auto axis types where supported."""
    shape, axes = tuple(shape), tuple(axes)
    try:
        from jax.sharding import AxisType
        return jax.make_mesh(shape, axes,
                             axis_types=(AxisType.Auto,) * len(axes))
    except (ImportError, TypeError):
        return jax.make_mesh(shape, axes)


def axis_size(axis) -> int:
    """Static mesh-axis size inside shard_map on any jax version.

    ``lax.psum(1, axis)`` of the literal 1 constant-folds to the axis size
    as a Python int on versions predating ``jax.lax.axis_size``.
    """
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis)
    return jax.lax.psum(1, axis)


def set_mesh(mesh):
    """Ambient-mesh context manager on any jax version.

    Modern jax exposes ``jax.set_mesh(mesh)`` as a context manager; on
    0.4.x the ``Mesh`` object itself is the context manager that installs
    the ambient mesh, so we hand it back unchanged.
    """
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh


def tree_flatten_with_path(tree):
    """``jax.tree.flatten_with_path`` (0.6+) / ``jax.tree_util`` (0.4.x)."""
    if hasattr(jax.tree, "flatten_with_path"):
        return jax.tree.flatten_with_path(tree)
    return jax.tree_util.tree_flatten_with_path(tree)


def abstract_mesh():
    """The ambient mesh, or None outside any mesh context.

    0.6+ tracks an abstract mesh (``jax.sharding.get_abstract_mesh``);
    0.4.x tracks the physical mesh installed by the ``with mesh:`` context.
    Callers must treat axis types as Auto when the mesh doesn't carry them.
    """
    get = getattr(jax.sharding, "get_abstract_mesh", None)
    if get is not None:
        return get()
    try:
        from jax._src.mesh import thread_resources
        mesh = thread_resources.env.physical_mesh
        return None if mesh.empty else mesh
    except Exception:
        return None


def shard_map(f, *, mesh, in_specs, out_specs, axis_names=None):
    """shard_map (replication checking off) on any jax.

    ``axis_names`` selects the *manual* axes (the 0.6+ vocabulary); axes
    not named stay automatic inside the body. The 0.4.x fallback expresses
    the same split through ``auto=`` (its complement).
    """
    auto = (frozenset(mesh.axis_names) - frozenset(axis_names)
            if axis_names is not None else frozenset())
    if hasattr(jax, "shard_map"):
        kwargs = dict(mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_vma=False)
        if axis_names is not None:
            kwargs["axis_names"] = axis_names
        try:
            return jax.shard_map(f, **kwargs)
        except TypeError as exc:
            if auto:
                # dropping axis_names would run the auto axes as manual —
                # missing collectives inside the body, silently wrong
                raise NotImplementedError(
                    f"this jax's shard_map has no axis_names support "
                    f"(needed for auto axes {sorted(auto)})") from exc
            kwargs.pop("axis_names", None)
            return jax.shard_map(f, **kwargs)
    from jax.experimental.shard_map import shard_map as _sm
    kwargs = dict(mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_rep=False)
    if auto:
        try:
            return _sm(f, auto=auto, **kwargs)
        except TypeError as exc:
            # running auto axes as manual would silently change the
            # body's semantics (missing collectives) — fail loudly
            raise NotImplementedError(
                f"this jax's shard_map has no partial-auto support "
                f"(needed for auto axes {sorted(auto)})") from exc
    return _sm(f, **kwargs)
