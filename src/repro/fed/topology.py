"""Topologies (chains + constellation trees), failure schedules, latency
models for the simulator.

``ChainTopology`` is the paper's linear chain. ``TreeTopology`` wraps a
:class:`repro.topo.graph.ConstellationGraph` plus a routing policy and turns
it into aggregation trees, re-routing around dead relays (tree re-rooting:
a failed relay's subtree is re-attached via surviving ISLs)."""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.topo.graph import ConstellationGraph
from repro.topo.routing import shortest_path_tree, widest_path_tree
from repro.topo.tree import AggTree


@dataclasses.dataclass
class ChainTopology:
    """Linear chain 1..K (node 1 adjacent to the PS)."""

    num_clients: int

    def order(self) -> np.ndarray:
        """Visiting order, farthest node first (identity chain)."""
        return np.arange(self.num_clients, dtype=np.int32)

    def healed_order(self, dead: list[int]) -> np.ndarray:
        """Chain with dead relays bypassed (neighbors splice together)."""
        return np.asarray([i for i in range(self.num_clients)
                           if i not in set(dead)], dtype=np.int32)

    def plan(self, *, pad_to: Optional[tuple] = None):
        """Compiled :class:`repro.agg.AggPlan` of the identity chain."""
        from repro.agg import compile_plan
        return compile_plan(self.num_clients, pad_to=pad_to)


@dataclasses.dataclass
class TreeTopology:
    """Constellation graph + routing policy → aggregation trees.

    ``routing``: "latency" / "hops" (shortest-path Dijkstra) or "widest"
    (max-bottleneck-bandwidth). ``dead`` entries are *client* indices
    (simulator row ids), mapped to graph nodes internally.
    """

    graph: ConstellationGraph
    routing: str = "latency"

    @property
    def num_clients(self) -> int:
        return self.graph.num_clients

    def tree(self, dead: tuple = ()) -> AggTree:
        """Aggregation tree over the surviving constellation.

        A dead relay is excluded from the graph before routing, so its
        subtree re-roots through surviving ISLs; the dead client itself is
        parked at the PS as an unreachable stub (zero bandwidth) — callers
        must zero its ``participate`` (see :func:`alive_mask`).
        """
        nodes = self.graph.client_nodes()
        exclude = [int(nodes[c]) for c in dead]
        if self.routing == "widest":
            return widest_path_tree(self.graph, exclude=exclude)
        return shortest_path_tree(self.graph, metric=self.routing,
                                  exclude=exclude)

    def plan(self, dead: tuple = (), *, pad_to: Optional[tuple] = None,
             bandwidth_aware: bool = False, cfg=None):
        """Compiled :class:`repro.agg.AggPlan` of the routed tree.

        ``bandwidth_aware`` attaches per-client Top-Q budgets scaled by each
        uplink's bandwidth (needs ``cfg`` for the base budget). The plan's
        ``alive`` mask already zeros dead/stranded clients — ``execute``
        folds it into ``participate``.
        """
        from repro.agg import bandwidth_budgets, compile_plan
        tree = self.tree(dead=dead)
        qb = None
        if bandwidth_aware:
            if cfg is None:
                raise ValueError("bandwidth_aware plans need cfg for the "
                                 "base Top-Q budget")
            qb = bandwidth_budgets(cfg, tree)
        return compile_plan(tree, pad_to=pad_to, q_budget=qb)

    def alive_mask(self, tree: AggTree, dead: tuple = ()) -> np.ndarray:
        """[K] 0/1 — zero for dead clients and stranded (unreachable) ones."""
        mask = np.ones((self.num_clients,), np.float32)
        if tree.reachable is not None:
            mask *= np.asarray(tree.reachable, np.float32)
        for c in dead:
            mask[c] = 0.0
        return mask


@dataclasses.dataclass
class FailureSchedule:
    """Deterministic failure/recovery schedule for reproducible tests.

    ``events[r] = ([fail_ids], [recover_ids])`` applied before round r.
    """

    num_clients: int
    events: dict

    def dead_at(self, r: int) -> list[int]:
        dead: set[int] = set()
        for rr in sorted(self.events):
            if rr > r:
                break
            fails, recovers = self.events[rr]
            dead |= set(fails)
            dead -= set(recovers)
        return sorted(dead)


@dataclasses.dataclass
class LatencyModel:
    """Log-normal per-client compute+uplink latency (straggler source)."""

    mean_s: float = 1.0
    sigma: float = 0.5
    seed: int = 0

    def sample(self, round_idx: int, k: int) -> np.ndarray:
        rng = np.random.default_rng(self.seed * 100003 + round_idx)
        return rng.lognormal(np.log(self.mean_s), self.sigma, size=k)
