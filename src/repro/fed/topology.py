"""Chain topologies, failure schedules, latency models for the simulator."""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np


@dataclasses.dataclass
class ChainTopology:
    """Linear chain 1..K (node 1 adjacent to the PS)."""

    num_clients: int

    def order(self) -> np.ndarray:
        """Visiting order, farthest node first (identity chain)."""
        return np.arange(self.num_clients, dtype=np.int32)

    def healed_order(self, dead: list[int]) -> np.ndarray:
        """Chain with dead relays bypassed (neighbors splice together)."""
        return np.asarray([i for i in range(self.num_clients)
                           if i not in set(dead)], dtype=np.int32)


@dataclasses.dataclass
class FailureSchedule:
    """Deterministic failure/recovery schedule for reproducible tests.

    ``events[r] = ([fail_ids], [recover_ids])`` applied before round r.
    """

    num_clients: int
    events: dict

    def dead_at(self, r: int) -> list[int]:
        dead: set[int] = set()
        for rr in sorted(self.events):
            if rr > r:
                break
            fails, recovers = self.events[rr]
            dead |= set(fails)
            dead -= set(recovers)
        return sorted(dead)


@dataclasses.dataclass
class LatencyModel:
    """Log-normal per-client compute+uplink latency (straggler source)."""

    mean_s: float = 1.0
    sigma: float = 0.5
    seed: int = 0

    def sample(self, round_idx: int, k: int) -> np.ndarray:
        rng = np.random.default_rng(self.seed * 100003 + round_idx)
        return rng.lognormal(np.log(self.mean_s), self.sigma, size=k)
