"""Multi-hop FL simulator — the paper's §VI experiment engine.

K clients on a chain train a d=7850 logistic-regression model on
(synthetic-)MNIST. Per round:

  1. every client takes one SGD step on its local minibatch → effective
     gradient g_k = w_k − w  (= −lr·∇_k);
  2. the chain aggregates {D_k·g_k} with the configured Algorithm 1–5
     (error feedback persists across rounds);
  3. the PS applies w ← w + γ_1 / D and broadcasts.

The round is one jitted function; the host loop only logs. Topology events
(stragglers, relay failures → healed chains) enter through per-round
``participate`` masks and ``order`` permutations.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.paper_mnist import PaperConfig
from repro.core import tcs as tcs_mod
from repro.core.algorithms import AggConfig, AggKind
from repro.core.chain import run_chain, run_chain_with_topology
from repro.data.federated import FederatedData, client_minibatch
from repro.fed.topology import FailureSchedule, TreeTopology
from repro.topo.tree import AggTree, run_tree

Array = jax.Array


# ---------------------------------------------------------------------------
# Logistic-regression model (w: [784,10], b: [10] — d = 7850)
# ---------------------------------------------------------------------------

def lr_init(pc: PaperConfig) -> dict:
    return {"w": jnp.zeros((pc.input_dim, pc.num_classes), jnp.float32),
            "b": jnp.zeros((pc.num_classes,), jnp.float32)}


def lr_loss(params: dict, x: Array, y: Array) -> Array:
    logits = x @ params["w"] + params["b"]
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))


def lr_accuracy(params: dict, x: Array, y: Array) -> Array:
    logits = x @ params["w"] + params["b"]
    return jnp.mean((jnp.argmax(logits, -1) == y).astype(jnp.float32))


def flatten_lr(params: dict) -> Array:
    return jnp.concatenate([params["w"].reshape(-1), params["b"]])


def unflatten_lr(flat: Array, pc: PaperConfig) -> dict:
    wd = pc.input_dim * pc.num_classes
    return {"w": flat[:wd].reshape(pc.input_dim, pc.num_classes),
            "b": flat[wd:wd + pc.num_classes]}


# ---------------------------------------------------------------------------
# Simulator
# ---------------------------------------------------------------------------

class SimState(NamedTuple):
    round: Array            # int32
    flat_w: Array           # [d] global model
    ef: Array               # [K, d] error feedback
    tcs_prev: Array         # [d] w^{t-1} (used by TC algorithms)
    rng: Array


class RoundLog(NamedTuple):
    loss: Array
    bits: Array             # total uplink bits this round (paper §V exact)
    nnz: Array              # Σ_k ‖γ_k‖₀
    err_sq: Array           # Σ_k ‖e_k‖²


@dataclasses.dataclass
class Simulator:
    """Multi-hop FL simulator over a chain (default) or an aggregation tree.

    With ``tree_topology`` set, rounds aggregate over the routed
    constellation tree via :func:`repro.topo.tree.run_tree`; relay deaths
    from a ``failure_schedule`` passed to :meth:`run` re-route the tree
    (re-rooting the severed subtree through surviving ISLs — each distinct
    dead-set is one jit specialization, cached across rounds).
    """

    pc: PaperConfig
    agg: AggConfig
    fed: FederatedData
    local_lr: float = 0.1
    tree_topology: Optional[TreeTopology] = None

    def __post_init__(self):
        self.k = self.fed.num_clients
        self.d = self.pc.d
        # D_k = per-round contribution weight (uniform minibatches → B each;
        # weights normalized at the PS by D = Σ D_k)
        self.weights = jnp.full((self.k,), 1.0, jnp.float32)

    def init(self, seed: int = 0) -> SimState:
        flat = flatten_lr(lr_init(self.pc))
        return SimState(round=jnp.int32(0), flat_w=flat,
                        ef=jnp.zeros((self.k, self.d), jnp.float32),
                        tcs_prev=flat, rng=jax.random.PRNGKey(seed))

    # -- one jitted round ---------------------------------------------------
    def round_fn(self, tree: Optional[AggTree] = None) -> Callable:
        """One-round closure; ``tree`` switches chain → tree aggregation."""
        pc, agg_cfg, k = self.pc, self.agg, self.k
        fed, weights, lr = self.fed, self.weights, self.local_lr
        needs_tcs = agg_cfg.kind in (AggKind.TC_SIA, AggKind.CL_TC_SIA)

        def one_round(state: SimState, participate: Optional[Array] = None,
                      order: Optional[Array] = None):
            rng, kb = jax.random.split(state.rng)
            params = unflatten_lr(state.flat_w, pc)
            bx, by = client_minibatch(fed, kb, pc.batch_size)

            # local SGD step per client → effective gradients
            def client_grad(x, y):
                g = jax.grad(lr_loss)(params, x, y)
                return -lr * flatten_lr(g)          # g_k = w_k − w

            g = jax.vmap(client_grad)(bx, by)        # [K, d]

            global_mask = None
            tcs_prev = state.tcs_prev
            if needs_tcs:
                global_mask = tcs_mod.global_mask(
                    tcs_mod.TCSState(tcs_prev), state.flat_w,
                    agg_cfg.q_global)
                tcs_prev = state.flat_w

            if tree is not None:
                res = run_tree(agg_cfg, tree, g, state.ef, weights,
                               global_mask=global_mask,
                               participate=participate)
            elif order is None:
                res = run_chain(agg_cfg, g, state.ef, weights,
                                global_mask=global_mask,
                                participate=participate)
            else:
                res = run_chain_with_topology(
                    agg_cfg, g, state.ef, weights, order,
                    global_mask=global_mask, participate=participate)

            d_total = jnp.sum(weights) if participate is None else \
                jnp.maximum(jnp.sum(weights * participate), 1e-9)
            flat_new = state.flat_w + res.aggregate / d_total

            new_state = SimState(round=state.round + 1, flat_w=flat_new,
                                 ef=res.e_new, tcs_prev=tcs_prev, rng=rng)
            log = RoundLog(
                loss=lr_loss(unflatten_lr(flat_new, pc),
                             fed.x.reshape(-1, pc.input_dim),
                             fed.y.reshape(-1)),
                bits=jnp.sum(res.stats.bits),
                nnz=jnp.sum(res.stats.nnz_out.astype(jnp.float32)),
                err_sq=jnp.sum(res.stats.err_sq),
            )
            return new_state, log

        return one_round

    # -- host loop ------------------------------------------------------------
    def run(self, rounds: int, *, seed: int = 0, eval_every: int = 10,
            test_x: Optional[Array] = None, test_y: Optional[Array] = None,
            participate_fn: Optional[Callable] = None,
            failure_schedule: Optional[FailureSchedule] = None):
        """→ dict of curves (accuracy, loss, bits/round).

        ``failure_schedule`` (tree mode only): relay deaths re-route the
        aggregation tree around the dead node and zero its participation;
        its banked EF mass transmits after recovery, as on the chain.
        """
        state = self.init(seed)
        topo = self.tree_topology
        if failure_schedule is not None and topo is None:
            raise ValueError("failure_schedule needs tree_topology (chain "
                             "failures go through participate_fn + order)")
        steps: dict = {}

        def step_for(dead: tuple):
            if dead not in steps:
                tree = None if topo is None else topo.tree(dead=dead)
                alive = None if topo is None else topo.alive_mask(tree, dead)
                steps[dead] = (jax.jit(self.round_fn(tree)), alive)
            return steps[dead]

        accs, losses, bits, nnzs = [], [], [], []
        for r in range(rounds):
            dead = (tuple(failure_schedule.dead_at(r))
                    if failure_schedule is not None else ())
            step, alive = step_for(dead)
            part = None
            if participate_fn is not None:
                part = participate_fn(r, state)
            if alive is not None and (part is not None or alive.min() < 1):
                part = jnp.asarray(alive) if part is None \
                    else part * jnp.asarray(alive)
            state, log = step(state, part)
            losses.append(float(log.loss))
            bits.append(float(log.bits))
            nnzs.append(float(log.nnz))
            if test_x is not None and (r % eval_every == 0
                                       or r == rounds - 1):
                acc = lr_accuracy(unflatten_lr(state.flat_w, self.pc),
                                  test_x, test_y)
                accs.append((r, float(acc)))
        return {"state": state, "loss": losses, "bits": bits, "nnz": nnzs,
                "accuracy": accs}
