"""Multi-hop FL simulator — the paper's §VI experiment engine.

K clients train a d=7850 logistic-regression model on (synthetic-)MNIST.
Per round:

  1. every client takes one SGD step on its local minibatch → effective
     gradient g_k = w_k − w  (= −lr·∇_k);
  2. the round's aggregation topology — chain, permuted chain, or routed
     constellation tree, compiled to an :class:`repro.agg.AggPlan` —
     aggregates {D_k·g_k} with the configured Algorithm 1–5 (error feedback
     persists across rounds);
  3. the PS applies w ← w + γ_1 / D and broadcasts.

The round is ONE jitted function for every topology: the plan's arrays are
traced arguments, so switching topologies per round (healed chains via
``order_fn``, relay deaths via ``failure_schedule``, LEO re-routing via
``topology_schedule``) re-traces only when the padded ``(L, W)`` schedule
shape grows — plans padded to a common shape share the executable.

``backend="device"`` runs the same rounds through the device-plan lowering
(:func:`repro.agg.device.execute_sharded`): one local device per client
(``XLA_FLAGS=--xla_force_host_platform_device_count=K`` fakes them on
CPU), levels in lockstep over the mesh, compact wire transport. The
lowered round is bit-exact to host ``execute`` on identical inputs
(tested in tests/test_device_plan.py); whole training *trajectories* agree
to float tolerance only, because XLA fuses the (identical) gradient math
differently when a shard_map consumes it.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.agg import (AggPlan, NestedPlan, TopologySchedule, compile_nested,
                       compile_plan, execute, execute_batched, execute_nested,
                       zero_stage_ef)
from repro.configs.paper_mnist import PaperConfig
from repro.core import tcs as tcs_mod
from repro.core.algorithms import AggConfig, AggKind
from repro.data.federated import FederatedData, client_minibatch
from repro.fed.topology import FailureSchedule, TreeTopology
from repro.obs.collector import RoundBuffer, TraceCounter
from repro.obs.timing import PhaseTimer
from repro.runtime.fault import banked_mass, dead_banked_mass

Array = jax.Array


# ---------------------------------------------------------------------------
# Logistic-regression model (w: [784,10], b: [10] — d = 7850)
# ---------------------------------------------------------------------------

def lr_init(pc: PaperConfig) -> dict:
    return {"w": jnp.zeros((pc.input_dim, pc.num_classes), jnp.float32),
            "b": jnp.zeros((pc.num_classes,), jnp.float32)}


def lr_loss(params: dict, x: Array, y: Array) -> Array:
    logits = x @ params["w"] + params["b"]
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))


def lr_accuracy(params: dict, x: Array, y: Array) -> Array:
    logits = x @ params["w"] + params["b"]
    return jnp.mean((jnp.argmax(logits, -1) == y).astype(jnp.float32))


def flatten_lr(params: dict) -> Array:
    return jnp.concatenate([params["w"].reshape(-1), params["b"]])


def unflatten_lr(flat: Array, pc: PaperConfig) -> dict:
    wd = pc.input_dim * pc.num_classes
    return {"w": flat[:wd].reshape(pc.input_dim, pc.num_classes),
            "b": flat[wd:wd + pc.num_classes]}


# ---------------------------------------------------------------------------
# Simulator
# ---------------------------------------------------------------------------

class SimState(NamedTuple):
    round: Array            # int32
    flat_w: Array           # [d] global model
    ef: Array               # [K, d] error feedback
    tcs_prev: Array         # [d] w^{t-1} (used by TC algorithms)
    rng: Array
    stage_ef: tuple = ()    # upper EF tiers ([K_s, d]) of a nested topology


class RoundLog(NamedTuple):
    """Per-round telemetry — everything the jitted round already computes.

    Leaves stay on device until the history buffer flushes (one
    ``device_get`` per flush — see :meth:`Simulator.run`). ``stats`` holds
    the per-stage :class:`~repro.core.algorithms.HopStats` (stage 0 = the
    client forest, leaves ``[K_s]`` in client index order); the scalar
    curves the simulator returns (loss/bits/nnz) reduce from these on the
    host, and the trace collector consumes them verbatim.
    """

    loss: Array
    stats: tuple            # per-stage HopStats (§V exact per-hop bits)
    participation: Array    # [K] effective mask (participate ∧ alive)
    ef_mass: Array          # [K] ‖e_k‖₁ banked after this round
    stage_ef_mass: tuple    # banked mass per upper EF tier ([K_s] each)
    ef_dead_mass: Array     # Σ over non-participants of ‖e_k‖₁ (‖e_dead‖)


def _fetch_logs(buffer: RoundBuffer) -> list:
    """The run loop's single device→host sync point: materialize every
    buffered round log with one ``device_get``. Module-level so tests can
    monkeypatch it to count syncs."""
    return buffer.flush()


class _PlanCache:
    """Host-side plan store keyed by topology identity, re-padded in place.

    All cached plans share one ``(L, W)`` (the running elementwise max), so
    the jitted round retraces only when a new topology *grows* the schedule
    shape — never when flipping between already-seen topologies.
    """

    def __init__(self, num_clients: int):
        self.k = num_clients
        self._plans: dict = {}
        self._raws: dict = {}
        self._shape: Optional[tuple] = None

    def raw(self, key):
        """The topology object ``build()`` returned (an AggTree in tree
        mode — it carries the link model the trace timeline uses)."""
        return self._raws.get(key)

    def get(self, key, build: Callable[[], Any]) -> AggPlan:
        plan = self._plans.get(key)
        if plan is None:
            raw = build()
            self._raws[key] = raw
            plan = compile_plan(raw, num_clients=self.k)
            shape = (plan.shape if self._shape is None else
                     (max(self._shape[0], plan.shape[0]),
                      max(self._shape[1], plan.shape[1])))
            self._shape = shape
            self._plans[key] = plan
            # a growing shape re-pads everything already cached so the whole
            # cache keeps sharing one specialization
            self._plans = {kk: pp.pad(shape)
                           for kk, pp in self._plans.items()}
        return self._plans[key]


@dataclasses.dataclass
class Simulator:
    """Multi-hop FL simulator over any aggregation topology.

    The default topology is the paper's identity chain. ``tree_topology``
    routes a constellation graph instead; relay deaths from a
    ``failure_schedule`` passed to :meth:`run` re-route the tree (re-rooting
    the severed subtree through surviving ISLs). Every topology goes through
    ``repro.agg.compile_plan`` into one shared jitted round — per-dead-set
    recompiles of the old engine collapse into a host-side plan lookup.
    """

    pc: PaperConfig
    agg: AggConfig
    fed: FederatedData
    local_lr: float = 0.1
    tree_topology: Optional[TreeTopology] = None
    # staged aggregation: a NestedPlan, a routed NestedTopology
    # (repro.topo.routing.cluster_routed), or a compile_nested stage spec —
    # rounds run execute_nested (host) / execute_nested_sharded (device),
    # the upper EF tiers persist in SimState.stage_ef
    nested_topology: Optional[Any] = None
    # "host": repro.agg.execute (single-device reference);
    # "device": repro.agg.device.execute_sharded — the plan lowered onto a
    # one-device-per-client shard_map mesh, bit-exact to "host".
    backend: str = "host"

    def __post_init__(self):
        self.k = self.fed.num_clients
        self.d = self.pc.d
        # D_k = per-round contribution weight (uniform minibatches → B each;
        # weights normalized at the PS by D = Σ D_k)
        self.weights = jnp.full((self.k,), 1.0, jnp.float32)
        if self.backend not in ("host", "device"):
            raise ValueError(f"unknown backend {self.backend!r}")
        self._nested = None
        if self.nested_topology is not None:
            if self.tree_topology is not None:
                raise ValueError("pass either tree_topology or "
                                 "nested_topology, not both")
            self._nested = (self.nested_topology
                            if isinstance(self.nested_topology, NestedPlan)
                            else compile_nested(self.nested_topology,
                                                num_clients=self.k))
            if self._nested.num_clients != self.k:
                raise ValueError(
                    f"nested topology has {self._nested.num_clients} "
                    f"clients, data has {self.k}")
        self._mesh = None
        if self.backend == "device":
            from repro.agg.device import client_mesh
            self._mesh = client_mesh(self.k)
        # counts jit specializations of the round closure: bumped at trace
        # time only, so attaching/detaching a trace collector provably
        # cannot add a retrace (tested in tests/test_obs.py)
        self.trace_counter = TraceCounter()

    def init(self, seed: int = 0) -> SimState:
        flat = flatten_lr(lr_init(self.pc))
        stage_ef = (() if self._nested is None
                    else zero_stage_ef(self._nested, self.d))
        return SimState(round=jnp.int32(0), flat_w=flat,
                        ef=jnp.zeros((self.k, self.d), jnp.float32),
                        tcs_prev=flat, rng=jax.random.PRNGKey(seed),
                        stage_ef=stage_ef)

    # -- one jitted round ---------------------------------------------------
    def round_fn(self) -> Callable:
        """One-round closure ``(state, plan, participate) -> (state, log)``.

        Topology-polymorphic: the plan is a traced argument, so one jit of
        this closure serves chains, healed chains, and routed trees alike.
        """
        pc, agg_cfg, k = self.pc, self.agg, self.k
        fed, weights, lr = self.fed, self.weights, self.local_lr
        needs_tcs = agg_cfg.kind in (AggKind.TC_SIA, AggKind.CL_TC_SIA)
        mesh = self._mesh
        if mesh is None:
            run_round = execute
            run_nested = execute_nested
        else:
            from repro.agg.device import (execute_nested_sharded,
                                          execute_sharded)

            def run_round(cfg, plan, g, e, w, *, global_mask=None,
                          participate=None):
                return execute_sharded(cfg, plan, g, e, w, mesh=mesh,
                                       global_mask=global_mask,
                                       participate=participate)

            def run_nested(cfg, plan, g, e, w, *, stage_e, global_mask=None,
                           participate=None):
                return execute_nested_sharded(
                    cfg, plan, g, e, w, mesh=mesh, stage_e=stage_e,
                    global_mask=global_mask, participate=participate)

        trace_counter = self.trace_counter

        def one_round(state: SimState, plan: AggPlan,
                      participate: Optional[Array] = None):
            trace_counter.bump()        # runs at trace time only
            rng, kb = jax.random.split(state.rng)
            params = unflatten_lr(state.flat_w, pc)
            bx, by = client_minibatch(fed, kb, pc.batch_size)

            # local SGD step per client → effective gradients
            def client_grad(x, y):
                g = jax.grad(lr_loss)(params, x, y)
                return -lr * flatten_lr(g)          # g_k = w_k − w

            g = jax.vmap(client_grad)(bx, by)        # [K, d]

            global_mask = None
            tcs_prev = state.tcs_prev
            if needs_tcs:
                global_mask = tcs_mod.global_mask(
                    tcs_mod.TCSState(tcs_prev), state.flat_w,
                    agg_cfg.q_global)
                tcs_prev = state.flat_w

            nested = isinstance(plan, NestedPlan)
            if nested:
                res = run_nested(agg_cfg, plan, g, state.ef, weights,
                                 stage_e=state.stage_ef,
                                 global_mask=global_mask,
                                 participate=participate)
                stage_ef = res.stage_e_new
                all_stats = (res.stats,) + res.stage_stats
                # whole-chain aliveness: a stub cluster's clients forward
                # nothing to the PS, so they must leave the denominator too
                alive = jnp.asarray(plan.client_alive(), weights.dtype)
            else:
                res = run_round(agg_cfg, plan, g, state.ef, weights,
                                global_mask=global_mask,
                                participate=participate)
                stage_ef = state.stage_ef
                all_stats = (res.stats,)
                alive = jnp.asarray(plan.alive, weights.dtype)

            part = alive if participate is None else participate * alive
            d_total = jnp.maximum(jnp.sum(weights * part), 1e-9)
            flat_new = state.flat_w + res.aggregate / d_total

            new_state = SimState(round=state.round + 1, flat_w=flat_new,
                                 ef=res.e_new, tcs_prev=tcs_prev, rng=rng,
                                 stage_ef=stage_ef)
            # telemetry riders — tiny [K] reductions of state the round
            # already holds, always computed so collection on/off cannot
            # change the jitted program
            ef_mass = banked_mass(res.e_new)
            ef_dead = dead_banked_mass(res.e_new, part)
            log = RoundLog(
                loss=lr_loss(unflatten_lr(flat_new, pc),
                             fed.x.reshape(-1, pc.input_dim),
                             fed.y.reshape(-1)),
                stats=all_stats,
                participation=part,
                ef_mass=ef_mass,
                stage_ef_mass=tuple(banked_mass(e) for e in stage_ef),
                ef_dead_mass=ef_dead,
            )
            return new_state, log

        return one_round

    # -- batched multi-tenant rounds -----------------------------------------

    def init_batched(self, seeds) -> SimState:
        """Stacked :class:`SimState` for B cohorts (leading cohort axis on
        every leaf except the shared round counter)."""
        if self._nested is not None:
            raise ValueError("batched rounds run flat plans; nested "
                             "topologies aggregate per cohort")
        states = [self.init(int(s)) for s in seeds]
        return SimState(
            round=jnp.int32(0),
            flat_w=jnp.stack([s.flat_w for s in states]),
            ef=jnp.stack([s.ef for s in states]),
            tcs_prev=jnp.stack([s.tcs_prev for s in states]),
            rng=jnp.stack([s.rng for s in states]))

    def round_fn_batched(self) -> Callable:
        """Cohort-batched round closure — B tenants through ONE launch.

        ``(state [B-stacked], plan, participate [B, K] | None) -> (state,
        log)``. The aggregation rides :func:`repro.agg.execute_batched`
        (host) / :func:`repro.agg.device.execute_sharded_batched` (device),
        so B cohorts cost one executor launch — one ``pallas_call`` per
        fused level, one collective wavefront per level on devices — while
        per-cohort EF, §V HopStats, and model trajectories stay exactly
        separated (bitwise equal per cohort to the sequential round on the
        same inputs; see tests/test_batched_rounds.py).
        """
        pc, agg_cfg, k = self.pc, self.agg, self.k
        fed, weights, lr = self.fed, self.weights, self.local_lr
        needs_tcs = agg_cfg.kind in (AggKind.TC_SIA, AggKind.CL_TC_SIA)
        mesh = self._mesh
        if mesh is None:
            run_batch = execute_batched
        else:
            from repro.agg.device import execute_sharded_batched

            def run_batch(cfg, plan, g, e, w, *, global_mask=None,
                          participate=None):
                return execute_sharded_batched(cfg, plan, g, e, w,
                                               mesh=mesh,
                                               global_mask=global_mask,
                                               participate=participate)

        trace_counter = self.trace_counter

        def one_round(state: SimState, plan: AggPlan,
                      participate: Optional[Array] = None):
            trace_counter.bump()        # runs at trace time only
            b = state.flat_w.shape[0]
            keys = jax.vmap(jax.random.split)(state.rng)   # [B, 2, 2]
            rng, kb = keys[:, 0], keys[:, 1]

            def cohort_grads(flat_w, key):
                params = unflatten_lr(flat_w, pc)
                bx, by = client_minibatch(fed, key, pc.batch_size)

                def client_grad(x, y):
                    gr = jax.grad(lr_loss)(params, x, y)
                    return -lr * flatten_lr(gr)

                return jax.vmap(client_grad)(bx, by)

            g = jax.vmap(cohort_grads)(state.flat_w, kb)   # [B, K, d]

            global_mask = None
            tcs_prev = state.tcs_prev
            if needs_tcs:
                global_mask = jax.vmap(
                    lambda prev, w: tcs_mod.global_mask(
                        tcs_mod.TCSState(prev), w, agg_cfg.q_global))(
                            tcs_prev, state.flat_w)        # [B, d]
                tcs_prev = state.flat_w

            res = run_batch(agg_cfg, plan, g, state.ef,
                            jnp.broadcast_to(weights, (b, k)),
                            global_mask=global_mask,
                            participate=participate)

            alive = jnp.asarray(plan.alive, weights.dtype)
            alive = jnp.broadcast_to(alive, (b, k))        # [K] | [B, K]
            part = alive if participate is None else participate * alive
            d_total = jnp.maximum(
                jnp.sum(weights * part, axis=1), 1e-9)     # [B]
            flat_new = state.flat_w + res.aggregate / d_total[:, None]

            new_state = SimState(round=state.round + 1, flat_w=flat_new,
                                 ef=res.e_new, tcs_prev=tcs_prev, rng=rng)
            xs = fed.x.reshape(-1, pc.input_dim)
            ys = fed.y.reshape(-1)
            log = RoundLog(
                loss=jax.vmap(lambda w: lr_loss(unflatten_lr(w, pc),
                                                xs, ys))(flat_new),
                stats=(res.stats,),                        # leaves [B, K]
                participation=part,
                ef_mass=banked_mass(res.e_new),            # [B, K]
                stage_ef_mass=(),
                ef_dead_mass=jax.vmap(dead_banked_mass)(res.e_new, part),
            )
            return new_state, log

        return one_round

    def run_batched(self, rounds: int, *, seeds, eval_every: int = 10,
                    test_x: Optional[Array] = None,
                    test_y: Optional[Array] = None,
                    participate_fn: Optional[Callable] = None,
                    failure_schedule: Optional[FailureSchedule] = None,
                    order_fn: Optional[Callable] = None,
                    topology_schedule: Optional[TopologySchedule] = None,
                    collector=None, flush_every: int = 32):
        """Train B independent cohorts through batched rounds → per-cohort
        curves.

        ``seeds`` (length B) initializes one model/data stream per cohort;
        all cohorts share the constellation (the per-round plan sources
        behave exactly as in :meth:`run`) and every round is ONE batched
        launch. The jitted round specializes once per plan *shape* — the
        cohort count rides the same specialization, audited by
        ``trace_counter`` exactly like the sequential loop. ``collector``
        records one round record per cohort per round, tagged with
        ``cohort=i`` (trace schema 1.1), so telemetry stays queryable per
        tenant. Returns ``{"state", "loss" [rounds][B], "bits" [rounds][B],
        "nnz" [rounds][B], "accuracy" [(round, [B])]}``.
        """
        seeds = list(seeds)
        b = len(seeds)
        state = self.init_batched(seeds)
        topo = self.tree_topology
        if failure_schedule is not None and topo is None:
            raise ValueError("failure_schedule needs tree_topology (chain "
                             "failures go through participate_fn + order_fn)")
        if order_fn is not None and (topo is not None
                                     or topology_schedule is not None):
            raise ValueError("order_fn is a chain-mode knob; trees and "
                             "schedules carry their own topology")
        if topology_schedule is not None and topo is not None:
            raise ValueError("pass either tree_topology or "
                             "topology_schedule, not both")
        if (topology_schedule is not None and len(topology_schedule)
                and isinstance(topology_schedule.plan_at(0), NestedPlan)):
            raise ValueError("batched rounds run flat plans; nested "
                             "topologies aggregate per cohort")

        step = jax.jit(self.round_fn_batched())
        cache = _PlanCache(self.k)

        def plan_for(r: int, state: SimState) -> tuple:
            if topology_schedule is not None:
                raw = topology_schedule.raw_at(r)
                return (topology_schedule.plan_at(r),
                        raw if hasattr(raw, "uplink_bw_bps") else None)
            if topo is not None:
                dead = (tuple(failure_schedule.dead_at(r))
                        if failure_schedule is not None else ())
                key = ("tree", dead)
                plan = cache.get(key, lambda: topo.tree(dead=dead))
                return plan, cache.raw(key)
            if order_fn is not None:
                order = np.asarray(order_fn(r, state), np.int32)
                return cache.get(("order", tuple(order.tolist())),
                                 lambda: order), None
            return cache.get(("chain",), lambda: self.k), None

        if collector is not None:
            collector.configure(
                cfg=self.agg, d=self.d, num_clients=self.k,
                backend=self.backend, cohorts=b,
                topology=("schedule" if topology_schedule is not None
                          else "tree" if topo is not None
                          else "order" if order_fn is not None else "chain"))

        timer = PhaseTimer()
        buf = RoundBuffer()
        pending: list = []
        accs, losses, bits, nnzs = [], [], [], []
        run_t0 = time.perf_counter()

        def flush():
            t0 = time.perf_counter()
            logs = _fetch_logs(buf)
            dur = time.perf_counter() - t0
            if collector is not None and logs:
                collector.record_span("flush", t0 - run_t0, dur,
                                      track="simulator",
                                      args={"rounds": len(logs)})
            for (log, acc), (r, plan, tree, retraces, phases) in zip(
                    logs, pending):
                losses.append(np.asarray(log.loss).tolist())
                st0 = log.stats[0]
                bits.append(np.sum(np.asarray(st0.bits), axis=-1).tolist())
                nnzs.append(np.sum(np.asarray(st0.nnz_out),
                                   axis=-1).tolist())
                if acc is not None:
                    accs.append((r, np.asarray(acc).tolist()))
                if collector is not None:
                    for i in range(b):
                        coh = jax.tree.map(lambda x: np.asarray(x)[i],
                                           log.stats[0])
                        collector.record_round(
                            r, coh, plan=plan, tree=tree,
                            loss=np.asarray(log.loss)[i],
                            participate=np.asarray(log.participation)[i],
                            ef_mass=np.asarray(log.ef_mass)[i],
                            ef_dead_mass=np.asarray(log.ef_dead_mass)[i],
                            retraces=retraces, phases=phases, cohort=i)
            del pending[:]

        for r in range(rounds):
            with timer.phase("plan"):
                plan, tree = plan_for(r, state)
                part = None
                if participate_fn is not None:
                    part = jnp.asarray(participate_fn(r, state))
                    if part.ndim == 1:     # one mask for every cohort
                        part = jnp.broadcast_to(part, (b, self.k))
            with timer.phase("dispatch"):
                state, log = step(state, plan, part)
                acc = None
                if test_x is not None and (r % eval_every == 0
                                           or r == rounds - 1):
                    acc = jax.vmap(
                        lambda w: lr_accuracy(unflatten_lr(w, self.pc),
                                              test_x, test_y))(state.flat_w)
            buf.push((log, acc))
            pending.append((r, plan, tree, self.trace_counter.count,
                            timer.take()))
            if len(buf) >= max(1, flush_every):
                flush()
        flush()
        return {"state": state, "loss": losses, "bits": bits, "nnz": nnzs,
                "accuracy": accs}

    # -- host loop ------------------------------------------------------------
    def run(self, rounds: int, *, seed: int = 0, eval_every: int = 10,
            test_x: Optional[Array] = None, test_y: Optional[Array] = None,
            participate_fn: Optional[Callable] = None,
            failure_schedule: Optional[FailureSchedule] = None,
            order_fn: Optional[Callable] = None,
            topology_schedule: Optional[TopologySchedule] = None,
            scenario=None, collector=None, flush_every: int = 32):
        """→ dict of curves (accuracy, loss, bits/round).

        Per-round topology sources (mutually exclusive):

        * ``failure_schedule`` (needs ``tree_topology``): relay deaths
          re-route the aggregation tree around the dead node and zero its
          participation; its banked EF mass transmits after recovery, as on
          the chain;
        * ``order_fn(r, state) -> [K] int`` permutation: healed/rotated
          chain visiting orders, compiled and cached per distinct order;
        * ``topology_schedule``: a pre-padded
          :class:`~repro.agg.TopologySchedule` — graph-per-round or link
          up/down events, one jit specialization for the whole schedule;
        * ``scenario``: a :class:`repro.scenario.Scenario` (compiled here)
          or a pre-compiled :class:`repro.scenario.CompiledScenario` — its
          schedule and realized participation drive every round, the
          simulator seed is pinned to the spec's ``seed``, and the spec
          dict + realized event stream are embedded in the trace (meta
          ``scenario_spec`` + ``track="scenario"`` spans), so the run is
          bit-reproducible from the spec *or* from its own trace.

        ``collector`` (a :class:`repro.obs.TraceCollector`) records every
        round to a JSONL trace; attaching one never changes the jitted
        round. Round logs stay on device and are materialized with one
        ``device_get`` every ``flush_every`` rounds (plus once at the
        end), so the device backend is not forced to sync per round.
        """
        compiled = None
        if scenario is not None:
            if (participate_fn is not None or failure_schedule is not None
                    or order_fn is not None or topology_schedule is not None
                    or self.tree_topology is not None
                    or self._nested is not None):
                raise ValueError("a scenario carries its own topology and "
                                 "participation — pass it alone")
            from repro.scenario import CompiledScenario, compile_scenario
            compiled = (scenario if isinstance(scenario, CompiledScenario)
                        else compile_scenario(scenario, cfg=self.agg))
            if compiled.num_clients != self.k:
                raise ValueError(f"scenario has {compiled.num_clients} "
                                 f"clients, data has {self.k}")
            # replay determinism: the model/data stream is pinned by the
            # spec, not the call site
            seed = compiled.spec.seed
            topology_schedule = compiled.schedule
        state = self.init(seed)
        topo = self.tree_topology
        if failure_schedule is not None and topo is None:
            raise ValueError("failure_schedule needs tree_topology (chain "
                             "failures go through participate_fn + order_fn)")
        if order_fn is not None and (topo is not None
                                     or topology_schedule is not None
                                     or self._nested is not None):
            raise ValueError("order_fn is a chain-mode knob; trees, nested "
                             "plans and schedules carry their own topology")
        if topology_schedule is not None and (topo is not None
                                              or self._nested is not None):
            raise ValueError("pass either tree_topology/nested_topology or "
                             "topology_schedule, not both")

        if (topology_schedule is not None and len(topology_schedule)
                and isinstance(topology_schedule.plan_at(0), NestedPlan)
                and not state.stage_ef):
            # a schedule of nested plans shares one per-stage unit count
            # (validated by TopologySchedule) → one set of EF tiers
            state = state._replace(stage_ef=zero_stage_ef(
                topology_schedule.plan_at(0), self.d))

        step = jax.jit(self.round_fn())
        cache = _PlanCache(self.k)

        def plan_for(r: int, state: SimState) -> tuple:
            """→ (plan, routed AggTree | None — the trace's link model)."""
            if self._nested is not None:
                return self._nested, None
            if topology_schedule is not None:
                raw = topology_schedule.raw_at(r)
                return (topology_schedule.plan_at(r),
                        raw if hasattr(raw, "uplink_bw_bps") else None)
            if topo is not None:
                dead = (tuple(failure_schedule.dead_at(r))
                        if failure_schedule is not None else ())
                key = ("tree", dead)
                plan = cache.get(key, lambda: topo.tree(dead=dead))
                return plan, cache.raw(key)
            if order_fn is not None:
                order = np.asarray(order_fn(r, state), np.int32)
                return cache.get(("order", tuple(order.tolist())),
                                 lambda: order), None
            return cache.get(("chain",), lambda: self.k), None

        if collector is not None:
            extra = {}
            if compiled is not None:
                # the full spec rides in the trace meta: a recorded trace is
                # sufficient to re-run its scenario (scenario_from_trace)
                extra = {"scenario": compiled.spec.name,
                         "scenario_spec": compiled.spec.to_dict()}
            collector.configure(
                cfg=self.agg, d=self.d, num_clients=self.k,
                backend=self.backend,
                topology=("scenario" if compiled is not None
                          else "nested" if self._nested is not None
                          else "schedule" if topology_schedule is not None
                          else "tree" if topo is not None
                          else "order" if order_fn is not None else "chain"),
                **extra)
            if compiled is not None:
                # realized event stream → span records on the scenario
                # track (t0_s/dur_s are in *rounds*, not seconds)
                for ev in compiled.events:
                    collector.record_span(
                        ev["name"], float(ev["round"]), float(ev["rounds"]),
                        track="scenario",
                        args={"kind": ev["kind"], **(ev.get("args") or {})})

        timer = PhaseTimer()
        buf = RoundBuffer()
        pending: list = []      # (round, plan, tree, retraces, phases)
        accs, losses, bits, nnzs = [], [], [], []
        run_t0 = time.perf_counter()

        def flush():
            t0 = time.perf_counter()
            logs = _fetch_logs(buf)
            dur = time.perf_counter() - t0
            if collector is not None and logs:
                collector.record_span("flush", t0 - run_t0, dur,
                                      track="simulator",
                                      args={"rounds": len(logs)})
            for (log, acc), (r, plan, tree, retraces, phases) in zip(
                    logs, pending):
                losses.append(float(log.loss))
                bits.append(float(sum(np.sum(np.asarray(s.bits))
                                      for s in log.stats)))
                nnzs.append(float(sum(np.sum(np.asarray(s.nnz_out))
                                      for s in log.stats)))
                if acc is not None:
                    accs.append((r, float(acc)))
                if collector is not None:
                    collector.record_round(
                        r, log.stats, plan=plan, tree=tree, loss=log.loss,
                        participate=log.participation, ef_mass=log.ef_mass,
                        stage_ef_mass=log.stage_ef_mass,
                        ef_dead_mass=log.ef_dead_mass, retraces=retraces,
                        phases=phases)
            del pending[:]

        for r in range(rounds):
            with timer.phase("plan"):
                plan, tree = plan_for(r, state)
                part = None
                if compiled is not None:
                    part = jnp.asarray(compiled.participate_at(r))
                elif participate_fn is not None:
                    part = participate_fn(r, state)
            # stranded/dead clients are masked inside execute via plan.alive
            with timer.phase("dispatch"):
                state, log = step(state, plan, part)
                acc = None
                if test_x is not None and (r % eval_every == 0
                                           or r == rounds - 1):
                    acc = lr_accuracy(unflatten_lr(state.flat_w, self.pc),
                                      test_x, test_y)
            # logs stay un-fetched on device until the next flush
            buf.push((log, acc))
            pending.append((r, plan, tree, self.trace_counter.count,
                            timer.take()))
            if len(buf) >= max(1, flush_every):
                flush()
        flush()
        return {"state": state, "loss": losses, "bits": bits, "nnz": nnzs,
                "accuracy": accs}
