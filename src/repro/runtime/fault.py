"""Straggler mitigation and failure handling (DESIGN §6).

The mechanism is the paper's own error feedback: a client that misses the
round's deadline gets ``participate=0`` — its node step forwards γ
unchanged and banks the *entire* effective gradient in EF, which is then
transmitted (sparsified) in later rounds. Tests prove no mass is lost.

Failure handling is topological: a dead *relay* is bypassed by re-ordering
the chain (fedsim) / rebuilding the ring permutation without the dead rank
(production: re-mesh + elastic restore from the last checkpoint — EF rows
of surviving clients carry over; the dead client's banked mass is lost and
bounded by ‖e_dead‖, which we expose as a metric:
:func:`dead_banked_mass` is computed every round by the simulator
(``RoundLog.ef_dead_mass``, the ``ef_dead_mass`` field of trace round
records) and by ``train.step`` when its telemetry flag is on).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class StragglerModel:
    """Random straggler process for simulation/testing."""

    p_straggle: float = 0.0          # per-client per-round straggle prob
    correlated: bool = False         # slow client stays slow next round
    p_recover: float = 0.5

    def sample(self, key, k: int, prev: Optional[Array] = None) -> Array:
        """→ participation mask [K] of {0.,1.}."""
        if self.p_straggle <= 0:
            return jnp.ones((k,), jnp.float32)
        fresh = (jax.random.uniform(key, (k,)) >= self.p_straggle)
        if self.correlated and prev is not None:
            k2 = jax.random.fold_in(key, 1)
            recover = jax.random.uniform(k2, (k,)) < self.p_recover
            stay_slow = (prev == 0) & ~recover
            fresh = fresh & ~stay_slow
        return fresh.astype(jnp.float32)


def deadline_mask(arrival_times: Array, deadline: float) -> Array:
    """Deadline-based participation from (simulated) per-client latencies."""
    return (arrival_times <= deadline).astype(jnp.float32)


def heal_chain(order: np.ndarray, dead) -> np.ndarray:
    """Remove dead relay(s) from a chain order (numpy, host-side decision).

    ``dead`` is a single node or any iterable of simultaneously dead nodes
    (the scenario compiler's multi-node crash events); the single-node call
    is bit-compatible with the historic signature. Relative order of the
    survivors is preserved — the chain splices around the gap(s).
    """
    dead_set = {int(dead)} if np.isscalar(dead) else {int(d) for d in dead}
    return np.asarray([o for o in order if int(o) not in dead_set],
                      dtype=np.int32)


def banked_mass(ef: Array) -> Array:
    """Per-client ‖e_k‖₁ — the loss bound if client k dies now."""
    return jnp.sum(jnp.abs(ef), axis=-1)


def dead_banked_mass(ef: Array, participation: Array) -> Array:
    """‖e_dead‖ — total banked EF mass currently held by non-participants.

    ``participation`` is the effective [K] mask (participate ∧ alive). A
    client at 0 still *holds* its bank — the mass is only lost if it never
    returns — so this is the round's exposure bound: what the global model
    permanently forfeits if every currently-dead client stays dead.
    Jit-safe; the simulator logs it every round.
    """
    dead = 1.0 - jnp.clip(participation, 0.0, 1.0)
    return jnp.sum(dead * banked_mass(ef))
