"""Elastic scaling: change the client count K between rounds.

State transformations for grow/shrink — EF rows are per-client, so scaling
is a row-level operation; the flat master/optimizer are K-independent.
A K-change in production means a re-mesh + recompile; these helpers produce
the new state arrays for the checkpoint-restore path.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


def resize_ef(ef: Array, new_k: int, *, redistribute: bool = True) -> Array:
    """[K, D] → [new_K, D].

    Shrink: surviving rows keep their memory; departing rows' banked mass is
    redistributed equally to survivors (``redistribute=True``, keeps the
    total un-transmitted mass conserved) or dropped (False — bounded-loss
    mode, matches a crash).
    Grow: new clients start with zero memory.
    """
    k, d = ef.shape
    if new_k == k:
        return ef
    if new_k > k:
        pad = jnp.zeros((new_k - k, d), ef.dtype)
        return jnp.concatenate([ef, pad], axis=0)
    kept = ef[:new_k]
    if redistribute:
        lost = jnp.sum(ef[new_k:], axis=0, keepdims=True)
        kept = kept + lost / new_k
    return kept


def rebalance_weights(num_clients: int, sample_counts=None) -> Array:
    """D_k weights after membership change (uniform unless counts given)."""
    if sample_counts is None:
        return jnp.full((num_clients,), 1.0 / num_clients, jnp.float32)
    c = jnp.asarray(sample_counts, jnp.float32)
    return c / jnp.sum(c)
