"""Declarative fault-injection scenarios with deterministic replay.

Real constellation FL (the on-board satellite setting of arXiv 2307.08346
and the sparse-IA follow-up arXiv 2501.11385) is defined by orbital link
churn, relay deaths, and straggler bursts. This package makes those
failure modes *config files* instead of hand-written test functions:

* :mod:`repro.scenario.spec` — the declarative scenario schema
  (:class:`Scenario`: link-flap schedules, crash/recovery events,
  straggler windows wrapping :class:`repro.runtime.fault.StragglerModel`,
  bandwidth-degradation ramps, deadline windows) with a dict/JSON
  round-trip, so a scenario travels as a file and rides inside every
  emitted trace;
* :mod:`repro.scenario.compile` — :func:`compile_scenario` lowers a spec +
  base :class:`~repro.topo.graph.ConstellationGraph` onto the objects the
  system already consumes: a padded
  :class:`~repro.agg.schedule.TopologySchedule` (one jit specialization
  for the whole scenario), per-round participation masks, and per-round
  ``q_budget`` arrays — nothing inside jit changes;
* :mod:`repro.scenario.presets` — the small preset library
  (relay-cascade, orbital-eclipse link flaps, heterogeneous-uplink
  degradation, straggler-storm);
* :mod:`repro.scenario.run` — ``python -m repro.scenario.run spec.json``
  executes a scenario through the :class:`~repro.fed.simulator.Simulator`
  (host or device backend) and writes a validated ``repro.obs`` trace.

Replay is deterministic by construction: every stochastic ingredient
(straggler draws, latency samples) is seeded in the spec and realized at
compile time, so the same spec — whether loaded from JSON or recovered
from a previously emitted trace via :func:`spec.scenario_from_trace` —
re-runs bit-exactly on ``backend="host"`` and ``backend="device"``.
"""

from repro.scenario.compile import CompiledScenario, compile_scenario
from repro.scenario.presets import PRESETS, preset
from repro.scenario.spec import (BandwidthRamp, Crash, DeadlineWindow,
                                 LinkFlap, Scenario, StragglerWindow,
                                 TopologySpec, scenario_from_trace)

__all__ = [
    "Scenario", "TopologySpec", "LinkFlap", "Crash", "StragglerWindow",
    "BandwidthRamp", "DeadlineWindow", "scenario_from_trace",
    "CompiledScenario", "compile_scenario", "PRESETS", "preset",
]
