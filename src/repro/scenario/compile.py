"""Lower a :class:`~repro.scenario.spec.Scenario` onto the round engine.

:func:`compile_scenario` realizes every fault timeline host-side and
produces a :class:`CompiledScenario` made only of objects the system
already consumes:

* a padded :class:`~repro.agg.schedule.TopologySchedule` — each distinct
  (down-links, dead-nodes, bandwidth-factors) configuration is routed and
  compiled **once**, then shared by every round it covers, and all plans
  are padded to one ``(L, W)`` so the whole scenario runs inside a single
  jit specialization (the trace counter proves it);
* a ``[rounds, K]`` participation matrix — crash windows, straggler draws
  (:class:`~repro.runtime.fault.StragglerModel` under
  ``fold_in(PRNGKey(seed), round)``), and deadline misses
  (:class:`~repro.fed.topology.LatencyModel` +
  :func:`~repro.runtime.fault.deadline_mask`), all materialized at compile
  time so the run itself draws no randomness;
* per-round ``q_budget`` arrays (``bandwidth_aware``:
  :func:`repro.agg.bandwidth_budgets` against the round's — possibly
  degraded — routed tree, attached to every plan so the schedule keeps one
  pytree structure);
* the realized event stream (window dicts) the simulator writes into the
  trace as ``track="scenario"`` span records.

Because everything stochastic is realized here from spec-carried seeds,
compiling the same spec twice yields bit-identical participation and
schedules — the foundation of deterministic replay.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.scenario.spec import Scenario


@dataclasses.dataclass(frozen=True)
class CompiledScenario:
    """A scenario lowered onto schedule + participation + events."""

    spec: Scenario
    schedule: object              # TopologySchedule (flat or nested plans)
    participation: np.ndarray     # [rounds, K] float32 in {0., 1.}
    events: tuple                 # realized window dicts, round order

    @property
    def rounds(self) -> int:
        return int(self.participation.shape[0])

    @property
    def num_clients(self) -> int:
        return int(self.participation.shape[1])

    def participate_at(self, r: int) -> np.ndarray:
        return self.participation[min(r, self.rounds - 1)]


def _window_events(kind: str, name: str, flags, args: dict) -> list:
    """Contiguous True runs of per-round ``flags`` → event window dicts."""
    out, start = [], None
    for r, f in enumerate(flags):
        if f and start is None:
            start = r
        elif not f and start is not None:
            out.append({"kind": kind, "name": name, "round": start,
                        "rounds": r - start, "args": args})
            start = None
    if start is not None:
        out.append({"kind": kind, "name": name, "round": start,
                    "rounds": len(flags) - start, "args": args})
    return out


def _realize_events(spec: Scenario) -> tuple:
    R = spec.rounds
    events: list = []
    for fl in spec.link_flaps:
        u, v = fl.link
        events += _window_events(
            "link_flap", f"flap {u}-{v}", [fl.is_down(r) for r in range(R)],
            {"link": [u, v], "period": fl.period})
    for cr in spec.crashes:
        events += _window_events(
            "crash", f"crash client {cr.node}",
            [cr.is_dead(r) for r in range(R)],
            {"node": cr.node, "recover": cr.recover})
    for i, sw in enumerate(spec.stragglers):
        events += _window_events(
            "stragglers", f"straggler window {i}",
            [sw.active(r) for r in range(R)],
            {"p_straggle": sw.p_straggle, "correlated": sw.correlated})
    for i, rp in enumerate(spec.ramps):
        events += _window_events(
            "bandwidth_ramp", f"bandwidth ramp {i}",
            [rp.factor(r) < 1.0 for r in range(R)],
            {"floor": rp.floor,
             "links": (None if rp.links is None
                       else [list(uv) for uv in rp.links])})
    for i, dl in enumerate(spec.deadlines):
        events += _window_events(
            "deadline", f"deadline {dl.deadline_s}s",
            [dl.active(r) for r in range(R)],
            {"deadline_s": dl.deadline_s, "mean_s": dl.mean_s})
    return tuple(sorted(events, key=lambda e: (e["round"], e["name"])))


def _participation(spec: Scenario) -> np.ndarray:
    """Realize all participation timelines into a [rounds, K] matrix."""
    import jax

    R, K = spec.rounds, spec.num_clients
    part = np.ones((R, K), np.float32)
    for cr in spec.crashes:
        for r in range(R):
            if cr.is_dead(r):
                part[r, cr.node] = 0.0
    for sw in spec.stragglers:
        model = sw.model()
        base = jax.random.PRNGKey(sw.seed)
        prev = None
        for r in range(R):
            if not sw.active(r):
                prev = None      # correlation does not leap over a gap
                continue
            mask = np.asarray(
                model.sample(jax.random.fold_in(base, r), K, prev),
                np.float32)
            prev = mask
            part[r] *= mask
    if spec.deadlines:
        from repro.fed.topology import LatencyModel
        from repro.runtime.fault import deadline_mask
        for dl in spec.deadlines:
            lm = LatencyModel(mean_s=dl.mean_s, sigma=dl.sigma, seed=dl.seed)
            for r in range(R):
                if dl.active(r):
                    times = lm.sample(r, K)
                    part[r] *= np.asarray(
                        deadline_mask(times, dl.deadline_s), np.float32)
    return part


def compile_scenario(spec: Scenario, graph=None, *,
                     cfg=None) -> CompiledScenario:
    """Lower ``spec`` (+ optional pre-built base graph) — see module doc.

    ``graph`` overrides ``spec.topology.build()`` (it must have the spec's
    client count); ``cfg`` overrides ``spec.agg_config()`` for the
    bandwidth-aware budget base.
    """
    from repro.agg.plan import bandwidth_budgets
    from repro.agg.schedule import TopologySchedule

    R, K = spec.rounds, spec.num_clients
    if cfg is None:
        cfg = spec.agg_config()
    chain = spec.topology.kind in ("chain", "path")
    clustered = spec.topology.clusters is not None

    if spec.bandwidth_aware and (chain or clustered):
        raise ValueError("bandwidth_aware budgets need a flat routed graph "
                         "(chain has no link model; clustered budgets are "
                         "not supported)")
    if clustered and spec.topology.routing == "widest":
        raise ValueError("cluster routing supports latency/hops metrics, "
                         "not widest")

    # per-round fault configuration keys (dead sets / down links / factors)
    dead_at = [frozenset(cr.node for cr in spec.crashes if cr.is_dead(r))
               for r in range(R)]

    if chain:
        # the paper's chain: crashes splice (PR-era heal_chain semantics),
        # no link model — one healed tree per distinct dead set
        from repro.topo.routing import healed_chain_tree
        keys = dead_at
        index_of: dict = {}
        topos, round_index = [], []
        for key in keys:
            if key not in index_of:
                index_of[key] = len(topos)
                topos.append(healed_chain_tree(K, sorted(key)))
            round_index.append(index_of[key])
        schedule = TopologySchedule.from_topologies(
            topos, num_clients=K, round_index=round_index, cyclic=False)
        return CompiledScenario(spec=spec, schedule=schedule,
                                participation=_participation(spec),
                                events=_realize_events(spec))

    if graph is None:
        graph = spec.topology.build()
    if graph.num_clients != K:
        raise ValueError(f"base graph has {graph.num_clients} clients, "
                         f"spec expects {K}")
    client_node = {i: int(v) for i, v in enumerate(graph.client_nodes())}

    fixed_clusters = None
    if clustered:
        # the partition is computed ONCE on the base graph and held fixed:
        # per-round exclusions re-route within it, so every round's nested
        # plan keeps the same per-stage unit counts (one padded signature)
        from repro.topo.routing import partition_clusters
        fixed_clusters = partition_clusters(graph, spec.topology.clusters)

    down_at = [frozenset(fl.link for fl in spec.link_flaps
                         if fl.is_down(r)) for r in range(R)]
    factors_at = [tuple(rp.factor(r) for rp in spec.ramps)
                  for r in range(R)]

    def build_config(down, dead, factors):
        g = graph
        for rp, f in zip(spec.ramps, factors):
            if f < 1.0:
                g = g.with_bandwidth_scaled(f, rp.links)
        if down:
            g = g.without_links(down)
        exclude = tuple(sorted(client_node[i] for i in dead))
        if clustered:
            from repro.topo.routing import cluster_routed
            topo = cluster_routed(g, clusters=fixed_clusters,
                                  metric=spec.topology.routing,
                                  exclude=exclude)
            return topo, None
        from repro.topo.routing import route_tree
        tree = route_tree(g, spec.topology.routing, exclude=exclude)
        qb = bandwidth_budgets(cfg, tree) if spec.bandwidth_aware else None
        return tree, qb

    index_of = {}
    topos, budgets, round_index = [], [], []
    for r in range(R):
        key = (down_at[r], dead_at[r], factors_at[r])
        if key not in index_of:
            index_of[key] = len(topos)
            topo, qb = build_config(*key)
            topos.append(topo)
            budgets.append(qb)
        round_index.append(index_of[key])
    schedule = TopologySchedule.from_topologies(
        topos, num_clients=K, q_budgets=budgets, round_index=round_index,
        cyclic=False)
    return CompiledScenario(spec=spec, schedule=schedule,
                            participation=_participation(spec),
                            events=_realize_events(spec))
