"""Built-in scenario presets (all sized K = 8 for the 8-fake-device CI).

* ``relay-cascade`` — the paper's chain under cascading relay deaths: three
  staggered crashes (one recovers), each splicing the chain around the gap
  while the dead client's banked EF mass waits for recovery.
* ``orbital-eclipse`` — a 2×4 Walker-delta shell whose inter-plane ISLs
  drop on staggered ephemeris windows (periodic link flaps), forcing
  per-window re-routes that all share one padded plan shape.
* ``uplink-degradation`` — a 2×4 ISL grid with heterogeneous-uplink rain
  fade: bandwidth ramps on the ground link and a mid-grid ISL under
  widest-path routing with bandwidth-aware Top-Q budgets, so narrow links
  shed §V bits as they degrade.
* ``straggler-storm`` — the chain under a correlated straggler burst plus
  a deadline window over log-normal latencies; participation collapses and
  recovers, EF conservation carries the banked mass through.

Each entry is a zero-argument factory so ``preset(name)`` always returns a
fresh, unshared :class:`~repro.scenario.spec.Scenario`.
"""

from __future__ import annotations

from repro.scenario.spec import (BandwidthRamp, Crash, DeadlineWindow,
                                 LinkFlap, Scenario, StragglerWindow,
                                 TopologySpec)


def relay_cascade() -> Scenario:
    return Scenario(
        name="relay-cascade", rounds=24, seed=0,
        topology=TopologySpec(kind="chain", clients=8),
        crashes=(Crash(node=5, round=4),
                 Crash(node=2, round=8, recover=16),
                 Crash(node=6, round=12)))


def orbital_eclipse() -> Scenario:
    # walker_delta(2, 4): sat j of plane p is node 1 + p*4 + j; the
    # inter-plane ISLs (1+j, 5+j) occlude on staggered 12-round periods
    return Scenario(
        name="orbital-eclipse", rounds=24, seed=0,
        topology=TopologySpec(kind="walker_delta", clients=8,
                              params={"num_planes": 2, "sats_per_plane": 4,
                                      "gateways": [1, 5]}),
        link_flaps=(LinkFlap(link=(1, 5), start=2, down=3, period=12),
                    LinkFlap(link=(2, 6), start=5, down=3, period=12),
                    LinkFlap(link=(3, 7), start=8, down=3, period=12),
                    LinkFlap(link=(0, 5), start=10, down=4)))


def uplink_degradation() -> Scenario:
    # grid_graph(2, 4): PS uplinks to node 1; ramps hit the ground link and
    # a mid-grid ISL, budgets follow via bandwidth_aware widest-path routing
    return Scenario(
        name="uplink-degradation", rounds=20, seed=0,
        topology=TopologySpec(kind="grid", clients=8,
                              params={"rows": 2, "cols": 4},
                              routing="widest"),
        bandwidth_aware=True,
        ramps=(BandwidthRamp(start=4, end=12, floor=0.2, recover=16,
                             links=((0, 1),)),
               BandwidthRamp(start=6, end=10, floor=0.5,
                             links=((2, 3), (6, 7)))))


def straggler_storm() -> Scenario:
    return Scenario(
        name="straggler-storm", rounds=24, seed=0,
        topology=TopologySpec(kind="chain", clients=8),
        stragglers=(StragglerWindow(p_straggle=0.4, start=6, end=18,
                                    correlated=True, p_recover=0.5, seed=3),),
        deadlines=(DeadlineWindow(deadline_s=1.6, start=10, end=14,
                                  mean_s=1.0, sigma=0.5, seed=7),))


PRESETS = {
    "relay-cascade": relay_cascade,
    "orbital-eclipse": orbital_eclipse,
    "uplink-degradation": uplink_degradation,
    "straggler-storm": straggler_storm,
}


def preset(name: str) -> Scenario:
    """A fresh copy of a built-in scenario by name."""
    try:
        return PRESETS[name]()
    except KeyError:
        raise ValueError(f"unknown preset {name!r} "
                         f"(have: {', '.join(sorted(PRESETS))})") from None
