"""Scenario driver — ``python -m repro.scenario.run SPEC``.

``SPEC`` is a preset name (:mod:`repro.scenario.presets`), a path to a
scenario JSON file (:meth:`~repro.scenario.spec.Scenario.to_json`), or a
path to a previously emitted ``repro.obs`` trace — in which case the spec
embedded in the trace meta is replayed bit-exactly. The scenario is
compiled once, run through the :class:`~repro.fed.simulator.Simulator`
(``--backend host|device``), and written as a schema-validated JSONL trace
whose meta carries the spec and whose ``track="scenario"`` spans carry the
realized event stream. Exits nonzero if the trace fails validation or the
run took more than one jit specialization.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys


def load_spec(ref: str):
    """Resolve a preset name / spec JSON path / trace path to a Scenario."""
    from repro.scenario.presets import PRESETS, preset
    from repro.scenario.spec import Scenario, scenario_from_trace
    if ref in PRESETS:
        return preset(ref)
    if not os.path.exists(ref):
        raise FileNotFoundError(f"{ref}: not a preset "
                                f"({', '.join(sorted(PRESETS))}) and not a "
                                f"file")
    with open(ref) as f:
        head = f.readline()
    try:                    # a JSONL trace has a one-line meta record first
        obj = json.loads(head)
    except json.JSONDecodeError:
        obj = None          # multi-line spec JSON
    if isinstance(obj, dict) and obj.get("kind") == "meta":
        return scenario_from_trace(ref)[0]
    return Scenario.from_json(ref)


def run_scenario(spec, *, backend: str = "host", out: str = "trace.jsonl",
                 flush_every: int = 8) -> dict:
    """Compile + run one scenario; → the simulator's curves dict plus the
    compiled scenario and trace counter under ``_scenario``/``_retraces``."""
    import jax

    from repro.configs import PAPER
    from repro.data.federated import partition_iid
    from repro.data.synthetic import make_synthetic_mnist
    from repro.fed.simulator import Simulator
    from repro.obs import TraceCollector
    from repro.scenario.compile import compile_scenario

    k = spec.num_clients
    pc = dataclasses.replace(PAPER, num_clients=k)
    train = make_synthetic_mnist(jax.random.PRNGKey(0), k * 40)
    fed = partition_iid(jax.random.PRNGKey(2), train, k)
    sim = Simulator(pc, spec.agg_config(), fed, local_lr=pc.lr,
                    backend=backend)
    compiled = compile_scenario(spec, cfg=sim.agg)
    with TraceCollector(out) as col:
        curves = sim.run(spec.rounds, scenario=compiled, collector=col,
                         flush_every=flush_every)
    curves["_scenario"] = compiled
    curves["_retraces"] = sim.trace_counter.count
    return curves


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.scenario.run",
                                 description=__doc__.split("\n")[0])
    ap.add_argument("spec", help="preset name, scenario .json, or a "
                                 "recorded trace to replay")
    ap.add_argument("--out", default="scenario_trace.jsonl",
                    help="output trace path")
    ap.add_argument("--backend", default="host",
                    choices=("host", "device"))
    ap.add_argument("--flush-every", type=int, default=8)
    args = ap.parse_args(argv)

    spec = load_spec(args.spec)

    import jax
    if args.backend == "device" and jax.device_count() < spec.num_clients:
        print(f"--backend device needs {spec.num_clients} devices, have "
              f"{jax.device_count()} (set XLA_FLAGS="
              f"--xla_force_host_platform_device_count="
              f"{spec.num_clients})")
        return 2

    curves = run_scenario(spec, backend=args.backend, out=args.out)

    from repro.obs import validate_trace
    from repro.obs.report import print_summary, summarize
    res = validate_trace(args.out)
    errs = list(res.pop("errors"))
    if curves["_retraces"] != 1:
        errs.append(f"{curves['_retraces']} jit specializations (want 1)")
    status = "OK" if not errs else "FAIL"
    events = curves["_scenario"].events
    print(f"[{status}] {spec.name}: {spec.rounds} rounds, "
          f"{len(events)} injected events, final loss "
          f"{curves['loss'][-1]:.6f} → {args.out} ({res})")
    for e in errs[:10]:
        print(f"    {e}")
    print_summary(summarize(args.out))
    return 0 if not errs else 1


if __name__ == "__main__":
    sys.exit(main())
