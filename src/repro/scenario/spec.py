"""The declarative scenario schema (dataclasses + dict/JSON round-trip).

A :class:`Scenario` is a complete, self-contained description of a
fault-injection experiment: the base constellation (a
:class:`TopologySpec` naming a ``repro.topo.graph`` builder), the
aggregation algorithm, and a set of fault timelines —

* :class:`LinkFlap` — a link outage window, one-shot or periodic
  (ephemeris-like: the link is down for ``down`` consecutive rounds out
  of every ``period``, an orbital-occlusion schedule);
* :class:`Crash` — a client/relay death at a round, with optional
  recovery (the scenario compiler routes around the dead node, so its
  subtree re-roots through surviving ISLs);
* :class:`StragglerWindow` — a window during which participation is
  drawn from :class:`repro.runtime.fault.StragglerModel` under a
  dedicated seed stream (``fold_in(PRNGKey(seed), round)``), optionally
  correlated round-to-round;
* :class:`BandwidthRamp` — a linear bandwidth-degradation ramp on a set
  of links (re-routing and, with ``bandwidth_aware``, per-client Top-Q
  budgets follow the shrinking links);
* :class:`DeadlineWindow` — a per-round deadline over
  :class:`repro.fed.topology.LatencyModel` draws
  (:func:`repro.runtime.fault.deadline_mask` participation).

Everything stochastic carries its own seed and every timeline is a pure
function of the round index, so a spec realizes the same event stream on
every compile — the determinism replay rests on. ``to_dict``/``from_dict``
round-trip through JSON-safe types (tuples normalize in both directions);
:func:`scenario_from_trace` recovers the spec a simulator run embedded in
its trace meta record, closing the record→replay loop.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Optional, Sequence

#: Versioned spec schema tag (bump the suffix on incompatible changes).
SPEC_SCHEMA = "repro.scenario/1"


def _link(uv) -> tuple:
    u, v = int(uv[0]), int(uv[1])
    return (min(u, v), max(u, v))


def _in_window(r: int, start: int, end: Optional[int]) -> bool:
    return r >= start and (end is None or r < end)


@dataclasses.dataclass(frozen=True)
class TopologySpec:
    """Base constellation: a named ``repro.topo.graph`` builder + routing.

    ``kind``: ``chain`` (the paper's linear chain — crashes heal by
    splicing, :func:`repro.topo.routing.healed_chain_tree`), ``star``,
    ``grid``, ``walker_delta``, ``walker_star``, or ``geometric``;
    ``params`` are the builder's keyword arguments. ``routing`` picks the
    spanning-tree policy (``latency``/``hops``/``widest``); ``clusters``
    switches to staged aggregation via
    :func:`repro.topo.routing.cluster_routed` (the partition is computed
    once on the base graph and held fixed, so every round's
    :class:`~repro.agg.nested.NestedPlan` shares one per-stage shape).
    """

    kind: str = "chain"
    clients: int = 8
    params: dict = dataclasses.field(default_factory=dict)
    routing: str = "latency"
    clusters: Optional[int] = None

    def __post_init__(self):
        if self.routing not in ("latency", "hops", "widest"):
            raise ValueError(f"unknown routing {self.routing!r}")

    def build(self):
        """→ the base :class:`~repro.topo.graph.ConstellationGraph`."""
        from repro.topo import graph as tg
        p = dict(self.params)
        if self.kind in ("chain", "path"):
            return tg.path_graph(self.clients, **p)
        if self.kind == "star":
            return tg.star_graph(self.clients, **p)
        if self.kind == "grid":
            rows = int(p.pop("rows", 2))
            cols = int(p.pop("cols", max(1, self.clients // 2)))
            return tg.grid_graph(rows, cols, **p)
        if self.kind in ("walker_delta", "walker_star"):
            planes = int(p.pop("num_planes", 2))
            sats = int(p.pop("sats_per_plane", max(2, self.clients // 2)))
            if "gateways" in p:
                p["gateways"] = tuple(int(g) for g in p["gateways"])
            builder = (tg.walker_delta if self.kind == "walker_delta"
                       else tg.walker_star)
            return builder(planes, sats, **p)
        if self.kind == "geometric":
            return tg.random_geometric(self.clients, **p)
        raise ValueError(f"unknown topology kind {self.kind!r}")

    @property
    def num_clients(self) -> int:
        if self.kind == "grid":
            return (int(self.params.get("rows", 2))
                    * int(self.params.get("cols",
                                          max(1, self.clients // 2))))
        if self.kind in ("walker_delta", "walker_star"):
            return (int(self.params.get("num_planes", 2))
                    * int(self.params.get("sats_per_plane",
                                          max(2, self.clients // 2))))
        return self.clients


@dataclasses.dataclass(frozen=True)
class LinkFlap:
    """Link outage: one-shot (``period=None``) or a periodic window.

    ``link`` is a graph-node pair (canonicalized u < v). Periodic flaps
    model ephemeris windows: starting at ``start``, the link is down for
    the first ``down`` rounds of every ``period``-round cycle.
    """

    link: tuple
    start: int = 0
    down: int = 1
    period: Optional[int] = None

    def __post_init__(self):
        object.__setattr__(self, "link", _link(self.link))
        if self.down < 1:
            raise ValueError("down must be >= 1 round")
        if self.period is not None and self.period < self.down:
            raise ValueError("period must cover the down window")

    def is_down(self, r: int) -> bool:
        if r < self.start:
            return False
        if self.period is None:
            return r < self.start + self.down
        return (r - self.start) % self.period < self.down


@dataclasses.dataclass(frozen=True)
class Crash:
    """Client/relay death at round ``round``; ``recover=None`` = stays
    dead. ``node`` is a *client index* (the simulator's [K, d] row)."""

    node: int
    round: int
    recover: Optional[int] = None

    def __post_init__(self):
        if self.recover is not None and self.recover <= self.round:
            raise ValueError("recover must come after the crash")

    def is_dead(self, r: int) -> bool:
        return _in_window(r, self.round, self.recover)


@dataclasses.dataclass(frozen=True)
class StragglerWindow:
    """Straggler burst: :class:`~repro.runtime.fault.StragglerModel`
    draws inside ``[start, end)`` under a dedicated seed stream."""

    p_straggle: float
    start: int = 0
    end: Optional[int] = None
    correlated: bool = False
    p_recover: float = 0.5
    seed: int = 0

    def active(self, r: int) -> bool:
        return _in_window(r, self.start, self.end)

    def model(self):
        from repro.runtime.fault import StragglerModel
        return StragglerModel(p_straggle=self.p_straggle,
                              correlated=self.correlated,
                              p_recover=self.p_recover)


@dataclasses.dataclass(frozen=True)
class BandwidthRamp:
    """Linear bandwidth degradation on ``links`` (None = every link).

    The multiplier ramps 1 → ``floor`` over ``[start, end)``, holds at
    ``floor``, and snaps back at ``recover`` (None = degraded forever).
    Factors are quantized to 1e-3 so a long ramp compiles a bounded
    number of distinct topologies.
    """

    start: int
    end: int
    floor: float = 0.1
    links: Optional[tuple] = None
    recover: Optional[int] = None

    def __post_init__(self):
        if self.end <= self.start:
            raise ValueError("ramp window must be non-empty")
        if not 0.0 < self.floor <= 1.0:
            raise ValueError("floor must be in (0, 1]")
        if self.links is not None:
            object.__setattr__(self, "links",
                               tuple(_link(uv) for uv in self.links))

    def factor(self, r: int) -> float:
        if r < self.start or (self.recover is not None
                              and r >= self.recover):
            return 1.0
        if r >= self.end:
            return self.floor
        frac = (r - self.start) / (self.end - self.start)
        return round(1.0 + frac * (self.floor - 1.0), 3)


@dataclasses.dataclass(frozen=True)
class DeadlineWindow:
    """Deadline-based participation over log-normal latency draws
    (:class:`repro.fed.topology.LatencyModel`) inside ``[start, end)``."""

    deadline_s: float
    start: int = 0
    end: Optional[int] = None
    mean_s: float = 1.0
    sigma: float = 0.5
    seed: int = 0

    def active(self, r: int) -> bool:
        return _in_window(r, self.start, self.end)


_FAULT_TYPES = {"link_flaps": LinkFlap, "crashes": Crash,
                "stragglers": StragglerWindow, "ramps": BandwidthRamp,
                "deadlines": DeadlineWindow}


@dataclasses.dataclass(frozen=True)
class Scenario:
    """One declarative fault-injection scenario (see module doc).

    ``agg`` holds :class:`~repro.core.algorithms.AggConfig` keyword
    arguments (``kind`` as the string enum value); ``bandwidth_aware``
    attaches per-round :func:`repro.agg.bandwidth_budgets` Top-Q budgets
    that follow the (possibly degraded) link bandwidths. ``seed`` drives
    the simulator's model/data stream — fault streams carry their own
    seeds — so one integer pins the whole run.
    """

    name: str
    rounds: int
    topology: TopologySpec
    seed: int = 0
    agg: Optional[dict] = None
    bandwidth_aware: bool = False
    link_flaps: tuple = ()
    crashes: tuple = ()
    stragglers: tuple = ()
    ramps: tuple = ()
    deadlines: tuple = ()

    def __post_init__(self):
        if self.rounds < 1:
            raise ValueError("scenario needs >= 1 round")
        for field, typ in _FAULT_TYPES.items():
            vals = tuple(v if isinstance(v, typ) else typ(**v)
                         for v in getattr(self, field))
            object.__setattr__(self, field, vals)
        if self.topology.kind in ("chain", "path") and (
                self.link_flaps or self.ramps):
            raise ValueError(
                "chain scenarios heal by splicing and have no link model — "
                "use a graph topology (grid/walker/...) for link-level "
                "faults")

    @property
    def num_clients(self) -> int:
        return self.topology.num_clients

    def agg_config(self):
        """→ the :class:`~repro.core.algorithms.AggConfig` to run under."""
        from repro.core.algorithms import AggConfig, AggKind
        kw = dict(self.agg or {})
        if "kind" in kw:
            kw["kind"] = AggKind(kw["kind"])
        return AggConfig(**kw)

    # -- dict / JSON round-trip ---------------------------------------------

    def to_dict(self) -> dict:
        out = {"schema": SPEC_SCHEMA, "name": self.name,
               "rounds": self.rounds, "seed": self.seed,
               "topology": dataclasses.asdict(self.topology),
               "bandwidth_aware": self.bandwidth_aware}
        if self.agg is not None:
            out["agg"] = dict(self.agg)
        faults = {field: [dataclasses.asdict(v)
                          for v in getattr(self, field)]
                  for field in _FAULT_TYPES if getattr(self, field)}
        if faults:
            out["faults"] = faults
        return out

    @classmethod
    def from_dict(cls, obj: dict) -> "Scenario":
        schema = obj.get("schema", SPEC_SCHEMA)
        if schema.split("/")[0] != SPEC_SCHEMA.split("/")[0]:
            raise ValueError(f"unknown scenario schema {schema!r}")
        topo = dict(obj["topology"])
        faults = obj.get("faults", {})
        kw = {field: tuple(typ(**v) for v in faults.get(field, ()))
              for field, typ in _FAULT_TYPES.items()}
        return cls(name=obj["name"], rounds=int(obj["rounds"]),
                   seed=int(obj.get("seed", 0)),
                   topology=TopologySpec(**topo),
                   agg=obj.get("agg"),
                   bandwidth_aware=bool(obj.get("bandwidth_aware", False)),
                   **kw)

    def to_json(self, path: Optional[str] = None) -> str:
        text = json.dumps(self.to_dict(), indent=1, sort_keys=True) + "\n"
        if path is not None:
            with open(path, "w") as f:
                f.write(text)
        return text

    @classmethod
    def from_json(cls, path: str) -> "Scenario":
        with open(path) as f:
            return cls.from_dict(json.load(f))


def scenario_from_trace(path: str) -> tuple:
    """Recover ``(Scenario, meta record)`` from an emitted trace.

    A simulator run with ``scenario=`` embeds the full spec dict in the
    trace's meta record (``scenario_spec``), so a trace is sufficient to
    re-run its scenario bit-exactly — no separate spec file needed.
    """
    from repro.obs.record import iter_trace
    for rec in iter_trace(path):
        if rec.get("kind") == "meta":
            spec = rec.get("scenario_spec")
            if spec is None:
                raise ValueError(f"{path}: trace was not recorded under a "
                                 f"scenario (no scenario_spec in meta)")
            return Scenario.from_dict(spec), rec
    raise ValueError(f"{path}: no meta record")
