"""Device-plan execution: lower any :class:`AggPlan` onto the shard_map ring.

The plan/execute API (:mod:`repro.agg.plan`) made every topology — chain,
permuted order, routed constellation tree, graph, or one step of a
:class:`~repro.agg.schedule.TopologySchedule` — compile to one canonical
padded ``(L, W)`` level schedule. This module is the missing half: the same
schedule drives a **multi-device** shard_map program, so non-chain
topologies are no longer simulator-only (the ROADMAP's "tree-aware
distributed ring"). Two lowerings share the level walk, the compact
``(values, indices)`` wire transport, and the §V bit accounting of the
rotated ring:

``run_plan_segments_local``
    The *rotated-segment* kernel — the tree generalization of
    :func:`repro.core.ring.rotated_ring_local`. Rank r holds client r's
    flat gradient, split into K segments; segment s executes the plan with
    every tree position relabeled by ``+s (mod K)`` ("rotated start
    ranks"), so each rank runs one node step per real slot per level and
    every ICI link is busy at every level. The parameter server for
    segment s is rank s — the round's aggregate comes out naturally
    ZeRO-sharded, exactly like the ring. On the chain plan
    (:func:`ring_chain_plan`) this *is* the rotated ring, collective for
    collective — ``rotated_ring_local`` now delegates here.

``run_plan_clients_local``
    The *client-per-rank* kernel — the paper-faithful federated mapping.
    Rank r is client r with its full flat vector; one level-synchronous
    round is executed jointly, and the result is **bit-exact** to host
    :func:`repro.agg.plan.execute` (same values, EF, per-client §V stats).
    This is the kernel behind ``Simulator(backend="device")`` and the
    device/host equivalence tests.

Routing: a level's payload must travel from the rank playing a node to the
rank playing its parent. Under segment rotation that offset —
``(parent − node) mod K`` — is *rank-independent*, so a level is exactly a
set of ``ppermute`` steps. When the plan is a trace-time constant the
kernel emits one ppermute per real slot (the chain plan reproduces the
ring's K hops). When the plan's arrays are **traced** jit arguments (a
``TopologySchedule`` swapping plans per round under one specialization)
the offsets are traced too, so the kernel routes every level through a
⌈log₂K⌉-round ppermute butterfly: round j shifts the whole payload bundle
by 2^j and each slot keeps the shifted copy iff bit j of its offset is
set. Same values either way; the butterfly trades ~log₂K× wire for a
single XLA executable serving every same-shape plan.
"""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import compat
from repro.agg.plan import AggPlan, RoundResult, compile_plan
from repro.core import sparsify as sp
from repro.core.algorithms import (AggConfig, AggKind, HopStats, NodeCtx,
                                   level_step, level_step_batched, node_step)
from repro.core.ring import RingStats

Array = jax.Array

# Algorithms whose per-hop payload is bounded by the budget → eligible for
# compact (values, indices) wire transport, the paper's ω+⌈log₂d⌉ format
# (same rule as the ring; see repro.core.ring._send).
_COMPACT_KINDS = (AggKind.CL_SIA, AggKind.CL_TC_SIA)


def _wire_budget(cfg: AggConfig) -> int:
    if cfg.kind == AggKind.CL_TC_SIA:
        return cfg.q_global + cfg.q_local
    return cfg.q


def _compact_eligible(cfg: AggConfig, seg: int, budgeted: bool) -> bool:
    """Wire-format eligibility (identical to the historic ring rule).

    Threshold Top-Q keeps ≥ q survivors (ties inside the final bisection
    bin over-select), so the CL bound ‖γ‖₀ ≤ q that sizes the q compact
    wire slots does not hold — only the exact ``lax.top_k`` sparsifier may
    use the compact segment. Same reasoning excludes dynamic per-client
    budgets (sort-threshold over-selection on ties).
    """
    q = _wire_budget(cfg)
    return (cfg.kind in _COMPACT_KINDS and not budgeted
            and cfg.topq_impl == "exact" and q < seg // 2)


def _use_compact(cfg: AggConfig, seg: int, plan: AggPlan,
                 participate_present: bool, wire: str) -> bool:
    """Decide the wire format for one lowering.

    Compact ``(values[q], indices[q])`` needs the CL bound ‖γ‖₀ ≤ q to hold
    on *every* hop. A non-participating (or stranded-stub) node forwards its
    incoming γ unchanged — on a tree that γ is a **sum over children** and
    can exceed q, so compact would silently drop coordinates. Chains are
    safe for any straggler set (every node has ≤ 1 child, so a forwarded γ
    was itself compacted); general plans are safe only when every node
    transmits, i.e. no ``participate`` mask and an all-alive plan.
    ``wire="auto"`` proves one of those statically (traced plans fall back
    to dense); ``wire="compact"`` lets a caller with host-side knowledge
    (e.g. the simulator on an all-alive schedule) assert safety;
    ``wire="dense"`` forces the dense segment.
    """
    if wire == "dense":
        return False
    eligible = _compact_eligible(cfg, seg, plan.q_budget is not None)
    if wire == "compact":
        if (cfg.kind not in _COMPACT_KINDS or plan.q_budget is not None
                or cfg.topq_impl != "exact"):
            raise ValueError(
                f"wire='compact' needs a constant-length algorithm with the "
                f"exact Top-Q sparsifier and no dynamic budgets; got "
                f"{cfg.kind} (topq_impl={cfg.topq_impl!r}, "
                f"q_budget={'set' if plan.q_budget is not None else 'none'})")
        return eligible
    if wire != "auto":
        raise ValueError(f"unknown wire format {wire!r}")
    if not eligible or not _is_static_plan(plan):
        return False
    k = plan.num_clients
    par = np.asarray(plan.parent_row)
    internal = par[(np.asarray(plan.slot_mask) > 0) & (par < k)]
    chain_like = (internal.size == 0
                  or np.bincount(internal, minlength=k).max() <= 1)
    all_alive = bool(np.all(np.asarray(plan.alive) > 0))
    return chain_like or (not participate_present and all_alive)


def _is_static_plan(plan: AggPlan) -> bool:
    """True when the plan's arrays are trace-time constants."""
    return not any(isinstance(leaf, jax.core.Tracer)
                   for leaf in jax.tree.leaves(plan))


def ring_chain_tree(num_ranks: int):
    """The rotated ring's chain as an ``AggTree`` (reversed path tree)."""
    from repro.topo.tree import PS, AggTree
    return AggTree(parent=tuple(range(1, num_ranks)) + (PS,))


@functools.lru_cache(maxsize=None)
def ring_chain_plan(num_ranks: int) -> AggPlan:
    """The rotated ring's chain as an :class:`AggPlan`.

    Visiting order of segment s is ranks ``s, s+1, …, s+K−1`` — i.e. the
    *reversed* path tree (client 0 deepest, client K−1 adjacent to the PS),
    whose every transport offset is +1: the plan-driven kernel emits the
    ring's single ``ppermute(+1)`` per level.
    """
    return compile_plan(ring_chain_tree(num_ranks))


# ---------------------------------------------------------------------------
# Wire transport
# ---------------------------------------------------------------------------

def _shift_perm(num_ranks: int, shift: int) -> list:
    return [(i, (i + shift) % num_ranks) for i in range(num_ranks)]


def _nest_vmap(fn, levels: int):
    for _ in range(levels):
        fn = jax.vmap(fn)
    return fn


def _send_static(cfg: AggConfig, payload: Array, seg: int, axis,
                 shift: int, compact: bool) -> Array:
    """One logical hop by a static ring shift (the ring's ``_send``).

    ``payload`` may carry leading batch axes (``[B, seg]`` cohort batches
    — B cohorts ride ONE ppermute per hop; compact transport compacts per
    trailing vector).
    """
    if shift == 0:
        return payload
    perm = _shift_perm(compat.axis_size(axis), shift)
    if not compact:
        return jax.lax.ppermute(payload, axis, perm)
    lead = payload.ndim - 1
    q = _wire_budget(cfg)
    vals, idx, _ = _nest_vmap(lambda x: sp.compact(x, q), lead)(payload)
    vals = jax.lax.ppermute(vals.astype(jnp.dtype(cfg.wire_dtype)), axis,
                            perm)
    idx = jax.lax.ppermute(idx, axis, perm)
    return _nest_vmap(
        lambda v, i: sp.scatter(v.astype(jnp.float32), i, seg),
        lead)(vals, idx)


def _route_butterfly(cfg: AggConfig, payload: Array, offsets: Array,
                     seg: int, axis, compact: bool) -> Array:
    """Deliver ``payload[w]`` to rank ``r + offsets[w]`` for every rank r.

    Offsets are *traced* (plan-dependent) but rank-uniform per slot, so a
    ⌈log₂K⌉-round butterfly of whole-bundle ppermutes with per-slot bit
    selection realizes any shift pattern under one specialization.

    ``payload`` is ``[W, seg]``, or ``[W, B, seg]`` for a cohort batch —
    slots stay the leading axis (the bit selection broadcasts over the
    cohorts) and each butterfly round remains ONE ppermute of the whole
    bundle for all B cohorts.
    """
    K = compat.axis_size(axis)
    rounds = max(1, math.ceil(math.log2(K))) if K > 1 else 0
    lead = payload.ndim - 1
    if compact:
        q = _wire_budget(cfg)
        vals, idx, _ = _nest_vmap(lambda x: sp.compact(x, q),
                                  lead)(payload)
        vals = vals.astype(jnp.dtype(cfg.wire_dtype))
        bundle = (vals, idx)
    else:
        bundle = (payload,)
    for j in range(rounds):
        perm = _shift_perm(K, 2 ** j)
        moved = tuple(jax.lax.ppermute(b, axis, perm) for b in bundle)
        take = ((offsets >> j) & 1) > 0                       # [W] bool
        bundle = tuple(
            jnp.where(take.reshape((-1,) + (1,) * (b.ndim - 1)), m, b)
            for b, m in zip(bundle, moved))
    if compact:
        vals, idx = bundle
        return _nest_vmap(lambda v, i: sp.scatter(
            v.astype(jnp.float32), i, seg), lead)(vals, idx)
    return bundle[0]


# ---------------------------------------------------------------------------
# Rotated-segment kernel (the ring generalization)
# ---------------------------------------------------------------------------

def _is_register_chain(plan: AggPlan, np_node, np_par) -> bool:
    """True for chain-structured plans: one slot per level, no padding, and
    level l's parent is level l+1's node (the delivery is consumed on the
    very next level), finishing at the PS. Such plans — the ring chain and
    every permuted chain order — need no inbox buffer."""
    L, W = plan.shape
    k = plan.num_clients
    if W != 1 or L != k or np.any(np.asarray(plan.slot_mask)[:, 0] <= 0):
        return False
    ids, par = np_node[:, 0], np_par[:, 0]
    return (all(par[l] == ids[l + 1] for l in range(L - 1))
            and par[L - 1] == k)


def _run_chain_register(cfg, plan, flat_local, ef_local, weight, *, axis,
                        np_node, np_par, global_mask_local, p_eff, qb,
                        compact):
    """Chain specialization: the historic rotated-ring register loop.

    Keeps the full-size buffers in their storage dtype (bf16 by default —
    a full f32 upcast here would materialize 2× the gradient shard);
    per-segment slices are upcast to f32 inside the loop.
    """
    K = compat.axis_size(axis)
    r = jax.lax.axis_index(axis)
    n = flat_local.shape[0]
    seg = n // K
    L = plan.shape[0]
    x = flat_local.reshape(K, seg)
    ef = ef_local.reshape(K, seg)
    gm = (None if global_mask_local is None
          else global_mask_local.reshape(K, seg))

    step_fn = node_step(cfg)
    gamma = jnp.zeros((seg,), jnp.float32)
    bits = jnp.float32(0)
    nnz = jnp.float32(0)
    err = jnp.float32(0)
    for l in range(L):
        b, p = int(np_node[l, 0]), int(np_par[l, 0])
        s = jnp.mod(r - b, K)
        g_seg = x[s].astype(jnp.float32)
        e_seg = ef[s].astype(jnp.float32)
        m_seg = (jnp.zeros((seg,), jnp.float32) if gm is None
                 else gm[s].astype(jnp.float32))
        ctx = NodeCtx(global_mask=m_seg, participate=p_eff, q_budget=qb)
        gamma_out, e_new, st = step_fn(cfg, g_seg, gamma, e_seg, weight, ctx)
        ef = ef.at[s].set(e_new.astype(ef.dtype))
        bits = bits + st.bits
        nnz = nnz + st.nnz_out.astype(jnp.float32)
        err = err + st.err_sq
        shift = (-b) % K if p == K else (p - b) % K
        gamma = _send_static(cfg, gamma_out, seg, axis, shift, compact)
    # the final send was the ownership shift: rank r holds segment r
    return gamma, ef.reshape(n), RingStats(bits=bits, nnz=nnz, err_sq=err)


def run_plan_segments_local(
    cfg: AggConfig,
    plan: AggPlan,
    flat_local: Array,                # [n] this rank's gradient slice
    ef_local: Array,                  # [n] this rank's EF memory
    weight: Array,                    # scalar D_k
    *,
    axis,                             # mesh axis name or tuple (ring order)
    global_mask_local: Optional[Array] = None,   # [n] TCS mask slice
    participate: Optional[Array] = None,         # scalar 0/1
    transport: str = "auto",          # "auto" | "static" | "butterfly"
    wire: str = "auto",               # "auto" | "compact" | "dense"
) -> tuple[Array, Array, RingStats]:
    """Execute an AggPlan over the K-rank ring, one rotated copy per segment.

    Must be called inside shard_map with ``axis`` manual; ``n % K == 0``.
    Segment s runs the plan with tree positions relabeled by ``+s (mod K)``
    and its parameter server at rank s, so after the round rank r holds the
    fully-aggregated segment r — the ring's ownership layout. Per segment,
    the value path is bit-exact to :func:`repro.agg.plan.execute` on that
    segment with the client relabeling (tested), and on
    :func:`ring_chain_plan` the whole kernel is bit-exact to the historic
    ``rotated_ring_local``. Returns (final segment [n//K], new EF [n],
    summed RingStats).

    Memory: chain-structured static plans (the training default) take the
    register fast path — a single [seg] γ carry, no extra buffers, the
    historic ring's footprint. General trees need the [K+3, seg] f32 inbox
    (a parent may consume a child's delivery several levels later) plus
    padded-read copies of the gradient/EF shards — ~3 extra f32 shards per
    rank, the price of arbitrary topologies.

    Participation semantics: ``participate``, ``plan.alive``, and
    ``plan.q_budget`` are **physical-rank** properties here — rank r
    straggles, is stranded, or owns a narrow uplink as a device, in every
    segment, whatever plan position it plays (the host executor instead
    folds them per plan position; the per-segment host reference for a
    plan with stubs/budgets is therefore ``execute`` on an all-alive copy
    with ``(participate·alive)`` and ``q_budget`` relabeled by the
    segment's rotation — see tests/test_device_plan.py).
    """
    K = compat.axis_size(axis)
    if plan.num_clients != K:
        raise ValueError(
            f"plan has {plan.num_clients} clients but the mesh axis "
            f"{axis!r} has {K} ranks")
    if plan.num_sinks != 1:
        raise ValueError(
            "the segments kernel runs single-sink plans; lower a "
            "NestedPlan through run_nested_segments_local")
    r = jax.lax.axis_index(axis)
    n = flat_local.shape[0]
    assert n % K == 0, (n, K)
    seg = n // K
    L, W = plan.shape

    if transport not in ("auto", "static", "butterfly"):
        raise ValueError(f"unknown transport {transport!r}")
    static = (_is_static_plan(plan) if transport == "auto"
              else transport == "static")
    if static and not _is_static_plan(plan):
        raise ValueError("transport='static' needs a trace-time-constant "
                         "plan (numpy arrays, not traced jit arguments)")
    np_node = np.asarray(plan.node_id) if static else None
    np_par = np.asarray(plan.parent_row) if static else None

    compact = _use_compact(cfg, seg, plan, participate is not None, wire)
    alive_r = jnp.asarray(plan.alive)[r]
    p_scalar = jnp.float32(1) if participate is None else participate.astype(
        jnp.float32)
    p_eff = p_scalar * alive_r
    qb = (None if plan.q_budget is None
          else jnp.asarray(plan.q_budget, jnp.int32)[r])

    if static and _is_register_chain(plan, np_node, np_par):
        # Chain-structured plan (every level's delivery is consumed at the
        # next level): carry γ in a single [seg] register exactly like the
        # historic hand-written ring — no inbox buffer, no concat copies.
        return _run_chain_register(cfg, plan, flat_local, ef_local, weight,
                                   axis=axis, np_node=np_node,
                                   np_par=np_par,
                                   global_mask_local=global_mask_local,
                                   p_eff=p_eff, qb=qb, compact=compact)

    node_id = jnp.asarray(plan.node_id)
    slot_mask = jnp.asarray(plan.slot_mask)
    parent_row = jnp.asarray(plan.parent_row)

    # Storage-dtype buffers, one zero row (K) backing padded-slot reads —
    # mirrors the host executor's dummy row.
    zrow = lambda buf: jnp.zeros((1, seg), buf.dtype)
    x_ext = jnp.concatenate([flat_local.reshape(K, seg)] +
                            [zrow(flat_local)])
    ef_ext = jnp.concatenate([ef_local.reshape(K, seg),
                              zrow(ef_local), zrow(ef_local)])   # K+1 trash
    gm_ext = None
    if global_mask_local is not None:
        gm_ext = jnp.concatenate([global_mask_local.reshape(K, seg)] +
                                 [zrow(global_mask_local)])

    # inbox rows: 0..K−1 per-segment incoming sums, K = this rank's PS
    # accumulator (segment r), K+1 = trash, K+2 = zero dummy (read-only).
    inbox = jnp.zeros((K + 3, seg), jnp.float32)

    lvl_fn = level_step(cfg)
    w_bcast = jnp.broadcast_to(jnp.asarray(weight, jnp.float32), (W,))
    p_bcast = jnp.broadcast_to(p_eff, (W,))
    qb_bcast = None if qb is None else jnp.broadcast_to(qb, (W,))
    bits = jnp.float32(0)
    nnz = jnp.float32(0)
    err = jnp.float32(0)

    for l in range(L):
        ids_l = node_id[l]                               # [W]
        mask_l = slot_mask[l]
        par_l = parent_row[l]
        valid = mask_l > 0
        s_w = jnp.mod(r - ids_l, K).astype(jnp.int32)    # my segment per slot
        s_read = jnp.where(valid, s_w, K)                # padding → zero row

        g_lvl = x_ext[s_read].astype(jnp.float32)
        e_lvl = ef_ext[s_read].astype(jnp.float32)
        gam_in = inbox[jnp.where(valid, s_w, K + 2)]
        m_lvl = (jnp.zeros((W, seg), jnp.float32) if gm_ext is None
                 else gm_ext[s_read].astype(jnp.float32))

        gamma_out, e_new, st = lvl_fn(g_lvl, gam_in, e_lvl, w_bcast,
                                      p_bcast, m_lvl, qb_bcast, mask_l)

        ef_ext = ef_ext.at[jnp.where(valid, s_w, K + 1)].set(
            e_new.astype(ef_ext.dtype))
        bits = bits + jnp.sum(st.bits * mask_l)
        nnz = nnz + jnp.sum(st.nnz_out.astype(jnp.float32) * mask_l)
        err = err + jnp.sum(st.err_sq * mask_l)

        payload = gamma_out * mask_l[:, None]
        is_ps = par_l == K
        if static:
            arrived = []
            for w in range(W):
                b = int(np_node[l, w])
                if b >= K:                               # padding slot
                    arrived.append(jnp.zeros((seg,), jnp.float32))
                    continue
                p = int(np_par[l, w])
                shift = (-b) % K if p == K else (p - b) % K
                arrived.append(_send_static(cfg, payload[w], seg, axis,
                                            shift, compact))
            arrived = jnp.stack(arrived)
        else:
            offsets = jnp.where(is_ps, jnp.mod(-ids_l, K),
                                jnp.mod(par_l - ids_l, K)).astype(jnp.int32)
            arrived = _route_butterfly(cfg, payload, offsets, seg, axis,
                                       compact)
        # receiver's inbox row: segment (r − parent) for ordinary slots,
        # the PS accumulator for PS slots, trash for padding — one
        # slot-ordered scatter-add, mirroring the host executor's.
        rows = jnp.where(valid,
                         jnp.where(is_ps, K, jnp.mod(r - par_l, K)),
                         K + 1).astype(jnp.int32)
        inbox = inbox.at[rows].add(arrived)

    final = inbox[K]
    return final, ef_ext[:K].reshape(n), RingStats(bits=bits, nnz=nnz,
                                                   err_sq=err)


# ---------------------------------------------------------------------------
# Client-per-rank kernel (bit-exact to host execute)
# ---------------------------------------------------------------------------

def run_plan_clients_local(
    cfg: AggConfig,
    plan: AggPlan,
    g_local: Array,                   # [d] this client's flat gradient
    ef_local: Array,                  # [d] this client's EF memory
    weight: Array,                    # scalar D_k
    *,
    axis,                             # mesh axis (one rank per client)
    global_mask: Optional[Array] = None,   # [d] TCS mask (replicated)
    participate: Optional[Array] = None,   # scalar 0/1
    wire: str = "auto",                    # "auto" | "compact" | "dense"
) -> tuple[Array, Array, HopStats]:
    """Execute an AggPlan with client k living on rank k (paper mapping).

    Must be called inside shard_map with ``axis`` manual and axis size ==
    ``plan.num_clients`` (a nested stage plan is first padded by
    :func:`_pad_plan_clients` so it names every rank but schedules only
    its real clients — the extra ranks never activate). Levels run in
    lockstep; each level the
    active ranks fold their gradient into their inbox and ship γ toward
    the rank playing their parent (compact wire for the CL algorithms).
    Bit-exact to host :func:`repro.agg.plan.execute` — same aggregate, EF
    rows, and per-client §V HopStats (returned for *this* rank's client).
    The sink aggregate is returned replicated on every rank: ``[d]`` for
    single-sink plans, ``[R, d]`` sink-ordered for forest plans (the
    stage form of a :class:`~repro.agg.nested.NestedPlan`).
    """
    K = compat.axis_size(axis)
    if plan.num_clients != K:
        raise ValueError(
            f"plan has {plan.num_clients} clients but the mesh axis "
            f"{axis!r} has {K} ranks")
    r = jax.lax.axis_index(axis)
    d = g_local.shape[0]
    L, W = plan.shape

    node_id = jnp.asarray(plan.node_id)
    slot_mask = jnp.asarray(plan.slot_mask)
    parent_row = jnp.asarray(plan.parent_row)
    # dtype-faithful to the host executor: participation, masks, and the
    # inbox all live in the gradient dtype, exactly as execute()'s
    # g_ext/e_ext/inbox do — bit-exactness holds for bf16 inputs too
    dt = g_local.dtype
    alive_r = jnp.asarray(plan.alive, dt)[r]
    p_scalar = jnp.ones((), dt) if participate is None else participate
    p_eff = p_scalar * alive_r
    qb = (None if plan.q_budget is None
          else jnp.asarray(plan.q_budget, jnp.int32)[r])
    compact = _use_compact(cfg, d, plan, participate is not None, wire)
    if wire == "auto" and jnp.dtype(cfg.wire_dtype) != jnp.float32:
        # a quantizing wire (ω=16 bf16 knob) breaks host parity — this
        # kernel's contract; wire="compact" still opts in explicitly
        compact = False
    q_wire = _wire_budget(cfg)

    gm = jnp.zeros((d,), dt) if global_mask is None else global_mask
    step_fn = node_step(cfg)
    ctx = NodeCtx(global_mask=gm, participate=p_eff, q_budget=qb)

    # buf rows: 0 = my inbox, 1..R = the (replicated) sink accumulators
    # (R = 1: the PS), R+1 = trash
    r_sinks = plan.num_sinks
    buf = jnp.zeros((2 + r_sinks, d), dt)
    e_cur = ef_local
    zero_i = jnp.int32(0)
    my_stats = HopStats(nnz_out=zero_i, nnz_global=zero_i, nnz_local=zero_i,
                        bits=jnp.float32(0), err_sq=jnp.float32(0))

    for l in range(L):
        ids_l = node_id[l]
        valid = slot_mask[l] > 0                         # [W]
        is_me = (ids_l == r) & valid
        active = jnp.any(is_me)

        gamma_out, e_new, st = step_fn(cfg, g_local, buf[0], e_cur, weight,
                                       ctx)
        # no down-cast: the host executor returns EF in the node step's
        # (possibly promoted) dtype, and where() promotes e_cur to match
        e_cur = jnp.where(active, e_new, e_cur)
        my_stats = jax.tree.map(
            lambda acc, s: jnp.where(active, s, acc), my_stats, st)

        payload = gamma_out * active.astype(gamma_out.dtype)
        if compact:
            vals, idx, _ = sp.compact(payload, q_wire)
            all_vals = jax.lax.all_gather(
                vals.astype(jnp.dtype(cfg.wire_dtype)), axis)
            all_idx = jax.lax.all_gather(idx, axis)
            def from_rank(b):
                return sp.scatter(all_vals[b].astype(payload.dtype),
                                  all_idx[b], d)
        else:
            all_pay = jax.lax.all_gather(payload, axis)  # [K, d]
            def from_rank(b):
                return all_pay[b]

        # deliver in slot order (the host executor's scatter order): row 0
        # if the sender's parent is me, rows 1..R if it is a sink, else
        # trash.
        b_clip = jnp.clip(ids_l, 0, K - 1)
        arrived = jax.vmap(from_rank)(b_clip) * slot_mask[l][:, None]
        par_l = parent_row[l]
        p_clients = plan.num_clients
        rows = jnp.where(
            valid & (par_l == r), 0,
            jnp.where(valid & (par_l >= p_clients)
                      & (par_l < p_clients + r_sinks),
                      1 + par_l - p_clients,
                      1 + r_sinks)).astype(jnp.int32)
        # mixed-dtype add on purpose: the host executor scatter-adds the
        # (possibly f32-promoted) γ into the grads-dtype inbox, and jax's
        # duplicate-index combining differs from pre-casting the updates —
        # pre-casting here would be one bf16 ulp off the host result
        buf = buf.at[rows].add(arrived)

    return (buf[1] if r_sinks == 1 else buf[1:1 + r_sinks]), e_cur, my_stats


# ---------------------------------------------------------------------------
# Host-side wrapper: full rounds over a client mesh
# ---------------------------------------------------------------------------

def client_mesh(num_clients: int, axis: str = "clients"):
    """1-D mesh with one device per client (first K local devices)."""
    devs = jax.devices()
    if len(devs) < num_clients:
        raise ValueError(
            f"device plan needs {num_clients} devices, have {len(devs)} "
            f"(set XLA_FLAGS=--xla_force_host_platform_device_count="
            f"{num_clients} before importing jax to fake them on CPU)")
    return jax.sharding.Mesh(np.asarray(devs[:num_clients]), (axis,))


def execute_sharded(
    cfg: AggConfig,
    plan: AggPlan,
    grads: Array,                  # [K, d] per-client effective gradients
    e: Array,                      # [K, d] EF memory
    weights: Array,                # [K]    D_k
    *,
    mesh=None,
    global_mask: Optional[Array] = None,
    participate: Optional[Array] = None,
    wire: str = "auto",
) -> RoundResult:
    """One aggregation round on devices — drop-in for host ``execute``.

    Shards clients one-per-device over ``mesh`` (default:
    :func:`client_mesh`), runs :func:`run_plan_clients_local`, and returns
    the same :class:`~repro.agg.plan.RoundResult` contract, bit-exact to
    the host executor. Jit-friendly: the plan rides through as a traced
    pytree argument, so every same-shape plan of a
    :class:`~repro.agg.schedule.TopologySchedule` reuses one trace.
    """
    k, d = grads.shape
    if plan.num_clients != k:
        raise ValueError(f"plan has {plan.num_clients} clients, grads {k}")
    if mesh is None:
        mesh = client_mesh(k)
    axis = mesh.axis_names[0]
    from jax.sharding import PartitionSpec as P

    has_part = participate is not None
    part = (jnp.ones((k,), grads.dtype) if participate is None
            else participate)
    gmask = (jnp.zeros((d,), grads.dtype) if global_mask is None
             else global_mask)

    # resolve the wire format here, where the plan may still be a host
    # constant — inside the shard_map body it is always traced; auto never
    # picks a quantizing wire (host parity), wire="compact" may
    wire_fmt = ("compact" if _use_compact(cfg, d, plan, has_part, wire)
                and (wire == "compact"
                     or jnp.dtype(cfg.wire_dtype) == jnp.float32)
                else "dense")

    def body(plan, g_l, e_l, w_l, part_l, gm):
        agg, e_new, st = run_plan_clients_local(
            cfg, plan, g_l[0], e_l[0], w_l[0], axis=axis, global_mask=gm,
            participate=part_l[0] if has_part else None, wire=wire_fmt)
        return agg, e_new[None], jax.tree.map(lambda s: s[None], st)

    plan_specs = jax.tree.map(lambda _: P(), plan)
    stats_specs = jax.tree.map(lambda _: P(axis), HopStats(
        0, 0, 0, 0., 0.))
    agg, e_new, stats = compat.shard_map(
        body, mesh=mesh,
        in_specs=(plan_specs, P(axis), P(axis), P(axis), P(axis), P()),
        out_specs=(P(), P(axis), stats_specs),
        axis_names={axis},
    )(plan, grads, e, weights, part, gmask)
    return RoundResult(aggregate=agg, e_new=e_new, stats=stats)


# ---------------------------------------------------------------------------
# Cohort-batched lowerings: B multi-tenant rounds ride one collective
# ---------------------------------------------------------------------------

def _run_chain_register_batched(cfg, plan, flat_local, ef_local, weight, *,
                                axis, np_node, np_par, global_mask_local,
                                p_eff, qb, compact):
    """Cohort-batched chain register loop: γ is a ``[B, seg]`` carry.

    Same hop schedule as :func:`_run_chain_register`, but the B cohorts run
    the level as one :func:`level_step` launch (lanes = B) and every hop is
    ONE ``ppermute`` of the ``[B, seg]`` register — the collective count of
    the sequential ring, whatever B is.
    """
    K = compat.axis_size(axis)
    r = jax.lax.axis_index(axis)
    b_coh, n = flat_local.shape
    seg = n // K
    L = plan.shape[0]
    x = flat_local.reshape(b_coh, K, seg)
    ef = ef_local.reshape(b_coh, K, seg)
    gm = (None if global_mask_local is None
          else global_mask_local.reshape(b_coh, K, seg))

    lvl_fn = level_step(cfg)
    gamma = jnp.zeros((b_coh, seg), jnp.float32)
    bits = jnp.zeros((b_coh,), jnp.float32)
    nnz = jnp.zeros((b_coh,), jnp.float32)
    err = jnp.zeros((b_coh,), jnp.float32)
    for l in range(L):
        b, p = int(np_node[l, 0]), int(np_par[l, 0])
        s = jnp.mod(r - b, K)
        g_seg = x[:, s].astype(jnp.float32)
        e_seg = ef[:, s].astype(jnp.float32)
        m_seg = (jnp.zeros((b_coh, seg), jnp.float32) if gm is None
                 else gm[:, s].astype(jnp.float32))
        gamma_out, e_new, st = lvl_fn(g_seg, gamma, e_seg, weight, p_eff,
                                      m_seg, qb)
        ef = ef.at[:, s].set(e_new.astype(ef.dtype))
        bits = bits + st.bits
        nnz = nnz + st.nnz_out.astype(jnp.float32)
        err = err + st.err_sq
        shift = (-b) % K if p == K else (p - b) % K
        gamma = _send_static(cfg, gamma_out, seg, axis, shift, compact)
    return gamma, ef.reshape(b_coh, n), RingStats(bits=bits, nnz=nnz,
                                                  err_sq=err)


def run_plan_segments_batched(
    cfg: AggConfig,
    plan: AggPlan,
    flat_local: Array,                # [B, n] this rank's cohort slices
    ef_local: Array,                  # [B, n] EF memories
    weight: Array,                    # [B] per-cohort D_k
    *,
    axis,
    global_mask_local: Optional[Array] = None,   # [B, n]
    participate: Optional[Array] = None,         # [B] 0/1
    transport: str = "auto",
    wire: str = "auto",
) -> tuple[Array, Array, RingStats]:
    """Cohort-batched :func:`run_plan_segments_local` — one shared plan,
    B tenants per rank, one ppermute wavefront per level.

    Every cohort runs the plan exactly as the sequential kernel would; the
    payloads stack to ``[B, seg]`` (chain register) / ``[W, B, seg]``
    (butterfly bundle) so each hop or butterfly round stays a single
    collective for all B cohorts. Per cohort the result is bitwise what
    the sequential kernel returns. Returns ``([B, seg], [B, n],``
    :class:`RingStats` with ``[B]`` leaves``)``.
    """
    if jnp.ndim(jnp.asarray(plan.node_id)) == 3:
        raise ValueError("the batched segments kernel runs one shared "
                         "plan; stacked per-cohort plans are a host "
                         "(execute_batched) feature")
    K = compat.axis_size(axis)
    if plan.num_clients != K:
        raise ValueError(
            f"plan has {plan.num_clients} clients but the mesh axis "
            f"{axis!r} has {K} ranks")
    if plan.num_sinks != 1:
        raise ValueError("the batched segments kernel runs single-sink "
                         "plans")
    r = jax.lax.axis_index(axis)
    b_coh, n = flat_local.shape
    assert n % K == 0, (n, K)
    seg = n // K
    L, W = plan.shape

    if transport not in ("auto", "static", "butterfly"):
        raise ValueError(f"unknown transport {transport!r}")
    static = (_is_static_plan(plan) if transport == "auto"
              else transport == "static")
    if static and not _is_static_plan(plan):
        raise ValueError("transport='static' needs a trace-time-constant "
                         "plan (numpy arrays, not traced jit arguments)")
    np_node = np.asarray(plan.node_id) if static else None
    np_par = np.asarray(plan.parent_row) if static else None

    compact = _use_compact(cfg, seg, plan, participate is not None, wire)
    alive_r = jnp.asarray(plan.alive)[r]
    p_vec = (jnp.ones((b_coh,), jnp.float32) if participate is None
             else participate.astype(jnp.float32))
    p_eff = p_vec * alive_r
    qb = (None if plan.q_budget is None
          else jnp.broadcast_to(jnp.asarray(plan.q_budget, jnp.int32)[r],
                                (b_coh,)))

    if static and _is_register_chain(plan, np_node, np_par):
        return _run_chain_register_batched(
            cfg, plan, flat_local, ef_local, weight, axis=axis,
            np_node=np_node, np_par=np_par,
            global_mask_local=global_mask_local, p_eff=p_eff, qb=qb,
            compact=compact)

    node_id = jnp.asarray(plan.node_id)
    slot_mask = jnp.asarray(plan.slot_mask)
    parent_row = jnp.asarray(plan.parent_row)

    zrow = lambda buf: jnp.zeros((b_coh, 1, seg), buf.dtype)
    x_ext = jnp.concatenate([flat_local.reshape(b_coh, K, seg),
                             zrow(flat_local)], axis=1)
    ef_ext = jnp.concatenate([ef_local.reshape(b_coh, K, seg),
                              zrow(ef_local), zrow(ef_local)], axis=1)
    gm_ext = None
    if global_mask_local is not None:
        gm_ext = jnp.concatenate([global_mask_local.reshape(b_coh, K, seg),
                                  zrow(global_mask_local)], axis=1)

    inbox = jnp.zeros((b_coh, K + 3, seg), jnp.float32)

    lvl_fn = level_step_batched(cfg)
    w_bcast = jnp.broadcast_to(jnp.asarray(weight, jnp.float32)[:, None],
                               (b_coh, W))
    p_bcast = jnp.broadcast_to(p_eff[:, None], (b_coh, W))
    qb_bcast = (None if qb is None
                else jnp.broadcast_to(qb[:, None], (b_coh, W)))
    bits = jnp.zeros((b_coh,), jnp.float32)
    nnz = jnp.zeros((b_coh,), jnp.float32)
    err = jnp.zeros((b_coh,), jnp.float32)

    for l in range(L):
        ids_l = node_id[l]                               # [W]
        mask_l = slot_mask[l]
        par_l = parent_row[l]
        valid = mask_l > 0
        s_w = jnp.mod(r - ids_l, K).astype(jnp.int32)
        s_read = jnp.where(valid, s_w, K)

        g_lvl = x_ext[:, s_read].astype(jnp.float32)     # [B, W, seg]
        e_lvl = ef_ext[:, s_read].astype(jnp.float32)
        gam_in = inbox[:, jnp.where(valid, s_w, K + 2)]
        m_lvl = (jnp.zeros((b_coh, W, seg), jnp.float32) if gm_ext is None
                 else gm_ext[:, s_read].astype(jnp.float32))
        valid_b = jnp.broadcast_to(mask_l, (b_coh, W))

        gamma_out, e_new, st = lvl_fn(g_lvl, gam_in, e_lvl, w_bcast,
                                      p_bcast, m_lvl, qb_bcast, valid_b)

        rows_ef = jnp.where(valid, s_w, K + 1)
        ef_ext = jax.vmap(lambda efc, en: efc.at[rows_ef].set(
            en.astype(ef_ext.dtype)))(ef_ext, e_new)
        bits = bits + jnp.sum(st.bits * mask_l, axis=1)
        nnz = nnz + jnp.sum(st.nnz_out.astype(jnp.float32) * mask_l,
                            axis=1)
        err = err + jnp.sum(st.err_sq * mask_l, axis=1)

        payload = gamma_out * mask_l[None, :, None]      # [B, W, seg]
        is_ps = par_l == K
        if static:
            arrived = []
            for w in range(W):
                b = int(np_node[l, w])
                if b >= K:                               # padding slot
                    arrived.append(jnp.zeros((b_coh, seg), jnp.float32))
                    continue
                p = int(np_par[l, w])
                shift = (-b) % K if p == K else (p - b) % K
                arrived.append(_send_static(cfg, payload[:, w], seg, axis,
                                            shift, compact))
            arrived = jnp.stack(arrived, axis=1)         # [B, W, seg]
        else:
            offsets = jnp.where(is_ps, jnp.mod(-ids_l, K),
                                jnp.mod(par_l - ids_l, K)).astype(jnp.int32)
            arrived = jnp.moveaxis(
                _route_butterfly(cfg, jnp.moveaxis(payload, 0, 1), offsets,
                                 seg, axis, compact), 0, 1)
        rows = jnp.where(valid,
                         jnp.where(is_ps, K, jnp.mod(r - par_l, K)),
                         K + 1).astype(jnp.int32)
        inbox = jax.vmap(lambda ib, ar: ib.at[rows].add(ar))(inbox, arrived)

    final = inbox[:, K]
    return final, ef_ext[:, :K].reshape(b_coh, n), RingStats(
        bits=bits, nnz=nnz, err_sq=err)


def run_plan_clients_batched(
    cfg: AggConfig,
    plan: AggPlan,
    g_local: Array,                   # [B, d] this client's cohort grads
    ef_local: Array,                  # [B, d] EF memories
    weight: Array,                    # [B] per-cohort D_k
    *,
    axis,
    global_mask: Optional[Array] = None,   # [B, d] per-cohort TCS masks
    participate: Optional[Array] = None,   # [B] 0/1
    wire: str = "auto",
) -> tuple[Array, Array, HopStats]:
    """Cohort-batched :func:`run_plan_clients_local` — B tenants per rank.

    ``plan`` is shared ``[L, W]`` or stacked ``[B, L, W]``
    (:func:`repro.agg.plan.stack_plans`); either way each level is ONE
    :func:`level_step` launch (lanes = B) plus ONE ``all_gather`` of the
    ``[B, d]`` payload stack for all cohorts. Per cohort, bit-exact to the
    sequential kernel and hence to host ``execute``. Returns the sink
    aggregates ``[B, d]`` (or ``[B, R, d]``), EF ``[B, d]``, and this
    rank's per-cohort :class:`HopStats` (``[B]`` leaves).
    """
    K = compat.axis_size(axis)
    if plan.num_clients != K:
        raise ValueError(
            f"plan has {plan.num_clients} clients but the mesh axis "
            f"{axis!r} has {K} ranks")
    r = jax.lax.axis_index(axis)
    b_coh, d = g_local.shape

    node_id = jnp.asarray(plan.node_id)
    slot_mask = jnp.asarray(plan.slot_mask)
    parent_row = jnp.asarray(plan.parent_row)
    stacked = node_id.ndim == 3
    if stacked and node_id.shape[0] != b_coh:
        raise ValueError(f"stacked plan has {node_id.shape[0]} cohorts, "
                         f"inputs {b_coh}")
    L, W = plan.shape[-2:]
    lvl = lambda a, l: a[:, l] if a.ndim == 3 else a[l]   # [B, W] | [W]

    dt = g_local.dtype
    alive = jnp.asarray(plan.alive, dt)
    alive_r = alive[:, r] if alive.ndim == 2 else jnp.broadcast_to(
        alive[r], (b_coh,))
    p_vec = jnp.ones((b_coh,), dt) if participate is None else participate
    p_eff = p_vec * alive_r
    if plan.q_budget is None:
        qb = None
    else:
        qbs = jnp.asarray(plan.q_budget, jnp.int32)
        qb = qbs[:, r] if qbs.ndim == 2 else jnp.broadcast_to(
            qbs[r], (b_coh,))
    compact = _use_compact(cfg, d, plan, participate is not None, wire)
    if wire == "auto" and jnp.dtype(cfg.wire_dtype) != jnp.float32:
        compact = False
    q_wire = _wire_budget(cfg)

    gm = jnp.zeros((b_coh, d), dt) if global_mask is None else global_mask
    lvl_fn = level_step(cfg)

    r_sinks = plan.num_sinks
    buf = jnp.zeros((b_coh, 2 + r_sinks, d), dt)
    e_cur = ef_local
    zero_i = jnp.zeros((b_coh,), jnp.int32)
    my_stats = HopStats(nnz_out=zero_i, nnz_global=zero_i,
                        nnz_local=zero_i,
                        bits=jnp.zeros((b_coh,), jnp.float32),
                        err_sq=jnp.zeros((b_coh,), jnp.float32))

    for l in range(L):
        ids_l = jnp.broadcast_to(lvl(node_id, l), (b_coh, W))
        mask_l = jnp.broadcast_to(lvl(slot_mask, l), (b_coh, W))
        par_l = jnp.broadcast_to(lvl(parent_row, l), (b_coh, W))
        valid = mask_l > 0
        active = jnp.any((ids_l == r) & valid, axis=1)   # [B]

        gamma_out, e_new, st = lvl_fn(g_local, buf[:, 0], e_cur, weight,
                                      p_eff, gm, qb)
        e_cur = jnp.where(active[:, None], e_new, e_cur)
        my_stats = jax.tree.map(
            lambda acc, s: jnp.where(active, s, acc), my_stats, st)

        payload = gamma_out * active[:, None].astype(gamma_out.dtype)
        b_clip = jnp.clip(ids_l, 0, K - 1)               # [B, W]
        if compact:
            vals, idx, _ = jax.vmap(
                lambda x: sp.compact(x, q_wire))(payload)
            all_vals = jax.lax.all_gather(
                vals.astype(jnp.dtype(cfg.wire_dtype)), axis)  # [K, B, q]
            all_idx = jax.lax.all_gather(idx, axis)
            sel = lambda a: jnp.take_along_axis(
                jnp.moveaxis(a, 0, 1), b_clip[:, :, None], axis=1)
            arrived = jax.vmap(jax.vmap(
                lambda v, i: sp.scatter(v.astype(payload.dtype), i, d)))(
                    sel(all_vals), sel(all_idx))         # [B, W, d]
        else:
            all_pay = jax.lax.all_gather(payload, axis)  # [K, B, d]
            arrived = jnp.take_along_axis(
                jnp.moveaxis(all_pay, 0, 1), b_clip[:, :, None], axis=1)
        arrived = arrived * mask_l[:, :, None]
        p_clients = plan.num_clients
        rows = jnp.where(
            valid & (par_l == r), 0,
            jnp.where(valid & (par_l >= p_clients)
                      & (par_l < p_clients + r_sinks),
                      1 + par_l - p_clients,
                      1 + r_sinks)).astype(jnp.int32)    # [B, W]
        # per-cohort slot-ordered scatter-add, same (intentionally
        # mixed-dtype) duplicate combining as the sequential kernel
        buf = jax.vmap(lambda bc, rc, ac: bc.at[rc].add(ac))(
            buf, rows, arrived)

    agg = buf[:, 1] if r_sinks == 1 else buf[:, 1:1 + r_sinks]
    return agg, e_cur, my_stats


def execute_sharded_batched(
    cfg: AggConfig,
    plan: AggPlan,
    grads: Array,                  # [B, K, d] per-cohort client gradients
    e: Array,                      # [B, K, d] EF memories
    weights: Array,                # [B, K]
    *,
    mesh=None,
    global_mask: Optional[Array] = None,   # [B, d]
    participate: Optional[Array] = None,   # [B, K]
    wire: str = "auto",
) -> RoundResult:
    """B cohort rounds on devices as ONE shard_map launch — the device twin
    of :func:`repro.agg.plan.execute_batched`.

    Clients shard one-per-device exactly as :func:`execute_sharded`; the
    cohort axis stays local to every rank, so each level still costs one
    ``all_gather`` however many tenants ride it — this is where the
    multi-tenant throughput win lives. Per cohort, bit-exact to
    ``execute_sharded`` (and hence host ``execute``) on that cohort's
    inputs. Returns a :class:`RoundResult` with a leading cohort axis.
    """
    b, k, d = grads.shape
    if plan.num_clients != k:
        raise ValueError(f"plan has {plan.num_clients} clients, grads {k}")
    if mesh is None:
        mesh = client_mesh(k)
    axis = mesh.axis_names[0]
    from jax.sharding import PartitionSpec as P

    has_part = participate is not None
    part = (jnp.ones((b, k), grads.dtype) if participate is None
            else participate)
    gmask = (jnp.zeros((b, d), grads.dtype) if global_mask is None
             else global_mask)

    wire_fmt = ("compact" if _use_compact(cfg, d, plan, has_part, wire)
                and (wire == "compact"
                     or jnp.dtype(cfg.wire_dtype) == jnp.float32)
                else "dense")

    def body(plan, g_l, e_l, w_l, part_l, gm):
        agg, e_new, st = run_plan_clients_batched(
            cfg, plan, g_l[:, 0], e_l[:, 0], w_l[:, 0], axis=axis,
            global_mask=gm,
            participate=part_l[:, 0] if has_part else None, wire=wire_fmt)
        return agg, e_new[:, None], jax.tree.map(lambda s: s[:, None], st)

    plan_specs = jax.tree.map(lambda _: P(), plan)
    stats_specs = jax.tree.map(lambda _: P(None, axis), HopStats(
        0, 0, 0, 0., 0.))
    agg, e_new, stats = compat.shard_map(
        body, mesh=mesh,
        in_specs=(plan_specs, P(None, axis), P(None, axis), P(None, axis),
                  P(None, axis), P()),
        out_specs=(P(), P(None, axis), stats_specs),
        axis_names={axis},
    )(plan, grads, e, weights, part, gmask)
    return RoundResult(aggregate=agg, e_new=e_new, stats=stats)


# ---------------------------------------------------------------------------
# Nested (staged) plans on the shard_map ring — one mesh axis per stage
# ---------------------------------------------------------------------------

def run_nested_segments_local(
    cfg: AggConfig,
    nested,                           # NestedPlan (repro.agg.nested)
    flat_local: Array,                # [n] this rank's gradient slice
    ef_local: Array,                  # [n] client-tier EF memory
    stage_ef_local,                   # per-stage EF slices, stages ≥ 1:
                                      # stage s is [n // prod(K_0..K_{s-1})]
    weight: Array,                    # scalar D_k (stage-0 fold)
    *,
    axes,                             # one mesh axis name per stage,
                                      # stage-0 axis first (("data","pod"))
    global_mask_local: Optional[Array] = None,   # [n] TCS mask slice
    participate: Optional[Array] = None,         # scalar 0/1 (stage 0)
    transport: str = "auto",          # "auto" | "static" | "butterfly"
    wire: str = "auto",
    stage_cfgs=None,
) -> tuple:
    """Execute a :class:`~repro.agg.nested.NestedPlan` over a multi-axis
    mesh: stage s runs :func:`run_plan_segments_local` on ``axes[s]``.

    Must be called inside shard_map with **every** ``axes[s]`` manual.
    Stage 0 runs each cluster's intra tree concurrently over ``axes[0]``
    (cluster c = the rank group sharing the later-axis coordinates — the
    (pod, data) mesh's pod p holds clients ``p·K_d .. p·K_d+K_d−1``, so
    the plan must be mesh-aligned; checked while the plan is a host
    constant). Stage s ≥ 1 folds the previous stage's owned segment with
    weight 1 and that stage's EF tier over ``axes[s]`` — intra-stage
    ppermutes ride ``axes[0]`` (ICI), inter-stage ppermutes ``axes[1]``
    (DCI), exactly the two-stage hierarchical ring generalized to
    arbitrary per-stage trees.

    Per-pod trees may differ: the stage's clustered arrays travel as
    traced ``[C, L, W]`` leaves, each rank group selects its cluster's
    subplan by mesh index, and transport falls back to the ⌈log₂K⌉
    butterfly (identical clusters keep the static per-slot ppermute — the
    chain×chain nested plan reproduces the historic two-stage rotated
    ring, collective for collective). A ``TopologySchedule`` of nested
    plans therefore compiles to one specialization per padded nested
    shape.

    Returns ``(final segment [n // Πs K_s], new client EF [n],
    tuple of new stage-EF tiers, tuple of per-stage RingStats)``.
    """
    from repro.agg.nested import NestedPlan

    if not isinstance(nested, NestedPlan):
        raise TypeError(f"expected a NestedPlan, got {type(nested)!r}")
    n_stages = nested.num_stages
    axes = tuple(axes)
    if len(axes) != n_stages:
        raise ValueError(f"nested plan has {n_stages} stages but {len(axes)} "
                         f"axes were given")
    cfgs = list(stage_cfgs) if stage_cfgs is not None else [cfg] * n_stages
    if len(cfgs) != n_stages:
        raise ValueError(f"stage_cfgs has {len(cfgs)} entries for "
                         f"{n_stages} stages")
    stage_ef_local = tuple(stage_ef_local)
    if len(stage_ef_local) != n_stages - 1:
        raise ValueError(f"need {n_stages - 1} stage-EF slices, got "
                         f"{len(stage_ef_local)}")
    sizes = [compat.axis_size(a) for a in axes]
    if nested.num_clients != int(np.prod(sizes)):
        raise ValueError(
            f"nested plan has {nested.num_clients} clients but the axes "
            f"{axes!r} provide {int(np.prod(sizes))} ranks")
    if transport not in ("auto", "static", "butterfly"):
        raise ValueError(f"unknown transport {transport!r}")

    # cluster index at stage s = the unit this rank group feeds at stage
    # s+1: u_s = u_{s+1}·K_s + r_s (client k = ... r_{S-1}·K_{S-2}·K_0 +
    # ... + r_0 — later axes are major, matching the (pod, data) dp order)
    cluster_at = [None] * n_stages
    u = jnp.int32(0)
    for s in range(n_stages - 1, -1, -1):
        cluster_at[s] = u
        u = u * sizes[s] + jax.lax.axis_index(axes[s]).astype(jnp.int32)

    cur = flat_local
    cur_mask = global_mask_local
    ef_new = None
    stage_ef_new = []
    stage_stats = []
    for s in range(n_stages):
        last = s == n_stages - 1
        if last:
            plan_s = nested.stages[s]
            tr_s = transport
        else:
            clustered = nested.clustered[s]
            if clustered.num_units != sizes[s]:
                raise ValueError(
                    f"stage {s} clusters have {clustered.num_units} members "
                    f"but axis {axes[s]!r} has {sizes[s]} ranks")
            aligned = clustered.mesh_aligned()
            if aligned is False:
                raise ValueError(
                    f"stage {s} clusters are not mesh-aligned (cluster c "
                    f"must be clients c·{sizes[s]}..c·{sizes[s]}+"
                    f"{sizes[s] - 1}); re-cluster or run on host")
            if transport != "butterfly" and clustered.uniform():
                plan_s = clustered.subplan(0)     # static numpy subplan
                tr_s = transport
            else:
                if transport == "static":
                    raise ValueError(
                        "transport='static' needs identical trace-time-"
                        "constant cluster plans; per-cluster trees route "
                        "through the butterfly")
                plan_s = jax.tree.map(jnp.asarray, clustered).subplan(
                    cluster_at[s])
                tr_s = "butterfly"
        w_s = weight if s == 0 else jnp.float32(1)
        p_s = participate if s == 0 else None
        ef_s = ef_local if s == 0 else stage_ef_local[s - 1]
        seg_out, ef_out, st = run_plan_segments_local(
            cfgs[s], plan_s, cur, ef_s, w_s, axis=axes[s],
            global_mask_local=cur_mask, participate=p_s, transport=tr_s,
            wire=wire)
        if s == 0:
            ef_new = ef_out
        else:
            stage_ef_new.append(ef_out)
        stage_stats.append(st)
        if not last and cur_mask is not None:
            seg = seg_out.shape[0]
            r_s = jax.lax.axis_index(axes[s])
            cur_mask = jax.lax.dynamic_slice(cur_mask, (r_s * seg,), (seg,))
        cur = seg_out
    return cur, ef_new, tuple(stage_ef_new), tuple(stage_stats)


def _pad_plan_clients(plan: AggPlan, k_new: int) -> AggPlan:
    """Grow a stage plan's client count to the mesh size for the
    client-per-rank kernel: the added clients never appear in the level
    schedule (their ranks simply never activate), only the dummy/sink/
    trash row ids shift. jnp ops throughout so traced schedule plans pad
    under jit."""
    k = plan.num_clients
    if k == k_new:
        return plan
    if k > k_new:
        raise ValueError(f"cannot shrink a plan from {k} to {k_new} clients")
    shift = k_new - k
    node_id = jnp.where(jnp.asarray(plan.node_id) == k, k_new,
                        jnp.asarray(plan.node_id))
    par = jnp.asarray(plan.parent_row)
    parent_row = jnp.where(par >= k, par + shift, par)
    pad1 = lambda a, v, dt: jnp.concatenate(
        [jnp.asarray(a, dt), jnp.full((shift,), v, dt)])
    return AggPlan(
        node_id=node_id.astype(jnp.int32),
        slot_mask=jnp.asarray(plan.slot_mask),
        parent_row=parent_row.astype(jnp.int32),
        flat_pos=pad1(plan.flat_pos, 0, jnp.int32),
        alive=pad1(plan.alive, 1.0, jnp.float32),
        q_budget=(None if plan.q_budget is None
                  else pad1(plan.q_budget, 0, jnp.int32)),
        num_clients=k_new, num_sinks=plan.num_sinks)


def execute_nested_sharded(
    cfg: AggConfig,
    nested,                        # NestedPlan
    grads: Array,                  # [K, d] per-client effective gradients
    e: Array,                      # [K, d] client-tier EF memory
    weights: Array,                # [K]    D_k
    *,
    mesh=None,
    stage_e=None,                  # EF tiers for stages ≥ 1 ([K_s, d])
    global_mask: Optional[Array] = None,
    participate: Optional[Array] = None,
    wire: str = "auto",
    stage_cfgs=None,
):
    """One staged round on a client-per-rank mesh — drop-in for host
    :func:`repro.agg.nested.execute_nested` (same ``NestedResult``
    contract, bit-exact per stage: every stage runs
    :func:`run_plan_clients_local`, upper stages on the same mesh with the
    previous stage's replicated sink partials as rank-local gradients —
    ranks beyond a stage's unit count never activate)."""
    from repro.agg.nested import NestedPlan, NestedResult, zero_stage_ef

    if not isinstance(nested, NestedPlan):
        raise TypeError(f"expected a NestedPlan, got {type(nested)!r}")
    k, d = grads.shape
    if nested.num_clients != k:
        raise ValueError(f"nested plan has {nested.num_clients} clients, "
                         f"grads {k}")
    n_stages = nested.num_stages
    cfgs = list(stage_cfgs) if stage_cfgs is not None else [cfg] * n_stages
    if mesh is None:
        mesh = client_mesh(k)
    axis = mesh.axis_names[0]
    from jax.sharding import PartitionSpec as P

    if stage_e is None:
        stage_e = zero_stage_ef(nested, d, grads.dtype)
    stage_e = tuple(stage_e)
    units = nested.stage_units
    # stage EF tiers ride through the mesh padded to one row per rank
    stage_e_pad = tuple(
        jnp.concatenate([se, jnp.zeros((k - units[s + 1],) + se.shape[1:],
                                       se.dtype)])
        if units[s + 1] < k else se
        for s, se in enumerate(stage_e))

    has_part = participate is not None
    part = (jnp.ones((k,), grads.dtype) if participate is None
            else participate)
    gmask = (jnp.zeros((d,), grads.dtype) if global_mask is None
             else global_mask)

    def stage_wire(s, plan):
        use = _use_compact(cfgs[s], d, plan, has_part and s == 0, wire)
        return ("compact" if use and (wire == "compact"
                or jnp.dtype(cfgs[s].wire_dtype) == jnp.float32)
                else "dense")

    wires = [stage_wire(s, nested.stages[s]) for s in range(n_stages)]

    def body(nested, g_l, e_l, w_l, se_l, part_l, gm):
        r = jax.lax.axis_index(axis)
        agg, e_new, st0 = run_plan_clients_local(
            cfgs[0], nested.stages[0], g_l[0], e_l[0], w_l[0], axis=axis,
            global_mask=gm, participate=part_l[0] if has_part else None,
            wire=wires[0])
        prev = agg if nested.stages[0].num_sinks > 1 else agg[None]
        se_new, st_up = [], []
        for s in range(1, n_stages):
            c = units[s]
            plan_s = _pad_plan_clients(nested.stages[s], k)
            g_s = jnp.where(r < c, prev[jnp.clip(r, 0, c - 1)],
                            jnp.zeros((d,), prev.dtype))
            agg, e_s, st_s = run_plan_clients_local(
                cfgs[s], plan_s, g_s, se_l[s - 1][0], jnp.float32(1),
                axis=axis, global_mask=gm, wire=wires[s])
            prev = agg if plan_s.num_sinks > 1 else agg[None]
            se_new.append(e_s[None])
            st_up.append(jax.tree.map(lambda x: x[None], st_s))
        return (prev[0], e_new[None], tuple(se_new),
                jax.tree.map(lambda x: x[None], st0), tuple(st_up))

    nested_specs = jax.tree.map(lambda _: P(), nested)
    stats_spec = jax.tree.map(lambda _: P(axis), HopStats(0, 0, 0, 0., 0.))
    agg, e_new, se_new, st0, st_up = compat.shard_map(
        body, mesh=mesh,
        in_specs=(nested_specs, P(axis), P(axis), P(axis),
                  tuple(P(axis) for _ in stage_e_pad), P(axis), P()),
        out_specs=(P(), P(axis), tuple(P(axis) for _ in stage_e_pad),
                   stats_spec, tuple(stats_spec for _ in stage_e_pad)),
        axis_names={axis},
    )(nested, grads, e, weights, stage_e_pad, part, gmask)
    # drop the rank-padding rows of the upper tiers
    se_new = tuple(se[:units[s + 1]] for s, se in enumerate(se_new))
    st_up = tuple(jax.tree.map(lambda x: x[:units[s + 1]], st)
                  for s, st in enumerate(st_up))
    return NestedResult(aggregate=agg, e_new=e_new, stage_e_new=se_new,
                        stats=st0, stage_stats=st_up)
