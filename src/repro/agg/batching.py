"""Shape-bucket round scheduler — multi-tenant batched aggregation.

Serving many *independent* cohorts (per-region models, per-task adapters,
A/B arms) over one constellation means many concurrent rounds whose
``pallas_call`` + collective launch overhead would otherwise be paid once
per cohort. :class:`RoundScheduler` packs submitted cohort rounds into
**shape buckets** and runs each bucket through one
:func:`repro.agg.plan.execute_batched` launch:

* bucket identity is the jit-specialization structure — client count, sink
  count, ``q_budget`` presence, model dimension and gradient dtype;
* within a bucket, plans of different ``(L, W)`` are re-padded to the
  bucket's **running-max** shape (the ``_PlanCache`` policy of
  :class:`repro.fed.simulator.Simulator`, built on the elementwise-max
  ``common_shape`` rule of :class:`repro.agg.schedule.TopologySchedule`)
  and stacked with :func:`repro.agg.plan.stack_plans` — padding slots are
  bit-exact no-ops, so heterogeneous topologies share one executable;
* the cohort count is padded up to a power of two with zero dummy cohorts,
  so arbitrarily many tenants hit a bounded set of ``[B, ...]`` shapes.

One jit specialization per (bucket, padded shape, padded B) serves every
subsequent round of that bucket — audited by a
:class:`repro.obs.collector.TraceCounter` bumped at trace time
(:meth:`RoundScheduler.assert_bucket_specializations`). Results are
bitwise identical, per cohort, to a sequential ``execute`` call on the
cohort's own (unpadded) plan — except the ``err_sq`` diagnostic, which
the stacked-plan gathers let XLA re-associate (see
:func:`repro.agg.plan.execute_batched`; value leaves and integer §V
counters stay exact).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Hashable, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.agg.plan import (AggPlan, RoundResult, execute_batched,
                            stack_plans)
from repro.core.algorithms import AggConfig
from repro.obs.collector import TraceCounter

Array = jax.Array


@dataclasses.dataclass
class CohortRound:
    """One tenant's round submission: a plan plus its round inputs.

    ``global_mask`` / ``participate`` may be None (zeros / full
    participation — identical to the ``execute`` defaults).
    """

    cohort_id: Hashable
    plan: AggPlan
    grads: Array                         # [K, d]
    e: Array                             # [K, d]
    weights: Array                       # [K]
    global_mask: Optional[Array] = None  # [d]
    participate: Optional[Array] = None  # [K]


def _pow2(n: int) -> int:
    return 1 << max(0, n - 1).bit_length() if n > 1 else 1


class RoundScheduler:
    """Packs heterogeneous cohort rounds into padded shape buckets.

    One scheduler serves one :class:`AggConfig` (the config is a static
    jit argument — cohorts with different algorithms belong to different
    schedulers, which is the same specialization boundary jit itself
    draws).
    """

    def __init__(self, cfg: AggConfig, *,
                 trace_counter: Optional[TraceCounter] = None):
        self.cfg = cfg
        self.trace_counter = trace_counter or TraceCounter()
        self._bucket_shape: Dict[tuple, tuple] = {}   # key → running (L, W)
        self._specs: set = set()            # (key, (L, W), B) launched
        self.bucket_log: List[dict] = []    # one entry per bucket launch

        def _run(plan, grads, e, weights, global_mask, participate):
            self.trace_counter.bump()
            return execute_batched(self.cfg, plan, grads, e, weights,
                                   global_mask=global_mask,
                                   participate=participate)

        self._run = jax.jit(_run)

    # -- bucketing ---------------------------------------------------------

    @staticmethod
    def _bucket_key(r: CohortRound) -> tuple:
        return (r.plan.num_clients, r.plan.num_sinks,
                r.plan.q_budget is not None, r.grads.shape[-1],
                jnp.asarray(r.grads).dtype.name)

    def _bucket(self, rounds: Sequence[CohortRound]) -> Dict[tuple, list]:
        buckets: Dict[tuple, list] = {}
        for r in rounds:
            if np.ndim(r.plan.node_id) != 2:
                raise ValueError("submit unstacked plans; the scheduler "
                                 "stacks buckets itself")
            buckets.setdefault(self._bucket_key(r), []).append(r)
        return buckets

    @property
    def expected_specializations(self) -> int:
        """Distinct (bucket, padded shape, padded B) launches so far —
        the ceiling the trace counter must not exceed."""
        return len(self._specs)

    def assert_bucket_specializations(self):
        """Raise unless jit traced at most once per shape bucket."""
        if self.trace_counter.count > self.expected_specializations:
            raise AssertionError(
                f"batched round path traced {self.trace_counter.count}× "
                f"for {self.expected_specializations} shape bucket(s) — "
                f"a plan/input shape is leaking into new specializations")

    # -- execution ---------------------------------------------------------

    def submit(self, rounds: Sequence[CohortRound]
               ) -> Dict[Hashable, RoundResult]:
        """Run every submitted cohort round; returns per-cohort results.

        Cohorts land in their shape bucket, each bucket runs as ONE
        batched launch, and each cohort's ``RoundResult`` is bitwise what
        a sequential ``execute`` on its own plan would have produced
        (``err_sq`` to float summation order — module doc).
        """
        out: Dict[Hashable, RoundResult] = {}
        for key, members in self._bucket(rounds).items():
            shape = self._grow_shape(key, members)
            b, b_pad = len(members), _pow2(len(members))
            plans = [m.plan.pad(shape) for m in members]
            plans += [plans[-1]] * (b_pad - b)          # dummy cohorts
            plan = stack_plans(plans)

            k, d = members[0].grads.shape
            dt = jnp.asarray(members[0].grads).dtype

            def stack(get, fill, shp, dtype):
                rows = [jnp.asarray(get(m) if get(m) is not None else fill,
                                    dtype) for m in members]
                rows += [jnp.asarray(fill, dtype)] * (b_pad - b)
                return jnp.stack(rows).reshape((b_pad,) + shp)

            # mask/participation are exact 0/1 in any float dtype; weights
            # keep their own dtype so per-cohort bits match sequential
            wdt = jnp.asarray(members[0].weights).dtype
            grads = stack(lambda m: m.grads, jnp.zeros((k, d)), (k, d), dt)
            e = stack(lambda m: m.e, jnp.zeros((k, d)), (k, d), dt)
            weights = stack(lambda m: m.weights, jnp.zeros((k,)), (k,),
                            wdt)
            gmask = stack(lambda m: m.global_mask, jnp.zeros((d,)), (d,),
                          dt)
            part = stack(lambda m: m.participate, jnp.ones((k,)), (k,), dt)

            self._specs.add((key, shape, b_pad))
            self.bucket_log.append(dict(key=key, shape=shape, cohorts=b,
                                        padded_cohorts=b_pad))
            res = self._run(plan, grads, e, weights, gmask, part)
            for i, m in enumerate(members):
                out[m.cohort_id] = jax.tree.map(lambda x: x[i], res)
        return out

    def _grow_shape(self, key: tuple, members: Sequence[CohortRound]
                    ) -> tuple:
        shapes = [m.plan.shape for m in members]
        prev = self._bucket_shape.get(key, (1, 1))
        shape = (max(prev[0], *(s[0] for s in shapes)),
                 max(prev[1], *(s[1] for s in shapes)))
        self._bucket_shape[key] = shape
        return shape
