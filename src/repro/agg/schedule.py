"""Time-varying topologies: a schedule of plans sharing one jit shape.

LEO constellations re-route continuously — the chain the PS sees this round
is not the tree it sees the next (Razmi et al., arXiv:2501.11385 make the
satellite scenario explicitly time-varying). A :class:`TopologySchedule`
compiles a sequence of topologies (explicit graphs/trees, or a base graph
plus link up/down events) into :class:`repro.agg.plan.AggPlan`s padded to a
common ``(L, W)``, so a round loop that swaps plans per round stays inside
**one** jit specialization no matter how often the route changes.
"""

from __future__ import annotations

import dataclasses
from typing import Iterable, Optional, Sequence

import numpy as np

from repro.agg.plan import AggPlan, compile_plan
from repro.topo.graph import ConstellationGraph


def common_shape(plans: Iterable[AggPlan]) -> tuple:
    """Elementwise-max ``(L, W)`` over a set of plans (flat), or the
    elementwise-max per-stage shape signature (nested plans)."""
    plans = list(plans)
    shapes = [p.shape for p in plans]
    if not shapes:
        raise ValueError("no plans")
    if isinstance(shapes[0][0], tuple):        # NestedPlan signatures
        from repro.agg.nested import nested_common_shape
        return nested_common_shape(plans)
    return (max(s[0] for s in shapes), max(s[1] for s in shapes))


@dataclasses.dataclass(frozen=True)
class TopologySchedule:
    """Per-round aggregation plans, padded to one ``(L, W)``.

    ``plan_at(r)`` returns round r's plan: cyclic over the sequence when
    ``cyclic`` (a repeating orbital period), else clamped to the last entry
    (a one-shot event timeline). ``round_index[j]`` names the plan used at
    round j — distinct rounds may share a plan, so an N-round timeline with
    few distinct routes stores each route once.
    """

    plans: tuple                  # tuple[AggPlan, ...], one shape
    round_index: tuple            # per-round index into ``plans``
    cyclic: bool = True
    # optional raw topologies aligned with ``plans`` (AggTree / NestedTopology
    # / None) — the link model :meth:`raw_at` hands the trace collector for
    # crit-path timelines; () when the constructor had nothing to keep
    raws: tuple = ()

    def __post_init__(self):
        if not self.plans:
            raise ValueError("empty schedule")
        if self.raws and len(self.raws) != len(self.plans):
            raise ValueError("raws must align with plans")
        shape = self.plans[0].shape
        k = self.plans[0].num_clients
        budgeted = self.plans[0].q_budget is not None
        for p in self.plans:
            if p.shape != shape or p.num_clients != k:
                raise ValueError(
                    f"schedule plans must share one (L, W) and K; got "
                    f"{p.shape}/{p.num_clients} vs {shape}/{k}")
            if (p.q_budget is not None) != budgeted:
                # a None q_budget changes the plan's pytree structure, and a
                # structure flip between rounds would retrace the jitted
                # round — the recompilation this class exists to prevent
                raise ValueError("schedule plans must either all carry a "
                                 "q_budget or none of them")
        if any(not 0 <= i < len(self.plans) for i in self.round_index):
            raise ValueError("round_index out of range")

    @property
    def shape(self) -> tuple:
        """The shared ``(L, W)`` — one jit specialization for the whole
        schedule."""
        return self.plans[0].shape

    @property
    def num_clients(self) -> int:
        return self.plans[0].num_clients

    def __len__(self) -> int:
        return len(self.round_index)

    def _index_at(self, r: int) -> int:
        n = len(self.round_index)
        j = r % n if self.cyclic else min(r, n - 1)
        return self.round_index[j]

    def plan_at(self, r: int) -> AggPlan:
        return self.plans[self._index_at(r)]

    def raw_at(self, r: int):
        """Round r's raw topology (an :class:`~repro.topo.tree.AggTree`
        carrying the link model, or a ``NestedTopology``), if the
        constructor kept it; None otherwise."""
        return self.raws[self._index_at(r)] if self.raws else None

    # -- constructors -------------------------------------------------------

    @classmethod
    def from_topologies(cls, topologies: Sequence, *,
                        num_clients: Optional[int] = None,
                        q_budgets: Optional[Sequence] = None,
                        round_index: Optional[Sequence] = None,
                        cyclic: bool = True) -> "TopologySchedule":
        """One plan per topology (graph, tree, chain order, int K — or a
        nested topology: a :class:`~repro.agg.nested.NestedPlan`, a routed
        ``NestedTopology``, or a stage spec already compiled), padded to
        the common (per-stage) shape. Flat and nested topologies cannot
        mix in one schedule (their round signatures differ). ``round_index``
        maps rounds onto the topology list (default: one round each) — the
        scenario compiler's store-each-route-once timeline."""
        from repro.agg.nested import NestedPlan, compile_nested
        from repro.agg.plan import as_tree

        if q_budgets is None:
            q_budgets = [None] * len(topologies)

        def build(t, qb):
            if isinstance(t, NestedPlan) or hasattr(t, "nested_stages"):
                raw = t if hasattr(t, "nested_stages") else None
                return compile_nested(t, num_clients=num_clients,
                                      q_budget=qb), raw
            return (compile_plan(t, num_clients=num_clients, q_budget=qb),
                    as_tree(t, num_clients))

        built = [build(t, qb) for t, qb in zip(topologies, q_budgets)]
        plans = [p for p, _ in built]
        raws = tuple(raw for _, raw in built)
        nested = [isinstance(p, NestedPlan) for p in plans]
        if any(nested) and not all(nested):
            raise ValueError("cannot mix flat and nested topologies in one "
                             "schedule")
        shape = common_shape(plans)
        return cls(plans=tuple(p.pad(shape) for p in plans),
                   round_index=(tuple(range(len(plans)))
                                if round_index is None
                                else tuple(int(i) for i in round_index)),
                   cyclic=cyclic, raws=raws)

    @classmethod
    def from_link_events(cls, graph: ConstellationGraph, events: dict, *,
                         rounds: int, routing: str = "latency",
                         cyclic: bool = False) -> "TopologySchedule":
        """A base constellation plus a link up/down timeline.

        ``events[r] = ([down_links], [up_links])`` applied before round r,
        cumulative (a link stays down until an up event restores it); links
        are ``(u, v)`` node pairs. Each distinct down-set is routed and
        compiled once; routing around a lost link re-roots the affected
        subtree, and clients a partition strands become non-participating
        stubs (``plan.alive`` zeros them).
        """
        from repro.topo.routing import route_tree

        down: set = set()
        compiled: dict = {}
        plans: list = []
        raws: list = []
        round_index = []
        for r in range(rounds):
            if r in events:
                downs, ups = events[r]
                down |= {(min(int(u), int(v)), max(int(u), int(v)))
                         for u, v in downs}
                down -= {(min(int(u), int(v)), max(int(u), int(v)))
                         for u, v in ups}
            key = frozenset(down)
            if key not in compiled:
                g = graph.without_links(down) if down else graph
                compiled[key] = len(plans)
                tree = route_tree(g, routing)
                raws.append(tree)
                plans.append(compile_plan(tree))
            round_index.append(compiled[key])
        shape = common_shape(plans)
        return cls(plans=tuple(p.pad(shape) for p in plans),
                   round_index=tuple(round_index), cyclic=cyclic,
                   raws=tuple(raws))
