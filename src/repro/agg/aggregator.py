"""Pytree-aware, topology-polymorphic aggregator object.

:class:`Aggregator` wraps ``compile_plan``/``execute`` with the cross-round
state the five algorithms need (error feedback, TCS reference point) and
pytree plumbing, so callers hand it stacked per-client gradients in any
shape over any topology — chain, permuted chain, or routed tree — and get
back the PS-side aggregate with exact §V bit accounting. It replaces the
chain-only ``ChainAggregator`` (kept in :mod:`repro.core.api` as a
deprecated alias).
"""

from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.flatten_util import ravel_pytree

from repro.agg.plan import AggPlan, RoundResult, compile_plan, execute
from repro.core import tcs as tcs_mod
from repro.core.algorithms import AggConfig, AggKind, HopStats

Array = jax.Array


class AggState(NamedTuple):
    """Cross-round aggregator state (checkpointed as part of TrainState)."""

    ef: Array                        # [K, d] error-feedback memory
    tcs_prev: Optional[Array]        # [d] w^{t-1} (TC algorithms) or None


class RoundOut(NamedTuple):
    aggregate: Any                   # pytree (or flat) — Σ_k D_k g_k estimate
    state: AggState
    stats: HopStats                  # per-hop, leaves [K]
    total_bits: Array                # Σ_k bits — scalar float32


def _needs_tcs(kind: AggKind) -> bool:
    return kind in (AggKind.TC_SIA, AggKind.CL_TC_SIA)


class Aggregator:
    """Multi-hop aggregator for K clients over a d-dim model, on any
    topology.

    ``topology`` accepts whatever ``compile_plan`` does — an ``AggTree``, a
    chain order, a ``ConstellationGraph``, or nothing (the paper's identity
    chain). A precompiled ``plan`` takes precedence; ``round`` also takes a
    per-call ``plan`` so one Aggregator can follow a
    :class:`~repro.agg.schedule.TopologySchedule`.
    """

    def __init__(self, cfg: AggConfig, num_clients: int, dim: int, *,
                 topology: Any = None, plan: Optional[AggPlan] = None):
        self.cfg = cfg
        self.num_clients = num_clients
        self.dim = dim
        if plan is None:
            plan = compile_plan(
                num_clients if topology is None else topology,
                num_clients=num_clients)
        if plan.num_clients != num_clients:
            raise ValueError(f"plan is for {plan.num_clients} clients, "
                             f"aggregator for {num_clients}")
        self.plan = plan

    # -- state ------------------------------------------------------------
    def init_state(self, params: Any = None, dtype=jnp.float32) -> AggState:
        ef = jnp.zeros((self.num_clients, self.dim), dtype)
        tcs_prev = None
        if _needs_tcs(self.cfg.kind):
            if params is None:
                tcs_prev = jnp.zeros((self.dim,), dtype)
            else:
                tcs_prev = ravel_pytree(params)[0].astype(dtype)
        return AggState(ef=ef, tcs_prev=tcs_prev)

    # -- one round ----------------------------------------------------------
    def round(
        self,
        grads: Any,                    # [K, d] array OR list/stacked pytree
        state: AggState,
        weights: Array,                # [K] D_k
        *,
        params: Any = None,            # current params (TC algorithms)
        participate: Optional[Array] = None,
        plan: Optional[AggPlan] = None,
    ) -> RoundOut:
        flat, unravel = _as_flat_stack(grads, self.num_clients, self.dim)

        global_mask = None
        tcs_prev = state.tcs_prev
        if _needs_tcs(self.cfg.kind):
            if params is None:
                raise ValueError(f"{self.cfg.kind} needs current params for "
                                 "the TCS global mask")
            flat_params = ravel_pytree(params)[0].astype(flat.dtype)
            global_mask = tcs_mod.global_mask(
                tcs_mod.TCSState(tcs_prev), flat_params, self.cfg.q_global,
                topq_mask_fn=lambda x, q: self.cfg.topq_mask_fn()(x, q))
            tcs_prev = flat_params

        res: RoundResult = execute(
            self.cfg, self.plan if plan is None else plan,
            flat, state.ef, weights,
            global_mask=global_mask, participate=participate)

        agg = unravel(res.aggregate) if unravel is not None else res.aggregate
        return RoundOut(
            aggregate=agg,
            state=AggState(ef=res.e_new, tcs_prev=tcs_prev),
            stats=res.stats,
            total_bits=jnp.sum(res.stats.bits),
        )


def _as_flat_stack(grads: Any, num_clients: int, dim: int):
    """Accept [K,d] arrays, or a pytree whose leaves have leading dim K."""
    if isinstance(grads, jax.Array) and grads.ndim == 2:
        assert grads.shape == (num_clients, dim), (grads.shape, num_clients, dim)
        return grads, None
    # stacked pytree: vmap ravel over the leading axis
    leaves = jax.tree.leaves(grads)
    assert all(l.shape[0] == num_clients for l in leaves), "leading dim must be K"
    one = jax.tree.map(lambda l: l[0], grads)
    _, unravel = ravel_pytree(one)
    flat = jax.vmap(lambda t: ravel_pytree(t)[0])(grads)
    assert flat.shape == (num_clients, dim)
    return flat, unravel


def flat_dim(params: Any) -> int:
    """Total parameter count d of a pytree (the paper's model dimension)."""
    return int(sum(jnp.size(l) for l in jax.tree.leaves(params)))
